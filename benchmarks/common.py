"""Shared timing/reporting helpers for the benchmark harness."""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

Row = Tuple[str, float, str]   # (name, us_per_call, derived)

# structured payloads (per-scenario verdicts, ...) stashed by bench
# functions and written alongside the CSV rows by run.py --json
EXTRAS: Dict[str, object] = {}


def record_extra(name: str, payload: object):
    EXTRAS[name] = payload


def timed(fn: Callable, *args, repeat: int = 3, **kw) -> Tuple[float, object]:
    """Median wall time (us) of fn(*args) and its last result."""
    best = []
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best.append((time.perf_counter() - t0) * 1e6)
    best.sort()
    return best[len(best) // 2], out


def emit(rows: List[Row]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


def bench_meta() -> Dict[str, object]:
    """Environment stamp for BENCH_*.json: backend / device count / jax
    version, so cross-machine perf trajectories stay interpretable."""
    import platform
    meta: Dict[str, object] = {
        "python": platform.python_version(),
        "platform": platform.platform(),
    }
    try:
        import jax
        devs = jax.devices()
        meta.update(jax_version=jax.__version__,
                    backend=devs[0].platform, n_devices=len(devs))
    except Exception:
        meta.update(jax_version=None, backend=None, n_devices=0)
    try:
        from repro.kernels.backend import use_ufa_kernels
        meta["ufa_kernels"] = bool(use_ufa_kernels())
    except Exception:
        meta["ufa_kernels"] = None
    return meta
