"""Shared timing/reporting helpers for the benchmark harness."""

from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

Row = Tuple[str, float, str]   # (name, us_per_call, derived)

# structured payloads (per-scenario verdicts, ...) stashed by bench
# functions and written alongside the CSV rows by run.py --json
EXTRAS: Dict[str, object] = {}


def record_extra(name: str, payload: object):
    EXTRAS[name] = payload


def timed(fn: Callable, *args, repeat: int = 3, **kw) -> Tuple[float, object]:
    """Median wall time (us) of fn(*args) and its last result."""
    best = []
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best.append((time.perf_counter() - t0) * 1e6)
    best.sort()
    return best[len(best) // 2], out


def emit(rows: List[Row]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
