"""Kernel micro-benchmarks (interpret mode on CPU; TPU is the target).

Times are CPU-interpret wall clock — meaningful for relative comparisons
and regression tracking, not TPU projections; the derived column carries
the analytic FLOP count per call for roofline context.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed


def bench_kernels() -> List[Row]:
    from repro.kernels import ops

    rows: List[Row] = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    B, S, H, d = 1, 512, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, H, d))
    v = jax.random.normal(ks[2], (B, S, H, d))
    fn = lambda: ops.flash_attention(q, k, v, causal=True).block_until_ready()
    fn()  # compile
    us, _ = timed(fn)
    flops = 4 * B * H * S * S * d / 2
    rows.append(("kernel_flash_attention_512", us, f"flops/call={flops:.3e}"))

    KV, K = 2, 2048
    qd = jax.random.normal(ks[3], (B, H, d))
    kc = jax.random.normal(ks[4], (B, K, KV, d))
    vc = jax.random.normal(ks[5], (B, K, KV, d))
    fn = lambda: ops.decode_attention(qd, kc, vc, 1500, 0).block_until_ready()
    fn()
    us, _ = timed(fn)
    rows.append(("kernel_decode_attention_2k", us,
                 f"flops/call={4 * B * H * 1500 * d:.3e}"))

    Hs, P, N = 2, 16, 32
    x = jax.random.normal(ks[6], (B, 256, Hs, P))
    dt = jax.nn.softplus(jax.random.normal(ks[7], (B, 256, Hs)))
    a = -jnp.exp(jnp.linspace(0., 1., Hs))
    b = jax.random.normal(jax.random.PRNGKey(9), (B, 256, 1, N))
    c = jax.random.normal(jax.random.PRNGKey(10), (B, 256, 1, N))
    fn = lambda: ops.ssd_scan(x, dt, a, b, c, chunk=64)[0].block_until_ready()
    fn()
    us, _ = timed(fn)
    rows.append(("kernel_ssd_scan_256", us, f"state={Hs}x{P}x{N}"))

    xg = jax.random.normal(jax.random.PRNGKey(11), (4, 128, 256))
    wg = jax.random.normal(jax.random.PRNGKey(12), (4, 256, 128))
    fn = lambda: ops.grouped_matmul(xg, wg).block_until_ready()
    fn()
    us, _ = timed(fn)
    rows.append(("kernel_grouped_matmul", us,
                 f"flops/call={2 * 4 * 128 * 256 * 128:.3e}"))

    xr = jax.random.normal(jax.random.PRNGKey(13), (512, 1024))
    sc = jnp.ones((1024,))
    fn = lambda: ops.rmsnorm(xr, sc).block_until_ready()
    fn()
    us, _ = timed(fn)
    rows.append(("kernel_rmsnorm", us, "rows=512 d=1024"))
    return rows


def bench_ufa_kernels() -> List[Row]:
    """The three UFA hot-path kernels (``repro.kernels.ufa``), cold and
    warm, at paper-shaped sizes — interpret-mode wall clock on CPU."""
    import numpy as np

    from repro.kernels.ufa.ingest import ingest_hist
    from repro.kernels.ufa.propagation import ell_from_csr, fixed_point_ell
    from repro.kernels.ufa.reduce import timeline_reduce

    rows: List[Row] = []
    rng = np.random.default_rng(0)

    # frontier propagation: 4k services, avg degree ~4, 64-scenario batch
    n = 4096
    m = rng.random((n, n)) < (4.0 / n)
    np.fill_diagonal(m, False)
    src, dst = np.nonzero(m)
    closed = rng.random(len(src)) < 0.5
    indptr = np.searchsorted(src, np.arange(n + 1))
    ed, ec, _ = ell_from_csr(n, indptr, dst, closed)
    dark = jnp.asarray(rng.random((64, n)) < 0.1)
    ed_d, ec_d = jnp.asarray(ed), jnp.asarray(ec)

    def prop():
        b, r = fixed_point_ell(dark, ed_d, ec_d)
        return b.block_until_ready()

    us_cold, _ = timed(prop, repeat=1)
    us, _ = timed(prop)
    rows.append(("kernel_ufa_propagation_cold", us_cold,
                 "includes jit compile"))
    rows.append(("kernel_ufa_propagation", us,
                 f"64x{n} scenarios, {len(src)} edges, K={ed.shape[1]}"))

    # histogram ingest: one 4M-record chunk over a 100k-edge universe
    n_edges, n_rec = 100_000, 4_000_000
    eid = jnp.asarray(rng.integers(0, n_edges, n_rec))
    fl = jnp.asarray(rng.random(n_rec) < 0.3)
    er = jnp.asarray(rng.random(n_rec) < 0.4)

    def ingest():
        return ingest_hist(eid, fl, er, n_edges).block_until_ready()

    us_cold, _ = timed(ingest, repeat=1)
    us, _ = timed(ingest)
    rows.append(("kernel_ufa_ingest_cold", us_cold, "includes jit compile"))
    rows.append(("kernel_ufa_ingest", us,
                 f"{n_rec/1e6:.0f}M records x {n_edges} edges, "
                 f"{n_rec/(us/1e6)/1e6:.1f}M rec/s"))

    # verdict reduction: 4096 scenarios x 240 steps x 3 tiers
    S, T, R = 4096, 240, 3
    a = jnp.asarray(rng.random((S, T), dtype=np.float32))
    fr = jnp.asarray((0.99 + 0.02 * rng.random((S, T, R))
                      ).astype(np.float32))
    ts = jnp.asarray(np.linspace(0.0, 7200.0, T, dtype=np.float32))

    def reduce_():
        out = timeline_reduce(a, a, a, fr, ts, thresh=0.999)
        return out["avail_int"].block_until_ready()

    us_cold, _ = timed(reduce_, repeat=1)
    us, _ = timed(reduce_)
    rows.append(("kernel_ufa_reduce_cold", us_cold, "includes jit compile"))
    rows.append(("kernel_ufa_reduce", us,
                 f"{S}x{T}x{R} series, {S/(us/1e6):,.0f} scen/s"))
    return rows


ALL = [bench_kernels, bench_ufa_kernels]
