"""Kernel micro-benchmarks (interpret mode on CPU; TPU is the target).

Times are CPU-interpret wall clock — meaningful for relative comparisons
and regression tracking, not TPU projections; the derived column carries
the analytic FLOP count per call for roofline context.
"""

from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp

from benchmarks.common import Row, timed


def bench_kernels() -> List[Row]:
    from repro.kernels import ops

    rows: List[Row] = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 8)

    B, S, H, d = 1, 512, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, d))
    k = jax.random.normal(ks[1], (B, S, H, d))
    v = jax.random.normal(ks[2], (B, S, H, d))
    fn = lambda: ops.flash_attention(q, k, v, causal=True).block_until_ready()
    fn()  # compile
    us, _ = timed(fn)
    flops = 4 * B * H * S * S * d / 2
    rows.append(("kernel_flash_attention_512", us, f"flops/call={flops:.3e}"))

    KV, K = 2, 2048
    qd = jax.random.normal(ks[3], (B, H, d))
    kc = jax.random.normal(ks[4], (B, K, KV, d))
    vc = jax.random.normal(ks[5], (B, K, KV, d))
    fn = lambda: ops.decode_attention(qd, kc, vc, 1500, 0).block_until_ready()
    fn()
    us, _ = timed(fn)
    rows.append(("kernel_decode_attention_2k", us,
                 f"flops/call={4 * B * H * 1500 * d:.3e}"))

    Hs, P, N = 2, 16, 32
    x = jax.random.normal(ks[6], (B, 256, Hs, P))
    dt = jax.nn.softplus(jax.random.normal(ks[7], (B, 256, Hs)))
    a = -jnp.exp(jnp.linspace(0., 1., Hs))
    b = jax.random.normal(jax.random.PRNGKey(9), (B, 256, 1, N))
    c = jax.random.normal(jax.random.PRNGKey(10), (B, 256, 1, N))
    fn = lambda: ops.ssd_scan(x, dt, a, b, c, chunk=64)[0].block_until_ready()
    fn()
    us, _ = timed(fn)
    rows.append(("kernel_ssd_scan_256", us, f"state={Hs}x{P}x{N}"))

    xg = jax.random.normal(jax.random.PRNGKey(11), (4, 128, 256))
    wg = jax.random.normal(jax.random.PRNGKey(12), (4, 256, 128))
    fn = lambda: ops.grouped_matmul(xg, wg).block_until_ready()
    fn()
    us, _ = timed(fn)
    rows.append(("kernel_grouped_matmul", us,
                 f"flops/call={2 * 4 * 128 * 256 * 128:.3e}"))

    xr = jax.random.normal(jax.random.PRNGKey(13), (512, 1024))
    sc = jnp.ones((1024,))
    fn = lambda: ops.rmsnorm(xr, sc).block_until_ready()
    fn()
    us, _ = timed(fn)
    rows.append(("kernel_rmsnorm", us, "rows=512 d=1024"))
    return rows


ALL = [bench_kernels]
