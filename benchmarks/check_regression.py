"""Benchmark-regression guard: compare a fresh ``run.py --quick --json``
run against the committed baseline ``BENCH_*.json`` and fail on a >Nx
slowdown of any shared row.

The baseline is auto-picked as the highest-numbered ``BENCH_<n>.json`` in
the repo root that is not the fresh file itself — in CI the fresh run
overwrites the committed ``BENCH_<latest>.json`` in the workspace, so the
guard naturally compares against the previous PR's committed snapshot.

Rows are matched by name.  Sub-``--min-us`` fresh rows are ignored (they
are dispatch-overhead noise, not regressions), as are rows that exist on
only one side (new/retired benchmarks) and ``*_cold`` rows (first-call
compile time — tracked in the JSON for the trajectory, but XLA compile
latency is too machine/cache-sensitive to gate on; ``--include-cold``
restores them).  A fresh row that *errored* (``us_per_call`` null)
always fails.

Caveat: the committed baseline was produced on the author's machine, so
the ratio folds in machine-speed differences, not just code changes — the
2x default factor leaves headroom for a CI runner of roughly comparable
per-core speed, and ``--factor`` is the knob if a runner class proves
systematically slower.  A same-runner baseline (cached artifact from the
previous main build) would be tighter; the committed file keeps the guard
dependency-free and the trajectory reviewable in-repo.

  python benchmarks/check_regression.py BENCH_3.json
  python benchmarks/check_regression.py fresh.json --baseline BENCH_2.json
"""

from __future__ import annotations

import argparse
import json
import re
import sys
from pathlib import Path


def pick_baseline(root: Path, fresh: Path) -> Path:
    cands = []
    for p in root.glob("BENCH_*.json"):
        m = re.fullmatch(r"BENCH_(\d+)\.json", p.name)
        if m and p.resolve() != fresh.resolve():
            cands.append((int(m.group(1)), p))
    if not cands:
        raise SystemExit(f"no baseline BENCH_<n>.json found in {root}")
    return max(cands)[1]


def load_rows(path: Path):
    payload = json.loads(path.read_text())
    return {r["name"]: r["us_per_call"] for r in payload.get("rows", [])}, \
        payload.get("failures", 0)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh", type=Path,
                    help="fresh run.py --quick --json output")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline JSON (default: highest committed "
                         "BENCH_<n>.json that isn't the fresh file)")
    ap.add_argument("--factor", type=float, default=2.0,
                    help="fail on fresh > factor * baseline (default 2x)")
    ap.add_argument("--min-us", type=float, default=5_000.0,
                    help="ignore fresh rows faster than this (noise floor)")
    ap.add_argument("--include-cold", action="store_true",
                    help="also gate *_cold (compile-time) rows")
    args = ap.parse_args()

    baseline = args.baseline or pick_baseline(args.fresh.resolve().parent,
                                              args.fresh)
    fresh_rows, fresh_failures = load_rows(args.fresh)
    base_rows, _ = load_rows(baseline)
    print(f"regression guard: {args.fresh} vs baseline {baseline} "
          f"(factor {args.factor}x, noise floor {args.min_us:.0f}us)")

    violations = []
    errors = [n for n, us in fresh_rows.items() if us is None]
    for name, us in sorted(fresh_rows.items()):
        base = base_rows.get(name)
        if us is None or base is None or base <= 0:
            continue
        if us < args.min_us:
            continue
        if name.endswith("_cold") and not args.include_cold:
            continue
        ratio = us / base
        marker = " <-- REGRESSION" if ratio > args.factor else ""
        if ratio > args.factor or ratio < 1 / args.factor:
            # print every big mover (speedups too: the perf trajectory)
            print(f"  {name:42s} {base/1e3:10.1f}ms -> {us/1e3:10.1f}ms "
                  f"({ratio:5.2f}x){marker}")
        if ratio > args.factor:
            violations.append((name, base, us, ratio))

    # rows on only one side are informational, never gated: new kernels /
    # benches enter the trajectory here, retired ones leave it
    new_only = sorted(n for n in fresh_rows if n not in base_rows)
    retired = sorted(n for n in base_rows if n not in fresh_rows)
    if new_only:
        print(f"info: {len(new_only)} new row(s) not in baseline "
              f"(not gated): {', '.join(new_only)}")
    if retired:
        print(f"info: {len(retired)} baseline row(s) retired: "
              f"{', '.join(retired)}")

    ok = True
    if errors:
        print(f"FAIL: {len(errors)} errored row(s): {', '.join(errors)}")
        ok = False
    if fresh_failures:
        print(f"FAIL: fresh run recorded {fresh_failures} bench failure(s)")
        ok = False
    if violations:
        print(f"FAIL: {len(violations)} row(s) regressed more than "
              f"{args.factor}x vs {baseline.name}")
        ok = False
    if ok:
        shared = sum(1 for n in fresh_rows if n in base_rows)
        print(f"OK: {shared} shared rows within {args.factor}x of baseline")
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
