"""One benchmark per paper table/figure (UFA, CS.DC 2026).

Each function reproduces the table/figure's quantity from this repo's
implementation and returns CSV rows (name, us_per_call, derived) where
``derived`` carries the reproduced numbers next to the paper's claims.

``fleet_scale`` and ``scenario_sweep`` exercise the vectorized FleetState
engine: full paper scale (~22k service-environments) and a vmapped
scenario ensemble with per-scenario SLA verdicts (recorded into the
benchmark JSON via ``record_extra``).
"""

from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row, record_extra, timed

PAPER_SCALE = 0.05          # fleet synthesized at 5% of Uber's service count
SEED = 7


def _fleet(remediated: bool = True):
    from repro.core.drills import remediate
    from repro.core.service import synthesize_fleet, unsafe_edges
    fleet = synthesize_fleet(scale=PAPER_SCALE, seed=SEED)
    if remediated:
        remediate(fleet, set(unsafe_edges(fleet)))
    return fleet


def bench_table1_tiers() -> List[Row]:
    """Table 1: per-tier baseline core counts."""
    from repro.core.service import fleet_cores, synthesize_fleet
    from repro.core.tiers import BASELINE_CORES

    us, fleet = timed(synthesize_fleet, PAPER_SCALE, SEED)
    cores = fleet_cores(fleet)
    # fleet carries per-region demand = alloc * 0.25 (see service.py)
    errs = []
    for t, c in cores.items():
        target = BASELINE_CORES[t] * PAPER_SCALE * 0.25
        errs.append(abs(c - target) / max(1.0, target))
    derived = (f"tiers=7 total_demand={sum(cores.values()):,.0f} "
               f"max_tier_err={max(errs):.3f} (target shape: Table 1 x "
               f"{PAPER_SCALE} scale x 0.25 demand)")
    return [("table1_tier_capacity", us, derived)]


def bench_table2_rpc_matrix() -> List[Row]:
    """Table 2: cross-tier RPC volume shape + ~50% tier-inverted traffic.
    Array-native trace sampling: one vectorized draw returning
    (edge_id, callee_failed, caller_errored) arrays."""
    from repro.core.dependency import sample_traces, trace_edges

    fleet = _fleet()
    edges = trace_edges(fleet, seed=SEED)
    n = 4_000_000
    us, (eid, _, _) = timed(sample_traces, edges, n, SEED)
    down = edges.callee_tier[eid] > edges.caller_tier[eid]
    frac = float(down.mean())
    rate = n / max(1e-9, us / 1e6)
    derived = (f"rpcs={n} sampled_at={rate:,.0f}/s "
               f"to_lower_tier={frac:.2f} (paper: ~0.5 of 62T/wk)")
    return [("table2_rpc_matrix", us, derived)]


def bench_table4_failover_classes() -> List[Row]:
    """Table 4: per-failure-class behavior and RTO during a peak failover."""
    from repro.core.capacity import RegionCapacity
    from repro.core.omg import Orchestrator

    fleet = _fleet()

    def run():
        region = RegionCapacity.for_fleet("bench", fleet)
        orch = Orchestrator(fleet, region, scale=PAPER_SCALE)
        rep = orch.failover(tv_failover=1.0)
        return orch, rep

    us, (orch, rep) = timed(run, repeat=1)
    derived = (f"always_on=uninterrupted({rep.always_on_ok}) "
               f"active_migrate=0s_downtime(MBB,window={rep.am_migrated_at_s:.0f}s) "
               f"restore_later={rep.rl_restored_at_s:.0f}s(rto_1h_met={rep.rl_rto_met}) "
               f"terminate=down_until_failback (paper Table 4: secs/secs/1hr/none)")
    return [("table4_failover_classes", us, derived)]


def bench_table5_phased_rollout() -> List[Row]:
    """Table 5: phased cores returned via readiness reviews."""
    from repro.core.metrics import phased_rollout

    us, r = timed(phased_rollout)
    derived = (f"total_returned={r['total_returned']:,} "
               f"bbm={r['bbm_cores']:,}({r['bbm_fraction']:.0%}) "
               f"mbb={r['mbb_cores']:,}({r['mbb_fraction']:.0%}) "
               f"(paper: 1.025M at 54/46; Table 5 classes sum to 484K BBM "
               f"- the 66K delta sits in partially-BBM AM phases)")
    return [("table5_phased_cores", us, derived)]


def bench_table6_failclose() -> List[Row]:
    """Table 6: fail-close violations found by runtime vs static analysis."""
    from repro.core.dependency import runtime_analysis
    from repro.core.service import synthesize_fleet, unsafe_edges
    from repro.core.static_analysis import static_analysis

    fleet = synthesize_fleet(scale=0.15, seed=SEED,
                             unsafe_fraction=0.10)  # un-remediated
    us_rt, ra = timed(runtime_analysis, fleet, None, SEED, repeat=1)
    us_st, sa = timed(static_analysis, fleet, SEED, repeat=1)
    truth = set(unsafe_edges(fleet))
    static_extra = (sa["found"] - ra["found"]) & truth
    combined = (ra["found"] | sa["found"]) & truth
    rt_share = len(ra["found"] & truth) / max(1, len(combined))
    rate = ra["n_records"] / max(1e-9, us_rt / 1e6)
    derived = (f"total={len(truth)} runtime={len(ra['found'] & truth)} "
               f"static_extra={len(static_extra)} "
               f"runtime_share={rt_share:.2f} combined_recall="
               f"{len(combined)/max(1,len(truth)):.2f} "
               f"records={ra['n_records']} at {rate:,.0f}/s "
               f"(paper: 4155 total = 3041 runtime 73% + 1114 static)")
    return [("table6_runtime_analysis", us_rt, derived),
            ("table6_static_analysis", us_st,
             f"precision={sa['precision']:.2f} recall={sa['recall']:.2f}")]


def bench_fig2_3_failover_history() -> List[Row]:
    """Figs 2/3: failover minutes fraction + yearly counts."""
    from repro.core.metrics import (failover_counts_history,
                                    failover_minutes_history)

    us, mins = timed(failover_minutes_history)
    counts = failover_counts_history()
    avg_hours = sum(mins.values()) / len(mins) / 60.0
    worst_frac = max(mins.values()) / (365 * 24 * 60)
    derived = (f"avg_full_peak_hours_per_year={avg_hours:.1f} "
               f"worst_year_fraction={worst_frac:.4f} counts={list(counts.values())} "
               f"(paper: <20h/yr avg, 0.23% at the 2021 anomaly, declining)")
    return [("fig2_3_failover_history", us, derived)]


def bench_fig7_burst_conversion() -> List[Row]:
    """Fig 7: batch->burst conversion speed (paper: full in ~8 min;
    240K cores / 2,000 hosts < 20 min)."""
    from repro.core.capacity import RegionCapacity
    from repro.core.omg import Orchestrator

    fleet = _fleet()

    def run():
        region = RegionCapacity.for_fleet("bench", fleet)
        orch = Orchestrator(fleet, region, scale=PAPER_SCALE)
        rep = orch.failover(tv_failover=1.0)
        return region, rep

    us, (region, rep) = timed(run, repeat=1)
    rate_cores_per_s = region.batch.convertible_cores / max(
        1.0, rep.burst_full_at_s - (Orchestrator.BATCH_EVICT_S
                                    + Orchestrator.PREFETCH_S))
    # paper-scale equivalent: 0.25 cores/host/s * 2000 hosts
    paper_20min_ok = (240_000 / (0.25 * 2000)) / 60 < 20
    derived = (f"burst_full_min={rep.burst_full_at_s/60:.1f} "
               f"spawn_rate={rate_cores_per_s:,.0f}cores/s "
               f"paper_scale_240k_under_20min={paper_20min_ok} "
               f"(paper: ~8 min full)")
    return [("fig7_burst_conversion", us, derived)]


def bench_fig8_availability() -> List[Row]:
    """Fig 8: availability holds at 99.97% through failover+failback."""
    from repro.core.capacity import RegionCapacity
    from repro.core.metrics import availability_during_failover
    from repro.core.omg import Orchestrator

    fleet = _fleet(remediated=True)

    def run():
        region = RegionCapacity.for_fleet("bench", fleet)
        orch = Orchestrator(fleet, region, scale=PAPER_SCALE)
        orch.failover(tv_failover=1.0)
        series = availability_during_failover(fleet, orch)
        orch.failback()
        return series

    us, series = timed(run, repeat=1)
    mn = min(a for _, a in series)
    avg = sum(a for _, a in series) / len(series)
    derived = (f"min_availability={mn:.4f} avg={avg:.4f} "
               f"(paper: 99.97% held throughout)")
    return [("fig8_availability", us, derived)]


def bench_fig9_container_conversion() -> List[Row]:
    """Fig 9: container class counts through failover/failback."""
    from repro.core.capacity import RegionCapacity
    from repro.core.omg import Orchestrator
    from repro.core.tiers import FailureClass

    fleet = _fleet()

    def run():
        region = RegionCapacity.for_fleet("bench", fleet)
        orch = Orchestrator(fleet, region, scale=PAPER_SCALE)
        rep = orch.failover(tv_failover=1.0)
        am_b = orch.class_envs(FailureClass.ACTIVE_MIGRATE, "burst")
        rl_b = (orch.class_envs(FailureClass.RESTORE_LATER, "burst")
                + orch.class_envs(FailureClass.RESTORE_LATER, "cloud"))
        term_down = sum(1 for s in orch.se.values()
                        if s.spec.failure_class == FailureClass.TERMINATE
                        and s.placement == "down")
        orch.failback()
        restored = sum(1 for s in orch.se.values() if s.placement == "steady")
        return am_b, rl_b, term_down, restored, len(orch.se)

    us, (am_b, rl_b, term_down, restored, total) = timed(run, repeat=1)
    derived = (f"am_bursted={am_b} rl_bursted={rl_b} "
               f"terminate_down_during_failover={term_down} "
               f"restored_after_failback={restored}/{total} "
               f"(paper Fig 9 shape: AM converts ~15min, RL restores, "
               f"Terminate stays down, all back at failback)")
    return [("fig9_container_conversion", us, derived)]


def bench_fig10_region_utilization() -> List[Row]:
    """Fig 10: surviving-region utilization peaks ~50.2%, within safety."""
    from repro.core.capacity import RegionCapacity
    from repro.core.metrics import regional_utilization_series
    from repro.core.omg import Orchestrator

    fleet = _fleet()

    def run():
        region = RegionCapacity.for_fleet("bench", fleet)
        orch = Orchestrator(fleet, region, scale=PAPER_SCALE)
        orch.failover(tv_failover=1.0)
        return regional_utilization_series(orch)

    us, series = timed(run, repeat=1)
    peak = max(u for _, u in series)
    steady = series[0][1]
    derived = (f"steady_util={steady:.3f} failover_peak_util={peak:.3f} "
               f"under_75pct_threshold={peak < 0.75} (paper: 50.2% peak)")
    return [("fig10_region_utilization", us, derived)]


def bench_fig11_fleet_utilization() -> List[Row]:
    """Fig 11: fleet utilization 20% -> ~31% while returning 1.025M cores."""
    from repro.core.metrics import phased_rollout

    us, r = timed(phased_rollout)
    derived = (f"utilization {0.20:.0%} -> {r['final_utilization']:.1%} "
               f"provisioning {r['provisioning_multiple_before']:.1f}x -> "
               f"{r['provisioning_multiple_after']:.2f}x "
               f"(paper: 20%->31%, 2x->1.5x attained, 1.3x goal)")
    return [("fig11_fleet_utilization", us, derived)]


def bench_eviction_rates() -> List[Row]:
    """§8 eviction analysis: 312/hr failover peak vs 160/hr baseline peak."""
    from repro.core.eviction import failover_eviction_trace

    us, t = timed(failover_eviction_trace, repeat=1)
    derived = (f"failover_peak={t['peak']}/hr baseline_peak={t['baseline_peak']}/hr "
               f"ratio={t['peak_over_baseline']:.2f} (paper: 312 vs 160, ~2x)")
    return [("eviction_rates", us, derived)]


def bench_overcommit() -> List[Row]:
    """§4.4: O_max = 1.66x analytic; simulator recommends 1.5x."""
    from repro.core.overcommit_sim import recommend_factor
    from repro.core.tiers import o_max

    us, r = timed(recommend_factor, repeat=1)
    assert r["safe"], "default config must yield a certified-safe factor"
    derived = (f"o_max={o_max():.2f} recommended={r['recommended']} "
               f"safe={r['safe']} (paper: O_max=1.66, "
               f"simulator-recommended 1.5)")
    return [("overcommit_simulator", us, derived)]


def bench_canary_gate() -> List[Row]:
    """§6: canary gate over a 45-day window of ~8k deployments/week."""
    from repro.core.canary import CanaryRegressionGate

    fleet = _fleet()
    gate = CanaryRegressionGate(fleet, seed=11)
    us, w = timed(gate.run_window, 8000 * 6, repeat=1)
    derived = (f"deployments={w['deployments']} caught={w['regressions_caught']} "
               f"shipped={w['regressions_shipped']} (paper: ~3 caught/45d, 0 shipped)")
    return [("canary_gate", us, derived)]


def bench_fleet_scale() -> List[Row]:
    """Paper scale: ~22k service-environments (Table 3) synthesize and run
    a full peak failover on the vectorized FleetState engine."""
    from repro.core.capacity import RegionCapacity, provisioning_multiple
    from repro.core.drills import certify_fleet_state
    from repro.core.omg import Orchestrator
    from repro.core.service import synthesize_fleet

    def synth():
        fs = synthesize_fleet(scale=1.0, seed=SEED, as_arrays=True)
        fs.apply_ufa_target_classes()
        return fs

    us_synth, fs = timed(synth, repeat=1)

    def run():
        region = RegionCapacity.for_fleet("paper-scale", fs)
        orch = Orchestrator(fs, region, scale=1.0)
        rep = orch.failover(tv_failover=1.0)
        orch.failback()
        return region, rep

    us_fo, (region, rep) = timed(run, repeat=1)
    cert = certify_fleet_state(fs, seed=SEED)
    total = float(fs.spec_cores.sum())
    mult = provisioning_multiple(2 * total, region.steady.physical_cores)
    under_30s = (us_synth + us_fo) / 1e6 < 30.0
    derived = (f"services={fs.n} edges={fs.edges.n} "
               f"synth+failover_s={(us_synth + us_fo)/1e6:.2f} "
               f"under_30s={under_30s} ufa_mult={mult:.2f} "
               f"ao_ok={rep.always_on_ok} rl_rto={rep.rl_rto_met} "
               f"drill_flagged={cert['n_flagged']}/{cert['n_critical']} "
               f"(paper: 22k SEs, 2x->1.3x goal)")
    return [("fleet_scale_synthesis", us_synth,
             f"services={fs.n} array-native path"),
            ("fleet_scale_failover", us_fo, derived)]


def bench_scenario_sweep() -> List[Row]:
    """Scenario-ensemble driver: >= 256 failover variants (traffic mult x
    preheat delay x burst availability x cloud quota) in one vmapped
    sweep; per-scenario SLA verdicts land in the benchmark JSON."""
    from repro.core.scenarios import (FleetAggregates, scenario_grid,
                                      scenario_records, summarize_sweep,
                                      sweep_scenarios)
    from repro.core.service import synthesize_fleet

    fs = synthesize_fleet(scale=1.0, seed=SEED, as_arrays=True)
    fs.apply_ufa_target_classes()
    agg = FleetAggregates.from_fleet_state(fs)
    grid = scenario_grid()
    # compile (cold) vs steady-state (warm) reported as separate rows:
    # the first call pays tracing+XLA compilation, the warm row is the
    # per-sweep marginal cost the ensembles actually run at
    us_cold, _ = timed(sweep_scenarios, agg, grid, repeat=1)
    us, res = timed(sweep_scenarios, agg, grid, repeat=3)
    s = summarize_sweep(res)
    record_extra("scenario_sweep", {"summary": s,
                                    "cold_us": us_cold, "warm_us": us,
                                    "scenarios": scenario_records(res)})
    derived = (f"scenarios={s['n_scenarios']} sla_ok={s['n_sla_ok']} "
               f"avail_min={s['availability_min']:.4f} "
               f"avail_mean={s['availability_mean']:.4f} "
               f"worst_rl_min={s['worst_rl_done_min']:.1f} "
               f"(ensemble certification, Basiri-style)")
    return [("scenario_sweep_cold", us_cold,
             f"first call, includes jit compile"),
            ("scenario_sweep_vmap", us, derived)]


def bench_runtime_detection_scale() -> List[Row]:
    """Paper-scale runtime layer acceptance: the array-native telemetry
    engine samples + ingests ~48M RPCs (default ~400 obs/edge over ~120k
    edges, the regime of the paper's 62T RPCs/week) and detects fail-close
    edges end to end at scale=1.0.  Asserts >10M records/s sustained
    through generation+ingest and single-digit-second end-to-end
    detection."""
    from repro.core.dependency import runtime_analysis
    from repro.core.service import synthesize_fleet

    fs = synthesize_fleet(scale=1.0, seed=SEED, as_arrays=True,
                          unsafe_fraction=0.10)
    us, ra = timed(runtime_analysis, fs, None, SEED, repeat=1)
    total_s = us / 1e6
    rate = ra["records_per_s"]
    assert rate > 10e6, f"gen+ingest {rate:,.0f} rec/s (need >10M/s)"
    assert total_s < 10.0, f"end-to-end {total_s:.1f}s (need <10s)"
    record_extra("runtime_detection_scale", {
        "services": fs.n, "edges": fs.edges.n,
        "n_records": ra["n_records"],
        "gen_ingest_s": ra["gen_ingest_s"],
        "records_per_s": rate,
        "end_to_end_s": total_s,
        "precision": ra["precision"], "recall": ra["recall"],
        "missed": ra["missed"], "missed_cold": ra["missed_cold"],
    })
    derived = (f"backend=cpu-numpy-fused services={fs.n} "
               f"edges={fs.edges.n} records={ra['n_records']/1e6:.1f}M "
               f"gen+ingest={rate/1e6:.1f}M/s end_to_end_s={total_s:.2f} "
               f"precision={ra['precision']:.2f} recall={ra['recall']:.2f} "
               f"missed_cold={ra['missed_cold']}/{ra['missed']} "
               f"(acceptance: >10M rec/s, <10s at scale=1.0)")
    rows = [("runtime_detection_scale", us, derived)]

    # backend-labelled ingest rows: the same chunk through the fused
    # single-pass host bincount (the CPU production path behind
    # ``ingest_batch``) and the Pallas scatter-add histogram kernel in
    # interpret mode (the accelerator path; interpret wall clock tracks
    # the trajectory, it is not a device projection)
    import jax.numpy as jnp

    from repro.kernels.ufa.ingest import ingest_hist

    rng = np.random.default_rng(SEED)
    n_edges = fs.edges.n
    n_rec = 4_000_000
    eid = rng.integers(0, n_edges, n_rec)
    code = ((rng.random(n_rec) < 0.3).astype(np.uint8) << 1) \
        | (rng.random(n_rec) < 0.4)

    def numpy_fused():
        return np.bincount(eid.astype(np.int32) * 4 + code,
                           minlength=4 * n_edges).reshape(-1, 4)

    us_np, counts_np = timed(numpy_fused, repeat=3)
    rows.append(("runtime_ingest_fused_numpy", us_np,
                 f"backend=cpu {n_rec/1e6:.0f}M records x {n_edges} edges, "
                 f"{n_rec/(us_np/1e6)/1e6:.1f}M rec/s"))

    eid_d = jnp.asarray(eid)
    failed_d = jnp.asarray(code >= 2)
    errored_d = jnp.asarray((code & 1).astype(bool))

    def pallas_ingest():
        return np.asarray(ingest_hist(eid_d, failed_d, errored_d, n_edges,
                                      interpret=True))

    us_cold, _ = timed(pallas_ingest, repeat=1)
    us_warm, counts_pl = timed(pallas_ingest, repeat=3)
    assert np.array_equal(counts_pl, counts_np)       # exact, both paths
    rows.append(("runtime_ingest_pallas_interp_cold", us_cold,
                 "backend=cpu-interpret, includes jit compile"))
    rows.append(("runtime_ingest_pallas_interp", us_warm,
                 f"backend=cpu-interpret {n_rec/1e6:.0f}M records, "
                 f"{n_rec/(us_warm/1e6)/1e6:.1f}M rec/s, bit-equal to "
                 f"the numpy path"))
    return rows


def bench_graph_propagation() -> List[Row]:
    """Graph engine acceptance: full-fleet multi-hop blackhole
    certification at paper scale (~22k SEs, with relay chains) PLUS a
    256-scenario vmapped blackhole ensemble in < 5 s on CPU; then the
    greedy hardening planner runs the fleet to certified."""
    from repro.core.fleet_state import synthesize_fleet_state
    from repro.graph import (CallGraph, blackhole_ensemble, certify,
                             plan_hardening)

    fs = synthesize_fleet_state(scale=1.0, seed=SEED,
                                unsafe_chain_fraction=0.05)
    graph = CallGraph.from_fleet_state(fs)

    def cert_plus_ensemble():
        cert = certify(graph)
        ens = blackhole_ensemble(graph, n_scenarios=256, seed=SEED)
        return cert, ens

    # first call in this process; earlier benches may already have
    # compiled the (1, n) certify shape, so this is an upper bound on the
    # warm path and a lower bound on a truly fresh-process cold start —
    # the ensemble's (256, n) shape does compile here
    us_cert, (cert, ens) = timed(cert_plus_ensemble, repeat=1)
    us_warm, _ = timed(cert_plus_ensemble, repeat=3)
    under_5s = us_cert / 1e6 < 5.0
    us_plan, plan = timed(plan_hardening, graph, repeat=1)
    record_extra("graph_propagation", {
        "services": graph.n, "edges": graph.n_edges,
        "unsafe_edges": graph.n_unsafe,
        "broken_critical": cert.n_broken_critical,
        "multi_hop_only": int(cert.multi_hop.sum()),
        "propagation_rounds": cert.rounds,
        "first_call_cert_plus_256_ensemble_s": us_cert / 1e6,
        "warm_cert_plus_256_ensemble_s": us_warm / 1e6,
        "under_5s": under_5s,
        "ensemble_ok_fraction": float(ens["ok"].mean()),
        "hardened_edges": plan.n_hardened,
        "planner_rounds": plan.rounds,
        "planner_certified": plan.certified,
        "hardening_trajectory": plan.trajectory,
    })
    derived = (f"services={graph.n} edges={graph.n_edges} "
               f"unsafe={graph.n_unsafe} broken_crit={cert.n_broken_critical} "
               f"multi_hop={int(cert.multi_hop.sum())} "
               f"rounds={cert.rounds} first_call_s={us_cert/1e6:.2f} "
               f"under_5s={under_5s} (acceptance: cert + 256-ensemble < 5s)")
    derived_plan = (f"hardened={plan.n_hardened} rounds={plan.rounds} "
                    f"certified={plan.certified} "
                    f"(paper: 4,000+ hardened before dropping the 2x buffer)")
    return [("graph_certify_plus_ensemble", us_cert, derived),
            ("graph_certify_plus_ensemble_warm", us_warm,
             f"warm path, jit cached"),
            ("graph_hardening_planner", us_plan, derived_plan)]


def bench_timeline_ensemble() -> List[Row]:
    """Temporal-drill acceptance: the discrete-time failover kernel
    (lax.scan over 240 steps x vmap over 256 scenarios) runs a full-peak
    temporal ensemble for the paper-scale fleet in < 5 s on CPU,
    including compilation — per-scenario time-to-restore per tier,
    availability integral vs the 99.97% SLA, and peak on-demand draw."""
    from repro.core.capacity import RegionCapacity
    from repro.core.omg import Orchestrator
    from repro.core.scenarios import operating_point_mask, scenario_grid
    from repro.core.service import synthesize_fleet
    from repro.core.timeline_sim import (default_ts,
                                         summarize_timeline_sweep,
                                         sweep_timeline)

    fs = synthesize_fleet(scale=1.0, seed=SEED, as_arrays=True)
    fs.apply_ufa_target_classes()
    region = RegionCapacity.for_fleet("timeline", fs)
    orch = Orchestrator(fs, region, scale=1.0)
    cfg = orch.timeline_config()
    grid = scenario_grid()
    ts = default_ts(7200.0, 240)

    us_cold, res = timed(sweep_timeline, cfg, grid, ts, repeat=1)
    under_5s = us_cold / 1e6 < 5.0
    assert under_5s, (f"temporal ensemble first call {us_cold/1e6:.1f}s "
                      f"(acceptance: 256x240 < 5s)")
    us_warm, res = timed(sweep_timeline, cfg, grid, ts, repeat=3)
    s = summarize_timeline_sweep(res)
    # temporal vs event-loop cross-check: the orchestrator's single
    # trajectory must agree with the kernel's operating-point scenario
    rep = orch.failover(tv_failover=1.0)
    op = operating_point_mask(grid)
    op_rl_done = float(res["rl_done_s"][op][0])
    agree = abs(op_rl_done - rep.rl_restored_at_s) <= max(
        60.0, 0.05 * rep.rl_restored_at_s)
    assert agree, (f"kernel op-point rl_done {op_rl_done:.0f}s vs "
                   f"orchestrator {rep.rl_restored_at_s:.0f}s")
    record_extra("timeline_ensemble", {
        "scenarios": s["n_scenarios"], "steps": len(ts),
        "first_call_s": us_cold / 1e6, "warm_s": us_warm / 1e6,
        "under_5s": under_5s, "summary": s,
        "orchestrator_rl_done_s": rep.rl_restored_at_s,
        "kernel_op_rl_done_s": op_rl_done,
        "orchestrator_agreement": agree,
    })
    derived = (f"scenarios={s['n_scenarios']}x{len(ts)}steps "
               f"first_call_s={us_cold/1e6:.2f} under_5s={under_5s} "
               f"sla_ok={s['n_sla_ok']} rl_stranded={s['n_rl_never_restored']} "
               f"avail_floor={s['availability_floor']:.4f} "
               f"peak_cloud={s['peak_cloud_cores_max']:,.0f} "
               f"orch_agree={agree} (acceptance: 256x240 temporal "
               f"ensemble < 5s)")
    return [("timeline_ensemble", us_cold, derived),
            ("timeline_ensemble_warm", us_warm,
             f"warm path, jit cached, {s['n_scenarios']} scenarios")]


def bench_fused_sweep_scale() -> List[Row]:
    """Fused sweep engine acceptance: the single-jit analytic + timeline
    + dependency pipeline sweeps paper-scale temporal ensembles at grid
    sizes {256, 4k, 64k}, reporting compile (cold) and steady-state
    (warm) separately per size.  Asserts (a) no recompilation across
    sizes within a padding bucket, and (b) >= 10x the per-scenario warm
    rate of the PR-4 composed path (separate jits, trace
    materialization, host round-trips) measured in-process at 256 — the
    BENCH_4 ``timeline_ensemble`` configuration."""
    from repro.core.capacity import RegionCapacity
    from repro.core.omg import Orchestrator
    from repro.core.scenarios import (FleetAggregates, scenario_grid,
                                      sweep_scenarios)
    from repro.core.service import synthesize_fleet
    from repro.core.sweep_engine import (bucket_shape, compiled_variants,
                                         tile_grid)
    from repro.core.timeline_sim import default_ts, sweep_timeline
    from repro.graph import CallGraph, blackhole_ensemble

    fs = synthesize_fleet(scale=1.0, seed=SEED, as_arrays=True)
    fs.apply_ufa_target_classes()
    graph = CallGraph.from_fleet_state(fs)
    region = RegionCapacity.for_fleet("fused", fs)
    orch = Orchestrator(fs, region, scale=1.0)
    eng = orch.sweep_engine(graph=graph, seed=SEED)
    agg = FleetAggregates.from_fleet_state(fs)
    cfg = orch.timeline_config()
    base = scenario_grid()
    ts = default_ts(7200.0, 240)

    # baseline: the composed PR-4 pipeline at 256 scenarios — three
    # separate jitted stages with host round-trips, the timeline stage
    # materializing the full (S, T, series) trace stack
    def composed():
        ens = blackhole_ensemble(graph, seed=SEED,
                                 fractions=np.asarray(
                                     base["evict_fraction"]))
        res = sweep_scenarios(agg, base,
                              dep_broken_frac=ens["broken_critical_frac"])
        tres = sweep_timeline(cfg, grid=base, ts=ts,
                              dep_broken_frac=np.asarray(
                                  ens["broken_critical_frac"]),
                              return_traces=True)
        return res, tres

    composed()                                   # warm the composed jits
    us_composed, _ = timed(composed, repeat=3)
    composed_rate = 256 / (us_composed / 1e6)

    rows: List[Row] = []
    scaling = []
    rates = {}
    for n in (256, 4096, 65536):
        grid = tile_grid(base, n)
        us_cold, _ = timed(eng.run, grid, repeat=1)
        us_warm, res = timed(eng.run, grid, repeat=3)
        rate = n / (us_warm / 1e6)
        rates[n] = rate
        scaling.append({"scenarios": n, "cold_s": us_cold / 1e6,
                        "warm_s": us_warm / 1e6, "scenarios_per_s": rate,
                        "bucket": bucket_shape(n),
                        "n_sla_ok": int(res["sla_ok"].sum()),
                        "n_t_sla_ok": int(res["t_sla_ok"].sum())})
        rows.append((f"fused_sweep_{n}_cold", us_cold,
                     f"first call at this bucket, includes jit compile"))
        rows.append((f"fused_sweep_{n}", us_warm,
                     f"warm, {rate:,.0f} scen/s, bucket={bucket_shape(n)}"))

    # (a) bucket reuse: 40960 pads to the same (16, 4096) bucket as 64k —
    # must NOT add a compiled variant
    variants = compiled_variants()
    eng.run(tile_grid(base, 40960))
    no_recompile = compiled_variants() == variants
    assert no_recompile, "re-compiled within a padding bucket"

    # (b) the paper-scale acceptance: >= 64k-scenario temporal+dependency
    # ensemble with warm throughput >= 10x the composed per-scenario rate
    speedup = rates[65536] / composed_rate
    assert speedup >= 10.0, (
        f"fused 64k rate {rates[65536]:,.0f}/s is only {speedup:.1f}x the "
        f"composed 256-scenario rate {composed_rate:,.0f}/s (need >=10x)")

    # backend-labelled reducer rows: the same 256-scenario grid with the
    # timeline carry through the segmented Pallas verdict-reduction
    # kernel (interpret mode on CPU — trajectory only; the dispatch
    # default keeps plain CPU on the bit-exact scan path)
    eng_pal = orch.sweep_engine(graph=graph, seed=SEED, reducer="pallas")
    grid256 = tile_grid(base, 256)
    us_pcold, _ = timed(eng_pal.run, grid256, repeat=1)
    us_pwarm, pres = timed(eng_pal.run, grid256, repeat=3)
    pal_rate = 256 / (us_pwarm / 1e6)
    rows.append(("fused_sweep_256_pallas_cold", us_pcold,
                 "reducer=pallas backend=cpu-interpret, includes compile"))
    rows.append(("fused_sweep_256_pallas", us_pwarm,
                 f"reducer=pallas backend=cpu-interpret, "
                 f"{pal_rate:,.0f} scen/s"))

    record_extra("fused_sweep_scale", {
        "composed_256_rate_per_s": composed_rate,
        "composed_256_warm_s": us_composed / 1e6,
        "fused_scaling": scaling,
        "speedup_vs_composed_64k": speedup,
        "no_recompile_within_bucket": no_recompile,
        "devices": len(eng.devices),
        "pallas_reducer_256": {"cold_s": us_pcold / 1e6,
                               "warm_s": us_pwarm / 1e6,
                               "scenarios_per_s": pal_rate,
                               "n_t_sla_ok": int(pres["t_sla_ok"].sum())},
    })
    rows.append(("fused_sweep_composed_baseline", us_composed,
                 f"PR-4 composed path, 256 scen, "
                 f"{composed_rate:,.0f} scen/s"))
    rows.append(("fused_sweep_speedup", 0.0,
                 f"64k fused at {rates[65536]:,.0f} scen/s = "
                 f"{speedup:.1f}x composed (assert >=10x) on "
                 f"{len(eng.devices)} device(s)"))

    # (c) observability agreement: with the metrics plane ON, the
    # engine's self-reported interior throughput (the
    # ufa_sweep_scenarios_per_s gauge) must agree with the harness's
    # exterior wall-clock measurement of the SAME warm call within 5% —
    # i.e. the plane reports the truth and costs ~nothing
    from repro import obs
    grid4k = tile_grid(base, 4096)
    eng.run(grid4k)                       # warm this bucket with obs off
    was_on = obs.enabled()
    obs.enable()
    try:
        t0 = time.perf_counter()
        eng.run(grid4k)
        ext_s = time.perf_counter() - t0
        ext_rate = 4096 / ext_s
        int_rate = obs.value("ufa_sweep_scenarios_per_s")
        rel = abs(int_rate - ext_rate) / ext_rate
    finally:
        if not was_on:
            obs.disable()
    assert rel <= 0.05, (
        f"obs-reported rate {int_rate:,.0f}/s disagrees with measured "
        f"{ext_rate:,.0f}/s by {rel:.1%} (need <=5%)")
    record_extra("fused_sweep_obs_agreement", {
        "interior_scen_per_s": int_rate, "exterior_scen_per_s": ext_rate,
        "relative_error": rel})
    rows.append(("fused_sweep_obs_agreement", ext_s * 1e6,
                 f"metrics on: gauge {int_rate:,.0f} scen/s vs measured "
                 f"{ext_rate:,.0f} scen/s ({rel:.2%} apart, assert <=5%)"))
    return rows


def bench_chaos_campaign() -> List[Row]:
    """Adversarial chaos-campaign acceptance: on the paper-scale
    hardened fleet, bandit-allocated bisection localizes the
    SLA-violating frontier along >= 3 fault-severity rays to 1/64
    severity resolution with >= 10x fewer engine scenario-evaluations
    than an exhaustive per-ray grid at the same resolution; every
    logged probe verdict replays bit-identically on an independent
    engine; the whole campaign is reproducible from one seed."""
    from repro import obs
    from repro.chaos import campaign_for_fleet, verify_report
    from repro.core.service import synthesize_fleet
    from repro.graph import CallGraph
    from repro.graph.planner import plan_hardening

    fs = synthesize_fleet(scale=PAPER_SCALE, seed=SEED, as_arrays=True)
    fs.apply_ufa_target_classes()
    # harden the critical call paths first — the chaos campaign probes
    # the fleet the paper actually certifies (the unhardened fleet
    # already fails dep_ok at its own operating point)
    graph = CallGraph.from_fleet_state(fs)
    plan = plan_hardening(graph)
    fs.edges.fail_open[graph.input_edge_indices(plan.hardened_edges)] = True

    tol = 1.0 / 64.0
    obs.enable()
    try:
        us_cold, rep = timed(
            lambda: campaign_for_fleet(fs, seed=SEED, tol=tol).run(),
            repeat=1)
        evals_metered = obs.value("ufa_chaos_evals_total")
    finally:
        obs.disable()
    # warm pass doubles as the single-seed reproducibility check: the
    # jit cache is hot, and a fresh campaign from the same seed must
    # produce a byte-identical report
    us_warm, rep2 = timed(
        lambda: campaign_for_fleet(fs, seed=SEED, tol=tol).run(), repeat=1)
    assert rep.to_json(sort_keys=True) == rep2.to_json(sort_keys=True), \
        "campaign is not reproducible from its seed"
    assert evals_metered == rep.n_evals, (
        f"obs metered {evals_metered} evals, report says {rep.n_evals}")

    assert rep.op_ok, "hardened paper fleet must pass its operating point"
    assert rep.n_localized >= 3, (
        f"only {rep.n_localized} rays localized (need >=3): "
        f"{[(r.name, r.status) for r in rep.rays]}")
    speedup = rep.speedup_vs_grid
    assert speedup is not None and speedup >= 10.0, (
        f"{rep.n_evals} evals vs grid-equivalent {rep.grid_equiv_evals} "
        f"is only {speedup:.1f}x (need >=10x)")

    # bit-exact audit: replay EVERY probe (frontiers, counterexamples,
    # brackets) through an independent engine in one batch
    fresh = campaign_for_fleet(fs, seed=SEED, tol=tol)
    us_verify, audit = timed(lambda: verify_report(rep, fresh.engine),
                             repeat=1)
    assert audit["n_probes"] == rep.n_evals and not audit["mismatches"]

    frontier = {r.name: round(r.frontier_severity, 6) for r in rep.rays
                if r.frontier_severity is not None}
    record_extra("chaos_campaign", {
        "tol": tol, "seed": SEED, "op_ok": rep.op_ok,
        "n_evals": rep.n_evals, "n_rounds": rep.n_rounds,
        "grid_equiv_evals": rep.grid_equiv_evals,
        "speedup_vs_grid": speedup, "n_localized": rep.n_localized,
        "frontier_severity": frontier,
        "rays": {r.name: r.status for r in rep.rays},
        "counterexamples": {r.name: r.counterexample for r in rep.rays
                            if r.status == "localized"},
        "reverified_probes": audit["n_probes"],
    })
    return [
        ("chaos_campaign_cold", us_cold,
         f"first campaign incl. jit compile; {rep.n_evals} evals over "
         f"{rep.n_rounds} rounds"),
        ("chaos_campaign", us_warm,
         f"{rep.n_localized} rays localized to 1/{round(1 / tol)}, "
         f"{rep.n_evals} evals vs {rep.grid_equiv_evals} grid "
         f"({speedup:.1f}x, assert >=10x)"),
        ("chaos_verify", us_verify,
         f"bit-exact replay of {audit['n_probes']} probes on an "
         f"independent engine"),
    ]


def bench_capacity_opt() -> List[Row]:
    """Capacity-optimizer acceptance: on the paper-scale hardened fleet
    the two-mode search (grad anneal + CEM polish) must come in at
    <= 1.4x provisioned/steady while the hard engine certifies every
    scenario of the 48-point ensemble at >= 99.97 % availability, and
    the soft gradient must agree with central finite differences."""
    import jax
    import jax.numpy as jnp

    from repro.core.service import synthesize_fleet
    from repro.core.timeline_sim import default_ts
    from repro.graph import CallGraph
    from repro.graph.planner import plan_hardening
    from repro.optim import hardening_weights, optimize_capacity
    from repro.optim.capacity import (DesignBase, _grid_cols,
                                      certification_grid, make_knobs,
                                      soft_loss)

    fs = synthesize_fleet(scale=PAPER_SCALE, seed=SEED, as_arrays=True)
    fs.apply_ufa_target_classes()
    graph = CallGraph.from_fleet_state(fs)
    plan = plan_hardening(graph)
    fs.edges.fail_open[graph.input_edge_indices(plan.hardened_edges)] = True

    us_opt, res = timed(lambda: optimize_capacity(fs, mode="both"),
                        repeat=1)
    v = res.verification
    assert res.improved, (res.start_multiple, res.provisioning_multiple)
    assert res.provisioning_multiple <= 1.4, res.provisioning_multiple
    assert v["all_ok"], v
    assert v["availability_min"] >= 0.9997 - 1e-9, v["availability_min"]

    # gradient spot-check vs central differences (buffer knob, tau=1)
    base = DesignBase.from_fleet_state(fs).as_arrays()
    cols = _grid_cols(certification_grid())
    ts = jnp.asarray(default_ts(), jnp.float32)
    tau = jnp.asarray(1.0, jnp.float32)
    pen = jnp.asarray(200.0, jnp.float32)
    knobs = make_knobs(buffer=0.6, promote=(0.4, 0.3, 0.2),
                       overcommit=1.4, ramp=0.9, evict_lambda=0.2)
    g = float(jax.grad(soft_loss)(knobs, base, cols, ts, tau, pen)
              ["buffer"])
    eps = 0.05
    hi = dict(knobs, buffer=knobs["buffer"] + eps)
    lo = dict(knobs, buffer=knobs["buffer"] - eps)
    fd = float((soft_loss(hi, base, cols, ts, tau, pen)
                - soft_loss(lo, base, cols, ts, tau, pen)) / (2 * eps))
    assert abs(g - fd) <= 0.08 * max(abs(fd), abs(g)), (g, fd)

    us_w, w = timed(lambda: hardening_weights(fs, graph, knobs=res.knobs),
                    repeat=1)
    wplan = plan_hardening(graph, service_weights=w)
    assert wplan.certified

    record_extra("capacity_opt", {
        "start_multiple": round(res.start_multiple, 4),
        "optimized_multiple": round(res.provisioning_multiple, 4),
        "design": {k: (round(float(x), 4) if not getattr(x, "ndim", 0)
                       else None) for k, x in res.design.items()
                   if not getattr(x, "ndim", 0)},
        "n_scenarios": v["n_scenarios"], "all_ok": v["all_ok"],
        "availability_min": round(v["availability_min"], 6),
        "grad_vs_fd": {"grad": round(g, 5), "fd": round(fd, 5)},
        "weighted_plan_edges": len(wplan.hardened_edges),
        "weighted_plan_certified": wplan.certified,
    })
    return [
        ("capacity_opt", us_opt,
         f"{res.start_multiple:.2f}x -> {res.provisioning_multiple:.2f}x "
         f"(assert <=1.4x), {v['n_scenarios']} scenarios hard-certified "
         f"at min avail {v['availability_min']:.4f}"),
        ("capacity_hardening_weights", us_w,
         f"availability-gradient blast-radius weights; weighted plan "
         f"{len(wplan.hardened_edges)} edges certified={wplan.certified}"),
    ]


def bench_serving_failover() -> List[Row]:
    """Live-workload failover acceptance: the timeline kernel's capacity
    traces actuate a real serving pool through a scripted full-peak
    failover under open-loop Poisson load, and the *measured request*
    verdicts show §4.2's differentiated SLAs — critical availability
    >= 99.97 % with no burn-rate alert, the preemptible tier preempted,
    blacked out (user-visible alert) and restored within its RTO.  The
    drill is bit-deterministic per spec, and a chaos campaign over the
    request-plane fault families localizes the SLA frontier with a
    bit-exact oracle replay."""
    import dataclasses

    from repro.chaos import verify_report
    from repro.core.tiers import FailureClass, RTO_SECONDS
    from repro.serving import (DrillSpec, drill_oracle, request_campaign,
                               run_drill)

    spec = DrillSpec()
    rto = RTO_SECONDS[FailureClass.RESTORE_LATER]
    us_cold, rep = timed(lambda: run_drill(spec), repeat=1)
    # warm pass doubles as the determinism check: pooled engines and a
    # hot jit cache must reproduce every verdict bit for bit
    us_warm, rep2 = timed(lambda: run_drill(spec), repeat=1)
    assert all(rep.tiers[t].as_dict() == rep2.tiers[t].as_dict()
               for t in rep.tiers), "drill is not deterministic"

    crit, pre = rep.crit, rep.pre
    assert rep.sla_ok, "drill SLA verdict failed"
    assert crit.availability >= 0.9997, crit.availability
    assert not crit.slo_alert
    assert crit.p99_s <= spec.crit_p99_slo_s, crit.p99_s
    assert pre.preempted > 0 and pre.requeued > 0
    assert pre.slo_alert, "blackout must be user-visible on the pre tier"
    assert pre.time_to_restore_s <= rto, pre.time_to_restore_s

    # request-plane chaos: a cheaper drill spec keeps the campaign tight
    small = dataclasses.replace(spec, n_steps=48, ticks_per_step=4,
                                crit_rps=0.03, pre_rps=0.02,
                                max_new_tokens=2, seed=11)
    us_camp, crep = timed(
        lambda: request_campaign(small, tol=1.0 / 8.0, max_rounds=5).run(),
        repeat=1)
    assert crep.op_ok and crep.n_localized >= 1, (
        [(r.name, r.status) for r in crep.rays])
    us_verify, audit = timed(
        lambda: verify_report(crep, oracle=drill_oracle(small)), repeat=1)
    assert audit["n_probes"] == crep.n_evals and not audit["mismatches"]

    record_extra("serving_failover", {
        "spec_seed": spec.seed, "horizon_s": spec.horizon_s,
        "users_served": round(rep.users_served),
        "actuation_log": [(t, tier.name, tgt)
                          for t, tier, tgt in rep.actuation_log],
        "tiers": {v.tier: v.as_dict() for v in rep.tiers.values()},
        "campaign": {
            "n_evals": crep.n_evals, "n_localized": crep.n_localized,
            "rays": {r.name: r.status for r in crep.rays},
            "frontiers": {r.name: r.frontier_knobs() for r in crep.rays
                          if r.status == "localized"},
            "reverified_probes": audit["n_probes"],
        },
    })
    return [
        ("serving_failover_cold", us_cold,
         f"first live drill incl. jit compile; ~{rep.users_served / 1e6:.1f}M "
         f"users, crit avail {crit.availability:.4f}"),
        ("serving_failover", us_warm,
         f"crit {crit.tier} avail {crit.availability:.4f} (assert >=0.9997) "
         f"p99 {crit.p99_s:.0f}s; pre {pre.tier} preempted {pre.preempted}, "
         f"restored in {pre.time_to_restore_s:.0f}s <= RTO {rto:.0f}s"),
        ("serving_request_campaign", us_camp,
         f"{crep.n_localized} request-plane rays localized in "
         f"{crep.n_evals} drills; frontier "
         + str({r.name: round(r.frontier_severity, 3) for r in crep.rays
                if r.frontier_severity is not None})),
        ("serving_campaign_verify", us_verify,
         f"bit-exact oracle replay of {audit['n_probes']} drill probes"),
    ]


ALL = [
    bench_table1_tiers,
    bench_table2_rpc_matrix,
    bench_table4_failover_classes,
    bench_table5_phased_rollout,
    bench_table6_failclose,
    bench_fig2_3_failover_history,
    bench_fig7_burst_conversion,
    bench_fig8_availability,
    bench_fig9_container_conversion,
    bench_fig10_region_utilization,
    bench_fig11_fleet_utilization,
    bench_eviction_rates,
    bench_overcommit,
    bench_canary_gate,
    bench_fleet_scale,
    bench_scenario_sweep,
    bench_runtime_detection_scale,
    bench_graph_propagation,
    bench_timeline_ensemble,
    bench_fused_sweep_scale,
    bench_chaos_campaign,
    bench_capacity_opt,
    bench_serving_failover,
]
