# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--no-kernels", action="store_true")
    args = ap.parse_args()

    from benchmarks import bench_paper
    from benchmarks.common import emit

    suites = list(bench_paper.ALL)
    if not args.no_kernels:
        from benchmarks import bench_kernels
        suites += bench_kernels.ALL

    print("name,us_per_call,derived")
    failures = 0
    for fn in suites:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            emit(fn())
        except Exception as e:
            failures += 1
            print(f"{fn.__name__},nan,ERROR {type(e).__name__}: {e}",
                  file=sys.stdout)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
