# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV; --json additionally writes rows + structured extras (per-scenario
# SLA verdicts, ...) for the perf trajectory (BENCH_*.json).
import argparse
import json
import os
import sys
import traceback

# allow `python benchmarks/run.py` as well as `python -m benchmarks.run`
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark names")
    ap.add_argument("--no-kernels", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="alias for --no-kernels (the kernel benches "
                    "dominate runtime) — the CI smoke configuration")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write rows + extras as JSON")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="enable the observability plane for the whole "
                    "run and write a Prometheus snapshot of the metrics "
                    "registry (bench rows included as "
                    "ufa_bench_us_per_call gauges)")
    args = ap.parse_args()
    args.no_kernels = args.no_kernels or args.quick

    if args.metrics_out:
        from repro import obs
        obs.enable()

    from benchmarks import bench_paper
    from benchmarks.common import EXTRAS, bench_meta, emit

    suites = list(bench_paper.ALL)
    if not args.no_kernels:
        from benchmarks import bench_kernels
        suites += bench_kernels.ALL

    print("name,us_per_call,derived")
    all_rows = []
    failures = 0
    for fn in suites:
        if args.only and args.only not in fn.__name__:
            continue
        try:
            rows = fn()
            emit(rows)
            all_rows.extend(rows)
        except Exception as e:
            failures += 1
            err_row = (fn.__name__, float("nan"),
                       f"ERROR {type(e).__name__}: {e}")
            print(f"{err_row[0]},nan,{err_row[2]}", file=sys.stdout)
            all_rows.append(err_row)
            traceback.print_exc(file=sys.stderr)

    if args.json:
        payload = {
            "meta": bench_meta(),
            # NaN (error rows) -> null: keep the artifact strict JSON
            "rows": [{"name": n,
                      "us_per_call": None if us != us else us,
                      "derived": d}
                     for n, us, d in all_rows],
            "extras": EXTRAS,
            "failures": failures,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {args.json}", file=sys.stderr)
    if args.metrics_out:
        from repro import obs
        from repro.obs import export
        for n, us, _ in all_rows:
            if us == us:                      # skip NaN error rows
                obs.set_gauge("ufa_bench_us_per_call", us, name=n)
        export.write_prometheus(args.metrics_out)
        print(f"wrote {args.metrics_out}", file=sys.stderr)
    if failures:
        raise SystemExit(1)


if __name__ == '__main__':
    main()
