from repro.data.pipeline import (  # noqa: F401
    SyntheticLMDataset,
    make_train_iterator,
)
