"""Deterministic synthetic-token data pipeline.

Markov-chain token streams (stable bigram structure so small models show a
real, decreasing loss) generated on the fly from a counter-based PRNG:
batch N is a pure function of (seed, N), so any worker/restart resumes
exactly — the property UFA's preempt-and-restore path (BBM) relies on: a
training job revived in burst capacity continues from (checkpoint step + 1)
with bit-identical data order.  Sharded hosts slice the global batch by
process index.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_clusters: int = 16      # latent "topics" giving learnable structure

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        V, C = self.vocab_size, self.n_clusters
        # each cluster prefers a band of tokens; transitions are sticky
        self.cluster_of = rng.integers(0, C, size=V)
        self.trans = rng.dirichlet(np.ones(C) * 0.3, size=C)
        self.band = [np.flatnonzero(self.cluster_of == c) for c in range(C)]
        for c in range(C):
            if len(self.band[c]) == 0:
                self.band[c] = np.array([c % V])

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        """Batch `index`, deterministically (counter-based)."""
        rng = np.random.default_rng((self.seed << 20) ^ index)
        B, S, C = self.global_batch, self.seq_len, self.n_clusters
        clusters = np.empty((B, S), np.int64)
        clusters[:, 0] = rng.integers(0, C, size=B)
        u = rng.random((B, S))
        cum = np.cumsum(self.trans, axis=1)
        for t in range(1, S):
            clusters[:, t] = (u[:, t, None] <
                              cum[clusters[:, t - 1]]).argmax(axis=1)
        pick = rng.integers(0, 1 << 30, size=(B, S))
        tokens = np.empty((B, S), np.int32)
        for c in range(C):
            m = clusters == c
            tokens[m] = self.band[c][pick[m] % len(self.band[c])]
        inputs = tokens[:, :-1] if S > 1 else tokens
        labels = tokens[:, 1:] if S > 1 else tokens
        # pad back to S with a wrap token so shapes stay (B, S)
        inputs = np.concatenate([tokens[:, :1], inputs], axis=1)[:, :S]
        labels = tokens
        return {"inputs": inputs.astype(np.int32),
                "labels": labels.astype(np.int32)}


def make_train_iterator(ds: SyntheticLMDataset, start_step: int = 0,
                        shardings: Optional[Dict] = None
                        ) -> Iterator[Dict[str, jnp.ndarray]]:
    """Resumable iterator: step N always yields the same batch."""
    step = start_step
    while True:
        b = ds.batch(step)
        if shardings:
            b = {k: jax.device_put(v, shardings[k]) for k, v in b.items()}
        else:
            b = {k: jnp.asarray(v) for k, v in b.items()}
        yield b
        step += 1
