"""llama3.2-3b [dense] — small llama3.

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256
[hf:meta-llama/Llama-3.2-1B; unverified]
"""

from repro.configs.base import ArchSpec, register, FULL_ATTENTION_500K_SKIP
from repro.core.tiers import Tier
from repro.models import LMConfig

CONFIG = LMConfig(
    name="llama3.2-3b",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab_size=128256,
    rope_theta=500000.0, tie_embeddings=True, max_seq_len=131072,
    param_dtype="bfloat16", activ_dtype="bfloat16", remat="full",
)

REDUCED = LMConfig(
    name="llama3.2-3b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256, tie_embeddings=True,
)

SPEC = register(ArchSpec(
    arch_id="llama3.2-3b", family="dense", config=CONFIG, reduced=REDUCED,
    tier=Tier.T1, source="hf:meta-llama/Llama-3.2-1B; unverified",
    skips={"long_500k": FULL_ATTENTION_500K_SKIP},
))
