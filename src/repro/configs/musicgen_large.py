"""musicgen-large [audio] — decoder-only over EnCodec tokens.

48L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=2048  [arXiv:2306.05284; hf]
Modality frontend (EnCodec) is a stub: input_specs() provides precomputed
frame embeddings (B, S, d_model).
"""

from repro.configs.base import ArchSpec, register, FULL_ATTENTION_500K_SKIP
from repro.core.tiers import Tier
from repro.models import LMConfig

CONFIG = LMConfig(
    name="musicgen-large",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab_size=2048,
    embed_inputs=False,          # EnCodec frame embeddings from the stub frontend
    rope_theta=1e4, max_seq_len=32768,
    param_dtype="bfloat16", activ_dtype="bfloat16", remat="full",
)

REDUCED = LMConfig(
    name="musicgen-large-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_head=16,
    d_ff=128, vocab_size=128, embed_inputs=False,
)

SPEC = register(ArchSpec(
    arch_id="musicgen-large", family="audio", config=CONFIG, reduced=REDUCED,
    tier=Tier.T3, source="arXiv:2306.05284; hf",
    skips={"long_500k": FULL_ATTENTION_500K_SKIP},
))
