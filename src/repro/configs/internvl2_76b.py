"""internvl2-76b [vlm] — InternViT + InternLM2 backbone.

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256  [arXiv:2404.16821; unverified]
Vision frontend (InternViT) is a stub: input_specs() provides precomputed
patch embeddings (B, S, d_model).
"""

from repro.configs.base import ArchSpec, register, FULL_ATTENTION_500K_SKIP
from repro.core.tiers import Tier
from repro.models import LMConfig

CONFIG = LMConfig(
    name="internvl2-76b",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=28672, vocab_size=128256,
    embed_inputs=False,          # patch/text embeddings from the stub frontend
    rope_theta=1e6, max_seq_len=32768,
    param_dtype="bfloat16", activ_dtype="bfloat16", remat="full",
)

REDUCED = LMConfig(
    name="internvl2-76b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=160, vocab_size=256, embed_inputs=False,
)

SPEC = register(ArchSpec(
    arch_id="internvl2-76b", family="vlm", config=CONFIG, reduced=REDUCED,
    tier=Tier.T2, source="arXiv:2404.16821; unverified",
    skips={"long_500k": FULL_ATTENTION_500K_SKIP},
))
