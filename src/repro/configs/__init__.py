from repro.configs.base import (  # noqa: F401
    SHAPES,
    ArchSpec,
    ShapeSpec,
    all_archs,
    get_arch,
    input_specs,
    register,
)
