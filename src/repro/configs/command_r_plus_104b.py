"""command-r-plus-104b [dense] — GQA, no-bias.

64L d_model=12288 96H (GQA kv=8) d_ff=33792 vocab=256000
[hf:CohereForAI/c4ai-command-r-v01; unverified]
"""

from repro.configs.base import ArchSpec, register, FULL_ATTENTION_500K_SKIP
from repro.core.tiers import Tier
from repro.models import LMConfig

CONFIG = LMConfig(
    name="command-r-plus-104b",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_head=128,
    d_ff=33792, vocab_size=256000,
    rope_theta=75e6, max_seq_len=131072,
    param_dtype="bfloat16", activ_dtype="bfloat16", remat="full",
)

REDUCED = LMConfig(
    name="command-r-plus-104b-reduced",
    n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_head=8,
    d_ff=192, vocab_size=256,
)

SPEC = register(ArchSpec(
    arch_id="command-r-plus-104b", family="dense", config=CONFIG, reduced=REDUCED,
    tier=Tier.T1, source="hf:CohereForAI/c4ai-command-r-v01; unverified",
    skips={"long_500k": FULL_ATTENTION_500K_SKIP},
))
