"""mamba2-780m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1536 d_ff=0 vocab=50280, ssm_state=128  [arXiv:2405.21060; unverified]
"""

from repro.configs.base import ArchSpec, register
from repro.core.tiers import Tier
from repro.models import LMConfig

CONFIG = LMConfig(
    name="mamba2-780m",
    n_layers=48, d_model=1536, n_heads=0, n_kv_heads=1, d_head=1,
    d_ff=0, vocab_size=50280, block="ssm",
    ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    tie_embeddings=True, max_seq_len=1 << 20, sub_quadratic=True,
    param_dtype="bfloat16", activ_dtype="bfloat16", remat="full",
)

REDUCED = LMConfig(
    name="mamba2-780m-reduced",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=1, d_head=1,
    d_ff=0, vocab_size=256, block="ssm",
    ssm_state=16, ssm_head_dim=16, ssm_chunk=8, tie_embeddings=True,
    sub_quadratic=True,
)

SPEC = register(ArchSpec(
    arch_id="mamba2-780m", family="ssm", config=CONFIG, reduced=REDUCED,
    tier=Tier.T3, source="arXiv:2405.21060; unverified",
    skips={},
))
