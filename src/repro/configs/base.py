"""Architecture registry: assigned architectures × input shapes.

Each arch module defines ``CONFIG`` (exact assigned numbers), ``REDUCED``
(same family, tiny, for CPU smoke tests) and registers itself here.
``input_specs`` builds ShapeDtypeStruct stand-ins — weak-type-correct,
shardable, no device allocation — for every (arch × shape) dry-run cell.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import LMConfig, init_decode_state
from repro.core.tiers import Tier

# ---------------------------------------------------------------------------
# Shapes (assigned): all LM-family archs share these four shape cells.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                    # audio|dense|ssm|moe|vlm|hybrid
    config: LMConfig
    reduced: LMConfig
    tier: Tier                     # business-criticality tier for UFA examples
    source: str
    # shape-name -> skip reason (None = runs)
    skips: Dict[str, Optional[str]] = dataclasses.field(default_factory=dict)

    def shape_runnable(self, shape: str) -> bool:
        return self.skips.get(shape) is None


_REGISTRY: Dict[str, ArchSpec] = {}


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    _load_all()
    return _REGISTRY[arch_id]


def all_archs() -> Dict[str, ArchSpec]:
    _load_all()
    return dict(_REGISTRY)


_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    from repro.configs import (  # noqa: F401
        musicgen_large, command_r_plus_104b, llama3_2_3b, gemma3_4b,
        qwen3_1_7b, mamba2_780m, kimi_k2_1t_a32b, phi3_5_moe_42b_a6_6b,
        internvl2_76b, hymba_1_5b)
    _LOADED = True


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def input_specs(arch: ArchSpec, shape_name: str,
                activ_dtype: str = "bfloat16") -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for every model input of the given dry-run cell."""
    cfg = arch.config
    ss = SHAPES[shape_name]
    B, S = ss.global_batch, ss.seq_len
    if ss.kind == "train":
        if cfg.embed_inputs:
            inputs = _sds((B, S), jnp.int32)
        else:
            inputs = _sds((B, S, cfg.d_model), activ_dtype)
        return {"inputs": inputs, "labels": _sds((B, S), jnp.int32)}
    if ss.kind == "prefill":
        if cfg.embed_inputs:
            return {"inputs": _sds((B, S), jnp.int32)}
        return {"inputs": _sds((B, S, cfg.d_model), activ_dtype)}
    # decode: one new token against a KV cache of seq_len
    from repro.dist.sharding import cache_seq_len
    state = jax.eval_shape(
        lambda: init_decode_state(cfg, B, cache_seq_len(S), jnp.bfloat16, length=S))
    if cfg.embed_inputs:
        tokens = _sds((B,), jnp.int32)
    else:
        tokens = _sds((B, cfg.d_model), activ_dtype)
    return {"state": state, "tokens": tokens}


FULL_ATTENTION_500K_SKIP = (
    "long_500k skipped: pure full-attention architecture — published config "
    "does not support 524k context (quadratic prefill, positional scheme); "
    "see DESIGN.md §Arch-applicability.")
