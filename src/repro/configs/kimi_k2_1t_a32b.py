"""kimi-k2-1t-a32b [moe] — trillion-param MoE (paper-table).

61L d_model=7168 64H (GQA kv=8) d_ff=2048 vocab=163840, MoE 384e top-8
[arXiv:2501.kimi2; unverified]
"""

from repro.configs.base import ArchSpec, register, FULL_ATTENTION_500K_SKIP
from repro.core.tiers import Tier
from repro.models import LMConfig

CONFIG = LMConfig(
    name="kimi-k2-1t-a32b",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_head=128,
    d_ff=2048, vocab_size=163840,
    n_experts=384, moe_top_k=8, capacity_factor=1.25,
    rope_theta=50000.0, max_seq_len=131072,
    param_dtype="bfloat16", activ_dtype="bfloat16", remat="full",
)

REDUCED = LMConfig(
    name="kimi-k2-1t-a32b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=96, vocab_size=256, n_experts=8, moe_top_k=2,
)

SPEC = register(ArchSpec(
    arch_id="kimi-k2-1t-a32b", family="moe", config=CONFIG, reduced=REDUCED,
    tier=Tier.T1, source="arXiv:2501.kimi2; unverified",
    skips={"long_500k": FULL_ATTENTION_500K_SKIP},
))
