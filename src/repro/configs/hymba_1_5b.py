"""hymba-1.5b [hybrid] — parallel attention + mamba heads.

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16
[arXiv:2411.13676; hf]

Global full attention at layers {0, 15, 31}; sliding window (1024) elsewhere
(per the Hymba paper).  Meta-tokens are omitted: the assignment's backbone
spec is authoritative.  sub_quadratic: mamba heads are O(1)-state and 29/32
attention layers have window-bounded KV, so long_500k runs.
"""

from repro.configs.base import ArchSpec, register
from repro.core.tiers import Tier
from repro.models import LMConfig

_WINDOWS = tuple(0 if i in (0, 15, 31) else 1024 for i in range(32))

CONFIG = LMConfig(
    name="hymba-1.5b",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_head=64,
    d_ff=5504, vocab_size=32001, block="hybrid",
    ssm_state=16, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    window_pattern=_WINDOWS, rope_theta=1e4,
    tie_embeddings=True, max_seq_len=1 << 20, sub_quadratic=True,
    param_dtype="bfloat16", activ_dtype="bfloat16", remat="full",
)

REDUCED = LMConfig(
    name="hymba-1.5b-reduced",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256, block="hybrid",
    ssm_state=8, ssm_head_dim=16, ssm_chunk=8,
    window_pattern=(0, 8, 8, 0), tie_embeddings=True, sub_quadratic=True,
)

SPEC = register(ArchSpec(
    arch_id="hymba-1.5b", family="hybrid", config=CONFIG, reduced=REDUCED,
    tier=Tier.T4, source="arXiv:2411.13676; hf",
    skips={},
))
