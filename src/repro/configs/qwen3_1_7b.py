"""qwen3-1.7b [dense] — qk_norm, GQA.

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936  [hf:Qwen/Qwen3-8B; hf]
"""

from repro.configs.base import ArchSpec, register, FULL_ATTENTION_500K_SKIP
from repro.core.tiers import Tier
from repro.models import LMConfig

CONFIG = LMConfig(
    name="qwen3-1.7b",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=6144, vocab_size=151936,
    qk_norm=True, rope_theta=1e6, tie_embeddings=True, max_seq_len=40960,
    param_dtype="bfloat16", activ_dtype="bfloat16", remat="full",
)

REDUCED = LMConfig(
    name="qwen3-1.7b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256, qk_norm=True, tie_embeddings=True,
)

SPEC = register(ArchSpec(
    arch_id="qwen3-1.7b", family="dense", config=CONFIG, reduced=REDUCED,
    tier=Tier.T1, source="hf:Qwen/Qwen3-8B; hf",
    skips={"long_500k": FULL_ATTENTION_500K_SKIP},
))
