"""phi3.5-moe-42b-a6.6b [moe] — 16 experts top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16e top-2
[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""

from repro.configs.base import ArchSpec, register, FULL_ATTENTION_500K_SKIP
from repro.core.tiers import Tier
from repro.models import LMConfig

CONFIG = LMConfig(
    name="phi3.5-moe-42b-a6.6b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_head=128,
    d_ff=6400, vocab_size=32064,
    n_experts=16, moe_top_k=2, capacity_factor=1.25,
    rope_theta=1e4, max_seq_len=131072,
    param_dtype="bfloat16", activ_dtype="bfloat16", remat="full",
)

REDUCED = LMConfig(
    name="phi3.5-moe-42b-a6.6b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=96, vocab_size=256, n_experts=4, moe_top_k=2,
)

SPEC = register(ArchSpec(
    arch_id="phi3.5-moe-42b-a6.6b", family="moe", config=CONFIG, reduced=REDUCED,
    tier=Tier.T2, source="hf:microsoft/Phi-3.5-MoE-instruct; hf",
    skips={"long_500k": FULL_ATTENTION_500K_SKIP},
))
