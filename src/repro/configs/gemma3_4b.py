"""gemma3-4b [dense] — 5:1 local:global sliding-window attention, 128k.

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144
[hf:google/gemma-3-1b-pt; unverified]

long_500k RUNS for this arch: 5/6 of layers have window-bounded (1024) KV;
the 1-in-6 global layers hold full-length KV, which at decode is
linear-compute and sequence-shardable.  See DESIGN.md §Arch-applicability.
"""

from repro.configs.base import ArchSpec, register
from repro.core.tiers import Tier
from repro.models import LMConfig

CONFIG = LMConfig(
    name="gemma3-4b",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=10240, vocab_size=262144,
    qk_norm=True,
    window_pattern=(1024, 1024, 1024, 1024, 1024, 0),   # 5 local : 1 global
    rope_theta=1e6, rope_theta_local=1e4,
    tie_embeddings=True, embed_scale=True, max_seq_len=131072,
    sub_quadratic=True,
    param_dtype="bfloat16", activ_dtype="bfloat16", remat="full",
)

REDUCED = LMConfig(
    name="gemma3-4b-reduced",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=128, vocab_size=256, qk_norm=True,
    window_pattern=(8, 8, 8, 8, 8, 0), rope_theta=1e6, rope_theta_local=1e4,
    tie_embeddings=True, embed_scale=True, sub_quadratic=True,
)

SPEC = register(ArchSpec(
    arch_id="gemma3-4b", family="dense", config=CONFIG, reduced=REDUCED,
    tier=Tier.T2, source="hf:google/gemma-3-1b-pt; unverified",
    skips={},
))
