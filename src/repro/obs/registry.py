"""Labeled metrics registry — the substrate of the observability plane.

Prometheus-shaped primitives (``Counter`` / ``Gauge`` / ``Histogram``,
each optionally labeled) collected into a ``Registry``, plus one
process-global *default* registry that every instrumented call site in
the repro reports into.  Two properties matter more than features:

  * **off by default, free when off** — the default registry starts
    disabled, and the hot-path helpers in ``repro.obs`` bail on a single
    module-level bool before touching any metric object, so the fused
    sweep engine / telemetry ingest pay one branch per *call* (not per
    record) when observability is off;
  * **zero dependencies** — plain Python + a ``threading.Lock``; nothing
    here imports jax/numpy, so ``repro.core`` modules can import the
    plane without ordering constraints.

Updates are lock-protected (callbacks may fire from worker threads or
re-entrantly from inside event-loop handlers); child creation is
idempotent, so ``registry.counter(name, ...)`` at a call site is a cheap
get-or-create, not a redefinition.
"""

from __future__ import annotations

import math
import re
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

_NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")

# default histogram buckets: wall-time seconds from sub-ms dispatch to
# multi-minute end-to-end phases
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0, 300.0)

LabelKey = Tuple[Tuple[str, str], ...]      # sorted ((name, value), ...)


def _label_key(names: Tuple[str, ...], kw: Dict[str, object]) -> LabelKey:
    if set(kw) != set(names):
        raise ValueError(f"labels {sorted(kw)} != declared {sorted(names)}")
    return tuple(sorted((k, str(v)) for k, v in kw.items()))


class _Child:
    """One labeled series of a metric (or the metric's only series when
    it is label-less)."""

    __slots__ = ("_m", "value", "bucket_counts", "sum", "count")

    def __init__(self, metric: "Metric"):
        self._m = metric
        self.value = 0.0
        if metric.kind == "histogram":
            self.bucket_counts = [0] * len(metric.buckets)
            self.sum = 0.0
            self.count = 0

    # -- counter / gauge ------------------------------------------------
    def inc(self, v: float = 1.0):
        m = self._m
        if not m.registry.enabled:
            return
        if m.kind == "counter" and v < 0:
            raise ValueError(f"counter {m.name} decremented by {v}")
        with m.registry._lock:
            self.value += v

    def dec(self, v: float = 1.0):
        self.inc(-v)

    def set(self, v: float):
        m = self._m
        if not m.registry.enabled:
            return
        if m.kind != "gauge":
            raise ValueError(f"set() on {m.kind} {m.name}")
        with m.registry._lock:
            self.value = float(v)

    # -- histogram ------------------------------------------------------
    def observe(self, v: float):
        m = self._m
        if not m.registry.enabled:
            return
        if m.kind != "histogram":
            raise ValueError(f"observe() on {m.kind} {m.name}")
        v = float(v)
        with m.registry._lock:
            for i, le in enumerate(m.buckets):
                if v <= le:
                    self.bucket_counts[i] += 1
            self.sum += v
            self.count += 1


class Metric:
    """One named metric: a family of labeled children."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labels: Sequence[str] = (),
                 registry: Optional["Registry"] = None,
                 buckets: Optional[Sequence[float]] = None):
        if not _NAME_RE.match(name):
            raise ValueError(f"invalid metric name {name!r}")
        self.name = name
        self.help = help
        self.label_names: Tuple[str, ...] = tuple(labels)
        for ln in self.label_names:
            if not _LABEL_RE.match(ln) or ln.startswith("__"):
                raise ValueError(f"invalid label name {ln!r}")
        self.registry = registry if registry is not None else _DEFAULT
        if self.kind == "histogram":
            b = tuple(float(x) for x in
                      (DEFAULT_BUCKETS if buckets is None else buckets))
            if list(b) != sorted(b) or len(set(b)) != len(b):
                raise ValueError("histogram buckets must be sorted, unique")
            if not b or not math.isinf(b[-1]):
                b = b + (math.inf,)
            self.buckets: Tuple[float, ...] = b
        self._children: Dict[LabelKey, _Child] = {}
        if not self.label_names:
            self._children[()] = _Child(self)

    # ------------------------------------------------------------------
    def labels(self, **kw) -> _Child:
        key = _label_key(self.label_names, kw)
        child = self._children.get(key)
        if child is None:
            with self.registry._lock:
                child = self._children.setdefault(key, _Child(self))
        return child

    def _default_child(self) -> _Child:
        if self.label_names:
            raise ValueError(f"{self.name} is labeled "
                             f"{self.label_names}; use .labels(...)")
        return self._children[()]

    # label-less convenience: metric.inc(...) / .set(...) / .observe(...)
    def inc(self, v: float = 1.0):
        self._default_child().inc(v)

    def dec(self, v: float = 1.0):
        self._default_child().dec(v)

    def set(self, v: float):
        self._default_child().set(v)

    def observe(self, v: float):
        self._default_child().observe(v)

    # ------------------------------------------------------------------
    def samples(self) -> List[Tuple[LabelKey, _Child]]:
        return sorted(self._children.items())


class Counter(Metric):
    kind = "counter"


class Gauge(Metric):
    kind = "gauge"


class Histogram(Metric):
    kind = "histogram"


_KINDS = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Registry:
    """A set of metrics + the enabled switch their updates check."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._lock = threading.RLock()
        self._metrics: Dict[str, Metric] = {}

    # -- get-or-create (idempotent at call sites) -----------------------
    def _get_or_create(self, cls, name: str, help: str,
                       labels: Sequence[str],
                       buckets: Optional[Sequence[float]] = None) -> Metric:
        m = self._metrics.get(name)
        if m is not None:
            if not isinstance(m, cls) or m.label_names != tuple(labels):
                raise ValueError(
                    f"metric {name} re-registered as {cls.kind} "
                    f"labels={tuple(labels)} (was {m.kind} "
                    f"labels={m.label_names})")
            return m
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, labels, registry=self, buckets=buckets)
                self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labels: Sequence[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "",
              labels: Sequence[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Sequence[str] = (),
                  buckets: Optional[Sequence[float]] = None) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels, buckets)

    # ------------------------------------------------------------------
    def metrics(self) -> List[Metric]:
        return [self._metrics[k] for k in sorted(self._metrics)]

    def get(self, name: str) -> Optional[Metric]:
        return self._metrics.get(name)

    def value(self, name: str, /, **labels) -> float:
        """Read one sample's value (counters/gauges) — tests and
        acceptance checks read the plane back through this."""
        m = self._metrics[name]
        child = (m.labels(**labels) if m.label_names else
                 m._default_child())
        return child.value

    def collect(self) -> List[Dict[str, object]]:
        """Snapshot every sample: a list of dicts, one per labeled child
        (histograms carry buckets/sum/count), deterministically ordered.
        The single source for both exporters."""
        out: List[Dict[str, object]] = []
        with self._lock:
            for m in self.metrics():
                for key, child in m.samples():
                    row: Dict[str, object] = {
                        "name": m.name, "kind": m.kind, "help": m.help,
                        "labels": dict(key)}
                    if m.kind == "histogram":
                        row["buckets"] = [
                            [le, c] for le, c in
                            zip(m.buckets, child.bucket_counts)]
                        row["sum"] = child.sum
                        row["count"] = child.count
                    else:
                        row["value"] = child.value
                    out.append(row)
        return out

    def reset(self):
        """Drop all metrics (tests; a fresh run starts clean)."""
        with self._lock:
            self._metrics.clear()


# ---------------------------------------------------------------------------
# The process-global default registry: off until someone turns the plane on.
# ---------------------------------------------------------------------------

_DEFAULT = Registry(enabled=False)


def default_registry() -> Registry:
    return _DEFAULT


def enabled() -> bool:
    """The one check hot paths make before doing any metric work."""
    return _DEFAULT.enabled


def enable() -> Registry:
    _DEFAULT.enabled = True
    return _DEFAULT


def disable():
    _DEFAULT.enabled = False
