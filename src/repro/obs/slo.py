"""Multi-window multi-burn-rate SLO monitoring for failover traces.

Implements the SRE-workbook alerting recipe against the paper's 99.97%
availability target: *burn rate* is the error-budget consumption speed
(``(1 - avail) / (1 - target)``), and a rule fires when the trailing
**long** window *and* a trailing **short** window both burn faster than
its threshold — fast enough to page inside a failover window, while the
short window makes the alert reset promptly once availability recovers.

Two execution paths share one definition:

  * :func:`alerts_np` — plain numpy, float64; the scalar reference and
    the host-side monitor for `Orchestrator` runs (via
    :func:`monitor_orchestrator`, which samples the event-loop timeline
    through ``core.metrics.availability_during_failover`` — a uniform
    time grid by construction).
  * :func:`sweep_alerts` — the same math jitted + vmapped over the
    ``timeline_sim`` availability traces ``(S, T)`` that
    ``sweep_timeline(..., return_traces=True)`` /
    ``SweepEngine.run`` produce, yielding per-scenario
    ``alert`` / ``t_first_alert`` / ``rule_first_alert`` / ``burn_peak``
    at ensemble rates.

Window sizes are converted to whole steps host-side (static under jit);
rolling means use an exact cumulative-sum formulation with partial
prefixes (the first ``k-1`` samples average over what exists so far),
so the jitted and numpy paths agree bit-for-bit on well-separated
traces and the monitor is alertable from t=0.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# The paper's availability target (Fig 8); error budget is 1 - target.
DEFAULT_TARGET = 0.9997


@dataclasses.dataclass(frozen=True)
class BurnRateRule:
    """One (long window, short window, burn threshold) alerting rule."""
    long_s: float     # trailing long window, seconds
    short_s: float    # trailing short window, seconds
    burn: float       # fire when both windows burn >= this rate

    @property
    def name(self) -> str:
        return f"burn{self.burn:g}x_{int(self.long_s)}s"


# SRE-workbook-shaped defaults scaled to a ~2 h failover window
# (default_ts horizon 7200 s): a fast-burn page and a faster-burn page.
DEFAULT_RULES: Tuple[BurnRateRule, ...] = (
    BurnRateRule(long_s=3600.0, short_s=300.0, burn=6.0),
    BurnRateRule(long_s=600.0, short_s=60.0, burn=14.4),
)


def _steps(window_s: float, dt: float) -> int:
    return max(1, int(round(window_s / dt)))


def rule_steps(rules: Sequence[BurnRateRule], dt: float
               ) -> Tuple[Tuple[int, int, float], ...]:
    """(long_k, short_k, burn) per rule — the static jit arguments."""
    return tuple((_steps(r.long_s, dt), _steps(r.short_s, dt), r.burn)
                 for r in rules)


# ---------------------------------------------------------------------------
# numpy reference / host-side monitor
# ---------------------------------------------------------------------------

def _rolling_mean_np(x: np.ndarray, k: int) -> np.ndarray:
    """Trailing-k mean with partial prefixes: out[i] = mean(x[max(0,i-k+1)..i])."""
    c = np.cumsum(x, dtype=np.float64)
    out = c.copy()
    out[k:] = c[k:] - c[:-k]
    denom = np.minimum(np.arange(1, len(x) + 1), k)
    return out / denom


def alerts_np(avail: np.ndarray, ts: np.ndarray,
              target: float = DEFAULT_TARGET,
              rules: Sequence[BurnRateRule] = DEFAULT_RULES
              ) -> Dict[str, np.ndarray]:
    """Scalar-reference burn-rate monitor over one availability trace.

    ``avail``: (T,) availability samples on the uniform grid ``ts``.
    Returns per-trace verdicts plus the per-step alert matrix.
    """
    avail = np.asarray(avail, dtype=np.float64)
    ts = np.asarray(ts, dtype=np.float64)
    assert avail.ndim == 1 and avail.shape == ts.shape
    dt = float(ts[1] - ts[0]) if len(ts) > 1 else 1.0
    budget = 1.0 - target
    burn = (1.0 - avail) / budget
    firing = np.zeros((len(rules), len(ts)), dtype=bool)
    burn_long_peak = np.zeros(len(rules))
    for ri, (lk, sk, thr) in enumerate(rule_steps(rules, dt)):
        b_long = _rolling_mean_np(burn, lk)
        b_short = _rolling_mean_np(burn, sk)
        firing[ri] = (b_long >= thr) & (b_short >= thr)
        burn_long_peak[ri] = b_long.max()
    any_fire = firing.any(axis=0)
    alert = bool(any_fire.any())
    if alert:
        i_first = int(np.argmax(any_fire))
        t_first = float(ts[i_first])
        rule_first = int(np.argmax(firing[:, i_first]))
    else:
        t_first, rule_first = float("inf"), -1
    return {
        "alert": np.bool_(alert),
        "t_first_alert": np.float64(t_first),
        "rule_first_alert": np.int32(rule_first),
        "burn_peak": np.float64(burn_long_peak.max()),
        "firing": firing,
    }


# ---------------------------------------------------------------------------
# jitted / vmapped ensemble monitor
# ---------------------------------------------------------------------------

def _sweep_alerts_impl(avail, ts, target: float,
                       steps: Tuple[Tuple[int, int, float], ...]):
    import jax.numpy as jnp

    avail = jnp.asarray(avail, dtype=jnp.float32)     # (S, T)
    ts = jnp.asarray(ts, dtype=jnp.float32)           # (T,)
    T = avail.shape[-1]
    budget = jnp.float32(1.0 - target)
    burn = (jnp.float32(1.0) - avail) / budget        # (S, T)
    c = jnp.cumsum(burn, axis=-1)
    idx = jnp.arange(T)

    def roll(k: int):
        shifted = jnp.where(idx >= k, c[..., jnp.maximum(idx - k, 0)], 0.0)
        denom = jnp.minimum(idx + 1, k).astype(jnp.float32)
        return (c - shifted) / denom

    firing = []
    peaks = []
    for lk, sk, thr in steps:
        b_long, b_short = roll(lk), roll(sk)
        firing.append((b_long >= thr) & (b_short >= thr))
        peaks.append(jnp.max(b_long, axis=-1))
    firing = jnp.stack(firing, axis=-2)               # (S, R, T)
    any_fire = jnp.any(firing, axis=-2)               # (S, T)
    alert = jnp.any(any_fire, axis=-1)                # (S,)
    i_first = jnp.argmax(any_fire, axis=-1)           # (S,)
    t_first = jnp.where(alert, ts[i_first], jnp.float32(jnp.inf))
    first_col = jnp.take_along_axis(
        firing, i_first[..., None, None], axis=-1)[..., 0]  # (S, R)
    rule_first = jnp.where(
        alert, jnp.argmax(first_col, axis=-1), -1).astype(jnp.int32)
    return {
        "alert": alert,
        "t_first_alert": t_first,
        "rule_first_alert": rule_first,
        "burn_peak": jnp.max(jnp.stack(peaks, axis=-1), axis=-1),
    }


_SWEEP_CACHE: Dict[Tuple, object] = {}


def sweep_alerts(avail, ts, target: float = DEFAULT_TARGET,
                 rules: Sequence[BurnRateRule] = DEFAULT_RULES,
                 dt: Optional[float] = None) -> Dict[str, np.ndarray]:
    """Jitted ensemble burn-rate monitor.

    ``avail``: (S, T) availability traces (e.g. ``trace_availability``
    from ``sweep_timeline(..., return_traces=True)``); ``ts``: (T,)
    uniform grid.  Returns per-scenario numpy arrays: ``alert`` (bool),
    ``t_first_alert`` (inf when never), ``rule_first_alert`` (index into
    ``rules``, -1 when never) and ``burn_peak`` (peak long-window burn).
    """
    import jax

    ts_np = np.asarray(ts)
    if dt is None:
        dt = float(ts_np[1] - ts_np[0]) if len(ts_np) > 1 else 1.0
    steps = rule_steps(rules, dt)
    key = (float(target), steps)
    fn = _SWEEP_CACHE.get(key)
    if fn is None:
        fn = jax.jit(
            lambda a, t: _sweep_alerts_impl(a, t, float(target), steps))
        _SWEEP_CACHE[key] = fn
    avail = np.atleast_2d(np.asarray(avail))
    out = {k: np.asarray(v) for k, v in fn(avail, ts_np).items()}

    from repro import obs
    if obs.enabled():
        n_alert = int(out["alert"].sum())
        obs.set_gauge("ufa_slo_scenarios_alerting", n_alert)
        for ri, r in enumerate(rules):
            n = int((out["rule_first_alert"] == ri).sum())
            if n:
                obs.inc("ufa_slo_alerts_total", n, rule=r.name)
    return out


# ---------------------------------------------------------------------------
# verdict quality + host-side orchestration monitor
# ---------------------------------------------------------------------------

def alert_quality(alert: np.ndarray, violated: np.ndarray,
                  t_first_alert: Optional[np.ndarray] = None
                  ) -> Dict[str, float]:
    """Alert precision/recall against ground-truth SLA violation, plus
    median time-to-first-alert over true positives."""
    alert = np.asarray(alert, dtype=bool)
    violated = np.asarray(violated, dtype=bool)
    tp = int((alert & violated).sum())
    fp = int((alert & ~violated).sum())
    fn = int((~alert & violated).sum())
    out = {
        "n_scenarios": int(alert.size),
        "n_alerts": int(alert.sum()),
        "n_violations": int(violated.sum()),
        "precision": tp / (tp + fp) if (tp + fp) else 1.0,
        "recall": tp / (tp + fn) if (tp + fn) else 1.0,
    }
    if t_first_alert is not None:
        tta = np.asarray(t_first_alert, dtype=np.float64)[alert & violated]
        out["median_t_first_alert"] = (
            float(np.median(tta)) if tta.size else float("inf"))
    return out


def monitor_orchestrator(fleet, orch, target: float = DEFAULT_TARGET,
                         rules: Sequence[BurnRateRule] = DEFAULT_RULES,
                         n_samples: int = 48, seed: int = 3
                         ) -> Dict[str, object]:
    """Host-side SLO monitor for an event-loop failover run.

    Samples availability through the failover window (uniform grid) and
    runs the numpy burn-rate monitor over it.
    """
    from repro.core.metrics import availability_during_failover

    samples = availability_during_failover(
        fleet, orch, n_samples=n_samples, seed=seed)
    ts = np.array([t for t, _ in samples])
    avail = np.array([a for _, a in samples])
    verdict = alerts_np(avail, ts, target=target, rules=rules)

    from repro import obs
    if obs.enabled():
        if bool(verdict["alert"]):
            ri = int(verdict["rule_first_alert"])
            obs.inc("ufa_slo_alerts_total", rule=rules[ri].name)
        obs.set_gauge("ufa_slo_scenarios_alerting",
                      1.0 if bool(verdict["alert"]) else 0.0)
    tracer = obs.get_tracer()
    if tracer is not None and bool(verdict["alert"]):
        t0 = float(verdict["t_first_alert"])
        ri = int(verdict["rule_first_alert"])
        tracer.sim_instant(f"slo-alert:{rules[ri].name}", t0,
                           args={"burn_peak": float(verdict["burn_peak"])})
    return {
        "ts": ts, "availability": avail,
        "alert": bool(verdict["alert"]),
        "t_first_alert": float(verdict["t_first_alert"]),
        "rule_first_alert": int(verdict["rule_first_alert"]),
        "burn_peak": float(verdict["burn_peak"]),
        "rules": [r.name for r in rules],
        "target": target,
    }
