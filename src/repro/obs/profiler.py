"""Phase timing + JAX-aware pipeline profiling.

A ``Profiler`` times named phases of a pipeline run (detect, plan,
sweep, export, ...) into the ``ufa_phase_seconds`` histogram and — when
a tracer is attached — onto the host track of the Chrome trace.  The
JAX-aware pieces:

  * ``phase(..., sync=tree)`` calls ``jax.block_until_ready`` on the
    tree before stopping the clock, so async-dispatched device work is
    charged to the phase that launched it instead of whoever touches
    the result first;
  * ``jit_cache_watch`` diffs a jit-cache size callable (e.g.
    ``sweep_engine.compiled_variants``) around a block, turning
    recompiles into a counter delta + gauge;
  * ``throughput`` / ``padding_waste`` are the shared recording shims
    the engine call sites use, so gauge/counter naming stays in one
    place.

``jax`` is imported lazily (only when ``sync`` is actually used), so
the module itself stays importable in jax-free contexts.
"""

from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Any, Callable, Dict, Optional

from repro import obs


class Profiler:
    """Times phases into the registry (+ optional tracer host spans)."""

    def __init__(self, tracer=None):
        self.tracer = tracer if tracer is not None else obs.get_tracer()
        self.phases: Dict[str, float] = {}     # last wall time per phase

    @contextmanager
    def phase(self, name: str, sync: Any = None, **args):
        """Time a named phase.  ``sync`` is an optional pytree to
        ``block_until_ready`` before the clock stops."""
        t0 = time.perf_counter()
        span = (self.tracer.span(name, **args)
                if self.tracer is not None else None)
        if span is not None:
            span.__enter__()
        try:
            yield self
        finally:
            if sync is not None:
                import jax
                jax.block_until_ready(sync)
            if span is not None:
                span.__exit__(None, None, None)
            dt = time.perf_counter() - t0
            self.phases[name] = dt
            obs.observe("ufa_phase_seconds", dt, phase=name)

    @contextmanager
    def jit_cache_watch(self, cache_size: Callable[[], int],
                        gauge: str = "ufa_sweep_compiled_variants",
                        misses: str = "ufa_sweep_compile_misses_total"):
        """Diff a jit-cache size around a block: new entries are compile
        misses (counter), the post size a gauge."""
        before = cache_size()
        try:
            yield
        finally:
            after = cache_size()
            obs.set_gauge(gauge, after)
            if after > before:
                obs.inc(misses, after - before)


# ---------------------------------------------------------------------------
# recording shims shared by the engine call sites
# ---------------------------------------------------------------------------

def throughput(kind: str, n: int, seconds: float, **labels):
    """Record one {ingest,sweep,timeline} call's throughput: the
    ``*_total`` counter and the ``*_per_s`` gauge for ``kind``."""
    obs.inc(f"ufa_{kind}_total", n, **labels)
    if seconds > 0:
        obs.set_gauge(f"ufa_{kind}_per_s", n / seconds)


def padding_waste(n: int, padded: int,
                  gauge: str = "ufa_sweep_padding_waste_ratio"):
    """Record the padding-waste fraction of a bucket-padded mega-batch."""
    if padded > 0:
        obs.set_gauge(gauge, (padded - n) / padded)
