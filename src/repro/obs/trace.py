"""Structured span/event tracing with Chrome trace-event JSON export.

A ``Tracer`` collects *spans* (things with a beginning and a duration)
and *instants* (point events) and serialises them to the Chrome
trace-event format — ``chrome://tracing`` / https://ui.perfetto.dev
load the file directly, so a failover becomes a scrollable timeline:
MBB eviction waves, burst-capacity conversion, cloud restores and
traffic-shift milestones each render as real-width bars.

Two clock domains share one trace, kept apart as separate *processes*
(Perfetto renders them as separate tracks):

  * **sim** (pid ``SIM_PID``) — discrete-event simulation time.  The
    event loop runs handlers in zero sim-time, so a span's extent is
    *scheduled-at → fired-at*: exactly the window the orchestrator was
    "waiting on" that action, which is what an operator wants to see
    (a 45 s MBB wave shows up 45 s wide).  Handler host wall-time is
    attached as an arg instead.
  * **host** (pid ``HOST_PID``) — wall-clock phases from
    ``Profiler``/``Tracer.span()`` (ingest, compile, sweep, export).

Timestamps are microseconds (the format's native unit); sim seconds
map 1 s → 1 µs·1e6 so durations read naturally in Perfetto's ruler.
Zero third-party deps — stdlib ``json`` and ``time`` only.
"""

from __future__ import annotations

import json
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

SIM_PID = 1       # simulation-time track
HOST_PID = 2      # wall-clock track

_S_TO_US = 1e6


class Tracer:
    """Collects trace events; thread-safe; cheap to leave attached."""

    def __init__(self):
        self._lock = threading.Lock()
        self._events: List[Dict[str, Any]] = []
        self._t0_host = time.perf_counter()
        self._meta_done = set()
        self._meta(SIM_PID, "sim (event loop)")
        self._meta(HOST_PID, "host (wall clock)")

    # -- low-level emitters --------------------------------------------
    def _meta(self, pid: int, name: str, tid: int = 0):
        key = (pid, tid)
        if key in self._meta_done:
            return
        self._meta_done.add(key)
        self._events.append({
            "name": "process_name", "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": name}})
        self._events.append({
            "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": name}})

    def complete(self, name: str, ts_us: float, dur_us: float,
                 pid: int = SIM_PID, tid: int = 0,
                 args: Optional[Dict[str, Any]] = None):
        """A 'X' (complete) event: one bar from ts to ts+dur."""
        ev: Dict[str, Any] = {
            "name": name, "ph": "X", "pid": pid, "tid": tid,
            "ts": float(ts_us), "dur": max(float(dur_us), 0.0),
            "cat": "sim" if pid == SIM_PID else "host"}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    def instant(self, name: str, ts_us: float, pid: int = SIM_PID,
                tid: int = 0, args: Optional[Dict[str, Any]] = None):
        """An 'i' (instant) event: a point-in-time marker."""
        ev: Dict[str, Any] = {
            "name": name, "ph": "i", "pid": pid, "tid": tid,
            "ts": float(ts_us), "s": "p",
            "cat": "sim" if pid == SIM_PID else "host"}
        if args:
            ev["args"] = args
        with self._lock:
            self._events.append(ev)

    # -- sim-time conveniences (seconds in, µs stored) ------------------
    def sim_span(self, name: str, t_start_s: float, t_end_s: float,
                 args: Optional[Dict[str, Any]] = None):
        self.complete(name, t_start_s * _S_TO_US,
                      (t_end_s - t_start_s) * _S_TO_US,
                      pid=SIM_PID, args=args)

    def sim_instant(self, name: str, t_s: float,
                    args: Optional[Dict[str, Any]] = None):
        self.instant(name, t_s * _S_TO_US, pid=SIM_PID, args=args)

    # -- host wall-clock span ------------------------------------------
    def _host_now_us(self) -> float:
        return (time.perf_counter() - self._t0_host) * _S_TO_US

    @contextmanager
    def span(self, name: str, **args):
        """Wall-clock span on the host track (profiler phases)."""
        t0 = self._host_now_us()
        try:
            yield self
        finally:
            self.complete(name, t0, self._host_now_us() - t0,
                          pid=HOST_PID, args=args or None)

    # -- export ---------------------------------------------------------
    def to_chrome(self) -> Dict[str, Any]:
        with self._lock:
            return {"traceEvents": list(self._events),
                    "displayTimeUnit": "ms"}

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            json.dump(self.to_chrome(), f)
        return path

    def __len__(self) -> int:
        return len(self._events)


# ---------------------------------------------------------------------------
# Optional process-global tracer (None unless a run attaches one).
# ---------------------------------------------------------------------------

_TRACER: Optional[Tracer] = None


def get_tracer() -> Optional[Tracer]:
    return _TRACER


def set_tracer(tracer: Optional[Tracer]) -> Optional[Tracer]:
    global _TRACER
    prev, _TRACER = _TRACER, tracer
    return prev


def validate_chrome_trace(obj: Any) -> List[str]:
    """Schema-check a Chrome trace object; returns a list of problems
    (empty == valid).  Used by tests and the CI smoke step."""
    errs: List[str] = []
    if not isinstance(obj, dict) or "traceEvents" not in obj:
        return ["top level must be an object with 'traceEvents'"]
    evs = obj["traceEvents"]
    if not isinstance(evs, list) or not evs:
        return ["'traceEvents' must be a non-empty list"]
    for i, ev in enumerate(evs):
        if not isinstance(ev, dict):
            errs.append(f"event {i}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "i", "I", "M", "B", "E", "C"):
            errs.append(f"event {i}: bad ph {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            errs.append(f"event {i}: missing name")
        if not isinstance(ev.get("pid"), int):
            errs.append(f"event {i}: missing pid")
        if ph == "M":
            continue
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errs.append(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errs.append(f"event {i}: bad dur {dur!r}")
    return errs
