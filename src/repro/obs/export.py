"""Prometheus text exposition + JSONL snapshots (and their validators).

One ``Registry.collect()`` snapshot feeds both exporters, so live
metrics, bench rows and CI artifacts share a single source of truth:

  * :func:`to_prometheus` — the text exposition format (version 0.0.4):
    ``# HELP`` / ``# TYPE`` headers, escaped label values, cumulative
    ``_bucket{le=...}`` / ``_sum`` / ``_count`` histogram series;
  * :func:`write_jsonl` — one JSON object per snapshot appended to a
    ``.jsonl`` file (timestamped, with optional run metadata) for
    offline trajectory analysis;
  * :func:`validate_prometheus` / ``trace.validate_chrome_trace`` —
    format checkers used by tests and the CI smoke step
    (``python -m repro.obs.export --validate metrics.prom
    --validate-trace failover_trace.json``).

Zero third-party deps — stdlib only.
"""

from __future__ import annotations

import json
import math
import re
import time
from typing import Any, Dict, List, Optional, Tuple

from .registry import Registry, default_registry


def _esc_help(s: str) -> str:
    return s.replace("\\", "\\\\").replace("\n", "\\n")


def _esc_label(s: str) -> str:
    return (s.replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _fmt_labels(labels: Dict[str, str], extra: Tuple[Tuple[str, str], ...] = ()
                ) -> str:
    items = list(labels.items()) + list(extra)
    if not items:
        return ""
    body = ",".join(f'{k}="{_esc_label(str(v))}"' for k, v in items)
    return "{" + body + "}"


def _fmt_value(v: float) -> str:
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    if math.isnan(v):
        return "NaN"
    return repr(float(v))


def _fmt_le(le: float) -> str:
    return "+Inf" if math.isinf(le) else f"{le:g}"


def to_prometheus(registry: Optional[Registry] = None) -> str:
    """Render a registry snapshot in the Prometheus text format."""
    reg = registry if registry is not None else default_registry()
    lines: List[str] = []
    for row_name, rows in _group_by_name(reg.collect()):
        kind = rows[0]["kind"]
        help_ = rows[0]["help"]
        if help_:
            lines.append(f"# HELP {row_name} {_esc_help(help_)}")
        lines.append(f"# TYPE {row_name} {kind}")
        for row in rows:
            labels = row["labels"]
            if kind == "histogram":
                for le, c in row["buckets"]:
                    lines.append(
                        f"{row_name}_bucket"
                        f"{_fmt_labels(labels, (('le', _fmt_le(le)),))}"
                        f" {c}")
                lines.append(f"{row_name}_sum{_fmt_labels(labels)}"
                             f" {_fmt_value(row['sum'])}")
                lines.append(f"{row_name}_count{_fmt_labels(labels)}"
                             f" {row['count']}")
            else:
                lines.append(f"{row_name}{_fmt_labels(labels)}"
                             f" {_fmt_value(row['value'])}")
    return "\n".join(lines) + ("\n" if lines else "")


def _group_by_name(rows: List[Dict[str, Any]]
                   ) -> List[Tuple[str, List[Dict[str, Any]]]]:
    out: List[Tuple[str, List[Dict[str, Any]]]] = []
    for row in rows:
        if out and out[-1][0] == row["name"]:
            out[-1][1].append(row)
        else:
            out.append((row["name"], [row]))
    return out


def write_prometheus(path: str, registry: Optional[Registry] = None) -> str:
    with open(path, "w") as f:
        f.write(to_prometheus(registry))
    return path


def write_jsonl(path: str, registry: Optional[Registry] = None,
                meta: Optional[Dict[str, Any]] = None) -> str:
    """Append one timestamped snapshot object to a JSONL file."""
    reg = registry if registry is not None else default_registry()
    rows = reg.collect()
    for row in rows:                       # JSON has no Infinity
        if "buckets" in row:
            row["buckets"] = [["+Inf" if math.isinf(le) else le, c]
                              for le, c in row["buckets"]]
    snap = {"ts_unix": time.time(), "metrics": rows}
    if meta:
        snap["meta"] = meta
    with open(path, "a") as f:
        f.write(json.dumps(snap) + "\n")
    return path


# ---------------------------------------------------------------------------
# exposition-format parsing + validation
# ---------------------------------------------------------------------------

_SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>.*)\})?'
    r'\s+(?P<value>[^\s]+)(?:\s+(?P<ts>-?\d+))?$')
_LABEL_PAIR_RE = re.compile(
    r'\s*([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"\s*(?:,|$)')
_UNESCAPE_RE = re.compile(r'\\(.)')


def _unescape_label(s: str) -> str:
    # single pass: sequential str.replace would corrupt e.g. a literal
    # backslash followed by 'n' into a newline
    return _UNESCAPE_RE.sub(
        lambda m: "\n" if m.group(1) == "n" else m.group(1), s)


def _parse_value(s: str) -> float:
    if s == "+Inf":
        return math.inf
    if s == "-Inf":
        return -math.inf
    if s == "NaN":
        return math.nan
    return float(s)


def parse_prometheus(text: str) -> Dict[str, Dict[str, Any]]:
    """Parse exposition text into ``{name: {"type":..., "help":...,
    "samples": [(sample_name, labels, value)]}}``.  Raises ``ValueError``
    on malformed lines (validation wraps this)."""
    out: Dict[str, Dict[str, Any]] = {}

    def family(name: str) -> Dict[str, Any]:
        return out.setdefault(
            name, {"type": None, "help": None, "samples": []})

    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            parts = line[len("# HELP "):].split(" ", 1)
            family(parts[0])["help"] = parts[1] if len(parts) > 1 else ""
            continue
        if line.startswith("# TYPE "):
            parts = line[len("# TYPE "):].split(" ", 1)
            if len(parts) != 2 or parts[1] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                raise ValueError(f"line {ln}: bad TYPE line {line!r}")
            family(parts[0])["type"] = parts[1]
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"line {ln}: unparseable sample {line!r}")
        name = m.group("name")
        labels: Dict[str, str] = {}
        body = m.group("labels")
        if body is not None:
            pos = 0
            while pos < len(body):
                pm = _LABEL_PAIR_RE.match(body, pos)
                if not pm:
                    raise ValueError(
                        f"line {ln}: bad label syntax in {line!r}")
                labels[pm.group(1)] = _unescape_label(pm.group(2))
                pos = pm.end()
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            stem = name[:-len(suffix)] if name.endswith(suffix) else None
            if stem and out.get(stem, {}).get("type") == "histogram":
                base = stem
                break
        family(base)["samples"].append(
            (name, labels, _parse_value(m.group("value"))))
    return out


def validate_prometheus(text: str) -> List[str]:
    """Check exposition text; returns a list of problems (empty == OK):
    parseable lines, a TYPE for every family, non-negative counters, and
    coherent histograms (cumulative buckets, ``+Inf`` bucket == _count).
    """
    errs: List[str] = []
    try:
        fams = parse_prometheus(text)
    except ValueError as e:
        return [str(e)]
    if not fams:
        return ["no metric families found"]
    for name, fam in fams.items():
        if fam["type"] is None:
            errs.append(f"{name}: no # TYPE line")
            continue
        if not fam["samples"]:
            errs.append(f"{name}: no samples")
            continue
        if fam["type"] == "counter":
            for sname, labels, v in fam["samples"]:
                if not (v >= 0):
                    errs.append(f"{name}{labels}: negative counter {v}")
        if fam["type"] == "histogram":
            series: Dict[Tuple, Dict[str, Any]] = {}
            for sname, labels, v in fam["samples"]:
                key = tuple(sorted((k, vv) for k, vv in labels.items()
                                   if k != "le"))
                s = series.setdefault(key, {"buckets": [], "sum": None,
                                            "count": None})
                if sname.endswith("_bucket"):
                    if "le" not in labels:
                        errs.append(f"{name}{labels}: _bucket without le")
                        continue
                    s["buckets"].append((_parse_value(labels["le"]), v))
                elif sname.endswith("_sum"):
                    s["sum"] = v
                elif sname.endswith("_count"):
                    s["count"] = v
                else:
                    errs.append(f"{name}: stray sample {sname}")
            for key, s in series.items():
                bs = sorted(s["buckets"])
                if not bs or not math.isinf(bs[-1][0]):
                    errs.append(f"{name}{dict(key)}: no +Inf bucket")
                    continue
                counts = [c for _, c in bs]
                if any(b > a for b, a in zip(counts, counts[1:])):
                    errs.append(f"{name}{dict(key)}: non-cumulative buckets")
                if s["count"] is None or s["sum"] is None:
                    errs.append(f"{name}{dict(key)}: missing _sum/_count")
                elif counts[-1] != s["count"]:
                    errs.append(
                        f"{name}{dict(key)}: +Inf bucket {counts[-1]} "
                        f"!= _count {s['count']}")
    return errs


# ---------------------------------------------------------------------------
# CLI: CI smoke validation
# ---------------------------------------------------------------------------

def _main(argv: Optional[List[str]] = None) -> int:
    import argparse
    ap = argparse.ArgumentParser(
        description="Validate observability artifacts")
    ap.add_argument("--validate", metavar="PROM",
                    help="Prometheus text file to validate")
    ap.add_argument("--validate-trace", metavar="JSON",
                    help="Chrome trace JSON file to validate")
    args = ap.parse_args(argv)
    rc = 0
    if args.validate:
        with open(args.validate) as f:
            errs = validate_prometheus(f.read())
        if errs:
            rc = 1
            for e in errs:
                print(f"PROM INVALID: {e}")
        else:
            n = len(parse_prometheus(open(args.validate).read()))
            print(f"prometheus OK: {args.validate} ({n} families)")
    if args.validate_trace:
        from .trace import validate_chrome_trace
        with open(args.validate_trace) as f:
            obj = json.load(f)
        errs = validate_chrome_trace(obj)
        if errs:
            rc = 1
            for e in errs:
                print(f"TRACE INVALID: {e}")
        else:
            print(f"trace OK: {args.validate_trace} "
                  f"({len(obj['traceEvents'])} events)")
    return rc


if __name__ == "__main__":
    raise SystemExit(_main())
