"""repro.obs — the failover observability plane.

Zero-dependency instrumentation substrate for the UFA repro: a labeled
metrics registry ([`registry`](registry.py)), Chrome-trace span/event
tracing for the discrete-event orchestration ([`trace`](trace.py)),
multi-window multi-burn-rate SLO monitors ([`slo`](slo.py)), JAX-aware
pipeline profiling ([`profiler`](profiler.py)) and Prometheus/JSONL
export ([`export`](export.py)).

Importing this package pulls in **no** jax/numpy — ``slo``/``profiler``
are imported explicitly by consumers that already depend on jax.  Core
hot paths call the module-level helpers below, which no-op on a single
bool when the plane is off::

    from repro import obs
    ...
    if obs.enabled():                       # one branch per call
        obs.inc("ufa_ingest_records_total", n, backend="numpy")
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

from .registry import (Counter, Gauge, Histogram, Metric, Registry,
                       default_registry, disable, enable, enabled)
from .trace import Tracer, get_tracer, set_tracer, validate_chrome_trace

__all__ = [
    "Counter", "Gauge", "Histogram", "Metric", "Registry", "Tracer",
    "default_registry", "enable", "disable", "enabled",
    "get_tracer", "set_tracer", "validate_chrome_trace",
    "CATALOG", "inc", "set_gauge", "observe", "value", "describe",
]

# ---------------------------------------------------------------------------
# Metric catalogue — every metric the instrumented stack emits, with its
# kind, help string and label names.  One authoritative place so call
# sites stay one-liners and the README table is generated, not drifted.
# ---------------------------------------------------------------------------

# name -> (kind, help, label names, histogram buckets or None)
CATALOG: Dict[str, Tuple[str, str, Tuple[str, ...],
                         Optional[Tuple[float, ...]]]] = {
    # -- telemetry ingest / detection (core/dependency.py) --------------
    "ufa_ingest_records_total": (
        "counter", "RPC telemetry records ingested", ("backend",), None),
    "ufa_ingest_batches_total": (
        "counter", "ingest_batch calls", ("backend",), None),
    "ufa_ingest_records_per_s": (
        "gauge", "throughput of the most recent ingest_batch call",
        (), None),
    "ufa_detect_runs_total": (
        "counter", "fail-close detection passes", (), None),
    "ufa_detect_edges_flagged": (
        "gauge", "edges flagged fail-close by the latest detection pass",
        (), None),
    # -- fused sweep engine (core/sweep_engine.py) ----------------------
    "ufa_sweep_runs_total": (
        "counter", "SweepEngine.run calls", (), None),
    "ufa_sweep_scenarios_total": (
        "counter", "scenarios evaluated by the fused sweep engine",
        (), None),
    "ufa_sweep_scenarios_per_s": (
        "gauge", "throughput of the most recent SweepEngine.run call",
        (), None),
    "ufa_sweep_run_seconds": (
        "histogram", "SweepEngine.run wall time", (), None),
    "ufa_sweep_padding_waste_ratio": (
        "gauge", "fraction of the padded mega-batch that was padding "
        "in the most recent run", (), None),
    "ufa_sweep_compiled_variants": (
        "gauge", "programs resident in the sweep engine jit cache",
        (), None),
    "ufa_sweep_compile_misses_total": (
        "counter", "jit cache misses (new compiled variants) observed "
        "across SweepEngine.run calls", (), None),
    # -- temporal kernel (core/timeline_sim.py) -------------------------
    "ufa_timeline_scenarios_total": (
        "counter", "scenarios evaluated by sweep_timeline", (), None),
    "ufa_timeline_scenarios_per_s": (
        "gauge", "throughput of the most recent sweep_timeline call",
        (), None),
    # -- hardening planner / regression gate (graph/planner.py) ---------
    "ufa_planner_rounds_total": (
        "counter", "hardening-planner greedy rounds", (), None),
    "ufa_planner_hardened_edges_total": (
        "counter", "edges hardened by plan_hardening", (), None),
    "ufa_planner_broken_critical": (
        "gauge", "critical services still reachable by failure "
        "propagation after the latest planner round", (), None),
    "ufa_gate_checks_total": (
        "counter", "dependency regression-gate checks", ("verdict",),
        None),
    "ufa_gate_violations": (
        "gauge", "unsafe critical-path edges found by the latest gate "
        "check", (), None),
    # -- orchestrator / event loop (core/omg.py, core/events.py) --------
    "ufa_orch_events_total": (
        "counter", "discrete events fired by the orchestration event "
        "loop", ("label",), None),
    "ufa_orch_envs_total": (
        "counter", "service environments acted on during failover",
        ("action",), None),
    # -- SLO monitor (obs/slo.py) ---------------------------------------
    "ufa_slo_alerts_total": (
        "counter", "burn-rate alerts raised", ("rule",), None),
    "ufa_slo_scenarios_alerting": (
        "gauge", "scenarios alerting in the latest monitored ensemble",
        (), None),
    # -- chaos campaigns (chaos/campaign.py, chaos/report.py) -----------
    "ufa_chaos_rounds_total": (
        "counter", "chaos-campaign search rounds executed", (), None),
    "ufa_chaos_evals_total": (
        "counter", "engine scenario-evaluations submitted by chaos "
        "campaigns", (), None),
    "ufa_chaos_rays_localized": (
        "gauge", "fault-severity rays whose SLA frontier the latest "
        "campaign localized to tolerance", (), None),
    "ufa_chaos_frontier_severity": (
        "gauge", "localized frontier severity of a fault-severity ray "
        "in the latest campaign", ("ray",), None),
    "ufa_chaos_speedup_vs_grid": (
        "gauge", "engine-evaluation savings of the latest campaign vs "
        "an exhaustive per-ray grid at the same resolution", (), None),
    # -- serving plane (serving/scheduler.py, serving/failover.py,
    #    serving/workload.py) ---------------------------------------------
    "ufa_serving_requests_total": (
        "counter", "request-plane final verdicts by tier and outcome",
        ("tier", "outcome"), None),
    "ufa_serving_retries_total": (
        "counter", "bounded request retries scheduled (backoff + jitter)",
        ("tier",), None),
    "ufa_serving_request_latency_s": (
        "histogram", "end-to-end request latency in simulated seconds",
        ("tier",), (1.0, 5.0, 15.0, 30.0, 60.0, 120.0, 300.0, 600.0,
                    1800.0, 3600.0)),
    "ufa_serving_replicas_active": (
        "gauge", "replica target actuated by the failover bridge",
        ("tier",), None),
    "ufa_serving_queue_depth": (
        "gauge", "scheduler queue depth at the latest drill step",
        ("tier",), None),
    # -- profiler / bench -----------------------------------------------
    "ufa_phase_seconds": (
        "histogram", "wall time of named pipeline phases", ("phase",),
        None),
    "ufa_bench_us_per_call": (
        "gauge", "benchmark harness rows (microseconds per call)",
        ("name",), None),
}


def describe(name: str) -> Tuple[str, str, Tuple[str, ...]]:
    kind, help_, labels, _ = CATALOG[name]
    return kind, help_, labels


def _metric(name: str) -> Metric:
    reg = default_registry()
    m = reg.get(name)
    if m is not None:
        return m
    kind, help_, labels, buckets = CATALOG.get(
        name, ("gauge", "", (), None))
    if kind == "counter":
        return reg.counter(name, help_, labels)
    if kind == "histogram":
        return reg.histogram(name, help_, labels, buckets=buckets)
    return reg.gauge(name, help_, labels)


# ---------------------------------------------------------------------------
# Hot-path helpers: free when the plane is off (one bool check, no
# allocation), catalogue-driven when it is on.
# ---------------------------------------------------------------------------

def inc(name: str, v: float = 1.0, /, **labels):
    if not enabled():
        return
    m = _metric(name)
    (m.labels(**labels) if labels else m).inc(v)


def set_gauge(name: str, v: float, /, **labels):
    if not enabled():
        return
    m = _metric(name)
    (m.labels(**labels) if labels else m).set(v)


def observe(name: str, v: float, /, **labels):
    if not enabled():
        return
    m = _metric(name)
    (m.labels(**labels) if labels else m).observe(v)


def value(name: str, /, **labels) -> float:
    reg = default_registry()
    if reg.get(name) is None:
        return 0.0                  # never touched (e.g. plane was off)
    return reg.value(name, **labels)
