"""Checkpoint/restore with elastic reshard-on-load.

Format: one directory per step containing a msgpack-free, dependency-free
layout — ``manifest.json`` (tree structure, shapes, dtypes) plus one ``.npy``
-style raw buffer per leaf.  Arrays are written *unsharded* (gathered) with
layout metadata, so a checkpoint saved from an N-device mesh restores onto
any M-device mesh: the loader places each array with the target sharding
(elastic scaling — UFA's BBM restore path uses exactly this to revive a
preempted training job on whatever capacity the burst cluster offers).

``AsyncCheckpointer`` double-buffers: device->host transfer happens on the
caller thread (cheap), serialization + fsync on a background thread, so the
training loop is not blocked by storage (the paper's MBB philosophy:
overlap the slow path with useful work).
"""

from __future__ import annotations

import json
import os
import shutil
import struct
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_MANIFEST = "manifest.json"


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)
    leaves = []
    for kp, leaf in flat[0]:
        path = "/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                        for k in kp)
        leaves.append((path, leaf))
    return leaves, flat[1]


def save_checkpoint(directory: str | Path, step: int, tree: Any,
                    extra: Optional[Dict[str, Any]] = None) -> Path:
    """Atomic (write-to-temp + rename) full checkpoint."""
    directory = Path(directory)
    final = directory / f"step_{step:010d}"
    tmp = directory / f".tmp_step_{step:010d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (path, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.bin"
        manifest["leaves"].append({
            "path": path, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype)})
        with open(tmp / fname, "wb") as f:
            f.write(arr.tobytes())
    with open(tmp / _MANIFEST, "w") as f:
        json.dump(manifest, f)
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str | Path) -> Optional[int]:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in directory.iterdir()
             if p.name.startswith("step_")]
    return max(steps) if steps else None


def load_checkpoint(directory: str | Path, like: Any,
                    step: Optional[int] = None,
                    shardings: Any = None) -> Tuple[Any, Dict[str, Any]]:
    """Restore into the structure of ``like``; if ``shardings`` (a matching
    pytree of NamedSharding) is given, each array is placed with it —
    reshard-on-load onto any mesh."""
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        assert step is not None, f"no checkpoints in {directory}"
    d = directory / f"step_{step:010d}"
    manifest = json.loads((d / _MANIFEST).read_text())
    by_path = {m["path"]: m for m in manifest["leaves"]}

    leaves, treedef = _flatten_with_paths(like)
    shard_leaves = None
    if shardings is not None:
        shard_leaves = [s for _, s in _flatten_with_paths(shardings)[0]]

    out = []
    for i, (path, leaf) in enumerate(leaves):
        m = by_path.get(path)
        assert m is not None, f"checkpoint missing leaf {path}"
        raw = (d / m["file"]).read_bytes()
        arr = np.frombuffer(raw, dtype=np.dtype(m["dtype"])).reshape(m["shape"])
        target_dtype = getattr(leaf, "dtype", arr.dtype)
        arr = arr.astype(target_dtype, copy=False)
        if shard_leaves is not None:
            out.append(jax.device_put(arr, shard_leaves[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


class AsyncCheckpointer:
    """Double-buffered async writer: ``save()`` returns once the host copy
    exists; serialization happens on a daemon thread.  ``wait()`` joins."""

    def __init__(self, directory: str | Path, keep: int = 3):
        self.directory = Path(directory)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = sorted(p for p in self.directory.iterdir()
                       if p.name.startswith("step_"))
        for p in steps[:-self.keep]:
            shutil.rmtree(p, ignore_errors=True)
