"""Core neural layers, pure JAX (no flax).

Parameters are plain pytrees (nested dicts of jnp arrays).  Every layer is a
pair of functions: ``init_*(key, cfg) -> params`` and ``apply(params, x, ...)``.
Layers are written so that a stack of them can be driven by ``jax.lax.scan``
with parameters stacked along a leading layer axis.

Compute-dtype policy: matmuls run in the activation dtype (bf16 in production)
with fp32 accumulation via ``preferred_element_type``; softmax / norms / router
run in fp32.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.ctx import hint

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def _dense_init(key, shape, dtype, in_axis_size=None):
    """Truncated-normal fan-in init (maxtext-style)."""
    fan_in = in_axis_size if in_axis_size is not None else shape[0]
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def _embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype=dtype)}


def rmsnorm(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def rmsnorm_noscale(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """Scale-free RMS norm (used for qk-norm when per-head scale is folded)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * lax.rsqrt(var + eps)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, d_head); positions: (..., seq) int32."""
    d_head = x.shape[-1]
    freqs = rope_frequencies(d_head, theta)  # (d_head//2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, d/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., seq, 1, d/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, optional sliding window, optional qk-norm)
# ---------------------------------------------------------------------------


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int, d_head: int,
                   qk_norm: bool, dtype=jnp.float32) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(k1, (d_model, n_heads * d_head), dtype),
        "wk": _dense_init(k2, (d_model, n_kv_heads * d_head), dtype),
        "wv": _dense_init(k3, (d_model, n_kv_heads * d_head), dtype),
        "wo": _dense_init(k4, (n_heads * d_head, d_model), dtype, in_axis_size=n_heads * d_head),
    }
    if qk_norm:
        p["q_norm"] = init_rmsnorm(d_head, dtype)
        p["k_norm"] = init_rmsnorm(d_head, dtype)
    return p


def quantize_kv(x: jnp.ndarray):
    """Per-(token, head) symmetric int8 quantization of K/V.
    x: (B, S, KV, dh) -> (int8 values, fp32 scales (B, S, KV))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def _attn_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, window) -> jnp.ndarray:
    """Boolean (q, k) mask. window: scalar (traced ok); <=0 means global causal."""
    causal = k_pos[None, :] <= q_pos[:, None]
    in_window = (q_pos[:, None] - k_pos[None, :]) < jnp.maximum(window, 1)
    return jnp.where(window > 0, causal & in_window, causal)


def _blocked_local_attention(q, k, v, window, block: int, scale: float):
    """Sliding-window attention computed over (block, 2*block) tiles: each
    query block attends to itself + the previous block, masked to the exact
    (traced) window.  Cuts score cost from O(S^2) to O(S * 2*block) — the
    pure-XLA analogue of the windowed flash kernel.  Requires S % block == 0
    and window <= block.  q/k/v: (B, S, H, d) with KV pre-repeated."""
    B, S, H, d = q.shape
    nb = S // block
    qb = q.reshape(B, nb, block, H, d)
    kb = k.reshape(B, nb, block, H, d)
    vb = v.reshape(B, nb, block, H, d)
    pad = ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0))
    k2 = jnp.concatenate([jnp.pad(kb, pad)[:, :-1], kb], axis=2)  # (B,nb,2b,H,d)
    v2 = jnp.concatenate([jnp.pad(vb, pad)[:, :-1], vb], axis=2)
    scores = jnp.einsum("bnqhd,bnkhd->bnhqk", qb, k2,
                        preferred_element_type=jnp.float32) * scale
    q_pos = jnp.arange(block)[:, None]                    # within-block
    k_pos = jnp.arange(2 * block)[None, :] - block        # relative to block
    dist = q_pos - k_pos
    mask = (dist >= 0) & (dist < jnp.maximum(window, 1))
    # first block has no predecessor: mask the padded half
    first = (jnp.arange(nb) == 0)[None, :, None, None, None]
    pad_mask = (k_pos >= 0)[None, None, None, :, :] | ~first
    scores = jnp.where(mask[None, None, None, :, :] & pad_mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bnhqk,bnkhd->bnqhd", probs, v2)
    return out.reshape(B, S, H, d)


def attention(params: Params, x: jnp.ndarray, *, n_heads: int, n_kv_heads: int,
              d_head: int, theta: float, window=0, positions: Optional[jnp.ndarray] = None,
              qk_norm: bool = False, eps: float = 1e-6,
              kv_cache: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              cache_len: Optional[jnp.ndarray] = None,
              local_block: int = 0,
              seq_parallel: bool = False) -> Tuple[jnp.ndarray, Optional[Tuple]]:
    """Multi-head GQA attention.

    x: (B, S, D).  If ``kv_cache`` is given (decode path), it is a tuple
    (k_cache, v_cache) of shape (B, max_seq, n_kv, d_head) and ``cache_len``
    is the number of valid entries; the new k/v are written at cache_len and
    attention runs over the cache.  Returns (out, new_cache).
    """
    B, S, D = x.shape
    cdt = x.dtype
    x = hint(x, "batch", None, "embed")
    q = hint(x @ params["wq"].astype(cdt), "batch", None, "ff")
    k = hint(x @ params["wk"].astype(cdt), "batch", None, "ff")
    v = hint(x @ params["wv"].astype(cdt), "batch", None, "ff")
    q = q.reshape(B, S, n_heads, d_head)
    k = k.reshape(B, S, n_kv_heads, d_head)
    v = v.reshape(B, S, n_kv_heads, d_head)

    if qk_norm:
        q = rmsnorm(params["q_norm"], q, eps)
        k = rmsnorm(params["k_norm"], k, eps)

    if positions is None:
        if kv_cache is not None:
            positions = (cache_len + jnp.arange(S, dtype=jnp.int32))[None, :]  # (1, S)
        else:
            positions = jnp.arange(S, dtype=jnp.int32)[None, :]
    q = apply_rope(q, positions, theta)
    k = apply_rope(k, positions, theta)

    new_cache = None
    if kv_cache is not None:
        from repro.dist import ctx as dctx
        from repro.dist.splitkv import splitkv_decode_attention
        quant = len(kv_cache) == 4
        if quant:
            k_cache, v_cache, k_scale, v_scale = kv_cache
            kq, ks_new = quantize_kv(k)
            vq, vs_new = quantize_kv(v)
        else:
            k_cache, v_cache = kv_cache
            k_scale = v_scale = None
        max_seq = k_cache.shape[1]
        start = cache_len.reshape(())
        mesh = dctx.current_mesh()
        n_seq = dctx.axis_size("kv_seq")
        if (mesh is not None and n_seq > 1 and S == 1
                and max_seq % n_seq == 0):
            # manual split-KV (flash-decode) under shard_map: local cache
            # write + partial softmax with pmax/psum LSE merge.
            batch_rule = dctx.get_rule("batch") or ()
            baxes = ((batch_rule,) if isinstance(batch_rule, str)
                     else tuple(batch_rule))
            seq_rule = dctx.get_rule("kv_seq")
            if quant:
                out, caches = splitkv_decode_attention(
                    q, kq, vq, k_cache, v_cache, start, window,
                    mesh=mesh, batch_axes=baxes, seq_axis=seq_rule,
                    k_scale=k_scale, v_scale=v_scale,
                    new_scales=(ks_new, vs_new))
            else:
                out, caches = splitkv_decode_attention(
                    q, k, v, k_cache, v_cache, start, window,
                    mesh=mesh, batch_axes=baxes, seq_axis=seq_rule)
            out = out.reshape(B, S, n_heads * d_head)
            return (hint(out @ params["wo"].astype(cdt),
                         "batch", None, "embed"), caches)
        # single-device / unsharded fallback
        if quant:
            k_cache = lax.dynamic_update_slice(k_cache, kq, (0, start, 0, 0))
            v_cache = lax.dynamic_update_slice(v_cache, vq, (0, start, 0, 0))
            k_scale = lax.dynamic_update_slice(k_scale, ks_new, (0, start, 0))
            v_scale = lax.dynamic_update_slice(v_scale, vs_new, (0, start, 0))
            new_cache = (k_cache, v_cache, k_scale, v_scale)
            k_all = (k_cache.astype(jnp.float32)
                     * k_scale[..., None]).astype(cdt)
            v_all = (v_cache.astype(jnp.float32)
                     * v_scale[..., None]).astype(cdt)
        else:
            k_cache = lax.dynamic_update_slice(
                k_cache, k.astype(k_cache.dtype), (0, start, 0, 0))
            v_cache = lax.dynamic_update_slice(
                v_cache, v.astype(v_cache.dtype), (0, start, 0, 0))
            new_cache = (k_cache, v_cache)
            k_all = k_cache.astype(cdt)
            v_all = v_cache.astype(cdt)
        k_pos = jnp.arange(max_seq, dtype=jnp.int32)
        valid = k_pos[None, :] < (start + S)  # (1, max_seq)
    else:
        k_all, v_all = k, v
        k_pos = positions[0].astype(jnp.int32)
        valid = None

    q_pos = positions[0].astype(jnp.int32) if kv_cache is None else (
        cache_len + jnp.arange(S, dtype=jnp.int32))
    mask = _attn_mask(q_pos, k_pos, window)  # (S, K)
    if valid is not None:
        mask = mask & valid[0][None, :]

    group = n_heads // n_kv_heads
    if kv_cache is None:
        # train/prefill: repeat KV to full heads so scores shard per-head
        # over the model axis (keeps fp32 score memory per device bounded).
        if seq_parallel:
            # SP attention for TP-unfriendly head counts: shard the QUERY
            # sequence over the model axis instead of heads; K/V replicate.
            kr = hint(jnp.repeat(k_all, group, axis=2), "batch", None, None, None)
            vr = hint(jnp.repeat(v_all, group, axis=2), "batch", None, None, None)
            qh = hint(q, "batch", "seq", None, None)
        else:
            kr = hint(jnp.repeat(k_all, group, axis=2), "batch", None, "heads", None)
            vr = hint(jnp.repeat(v_all, group, axis=2), "batch", None, "heads", None)
            qh = hint(q, "batch", None, "heads", None)
        scale = 1.0 / math.sqrt(d_head)
        if local_block > 0:
            def _local(qkv):
                return _blocked_local_attention(*qkv, window, local_block, scale)

            def _full(qkv):
                qh, kr, vr = qkv
                s = jnp.einsum("bshd,bkhd->bhsk", qh, kr,
                               preferred_element_type=jnp.float32) * scale
                if seq_parallel:
                    s = hint(s, "batch", None, "seq", None)
                else:
                    s = hint(s, "batch", "heads", None, None)
                s = jnp.where(mask[None, None, :, :], s, -1e30)
                p = jax.nn.softmax(s, axis=-1).astype(cdt)
                return jnp.einsum("bhsk,bkhd->bshd", p, vr)

            out = lax.cond(window > 0, _local, _full, (qh, kr, vr))
        else:
            scores = jnp.einsum("bshd,bkhd->bhsk", qh, kr,
                                preferred_element_type=jnp.float32) * scale
            if seq_parallel:
                scores = hint(scores, "batch", None, "seq", None)
            else:
                scores = hint(scores, "batch", "heads", None, None)
            scores = jnp.where(mask[None, None, :, :], scores, -1e30)
            probs = jax.nn.softmax(scores, axis=-1).astype(cdt)
            out = jnp.einsum("bhsk,bkhd->bshd", probs, vr)
    else:
        # decode: grouped-query attention against the (seq-sharded) cache
        qg = q.reshape(B, S, n_kv_heads, group, d_head)
        scores = jnp.einsum("bsngh,bknh->bngsk", qg, k_all,
                            preferred_element_type=jnp.float32)
        scores = hint(scores / math.sqrt(d_head),
                      "batch", None, None, None, "kv_seq")
        scores = jnp.where(mask[None, None, None, :, :], scores, -1e30)
        probs = jax.nn.softmax(scores, axis=-1).astype(cdt)
        out = jnp.einsum("bngsk,bknh->bsngh", probs, v_all)
    out = hint(out.reshape(B, S, n_heads * d_head), "batch", None, "ff")
    return hint(out @ params["wo"].astype(cdt), "batch", None, "embed"), new_cache


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(k1, (d_model, d_ff), dtype),
        "w_up": _dense_init(k2, (d_model, d_ff), dtype),
        "w_down": _dense_init(k3, (d_ff, d_model), dtype, in_axis_size=d_ff),
    }


def mlp(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    cdt = x.dtype
    nb = x.ndim - 2  # leading batch-like dims
    bspec = ("batch",) + (None,) * (nb - 1) if nb else ()
    x = hint(x, *bspec, None, "embed") if nb else x
    g = hint(x @ params["w_gate"].astype(cdt), *bspec, None, "ff")
    u = hint(x @ params["w_up"].astype(cdt), *bspec, None, "ff")
    out = (jax.nn.silu(g) * u) @ params["w_down"].astype(cdt)
    return hint(out, *bspec, None, "embed") if nb else out


# ---------------------------------------------------------------------------
# Mixture of Experts (top-k, capacity-dropped, sort-free scatter dispatch)
# ---------------------------------------------------------------------------


def init_moe(key, d_model: int, n_experts: int, d_ff: int, n_shared: int = 0,
             dtype=jnp.float32) -> Params:
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": _dense_init(k1, (d_model, n_experts), jnp.float32),
        "w_gate": _dense_init(k2, (n_experts, d_model, d_ff), dtype),
        "w_up": _dense_init(k3, (n_experts, d_model, d_ff), dtype),
        "w_down": _dense_init(k4, (n_experts, d_ff, d_model), dtype, in_axis_size=d_ff),
    }
    if n_shared:
        p["shared"] = init_mlp(k5, d_model, n_shared * d_ff, dtype)
    return p


def moe_routing(router_w: jnp.ndarray, x: jnp.ndarray, top_k: int):
    """Router in fp32. x: (T, D). Returns (weights (T,k), experts (T,k), aux_loss)."""
    logits = x.astype(jnp.float32) @ router_w.astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = lax.top_k(probs, top_k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    # Switch-style load-balancing aux loss
    n_experts = router_w.shape[1]
    me = jnp.mean(probs, axis=0)                                    # (E,)
    ce = jnp.mean(jax.nn.one_hot(top_e[:, 0], n_experts, dtype=jnp.float32), axis=0)
    aux = n_experts * jnp.sum(me * ce)
    return top_w, top_e, aux


def _expert_positions(top_e: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Position of each (token, slot) within its expert queue, counted jointly
    across slots in (slot-major, token) order.  top_e: (T, k) -> pos (T, k)."""
    T, k = top_e.shape
    flat = top_e.T.reshape(-1)  # slot-major: all slot-0 tokens first
    onehot = jax.nn.one_hot(flat, n_experts, dtype=jnp.int32)  # (T*k, E)
    pos_flat = jnp.cumsum(onehot, axis=0) - 1                   # (T*k, E)
    pos = jnp.take_along_axis(pos_flat, flat[:, None], axis=1)[:, 0]
    return pos.reshape(k, T).T  # (T, k)


def _expert_positions_big(top_e: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Sort-based variant that avoids the (T*k, E) one-hot (for big E)."""
    T, k = top_e.shape
    flat = top_e.T.reshape(-1)
    tk = flat.shape[0]
    order = jnp.argsort(flat, stable=True)
    counts = jnp.zeros((n_experts,), jnp.int32).at[flat].add(1)
    starts = jnp.cumsum(counts) - counts                       # (E,)
    pos_sorted = jnp.arange(tk, dtype=jnp.int32) - starts[flat[order]]
    pos = jnp.zeros((tk,), jnp.int32).at[order].set(pos_sorted)
    return pos.reshape(k, T).T


def moe_apply_local(params: Params, x: jnp.ndarray, *, top_k: int,
                    capacity: int, n_experts: int,
                    expert_start: int = 0, n_local_experts: Optional[int] = None,
                    big_e_threshold: int = 64) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Apply a (possibly expert-sharded) MoE to local tokens.

    x: (T, D) local tokens (hidden replicated across the expert axis).
    params hold only the local expert slab [expert_start, expert_start+n_local).
    Returns (partial y (T, D), aux loss).  When experts are sharded the caller
    must psum y over the expert axis.
    """
    T, D = x.shape
    cdt = x.dtype
    n_local = n_local_experts if n_local_experts is not None else n_experts
    top_w, top_e, aux = moe_routing(params["router"], x, top_k)

    if n_experts >= big_e_threshold:
        pos = _expert_positions_big(top_e, n_experts)
    else:
        pos = _expert_positions(top_e, n_experts)

    # Scatter tokens into per-expert queues: xe (n_local * capacity, D)
    xe = jnp.zeros((n_local * capacity + 1, D), cdt)  # +1 = trash row
    trash = n_local * capacity
    for s in range(top_k):
        e = top_e[:, s] - expert_start
        ok = (e >= 0) & (e < n_local) & (pos[:, s] < capacity)
        dst = jnp.where(ok, e * capacity + jnp.minimum(pos[:, s], capacity - 1), trash)
        xe = xe.at[dst].add(jnp.where(ok[:, None], x, 0), mode="drop",
                            unique_indices=False)
    xe = xe[:trash].reshape(n_local, capacity, D)

    # Expert GEMMs (grouped): (E_l, C, D) x (E_l, D, F)
    g = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(cdt),
                   preferred_element_type=jnp.float32).astype(cdt)
    u = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(cdt),
                   preferred_element_type=jnp.float32).astype(cdt)
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"].astype(cdt),
                    preferred_element_type=jnp.float32).astype(cdt)
    ye = ye.reshape(n_local * capacity, D)
    ye = jnp.concatenate([ye, jnp.zeros((1, D), cdt)], axis=0)

    # Combine: gather each slot's expert output back to its token
    y = jnp.zeros((T, D), cdt)
    for s in range(top_k):
        e = top_e[:, s] - expert_start
        ok = (e >= 0) & (e < n_local) & (pos[:, s] < capacity)
        src = jnp.where(ok, e * capacity + jnp.minimum(pos[:, s], capacity - 1), trash)
        y = y + ye[src] * jnp.where(ok, top_w[:, s], 0.0).astype(cdt)[:, None]

    if "shared" in params:
        y = y + mlp(params["shared"], x)
    return y, aux


# ---------------------------------------------------------------------------
# Mamba-2 (SSD) block
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SSMDims:
    d_model: int
    d_inner: int
    n_heads: int
    head_dim: int
    d_state: int
    n_groups: int = 1
    d_conv: int = 4
    chunk: int = 64


def ssm_dims(d_model: int, d_state: int, head_dim: int = 64, expand: int = 2,
             chunk: int = 64) -> SSMDims:
    d_inner = expand * d_model
    return SSMDims(d_model=d_model, d_inner=d_inner, n_heads=d_inner // head_dim,
                   head_dim=head_dim, d_state=d_state, chunk=chunk)


def init_ssm(key, dims: SSMDims, dtype=jnp.float32) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    conv_dim = dims.d_inner + 2 * dims.n_groups * dims.d_state
    d_in_proj = 2 * dims.d_inner + 2 * dims.n_groups * dims.d_state + dims.n_heads
    return {
        "in_proj": _dense_init(k1, (dims.d_model, d_in_proj), dtype),
        "conv_w": _dense_init(k2, (dims.d_conv, conv_dim), dtype, in_axis_size=dims.d_conv),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, dims.n_heads)).astype(jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(k3, (dims.n_heads,), jnp.float32,
                                       math.log(1e-3), math.log(1e-1))))),
        "d_skip": jnp.ones((dims.n_heads,), jnp.float32),
        "norm": init_rmsnorm(dims.d_inner, dtype),
        "out_proj": _dense_init(k4, (dims.d_inner, dims.d_model), dtype,
                                in_axis_size=dims.d_inner),
    }


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Stable 'segment sum' producing L[i, j] = sum_{j < m <= i} x[m] (i >= j).
    x: (..., c) -> (..., c, c) with -inf above the diagonal."""
    c = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((c, c), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                b: jnp.ndarray, c: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD (state-space dual) exact chunked scan — pure-jnp reference used by
    the model (the Pallas kernel optionally replaces the heavy inner einsums).

    Follows the Mamba-2 ``ssd_minimal_discrete`` algorithm with
    ``X <- dt*x`` and ``A <- dt*a`` discretization done here.

    x: (B, S, H, P); dt: (B, S, H) (already softplus'd, >0); a: (H,) (negative);
    b, c: (B, S, G, N).  Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    B, S, H, P = x.shape
    G, N = b.shape[2], b.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    def to_heads(t):  # (B,S,G,N) -> (B,nc,c,H,N)
        th = jnp.repeat(t, rep, axis=2) if rep != 1 else t
        return th.reshape(B, nc, chunk, H, N).astype(jnp.float32)

    xw = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
          ).reshape(B, nc, chunk, H, P)                    # dt-weighted input
    bb = to_heads(b)
    cb = to_heads(c)
    da = (dt.astype(jnp.float32) * a[None, None, :]).reshape(B, nc, chunk, H)
    da_h = da.transpose(0, 1, 3, 2)                        # (B,nc,H,c)
    da_cs = jnp.cumsum(da_h, axis=-1)                      # (B,nc,H,c)

    # --- 1. intra-chunk (diagonal blocks) ---
    L = jnp.exp(_segsum(da_h))                             # (B,nc,H,c,c)
    y_diag = jnp.einsum("bzihn,bzjhn,bzhij,bzjhp->bzihp",
                        cb, bb, L, xw)                     # (B,nc,c,H,P)

    # --- 2. state contributed by each chunk ---
    decay_states = jnp.exp(da_cs[..., -1:] - da_cs)        # (B,nc,H,c)
    states = jnp.einsum("bzchn,bzhc,bzchp->bzhpn", bb, decay_states, xw)

    # --- 3. inter-chunk recurrence ---
    chunk_decay = jnp.exp(da_cs[..., -1])                  # (B,nc,H)
    s0 = jnp.zeros((B, H, P, N), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)

    def scan_fn(carry, inp):
        dec, st = inp                                      # dec: (B,H), st: (B,H,P,N)
        new = carry * dec[..., None, None] + st
        return new, carry                                  # emit state *entering* the chunk

    final_state, prev_states = lax.scan(
        scan_fn, s0,
        (chunk_decay.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)     # (B,nc,H,P,N)

    # --- 4. contribution of previous chunks' state ---
    state_decay = jnp.exp(da_cs)                           # (B,nc,H,c)
    y_off = jnp.einsum("bzchn,bzhpn,bzhc->bzchp", cb, prev_states, state_decay)

    y = (y_diag + y_off).reshape(B, S, H, P)
    return y, final_state


def ssm_step(params: Params, dims: SSMDims, x_t: jnp.ndarray,
             conv_state: jnp.ndarray, ssm_state: jnp.ndarray
             ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-token recurrent step (decode).  x_t: (B, D).
    conv_state: (B, d_conv-1, conv_dim); ssm_state: (B, H, P, N)."""
    B, D = x_t.shape
    d = dims
    cdt = x_t.dtype
    zxbcdt = x_t @ params["in_proj"].astype(cdt)
    z, xin, bc, dt = jnp.split(
        zxbcdt, [d.d_inner, 2 * d.d_inner, 2 * d.d_inner + 2 * d.n_groups * d.d_state],
        axis=-1)
    conv_in = jnp.concatenate([xin, bc], axis=-1)          # (B, conv_dim)
    # causal conv over the rolling window
    window = jnp.concatenate([conv_state, conv_in[:, None, :]], axis=1)  # (B,dc,cd)
    conv_out = jnp.einsum("btc,tc->bc", window.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))
    new_conv_state = window[:, 1:, :]

    xc = conv_out[:, :d.d_inner].reshape(B, d.n_heads, d.head_dim)
    bcx = conv_out[:, d.d_inner:]
    b_t = bcx[:, :d.n_groups * d.d_state].reshape(B, d.n_groups, d.d_state)
    c_t = bcx[:, d.n_groups * d.d_state:].reshape(B, d.n_groups, d.d_state)
    rep = d.n_heads // d.n_groups
    b_h = jnp.repeat(b_t, rep, axis=1)                     # (B,H,N)
    c_h = jnp.repeat(c_t, rep, axis=1)

    dt_t = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])                          # (H,)
    decay = jnp.exp(dt_t * a[None, :])                     # (B,H)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt_t, xc.astype(jnp.float32), b_h)
    new_ssm_state = ssm_state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new_ssm_state, c_h)
    y = y + params["d_skip"][None, :, None] * xc.astype(jnp.float32)
    y = y.reshape(B, d.d_inner).astype(cdt)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    return y @ params["out_proj"].astype(cdt), new_conv_state, new_ssm_state


def ssm_apply(params: Params, dims: SSMDims, x: jnp.ndarray) -> jnp.ndarray:
    """Full-sequence SSD pass (train / prefill).  x: (B, S, D)."""
    B, S, D = x.shape
    d = dims
    cdt = x.dtype
    zxbcdt = x @ params["in_proj"].astype(cdt)             # (B,S,*)
    z, xin, bc, dt = jnp.split(
        zxbcdt, [d.d_inner, 2 * d.d_inner, 2 * d.d_inner + 2 * d.n_groups * d.d_state],
        axis=-1)
    conv_in = jnp.concatenate([xin, bc], axis=-1)          # (B,S,conv_dim)
    # depthwise causal conv, kernel d_conv
    pad = jnp.pad(conv_in, ((0, 0), (d.d_conv - 1, 0), (0, 0)))
    conv_out = sum(pad[:, i:i + S, :].astype(jnp.float32) *
                   params["conv_w"][i].astype(jnp.float32)
                   for i in range(d.d_conv))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32)).astype(cdt)

    xc = conv_out[..., :d.d_inner].reshape(B, S, d.n_heads, d.head_dim)
    bcx = conv_out[..., d.d_inner:]
    b = bcx[..., :d.n_groups * d.d_state].reshape(B, S, d.n_groups, d.d_state)
    c = bcx[..., d.n_groups * d.d_state:].reshape(B, S, d.n_groups, d.d_state)
    dt_v = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["a_log"])

    y, _ = ssd_chunked(xc, dt_v, a, b, c, dims.chunk)
    y = y + params["d_skip"][None, None, :, None] * xc.astype(jnp.float32)
    y = y.reshape(B, S, d.d_inner).astype(cdt)
    y = rmsnorm(params["norm"], y * jax.nn.silu(z))
    return y @ params["out_proj"].astype(cdt)
