"""Unified decoder-LM model covering all assigned architecture families.

One ``LMConfig`` describes dense / MoE / SSM (Mamba-2) / hybrid (Hymba)
decoder stacks.  The stack is driven by ``jax.lax.scan`` over stacked
per-layer parameters so HLO size and compile time stay bounded for 100+
layer models.  Modality frontends (audio frames, vision patches) are stubs:
``embed_inputs=False`` configs take precomputed ``(B, S, d_model)``
embeddings, per the assignment brief.
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.dist.ctx import hint
from repro.models import layers as L

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class MoEParallel:
    """How to run the MoE layer under SPMD.

    mode="auto": global-math einsum/scatter dispatch, XLA SPMD partitions it.
    mode="shard_map": manual expert-parallel dispatch — experts sharded over
    ``model_axis``, expert weights FSDP-sharded over ``fsdp_axes`` and
    all-gathered per layer, hidden replicated over the model axis
    (Megatron-TP style), partial outputs psum'd.
    """
    mode: str = "auto"
    model_axis: str = "model"
    fsdp_axes: Tuple[str, ...] = ()
    mesh: Any = None


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    block: str = "attn"               # "attn" | "ssm" | "hybrid"
    qk_norm: bool = False
    # sliding-window pattern, repeated over layers; 0 = global causal.
    # gemma3: (W,W,W,W,W,0) — 5 local : 1 global.
    window_pattern: Tuple[int, ...] = ()
    rope_theta: float = 1e4
    rope_theta_local: Optional[float] = None   # theta for windowed layers
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    # SSM
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 64
    ssm_groups: int = 1
    # misc
    embed_inputs: bool = True         # False => frontend stub feeds embeddings
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    embed_scale: bool = False         # gemma sqrt(d_model) embedding multiplier
    max_seq_len: int = 131072
    param_dtype: str = "float32"
    activ_dtype: str = "float32"
    remat: str = "none"               # "none" | "full" | "dots"
    sub_quadratic: bool = False       # supports long_500k decode
    unroll_layers: bool = False       # fully unroll the layer scan (cost probes)
    block_local_attn: bool = False    # blocked O(S*W) sliding-window attention
    seq_parallel_attn: bool = False   # SP attention (TP-unfriendly head counts)
    kv_quant: bool = False            # int8 KV cache w/ per-token-head scales

    @property
    def local_block(self) -> int:
        if not self.block_local_attn or not self.window_pattern:
            return 0
        locals_ = [w for w in self.window_pattern if w > 0]
        return max(locals_) if locals_ else 0

    # ------------------------------------------------------------------
    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def adtype(self):
        return jnp.dtype(self.activ_dtype)

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def has_attn(self) -> bool:
        return self.block in ("attn", "hybrid")

    @property
    def has_ssm(self) -> bool:
        return self.block in ("ssm", "hybrid")

    @property
    def has_ffn(self) -> bool:
        return self.d_ff > 0 or self.is_moe

    @property
    def ssm_dims(self) -> L.SSMDims:
        return L.ssm_dims(self.d_model, self.ssm_state, self.ssm_head_dim,
                          self.ssm_expand, self.ssm_chunk)

    def layer_windows(self) -> jnp.ndarray:
        if not self.window_pattern:
            return jnp.zeros((self.n_layers,), jnp.int32)
        pat = list(self.window_pattern)
        reps = (self.n_layers + len(pat) - 1) // len(pat)
        return jnp.array((pat * reps)[: self.n_layers], jnp.int32)

    def layer_thetas(self) -> jnp.ndarray:
        w = self.layer_windows()
        local_theta = self.rope_theta_local or self.rope_theta
        return jnp.where(w > 0, jnp.float32(local_theta), jnp.float32(self.rope_theta))

    def param_count(self) -> int:
        """Analytic parameter count (for roofline MODEL_FLOPS)."""
        d, V = self.d_model, self.vocab_size
        n = 0
        if self.embed_inputs:
            n += V * d
        if not self.tie_embeddings:
            n += d * V
        per_layer = d  # ln1
        if self.has_attn:
            per_layer += d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head
            per_layer += self.n_heads * self.d_head * d
            if self.qk_norm:
                per_layer += 2 * self.d_head
        if self.has_ssm:
            sd = self.ssm_dims
            d_in = 2 * sd.d_inner + 2 * sd.n_groups * sd.d_state + sd.n_heads
            conv_dim = sd.d_inner + 2 * sd.n_groups * sd.d_state
            per_layer += d * d_in + sd.d_conv * conv_dim + conv_dim
            per_layer += 3 * sd.n_heads + sd.d_inner + sd.d_inner * d
        if self.has_ffn:
            per_layer += d  # ln2
            if self.is_moe:
                per_layer += d * self.n_experts
                per_layer += self.n_experts * 3 * d * self.d_ff
                per_layer += self.n_shared_experts * 3 * d * self.d_ff
            else:
                per_layer += 3 * d * self.d_ff
        n += self.n_layers * per_layer + d  # + final norm
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts count)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dense_experts = self.moe_top_k + self.n_shared_experts
        inactive = self.n_layers * (self.n_experts - self.moe_top_k) * 3 * d * self.d_ff
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def init_layer(key, cfg: LMConfig) -> Params:
    keys = jax.random.split(key, 8)
    dt = cfg.pdtype
    p: Params = {"ln1": L.init_rmsnorm(cfg.d_model, dt)}
    if cfg.has_attn:
        p["attn"] = L.init_attention(keys[0], cfg.d_model, cfg.n_heads,
                                     cfg.n_kv_heads, cfg.d_head, cfg.qk_norm, dt)
    if cfg.has_ssm:
        p["ssm"] = L.init_ssm(keys[1], cfg.ssm_dims, dt)
        if cfg.block == "hybrid":
            p["mix"] = jnp.full((2,), 0.5, jnp.float32)
    if cfg.has_ffn:
        p["ln2"] = L.init_rmsnorm(cfg.d_model, dt)
        if cfg.is_moe:
            p["moe"] = L.init_moe(keys[2], cfg.d_model, cfg.n_experts, cfg.d_ff,
                                  cfg.n_shared_experts, dt)
        else:
            p["mlp"] = L.init_mlp(keys[2], cfg.d_model, cfg.d_ff, dt)
    return p


def init_params(cfg: LMConfig, key) -> Params:
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    p: Params = {}
    if cfg.embed_inputs:
        p["embed"] = L._embed_init(k_emb, (cfg.vocab_size, cfg.d_model), cfg.pdtype)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    p["layers"] = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    p["final_norm"] = L.init_rmsnorm(cfg.d_model, cfg.pdtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = L._dense_init(k_head, (cfg.d_model, cfg.vocab_size), cfg.pdtype)
    return p


def init_abstract(cfg: LMConfig, key=None) -> Params:
    """Shape/dtype skeleton of the params (no allocation) for dry-run lowering."""
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(lambda k: init_params(cfg, k), key)


# ---------------------------------------------------------------------------
# Forward (training / prefill)
# ---------------------------------------------------------------------------


def _layer_forward(lp: Params, h: jnp.ndarray, cfg: LMConfig, window, theta,
                   moe_parallel: Optional[MoEParallel], capacity: int):
    aux = jnp.float32(0.0)
    h = hint(h, "batch", None, "embed")
    if cfg.has_attn and cfg.has_ssm:       # hybrid: parallel attn + ssm heads
        hn = L.rmsnorm(lp["ln1"], h, cfg.norm_eps)
        ao, _ = L.attention(lp["attn"], hn, n_heads=cfg.n_heads,
                            n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
                            theta=theta, window=window, qk_norm=cfg.qk_norm,
                            eps=cfg.norm_eps, local_block=cfg.local_block,
                            seq_parallel=cfg.seq_parallel_attn)
        so = L.ssm_apply(lp["ssm"], cfg.ssm_dims, hn)
        mix = lp["mix"].astype(h.dtype)
        h = h + mix[0] * ao + mix[1] * so
    elif cfg.has_attn:
        hn = L.rmsnorm(lp["ln1"], h, cfg.norm_eps)
        ao, _ = L.attention(lp["attn"], hn, n_heads=cfg.n_heads,
                            n_kv_heads=cfg.n_kv_heads, d_head=cfg.d_head,
                            theta=theta, window=window, qk_norm=cfg.qk_norm,
                            eps=cfg.norm_eps, local_block=cfg.local_block,
                            seq_parallel=cfg.seq_parallel_attn)
        h = h + ao
    else:                                   # pure SSM
        hn = L.rmsnorm(lp["ln1"], h, cfg.norm_eps)
        h = h + L.ssm_apply(lp["ssm"], cfg.ssm_dims, hn)

    if cfg.has_ffn:
        hn = L.rmsnorm(lp["ln2"], h, cfg.norm_eps)
        if cfg.is_moe:
            B, S, D = hn.shape
            x2 = hn.reshape(B * S, D)
            if moe_parallel is not None and moe_parallel.mode == "shard_map":
                y2, aux = _moe_shard_map(lp["moe"], x2, cfg, moe_parallel, capacity)
            else:
                y2, aux = L.moe_apply_local(lp["moe"], x2, top_k=cfg.moe_top_k,
                                            capacity=capacity,
                                            n_experts=cfg.n_experts)
            h = h + y2.reshape(B, S, D)
        else:
            h = h + L.mlp(lp["mlp"], hn)
    return h, aux


def _moe_shard_map(mp: Params, x2: jnp.ndarray, cfg: LMConfig,
                   par: MoEParallel, capacity: int):
    """Expert-parallel MoE: experts sharded over the model axis, expert weights
    FSDP-sharded over fsdp_axes (all-gathered per use), hidden replicated over
    the model axis, partial outputs psum'd over the model axis."""
    from jax.sharding import PartitionSpec as P

    from repro.dist.smap import shard_map

    mesh = par.mesh
    model_ax = par.model_axis
    fsdp = tuple(par.fsdp_axes)
    n_model = mesh.shape[model_ax]
    assert cfg.n_experts % n_model == 0, (cfg.n_experts, n_model)
    e_local = cfg.n_experts // n_model
    batch_axes = tuple(a for a in mesh.axis_names if a not in (model_ax,))
    # capacity is per *local* token count: x2 is global here, the shard_map
    # body sees T_global / prod(batch_axes) tokens.
    n_batch_shards = 1
    for a in batch_axes:
        n_batch_shards *= mesh.shape[a]
    t_local = max(1, x2.shape[0] // n_batch_shards)
    capacity = moe_capacity(cfg, t_local)

    def f(x_l, router, wg, wu, wd, shared):
        if fsdp:
            wg = lax.all_gather(wg, fsdp, axis=2, tiled=True)
            wu = lax.all_gather(wu, fsdp, axis=2, tiled=True)
            wd = lax.all_gather(wd, fsdp, axis=1, tiled=True)
        start = lax.axis_index(model_ax) * e_local
        params = {"router": router, "w_gate": wg, "w_up": wu, "w_down": wd}
        if shared is not None:
            params["shared"] = shared
        y, aux = L.moe_apply_local(params, x_l, top_k=cfg.moe_top_k,
                                   capacity=capacity, n_experts=cfg.n_experts,
                                   expert_start=start, n_local_experts=e_local)
        y = lax.psum(y, model_ax)
        aux = lax.pmean(aux, mesh.axis_names)
        return y, aux

    shared = mp.get("shared")
    tok_spec = P(batch_axes, None)
    w_spec = P(model_ax, None, fsdp if fsdp else None)
    wd_spec = P(model_ax, fsdp if fsdp else None, None)
    shared_spec = (None if shared is None else
                   {"w_gate": P(None, model_ax), "w_up": P(None, model_ax),
                    "w_down": P(model_ax, None)})
    y, aux = shard_map(
        f, mesh=mesh,
        in_specs=(tok_spec, P(), w_spec, w_spec, wd_spec, shared_spec),
        out_specs=(tok_spec, P()),
    )(x2, mp["router"], mp["w_gate"], mp["w_up"], mp["w_down"], shared)
    return y, aux


def moe_capacity(cfg: LMConfig, n_tokens: int) -> int:
    """Per-expert token capacity for a global token count (static)."""
    if not cfg.is_moe:
        return 0
    cap = int(math.ceil(n_tokens * cfg.moe_top_k * cfg.capacity_factor
                        / cfg.n_experts))
    return max(8, ((cap + 7) // 8) * 8)


def forward(params: Params, cfg: LMConfig, inputs: jnp.ndarray,
            moe_parallel: Optional[MoEParallel] = None) -> jnp.ndarray:
    """Full-sequence forward -> final hidden states (B, S, D), aux loss.

    ``inputs``: (B, S) int32 token ids when cfg.embed_inputs else
    (B, S, d_model) precomputed embeddings (frontend stub).
    """
    adt = cfg.adtype
    if cfg.embed_inputs:
        h = params["embed"].astype(adt)[inputs]
    else:
        h = inputs.astype(adt)
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), adt)
    h = hint(h, "batch", None, "embed")

    B, S = h.shape[0], h.shape[1]
    capacity = moe_capacity(cfg, B * S)
    windows = cfg.layer_windows()
    thetas = cfg.layer_thetas()

    def body(carry, xs):
        lp, window, theta = xs
        h, aux = carry
        h, aux_l = _layer_forward(lp, h, cfg, window, theta, moe_parallel, capacity)
        return (h, aux + aux_l), None

    body_fn = body
    if cfg.remat == "full":
        body_fn = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    elif cfg.remat == "dots":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    (h, aux), _ = lax.scan(body_fn, (h, jnp.float32(0.0)),
                           (params["layers"], windows, thetas),
                           unroll=cfg.n_layers if cfg.unroll_layers else 1)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    return h, aux / cfg.n_layers


def logits_fn(params: Params, cfg: LMConfig, hidden: jnp.ndarray) -> jnp.ndarray:
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return hint(hidden @ head.astype(hidden.dtype), "batch", None, "vocab")


# ---------------------------------------------------------------------------
# KV / state caches for decode
# ---------------------------------------------------------------------------


class DecodeState(NamedTuple):
    """Stacked per-layer decode caches. Unused members are size-0 arrays."""
    k_cache: jnp.ndarray       # (L, B, max_seq, n_kv, d_head)
    v_cache: jnp.ndarray
    k_scale: jnp.ndarray       # (L, B, max_seq, n_kv) — int8 KV quant scales
    v_scale: jnp.ndarray       #   (size-0 when kv_quant is off)
    conv_state: jnp.ndarray    # (L, B, d_conv-1, conv_dim)
    ssm_state: jnp.ndarray     # (L, B, H, P, N)
    length: jnp.ndarray        # () int32 — tokens already in cache


def init_decode_state(cfg: LMConfig, batch: int, max_seq: int,
                      dtype=jnp.bfloat16, length: int = 0) -> DecodeState:
    Lx = cfg.n_layers
    if cfg.kv_quant:
        dtype = jnp.int8
    if cfg.has_attn:
        kv_len = max_seq
        if cfg.window_pattern and not any(w == 0 for w in cfg.window_pattern):
            kv_len = min(max_seq, max(cfg.window_pattern))
        k = jnp.zeros((Lx, batch, kv_len, cfg.n_kv_heads, cfg.d_head), dtype)
        v = jnp.zeros_like(k)
    else:
        k = jnp.zeros((Lx, batch, 0, cfg.n_kv_heads, cfg.d_head), dtype)
        v = jnp.zeros_like(k)
    if cfg.kv_quant and cfg.has_attn:
        ks = jnp.ones((Lx, batch, k.shape[2], cfg.n_kv_heads), jnp.float32)
        vs = jnp.ones_like(ks)
    else:
        ks = jnp.zeros((Lx, batch, 0, 0), jnp.float32)
        vs = jnp.zeros_like(ks)
    if cfg.has_ssm:
        sd = cfg.ssm_dims
        conv_dim = sd.d_inner + 2 * sd.n_groups * sd.d_state
        cdt = jnp.bfloat16 if cfg.kv_quant else dtype
        conv = jnp.zeros((Lx, batch, sd.d_conv - 1, conv_dim), cdt)
        ssm = jnp.zeros((Lx, batch, sd.n_heads, sd.head_dim, sd.d_state), jnp.float32)
    else:
        conv = jnp.zeros((Lx, batch, 0, 0), dtype)
        ssm = jnp.zeros((Lx, batch, 0, 0, 0), jnp.float32)
    return DecodeState(k, v, ks, vs, conv, ssm, jnp.asarray(length, jnp.int32))


def decode_step(params: Params, cfg: LMConfig, state: DecodeState,
                tokens: jnp.ndarray) -> Tuple[jnp.ndarray, DecodeState]:
    """One decode step.  tokens: (B,) int32 (or (B, d_model) embeddings for
    stub-frontend configs).  Returns (logits (B, V), new state)."""
    adt = cfg.adtype
    if cfg.embed_inputs:
        h = params["embed"].astype(adt)[tokens][:, None, :]      # (B,1,D)
    else:
        h = tokens.astype(adt)[:, None, :]
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), adt)

    windows = cfg.layer_windows()
    thetas = cfg.layer_thetas()
    capacity = moe_capacity(cfg, h.shape[0])

    def body(carry, xs):
        h, pos = carry
        lp, window, theta, kc, vc, ks, vs, conv, ssm = xs
        kv_cache = (kc, vc, ks, vs) if cfg.kv_quant else (kc, vc)
        if cfg.has_attn and cfg.has_ssm:
            hn = L.rmsnorm(lp["ln1"], h, cfg.norm_eps)
            ao, cache = L.attention(
                lp["attn"], hn, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                d_head=cfg.d_head, theta=theta, window=window, qk_norm=cfg.qk_norm,
                eps=cfg.norm_eps, kv_cache=kv_cache, cache_len=pos)
            so, conv, ssm = L.ssm_step(lp["ssm"], cfg.ssm_dims, hn[:, 0, :],
                                       conv, ssm)
            mix = lp["mix"].astype(h.dtype)
            h = h + mix[0] * ao + mix[1] * so[:, None, :]
        elif cfg.has_attn:
            hn = L.rmsnorm(lp["ln1"], h, cfg.norm_eps)
            ao, cache = L.attention(
                lp["attn"], hn, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                d_head=cfg.d_head, theta=theta, window=window, qk_norm=cfg.qk_norm,
                eps=cfg.norm_eps, kv_cache=kv_cache, cache_len=pos)
            h = h + ao
        else:
            hn = L.rmsnorm(lp["ln1"], h, cfg.norm_eps)
            so, conv, ssm = L.ssm_step(lp["ssm"], cfg.ssm_dims, hn[:, 0, :],
                                       conv, ssm)
            h = h + so[:, None, :]
            cache = None
        if cache is not None:
            if cfg.kv_quant:
                kc, vc, ks, vs = cache
            else:
                kc, vc = cache

        if cfg.has_ffn:
            hn = L.rmsnorm(lp["ln2"], h, cfg.norm_eps)
            if cfg.is_moe:
                B = hn.shape[0]
                y2, _ = L.moe_apply_local(lp["moe"], hn.reshape(B, -1),
                                          top_k=cfg.moe_top_k, capacity=capacity,
                                          n_experts=cfg.n_experts)
                h = h + y2.reshape(B, 1, -1)
            else:
                h = h + L.mlp(lp["mlp"], hn)
        return (h, pos), (kc, vc, ks, vs, conv, ssm)

    (h, _), (kc, vc, ks, vs, conv, ssm) = lax.scan(
        body, (h, state.length),
        (params["layers"], windows, thetas,
         state.k_cache, state.v_cache, state.k_scale, state.v_scale,
         state.conv_state, state.ssm_state),
        unroll=cfg.n_layers if cfg.unroll_layers else 1)
    h = L.rmsnorm(params["final_norm"], h, cfg.norm_eps)
    logits = logits_fn(params, cfg, h)[:, 0, :]
    new_state = DecodeState(kc, vc, ks, vs, conv, ssm, state.length + 1)
    return logits, new_state
