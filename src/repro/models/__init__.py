from repro.models.model import (  # noqa: F401
    DecodeState,
    LMConfig,
    MoEParallel,
    decode_step,
    forward,
    init_abstract,
    init_decode_state,
    init_params,
    logits_fn,
    moe_capacity,
)
