"""Composable fault families and the correlated fault sampler.

A *fault family* maps a dimensionless severity in ``[0, 1]`` onto one
scenario knob of the fused sweep engine: severity 0 is the paper
operating point, severity 1 the worst modelled value.  Campaigns search
along *rays* in this severity space; the sampler below draws joint
severities with an explicit correlation structure (Gaussian copula with
uniform marginals), so "the blackhole that also spikes traffic and eats
the cloud quota" is one reproducible draw, not three independent knobs.

Everything random derives from ONE campaign seed via
``core.scenarios.stage_seed(seed, "faults")``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.scenarios import stage_seed

__all__ = [
    "FaultFamily", "FAULT_LIBRARY", "FAMILIES", "REQUEST_FAULT_LIBRARY",
    "REQUEST_FAMILIES", "severity_grid", "ray_severities",
    "DEFAULT_CORR_PAIRS", "correlation_matrix", "sample_faults",
]


@dataclasses.dataclass(frozen=True)
class FaultFamily:
    """One severity axis: ``knob = base + severity * (worst - base)``."""

    name: str
    knob: str        # scenario-grid key the severity maps onto
    base: float      # knob value at severity 0 (paper operating point)
    worst: float     # knob value at severity 1
    doc: str = ""

    def value(self, severity):
        """Knob value(s) for severity in [0, 1] (scalar or array)."""
        return self.base + np.asarray(severity, np.float64) * (
            self.worst - self.base)

    def severity(self, value):
        """Inverse of :meth:`value` (for reporting observed knobs)."""
        return (np.asarray(value, np.float64) - self.base) / (
            self.worst - self.base)


# Canonical fault library, one entry per scenario knob the engine sweeps.
# ``base`` is the §6 operating point; ``worst`` the harshest value the
# analytic/temporal models are calibrated for.
FAULT_LIBRARY: Dict[str, FaultFamily] = {
    f.name: f for f in (
        FaultFamily(
            "traffic_spike", "traffic_mult", 2.0, 4.0,
            "surviving-region load beyond the 2.0x single-failover step"),
        FaultFamily(
            "preheat_stall", "burst_delay_s", 270.0, 1800.0,
            "cloud burst capacity arrives late (preheat pipeline stalled)"),
        FaultFamily(
            "burst_shortfall", "burst_availability", 1.0, 0.0,
            "fraction of requested burst capacity that never materializes"),
        FaultFamily(
            "quota_shortfall", "cloud_quota_frac", 1.0, 0.0,
            "cloud provider delivers only a fraction of the reserved quota"),
        FaultFamily(
            "evict_shortfall", "evict_fraction", 1.0, 0.0,
            "preemptible eviction frees less capacity than planned"),
        FaultFamily(
            "region_degradation", "region_degradation", 0.0, 0.8,
            "surviving region loses a fraction of its own capacity"),
        FaultFamily(
            "dependency_storm", "storm_refrac", 0.0, 1.0,
            "restored services re-darken mid-recovery (cascading storm)"),
    )
}

# Canonical ordering — the column order of every severity matrix.
# Deliberately frozen to the ENGINE families above (before the
# request-plane families register below): ``severity_grid(..., FAMILIES)``
# must only emit knobs the sweep engine's ``validate_grid`` accepts.
FAMILIES: Tuple[str, ...] = tuple(FAULT_LIBRARY)

# Request-plane fault families (serving.workload drills): severities map
# onto workload knobs, not engine scenario knobs — campaigns over these
# pass ``families=REQUEST_FAMILIES`` and a drill oracle instead of a
# SweepEngine.  Registered in FAULT_LIBRARY so ``Ray`` validates them.
REQUEST_FAULT_LIBRARY: Dict[str, FaultFamily] = {
    f.name: f for f in (
        FaultFamily(
            "arrival_spike", "arrival_mult", 1.0, 8.0,
            "open-loop arrival-rate multiplier beyond the absorbed 2.0x"),
        FaultFamily(
            "retry_storm", "retry_storm", 0.0, 1.0,
            "speculative client-duplicate amplification per arrival"),
    )
}
FAULT_LIBRARY.update(REQUEST_FAULT_LIBRARY)
REQUEST_FAMILIES: Tuple[str, ...] = tuple(REQUEST_FAULT_LIBRARY)


def severity_grid(severity, families: Sequence[str] = FAMILIES
                  ) -> Dict[str, np.ndarray]:
    """Map a severity matrix onto an engine scenario grid.

    ``severity`` is ``(n, F)`` with column ``j`` the severity of
    ``families[j]``.  Returns a dict of ``(n,)`` float64 columns — one
    per family knob — suitable for ``SweepEngine.run``.  Every family's
    knob is always emitted (at its base value for zero severity) so grid
    keys, and therefore compiled-program signatures, stay constant
    across campaign rounds.
    """
    sev = np.atleast_2d(np.asarray(severity, np.float64))
    if sev.shape[1] != len(families):
        raise ValueError(
            f"severity has {sev.shape[1]} columns, expected "
            f"{len(families)} for families {families}")
    grid: Dict[str, np.ndarray] = {}
    for j, name in enumerate(families):
        fam = FAULT_LIBRARY[name]
        if fam.knob in grid:
            raise ValueError(f"duplicate knob {fam.knob!r}")
        grid[fam.knob] = fam.value(sev[:, j])
    return grid


def ray_severities(direction: Mapping[str, float], s,
                   families: Sequence[str] = FAMILIES) -> np.ndarray:
    """Severity matrix for scalar severities ``s`` along a ray.

    ``direction`` maps family name -> weight in (0, 1]; row ``i`` has
    ``s[i] * weight`` in each named family's column, zero elsewhere.
    """
    s = np.atleast_1d(np.asarray(s, np.float64))
    sev = np.zeros((s.shape[0], len(families)), np.float64)
    for name, w in direction.items():
        if name not in families:
            raise KeyError(f"unknown fault family {name!r}")
        sev[:, list(families).index(name)] = s * float(w)
    return sev


# ---------------------------------------------------------------------------
# Correlated sampler: Gaussian copula with Uniform(0, max_severity)
# marginals.  Positive off-diagonals make the *bad* tails co-occur —
# the paper's compound incidents (regional blackhole + traffic spike +
# quota shortfall) are the motivating case.
# ---------------------------------------------------------------------------

DEFAULT_CORR_PAIRS: Dict[Tuple[str, str], float] = {
    ("evict_shortfall", "traffic_spike"): 0.6,
    ("traffic_spike", "quota_shortfall"): 0.5,
    ("evict_shortfall", "quota_shortfall"): 0.4,
    ("dependency_storm", "region_degradation"): 0.3,
}


def correlation_matrix(families: Sequence[str] = FAMILIES,
                       pairs: Optional[Mapping[Tuple[str, str], float]] = None
                       ) -> np.ndarray:
    """Dense (F, F) correlation matrix from sparse named pairs."""
    pairs = DEFAULT_CORR_PAIRS if pairs is None else pairs
    idx = {name: j for j, name in enumerate(families)}
    corr = np.eye(len(families), dtype=np.float64)
    for (a, b), rho in pairs.items():
        if a in idx and b in idx:
            corr[idx[a], idx[b]] = corr[idx[b], idx[a]] = float(rho)
    # fail fast if the requested structure is not a valid correlation
    np.linalg.cholesky(corr)
    return corr


@partial(jax.jit, static_argnames=("n",))
def _copula_severities(key, chol, max_sev, *, n: int) -> jnp.ndarray:
    """(n, F) severities: correlated normals -> uniform marginals."""
    z = jax.random.normal(key, (n, chol.shape[0])) @ chol.T
    u = jax.scipy.stats.norm.cdf(z)          # Uniform(0,1) marginals
    return u * max_sev


def sample_faults(seed: int, n: int, *,
                  families: Sequence[str] = FAMILIES,
                  corr: Optional[np.ndarray] = None,
                  max_severity: float = 1.0) -> Dict[str, object]:
    """Draw ``n`` correlated joint faults from one campaign seed.

    Returns ``{"severity": (n, F) array, "families": tuple, "grid":
    scenario-grid dict}``.  Marginals are Uniform(0, max_severity);
    the rank correlation follows ``corr`` (Gaussian copula).  The
    stream is independent of the engine's blackhole/storm draws for
    the same campaign seed (distinct ``stage_seed`` stage).
    """
    if corr is None:
        corr = correlation_matrix(families)
    corr = np.asarray(corr, np.float64)
    if corr.shape != (len(families),) * 2:
        raise ValueError(
            f"corr shape {corr.shape} != ({len(families)}, {len(families)})")
    chol = np.linalg.cholesky(corr)
    key = jax.random.PRNGKey(stage_seed(seed, "faults"))
    sev = np.asarray(_copula_severities(
        key, jnp.asarray(chol, jnp.float32),
        jnp.float32(max_severity), n=int(n)), np.float64)
    sev = np.clip(sev, 0.0, max_severity)    # guard cdf rounding at the edges
    return {"severity": sev, "families": tuple(families),
            "grid": severity_grid(sev, families)}
