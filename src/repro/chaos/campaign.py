"""Frontier-hunting chaos campaigns over the fused sweep engine.

A campaign searches along *fault-severity rays* — directions in the
``faults.FAMILIES`` severity space — for the lowest severity at which
the fleet violates its SLA.  The search is bisection per ray with a
bandit allocator across rays: each round, the rays with the widest
remaining brackets (largest uncertainty, so largest information gain
per probe) get the round's probe budget, all probes are fused into ONE
bucket-padded ``SweepEngine.run`` batch, and the batched ``sla_ok``
verdicts refine every bracket at once.

Localizing a frontier to severity resolution ``tol`` costs
``~log2(1/tol)`` engine evaluations per ray instead of the
``1/tol + 1`` an exhaustive grid at the same resolution needs — the
bench asserts the >=10x saving on the paper-scale fleet.

Every probe's verdict row is logged so ``report.verify_report`` can
re-evaluate the whole campaign on a fresh engine and assert the
verdicts are bit-identical (same compiled programs, same stage seeds).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.scenarios import stage_seed

from .faults import FAMILIES, FAULT_LIBRARY, ray_severities, severity_grid
from .report import CampaignReport, RayResult

__all__ = ["Ray", "default_rays", "Campaign", "engine_oracle",
           "campaign_for_fleet", "VERDICT_KEYS"]

# Result keys snapshotted per probe for bit-exact re-verification.
# Only keys present in the engine result are logged.
VERDICT_KEYS: Tuple[str, ...] = (
    "sla_ok", "t_sla_ok", "availability", "t_availability_mean",
    "rl_done_s", "t_rl_done_s", "util_peak", "t_util_peak",
    # request-plane drill oracles (serving.workload.drill_oracle)
    "crit_availability", "crit_p99_s", "pre_restore_s",
)


@dataclasses.dataclass(frozen=True)
class Ray:
    """A direction in fault-severity space.

    ``direction`` maps family name -> weight in (0, 1]; severity ``s``
    along the ray puts ``s * weight`` into each named family (other
    families stay at their operating point).  ``fixed`` pins extra
    scenario knobs for every probe on this ray.
    """

    name: str
    direction: Mapping[str, float]
    fixed: Mapping[str, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.direction:
            raise ValueError(f"ray {self.name!r} has an empty direction")
        for fam, w in self.direction.items():
            if fam not in FAULT_LIBRARY:
                raise KeyError(f"unknown fault family {fam!r}")
            if not 0.0 < float(w) <= 1.0:
                raise ValueError(
                    f"ray {self.name!r}: weight for {fam} must be in "
                    f"(0, 1], got {w}")


def default_rays(families: Sequence[str] = FAMILIES) -> Tuple[Ray, ...]:
    """One single-family ray per fault family, plus the paper's
    compound incident (blackhole with traffic spike + quota shortfall)."""
    rays = [Ray(name, {name: 1.0}) for name in families]
    compound = {"traffic_spike": 1.0, "quota_shortfall": 0.75,
                "evict_shortfall": 0.5}
    if all(f in families for f in compound):
        rays.append(Ray("correlated_incident", compound))
    return tuple(rays)


@dataclasses.dataclass
class _RayState:
    ray: Ray
    lo: float = 0.0             # highest severity known to PASS
    hi: float = 1.0             # lowest severity known to FAIL
    status: str = "active"      # active | localized | no_violation | degenerate
    n_probes: int = 0

    @property
    def width(self) -> float:
        return self.hi - self.lo


def engine_oracle(engine, *, temporal: bool = True) -> Callable:
    """Wrap a ``SweepEngine`` as a campaign oracle.

    The oracle maps a scenario grid to ``(ok, result)`` where ``ok``
    is the per-row boolean SLA verdict (analytic AND temporal when the
    temporal kernel runs) and ``result`` the raw engine result dict.
    """

    def oracle(grid: Dict[str, np.ndarray]):
        res = engine.run(grid, temporal=temporal)
        ok = np.asarray(res["sla_ok"], bool)
        if "t_sla_ok" in res:
            ok = ok & np.asarray(res["t_sla_ok"], bool)
        return ok, res

    return oracle


class Campaign:
    """Bandit-allocated bisection along fault-severity rays.

    Parameters
    ----------
    engine:
        A ``SweepEngine`` (or None when ``oracle`` is injected, e.g. in
        property tests with a synthetic oracle).
    rays:
        Rays to search; defaults to :func:`default_rays`.
    tol:
        Target severity resolution of the localized frontier bracket.
    round_budget:
        Max rays probed per bisection round (bandit budget).  ``None``
        probes every active ray each round.
    max_rounds:
        Hard cap on bisection rounds (excludes the probe round).
    seed:
        Campaign seed, recorded in the report.  The engine's own draws
        are seeded at construction; ``campaign_for_fleet`` derives both
        from one seed via ``stage_seed``.
    """

    def __init__(self, engine=None, *, rays: Optional[Sequence[Ray]] = None,
                 tol: float = 1.0 / 256.0, round_budget: Optional[int] = None,
                 max_rounds: int = 64, temporal: bool = True, seed: int = 0,
                 oracle: Optional[Callable] = None, profiler=None,
                 families: Optional[Sequence[str]] = None):
        if oracle is None and engine is None:
            raise ValueError("need an engine or an oracle")
        if not 0.0 < tol < 1.0:
            raise ValueError(f"tol must be in (0, 1), got {tol}")
        self.engine = engine            # for report re-verification
        self.oracle = oracle or engine_oracle(engine, temporal=temporal)
        # severity-space axes: engine campaigns stay on the engine-knob
        # FAMILIES; drill campaigns pass faults.REQUEST_FAMILIES so only
        # request-plane knobs reach their oracle
        self.families = tuple(families) if families is not None else FAMILIES
        self.rays = tuple(rays if rays is not None
                          else default_rays(self.families))
        if not self.rays:
            raise ValueError("campaign needs at least one ray")
        self.tol = float(tol)
        self.round_budget = round_budget
        self.max_rounds = int(max_rounds)
        self.seed = int(seed)
        self.profiler = profiler
        self.n_evals = 0
        self.n_rounds = 0
        self.probe_log: List[dict] = []    # every probe: row + verdict snapshot

    # -- one fused engine batch for a list of (ray_index, severity) ---------
    def _grid_for(self, probes: Sequence[Tuple[int, float]]
                  ) -> Dict[str, np.ndarray]:
        sev = np.zeros((len(probes), len(self.families)), np.float64)
        for i, (ri, s) in enumerate(probes):
            sev[i] = ray_severities(self.rays[ri].direction, [s],
                                    self.families)[0]
        grid = severity_grid(sev, self.families)
        for i, (ri, _) in enumerate(probes):
            for knob, val in self.rays[ri].fixed.items():
                if knob not in grid:
                    # constant column at the knob's default so only this
                    # ray's rows deviate; engine fills true defaults for
                    # keys we never mention
                    fam = next((f for f in FAULT_LIBRARY.values()
                                if f.knob == knob), None)
                    base = fam.base if fam is not None else float(val)
                    grid[knob] = np.full(len(probes), base, np.float64)
                grid[knob][i] = float(val)
        return grid

    def _evaluate(self, probes: Sequence[Tuple[int, float]]) -> np.ndarray:
        grid = self._grid_for(probes)
        ok, res = self.oracle(grid)
        ok = np.asarray(ok, bool)
        self.n_evals += len(probes)
        keys = [k for k in VERDICT_KEYS if k in res]
        for i, (ri, s) in enumerate(probes):
            self.probe_log.append({
                "ray": self.rays[ri].name,
                "severity": float(s),
                "ok": bool(ok[i]),
                "row": {k: float(grid[k][i]) for k in grid},
                "verdict": {k: np.asarray(res[k])[i].item() for k in keys},
            })
        if obs.enabled():
            obs.inc("ufa_chaos_evals_total", len(probes))
        return ok

    # -- bandit allocator: widest bracket first -----------------------------
    def _allocate(self, states: List[_RayState]) -> List[int]:
        active = [i for i, st in enumerate(states) if st.status == "active"]
        # widest remaining bracket = largest uncertainty = largest
        # information gain per bisection probe (greedy bandit)
        active.sort(key=lambda i: (-states[i].width, i))
        if self.round_budget is not None:
            active = active[: self.round_budget]
        return active

    def run(self) -> CampaignReport:
        phase = (self.profiler.phase if self.profiler is not None
                 else _null_phase)
        states = [_RayState(ray=r) for r in self.rays]

        # Round 0: the shared operating point (severity 0) plus each
        # ray's worst case (severity 1) — establishes every bracket.
        with phase("chaos-probe"):
            probes = [(0, 0.0)] + [(i, 1.0) for i in range(len(states))]
            ok = self._evaluate(probes)
        op_ok = bool(ok[0])
        for i, st in enumerate(states):
            st.n_probes += 1
            if not op_ok:
                st.status = "degenerate"   # fleet fails at its own
            elif ok[1 + i]:                # operating point: nothing to hunt
                st.status = "no_violation"

        while any(st.status == "active" for st in states) \
                and self.n_rounds < self.max_rounds:
            chosen = self._allocate(states)
            if not chosen:
                break
            with phase("chaos-bisect"):
                probes = [(i, (states[i].lo + states[i].hi) / 2.0)
                          for i in chosen]
                ok = self._evaluate(probes)
            for (i, mid), good in zip(probes, ok):
                st = states[i]
                st.n_probes += 1
                if good:
                    st.lo = mid
                else:
                    st.hi = mid
                if st.width <= self.tol:
                    st.status = "localized"
            self.n_rounds += 1
            if obs.enabled():
                obs.inc("ufa_chaos_rounds_total")

        return self._report(states, op_ok)

    def _report(self, states: List[_RayState], op_ok: bool) -> CampaignReport:
        results = []
        for st in states:
            frontier = (st.lo + st.hi) / 2.0 if st.status == "localized" \
                else None
            counterexample = None
            if st.status in ("localized", "active"):
                # active/localized both imply st.hi was CONFIRMED failing
                # (severity 1.0 failed in the probe round, and hi only
                # ever moves to a severity the oracle rejected) — the
                # knob values at hi are the minimal known counterexample
                sev = ray_severities(st.ray.direction, [st.hi],
                                     self.families)
                counterexample = {
                    k: float(v[0])
                    for k, v in severity_grid(sev, self.families).items()}
            results.append(RayResult(
                name=st.ray.name, direction=dict(st.ray.direction),
                status=st.status, lo=st.lo, hi=st.hi,
                frontier_severity=frontier, counterexample=counterexample,
                n_probes=st.n_probes, families=self.families))
        grid_points_per_ray = int(math.ceil(1.0 / self.tol)) + 1
        searched = [r for r in results
                    if r.status in ("localized", "no_violation")]
        grid_equiv = grid_points_per_ray * len(searched)
        report = CampaignReport(
            seed=self.seed, tol=self.tol, op_ok=op_ok, rays=results,
            n_evals=self.n_evals, n_rounds=self.n_rounds,
            grid_equiv_evals=grid_equiv, probe_log=list(self.probe_log))
        if obs.enabled():
            obs.set_gauge("ufa_chaos_rays_localized", report.n_localized)
            if report.speedup_vs_grid is not None:
                obs.set_gauge("ufa_chaos_speedup_vs_grid",
                              report.speedup_vs_grid)
            for r in results:
                if r.frontier_severity is not None:
                    obs.set_gauge("ufa_chaos_frontier_severity",
                                  r.frontier_severity, ray=r.name)
        return report


class _null_phase:
    def __init__(self, _name: str = ""):
        pass

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


def campaign_for_fleet(fs, *, seed: int = 0, with_graph: bool = True,
                       temporal: bool = True, t_end_s: float = 7200.0,
                       t_points: int = 240, scale: float = 1.0,
                       **campaign_kw) -> Campaign:
    """Build a fully seeded campaign over a fleet state.

    ONE ``seed`` reproduces the whole campaign: the engine's blackhole
    and storm draws get independent streams via ``stage_seed`` inside
    ``SweepEngine``; the deterministic bisection consumes no randomness
    beyond the engine's; the report records the same seed.

    The fleet is placed by a fresh ``Orchestrator`` (steady state) so
    the engine sees post-placement pool occupancy, exactly like the
    fused-sweep bench.
    """
    from repro.core.capacity import RegionCapacity
    from repro.core.omg import Orchestrator
    from repro.core.timeline_sim import default_ts
    from repro.graph import CallGraph

    region = RegionCapacity.for_fleet("chaos", fs)
    orch = Orchestrator(fs, region, scale=scale)
    graph = CallGraph.from_fleet_state(fs) if with_graph else None
    ts = default_ts(t_end_s, t_points) if temporal else None
    engine = orch.sweep_engine(graph=graph,
                               seed=stage_seed(seed, "sweep-engine"), ts=ts)
    return Campaign(engine, temporal=temporal, seed=seed, **campaign_kw)
