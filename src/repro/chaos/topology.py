"""N>2-region failure topologies on the fused engine's scenario axis.

The analytic and temporal kernels model ONE surviving region absorbing
shed traffic — the paper's 2-region operating point is the special case
``traffic_mult = 2.0``.  For N regions the per-survivor picture is the
same model with a different multiplier: a survivor holding share ``w_r``
of the traffic absorbs ``w_r / W_surv`` of the shed load, so its load
step is ``1 + shed / W_surv`` (uniform 3-region single failure ->
1.5x, the 2-region case -> 2.0x).  :func:`expand_failures` therefore
maps *(failure pattern, surviving region)* pairs onto scenario rows —
the engine's vmapped scenario axis IS the region axis — and
:func:`reduce_pattern_verdicts` folds row verdicts back per pattern
(a pattern passes iff EVERY surviving region passes).

Partial-region degradation composes orthogonally: per-survivor
fractional capacity loss rides the ``region_degradation`` knob.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

__all__ = ["RegionTopology", "expand_failures", "reduce_pattern_verdicts"]


@dataclasses.dataclass(frozen=True)
class RegionTopology:
    """Traffic shares of an N-region deployment (normalized to sum 1)."""

    weights: Tuple[float, ...]
    names: Tuple[str, ...]

    def __post_init__(self):
        if len(self.weights) != len(self.names):
            raise ValueError("weights and names length mismatch")
        if len(self.weights) < 2:
            raise ValueError("a topology needs at least 2 regions")
        w = np.asarray(self.weights, np.float64)
        if (w <= 0).any():
            raise ValueError("region weights must be positive")
        object.__setattr__(self, "weights",
                           tuple((w / w.sum()).tolist()))

    @classmethod
    def uniform(cls, n: int, prefix: str = "region") -> "RegionTopology":
        return cls(weights=tuple([1.0 / n] * n),
                   names=tuple(f"{prefix}-{i}" for i in range(n)))

    @property
    def n(self) -> int:
        return len(self.weights)

    def single_failures(self) -> np.ndarray:
        """(N, N) bool: pattern i fails exactly region i."""
        return np.eye(self.n, dtype=bool)


def expand_failures(topo: RegionTopology, failed,
                    degradation=None,
                    base_traffic_mult: float = 1.0
                    ) -> Tuple[Dict[str, np.ndarray], np.ndarray, np.ndarray]:
    """Expand failure patterns into engine scenario rows.

    Parameters
    ----------
    failed:
        ``(P, N)`` bool — which regions are dark in each pattern.  Every
        pattern must leave at least one survivor.
    degradation:
        optional ``(P, N)`` float in [0, 1) — fractional capacity loss
        of each *surviving* region (ignored for failed regions).
    base_traffic_mult:
        pre-failure load factor of each region (1.0 = regions at their
        steady share).

    Returns ``(grid, pattern_id, region_id)``: a scenario grid with one
    row per (pattern, survivor) containing ``traffic_mult`` and
    ``region_degradation`` columns, plus the row -> pattern and row ->
    region index maps for :func:`reduce_pattern_verdicts`.
    """
    failed = np.atleast_2d(np.asarray(failed, bool))
    if failed.shape[1] != topo.n:
        raise ValueError(
            f"failed has {failed.shape[1]} columns, topology has {topo.n}")
    if degradation is None:
        degradation = np.zeros(failed.shape, np.float64)
    degradation = np.atleast_2d(np.asarray(degradation, np.float64))
    if degradation.shape != failed.shape:
        raise ValueError("degradation shape must match failed")

    w = np.asarray(topo.weights, np.float64)
    mult_rows, degr_rows, pattern_id, region_id = [], [], [], []
    for p in range(failed.shape[0]):
        surv = np.flatnonzero(~failed[p])
        if surv.size == 0:
            raise ValueError(f"pattern {p} fails every region")
        shed = w[failed[p]].sum()
        w_surv = w[surv].sum()
        # each survivor absorbs shed load proportionally to its own
        # share: load step = 1 + shed / W_surv, identical for every
        # survivor under proportional routing
        mult = base_traffic_mult * (1.0 + shed / w_surv)
        for r in surv:
            mult_rows.append(mult)
            degr_rows.append(float(np.clip(degradation[p, r], 0.0, 0.999)))
            pattern_id.append(p)
            region_id.append(int(r))
    grid = {"traffic_mult": np.asarray(mult_rows, np.float64),
            "region_degradation": np.asarray(degr_rows, np.float64)}
    return grid, np.asarray(pattern_id, np.int32), np.asarray(
        region_id, np.int32)


def reduce_pattern_verdicts(result: Dict[str, np.ndarray],
                            pattern_id: np.ndarray,
                            topo: RegionTopology,
                            region_id: np.ndarray,
                            n_patterns: Optional[int] = None) -> Dict[str, np.ndarray]:
    """Fold per-(pattern, survivor) rows back to per-pattern verdicts.

    A pattern meets the SLA iff every surviving region does; pattern
    availability is the traffic-weighted mean over survivors (failed
    regions shed all traffic, so they carry no weight).  Returns
    ``{"sla_ok", "availability", "worst_region"}`` arrays of length P.
    """
    pattern_id = np.asarray(pattern_id)
    region_id = np.asarray(region_id)
    n_p = int(n_patterns if n_patterns is not None
              else pattern_id.max() + 1)
    ok = np.asarray(result["sla_ok"], bool)[: len(pattern_id)]
    if "t_sla_ok" in result:
        ok = ok & np.asarray(result["t_sla_ok"], bool)[: len(pattern_id)]
    avail = np.asarray(result["availability"],
                       np.float64)[: len(pattern_id)]
    w = np.asarray(topo.weights, np.float64)[region_id]

    out_ok = np.ones(n_p, bool)
    out_avail = np.zeros(n_p, np.float64)
    out_worst = np.full(n_p, -1, np.int32)
    for p in range(n_p):
        rows = np.flatnonzero(pattern_id == p)
        if rows.size == 0:
            out_ok[p] = False
            continue
        out_ok[p] = bool(ok[rows].all())
        wr = w[rows] / w[rows].sum()
        out_avail[p] = float((avail[rows] * wr).sum())
        out_worst[p] = int(region_id[rows[np.argmin(avail[rows])]])
    return {"sla_ok": out_ok, "availability": out_avail,
            "worst_region": out_worst}
