"""repro.chaos — adversarial chaos-campaign engine.

Instead of sweeping rectangular scenario grids, a campaign *hunts* the
SLA-violating frontier: composable fault families with an explicit
correlation structure ([`faults`](faults.py)), bandit-allocated bisection
along fault-severity rays driven by the fused sweep engine's batched
verdicts ([`campaign`](campaign.py)), per-family frontier reports with
minimal-severity counterexamples and a bit-exact re-verification pass
([`report`](report.py)), and N>2-region failure topologies expanded onto
the engine's scenario axis ([`topology`](topology.py)).

The whole loop reuses ``SweepEngine``'s compiled programs — each round
submits one bucket-padded batch, so a campaign is a handful of jit
variants, not thousands — and every random stage (blackhole draws, storm
draws, fault sampling) derives an independent stream from ONE campaign
seed via ``core.scenarios.stage_seed``.
"""

from .campaign import Campaign, Ray, campaign_for_fleet, default_rays
from .faults import (FAMILIES, FAULT_LIBRARY, REQUEST_FAMILIES,
                     FaultFamily, correlation_matrix, sample_faults,
                     severity_grid)
from .report import CampaignReport, RayResult, verify_report
from .topology import RegionTopology, expand_failures, reduce_pattern_verdicts

__all__ = [
    "Campaign", "Ray", "campaign_for_fleet", "default_rays",
    "FAMILIES", "FAULT_LIBRARY", "REQUEST_FAMILIES", "FaultFamily",
    "correlation_matrix", "sample_faults", "severity_grid",
    "CampaignReport", "RayResult", "verify_report",
    "RegionTopology", "expand_failures", "reduce_pattern_verdicts",
]
