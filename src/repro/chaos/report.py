"""Campaign reports: frontier coordinates, counterexamples, re-verification.

A :class:`CampaignReport` is the durable artifact of a chaos campaign —
per-ray frontier severities in knob coordinates ("max survivable
quota shortfall at the 2.0x operating point"), the minimal-severity
counterexample per violated ray, and the full probe log.  The probe log
makes the campaign *auditable*: :func:`verify_report` replays every
logged scenario row through a fresh engine in one batch and asserts the
verdicts are bit-identical — same compiled programs, same stage seeds,
so any drift is a real reproducibility bug, not noise.
"""

from __future__ import annotations

import dataclasses
import json
import math
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

__all__ = ["RayResult", "CampaignReport", "verify_report"]


@dataclasses.dataclass(frozen=True)
class RayResult:
    """Outcome of the frontier search along one fault-severity ray."""

    name: str
    direction: Dict[str, float]
    status: str                  # localized | no_violation | active | degenerate
    lo: float                    # highest severity known to pass
    hi: float                    # lowest severity known to fail
    frontier_severity: Optional[float]   # (lo+hi)/2 when localized
    counterexample: Optional[Dict[str, float]]  # knob values at hi
    n_probes: int
    # severity-space axes the campaign searched (None: engine FAMILIES)
    families: Optional[Tuple[str, ...]] = None

    def frontier_knobs(self) -> Optional[Dict[str, float]]:
        """Frontier severity mapped onto scenario-knob coordinates."""
        if self.frontier_severity is None:
            return None
        from .faults import FAMILIES, ray_severities, severity_grid
        fams = tuple(self.families) if self.families else FAMILIES
        sev = ray_severities(self.direction, [self.frontier_severity], fams)
        return {k: float(v[0]) for k, v in severity_grid(sev, fams).items()}


@dataclasses.dataclass(frozen=True)
class CampaignReport:
    seed: int
    tol: float
    op_ok: bool                  # fleet passes at its own operating point
    rays: List[RayResult]
    n_evals: int                 # engine scenario-evaluations submitted
    n_rounds: int                # bisection rounds (excl. the probe round)
    grid_equiv_evals: int        # exhaustive per-ray grid at the same tol
    probe_log: List[dict]        # every probe: grid row + verdict snapshot

    @property
    def n_localized(self) -> int:
        return sum(r.status == "localized" for r in self.rays)

    @property
    def speedup_vs_grid(self) -> Optional[float]:
        if self.n_evals == 0 or self.grid_equiv_evals == 0:
            return None
        return self.grid_equiv_evals / self.n_evals

    def ray(self, name: str) -> RayResult:
        for r in self.rays:
            if r.name == name:
                return r
        raise KeyError(name)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["n_localized"] = self.n_localized
        d["speedup_vs_grid"] = self.speedup_vs_grid
        return d

    def to_json(self, **kw) -> str:
        return json.dumps(self.to_dict(), **kw)

    def render(self) -> str:
        """Human-readable frontier table."""
        lines = [
            f"chaos campaign  seed={self.seed}  tol=1/{round(1 / self.tol)}"
            f"  operating point: {'PASS' if self.op_ok else 'FAIL'}",
            f"{self.n_evals} engine evals over {self.n_rounds} bisection "
            f"rounds (exhaustive grid at this resolution: "
            f"{self.grid_equiv_evals} evals"
            + (f", {self.speedup_vs_grid:.1f}x saved)"
               if self.speedup_vs_grid else ")"),
            "",
            f"{'ray':<22}{'status':<14}{'frontier':<10}bracket / "
            "counterexample",
        ]
        for r in self.rays:
            front = (f"{r.frontier_severity:.4f}"
                     if r.frontier_severity is not None else "-")
            if r.status == "localized" and r.counterexample:
                knobs = {k: round(v, 4) for k, v in r.counterexample.items()
                         if not math.isclose(
                             v, _base_knob(k), abs_tol=1e-12)}
                detail = f"[{r.lo:.4f}, {r.hi:.4f}]  fails at {knobs}"
            elif r.status == "no_violation":
                detail = "survives severity 1.0"
            elif r.status == "degenerate":
                detail = "operating point already violates SLA"
            else:
                detail = f"[{r.lo:.4f}, {r.hi:.4f}] (budget exhausted)"
            lines.append(f"{r.name:<22}{r.status:<14}{front:<10}{detail}")
        return "\n".join(lines)


def _base_knob(knob: str) -> float:
    from .faults import FAULT_LIBRARY
    for fam in FAULT_LIBRARY.values():
        if fam.knob == knob:
            return fam.base
    return float("nan")


def verify_report(report: CampaignReport, engine=None, *,
                  temporal: bool = True,
                  oracle: Optional[Callable] = None) -> dict:
    """Replay every logged probe through ``engine`` (or a campaign
    ``oracle``) and compare bitwise.

    ``engine`` must be built with the same fleet/graph and stage seeds
    (e.g. a second ``campaign_for_fleet(...).oracle`` engine from the
    same campaign seed).  All probes are resubmitted as ONE batch — row
    results must be bit-identical regardless of the batch composition
    they were originally evaluated in, because every engine row is
    vmapped independently and every drill-oracle row is an independent
    deterministic drill.

    ``oracle`` replays campaigns that never had an engine (request-plane
    drill campaigns): it receives the replayed grid and must return
    ``(ok, result)`` like the original oracle did.

    Returns ``{"n_probes", "mismatches"}`` and raises ``AssertionError``
    on any verdict drift.
    """
    if engine is None and oracle is None:
        raise ValueError("verify_report needs an engine or an oracle")
    probes = report.probe_log
    if not probes:
        return {"n_probes": 0, "mismatches": []}
    row_keys = list(probes[0]["row"])
    grid = {k: np.asarray([p["row"][k] for p in probes], np.float64)
            for k in row_keys}
    if oracle is not None:
        ok_replayed, res = oracle(grid)
        ok_replayed = np.asarray(ok_replayed, bool)
    else:
        res = engine.run(grid, temporal=temporal)
        ok_replayed = None

    mismatches = []
    verdict_keys = list(probes[0]["verdict"])
    for k in verdict_keys:
        got = np.asarray(res[k])[: len(probes)]
        want = np.asarray([p["verdict"][k] for p in probes]).astype(got.dtype)
        if not np.array_equal(want, got, equal_nan=got.dtype.kind == "f"):
            bad = np.flatnonzero(
                ~_eq(want, got))
            for i in bad[:8]:
                mismatches.append({
                    "probe": int(i), "key": k, "ray": probes[i]["ray"],
                    "severity": probes[i]["severity"],
                    "logged": want[i].item(), "replayed": got[i].item()})
    if ok_replayed is not None:
        ok = ok_replayed[: len(probes)]
    else:
        ok = np.asarray(res["sla_ok"], bool)[: len(probes)]
        if "t_sla_ok" in res:
            ok = ok & np.asarray(res["t_sla_ok"], bool)[: len(probes)]
    for i, p in enumerate(probes):
        if bool(ok[i]) != p["ok"]:
            mismatches.append({
                "probe": int(i), "key": "ok", "ray": p["ray"],
                "severity": p["severity"],
                "logged": p["ok"], "replayed": bool(ok[i])})
    assert not mismatches, (
        f"campaign replay drifted on {len(mismatches)} verdict(s): "
        f"{mismatches[:3]}")
    return {"n_probes": len(probes), "mismatches": mismatches}


def _eq(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    if a.dtype.kind == "f":
        return (a == b) | (np.isnan(a) & np.isnan(b))
    return a == b
