from repro.train.train_step import (  # noqa: F401
    TrainState,
    chunked_ce_loss,
    make_train_state,
    make_train_state_abstract,
    make_train_step,
    make_prefill_step,
    make_decode_fn,
)
