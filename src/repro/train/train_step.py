"""Training / prefill / decode step factories.

The loss is a vocab-shardable cross-entropy computed in sequence chunks under
``jax.checkpoint`` so that full (B, S, V) logits are never live — for 256k
vocab × 1M token batches the logits would otherwise dominate HBM.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import (LMConfig, MoEParallel, decode_step, forward,
                          init_params, logits_fn)
from repro.optim import AdamWState, make_optimizer


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jnp.ndarray   # () int32  (duplicate of opt.step; survives opt swaps)


def chunked_ce_loss(params, cfg: LMConfig, hidden: jnp.ndarray,
                    labels: jnp.ndarray, n_chunks: int = 8) -> jnp.ndarray:
    """Mean token cross-entropy, computed over sequence chunks.

    hidden: (B, S, D); labels: (B, S) int32.  Each chunk's logits are
    rematerialized in the backward pass (jax.checkpoint), bounding live
    logits to (B, S/n_chunks, V).
    """
    B, S, D = hidden.shape
    while S % n_chunks != 0:
        n_chunks -= 1
    c = S // n_chunks
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])

    from repro.dist.ctx import hint

    @jax.checkpoint
    def chunk_loss(h_c, y_c):
        logits = (h_c @ head.astype(h_c.dtype)).astype(jnp.float32)  # (B,c,V)
        logits = hint(logits, "batch", None, "vocab")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        return jnp.sum(lse - gold)

    # NOTE: a python loop (not lax.scan) so XLA cost analysis counts every
    # chunk — while-loop bodies are only counted once by cost_analysis.
    total = jnp.float32(0.0)
    for i in range(n_chunks):
        total = total + chunk_loss(hidden[:, i * c:(i + 1) * c, :],
                                   labels[:, i * c:(i + 1) * c])
    return total / (B * S)


def make_train_step(cfg: LMConfig, optimizer=None,
                    moe_parallel: Optional[MoEParallel] = None,
                    aux_weight: float = 0.01, n_loss_chunks: int = 8):
    """Returns train_step(state, batch) -> (state, metrics)."""
    optimizer = optimizer or make_optimizer()

    def loss_fn(params, batch):
        h, aux = forward(params, cfg, batch["inputs"], moe_parallel)
        ce = chunked_ce_loss(params, cfg, h, batch["labels"], n_loss_chunks)
        loss = ce + (aux_weight * aux if cfg.is_moe else 0.0)
        return loss, {"ce": ce, "aux": aux}

    def train_step(state: TrainState, batch: Dict[str, jnp.ndarray]):
        (loss, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            state.params, batch)
        new_params, new_opt, om = optimizer.update(grads, state.opt, state.params)
        metrics = {"loss": loss, **parts, **om}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step, optimizer


def make_train_state_abstract(cfg: LMConfig, optimizer=None):
    """Abstract (ShapeDtypeStruct) TrainState for dry-run lowering."""
    optimizer = optimizer or make_optimizer()
    def build(key):
        p = init_params(cfg, key)
        return TrainState(p, optimizer.init(p), jnp.zeros((), jnp.int32))
    return jax.eval_shape(build, jax.random.PRNGKey(0))


def make_train_state(cfg: LMConfig, key, optimizer=None) -> TrainState:
    optimizer = optimizer or make_optimizer()
    p = init_params(cfg, key)
    return TrainState(p, optimizer.init(p), jnp.zeros((), jnp.int32))


def make_prefill_step(cfg: LMConfig,
                      moe_parallel: Optional[MoEParallel] = None):
    """prefill_step(params, inputs) -> last-position logits (B, V).

    Used for the inference-prefill dry-run shape: runs the full forward and
    projects only the final position (production serving would also emit the
    KV cache; the compute/memory profile is identical)."""

    def prefill_step(params, inputs):
        h, _ = forward(params, cfg, inputs, moe_parallel)
        return logits_fn(params, cfg, h[:, -1:, :])[:, 0, :]

    return prefill_step


def make_decode_fn(cfg: LMConfig):
    def serve_step(params, state, tokens):
        return decode_step(params, cfg, state, tokens)
    return serve_step
