"""Training loop with fault-tolerance machinery.

- periodic async checkpointing (non-blocking);
- a straggler watchdog: per-step wall-time EWMA; a step exceeding
  ``straggler_factor`` x EWMA is recorded and (beyond ``max_strays``)
  triggers a checkpoint + re-shard recommendation — on real multi-host
  deployments this is where the UFA QoS controller would evict the hot
  host and the elastic restore path (checkpoint -> new mesh) takes over;
- preemption-safe: ``request_preempt()`` (called by the UFA orchestrator's
  on_evict hook) stops the loop at the next step boundary with a final
  checkpoint, and ``resume()`` restarts from storage onto any mesh.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Iterator, Optional

import jax

from repro.checkpoint import AsyncCheckpointer, latest_step, load_checkpoint
from repro.models import LMConfig
from repro.train.train_step import TrainState


@dataclasses.dataclass
class TrainerReport:
    steps_done: int
    final_loss: float
    losses: list
    straggler_steps: list
    preempted: bool
    resumed_from: Optional[int]


class Trainer:
    def __init__(self, cfg: LMConfig, train_step: Callable,
                 checkpoint_dir: str, checkpoint_every: int = 50,
                 straggler_factor: float = 3.0, max_strays: int = 5):
        self.cfg = cfg
        self.train_step = jax.jit(train_step, donate_argnums=(0,)) \
            if not hasattr(train_step, "lower") else train_step
        self.ckpt = AsyncCheckpointer(checkpoint_dir)
        self.checkpoint_dir = checkpoint_dir
        self.checkpoint_every = checkpoint_every
        self.straggler_factor = straggler_factor
        self.max_strays = max_strays
        self._preempt_requested = False

    def request_preempt(self):
        """UFA eviction hook: stop at the next step boundary."""
        self._preempt_requested = True

    def maybe_resume(self, state: TrainState,
                     shardings: Any = None) -> tuple[TrainState, int]:
        step = latest_step(self.checkpoint_dir)
        if step is None:
            return state, 0
        state, _ = load_checkpoint(self.checkpoint_dir, state,
                                   step=step, shardings=shardings)
        return state, step

    def run(self, state: TrainState, batches: Iterator[Dict],
            n_steps: int, start_step: int = 0) -> tuple[TrainState, TrainerReport]:
        losses = []
        strays = []
        ewma = None
        preempted = False
        done = 0
        for step in range(start_step, start_step + n_steps):
            if self._preempt_requested:
                preempted = True
                break
            batch = next(batches)
            t0 = time.perf_counter()
            state, metrics = self.train_step(state, batch)
            loss = float(metrics["loss"])  # blocks; acts as step barrier
            dt = time.perf_counter() - t0
            losses.append(loss)
            done += 1
            if ewma is None:
                ewma = dt
            else:
                if dt > self.straggler_factor * ewma and done > 3:
                    strays.append((step, dt, ewma))
                ewma = 0.9 * ewma + 0.1 * dt
            if (step + 1) % self.checkpoint_every == 0:
                self.ckpt.save(step + 1, state)
            if len(strays) > self.max_strays:
                # persistent straggler: checkpoint and hand off to the
                # elastic restore path (resume on a different mesh)
                break
        self.ckpt.save(start_step + done, state)
        self.ckpt.wait()
        return state, TrainerReport(
            steps_done=done,
            final_loss=losses[-1] if losses else float("nan"),
            losses=losses, straggler_steps=strays,
            preempted=preempted, resumed_from=start_step or None)
