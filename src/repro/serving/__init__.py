from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.scheduler import TieredScheduler  # noqa: F401
