from repro.serving.engine import Request, ServingEngine  # noqa: F401
from repro.serving.scheduler import (TieredScheduler,  # noqa: F401
                                     TierPolicy, default_policies)
from repro.serving.failover import (FailoverBridge,  # noqa: F401
                                    ReplicaGroup, tier_live_fractions)
from repro.serving.workload import (DrillReport, DrillSpec,  # noqa: F401
                                    TierVerdict, drill_oracle,
                                    request_campaign, run_drill)
