"""Tiered QoS request scheduler over one or more serving engines.

Implements the UFA request-plane policy: strict tier priority with
starvation-bounded aging, engine-level admission respecting blocked tiers,
and failover hooks that (1) block preemptible-tier traffic, (2) preempt
running non-critical waves so critical tiers get the capacity — the
request-level mirror of the container-level orchestration in core/omg.py.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from collections import defaultdict
from typing import Dict, List, Optional, Set, Tuple

from repro.core.tiers import FailureClass, DEFAULT_CLASS_OF_TIER, Tier
from repro.serving.engine import Request, ServingEngine


class TieredScheduler:
    def __init__(self, engines: Dict[str, ServingEngine],
                 aging_rounds: int = 50):
        self.engines = engines
        self.aging_rounds = aging_rounds
        self._q: List[Tuple[int, int, int, Request]] = []  # (tier, age, seq, r)
        self._seq = itertools.count()
        self.round = 0
        self.failover_active = False

    def submit(self, req: Request):
        heapq.heappush(self._q, (int(req.tier), self.round, next(self._seq), req))

    def _pop_wave(self, size: int, prompt_len: int) -> List[Request]:
        taken, rest = [], []
        while self._q and len(taken) < size:
            tier, born, seq, r = heapq.heappop(self._q)
            # starvation bound: promote ancient requests one tier
            eff_tier = max(0, tier - (self.round - born) // self.aging_rounds)
            if len(r.prompt) != prompt_len:
                rest.append((eff_tier, born, seq, r))
                continue
            taken.append(r)
        for item in rest:
            heapq.heappush(self._q, item)
        return taken

    def tick(self) -> int:
        """One scheduling round: keep engines busy, run one decode step.
        Returns number of decode steps executed."""
        self.round += 1
        steps = 0
        for engine in self.engines.values():
            if not engine.wave and self._q:
                plen = len(self._q[0][3].prompt)
                wave = self._pop_wave(engine.max_batch, plen)
                if wave:
                    admitted = engine.admit(wave)
                    for r in wave:
                        if r.state == "queued":  # didn't fit this wave
                            self.submit(r)
            if engine.wave:
                engine.decode_round()
                steps += 1
        return steps

    # ------------------------------------------------------------------
    # UFA failover integration
    # ------------------------------------------------------------------
    def enter_failover(self):
        """Block preemptible tiers, preempt their running work, and requeue
        nothing (Restore-Later requests fail fast until restoration)."""
        self.failover_active = True
        blocked = {t for t, fc in DEFAULT_CLASS_OF_TIER.items()
                   if fc.preemptible}
        for engine in self.engines.values():
            engine.block_tiers(blocked)
            if engine.wave and any(r.tier in blocked for r in engine.wave):
                engine.preempt()
        # drain queued blocked requests (fail fast, §4.2)
        kept = []
        while self._q:
            tier, born, seq, r = heapq.heappop(self._q)
            if r.tier in blocked:
                r.state = "rejected"
                for engine in self.engines.values():
                    engine.counters["rejected"][r.tier] += 1
                    break
            else:
                kept.append((tier, born, seq, r))
        for item in kept:
            heapq.heappush(self._q, item)

    def exit_failover(self):
        self.failover_active = False
        blocked = {t for t, fc in DEFAULT_CLASS_OF_TIER.items()
                   if fc.preemptible}
        for engine in self.engines.values():
            engine.unblock_tiers(blocked)

    def queue_depth(self) -> int:
        return len(self._q)
