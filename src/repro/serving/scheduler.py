"""Tiered QoS request scheduler over one or more serving engines.

Implements the UFA request-plane policy: strict tier priority with
starvation-bounded aging, engine-level admission respecting blocked tiers,
and failover hooks that (1) block preemptible-tier traffic, (2) preempt
running non-critical waves so critical tiers get the capacity — the
request-level mirror of the container-level orchestration in core/omg.py.

Request-plane hardening (§4.2 differentiated SLAs, made graceful):

  - per-tier :class:`TierPolicy` — deadline budget, bounded retries with
    exponential backoff + deterministic jitter, a queue-depth bound for
    load-shedding admission, and fail-fast rejection while a tier is
    blocked (no queue build-up behind a blacked-out tier).
  - ``block_tier``/``restore_tier`` — per-tier variants of the failover
    hooks so ``serving.failover.FailoverBridge`` can blackout and restore
    tiers independently, following the timeline kernel's capacity traces;
    preempted non-critical work is *held* during the blackout and requeued
    (re-prefilled, one retry consumed) after restoration.
  - scheduler-level counters + ``availability()`` — drained-queue
    rejections and fail-fast rejections are charged here, not to an
    arbitrary engine, so per-engine ``availability()`` stays truthful.

The scheduler keeps a simulation clock: ``tick(now=...)`` advances it,
``tick()`` (legacy) advances round-by-round at 1 s/round.  Finalized
requests are appended to ``events`` as ``(t, outcome, request)`` so the
workload driver can build per-step availability traces for the SLO
burn-rate monitors.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
import random
from collections import defaultdict
from typing import Dict, List, Optional, Set, Tuple

from repro import obs
from repro.core.tiers import (DEFAULT_CLASS_OF_TIER, FailureClass,
                              RTO_SECONDS, Tier)
from repro.serving.engine import Request, ServingEngine

__all__ = ["TierPolicy", "default_policies", "TieredScheduler"]


@dataclasses.dataclass(frozen=True)
class TierPolicy:
    """Per-tier request-plane budget (deadline, retries, shedding)."""
    deadline_s: float = float("inf")   # end-to-end latency budget
    max_retries: int = 2               # bounded retries (preempt/requeue)
    backoff_base_s: float = 5.0        # first-retry backoff
    backoff_mult: float = 2.0          # exponential backoff factor
    jitter_frac: float = 0.1           # uniform jitter on each backoff
    queue_bound: Optional[int] = None  # shed arrivals beyond this depth
    fail_fast_blocked: bool = True     # reject (vs queue) blocked tiers

    def backoff(self, attempts: int, u: float) -> float:
        """Backoff before retry ``attempts`` (1-based); ``u`` in [0, 1)."""
        base = self.backoff_base_s * self.backoff_mult ** max(0, attempts - 1)
        return base * (1.0 + self.jitter_frac * u)


def default_policies() -> Dict[Tier, TierPolicy]:
    """Differentiated budgets: critical tiers get tight deadlines and
    eager retries; preemptible tiers get their Restore-Later RTO as the
    deadline (a request may legitimately wait out the blackout)."""
    pol: Dict[Tier, TierPolicy] = {}
    for t, fc in DEFAULT_CLASS_OF_TIER.items():
        if fc.preemptible:
            rto = RTO_SECONDS[FailureClass.RESTORE_LATER]
            pol[t] = TierPolicy(deadline_s=2.0 * rto, max_retries=2,
                                backoff_base_s=30.0, queue_bound=512)
        else:
            pol[t] = TierPolicy(deadline_s=900.0, max_retries=3,
                                backoff_base_s=5.0, queue_bound=1024)
    return pol


class TieredScheduler:
    def __init__(self, engines: Dict[str, ServingEngine],
                 aging_rounds: int = 50,
                 policies: Optional[Dict[Tier, TierPolicy]] = None,
                 seed: int = 0):
        self.engines = engines
        self.aging_rounds = aging_rounds
        self.policies = default_policies()
        if policies:
            self.policies.update(policies)
        self._q: List[Tuple[int, int, int, Request]] = []  # (eff_tier, born, seq, r)
        self._seq = itertools.count()
        self.round = 0
        self.now = 0.0
        self.failover_active = False
        self.blocked: Set[Tier] = set()
        self._depth: Dict[Tier, int] = defaultdict(int)
        # retry buffer: (t_ready, seq, request) released when now >= t_ready
        self._retry: List[Tuple[float, int, Request]] = []
        # preempted blocked-tier work held until its tier is restored
        self._preempted: List[Tuple[ServingEngine, Request]] = []
        self._rng = random.Random(seed)    # deterministic backoff jitter
        self._aged_round = 0
        self.counters: Dict[str, Dict[Tier, int]] = {
            k: defaultdict(int)
            for k in ("arrived", "served", "rejected", "shed", "deadline",
                      "retry_exhausted", "preempted", "requeued")}
        # finalize log: (t, outcome, request) — consumed by the workload
        # driver to build per-step availability traces for the SLO monitor
        self.events: List[Tuple[float, str, Request]] = []

    # ------------------------------------------------------------------
    def policy(self, tier: Tier) -> TierPolicy:
        return self.policies.get(tier, TierPolicy())

    def submit(self, req: Request, now: Optional[float] = None):
        """External arrival: admission control (fail-fast + shedding),
        then enqueue.  ``now`` defaults to the scheduler clock."""
        t = self.now if now is None else float(now)
        pol = self.policy(req.tier)
        if req.t_arrival is None:
            req.t_arrival = t
        if req.deadline_s is None:
            req.deadline_s = pol.deadline_s
        self.counters["arrived"][req.tier] += 1
        if req.tier in self.blocked and pol.fail_fast_blocked:
            self._finalize(req, "rejected", t)
            return
        if pol.queue_bound is not None \
                and self._depth[req.tier] >= pol.queue_bound:
            self._finalize(req, "shed", t)
            return
        self._push(req)

    def _push(self, req: Request):
        """Enqueue without admission control (internal requeues)."""
        req.state = "queued"
        self._depth[req.tier] += 1
        heapq.heappush(
            self._q, (int(req.tier), self.round, next(self._seq), req))

    def _finalize(self, req: Request, outcome: str, t: float):
        req.t_finish = float(t)
        if outcome == "served":
            req.state = "done"
        else:
            req.state = "rejected" if outcome == "rejected" else "failed"
            req.fail_reason = outcome
        self.counters[outcome][req.tier] += 1
        self.events.append((float(t), outcome, req))
        if obs.enabled():
            obs.inc("ufa_serving_requests_total",
                    tier=req.tier.name, outcome=outcome)
            if outcome == "served" and req.t_arrival is not None:
                obs.observe("ufa_serving_request_latency_s",
                            float(t) - float(req.t_arrival),
                            tier=req.tier.name)

    # ------------------------------------------------------------------
    def _age_heap(self):
        """Re-key the heap with current effective tiers so starvation
        aging actually reorders pops: an ancient low-priority request is
        promoted one tier per ``aging_rounds`` rounds waited (ties break
        on ``born`` — oldest first), bounding its starvation."""
        if self._aged_round == self.round or not self._q:
            return
        self._aged_round = self.round
        if self.aging_rounds <= 0:
            return
        self._q = [
            (max(0, int(r.tier) - (self.round - born) // self.aging_rounds),
             born, seq, r)
            for (_, born, seq, r) in self._q]
        heapq.heapify(self._q)

    def _expired(self, r: Request) -> bool:
        return (r.deadline_s is not None and r.t_arrival is not None
                and self.now - r.t_arrival > r.deadline_s)

    def _pop_wave(self, engine: ServingEngine) -> List[Request]:
        """Pop up to ``max_batch`` equal-length requests this engine can
        serve, in aged-priority order; lazily expires deadline-blown and
        drops blocked-tier stragglers on the way."""
        self._age_heap()
        taken: List[Request] = []
        rest: List[Tuple[int, int, int, Request]] = []
        plen: Optional[int] = None
        while self._q and len(taken) < engine.max_batch:
            key, born, seq, r = heapq.heappop(self._q)
            self._depth[r.tier] -= 1
            if self._expired(r):
                self._finalize(r, "deadline", self.now)
                continue
            if r.tier in self.blocked:
                self._finalize(r, "rejected", self.now)
                continue
            if not engine.can_serve(r.tier) \
                    or (plen is not None and len(r.prompt) != plen):
                rest.append((key, born, seq, r))
                self._depth[r.tier] += 1
                continue
            plen = len(r.prompt)
            taken.append(r)
        for item in rest:
            heapq.heappush(self._q, item)
        return taken

    def tick(self, now: Optional[float] = None) -> int:
        """One scheduling round: release due retries, keep engines busy,
        run one decode step per engine.  ``now`` advances the sim clock
        (defaults to +1 s/round).  Returns decode steps executed."""
        self.round += 1
        self.now = self.now + 1.0 if now is None else max(self.now,
                                                          float(now))
        while self._retry and self._retry[0][0] <= self.now:
            _, _, r = heapq.heappop(self._retry)
            if self._expired(r):
                self._finalize(r, "deadline", self.now)
            else:
                self._push(r)
        steps = 0
        for engine in self.engines.values():
            if not engine.active:
                continue
            if not engine.wave and self._q:
                wave = self._pop_wave(engine)
                if wave:
                    engine.admit(wave)
                    for r in wave:
                        if r.state == "queued":   # didn't fit this wave
                            self._push(r)
                        elif r.state == "rejected":  # engine-level block
                            self._finalize(r, "rejected", self.now)
            if engine.wave:
                wave = list(engine.wave)
                engine.decode_round(self.now)
                steps += 1
                if not engine.wave:               # wave completed
                    for r in wave:
                        if r.state == "done":
                            self.counters["served"][r.tier] += 1
                            self.events.append((self.now, "served", r))
                            if obs.enabled():
                                obs.inc("ufa_serving_requests_total",
                                        tier=r.tier.name, outcome="served")
                                if r.t_arrival is not None:
                                    obs.observe(
                                        "ufa_serving_request_latency_s",
                                        self.now - float(r.t_arrival),
                                        tier=r.tier.name)
        return steps

    # ------------------------------------------------------------------
    # UFA failover integration
    # ------------------------------------------------------------------
    def absorb_preempted(self, engine: ServingEngine,
                         dropped: List[Request]):
        """Route a preempted wave: blocked-tier requests are held for
        post-restore requeue; others (critical riders of a mixed wave, or
        capacity-dip preemptions) retry immediately with backoff."""
        for r in dropped:
            self.counters["preempted"][r.tier] += 1
            if r.tier in self.blocked:
                self._preempted.append((engine, r))
            else:
                engine.restored_credit(r)
                self._requeue(r, self.now)

    def _requeue(self, r: Request, t: float):
        """Bounded retry with exponential backoff + jitter; re-prefill
        semantics (output restarts when the next wave starts)."""
        pol = self.policy(r.tier)
        r.attempts += 1
        if r.attempts > pol.max_retries:
            self._finalize(r, "retry_exhausted", t)
            return
        self.counters["requeued"][r.tier] += 1
        if obs.enabled():
            obs.inc("ufa_serving_retries_total", tier=r.tier.name)
        t_ready = t + pol.backoff(r.attempts, self._rng.random())
        r.state = "queued"
        heapq.heappush(self._retry, (t_ready, next(self._seq), r))

    def block_tier(self, tier: Tier, now: Optional[float] = None):
        """Blackout one tier: stop admission, preempt running waves that
        carry it, drain + reject its queued work (fail fast, §4.2).
        Rejections are charged at the scheduler level, not to an
        arbitrary engine."""
        if now is not None:
            self.now = max(self.now, float(now))
        self.blocked.add(tier)
        for engine in self.engines.values():
            engine.block_tiers({tier})
            if engine.wave and any(r.tier in self.blocked
                                   for r in engine.wave):
                self.absorb_preempted(engine, engine.preempt())
        kept = []
        while self._q:
            key, born, seq, r = heapq.heappop(self._q)
            if r.tier == tier:
                self._depth[r.tier] -= 1
                self._finalize(r, "rejected", self.now)
            else:
                kept.append((key, born, seq, r))
        for item in kept:
            heapq.heappush(self._q, item)

    def restore_tier(self, tier: Tier, now: Optional[float] = None):
        """Tier restored: reopen admission and requeue its held preempted
        work (re-prefill, one retry consumed, backoff + jitter)."""
        if now is not None:
            self.now = max(self.now, float(now))
        self.blocked.discard(tier)
        for engine in self.engines.values():
            engine.unblock_tiers({tier})
        held, rest = [], []
        for engine, r in self._preempted:
            (held if r.tier == tier else rest).append((engine, r))
        self._preempted = rest
        for engine, r in held:
            engine.restored_credit(r)
            self._requeue(r, self.now)

    def enter_failover(self, now: Optional[float] = None):
        """Block every preemptible tier, preempt its running work, drain
        its queue (fail fast until restoration)."""
        self.failover_active = True
        for t, fc in DEFAULT_CLASS_OF_TIER.items():
            if fc.preemptible:
                self.block_tier(t, now)

    def exit_failover(self, now: Optional[float] = None):
        self.failover_active = False
        for t, fc in DEFAULT_CLASS_OF_TIER.items():
            if fc.preemptible:
                self.restore_tier(t, now)

    # ------------------------------------------------------------------
    def queue_depth(self, tier: Optional[Tier] = None) -> int:
        if tier is not None:
            return self._depth[tier]
        return len(self._q)

    def preempted_pending(self, tier: Tier) -> int:
        return sum(1 for _, r in self._preempted if r.tier == tier)

    def availability(self, tier: Tier) -> float:
        """Scheduler-level request availability: served over every final
        (or still-preempted-pending) verdict for the tier.  Failures of
        all reasons — fail-fast rejections, shed arrivals, deadline
        misses, exhausted retries — count against the tier's SLA, as do
        preempted-and-not-yet-restored requests (§4.2: against the
        preemptible tier, never the critical one)."""
        c = self.counters
        s = c["served"][tier]
        fails = (c["rejected"][tier] + c["shed"][tier] + c["deadline"][tier]
                 + c["retry_exhausted"][tier])
        return s / max(1, s + fails + self.preempted_pending(tier))

    def drain_events(self) -> List[Tuple[float, str, Request]]:
        ev, self.events = self.events, []
        return ev
