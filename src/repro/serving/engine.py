"""Batched decode serving engine.

Wave-based continuous batching: up to ``max_batch`` equal-length requests
run as one wave (synthetic workloads use fixed prompt lengths; ragged
admission is future work — the UFA control-plane behaviors below are the
point).  The engine exposes exactly the hooks the UFA layer drives:

  - ``block_tiers`` / ``unblock_tiers``: the §4.2 traffic-isolation analog —
    requests of blocked tiers are refused at admission (fail-fast).
  - ``preempt()``: drop the running wave (Restore-Later semantics) and
    return its requests; KV caches are disposable on preemption, requests
    re-prefill after restore (stateless-service assumption, DESIGN.md §2).
  - ``active``: replica liveness — ``serving.failover.FailoverBridge``
    toggles it from the timeline kernel's per-tier capacity traces, so a
    full-peak failover evicts/restores actual inference replicas.
  - per-tier served/rejected/preempted/restored counters -> availability
    accounting with the §4.2 differentiated-SLA semantics: a preempted
    request counts against its own (preemptible) tier's SLA until it is
    requeued after restoration.
"""

from __future__ import annotations

import dataclasses
import functools
from collections import defaultdict
from typing import Dict, List, Optional, Set

import jax
import jax.numpy as jnp

from repro.core.tiers import Tier
from repro.models import (LMConfig, DecodeState, decode_step,
                          init_decode_state)


@functools.lru_cache(maxsize=None)
def _jitted_step(cfg: LMConfig):
    """One compiled decode step per ``LMConfig`` — shared by every engine
    built on the same config, so a multi-replica pool (the failover drill
    runs 6+) compiles each (batch,) shape once, not once per replica."""
    return jax.jit(lambda p, st, tok: decode_step(p, cfg, st, tok),
                   donate_argnums=(1,))


@dataclasses.dataclass
class Request:
    rid: int
    tier: Tier
    prompt: List[int]
    max_new_tokens: int = 16
    output: List[int] = dataclasses.field(default_factory=list)
    state: str = "queued"  # queued|running|done|rejected|preempted|failed
    # request-plane hardening fields (stamped by TieredScheduler.submit;
    # None means "scheduler fills from its clock / tier policy")
    t_arrival: Optional[float] = None
    deadline_s: Optional[float] = None
    attempts: int = 0                 # retry attempts consumed
    t_finish: Optional[float] = None  # sim time of the final verdict
    fail_reason: str = ""             # rejected|shed|deadline|retry_exhausted


class ServingEngine:
    def __init__(self, cfg: LMConfig, params, max_batch: int = 8,
                 max_seq: int = 256, cache_dtype=jnp.float32,
                 serves: Optional[Set[Tier]] = None):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.serves = set(serves) if serves is not None else None
        self.active = True            # replica liveness (FailoverBridge)
        self.blocked_tiers: Set[Tier] = set()
        self.counters: Dict[str, Dict[Tier, int]] = {
            k: defaultdict(int)
            for k in ("served", "rejected", "preempted", "restored")}
        self.wave: List[Request] = []
        self._state: Optional[DecodeState] = None
        self._step = _jitted_step(cfg)
        self.tokens_decoded = 0

    # ------------------------------------------------------------------
    def can_serve(self, tier: Tier) -> bool:
        return self.active and (self.serves is None or tier in self.serves)

    def admit(self, reqs: List[Request]) -> List[Request]:
        """Admission control: refuse blocked tiers, fill up to max_batch
        with equal-length prompts, highest criticality first."""
        if not self.active:
            return []                 # deactivated replica: leave queued
        accepted: List[Request] = []
        for r in sorted(reqs, key=lambda r: r.tier):
            if r.tier in self.blocked_tiers:
                r.state = "rejected"
                r.fail_reason = "rejected"
                self.counters["rejected"][r.tier] += 1
                continue
            if len(accepted) >= self.max_batch:
                r.state = "queued"
                continue
            if accepted and len(r.prompt) != len(accepted[0].prompt):
                continue  # wave requires uniform prompt length
            accepted.append(r)
        if accepted:
            self._start_wave(accepted)
        return accepted

    def _start_wave(self, reqs: List[Request]):
        assert not self.wave, "wave already running"
        self.wave = reqs
        for r in reqs:
            r.state = "running"
            r.output = []   # re-prefill after preemption: outputs restart
        B = len(reqs)
        self._state = init_decode_state(self.cfg, B, self.max_seq,
                                        self.cache_dtype)
        # prefill: feed prompt tokens (teacher-forced) through decode steps
        prompts = jnp.asarray([r.prompt for r in reqs], jnp.int32)
        for t in range(prompts.shape[1]):
            logits, self._state = self._step(self.params, self._state,
                                             prompts[:, t])
        self._last_logits = logits

    # ------------------------------------------------------------------
    def decode_round(self, now: Optional[float] = None) -> bool:
        """One greedy decode step for the running wave.  Returns True while
        the wave still has work.  ``now`` (sim time) stamps completions."""
        if not self.wave:
            return False
        next_tok = jnp.argmax(self._last_logits, axis=-1).astype(jnp.int32)
        for i, r in enumerate(self.wave):
            r.output.append(int(next_tok[i]))
        self.tokens_decoded += len(self.wave)
        done = all(len(r.output) >= r.max_new_tokens for r in self.wave)
        if done or int(self._state.length) >= self.max_seq - 1:
            for r in self.wave:
                r.state = "done"
                if now is not None:
                    r.t_finish = float(now)
                self.counters["served"][r.tier] += 1
            self.wave = []
            self._state = None
            return False
        self._last_logits, self._state = self._step(
            self.params, self._state, next_tok)
        return True

    # ------------------------------------------------------------------
    # UFA hooks
    # ------------------------------------------------------------------
    def block_tiers(self, tiers: Set[Tier]):
        self.blocked_tiers |= set(tiers)

    def unblock_tiers(self, tiers: Set[Tier]):
        self.blocked_tiers -= set(tiers)

    def preempt(self) -> List[Request]:
        """Drop the running wave (UFA eviction); caches are discarded."""
        dropped = self.wave
        for r in dropped:
            r.state = "preempted"
            self.counters["preempted"][r.tier] += 1
        self.wave = []
        self._state = None
        return dropped

    def restored_credit(self, req: Request):
        """A request this engine preempted has been requeued post-restore:
        it stops counting against this engine's availability (the request
        is back in flight, its final verdict lands wherever it completes)."""
        self.counters["restored"][req.tier] += 1

    def reset(self):
        """Back to a fresh steady state (pooled engines across drills)."""
        self.blocked_tiers = set()
        self.active = True
        self.counters = {
            k: defaultdict(int)
            for k in ("served", "rejected", "preempted", "restored")}
        self.wave = []
        self._state = None
        self.tokens_decoded = 0

    def availability(self, tier: Tier) -> float:
        """Per-tier request availability with §4.2 differentiated-SLA
        semantics: preempted-and-never-restored requests count against
        the (preemptible) tier they belong to."""
        s = self.counters["served"][tier]
        rej = self.counters["rejected"][tier]
        pending = max(0, self.counters["preempted"][tier]
                      - self.counters["restored"][tier])
        return s / max(1, s + rej + pending)
