"""Batched decode serving engine.

Wave-based continuous batching: up to ``max_batch`` equal-length requests
run as one wave (synthetic workloads use fixed prompt lengths; ragged
admission is future work — the UFA control-plane behaviors below are the
point).  The engine exposes exactly the hooks the UFA layer drives:

  - ``block_tiers`` / ``unblock_tiers``: the §4.2 traffic-isolation analog —
    requests of blocked tiers are refused at admission (fail-fast).
  - ``preempt()``: drop the running wave (Restore-Later semantics) and
    return its requests; KV caches are disposable on preemption, requests
    re-prefill after restore (stateless-service assumption, DESIGN.md §2).
  - per-tier served/rejected/preempted counters -> availability accounting.
"""

from __future__ import annotations

import dataclasses
import time
from collections import defaultdict
from typing import Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp

from repro.core.tiers import Tier
from repro.models import (LMConfig, DecodeState, decode_step,
                          init_decode_state)


@dataclasses.dataclass
class Request:
    rid: int
    tier: Tier
    prompt: List[int]
    max_new_tokens: int = 16
    output: List[int] = dataclasses.field(default_factory=list)
    state: str = "queued"     # queued|running|done|rejected|preempted


class ServingEngine:
    def __init__(self, cfg: LMConfig, params, max_batch: int = 8,
                 max_seq: int = 256, cache_dtype=jnp.float32):
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.cache_dtype = cache_dtype
        self.blocked_tiers: Set[Tier] = set()
        self.counters: Dict[str, Dict[Tier, int]] = {
            k: defaultdict(int) for k in ("served", "rejected", "preempted")}
        self.wave: List[Request] = []
        self._state: Optional[DecodeState] = None
        self._step = jax.jit(
            lambda p, st, tok: decode_step(p, cfg, st, tok),
            donate_argnums=(1,))
        self.tokens_decoded = 0

    # ------------------------------------------------------------------
    def admit(self, reqs: List[Request]) -> List[Request]:
        """Admission control: refuse blocked tiers, fill up to max_batch
        with equal-length prompts, highest criticality first."""
        accepted: List[Request] = []
        for r in sorted(reqs, key=lambda r: r.tier):
            if r.tier in self.blocked_tiers:
                r.state = "rejected"
                self.counters["rejected"][r.tier] += 1
                continue
            if len(accepted) >= self.max_batch:
                r.state = "queued"
                continue
            if accepted and len(r.prompt) != len(accepted[0].prompt):
                continue  # wave requires uniform prompt length
            accepted.append(r)
        if accepted:
            self._start_wave(accepted)
        return accepted

    def _start_wave(self, reqs: List[Request]):
        assert not self.wave, "wave already running"
        self.wave = reqs
        for r in reqs:
            r.state = "running"
        B = len(reqs)
        self._state = init_decode_state(self.cfg, B, self.max_seq,
                                        self.cache_dtype)
        # prefill: feed prompt tokens (teacher-forced) through decode steps
        prompts = jnp.asarray([r.prompt for r in reqs], jnp.int32)
        for t in range(prompts.shape[1]):
            logits, self._state = self._step(self.params, self._state,
                                             prompts[:, t])
        self._last_logits = logits

    # ------------------------------------------------------------------
    def decode_round(self) -> bool:
        """One greedy decode step for the running wave.  Returns True while
        the wave still has work."""
        if not self.wave:
            return False
        next_tok = jnp.argmax(self._last_logits, axis=-1).astype(jnp.int32)
        for i, r in enumerate(self.wave):
            r.output.append(int(next_tok[i]))
        self.tokens_decoded += len(self.wave)
        done = all(len(r.output) >= r.max_new_tokens for r in self.wave)
        if done or int(self._state.length) >= self.max_seq - 1:
            for r in self.wave:
                r.state = "done"
                self.counters["served"][r.tier] += 1
            self.wave = []
            self._state = None
            return False
        self._last_logits, self._state = self._step(
            self.params, self._state, next_tok)
        return True

    # ------------------------------------------------------------------
    # UFA hooks
    # ------------------------------------------------------------------
    def block_tiers(self, tiers: Set[Tier]):
        self.blocked_tiers |= set(tiers)

    def unblock_tiers(self, tiers: Set[Tier]):
        self.blocked_tiers -= set(tiers)

    def preempt(self) -> List[Request]:
        """Drop the running wave (UFA eviction); caches are discarded."""
        dropped = self.wave
        for r in dropped:
            r.state = "preempted"
            self.counters["preempted"][r.tier] += 1
        self.wave = []
        self._state = None
        return dropped

    def availability(self, tier: Tier) -> float:
        s = self.counters["served"][tier]
        rej = self.counters["rejected"][tier]
        return s / max(1, s + rej)
