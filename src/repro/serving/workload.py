"""Open-loop Poisson workload driver: a live workload through a failover.

``run_drill`` pushes a synthetic millions-of-users request trace (open
loop: arrivals never wait for completions, Poisson per tick from one
seeded stream) through a scripted full-peak failover:

  1. a paper-shaped fleet is synthesized and the timeline kernel
     simulates its failover (``simulate_timeline``);
  2. a :class:`~repro.serving.failover.FailoverBridge` replays the
     per-tier capacity traces as replica actuation on a pool of real
     ``ServingEngine`` replicas behind a hardened ``TieredScheduler``;
  3. tiered Poisson arrivals (critical traffic doubling as the surviving
     region absorbs the failed region's users) flow through the same
     window, and every request gets a user-visible verdict.

The result is a :class:`DrillReport` of *measured request* SLOs — p50/p99
latency, goodput, availability, time-to-restore per tier — fed through
the ``obs`` burn-rate monitors (``obs.slo.alerts_np``), in contrast to
the core-count availability the sweep engine reports.

Two chaos knobs make the drill a campaign target (``chaos.faults``
``REQUEST_FAMILIES``): ``arrival_mult`` scales every arrival rate (the
arrival-spike family) and ``retry_storm`` adds speculative client
duplicates per arrival (the retry-storm family).  ``drill_oracle`` wraps
the drill for ``chaos.Campaign`` so bisection can localize the
request-level SLA frontier; drills are bit-deterministic per spec, so
``verify_report`` replays campaigns exactly.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Mapping, Tuple

import numpy as np

from repro import obs
from repro.core.scenarios import stage_seed
from repro.core.tiers import FailureClass, RTO_SECONDS, Tier
from repro.core.timeline_sim import (TimelineConfig, config_for_fleet,
                                     default_ts, simulate_timeline)
from repro.serving.engine import Request, ServingEngine
from repro.serving.failover import FailoverBridge, ReplicaGroup
from repro.serving.scheduler import TieredScheduler, TierPolicy

__all__ = ["DrillSpec", "TierVerdict", "DrillReport", "run_drill",
           "drill_oracle", "request_campaign"]

# tiny model: the workload is real (jitted decode), the model is not the
# point — same shape the seed failover_drill example uses
_LM = dict(name="live-drill", n_layers=2, d_model=64, n_heads=4,
           n_kv_heads=2, d_head=16, d_ff=128, vocab_size=128,
           tie_embeddings=True)


@dataclasses.dataclass(frozen=True)
class DrillSpec:
    """A fully seeded live-workload failover drill.

    Frozen + hashable so the engine pool and the (workload-independent)
    timeline simulation are cached across drills — a chaos campaign
    re-runs the workload per probe, not the fleet synthesis or the jit
    compilation."""
    # control plane
    scale: float = 0.02            # fleet synthesis scale
    fleet_seed: int = 4
    horizon_s: float = 7200.0
    n_steps: int = 96
    traffic_mult: float = 2.0      # surviving-region multiplier (sim + load)
    # serving pool
    crit_tier: Tier = Tier.T1
    pre_tier: Tier = Tier.T5
    crit_replicas: int = 2
    crit_standby: int = 2          # Always-On upscale headroom
    pre_replicas: int = 2
    max_batch: int = 4
    prompt_len: int = 4
    max_new_tokens: int = 4
    # workload
    crit_rps: float = 0.06         # steady critical arrivals / sim-second
    pre_rps: float = 0.12
    users_per_request: float = 7000.0
    ticks_per_step: int = 5        # scheduler rounds per trace step
    ramp_s: float = 480.0          # city-wave ramp of the 2x crit traffic
    seed: int = 0
    drain: bool = True             # run the queue dry past the horizon
    # chaos knobs (request-plane fault families)
    arrival_mult: float = 1.0      # arrival-spike severity knob
    retry_storm: float = 0.0       # speculative-duplicate severity knob
    # request-level SLA
    avail_slo: float = 0.9997
    crit_p99_slo_s: float = 150.0

    @property
    def rates(self) -> Dict[Tier, float]:
        return {self.crit_tier: self.crit_rps, self.pre_tier: self.pre_rps}


@dataclasses.dataclass
class TierVerdict:
    """User-visible per-tier outcome of one drill."""
    tier: str
    arrived: int
    served: int
    rejected: int
    shed: int
    deadline: int
    retry_exhausted: int
    preempted: int
    requeued: int
    pending: int                   # in flight at the end (censored)
    availability: float            # served / completed verdicts
    goodput_rps: float             # served / horizon
    p50_s: float
    p99_s: float
    time_to_restore_s: float       # first post-blackout completion (inf: n/a)
    slo_alert: bool                # burn-rate monitor fired on this tier
    t_first_alert_s: float

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class DrillReport:
    spec: DrillSpec
    tiers: Dict[Tier, TierVerdict]
    sla_ok: bool
    users_served: float
    actuation_log: List[Tuple[float, Tier, int]]
    avail_trace: Dict[Tier, np.ndarray]    # per-step availability (SLO input)
    ts: np.ndarray

    @property
    def crit(self) -> TierVerdict:
        return self.tiers[self.spec.crit_tier]

    @property
    def pre(self) -> TierVerdict:
        return self.tiers[self.spec.pre_tier]

    def render(self) -> str:
        lines = [
            f"live failover drill  seed={self.spec.seed}  "
            f"horizon={self.spec.horizon_s:.0f}s  "
            f"~{self.users_served / 1e6:.2f}M users served  "
            f"SLA: {'PASS' if self.sla_ok else 'FAIL'}",
            f"{'tier':<6}{'arrived':>8}{'served':>8}{'failed':>8}"
            f"{'avail':>9}{'p50':>8}{'p99':>8}{'restore':>9}  slo",
        ]
        for t in sorted(self.tiers):
            v = self.tiers[t]
            failed = (v.rejected + v.shed + v.deadline + v.retry_exhausted)
            rest = ("-" if not np.isfinite(v.time_to_restore_s)
                    else f"{v.time_to_restore_s:.0f}s")
            lines.append(
                f"{v.tier:<6}{v.arrived:>8}{v.served:>8}{failed:>8}"
                f"{v.availability:>9.4f}{v.p50_s:>7.0f}s{v.p99_s:>7.0f}s"
                f"{rest:>9}  "
                + ("ALERT" if v.slo_alert else "ok"))
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# cached heavyweight pieces: fleet/timeline sim + the engine pool
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=8)
def _sim_for(scale: float, fleet_seed: int, horizon_s: float, n_steps: int,
             traffic_mult: float
             ) -> Tuple[TimelineConfig, Dict[str, np.ndarray]]:
    from repro.core.service import synthesize_fleet
    fleet = synthesize_fleet(scale=scale, seed=fleet_seed)
    cfg = config_for_fleet(fleet)
    sim = simulate_timeline(cfg, {"traffic_mult": traffic_mult},
                            ts=default_ts(horizon_s, n_steps))
    return cfg, sim


@functools.lru_cache(maxsize=4)
def _engine_pool(crit_tier: Tier, pre_tier: Tier, crit_replicas: int,
                 crit_standby: int, pre_replicas: int, max_batch: int,
                 max_seq: int) -> Tuple[Dict[str, ServingEngine],
                                        Tuple[ReplicaGroup, ...]]:
    import jax

    from repro.models import LMConfig, init_params
    cfg = LMConfig(**_LM)
    params = init_params(cfg, jax.random.PRNGKey(0))
    crit_serves = {t for t in Tier if t.is_critical}
    pre_serves = set(Tier) - crit_serves
    engines: Dict[str, ServingEngine] = {}
    crit_names, pre_names = [], []
    for i in range(crit_replicas + crit_standby):
        name = f"crit-{i}"
        engines[name] = ServingEngine(cfg, params, max_batch=max_batch,
                                      max_seq=max_seq, serves=crit_serves)
        crit_names.append(name)
    for i in range(pre_replicas):
        name = f"pre-{i}"
        engines[name] = ServingEngine(cfg, params, max_batch=max_batch,
                                      max_seq=max_seq, serves=pre_serves)
        pre_names.append(name)
    groups = (ReplicaGroup(crit_tier, tuple(crit_names), crit_replicas),
              ReplicaGroup(pre_tier, tuple(pre_names), pre_replicas))
    return engines, groups


def _policies(spec: DrillSpec) -> Dict[Tier, TierPolicy]:
    rto = RTO_SECONDS[FailureClass.RESTORE_LATER]
    return {
        spec.crit_tier: TierPolicy(deadline_s=900.0, max_retries=3,
                                   backoff_base_s=5.0, queue_bound=1024),
        spec.pre_tier: TierPolicy(deadline_s=2.0 * rto, max_retries=2,
                                  backoff_base_s=30.0, queue_bound=512),
    }


# ---------------------------------------------------------------------------
# the drill
# ---------------------------------------------------------------------------

def run_drill(spec: DrillSpec) -> DrillReport:
    """One scripted full-peak failover under live load.  Deterministic:
    the same spec reproduces every verdict bit for bit (one seeded
    arrival stream, greedy decode, deterministic backoff jitter)."""
    cfg, sim = _sim_for(spec.scale, spec.fleet_seed, spec.horizon_s,
                        spec.n_steps, spec.traffic_mult)
    engines, groups = _engine_pool(
        spec.crit_tier, spec.pre_tier, spec.crit_replicas,
        spec.crit_standby, spec.pre_replicas, spec.max_batch,
        spec.prompt_len + spec.max_new_tokens + 8)
    for e in engines.values():
        e.reset()
    sched = TieredScheduler(engines, policies=_policies(spec),
                            seed=stage_seed(spec.seed, "drill-jitter"))
    bridge = FailoverBridge(sched, groups)
    rng = np.random.default_rng(stage_seed(spec.seed, "drill-arrivals"))

    ts = sim["t"]
    dt = float(ts[1] - ts[0])
    tick_dt = dt / spec.ticks_per_step
    kill_t = float(cfg.kill_s)
    tiers = sorted(spec.rates)
    rid = iter(range(10 ** 9))
    lat: Dict[Tier, List[float]] = {t: [] for t in tiers}
    served_at: Dict[Tier, List[float]] = {t: [] for t in tiers}
    # per-step (served, failed) tallies -> availability trace per tier
    tally = {t: np.zeros((spec.n_steps, 2), np.int64) for t in tiers}

    def crit_mult(t: float) -> float:
        if t < kill_t:
            return 1.0
        ramp = min(1.0, (t - kill_t) / max(spec.ramp_s, 1e-9))
        return 1.0 + (spec.traffic_mult - 1.0) * ramp

    def record(events, step: int):
        for t_ev, outcome, r in events:
            if r.tier not in tally:
                continue
            i = min(step, spec.n_steps - 1)
            if outcome == "served":
                tally[r.tier][i, 0] += 1
                lat[r.tier].append(t_ev - float(r.t_arrival))
                served_at[r.tier].append(t_ev)
            else:
                tally[r.tier][i, 1] += 1

    for i in range(spec.n_steps):
        t0 = float(ts[i])
        bridge.drive_step(sim, cfg, i)
        for j in range(spec.ticks_per_step):
            t_tick = t0 + (j + 1) * tick_dt
            for tier in tiers:
                rate = spec.rates[tier] * spec.arrival_mult
                if tier.is_critical:
                    rate *= crit_mult(t_tick)
                n = int(rng.poisson(rate * tick_dt))
                if spec.retry_storm > 0.0 and n:
                    # speculative client duplicates (retry storm): extra
                    # copies of this tick's arrivals, same load path
                    n += int(rng.poisson(n * 3.0 * spec.retry_storm))
                for _ in range(n):
                    prompt = rng.integers(
                        0, _LM["vocab_size"], spec.prompt_len).tolist()
                    sched.submit(Request(
                        next(rid), tier=tier, prompt=prompt,
                        max_new_tokens=spec.max_new_tokens), now=t_tick)
            sched.tick(now=t_tick)
        record(sched.drain_events(), i)
        if obs.enabled():
            for tier in tiers:
                obs.set_gauge("ufa_serving_queue_depth",
                              sched.queue_depth(tier), tier=tier.name)

    if spec.drain:   # let retries/requeues complete past the horizon
        t = float(ts[-1]) + dt
        for _ in range(20 * spec.ticks_per_step * spec.n_steps):
            busy = sched.tick(now=t)
            t += tick_dt
            if not busy and not sched._q and not sched._retry:
                break
        record(sched.drain_events(), spec.n_steps - 1)

    # ---- verdicts ------------------------------------------------------
    from repro.obs.slo import alerts_np
    blackout_t = next((t for t, tier, tgt in bridge.log
                       if tier == spec.pre_tier and tgt == 0), None)
    react_t = None          # capacity back after the blackout
    if blackout_t is not None:
        react_t = next((t for t, tier, tgt in bridge.log
                        if tier == spec.pre_tier and tgt > 0
                        and t > blackout_t), None)
    verdicts: Dict[Tier, TierVerdict] = {}
    avail_trace: Dict[Tier, np.ndarray] = {}
    users_served = 0.0
    for tier in tiers:
        c = {k: sched.counters[k][tier] for k in sched.counters}
        done, failed = tally[tier][:, 0], tally[tier][:, 1]
        tot = done + failed
        avail = np.where(tot > 0, done / np.maximum(tot, 1), 1.0)
        avail_trace[tier] = avail
        al = alerts_np(avail, ts, target=spec.avail_slo)
        fails = (c["rejected"] + c["shed"] + c["deadline"]
                 + c["retry_exhausted"])
        pending = max(0, c["arrived"] - c["served"] - fails)  # censored
        ls = np.asarray(lat[tier], np.float64)
        # user-visible time-to-restore: blackout entry -> first served
        # completion once the bridge has reactivated capacity
        restore = float("inf")
        if blackout_t is not None and react_t is not None:
            post = [t_s for t_s in served_at[tier] if t_s >= react_t]
            if post:
                restore = min(post) - blackout_t
        verdicts[tier] = TierVerdict(
            tier=tier.name, arrived=c["arrived"], served=c["served"],
            rejected=c["rejected"], shed=c["shed"], deadline=c["deadline"],
            retry_exhausted=c["retry_exhausted"], preempted=c["preempted"],
            requeued=c["requeued"], pending=pending,
            availability=sched.availability(tier),
            goodput_rps=c["served"] / spec.horizon_s,
            p50_s=float(np.percentile(ls, 50)) if ls.size else float("nan"),
            p99_s=float(np.percentile(ls, 99)) if ls.size else float("nan"),
            time_to_restore_s=restore if tier == spec.pre_tier
            else (0.0 if c["served"] else float("inf")),
            slo_alert=bool(al["alert"]),
            t_first_alert_s=float(al["t_first_alert"]))
        users_served += c["served"] * spec.users_per_request

    crit, pre = verdicts[spec.crit_tier], verdicts[spec.pre_tier]
    rto = RTO_SECONDS[FailureClass.RESTORE_LATER]
    sla_ok = (crit.availability >= spec.avail_slo
              and not crit.slo_alert
              and np.isfinite(crit.p99_s)
              and crit.p99_s <= spec.crit_p99_slo_s
              and pre.time_to_restore_s <= rto)
    report = DrillReport(spec=spec, tiers=verdicts, sla_ok=bool(sla_ok),
                         users_served=users_served,
                         actuation_log=list(bridge.log),
                         avail_trace=avail_trace, ts=np.asarray(ts))
    return report


# ---------------------------------------------------------------------------
# chaos integration: the drill as a campaign target
# ---------------------------------------------------------------------------

def drill_oracle(base: DrillSpec) -> Callable:
    """Wrap the drill as a ``chaos.Campaign`` oracle over the
    request-plane fault knobs: each scenario row maps ``arrival_mult`` /
    ``retry_storm`` onto a fresh deterministic drill; ``ok`` is the
    drill's request-level SLA verdict.  Rows are independent drills, so
    replayed batches are bit-identical regardless of batch composition
    (``verify_report(..., oracle=...)``)."""

    def oracle(grid: Mapping[str, np.ndarray]):
        n = len(next(iter(grid.values())))
        am = np.asarray(grid.get("arrival_mult",
                                 np.full(n, base.arrival_mult)), np.float64)
        rs = np.asarray(grid.get("retry_storm",
                                 np.full(n, base.retry_storm)), np.float64)
        ok = np.zeros(n, bool)
        res = {k: np.zeros(n, np.float64) for k in
               ("sla_ok", "crit_availability", "crit_p99_s",
                "pre_restore_s")}
        for i in range(n):
            rep = run_drill(dataclasses.replace(
                base, arrival_mult=float(am[i]), retry_storm=float(rs[i])))
            ok[i] = rep.sla_ok
            res["sla_ok"][i] = float(rep.sla_ok)
            res["crit_availability"][i] = rep.crit.availability
            res["crit_p99_s"][i] = rep.crit.p99_s
            res["pre_restore_s"][i] = rep.pre.time_to_restore_s
        return ok, res

    return oracle


def request_campaign(base: DrillSpec, *, rays=None, tol: float = 1.0 / 16.0,
                     max_rounds: int = 6, **kw):
    """A chaos campaign over the request-plane fault families: hunts the
    arrival-spike / retry-storm severities at which the drill's measured
    request-level SLA first breaks."""
    from repro.chaos.campaign import Campaign, Ray
    from repro.chaos.faults import REQUEST_FAMILIES
    if rays is None:
        rays = (Ray("arrival_spike", {"arrival_spike": 1.0}),
                Ray("retry_storm", {"retry_storm": 1.0}))
    return Campaign(oracle=drill_oracle(base), rays=rays,
                    families=REQUEST_FAMILIES, tol=tol,
                    max_rounds=max_rounds, seed=base.seed, **kw)
