"""Control plane ⇄ serving plane bridge: capacity traces as replica actuation.

The timeline kernel (``core.timeline_sim``) and the Orchestrator
(``core.omg``) both produce per-tier *live-core* trajectories through a
full-peak failover.  :class:`FailoverBridge` replays either one as
replica-count actuation on a pool of :class:`~repro.serving.ServingEngine`
replicas grouped by tier:

  - full-peak entry drives a preemptible tier's live fraction to ~0 →
    its replicas deactivate, running waves are preempted (KV caches
    dropped), and the tier is blacked out at the scheduler (fail-fast
    §4.2);
  - Restore-Later capacity returns only when the trace says it does —
    burst conversion after the preheat delay, cloud arrivals after
    ``provision_time`` — so replicas (and the tier's admission) come
    back exactly when the control plane restores cores, and held
    preempted requests are requeued to re-prefill (stateless-service
    assumption);
  - Always-On tiers can exceed their steady fraction (the in-place 2x
    upscale into the failover buffer): standby replicas activate to
    absorb the surviving-region traffic multiplier.

Two drive modes, one actuation formula (``target = round(base * frac)``
clamped to the group's slots):

  - :meth:`drive_trace` / :meth:`drive_step` replay a
    ``simulate_timeline`` result step by step (the deterministic path
    the workload driver and the chaos drills use);
  - :meth:`bind` chains onto an Orchestrator's ``on_evict`` /
    ``on_migrate`` / ``on_restore`` callbacks and recomputes the same
    per-tier live fractions from ``orch.fs`` at event-loop time — the
    discrete-event path, parity-tested against the trace path.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Mapping, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core.tiers import DEFAULT_CLASS_OF_TIER, Tier
from repro.core.timeline_sim import N_TIERS, RESTORE_THRESH, TimelineConfig
from repro.serving.scheduler import TieredScheduler

__all__ = ["ReplicaGroup", "FailoverBridge", "tier_live_fractions"]


@dataclasses.dataclass(frozen=True)
class ReplicaGroup:
    """Replica slots of one tier: ``names`` index ``scheduler.engines``
    in activation order; the first ``base`` are active in steady state,
    the rest are standby headroom (Always-On upscale)."""
    tier: Tier
    names: Tuple[str, ...]
    base: int

    def __post_init__(self):
        if not 0 < self.base <= len(self.names):
            raise ValueError(
                f"group {self.tier.name}: base {self.base} not in "
                f"[1, {len(self.names)}]")


def tier_live_fractions(sim: Mapping[str, np.ndarray], cfg: TimelineConfig,
                        step: int) -> np.ndarray:
    """Per-tier live fraction at one trace step: ``tier_live / totals``."""
    totals = np.maximum(cfg.tier_totals(), 1e-9)
    return np.asarray(sim["tier_live"][step], np.float64) / totals


class FailoverBridge:
    def __init__(self, scheduler: TieredScheduler,
                 groups: Sequence[ReplicaGroup],
                 restore_thresh: float = RESTORE_THRESH):
        self.sched = scheduler
        self.groups: Dict[Tier, ReplicaGroup] = {}
        for g in groups:
            if g.tier in self.groups:
                raise ValueError(f"duplicate group for tier {g.tier.name}")
            for n in g.names:
                if n not in scheduler.engines:
                    raise KeyError(f"group {g.tier.name}: engine {n!r} "
                                   "not in scheduler.engines")
            self.groups[g.tier] = g
        self.restore_thresh = float(restore_thresh)
        # actuation log: (t, tier, target) — one entry per target change
        self.log: List[Tuple[float, Tier, int]] = []
        for g in self.groups.values():    # steady state: base active
            self._apply(0.0, g, g.base, record=False)

    # ------------------------------------------------------------------
    @staticmethod
    def target_for(group: ReplicaGroup, frac: float) -> int:
        """Replica target for a live fraction — the one actuation formula
        both drive modes share (parity-tested)."""
        return int(np.clip(round(group.base * frac), 0, len(group.names)))

    def active_count(self, tier: Tier) -> int:
        g = self.groups[tier]
        return sum(self.sched.engines[n].active for n in g.names)

    def actuate(self, now: float, live_frac: np.ndarray):
        """Drive every group toward ``round(base * frac)`` replicas; a
        preemptible tier is blacked out while its target is 0 and
        restored when capacity returns."""
        for tier, g in self.groups.items():
            self._apply(now, g, self.target_for(g, float(live_frac[tier])))

    def _apply(self, now: float, g: ReplicaGroup, target: int,
               record: bool = True):
        cur = self.active_count(g.tier)
        if target == cur:
            return
        preemptible = DEFAULT_CLASS_OF_TIER[g.tier].preemptible
        if target < cur:
            if preemptible and target == 0 \
                    and g.tier not in self.sched.blocked:
                # blackout first: queued work fails fast, running waves
                # are preempted and *held* for post-restore requeue
                self.sched.block_tier(g.tier, now)
            for name in reversed(g.names):      # standby-last deactivation
                if cur <= target:
                    break
                eng = self.sched.engines[name]
                if eng.active:
                    dropped = eng.preempt()
                    eng.active = False
                    if dropped:
                        self.sched.absorb_preempted(eng, dropped)
                    cur -= 1
        else:
            for name in g.names:
                if cur >= target:
                    break
                eng = self.sched.engines[name]
                if not eng.active:
                    eng.active = True
                    cur += 1
            if preemptible and g.tier in self.sched.blocked:
                self.sched.restore_tier(g.tier, now)
        if record:
            self.log.append((float(now), g.tier, target))
        if obs.enabled():
            obs.set_gauge("ufa_serving_replicas_active", target,
                          tier=g.tier.name)

    # ------------------------------------------------------------------
    # drive mode 1: timeline-kernel traces
    # ------------------------------------------------------------------
    def drive_step(self, sim: Mapping[str, np.ndarray], cfg: TimelineConfig,
                   step: int):
        self.actuate(float(sim["t"][step]),
                     tier_live_fractions(sim, cfg, step))

    def drive_trace(self, sim: Mapping[str, np.ndarray],
                    cfg: TimelineConfig):
        """Replay a whole ``simulate_timeline`` result (no workload —
        pure actuation; the workload driver interleaves arrivals)."""
        for i in range(len(sim["t"])):
            self.drive_step(sim, cfg, i)

    # ------------------------------------------------------------------
    # drive mode 2: live Orchestrator events
    # ------------------------------------------------------------------
    def bind(self, orch):
        """Chain onto the orchestrator's eviction/migration/restoration
        callbacks: after each fired service-environment, recompute the
        per-tier live fractions from ``orch.fs`` at ``orch.loop.now`` and
        actuate.  Same formula as the trace path — restores only happen
        when the event loop delivers capacity (cloud ``provision_time``
        included), so the two modes agree step for step."""
        totals = np.maximum(np.bincount(
            np.asarray(orch.fs.tier, np.int64),
            weights=np.asarray(orch.fs.spec_cores, np.float64),
            minlength=N_TIERS), 1e-9)

        def fire(_spec=None):
            live = np.bincount(
                np.asarray(orch.fs.tier, np.int64),
                weights=np.asarray(orch.fs.cores_live, np.float64),
                minlength=N_TIERS)
            self.actuate(float(orch.loop.now), live / totals)

        def chained(prev):
            if prev is None:
                return fire

            def cb(spec):
                prev(spec)
                fire(spec)
            return cb

        orch.on_evict = chained(orch.on_evict)
        orch.on_migrate = chained(orch.on_migrate)
        orch.on_restore = chained(orch.on_restore)
        return self
