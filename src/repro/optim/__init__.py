from repro.optim.adamw import (  # noqa: F401
    AdamWState,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    cosine_schedule,
    make_optimizer,
)
from repro.optim.capacity import (  # noqa: F401
    CapacityOptResult,
    DesignBase,
    certification_grid,
    design_consts,
    eviction_deltas,
    hardening_weights,
    knob_design,
    legacy_knobs,
    make_knobs,
    optimize_capacity,
    soft_loss,
    ufa_knobs,
    verify_design,
)
