"""Gradient compression: int8 quantization with stochastic rounding.

For cross-pod data parallelism the gradient all-reduce over the (slow,
inter-pod) "pod" axis dominates; quantizing to int8 with a per-tensor scale
cuts that wire traffic 4x vs bf16 (8x vs fp32).  Pattern: quantize ->
psum(int32) -> dequantize, which is exactly associative, so the mean is
unbiased when paired with stochastic rounding.
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def quantize_int8(x: jnp.ndarray, key) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (q int8, scale).  Stochastic rounding keeps E[deq(q)] = x."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)))
    scale = jnp.maximum(amax / 127.0, 1e-12)
    y = x.astype(jnp.float32) / scale
    noise = jax.random.uniform(key, x.shape, jnp.float32, -0.5, 0.5)
    q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compressed_psum_grads(grads: Any, axis_name: str, key) -> Any:
    """int8-compressed gradient mean over ``axis_name`` (inside shard_map):
    each participant quantizes, int32-psums, dequantizes with the max scale.

    Bias note: participants use their own scale; summing int8 payloads with
    per-participant scales requires a shared scale — we pmax the scale first
    (one tiny scalar collective) so the quantization grid is common.
    """
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    keys = jax.random.split(key, len(leaves))
    n = lax.psum(1.0, axis_name)
    out = []
    for leaf, k in zip(leaves, keys):
        amax = jnp.max(jnp.abs(leaf.astype(jnp.float32)))
        scale = jnp.maximum(lax.pmax(amax, axis_name) / 127.0, 1e-12)
        y = leaf.astype(jnp.float32) / scale
        noise = jax.random.uniform(k, leaf.shape, jnp.float32, -0.5, 0.5)
        q = jnp.clip(jnp.round(y + noise), -127, 127).astype(jnp.int32)
        s = lax.psum(q, axis_name)
        out.append((s.astype(jnp.float32) * scale / n).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
