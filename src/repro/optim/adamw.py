"""AdamW in pure JAX, with optional low-precision first/second moments.

For trillion-parameter configs (kimi-k2) the optimizer state dominates HBM;
``state_dtype="bfloat16"`` halves it at negligible quality cost (the update
math still runs in fp32).  State shardings mirror the parameter shardings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray           # () int32
    m: Any                      # pytree like params
    v: Any


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(1.0, warmup)
        prog = jnp.clip((step - warmup) / jnp.maximum(1.0, total - warmup), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree_util.tree_map(lambda g: (g.astype(jnp.float32) * scale
                                             ).astype(g.dtype), grads), gnorm


def adamw_init(params, state_dtype: Optional[str] = None) -> AdamWState:
    def zeros(p):
        dt = jnp.dtype(state_dtype) if state_dtype else p.dtype
        return jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, max_grad_norm: float = 1.0
                 ) -> Tuple[Any, AdamWState, dict]:
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    step = state.step + 1
    lr_t = lr(step) if callable(lr) else jnp.asarray(lr, jnp.float32)
    b1c = 1 - b1 ** step.astype(jnp.float32)
    b2c = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m32 / b1c
        vhat = v32 / b2c
        delta = mhat / (jnp.sqrt(vhat) + eps)
        # decoupled weight decay only on >=2D tensors (skip norms/scalars)
        if p.ndim >= 2:
            delta = delta + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)
        return new_p, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr_t}


def make_optimizer(lr=3e-4, state_dtype: Optional[str] = None, **kw) -> Optimizer:
    return Optimizer(
        init=lambda params: adamw_init(params, state_dtype),
        update=lambda grads, state, params: adamw_update(
            grads, state, params, lr=lr, **kw),
    )
