"""Differentiable capacity optimizer over the fused sweep engine.

The paper's provisioning knobs — the Always-On buffer fraction, the
tier-to-failure-class mix, the 1.5x overcommit factor, the batch->burst
conversion ramp, and the eviction order — were hand-tuned: §4.4's
simulator recommended the overcommit factor, Table 5's rollout phases
picked the class mix, and the 2x buffer survived on Tier 0 by fiat.
This module closes the loop: *minimize provisioned cores subject to the
99.97 % SLA across a scenario ensemble*, searching those same knobs with
the fused sweep engine (``repro.core.sweep_engine``) as the constraint
oracle.

Two search modes, sharing one design parameterization:

  * ``mode="grad"`` — ``jax.grad`` straight through the soft-relaxed
    fused pipeline (``soft_tau``: every hard verdict becomes a sigmoid
    of its signed margin, see ``timeline_sim.soft_ge``), AdamW on the
    knob logits with a temperature schedule annealing the relaxation
    down to the exact model.
  * ``mode="cem"`` — a vmapped cross-entropy/evolutionary loop over the
    *hard* (bit-exact) objective: every generation evaluates the whole
    population x ensemble batch through the engine's bucket-padded
    ``lax.map`` chunks (``bucket_shape`` + ``_fused_verdicts_block``) in
    ONE jitted call shaped exactly like ``SweepEngine.run``.

``mode="both"`` (default) anneals gradients first, then lets CEM polish
the non-smooth corners the sigmoids rounded off.  The optimum is
re-verified through the REAL hard pipeline (``verify_design`` builds a
``TimelineConfig``/``FleetAggregates`` from the optimized design and
runs an actual ``SweepEngine``), and ``hardening_weights`` turns the
availability gradient at the optimum into per-service blast-radius
weights for ``graph.planner.plan_hardening(service_weights=...)`` — the
planner spends its first rounds where breakage costs the most
availability at the optimized operating point.

Design knobs (unconstrained logits, sigmoid-squashed into bounds):

  buffer     Always-On buffer fraction b in [0.02, 1.5]: the region is
             sized ``((1+b)*AO + AM) * slack`` (paper: b = 1, the 2x
             buffer; the optimizer trades b against burst/cloud).
  promote    three flows TM->RL, RL->AM, AM->AO in [0, 1]: u = 0 is the
             fleet's classified tolerance frontier, u ~= 1 re-classes
             everything Always-On (the legacy 2x world, ~2.12x).
  overcommit host overcommit factor in [1, O_max] (§4.4 memory bound).
  ramp       burst-conversion spawn-rate multiplier in [0.4, 2.2].
  evict      eviction-order shift lambda in [-1, 1]: lambda > 0 evicts
             RL ahead of TM (budget-conserving per-class deltas on the
             evicted fraction; lambda = 0 is the pro-rata base model).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import capacity as C
from repro.core.fleet_state import AM, AO, RL, TM, FleetState
from repro.core.omg import Orchestrator
from repro.core.scenarios import FleetAggregates, scenario_grid
from repro.core.sweep_engine import (SweepEngine, _fused_verdicts,
                                     _fused_verdicts_block, bucket_shape)
from repro.core.tiers import o_max
from repro.core.timeline_sim import (N_CLASSES, N_TIERS, PARAM_KEYS,
                                     TimelineConfig, default_scenario,
                                     default_ts)
from repro.optim.adamw import make_optimizer

_SLACK = C.DEFAULT_SLACK
_TL_DEFAULTS = {f.name: f.default for f in dataclasses.fields(TimelineConfig)
                if f.default is not dataclasses.MISSING}

# knob bounds (sigmoid-squashed)
BUFFER_LO, BUFFER_HI = 0.02, 1.5
RAMP_LO, RAMP_HI = 0.4, 2.2
O_MAX = float(o_max())


# ---------------------------------------------------------------------------
# Design base + knob parameterization
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DesignBase:
    """The fleet's classified tolerance frontier — the fixed point the
    knobs deform: class core/env totals + the per-tier class matrix."""
    ao: float
    am: float
    rl: float
    tm: float
    am_envs: float
    rl_envs: float
    tm_envs: float
    tier_class: np.ndarray          # (N_TIERS, N_CLASSES) spec cores

    @property
    def total(self) -> float:
        return self.ao + self.am + self.rl + self.tm

    @classmethod
    def from_fleet_state(cls, fs: FleetState) -> "DesignBase":
        cores = fs.spec_cores
        tier_class = np.zeros((N_TIERS, N_CLASSES), np.float64)
        for t in range(N_TIERS):
            tmask = fs.tier == t
            for c in range(N_CLASSES):
                tier_class[t, c] = float(cores[tmask & (fs.fclass == c)].sum())
        return cls(
            ao=float(cores[fs.fclass == AO].sum()),
            am=float(cores[fs.fclass == AM].sum()),
            rl=float(cores[fs.fclass == RL].sum()),
            tm=float(cores[fs.fclass == TM].sum()),
            am_envs=float(np.count_nonzero(fs.fclass == AM)),
            rl_envs=float(np.count_nonzero(fs.fclass == RL)),
            tm_envs=float(np.count_nonzero(fs.fclass == TM)),
            tier_class=tier_class)

    def as_arrays(self) -> Dict[str, jnp.ndarray]:
        f = lambda v: jnp.asarray(v, jnp.float32)
        return {"ao": f(self.ao), "am": f(self.am), "rl": f(self.rl),
                "tm": f(self.tm), "am_envs": f(self.am_envs),
                "rl_envs": f(self.rl_envs), "tm_envs": f(self.tm_envs),
                "tier_class": f(self.tier_class), "total": f(self.total)}


def _logit(u: float) -> float:
    u = min(max(float(u), 1e-6), 1.0 - 1e-6)
    return math.log(u / (1.0 - u))


def _box_logit(v: float, lo: float, hi: float) -> float:
    return _logit((float(v) - lo) / (hi - lo))


def make_knobs(buffer: float = 1.0, promote=(0.9, 0.9, 0.9),
               overcommit: float = 1.5, ramp: float = 1.0,
               evict_lambda: float = 0.0) -> Dict[str, jnp.ndarray]:
    """Knob logits whose squashed values hit the given design point."""
    return {
        "buffer": jnp.asarray(_box_logit(buffer, BUFFER_LO, BUFFER_HI),
                              jnp.float32),
        "promote": jnp.asarray([_logit(u) for u in promote], jnp.float32),
        "overcommit": jnp.asarray(_box_logit(overcommit, 1.0, O_MAX),
                                  jnp.float32),
        "ramp": jnp.asarray(_box_logit(ramp, RAMP_LO, RAMP_HI), jnp.float32),
        "evict": jnp.asarray(_logit(0.5 * (evict_lambda + 1.0)), jnp.float32),
    }


def legacy_knobs() -> Dict[str, jnp.ndarray]:
    """The pre-UFA start point: full 2x buffer, (nearly) everything
    promoted to Always-On — ~2.12x provisioned (Fig. 11's 'before')."""
    return make_knobs(buffer=1.0, promote=(0.9, 0.9, 0.9), overcommit=1.5,
                      ramp=1.0, evict_lambda=0.0)


def ufa_knobs() -> Dict[str, jnp.ndarray]:
    """The paper's hand-tuned operating point (no promotion, 2x AO
    buffer, 1.5x overcommit, stock ramp, pro-rata eviction)."""
    return make_knobs(buffer=1.0, promote=(1e-4, 1e-4, 1e-4),
                      overcommit=1.5, ramp=1.0, evict_lambda=0.0)


def knob_design(base: Dict[str, jnp.ndarray],
                knobs: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Squash knob logits into a concrete *design*: deformed class
    totals/envs/tier matrix plus the scalar sizing knobs.  Differentiable
    end to end (every op is smooth in the logits)."""
    sig = jax.nn.sigmoid
    b = BUFFER_LO + (BUFFER_HI - BUFFER_LO) * sig(knobs["buffer"])
    u = sig(knobs["promote"])                      # (3,) TM->RL, RL->AM,
    oc = 1.0 + (O_MAX - 1.0) * sig(knobs["overcommit"])   # AM->AO flows
    ramp = RAMP_LO + (RAMP_HI - RAMP_LO) * sig(knobs["ramp"])
    lam = 2.0 * sig(knobs["evict"]) - 1.0

    # class flows (cores conserved: each stage moves a fraction one
    # class "up" the tolerance ladder)
    tm = base["tm"] * (1.0 - u[0])
    rl_mid = base["rl"] + base["tm"] * u[0]
    rl = rl_mid * (1.0 - u[1])
    am_mid = base["am"] + rl_mid * u[1]
    am = am_mid * (1.0 - u[2])
    ao = base["ao"] + am_mid * u[2]
    # envs ride the same flows (AO envs are not a kernel input)
    tm_envs = base["tm_envs"] * (1.0 - u[0])
    rl_envs_mid = base["rl_envs"] + base["tm_envs"] * u[0]
    rl_envs = rl_envs_mid * (1.0 - u[1])
    am_envs_mid = base["am_envs"] + rl_envs_mid * u[1]
    am_envs = am_envs_mid * (1.0 - u[2])
    # per-tier class matrix, same flows per row
    tc = base["tier_class"]
    tc_tm = tc[:, TM] * (1.0 - u[0])
    tc_rl_mid = tc[:, RL] + tc[:, TM] * u[0]
    tc_rl = tc_rl_mid * (1.0 - u[1])
    tc_am_mid = tc[:, AM] + tc_rl_mid * u[1]
    tc_am = tc_am_mid * (1.0 - u[2])
    tc_ao = tc[:, AO] + tc_am_mid * u[2]
    cols = [None] * N_CLASSES
    cols[AO], cols[AM], cols[RL], cols[TM] = tc_ao, tc_am, tc_rl, tc_tm
    tier_class = jnp.stack(cols, axis=1)

    stateless = ((1.0 + b) * ao + am) * _SLACK
    return {"ao": ao, "am": am, "rl": rl, "tm": tm,
            "am_envs": am_envs, "rl_envs": rl_envs, "tm_envs": tm_envs,
            "tier_class": tier_class, "buffer": 1.0 + b,
            "overcommit": oc, "spawn_mult": ramp, "evict_lambda": lam,
            "stateless": stateless, "total": base["total"]}


def design_consts(design: Dict[str, jnp.ndarray]) -> Dict[str, Dict]:
    """The fused pipeline's ``{"a": ..., "t": ...}`` consts from a
    design — the differentiable mirror of ``analytic_consts`` +
    ``RegionCapacity.for_fleet`` + ``extract_timeline_config``, with the
    host/placement ceils dropped (so gradients flow through sizing)."""
    ao, am, rl, tm = (design[k] for k in ("ao", "am", "rl", "tm"))
    stateless = design["stateless"]
    oc_cap = stateless * (design["overcommit"] - 1.0)
    preempt = rl + tm
    oc_preempt = jnp.minimum(preempt, oc_cap)
    sl_preempt = preempt - oc_preempt
    batch_cores = (am + rl) * C.BATCH_BURST_HEADROOM \
        / C.BATCH_PREEMPTIBLE_FRACTION
    spawn_rate = (Orchestrator.SPAWN_CORES_PER_HOST_S
                  / C.BATCH_CORES_PER_HOST * batch_cores
                  * design["spawn_mult"])
    f = lambda v: jnp.asarray(v, jnp.float32)
    t = {"ao": f(ao), "am": f(am), "rl": f(rl), "tm": f(tm),
         "am_envs": f(design["am_envs"]), "rl_envs": f(design["rl_envs"]),
         "tm_envs": f(design["tm_envs"]),
         "tier_class": f(design["tier_class"]),
         "stateless_cap": f(stateless), "overcommit_cap": f(oc_cap),
         "steady_used0": f(ao + am + sl_preempt),
         "overcommit_used0": f(oc_preempt),
         "oc_preempt_cores": f(oc_preempt), "sl_preempt_cores": f(sl_preempt),
         "am_stateless_cores": f(am),
         "burst_cap_full": f(batch_cores * C.BATCH_PREEMPTIBLE_FRACTION),
         "spawn_rate": f(spawn_rate),
         "cloud_quota": f(C.default_cloud_quota(rl)),
         "cloud_rate": f(jnp.maximum(C.CLOUD_RATE_FLOOR,
                                     rl / C.CLOUD_RATE_RL_DIVISOR)),
         "phys_cores": f(stateless)}
    t.update({k: f(v) for k, v in _TL_DEFAULTS.items()})
    a = {"ao": f(ao), "am": f(am), "rl": f(rl), "tm": f(tm),
         "am_envs": f(design["am_envs"]), "rl_envs": f(design["rl_envs"]),
         "ao_buffer": f(design["buffer"]),
         "spawn_mult": f(design["spawn_mult"])}
    return {"a": a, "t": t}


def eviction_deltas(design: Dict[str, jnp.ndarray], evict_fraction):
    """Budget-conserving per-class eviction shifts from the order knob.

    lambda > 0 evicts MORE of RL (and less of TM), lambda < 0 the
    reverse; the bounds keep both per-class evicted fractions in [0, 1]
    and ``rl*d_rl + tm*d_tm == 0`` (same total cores evicted — a
    different class mix).  lambda = 0 is d = 0: the pro-rata base model,
    exactly (the deltas are additive no-ops at 0 in the kernels)."""
    e = evict_fraction
    rl = jnp.maximum(design["rl"], 1.0)
    tm = jnp.maximum(design["tm"], 1.0)
    lam = design["evict_lambda"]
    m_pos = jnp.minimum(1.0 - e, e * tm / rl)       # room to evict RL more
    m_neg = jnp.minimum(e, (1.0 - e) * tm / rl)     # room to evict RL less
    d_rl = lam * jnp.where(lam >= 0.0, m_pos, m_neg)
    d_tm = -(rl / tm) * d_rl
    return d_rl, d_tm


# ---------------------------------------------------------------------------
# Ensembles + the soft objective
# ---------------------------------------------------------------------------


def certification_grid() -> Dict[str, np.ndarray]:
    """The optimizer's constraint ensemble: 48 scenarios around the
    paper's operating point (traffic x preheat x burst availability x
    cloud quota x eviction depth) that the hand-tuned UFA design passes —
    the optimum must keep passing all of them.  Partial-eviction rows
    (0.7) are what give the eviction-order knob signal."""
    return scenario_grid(traffic_mult=(1.8, 2.0, 2.2),
                         burst_delay_s=(270.0, 360.0),
                         burst_availability=(1.0, 0.85),
                         cloud_quota_frac=(1.0, 0.5),
                         evict_fraction=(1.0, 0.7))


def _grid_cols(grid: Dict[str, np.ndarray]) -> Dict[str, jnp.ndarray]:
    """Default-filled (n,) f32 columns for every scenario param (the
    un-chunked analogue of ``SweepEngine._params``)."""
    n = len(next(iter(grid.values())))
    defaults = default_scenario()
    return {k: jnp.asarray(np.asarray(grid[k], np.float32) if k in grid
                           else np.full(n, defaults[k], np.float32))
            for k in PARAM_KEYS}


def _design_params(design: Dict[str, jnp.ndarray],
                   cols: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Fold the design into the scenario params: the overcommit factor
    is a design choice (not a scenario axis), and the eviction-order
    deltas depend on each scenario's eviction depth."""
    d_rl, d_tm = eviction_deltas(design, cols["evict_fraction"])
    return dict(cols,
                overcommit_factor=cols["overcommit_factor"] * 0.0
                + design["overcommit"],
                rl_evict_delta=d_rl, tm_evict_delta=d_tm)


def soft_loss(knobs, base, cols, ts, tau, penalty):
    """Provisioning multiple + SLA-violation penalty, soft-relaxed at
    temperature ``tau`` — the ``jax.grad`` objective.  ``sla_ok`` /
    ``t_sla_ok`` are sigmoid products in [0, 1]; at low tau the penalty
    term approaches ``penalty * (fraction of ensemble failing)``."""
    design = knob_design(base, knobs)
    consts = design_consts(design)
    params = _design_params(design, cols)
    out = jax.vmap(lambda q: _fused_verdicts(consts, q, ts, True, tau)
                   )(params)
    mult = design["stateless"] / base["total"]
    bad = ((1.0 - jnp.mean(out["sla_ok"]))
           + (1.0 - jnp.mean(out["t_sla_ok"])))
    return mult + penalty * bad


_soft_loss_grad = jax.jit(jax.value_and_grad(soft_loss))


def provisioning(design) -> float:
    """Provisioned-to-needed multiple of a design (phys / steady demand,
    the ``provisioning_multiple`` convention: legacy ~2.12x, UFA <~1x)."""
    return float(design["stateless"]) / float(design["total"])


# ---------------------------------------------------------------------------
# Gradient mode
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CapacityOptResult:
    knobs: Dict[str, np.ndarray]       # optimized knob logits (host)
    design: Dict[str, object]          # concrete design (host floats)
    provisioning_multiple: float
    start_multiple: float
    history: List[Dict[str, float]]
    verification: Optional[Dict[str, object]] = None

    @property
    def improved(self) -> bool:
        return self.provisioning_multiple < self.start_multiple


def _host_design(design) -> Dict[str, object]:
    return {k: (np.asarray(v, np.float64) if getattr(v, "ndim", 0)
                else float(v)) for k, v in design.items()}


def fit_grad(base: Dict[str, jnp.ndarray], cols: Dict[str, jnp.ndarray],
             knobs: Dict[str, jnp.ndarray], ts,
             taus=(1.0, 0.3, 0.1, 0.03), steps_per_tau: int = 60,
             lr: float = 0.08, penalty: float = 200.0):
    """AdamW on the knob logits through the soft fused pipeline, with
    the relaxation temperature annealed toward the exact model.  One
    compiled value_and_grad serves every (tau, step): tau and penalty
    are traced scalars."""
    opt = make_optimizer(lr=lr, weight_decay=0.0, max_grad_norm=10.0)
    state = opt.init(knobs)
    pen = jnp.asarray(penalty, jnp.float32)
    history = []
    for tau in taus:
        tau_t = jnp.asarray(tau, jnp.float32)
        for _ in range(steps_per_tau):
            loss, grads = _soft_loss_grad(knobs, base, cols, ts, tau_t, pen)
            knobs, state, _ = opt.update(grads, state, knobs)
        history.append({"tau": float(tau), "loss": float(loss),
                        "multiple": provisioning(knob_design(base, knobs))})
    return knobs, history


# ---------------------------------------------------------------------------
# CEM mode (hard objective, one jitted call per generation)
# ---------------------------------------------------------------------------

_KNOB_KEYS = ("buffer", "promote", "overcommit", "ramp", "evict")


def _flatten_knobs(knobs) -> jnp.ndarray:
    return jnp.concatenate([jnp.atleast_1d(knobs[k]) for k in _KNOB_KEYS])


def _unflatten_knobs(flat) -> Dict[str, jnp.ndarray]:
    return {"buffer": flat[0], "promote": flat[1:4], "overcommit": flat[4],
            "ramp": flat[5], "evict": flat[6]}


@jax.jit
def _cem_scores(flat_pop, base, pchunks, mask, ts, penalty):
    """Hard objective for a whole CEM generation: vmap over candidates
    of the engine-shaped pipeline — the same bucket-padded
    ``lax.map``-of-``_fused_verdicts_block`` chunking ``SweepEngine.run``
    executes, evaluated for every (candidate, scenario) pair in ONE
    jitted call.  Infeasibility is charged per failing scenario
    (``sla_ok & t_sla_ok``, bit-exact hard verdicts)."""
    n = jnp.maximum(mask.sum(), 1.0)

    def one(flat):
        design = knob_design(base, _unflatten_knobs(flat))
        consts = design_consts(design)

        def chunk(args):
            p, m = args
            out = _fused_verdicts_block(consts, _design_params(design, p),
                                        ts, True, "scan")
            ok = out["sla_ok"] & out["t_sla_ok"]
            return jnp.sum((1.0 - ok.astype(jnp.float32)) * m)
        fails = lax.map(chunk, (pchunks, mask)).sum()
        return design["stateless"] / base["total"] + penalty * fails / n
    return jax.vmap(one)(flat_pop)


def fit_cem(base: Dict[str, jnp.ndarray], grid: Dict[str, np.ndarray],
            knobs: Dict[str, jnp.ndarray], ts,
            generations: int = 12, population: int = 48,
            elite: int = 12, sigma0: float = 1.0, seed: int = 0,
            penalty: float = 10.0):
    """Cross-entropy refinement around a start point: sample knob-logit
    populations, score each generation through the hard fused pipeline
    (one jitted call), refit the sampling Gaussian to the elites.  The
    incumbent rides along in every generation (elitism), so the result
    never regresses below its start."""
    n = len(next(iter(grid.values())))
    shape = bucket_shape(n)
    cols = _grid_cols(grid)
    total = shape[0] * shape[1]

    def chunked(col):
        col = jnp.concatenate([col, jnp.repeat(col[-1:], total - n, axis=0)])
        return col.reshape(shape)
    pchunks = {k: chunked(v) for k, v in cols.items()}
    # padding rows replicate the last scenario but must not be scored
    mask = jnp.zeros(total, jnp.float32).at[:n].set(1.0).reshape(shape)

    mean = _flatten_knobs(knobs)
    sigma = jnp.full(mean.shape, sigma0, jnp.float32)
    best, best_score = mean, jnp.inf
    pen = jnp.asarray(penalty, jnp.float32)
    history = []
    key = jax.random.PRNGKey(seed)
    for g in range(generations):
        key, k = jax.random.split(key)
        pop = mean[None, :] + sigma[None, :] * jax.random.normal(
            k, (population, mean.shape[0]), jnp.float32)
        pop = pop.at[0].set(best)          # elitism: keep the incumbent
        scores = _cem_scores(pop, base, pchunks, mask, ts, pen)
        order = jnp.argsort(scores)
        top = pop[order[:elite]]
        mean = top.mean(axis=0)
        sigma = top.std(axis=0) + 0.02     # floor keeps exploration alive
        if float(scores[order[0]]) < float(best_score):
            best, best_score = pop[order[0]], scores[order[0]]
        history.append({"generation": g, "best_score": float(best_score),
                        "multiple": provisioning(
                            knob_design(base, _unflatten_knobs(best)))})
    return _unflatten_knobs(best), history


# ---------------------------------------------------------------------------
# Hard verification + the driver
# ---------------------------------------------------------------------------


def design_timeline(design) -> tuple:
    """(TimelineConfig, FleetAggregates, analytic_extra) materialized
    from a design's host floats — inputs for a REAL ``SweepEngine``, so
    the optimum is certified by the same bit-exact hard kernels the
    historical sweeps run, not by the relaxation that found it."""
    d = _host_design(design)
    consts = design_consts({k: jnp.asarray(v) for k, v in design.items()})
    t = {k: float(v) for k, v in consts["t"].items() if np.ndim(v) == 0}
    timeline = TimelineConfig(
        ao_cores=d["ao"], am_cores=d["am"], rl_cores=d["rl"],
        tm_cores=d["tm"], am_envs=d["am_envs"], rl_envs=d["rl_envs"],
        tm_envs=d["tm_envs"],
        tier_class_cores=np.asarray(consts["t"]["tier_class"], np.float64),
        stateless_cap=t["stateless_cap"], overcommit_cap=t["overcommit_cap"],
        steady_used0=t["steady_used0"],
        overcommit_used0=t["overcommit_used0"],
        oc_preempt_cores=t["oc_preempt_cores"],
        sl_preempt_cores=t["sl_preempt_cores"],
        am_stateless_cores=t["am_stateless_cores"],
        burst_cap_full=t["burst_cap_full"], spawn_rate=t["spawn_rate"],
        cloud_quota=t["cloud_quota"], cloud_rate=t["cloud_rate"],
        phys_cores=t["phys_cores"])
    agg = FleetAggregates(ao_cores=d["ao"], am_cores=d["am"],
                          rl_cores=d["rl"], tm_cores=d["tm"],
                          am_envs=d["am_envs"], rl_envs=d["rl_envs"])
    extra = {"ao_buffer": d["buffer"], "spawn_mult": d["spawn_mult"]}
    return timeline, agg, extra


def verify_design(design, grid: Optional[Dict[str, np.ndarray]] = None,
                  graph=None, seed: int = 0) -> Dict[str, object]:
    """Run the optimized design through the REAL hard pipeline (an
    actual ``SweepEngine``, optionally with the dependency stage) over
    the certification ensemble; returns the pass counts + availability
    floor the bench asserts on."""
    grid = certification_grid() if grid is None else grid
    timeline, agg, extra = design_timeline(design)
    eng = SweepEngine(agg, timeline, graph=graph, seed=seed,
                      analytic_extra=extra, reducer="scan")
    n = len(next(iter(grid.values())))
    e = np.asarray(grid.get("evict_fraction", np.ones(n)), np.float64)
    d_rl, d_tm = eviction_deltas(
        {k: jnp.asarray(_host_design(design)[k]) for k in
         ("rl", "tm", "evict_lambda")}, jnp.asarray(e, jnp.float32))
    run_grid = dict(grid,
                    overcommit_factor=np.full(n, _host_design(design)
                                              ["overcommit"]),
                    rl_evict_delta=np.asarray(d_rl, np.float64),
                    tm_evict_delta=np.asarray(d_tm, np.float64))
    res = eng.run(run_grid)
    ok = res["sla_ok"] & res["t_sla_ok"]
    return {"n_scenarios": int(n),
            "n_sla_ok": int(res["sla_ok"].sum()),
            "n_t_sla_ok": int(res["t_sla_ok"].sum()),
            "n_t_avail_ok": int(res["t_avail_ok"].sum()),
            "all_ok": bool(ok.all() & res["t_avail_ok"].all()),
            "availability_min": float(res["availability"].min()),
            "t_availability_mean_min": float(
                res["t_availability_mean"].min()),
            "result": res}


def optimize_capacity(fs_or_base, grid: Optional[Dict[str, np.ndarray]]
                      = None, mode: str = "both",
                      knobs0: Optional[Dict] = None,
                      grad_steps: int = 60, taus=(1.0, 0.3, 0.1, 0.03),
                      lr: float = 0.08, penalty: float = 200.0,
                      cem_generations: int = 12, cem_population: int = 48,
                      seed: int = 0, graph=None,
                      verify: bool = True) -> CapacityOptResult:
    """End-to-end capacity optimization: start from the legacy 2x-buffer
    design, minimize provisioned cores subject to the ensemble SLA, and
    certify the optimum through the real hard pipeline."""
    assert mode in ("grad", "cem", "both"), mode
    base_obj = (fs_or_base if isinstance(fs_or_base, DesignBase)
                else DesignBase.from_fleet_state(fs_or_base))
    base = base_obj.as_arrays()
    grid = certification_grid() if grid is None else grid
    cols = _grid_cols(grid)
    ts = jnp.asarray(default_ts(), jnp.float32)
    knobs = legacy_knobs() if knobs0 is None else knobs0
    start_mult = provisioning(knob_design(base, knobs))
    history: List[Dict[str, float]] = []
    if mode in ("grad", "both"):
        knobs, hist = fit_grad(base, cols, knobs, ts, taus=taus,
                               steps_per_tau=grad_steps, lr=lr,
                               penalty=penalty)
        history += [dict(h, phase="grad") for h in hist]
    if mode in ("cem", "both"):
        knobs, hist = fit_cem(base, grid, knobs, ts,
                              generations=cem_generations,
                              population=cem_population, seed=seed)
        history += [dict(h, phase="cem") for h in hist]
    design = knob_design(base, knobs)
    verification = (verify_design(design, grid, graph=graph, seed=seed)
                    if verify else None)
    return CapacityOptResult(
        knobs={k: np.asarray(v) for k, v in knobs.items()},
        design=_host_design(design),
        provisioning_multiple=provisioning(design),
        start_multiple=start_mult,
        history=history, verification=verification)


# ---------------------------------------------------------------------------
# Feedback into the hardening planner
# ---------------------------------------------------------------------------


def hardening_weights(fs: FleetState, graph, knobs=None,
                      grid: Optional[Dict[str, np.ndarray]] = None,
                      tau: float = 1.0) -> np.ndarray:
    """Blast-radius weights from the availability gradient at a design
    point: how much the soft ensemble SLA (availability + the sigmoid
    verdict products, at temperature ``tau``) each class's cores buy,
    spread over services as ``sens[class] * spec_cores`` and normalized
    so the mean over critical services is 1 (the planner's RPC
    tie-break assumes score steps of ~1).  The raw ``availability``
    expression is *flat* at a comfortably-passing design (its penalty
    terms sit on hard ``max(0, .)`` plateaus), so the signal comes
    through the soft verdict sigmoids — which is why ``tau`` defaults
    high here.  Feed to ``plan_hardening(service_weights=...)``."""
    base = DesignBase.from_fleet_state(fs).as_arrays()
    knobs = ufa_knobs() if knobs is None else knobs
    cols = _grid_cols(certification_grid() if grid is None else grid)
    ts = jnp.asarray(default_ts(), jnp.float32)
    tau_t = jnp.asarray(tau, jnp.float32)

    def avail(cl4):
        b2 = dict(base, ao=cl4[0], am=cl4[1], rl=cl4[2], tm=cl4[3])
        design = knob_design(b2, knobs)
        consts = design_consts(design)
        params = _design_params(design, cols)
        out = jax.vmap(lambda q: _fused_verdicts(consts, q, ts, True,
                                                 tau_t))(params)
        return (jnp.mean(out["availability"])
                + jnp.mean(out["t_availability_mean"])
                + jnp.mean(out["sla_ok"]) + jnp.mean(out["t_sla_ok"]))

    cl4 = jnp.asarray([base["ao"], base["am"], base["rl"], base["tm"]])
    sens = jnp.clip(jax.grad(avail)(cl4), 0.0, None)     # avail per core
    w = np.asarray(sens, np.float64)[np.asarray(fs.fclass, np.int64)] \
        * np.asarray(fs.spec_cores, np.float64)
    crit = np.asarray(graph.critical, bool)
    mean_crit = float(w[crit].mean()) if crit.any() else 0.0
    if mean_crit <= 0.0:
        # gradient underflowed (margins >> tau * scale everywhere):
        # fall back to core-weighted ranking rather than all-zero
        w = np.asarray(fs.spec_cores, np.float64)
        mean_crit = float(w[crit].mean()) if crit.any() else float(w.mean())
    return (w / max(mean_crit, 1e-12)).astype(np.float32)
