"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

Grid (B, H, n_chunks): the TPU grid runs the chunk dimension innermost and
sequentially, so the (P, N) recurrent state lives in fp32 VMEM scratch and
is carried across chunk iterations — the inter-chunk recurrence costs no
HBM traffic.  Per step the kernel computes, entirely on-chip:

  cs   = cumsum(dt*a)               (via lower-triangular matmul -> MXU)
  L    = tril(exp(cs_i - cs_j))     (chunk x chunk decay)
  y    = (C B^T ⊙ L) (dt⊙x)  +  C state^T ⊙ exp(cs)     (intra + carry-in)
  state= state * exp(cs_last) + (dt⊙x)^T (B ⊙ exp(cs_last - cs))

Working set for (chunk=256, P=64, N=128): ~1 MB — comfortably VMEM-resident.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, s_ref, state_scr,
                *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)        # (c, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)         # (c,)
    a = a_ref[0]                                     # ()
    b = b_ref[0, :, 0, :].astype(jnp.float32)        # (c, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)        # (c, N)

    xw = x * dt[:, None]
    da = (dt * a)[:, None]                           # (c, 1)
    tril = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))
    cs = jax.lax.dot_general(tril, da, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (c,1) cumsum
    diff = cs - cs.T                                 # (c, c): cs_i - cs_j
    L = jnp.where(tril > 0, jnp.exp(diff), 0.0)

    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (c, c)
    y = jax.lax.dot_general(cb * L, xw, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (c, P)
    # carry-in from previous chunks' state: (c,N)@(N,P) scaled by exp(cs)
    state = state_scr[...]                           # (P, N)
    y_off = jax.lax.dot_general(c, state, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y = y + y_off * jnp.exp(cs)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update
    decay = jnp.exp(cs[-1, 0] - cs)                  # (c, 1)
    bd = b * decay
    s_new = jax.lax.dot_general(xw, bd, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (P, N)
    state_scr[...] = state * jnp.exp(cs[-1, 0]) + s_new

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        s_ref[0, 0] = state_scr[...].astype(s_ref.dtype)


def ssd_scan(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
             c: jnp.ndarray, chunk: int = 128, *, interpret: bool = False):
    """x: (B,S,H,P); dt: (B,S,H); a: (H,); b/c: (B,S,H,N) (head-expanded).
    Returns (y (B,S,H,P) fp32-accurate, final_state (B,H,P,N) fp32)."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    kern = functools.partial(_ssd_kernel, chunk=chunk, n_chunks=nc)
    y, state = pl.pallas_call(
        kern,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bi, h, ci: (bi, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bi, h, ci: (bi, ci, h)),
            pl.BlockSpec((1,), lambda bi, h, ci: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda bi, h, ci: (bi, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda bi, h, ci: (bi, ci, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bi, h, ci: (bi, ci, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda bi, h, ci: (bi, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c)
    return y, state
