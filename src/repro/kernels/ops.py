"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on any
real accelerator backend (TPU/GPU) — see ``repro.kernels.backend`` — so
the same call sites work in tests and production.  Layout plumbing between
the model's (B, S, H, d) convention and the kernels' blocked layouts lives
here, not in the model.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import decode_attention as _dec
from repro.kernels import flash_attention as _fa
from repro.kernels import grouped_matmul as _gmm
from repro.kernels import rmsnorm as _rms
from repro.kernels import ssd_scan as _ssd
from repro.kernels.backend import default_interpret as _default_interpret


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, block_q=128,
                    block_k=128, interpret: Optional[bool] = None):
    """q/k/v: (B, S, H, d) with KV already repeated to H heads."""
    interpret = _default_interpret() if interpret is None else interpret
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                              block_q=block_q, block_k=block_k,
                              interpret=interpret)
    return out.transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k_cache, v_cache, length, window=0, *, block_k=512,
                     interpret: Optional[bool] = None):
    """q: (B, H, d); caches: (B, K, KV, d) (model layout; transposed here)."""
    interpret = _default_interpret() if interpret is None else interpret
    kc = k_cache.transpose(0, 2, 1, 3)   # (B, KV, K, d)
    vc = v_cache.transpose(0, 2, 1, 3)
    return _dec.decode_attention(q, kc, vc, length, window,
                                 block_k=block_k, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, a, b, c, chunk=128, *, interpret: Optional[bool] = None):
    """x: (B,S,H,P); dt: (B,S,H); a: (H,); b/c: (B,S,G,N) (groups expanded)."""
    interpret = _default_interpret() if interpret is None else interpret
    H = x.shape[2]
    G = b.shape[2]
    if G != H:
        rep = H // G
        b = jnp.repeat(b, rep, axis=2)
        c = jnp.repeat(c, rep, axis=2)
    return _ssd.ssd_scan(x, dt, a, b, c, chunk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block_c", "block_f", "block_d",
                                             "interpret"))
def grouped_matmul(x, w, *, block_c=128, block_f=128, block_d=512,
                   interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _gmm.grouped_matmul(x, w, block_c=block_c, block_f=block_f,
                               block_d=block_d, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("eps", "block_r", "interpret"))
def rmsnorm(x, scale, eps=1e-6, *, block_r=256,
            interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    return _rms.rmsnorm(x, scale, eps, block_r=block_r, interpret=interpret)
