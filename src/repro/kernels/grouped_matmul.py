"""Grouped (per-expert) matmul Pallas TPU kernel for MoE expert FFNs.

x (E, C, D) @ w (E, D, F) -> (E, C, F): grid (E, C/bc, F/bf, D/bd) with an
fp32 VMEM accumulator across the contraction (bd) dimension (innermost, so
the sequential TPU grid keeps the accumulator live).  MXU-aligned 128x128
output tiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_scr, *, n_d: int):
    di = pl.program_id(3)

    @pl.when(di == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    x = x_ref[0].astype(jnp.float32)       # (bc, bd)
    w = w_ref[0].astype(jnp.float32)       # (bd, bf)
    acc_scr[...] += jax.lax.dot_general(
        x, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(di == n_d - 1)
    def _emit():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


def grouped_matmul(x: jnp.ndarray, w: jnp.ndarray, *, block_c: int = 128,
                   block_f: int = 128, block_d: int = 512,
                   interpret: bool = False) -> jnp.ndarray:
    """x: (E, C, D); w: (E, D, F) -> (E, C, F)."""
    E, C, D = x.shape
    _, _, F = w.shape
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    assert C % block_c == 0 and F % block_f == 0 and D % block_d == 0
    n_c, n_f, n_d = C // block_c, F // block_f, D // block_d

    kern = functools.partial(_gmm_kernel, n_d=n_d)
    return pl.pallas_call(
        kern,
        grid=(E, n_c, n_f, n_d),
        in_specs=[
            pl.BlockSpec((1, block_c, block_d), lambda e, ci, fi, di: (e, ci, di)),
            pl.BlockSpec((1, block_d, block_f), lambda e, ci, fi, di: (e, di, fi)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, ci, fi, di: (e, ci, fi)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
