"""Scatter-add histogram ingest Pallas kernel (telemetry hot path).

``core/dependency.py`` folds ``(edge_id, callee_failed, caller_errored)``
chunks into four per-edge count arrays.  On CPU that is a host
``np.bincount`` (measured 7x faster than XLA's CPU scatter in PR 3) — but
it forces a device->host round trip per 4M-record chunk and can never
ride an accelerator.  This kernel keeps the whole reduction
device-resident: records are encoded with the 2-bit outcome code

    code = 2 * callee_failed + caller_errored

and one pass accumulates the ``(n_edges, 4)`` histogram — column 0 =
clean call, 1 = error without failure, 2 = failure absorbed, 3 = failure
propagated — from which all four detector columns derive (``calls`` =
row sum, ``callee_failures`` = col2+col3, ``errors_given_failure`` =
col3, ``errors_given_ok`` = col1).

The grid walks record blocks sequentially against the full resident
histogram block (``pl.when`` zero-init on the first step); each step is
a flat ``jnp`` scatter-add *by value* (``zeros.at[...].add(1)``), which
— unlike in-kernel ``ref[idx] += 1`` — accumulates duplicate indices
correctly in both interpret and compiled modes.  Counts are int32 per
chunk (a 4M-record chunk cannot overflow); the caller folds chunks into
its int64 accumulators host-side.  Padding records carry an edge id one
past the histogram rows and are dropped by the scatter's out-of-bounds
mode.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import default_interpret

N_CODES = 4                      # 2-bit outcome code


def _hist_kernel(eid_ref, code_ref, o_ref):
    r = pl.program_id(0)

    @pl.when(r == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    eid = eid_ref[0]                               # (block_n,) int32
    code = code_ref[0]
    n_bins = o_ref.shape[0] * N_CODES
    flat = jnp.zeros((n_bins,), jnp.int32).at[
        eid * N_CODES + code].add(1, mode="drop")
    o_ref[...] += flat.reshape(o_ref.shape)


@functools.partial(jax.jit,
                   static_argnames=("n_edges", "block_n", "interpret"))
def ingest_hist(edge_id: jnp.ndarray, callee_failed: jnp.ndarray,
                caller_errored: jnp.ndarray, n_edges: int, *,
                block_n: int = 262_144,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """One chunk -> ``(n_edges, 4)`` int32 outcome-code histogram."""
    interpret = default_interpret() if interpret is None else interpret
    eid = edge_id.astype(jnp.int32)
    code = (callee_failed.astype(jnp.int32) * 2
            + caller_errored.astype(jnp.int32))
    n = eid.shape[0]
    if n == 0 or n_edges == 0:
        return jnp.zeros((n_edges, N_CODES), jnp.int32)

    block_n = min(block_n, n)
    n_pad = -(-n // block_n) * block_n
    e_pad = -(-n_edges // 8) * 8
    # pad records point past the histogram rows: either clipped into the
    # sliced-off row padding or dropped as out-of-bounds — never counted
    # (a negative sentinel would WRAP, Python-style, before the bounds
    # check and corrupt the last row)
    eid_p = jnp.pad(eid, (0, n_pad - n),
                    constant_values=e_pad).reshape(-1, block_n)
    code_p = jnp.pad(code, (0, n_pad - n)).reshape(-1, block_n)

    counts = pl.pallas_call(
        _hist_kernel,
        grid=(n_pad // block_n,),
        in_specs=[
            pl.BlockSpec((1, block_n), lambda r: (r, 0)),
            pl.BlockSpec((1, block_n), lambda r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((e_pad, N_CODES), lambda r: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((e_pad, N_CODES), jnp.int32),
        interpret=interpret,
    )(eid_p, code_p)
    return counts[:n_edges]


@functools.partial(jax.jit, static_argnames=("n_edges",))
def ref_ingest_hist(edge_id: jnp.ndarray, callee_failed: jnp.ndarray,
                    caller_errored: jnp.ndarray, n_edges: int) -> jnp.ndarray:
    """XLA reference: the same fused single-pass histogram as one flat
    scatter-add (and the same math as the host ``np.bincount`` fallback
    in ``core.dependency.ingest_batch``)."""
    eid = edge_id.astype(jnp.int32)
    code = (callee_failed.astype(jnp.int32) * 2
            + caller_errored.astype(jnp.int32))
    flat = jnp.zeros((n_edges * N_CODES,), jnp.int32).at[
        eid * N_CODES + code].add(1, mode="drop")
    return flat.reshape(n_edges, N_CODES)
