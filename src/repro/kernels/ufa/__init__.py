"""Pallas kernels for the UFA hot paths (ROADMAP item 4).

Three blocked kernels, one per profiled hot spot, each with an XLA
reference implementation in the same module and exact-parity dispatch at
the call site:

  * ``propagation`` — the multi-hop failure-propagation fixed point as a
    blocked ELL gather/reduce, batched over blackhole ensembles
    (replaces the scatter-heavy ``lax.while_loop`` body in
    ``graph/propagation.py``);
  * ``ingest``      — the telemetry scatter-add histogram: four per-edge
    RPC count columns accumulated device-resident in one pass over
    ``(edge_id, callee_failed, caller_errored)`` chunks
    (``core/dependency.py``; host ``np.bincount`` stays the CPU
    fallback);
  * ``reduce``      — the segmented timeline verdict reduction
    (availability integral/floor, peaks, per-tier restore first
    crossings) over whole scenario chunks at once, replacing the
    sequential ``lax.scan`` carry in ``core/sweep_engine.py``'s
    mega-batches.

Dispatch rule (see ``repro.kernels.backend``): the Pallas path runs by
default on accelerator backends and whenever ``REPRO_UFA_KERNELS=1``;
plain CPU keeps the measured-faster XLA/bincount fallbacks.  Wrappers
follow the house idiom of ``kernels/ops.py``: jitted, block sizes and
``interpret`` static, ``interpret`` defaulting via
``backend.default_interpret()`` (True only on CPU).
"""

from repro.kernels.ufa import ingest, propagation, reduce  # noqa: F401
