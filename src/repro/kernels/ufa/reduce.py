"""Segmented timeline verdict-reduction Pallas kernel.

``core/timeline_sim.timeline_verdicts`` folds the per-step series into
its summary carry with a sequential ``lax.scan`` — T dependent steps per
scenario, even though every accumulator is associative: the availability
integral is a dot with the step widths, the floor/peaks are min/max, and
the per-tier restore time is a first-crossing over a cumulative-OR.
This kernel reduces a whole scenario block at once:

    avail_int  = sum_t availability * dt        (dt[0] = 0, scan parity)
    avail_min  = min(1, min_t availability)
    util_peak  = max(0, max_t util_model)
    cloud_peak = max(0, max_t cloud_used)
    below      = tier_frac < thresh             (S, T, R)
    seen       = cumulative-OR_t below
    restore_t  = min_t { ts[t] : seen[t] & ~below[t] }   (inf if never)
    below_seen = seen[:, -1, :]

Min/max/first-crossing outputs are *exact* vs the scan (selections, not
sums); ``avail_int`` is a reordered float32 sum, so parity is
float32-tight rather than bitwise — which is why the sweep engine
dispatches this path per backend (``reducer="pallas"``) instead of
making it the CPU default (the default scan path stays bit-identical to
the composed sweeps, as pinned by ``tests/test_sweep_engine.py``).

``ref_timeline_reduce`` is the XLA reference (same math, plain ``jnp``).
"""

from __future__ import annotations

import functools
from typing import Dict, Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import default_interpret


def _reduce_kernel(a_ref, u_ref, cl_ref, fr_ref, dt_ref, ts_ref,
                   stats_ref, restore_ref, seen_ref, *, thresh: float):
    a = a_ref[...]                                     # (block_s, T)
    stats_ref[...] = jnp.stack([
        jnp.sum(a * dt_ref[...], axis=1),
        jnp.minimum(jnp.min(a, axis=1), 1.0),
        jnp.maximum(jnp.max(u_ref[...], axis=1), 0.0),
        jnp.maximum(jnp.max(cl_ref[...], axis=1), 0.0),
    ], axis=1)
    below = fr_ref[...] < thresh                       # (block_s, T, R)
    seen = jax.lax.associative_scan(jnp.logical_or, below, axis=1)
    crossed = seen & jnp.logical_not(below)
    restore_ref[...] = jnp.min(
        jnp.where(crossed, ts_ref[...][0][None, :, None], jnp.inf), axis=1)
    seen_ref[...] = seen[:, -1, :]


@functools.partial(jax.jit,
                   static_argnames=("thresh", "block_s", "interpret"))
def timeline_reduce(avail: jnp.ndarray, util: jnp.ndarray,
                    cloud: jnp.ndarray, tier_frac: jnp.ndarray,
                    ts: jnp.ndarray, *, thresh: float,
                    block_s: int = 128,
                    interpret: Optional[bool] = None
                    ) -> Dict[str, jnp.ndarray]:
    """avail/util/cloud (S, T) f32, tier_frac (S, T, R) f32, ts (T,) f32
    -> the scan-carry equivalents (all f32 / bool, shapes (S,) / (S, R)).
    """
    interpret = default_interpret() if interpret is None else interpret
    S, T = avail.shape
    R = tier_frac.shape[2]
    dt = jnp.maximum(jnp.diff(ts, prepend=ts[:1]), 0.0)
    dt2 = dt.astype(jnp.float32).reshape(1, T)
    ts2 = ts.astype(jnp.float32).reshape(1, T)

    block_s = min(block_s, S)
    s_pad = -(-S // block_s) * block_s
    pad = ((0, s_pad - S), (0, 0))
    stats, restore, seen = pl.pallas_call(
        functools.partial(_reduce_kernel, thresh=thresh),
        grid=(s_pad // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, T), lambda s: (s, 0)),
            pl.BlockSpec((block_s, T), lambda s: (s, 0)),
            pl.BlockSpec((block_s, T), lambda s: (s, 0)),
            pl.BlockSpec((block_s, T, R), lambda s: (s, 0, 0)),
            pl.BlockSpec((1, T), lambda s: (0, 0)),
            pl.BlockSpec((1, T), lambda s: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_s, 4), lambda s: (s, 0)),
            pl.BlockSpec((block_s, R), lambda s: (s, 0)),
            pl.BlockSpec((block_s, R), lambda s: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s_pad, 4), jnp.float32),
            jax.ShapeDtypeStruct((s_pad, R), jnp.float32),
            jax.ShapeDtypeStruct((s_pad, R), jnp.bool_),
        ],
        interpret=interpret,
    )(jnp.pad(avail, pad), jnp.pad(util, pad), jnp.pad(cloud, pad),
      jnp.pad(tier_frac, (*pad, (0, 0)), constant_values=1.0), dt2, ts2)
    return {"avail_int": stats[:S, 0], "avail_min": stats[:S, 1],
            "util_peak": stats[:S, 2], "cloud_peak": stats[:S, 3],
            "restore_t": restore[:S], "below_seen": seen[:S]}


@functools.partial(jax.jit, static_argnames=("thresh",))
def ref_timeline_reduce(avail: jnp.ndarray, util: jnp.ndarray,
                        cloud: jnp.ndarray, tier_frac: jnp.ndarray,
                        ts: jnp.ndarray, *, thresh: float
                        ) -> Dict[str, jnp.ndarray]:
    """XLA reference: identical math, no blocking."""
    dt = jnp.maximum(jnp.diff(ts, prepend=ts[:1]), 0.0).astype(jnp.float32)
    below = tier_frac < thresh
    seen = jax.lax.associative_scan(jnp.logical_or, below, axis=1)
    crossed = seen & jnp.logical_not(below)
    return {
        "avail_int": jnp.sum(avail * dt[None, :], axis=1),
        "avail_min": jnp.minimum(jnp.min(avail, axis=1), 1.0),
        "util_peak": jnp.maximum(jnp.max(util, axis=1), 0.0),
        "cloud_peak": jnp.maximum(jnp.max(cloud, axis=1), 0.0),
        "restore_t": jnp.min(
            jnp.where(crossed, ts.astype(jnp.float32)[None, :, None],
                      jnp.inf), axis=1),
        "below_seen": seen[:, -1, :],
    }
