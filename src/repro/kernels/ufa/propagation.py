"""Blocked ELL frontier-propagation Pallas kernel.

The XLA fixed point in ``graph/propagation.py`` runs one scatter-max over
the whole edge list per round — a data-dependent scatter XLA serializes on
CPU and lowers poorly on TPU.  This kernel flips the data layout: the CSR
adjacency is padded host-side to ELL form (every caller row gets exactly
``K`` callee slots, ``K`` = max out-degree rounded up; the paper-scale
graph measures max degree 13, so K=16 wastes little), and one round
becomes a dense blocked *gather*:

    hit[s, u] = any_k  broken[s, ell_dst[u, k]] & ell_closed[u, k]
    new[s, u] = broken[s, u] | hit[s, u]

The grid tiles (scenario block, caller-row block); each step loads the
full ``(block_s, n_pad)`` broken slab once, gathers its ``(block_s,
block_r, K)`` callee view and reduces over the slot axis — no scatter
anywhere, and the whole blackhole ensemble batch shares each adjacency
block read.  A ``lax.while_loop`` with the same round counter/bound as
the XLA path drives the kernel to the fixed point, so ``rounds`` and the
``broken`` matrix are bit-identical to the reference (booleans: exact).

``ref_fixed_point`` is the XLA reference (the scatter-max formulation,
kept here so kernel tests do not depend on the graph layer); dispatch
between the two lives in ``graph.propagation.fixed_point``.
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import default_interpret


# ---------------------------------------------------------------------------
# host-side ELL precompute
# ---------------------------------------------------------------------------


def ell_from_csr(n: int, indptr: np.ndarray, dst: np.ndarray,
                 closed: np.ndarray, pad_to: int = 8
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """CSR -> ELL: ``(ell_dst (n, K) int32, ell_closed (n, K) bool,
    slot (E,) int32)`` with ``K`` the max out-degree rounded up to
    ``pad_to`` (0 for an edge-free graph).  ``slot[e]`` is edge ``e``'s
    column in its caller's ELL row, so a fail-close mask update for edge
    ``e`` lands at ``ell_closed[src[e], slot[e]]`` (the planner's greedy
    loop flips edges in place).  Pad slots carry ``closed=False`` and
    never contribute a hit."""
    indptr = np.asarray(indptr, np.int64)
    dst = np.asarray(dst, np.int64)
    closed = np.asarray(closed, bool)
    deg = np.diff(indptr)
    kmax = int(deg.max(initial=0))
    if kmax == 0:
        return (np.zeros((n, 0), np.int32), np.zeros((n, 0), bool),
                np.zeros(len(dst), np.int32))
    K = -(-kmax // pad_to) * pad_to
    slot = np.arange(len(dst), dtype=np.int64) - np.repeat(indptr[:-1], deg)
    row = np.repeat(np.arange(n, dtype=np.int64), deg)
    ell_dst = np.zeros((n, K), np.int32)
    ell_closed = np.zeros((n, K), bool)
    ell_dst[row, slot] = dst
    ell_closed[row, slot] = closed
    return ell_dst, ell_closed, slot.astype(np.int32)


# ---------------------------------------------------------------------------
# the kernel: one propagation round
# ---------------------------------------------------------------------------


def _round_kernel(b_all_ref, b_cur_ref, dst_ref, closed_ref, o_ref):
    """One round for one (scenario block, caller-row block) tile."""
    b = b_all_ref[...]                       # (block_s, n_pad) bool
    idx = dst_ref[...]                       # (block_r, K) int32
    gathered = jnp.take(b, idx.reshape(-1), axis=1).reshape(
        b.shape[0], idx.shape[0], idx.shape[1])
    hit = jnp.any(gathered & closed_ref[...][None, :, :], axis=-1)
    o_ref[...] = b_cur_ref[...] | hit


@functools.partial(jax.jit,
                   static_argnames=("block_s", "block_r", "interpret"))
def fixed_point_ell(dark: jnp.ndarray, ell_dst: jnp.ndarray,
                    ell_closed: jnp.ndarray, *, block_s: int = 64,
                    block_r: int = 256,
                    interpret: Optional[bool] = None):
    """Batched least fixed point over the ELL adjacency:
    ``dark (S, n) bool -> (broken (S, n) bool, rounds int32)`` with the
    exact round-counting semantics of the XLA reference (a final
    no-change sweep is counted, bound ``n + 1``)."""
    interpret = default_interpret() if interpret is None else interpret
    S, n = dark.shape
    K = ell_dst.shape[1]
    if S == 0 or n == 0 or K == 0:
        # nothing can propagate: the reference still runs one (no-change)
        # round before the loop exits
        return dark, jnp.int32(1)

    block_s = min(block_s, S)
    block_r = min(block_r, n)
    s_pad = -(-S // block_s) * block_s
    n_pad = -(-n // block_r) * block_r
    dark_p = jnp.pad(dark, ((0, s_pad - S), (0, n_pad - n)))
    dst_p = jnp.pad(ell_dst, ((0, n_pad - n), (0, 0)))
    closed_p = jnp.pad(ell_closed, ((0, n_pad - n), (0, 0)))

    one_round = pl.pallas_call(
        _round_kernel,
        grid=(s_pad // block_s, n_pad // block_r),
        in_specs=[
            pl.BlockSpec((block_s, n_pad), lambda s, r: (s, 0)),
            pl.BlockSpec((block_s, block_r), lambda s, r: (s, r)),
            pl.BlockSpec((block_r, K), lambda s, r: (r, 0)),
            pl.BlockSpec((block_r, K), lambda s, r: (r, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, block_r), lambda s, r: (s, r)),
        out_shape=jax.ShapeDtypeStruct((s_pad, n_pad), jnp.bool_),
        interpret=interpret,
    )

    def cond(state):
        _, changed, i = state
        return changed & (i < n + 1)

    def body(state):
        broken, _, i = state
        new = one_round(broken, broken, dst_p, closed_p)
        return new, (new != broken).any(), i + 1

    broken, _, rounds = jax.lax.while_loop(
        cond, body, (dark_p, jnp.bool_(True), jnp.int32(0)))
    return broken[:S, :n], rounds


# ---------------------------------------------------------------------------
# XLA reference (the scatter-max formulation)
# ---------------------------------------------------------------------------


@jax.jit
def ref_fixed_point(dark: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
                    closed: jnp.ndarray):
    """Edge-list scatter-max fixed point — op-for-op the original
    ``graph.propagation._fixed_point`` (which remains the production CPU
    path; this copy pins the kernel without a layer dependency)."""
    n = dark.shape[1]

    def cond(state):
        _, changed, i = state
        return changed & (i < n + 1)

    def body(state):
        broken, _, i = state
        hit = broken[:, dst] & closed[None, :]
        new = broken | jnp.zeros_like(broken).at[:, src].max(hit)
        return new, (new != broken).any(), i + 1

    broken, _, rounds = jax.lax.while_loop(
        cond, body, (dark, jnp.bool_(True), jnp.int32(0)))
    return broken, rounds
