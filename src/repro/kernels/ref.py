"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each ``ref_*`` mirrors its kernel's contract exactly; kernel tests sweep
shapes/dtypes and assert allclose against these.
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def ref_rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


def ref_flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        *, causal: bool = True, window: int = 0,
                        scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B, S, H, d); k/v: (B, S, H, d) (KV already repeated to H heads).
    window: 0 = global; >0 = sliding window (causal)."""
    B, S, H, d = q.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    scores = jnp.einsum("bshd,bkhd->bhsk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(S)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask = k_pos <= q_pos
    if window > 0:
        mask = mask & ((q_pos - k_pos) < window)
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhsk,bkhd->bshd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray,
                         v_cache: jnp.ndarray, length: int,
                         *, window: int = 0,
                         scale: Optional[float] = None) -> jnp.ndarray:
    """One-token GQA decode. q: (B, H, d); caches: (B, K, KV, d);
    attends to positions < length (+window clipping)."""
    B, H, d = q.shape
    K, KV = k_cache.shape[1], k_cache.shape[2]
    g = H // KV
    scale = scale if scale is not None else 1.0 / math.sqrt(d)
    qg = q.reshape(B, KV, g, d).astype(jnp.float32)
    scores = jnp.einsum("bngd,bknd->bngk", qg, k_cache.astype(jnp.float32)) * scale
    k_pos = jnp.arange(K)
    valid = k_pos < length
    if window > 0:
        valid = valid & ((length - 1 - k_pos) < window)
    scores = jnp.where(valid[None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bngk,bknd->bngd", probs, v_cache.astype(jnp.float32))
    return out.reshape(B, H, d).astype(q.dtype)


def ref_grouped_matmul(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Per-expert GEMM: x (E, C, D) @ w (E, D, F) -> (E, C, F)."""
    return jnp.einsum("ecd,edf->ecf", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def ref_ssd_chunk(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                  b: jnp.ndarray, c: jnp.ndarray,
                  init_state: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-chunk SSD: the intra-chunk + state update computed by one grid
    step of the Pallas kernel.  x: (B, c, H, P); dt: (B, c, H); a: (H,);
    b, c: (B, c, H, N) (already head-expanded);
    init_state: (B, H, P, N).  Returns (y (B,c,H,P), out_state (B,H,P,N))."""
    B, L, H, Pd = x.shape
    N = b.shape[-1]
    x32 = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    da = dt.astype(jnp.float32) * a  # (B, c, H)
    da_h = da.transpose(0, 2, 1)     # (B, H, c)
    cs = jnp.cumsum(da_h, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    L_mat = jnp.where(jnp.tril(jnp.ones((L, L), bool)), jnp.exp(diff), 0.0)
    y = jnp.einsum("bihn,bjhn,bhij,bjhp->bihp",
                   c.astype(jnp.float32), b.astype(jnp.float32), L_mat, x32)
    if init_state is not None:
        state_decay = jnp.exp(cs)    # (B,H,c)
        y = y + jnp.einsum("bchn,bhpn,bhc->bchp",
                           c.astype(jnp.float32),
                           init_state.astype(jnp.float32), state_decay)
    decay_states = jnp.exp(cs[..., -1:] - cs)
    new_state = jnp.einsum("bchn,bhc,bchp->bhpn",
                           b.astype(jnp.float32), decay_states, x32)
    if init_state is not None:
        new_state = new_state + init_state.astype(jnp.float32) * \
            jnp.exp(cs[..., -1])[..., None, None]
    return y.astype(x.dtype), new_state
