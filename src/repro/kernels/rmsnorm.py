"""Fused RMSNorm Pallas TPU kernel.

Row-blocked: grid (rows/block_r,), each step normalizes a (block_r, D) tile
in fp32 and applies the scale — one HBM read + one write per element (the
unfused jnp version reads x twice: once for the variance, once for the
normalize)."""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6, *,
            block_r: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x: (..., D); scale: (D,)."""
    orig_shape = x.shape
    D = x.shape[-1]
    x2 = x.reshape(-1, D)
    R = x2.shape[0]
    block_r = min(block_r, R)
    while R % block_r != 0:
        block_r //= 2
    block_r = max(1, block_r)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(R // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, D), lambda r: (r, 0)),
            pl.BlockSpec((D,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r, D), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
