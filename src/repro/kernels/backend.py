"""Backend dispatch shared by every Pallas wrapper in the repo.

Two questions every kernel call site asks:

  * ``default_interpret()`` — should ``pl.pallas_call`` run in interpret
    mode?  True only on CPU (this container / CI), False on any real
    accelerator backend (TPU, GPU): interpret mode executes the kernel
    body with XLA ops on the host, which is what keeps kernel tests
    honest where no accelerator exists but would silently throw away the
    device compilation everywhere else.  (The old ``ops._default_interpret``
    returned True for *any* non-TPU backend, forcing interpret mode on
    GPU — this helper is the backend-aware replacement.)

  * ``use_ufa_kernels()`` — should the UFA hot paths (propagation fixed
    point, telemetry ingest, sweep reductions) route through the Pallas
    kernels in ``repro.kernels.ufa`` at all?  Default: yes on any
    accelerator, no on CPU — the CPU reference paths (``np.bincount``
    ingest, XLA scatter propagation, ``lax.scan`` reductions) are the
    measured winners there (PR 3 clocked host ``bincount`` 7x ahead of
    XLA's CPU scatter).  ``REPRO_UFA_KERNELS=1`` / ``=0`` overrides in
    either direction — CI sets ``1`` to drive the Pallas paths under
    interpret mode, and it is the escape hatch if a backend misbehaves.

Both read ``jax.default_backend()`` at call time (cheap, cached by JAX),
so a process that initializes JAX late still dispatches correctly.
"""

from __future__ import annotations

import os

import jax

# backends with a real Pallas lowering (Mosaic on TPU, Triton on GPU)
_ACCELERATOR_BACKENDS = ("tpu", "gpu", "cuda", "rocm")


def default_interpret() -> bool:
    """Interpret-mode default for ``pl.pallas_call``: True only on CPU."""
    return jax.default_backend() not in _ACCELERATOR_BACKENDS


def use_ufa_kernels() -> bool:
    """Route the UFA hot paths through the Pallas kernels?  Accelerators
    yes, CPU no (the bincount/XLA fallbacks win there); the
    ``REPRO_UFA_KERNELS`` env var forces either way (read per call, so
    tests/CI can flip it without re-importing)."""
    env = os.environ.get("REPRO_UFA_KERNELS", "").strip()
    if env in ("0", "1"):
        return env == "1"
    return jax.default_backend() in _ACCELERATOR_BACKENDS
