"""Split-K decode attention Pallas TPU kernel (FlashDecoding-style).

One new query token per sequence attends to a long KV cache.  Grid
(B, KV_heads, n_k_blocks): each step loads one (block_k, d) cache tile and
folds it into fp32 running max / denominator / accumulator scratch for the
GQA query group of that KV head.  ``length`` (valid cache entries) and
``window`` arrive as scalar-prefetch operands in SMEM.

On-chip working set per step: ~2 * block_k * d * 2B (K and V tiles), MXU
dims (group x d) x (d x block_k) — d is 64..256 across the assigned archs,
block_k defaults to 512 lanes.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, block_k: int, n_k: int,
                   scale: float, group: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = len_ref[0]
    window = win_ref[0]
    k_start = ki * block_k

    @pl.when(k_start < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale           # (g, d)
        k = k_ref[0, 0].astype(jnp.float32)                   # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (g, bk)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (group, block_k), 1)
        valid = k_pos < length
        valid = jnp.logical_and(
            valid, jnp.where(window > 0, (length - 1 - k_pos) < window, True))
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     length, window=0, *, scale: float | None = None,
                     block_k: int = 512, interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, d); caches: (B, KV, K, d).  Returns (B, H, d)."""
    B, H, d = q.shape
    KV, K = k_cache.shape[1], k_cache.shape[2]
    group = H // KV
    block_k = min(block_k, K)
    assert K % block_k == 0, (K, block_k)
    n_k = K // block_k
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    qg = q.reshape(B, KV, group, d)
    length = jnp.asarray(length, jnp.int32).reshape(1)
    window = jnp.asarray(window, jnp.int32).reshape(1)

    kern = functools.partial(_decode_kernel, block_k=block_k, n_k=n_k,
                             scale=scale, group=group)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, group, d), lambda b, n, ki, *_: (b, n, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, n, ki, *_: (b, n, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, n, ki, *_: (b, n, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d), lambda b, n, ki, *_: (b, n, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kern, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, group, d), q.dtype),
        interpret=interpret,
    )(length, window, qg, k_cache, v_cache)
    return out.reshape(B, H, d)
