"""Flash attention (forward) Pallas TPU kernel.

Online-softmax tiling: grid (B, H, n_q_blocks, n_k_blocks); the TPU grid is
executed sequentially with the last dimension innermost, so fp32 running
max / denominator / accumulator live in VMEM scratch across the k-block
iterations of one q block.  Causal + sliding-window masking is applied with
2-D iotas; fully-masked k blocks are skipped via pl.when.

Block shapes are MXU-aligned: (block_q, d_head) x (block_k, d_head) with
block_q/block_k multiples of 128 (d_head 64..256 per the assigned archs).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: int, n_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    # skip blocks that are entirely masked out
    run = True
    if causal:
        run = k_start <= q_start + block_q - 1
    if window > 0:
        # closest (q, k<=q) pair distance: q_start - (k_start + block_k - 1)
        run = jnp.logical_and(run, q_start - k_start - block_k + 1 < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (bq,bk)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]                                   # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_scr[...] = acc_scr[...] * alpha + pv
        m_scr[...] = m_new
        l_scr[...] = l_new

    @pl.when(ki == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    scale: float | None = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q/k/v: (B, H, S, d) — KV pre-repeated to H heads.  Returns (B, H, S, d)."""
    B, H, S, d = q.shape
    assert k.shape == v.shape == (B, H, S, d)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    n_q, n_k = S // block_q, S // block_k
    scale = scale if scale is not None else 1.0 / math.sqrt(d)

    kern = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=int(window), n_k=n_k)

    return pl.pallas_call(
        kern,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, qi, ki: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, qi, ki: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
