"""Greedy hardening planner + dependency regression gate (paper §5-6).

The paper hardened 4,000+ unsafe dependencies before the 2x buffer could be
dropped, then gated deployments so new fail-close edges onto critical paths
never ship.  ``plan_hardening`` reproduces the first process: repeatedly
certify the fleet (multi-hop blackhole propagation), rank the fail-close
edges still carrying breakage by the *blast radius* of their caller (how
many critical services break when that caller breaks — exact, via the
batched kernel), convert the worst offenders to fail-open, and stop as
soon as the fleet certifies.  The recorded trajectory (cumulative edges
hardened vs. broken critical services) is the paper's hardening-count
curve.  ``regression_gate`` reproduces the second: diff two graphs and
fail on any new unsafe edge whose failure can reach a critical service.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

import jax.numpy as jnp

from repro import obs
from repro.graph.callgraph import CallGraph
from repro.graph.propagation import (blast_radius, certify, edge_consts,
                                     fixed_point, harden_consts,
                                     radius_counts)


@dataclasses.dataclass
class HardeningPlan:
    graph: CallGraph                       # final (hardened) graph
    hardened_edges: List[int]              # CSR edge indices, in plan order
    hardened_edge_names: List[Tuple[str, str]]
    trajectory: List[Dict[str, int]]       # per round: hardened so far,
                                           # broken criticals remaining
    certified: bool
    rounds: int

    @property
    def n_hardened(self) -> int:
        return len(self.hardened_edges)


def plan_hardening(graph: CallGraph, batch: int = 64,
                   max_rounds: int = 10_000,
                   service_weights=None) -> HardeningPlan:
    """Greedy multi-hop hardening until the fleet certifies.

    Each round: propagate the full preemption blackhole; the *frontier* is
    every fail-close edge whose callee is broken (these are the edges
    actually relaying failure).  Rank frontier edges by the blast radius of
    their caller — the exact number of critical services saved if this
    caller stops breaking — with RPC volume as the tie-break, harden the
    top ``batch``, repeat.  Terminates because every round converts >= 1
    fail-close edge and certification needs only finitely many.

    ``service_weights`` (optional (n,) float array) switches the frontier
    ranking to the *weighted* blast radius: each broken service counts
    its weight instead of 1.  The capacity optimizer feeds its
    availability-sensitivity weights here (``repro.optim.capacity
    .hardening_weights``) so the plan spends its first rounds on the
    edges whose breakage costs the most availability at the optimized
    operating point.  Certification and termination are still judged on
    the unweighted broken-critical count — only the greedy order changes;
    ``None`` keeps the historical ranking bit-identical.

    The greedy loop is dispatch-hoisted: the edge/criticality arrays are
    uploaded to the device once and the two jitted propagation closures
    (full-blackhole certify, batched frontier blast radius) are reused
    across rounds — only the fail-close mask changes, updated in place on
    both sides.  Each round costs one (1, n) fixed point, one
    bucket-padded (B, n) fixed point for the whole frontier, and (n,)/(B,)
    transfers; nothing is re-traced and no (B, n) boolean matrix ever
    crosses the host boundary.
    """
    dark = np.asarray(graph.preemptible, bool)
    crit_live = graph.critical & ~dark
    closed = ~graph.fail_open.copy()           # host mirror of the mask
    consts = edge_consts(graph)                # backend-dispatched kernel
    crit_d = jnp.asarray(graph.critical)
    weights_d = (None if service_weights is None
                 else jnp.asarray(np.asarray(service_weights, np.float32)))
    dark_d = jnp.asarray(dark[None, :])
    hardened: List[int] = []
    trajectory: List[Dict[str, int]] = []
    rounds = 0
    certified = False
    while rounds < max_rounds:
        broken_d, _ = fixed_point(dark_d, consts)
        broken = np.asarray(broken_d[0])
        n_bc = int(np.count_nonzero(broken & crit_live))
        trajectory.append({"n_hardened": len(hardened),
                           "n_broken_critical": n_bc})
        obs.set_gauge("ufa_planner_broken_critical", n_bc)
        if n_bc == 0:
            certified = True
            break
        rounds += 1
        obs.inc("ufa_planner_rounds_total")
        # frontier: fail-close edges relaying breakage into a live caller
        # (hardening an edge whose caller is itself dark changes nothing)
        frontier = np.flatnonzero(closed & broken[graph.dst]
                                  & ~dark[graph.src])
        if len(frontier) == 0:
            # a bare assert here vanished under ``python -O``, leaving the
            # loop re-certifying the same stale state until max_rounds —
            # fail loudly instead (mirrors EventLoop.max_events)
            raise RuntimeError(
                "plan_hardening stalled: "
                f"{n_bc} broken critical service(s) after "
                f"{len(hardened)} hardened edge(s) but no fail-close "
                "frontier edge relays the breakage into a live caller — "
                "the propagation verdicts and the edge mask disagree "
                "(inconsistent graph state?); hardening cannot make "
                "progress")
        callers = np.unique(graph.src[frontier])
        counts = radius_counts(callers, graph.n, consts, crit_d,
                               weights=weights_d)
        radius = np.zeros(graph.n, counts.dtype)
        radius[callers] = counts
        score = radius[graph.src[frontier]].astype(np.float64)
        # tie-break on traffic volume (normalized to < 1 so it never
        # outranks a whole extra critical service)
        w = graph.weight[frontier].astype(np.float64)
        score += w / (w.max() + 1.0)
        pick = frontier[np.argsort(-score, kind="stable")[:batch]]
        obs.inc("ufa_planner_hardened_edges_total", int(len(pick)))
        hardened.extend(int(i) for i in pick)
        closed[pick] = False
        consts = harden_consts(consts, jnp.asarray(pick))
    g = graph.harden(hardened)
    if not certified:
        # ran out of rounds after a harden — the last cert is stale
        certified = certify(g, dark).ok
    return HardeningPlan(
        graph=g, hardened_edges=hardened,
        hardened_edge_names=g.edge_names(hardened),
        trajectory=trajectory, certified=certified, rounds=rounds)


# ---------------------------------------------------------------------------
# regression gate
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GateResult:
    ok: bool
    new_unsafe_edges: List[Tuple[str, str]]        # all newly-unsafe edges
    violations: List[Tuple[str, str, int]]         # those reaching critical
                                                   # services (+ blast count)

    def __bool__(self) -> bool:
        return self.ok


def regression_gate(baseline: CallGraph, candidate: CallGraph) -> GateResult:
    """Fail if the candidate graph introduces a fail-close edge that can
    reach a critical service — the per-deployment check that keeps the
    hardened fleet hardened.

    An edge (u -> v) "reaches a critical service" iff u, or any transitive
    fail-close caller of u, is critical: if v ever goes dark, that whole
    set breaks.  Computed exactly by darkening each new edge's *caller*
    alone and counting broken criticals (one batched propagation).  Edges
    are diffed by (caller, callee) name, so the two graphs may differ in
    shape (new services, re-ordered rows).
    """
    base_unsafe = baseline.unsafe_edge_keys()
    cand_unsafe_idx = np.flatnonzero(~candidate.fail_open)
    new_idx = [int(i) for i in cand_unsafe_idx
               if (candidate.names[candidate.src[i]],
                   candidate.names[candidate.dst[i]]) not in base_unsafe]
    new_edges = candidate.edge_names(new_idx)
    if not new_idx:
        obs.inc("ufa_gate_checks_total", verdict="ok")
        obs.set_gauge("ufa_gate_violations", 0)
        return GateResult(ok=True, new_unsafe_edges=[], violations=[])
    callers = np.unique(candidate.src[np.asarray(new_idx, np.int64)])
    radius = blast_radius(candidate, sources=callers)
    violations = [(c, d, int(radius[candidate.index[c]]))
                  for (c, d) in new_edges
                  if radius[candidate.index[c]] > 0]
    obs.inc("ufa_gate_checks_total",
            verdict="ok" if not violations else "fail")
    obs.set_gauge("ufa_gate_violations", len(violations))
    return GateResult(ok=not violations, new_unsafe_edges=new_edges,
                      violations=violations)
