"""CSR call-graph: the struct-of-arrays dependency layer (paper §5-6).

A ``CallGraph`` is the dependency-safety counterpart of
``core.fleet_state.FleetState``: one row per service-environment, edges as
parallel arrays in CSR order (sorted by caller, ``indptr`` delimiting each
caller's out-edges).  Every safety question the paper asks — which critical
services break when a preemption set goes dark, how far a failure
propagates, which unsafe edges to harden first — becomes an array program
over these columns (see ``repro.graph.propagation`` / ``planner``).

Builders cover the three places graphs come from in practice:

  * ``from_fleet_state`` — the synthesized ground truth (array path),
  * ``from_specs``       — the synthesized ground truth (object path),
  * ``from_detections``  — what the runtime/static analysis layers *found*
    (an edge is fail-close iff a detector flagged it); certification then
    runs against the detectors' view of the world, exactly like production.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Set, Tuple

import numpy as np

from repro.core.fleet_state import AM, FleetState, RL, _edge_weights


@dataclasses.dataclass
class CallGraph:
    """Dependency edges in CSR order + the node masks propagation needs."""
    n: int                      # number of service-environments (nodes)
    src: np.ndarray             # int32 caller row, sorted ascending (CSR)
    dst: np.ndarray             # int32 callee row
    fail_open: np.ndarray       # bool — False = fail-close (UNSAFE)
    weight: np.ndarray          # float32 per-edge RPC volume
    indptr: np.ndarray          # int64 (n+1,) — node u's out-edges are
                                # src/dst[indptr[u]:indptr[u+1]]
    critical: np.ndarray        # bool — survives failover (AO/AM)
    preemptible: np.ndarray     # bool — goes dark in a failover (RL/TM)
    names: List[str]
    # CSR position -> index in the edge arrays the builder consumed
    # (e.g. ``FleetState.edges`` order); lets plan/detection results be
    # mapped back without re-deriving the sort
    input_order: Optional[np.ndarray] = None

    # ------------------------------------------------------------------
    @property
    def n_edges(self) -> int:
        return len(self.src)

    @property
    def unsafe(self) -> np.ndarray:
        """Edge mask: fail-close edges (the jnp kernels consume ~fail_open
        directly; this is the numpy view)."""
        return ~self.fail_open

    @property
    def n_unsafe(self) -> int:
        return int(np.count_nonzero(~self.fail_open))

    def out_edges(self, u: int) -> slice:
        return slice(int(self.indptr[u]), int(self.indptr[u + 1]))

    def edge_names(self, edge_idx: Iterable[int]) -> List[Tuple[str, str]]:
        return [(self.names[self.src[i]], self.names[self.dst[i]])
                for i in edge_idx]

    def input_edge_indices(self, edge_idx: Iterable[int]) -> np.ndarray:
        """Map CSR edge indices (e.g. ``HardeningPlan.hardened_edges``)
        back to the builder's input edge order — for ``from_fleet_state``
        graphs, positions into ``FleetState.edges`` suitable for
        ``fs.edges.fail_open[...] = True``."""
        assert self.input_order is not None, \
            "graph was built without an input-order mapping"
        return self.input_order[np.asarray(list(edge_idx), np.int64)]

    def unsafe_edge_keys(self) -> Set[Tuple[str, str]]:
        """(caller, callee) name pairs of every fail-close edge."""
        idx = np.flatnonzero(~self.fail_open)
        return {(self.names[self.src[i]], self.names[self.dst[i]])
                for i in idx}

    # ------------------------------------------------------------------
    def harden(self, edge_idx: Iterable[int]) -> "CallGraph":
        """New graph with the given edges converted fail-open (the paper's
        code-level remediation); everything else is shared/copied cheaply."""
        fo = self.fail_open.copy()
        fo[np.asarray(list(edge_idx), np.int64)] = True
        return dataclasses.replace(self, fail_open=fo)

    def with_edge(self, caller: str, callee: str,
                  fail_open: bool = False,
                  weight: float = 1.0) -> "CallGraph":
        """New graph with one extra edge (regression-gate test vector)."""
        i, j = self.index[caller], self.index[callee]
        return _build_csr(self.n,
                          np.append(self.src, np.int32(i)),
                          np.append(self.dst, np.int32(j)),
                          np.append(self.fail_open, fail_open),
                          np.append(self.weight, np.float32(weight)),
                          self.critical, self.preemptible, self.names)

    @property
    def index(self) -> Dict[str, int]:
        idx = getattr(self, "_index", None)
        if idx is None:
            idx = {n: i for i, n in enumerate(self.names)}
            object.__setattr__(self, "_index", idx)
        return idx

    # ------------------------------------------------------------------
    # builders
    # ------------------------------------------------------------------
    @classmethod
    def from_fleet_state(cls, fs: FleetState) -> "CallGraph":
        assert fs.edges is not None, "FleetState synthesized without edges"
        e = fs.edges
        weight = e.weight if e.weight is not None else \
            _edge_weights(fs.tier, e.src, e.dst)
        return _build_csr(fs.n, e.src, e.dst, e.fail_open,
                          np.asarray(weight, np.float32),
                          fs.fclass <= AM, fs.fclass >= RL, list(fs.names))

    @classmethod
    def from_specs(cls, fleet: Dict[str, "object"]) -> "CallGraph":
        fs = FleetState.from_specs(fleet, with_edges=True)
        return cls.from_fleet_state(fs)

    @classmethod
    def from_detections(cls, fleet, fail_close_edges: Set[Tuple[str, str]]
                        ) -> "CallGraph":
        """Graph as the detection layers see it: every known RPC edge, with
        fail-close exactly where runtime/static analysis flagged it.
        Accepts either fleet representation (``Dict[str, ServiceSpec]`` or
        ``FleetState``)."""
        g = (cls.from_fleet_state(fleet) if isinstance(fleet, FleetState)
             else cls.from_specs(fleet))
        idx = g.index
        flagged = np.asarray(
            [idx[c] * np.int64(g.n) + idx[d]
             for c, d in fail_close_edges if c in idx and d in idx],
            np.int64)
        packed = g.src.astype(np.int64) * g.n + g.dst
        return dataclasses.replace(g, fail_open=~np.isin(packed, flagged))

    @classmethod
    def from_detection_mask(cls, fs: FleetState,
                            fail_close: np.ndarray) -> "CallGraph":
        """Array path of ``from_detections``: the runtime layer's edge mask
        (aligned with ``fs.edges`` order, True = detector flagged the edge
        fail-close) becomes the graph directly — no name sets, no packed-id
        joins, just the CSR build."""
        assert fs.edges is not None, "FleetState synthesized without edges"
        e = fs.edges
        fail_close = np.asarray(fail_close, bool)
        assert fail_close.shape == e.src.shape, (fail_close.shape, e.n)
        weight = e.weight if e.weight is not None else \
            _edge_weights(fs.tier, e.src, e.dst)
        return _build_csr(fs.n, e.src, e.dst, ~fail_close,
                          np.asarray(weight, np.float32),
                          fs.fclass <= AM, fs.fclass >= RL, list(fs.names))


def _build_csr(n: int, src: np.ndarray, dst: np.ndarray,
               fail_open: np.ndarray, weight: np.ndarray,
               critical: np.ndarray, preemptible: np.ndarray,
               names: List[str]) -> CallGraph:
    order = np.argsort(src, kind="stable")
    src = np.ascontiguousarray(src[order], np.int32)
    indptr = np.searchsorted(src, np.arange(n + 1)).astype(np.int64)
    return CallGraph(n=n, src=src,
                     dst=np.ascontiguousarray(dst[order], np.int32),
                     fail_open=np.ascontiguousarray(fail_open[order], bool),
                     weight=np.ascontiguousarray(weight[order], np.float32),
                     indptr=indptr,
                     critical=np.asarray(critical, bool),
                     preemptible=np.asarray(preemptible, bool),
                     names=list(names), input_order=order)
