"""Array-native dependency-graph engine (paper §5-6).

CSR call graph + JAX fixed-point failure propagation + vmapped blackhole
ensembles + the greedy hardening planner and regression gate.  How
certification flows: detect (runtime/static layers) -> build graph ->
propagate (multi-hop blackhole) -> gate (plan hardening, block
regressions).
"""

from repro.graph.callgraph import CallGraph
from repro.graph.planner import (GateResult, HardeningPlan, plan_hardening,
                                 regression_gate)
from repro.graph.propagation import (Certification, blackhole_ensemble,
                                     blast_radius,
                                     broken_critical_fractions, certify,
                                     dep_consts, propagate, propagate_many,
                                     shared_blackhole_draws)

__all__ = [
    "CallGraph", "Certification", "GateResult", "HardeningPlan",
    "blackhole_ensemble", "blast_radius", "broken_critical_fractions",
    "certify", "dep_consts", "plan_hardening", "propagate",
    "propagate_many", "regression_gate", "shared_blackhole_draws",
]
