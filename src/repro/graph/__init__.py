"""Array-native dependency-graph engine (paper §5-6).

CSR call graph + JAX fixed-point failure propagation + vmapped blackhole
ensembles + the greedy hardening planner and regression gate.  How
certification flows: detect (runtime/static layers) -> build graph ->
propagate (multi-hop blackhole) -> gate (plan hardening, block
regressions).
"""

from repro.graph.callgraph import CallGraph
from repro.graph.planner import (GateResult, HardeningPlan, plan_hardening,
                                 regression_gate)
from repro.graph.propagation import (Certification, blackhole_ensemble,
                                     blast_radius, certify, propagate,
                                     propagate_many)

__all__ = [
    "CallGraph", "Certification", "GateResult", "HardeningPlan",
    "blackhole_ensemble", "blast_radius", "certify", "plan_hardening",
    "propagate", "propagate_many", "regression_gate",
]
