"""Multi-hop failure propagation over a ``CallGraph`` (JAX fixed point).

The safety question behind the paper's 2x -> 1.3x efficiency claim: when a
preemption/blackhole set S goes dark, which services *break*?  Breakage is
the least fixed point of

    broken = S  ∪  { caller | ∃ fail-close edge caller->callee,
                              callee ∈ broken }

— fail-open edges absorb the failure (graceful degradation), fail-close
edges relay it, cycles are handled by monotonicity.  The kernel runs one
``jax.lax.while_loop`` of scatter-max rounds over the whole edge list for a
*batch* of scenarios at once ((S, n) boolean frontier, (E,) fail-close edge
mask as a ``jnp`` array), so a 256-scenario blackhole ensemble over the
~22k-SE paper fleet is a handful of vectorized sweeps, not 256 graph
traversals.  A scalar BFS reference lives in ``tests/test_graph.py`` and
pins the kernel exactly.

Two interchangeable propagation backends sit behind ``fixed_point``:

  * the XLA scatter-max loop (``_fixed_point``, the historical path and
    the CPU default), and
  * the blocked ELL gather/reduce Pallas kernel
    (``repro.kernels.ufa.propagation``), selected when the edge consts
    carry the ELL adjacency — which ``edge_consts``/``dep_consts`` attach
    when ``repro.kernels.backend.use_ufa_kernels()`` says so
    (accelerator backends, or ``REPRO_UFA_KERNELS=1``).

Both produce bit-identical ``broken`` matrices and round counts; every
entry point (``certify``, ``blast_radius``, ``propagate_many``, the
fused sweep engine's in-pipeline stage, the planner's frontier batches)
routes through the dispatcher.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence

import numpy as np

import jax
import jax.numpy as jnp

from repro.graph.callgraph import CallGraph
from repro.kernels import backend as _backend
from repro.kernels.ufa import propagation as _pallas_prop

# blast_radius pads source batches to multiples of _BUCKET (capped at
# _CHUNK rows per propagation) so jit compiles a handful of shapes, not one
# per call — and small source sets don't pay for a full 512-row batch
_CHUNK = 512
_BUCKET = 128


@jax.jit
def _fixed_point(dark: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
                 closed: jnp.ndarray):
    """Batched least fixed point: dark (S, n) bool -> (broken, rounds).

    Each round scatters ``broken[dst] & closed`` into the callers
    (segment-max over the edge list) and ORs it in; terminates when a full
    round changes nothing.  Round count is bounded by the longest fail-close
    chain (<= n), the loop exits as soon as the frontier stalls.
    """
    n = dark.shape[1]

    def cond(state):
        _, changed, i = state
        return changed & (i < n + 1)

    def body(state):
        broken, _, i = state
        hit = broken[:, dst] & closed[None, :]
        new = broken | jnp.zeros_like(broken).at[:, src].max(hit)
        return new, (new != broken).any(), i + 1

    broken, _, rounds = jax.lax.while_loop(
        cond, body, (dark, jnp.bool_(True), jnp.int32(0)))
    return broken, rounds


@jax.jit
def _radius_kernel(dark: jnp.ndarray, consts: Dict[str, jnp.ndarray],
                   crit: jnp.ndarray):
    """Batched blast-radius counts: propagate the (B, n) dark batch to its
    fixed point (backend-dispatched) and reduce to per-row
    broken-critical counts *on device*, so only (B,) ints cross the host
    boundary (the (B, n) broken matrix never does)."""
    broken, _ = fixed_point(dark, consts)
    return (broken & crit[None, :]).sum(axis=1).astype(jnp.int32)


@jax.jit
def _weighted_radius_kernel(dark: jnp.ndarray,
                            consts: Dict[str, jnp.ndarray],
                            weights: jnp.ndarray):
    """Weighted blast radius: same fixed point, but each broken service
    contributes its (f32) weight instead of 1 — the capacity optimizer's
    availability-sensitivity weights turn the planner's edge ranking into
    a blast-*impact* ranking (weights are expected to already encode
    criticality, e.g. zero on non-critical services)."""
    broken, _ = fixed_point(dark, consts)
    return (broken * weights[None, :]).sum(axis=1).astype(jnp.float32)


def fixed_point(dark: jnp.ndarray, consts: Dict[str, jnp.ndarray]):
    """Backend-dispatched batched fixed point: the ELL Pallas kernel when
    ``consts`` carries the ELL adjacency (see ``edge_consts``), the XLA
    scatter-max loop otherwise.  Bit-identical results either way
    (booleans and round counts are exact).  Traceable — the fused sweep
    engine calls it inside its jitted pipeline (the dict-key check is a
    trace-time static)."""
    if "ell_dst" in consts and consts["ell_dst"].shape[1] > 0:
        return _pallas_prop.fixed_point_ell(dark, consts["ell_dst"],
                                            consts["ell_closed"])
    return _fixed_point(dark, consts["src"], consts["dst"],
                        consts["closed"])


def _ell_topology(graph: CallGraph):
    """Cached node-topology half of the ELL build (``ell_dst``/``slot``
    depend only on src/dst/indptr, not on the fail-close mask, so they
    survive ``harden``-style mask churn; the mask half is a cheap scatter
    recomputed per ``edge_consts`` call)."""
    cache = getattr(graph, "_ell_topology", None)
    if cache is None:
        ell_dst, _, slot = _pallas_prop.ell_from_csr(
            graph.n, graph.indptr, graph.dst, ~graph.fail_open)
        cache = (ell_dst, slot)
        object.__setattr__(graph, "_ell_topology", cache)
    return cache


def edge_consts(graph: CallGraph) -> Dict[str, jnp.ndarray]:
    """Device-resident propagation constants: int32 edge endpoints plus
    the fail-close mask, and — when the Pallas path is on
    (``backend.use_ufa_kernels()``) — the ELL adjacency the kernel
    consumes (``ell_dst``/``ell_closed`` (n, K), plus ``ell_slot`` (E,)
    so ``harden_consts`` can flip individual edges in place)."""
    out = {"src": jnp.asarray(graph.src, jnp.int32),
           "dst": jnp.asarray(graph.dst, jnp.int32),
           "closed": jnp.asarray(~graph.fail_open)}
    if _backend.use_ufa_kernels():
        ell_dst, slot = _ell_topology(graph)
        if ell_dst.shape[1] > 0:
            closed = ~graph.fail_open
            ell_closed = np.zeros(ell_dst.shape, bool)
            ell_closed[graph.src, slot] = closed
            out["ell_dst"] = jnp.asarray(ell_dst)
            out["ell_closed"] = jnp.asarray(ell_closed)
            out["ell_slot"] = jnp.asarray(slot)
    return out


def harden_consts(consts: Dict[str, jnp.ndarray],
                  pick: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Convert edges ``pick`` (CSR indices) to fail-open in the device
    consts — both the edge-list mask and, when present, its ELL mirror —
    without re-uploading anything else (the planner's per-round update).
    """
    out = dict(consts, closed=consts["closed"].at[pick].set(False))
    if "ell_closed" in consts:
        out["ell_closed"] = consts["ell_closed"].at[
            consts["src"][pick], consts["ell_slot"][pick]].set(False)
    return out


def radius_counts(sources: np.ndarray, n: int,
                  consts: Dict[str, jnp.ndarray], crit_d,
                  weights=None) -> np.ndarray:
    """Blast-radius counts for ``sources`` against device-resident edge
    consts (``edge_consts``) — the reusable closure the hardening planner
    calls once per greedy round (the device arrays are uploaded once, not
    per call).  Sources are swept in bucket-padded batches (multiples of
    _BUCKET up to _CHUNK) through the jitted kernel; returns counts
    aligned with ``sources``.

    ``weights`` (optional, device-resident (n,) f32): rank by *weighted*
    blast radius — the sum of per-service weights over the broken set —
    instead of the unweighted broken-critical count.  ``None`` keeps the
    historical integer counts bit-identical."""
    sources = np.asarray(sources, np.int64)
    out = np.zeros(len(sources), np.int32 if weights is None else np.float32)
    for lo in range(0, len(sources), _CHUNK):
        chunk = sources[lo:lo + _CHUNK]
        width = min(_CHUNK, _BUCKET * -(-len(chunk) // _BUCKET))
        pad = np.full(width, chunk[-1], np.int64)
        pad[:len(chunk)] = chunk
        dark = np.zeros((width, n), bool)
        dark[np.arange(width), pad] = True
        if weights is None:
            counts = _radius_kernel(jnp.asarray(dark), consts, crit_d)
        else:
            counts = _weighted_radius_kernel(jnp.asarray(dark), consts,
                                             weights)
        out[lo:lo + len(chunk)] = np.asarray(counts)[:len(chunk)]
    return out


def dep_consts(graph: CallGraph) -> Dict[str, jnp.ndarray]:
    """Device-resident propagation constants for the fused sweep engine:
    ``edge_consts`` plus the critical mask and the (f32) critical count.
    Upload once per graph; every fused pipeline call reuses them (keyed
    jit cache on shapes only)."""
    out = edge_consts(graph)
    out["crit"] = jnp.asarray(graph.critical)
    out["n_crit"] = jnp.asarray(max(1, int(graph.critical.sum())),
                                jnp.float32)
    return out


def shared_blackhole_draws(graph: CallGraph, fractions: np.ndarray,
                           seed: int = 0
                           ) -> tuple[np.ndarray, np.ndarray]:
    """Host-side stage precompute for the fused engine: the
    ``blackhole_ensemble`` shared-draw semantics (one uniform per service,
    scenario s darkens preemptibles with ``u < fractions[s]``) compressed
    to *unique* fractions — equal fractions share one dark set, so a 100k
    scenario grid with a handful of ``evict_fraction`` values propagates
    a handful of dark sets, not 100k.  Returns ``(dark_unique (U, n)
    bool, inverse (S,) int32)`` with ``dark_unique[inverse]`` the full
    per-scenario dark matrix (never materialized)."""
    rng = np.random.default_rng(seed)
    fractions = np.asarray(fractions, np.float64)
    u = rng.random(graph.n)                  # same stream as the ensemble
    uniq, inverse = np.unique(fractions, return_inverse=True)
    dark = (u[None, :] < uniq[:, None]) & graph.preemptible[None, :]
    return dark, inverse.astype(np.int32)


def combined_dark_uniques(graph: CallGraph, evict_fractions: np.ndarray,
                          storm_fractions: Optional[np.ndarray],
                          seed: int, storm_seed: int
                          ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Unique dark sets for BOTH dependency stages of the fused engine in
    one propagation batch: the per-scenario blackhole uniques (stream
    ``seed``) plus — when a cascade storm is active anywhere in the grid
    — the storm's re-darkening uniques under an INDEPENDENT uniform
    stream (``storm_seed``; see ``core.scenarios.stage_seed``), appended
    row-wise so one ``fixed_point`` while_loop settles every dark set.
    Per-row fixed points are independent and monotone, so concatenating
    rows never changes any row's verdict.

    Returns ``(dark_u (U, n) bool, inv (S,) int32, storm_inv (S,)
    int32)`` — scenario ``s`` gathers its blackhole verdict at row
    ``inv[s]`` and its storm verdict at row ``storm_inv[s]``.  With no
    storm (``storm_fractions`` None or all zero) a single all-false row
    is appended and every ``storm_inv`` points at it, so the pipeline
    keeps one static structure either way."""
    dark_u, inv = shared_blackhole_draws(graph, evict_fractions, seed=seed)
    storm_fractions = (None if storm_fractions is None
                       else np.asarray(storm_fractions, np.float64))
    if storm_fractions is None or not (storm_fractions > 0.0).any():
        dark_u = np.concatenate(
            [dark_u, np.zeros((1, graph.n), bool)])
        storm_inv = np.full(len(inv), len(dark_u) - 1, np.int32)
        return dark_u, inv, storm_inv
    sdark, sinv = shared_blackhole_draws(graph, storm_fractions,
                                         seed=storm_seed)
    storm_inv = (sinv + len(dark_u)).astype(np.int32)
    return np.concatenate([dark_u, sdark]), inv, storm_inv


def broken_critical_fractions(dark_u: jnp.ndarray, dep: Dict
                              ) -> tuple[jnp.ndarray, jnp.ndarray,
                                         jnp.ndarray]:
    """Traceable blackhole verdicts for a (U, n) dark batch against
    ``dep_consts`` arrays: per-row broken-critical counts (int32), the
    f32 broken-critical fraction that feeds the availability penalty, and
    the dark-set sizes (int32).  Runs the same ``_fixed_point`` kernel as
    ``propagate_many`` but stays on device — the fused sweep engine calls
    it *inside* its jitted pipeline."""
    broken, _ = fixed_point(dark_u, dep)
    counts = (broken & dep["crit"][None, :]).sum(axis=1).astype(jnp.int32)
    frac = counts.astype(jnp.float32) / dep["n_crit"]
    n_dark = dark_u.sum(axis=1).astype(jnp.int32)
    return counts, frac, n_dark


def propagate_many(graph: CallGraph, dark: np.ndarray
                   ) -> tuple[np.ndarray, int]:
    """dark (S, n) bool -> (broken (S, n) bool, rounds)."""
    dark = np.asarray(dark, bool)
    assert dark.ndim == 2 and dark.shape[1] == graph.n, dark.shape
    broken, rounds = fixed_point(jnp.asarray(dark), edge_consts(graph))
    return np.asarray(broken), int(rounds)


def propagate(graph: CallGraph, dark: np.ndarray) -> np.ndarray:
    """dark (n,) bool -> broken (n,) bool (single-scenario convenience)."""
    broken, _ = propagate_many(graph, np.asarray(dark, bool)[None, :])
    return broken[0]


# ---------------------------------------------------------------------------
# certification, blast radius, ensembles
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Certification:
    ok: bool                      # no critical service breaks
    broken: np.ndarray            # (n,) bool — dark set included
    broken_critical: np.ndarray   # (n,) bool
    n_broken_critical: int
    n_critical: int
    n_dark: int
    rounds: int                   # propagation rounds to the fixed point

    @property
    def multi_hop(self) -> np.ndarray:
        """Criticals that broke but have no direct fail-close cause — they
        can only have been reached through a relay chain."""
        return self.broken_critical & ~self._direct

    _direct: np.ndarray = dataclasses.field(default=None, repr=False)


def certify(graph: CallGraph, dark: Optional[np.ndarray] = None
            ) -> Certification:
    """Full-fleet multi-hop blackhole certification: default dark set is
    every preemptible service (the failover worst case)."""
    if dark is None:
        dark = graph.preemptible
    dark = np.asarray(dark, bool)
    broken_b, rounds = propagate_many(graph, dark[None, :])
    broken = broken_b[0]
    bc = broken & graph.critical & ~dark
    # direct causes: criticals with a fail-close edge into the dark set
    direct_edge = ~graph.fail_open & np.asarray(dark, bool)[graph.dst]
    direct = np.zeros(graph.n, bool)
    direct[graph.src[direct_edge]] = True
    return Certification(
        ok=not bc.any(), broken=broken, broken_critical=bc,
        n_broken_critical=int(bc.sum()),
        n_critical=int(graph.critical.sum()),
        n_dark=int(np.count_nonzero(dark)), rounds=rounds,
        _direct=direct & graph.critical)


def blast_radius(graph: CallGraph,
                 sources: Optional[Sequence[int]] = None) -> np.ndarray:
    """Exact per-service blast radius: entry j = number of critical
    services that break when service j (alone) goes dark, j itself
    included if critical.

    Default sources are the services that can actually go dark and feed an
    unsafe edge — preemptible callees of fail-close edges — which is the
    set the hardening planner ranks.  Pass explicit sources for arbitrary
    what-if sweeps.  Sources are swept in padded chunks through the batched
    kernel (one (chunk, n) propagation per chunk).
    """
    if sources is None:
        unsafe_dst = graph.dst[~graph.fail_open]
        sources = np.unique(unsafe_dst[graph.preemptible[unsafe_dst]])
    sources = np.asarray(sources, np.int64)
    out = np.zeros(graph.n, np.int32)
    if len(sources) == 0:
        return out
    out[sources] = radius_counts(sources, graph.n, edge_consts(graph),
                                 jnp.asarray(graph.critical))
    return out


def blackhole_ensemble(graph: CallGraph, n_scenarios: int = 256,
                       seed: int = 0,
                       fractions: Optional[np.ndarray] = None,
                       kind: str = "random") -> Dict[str, np.ndarray]:
    """Certify a whole ensemble of preemption scenarios in one batched
    pass (chaos-engineering style: hundreds of distinct blackhole sets,
    per-scenario verdicts).

    kind="random": scenario s darkens each preemptible service i.i.d. with
    probability fractions[s]; the uniform draws are shared across
    scenarios, so sorting the fractions makes the dark sets *nested* — the
    broken counts are then provably monotone in the fraction, which the
    property tests exploit.
    kind="grid": fractions swept over a linspace, same shared draws.
    """
    rng = np.random.default_rng(seed)
    if fractions is None:
        fractions = (np.linspace(0.0, 1.0, n_scenarios)
                     if kind == "grid"
                     else rng.uniform(0.05, 1.0, n_scenarios))
    fractions = np.asarray(fractions, np.float64)
    u = rng.random(graph.n)
    dark = (u[None, :] < fractions[:, None]) & graph.preemptible[None, :]
    broken, rounds = propagate_many(graph, dark)
    bc = broken & graph.critical[None, :]
    return {
        "dark_fraction": fractions,
        "n_dark": dark.sum(axis=1),
        "n_broken": broken.sum(axis=1),
        "n_broken_critical": bc.sum(axis=1),
        "broken_critical_frac": bc.sum(axis=1)
        / max(1, int(graph.critical.sum())),
        "ok": ~bc.any(axis=1),
        "rounds": np.int32(rounds),
    }
