"""Logical-axis sharding context.

Model code annotates activations with *logical* axis names
(``hint(x, "batch", None, "embed")``); a ``sharding_rules`` context binds
those names to mesh axes.  Outside a context — or when a dimension does
not divide the mapped mesh-axis product — the annotation is a no-op, so
the same model code runs unchanged on one device.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

Rule = Union[None, str, Tuple[str, ...]]

_TLS = threading.local()


def default_rules(mesh) -> Dict[str, Rule]:
    """Logical-axis -> mesh-axis table.  Batch-like axes map onto every
    non-model mesh axis (so multi-pod meshes data-parallelize over
    pod x data); everything width-like maps onto "model"."""
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    model = "model" if "model" in mesh.axis_names else None
    return {
        "batch": data_axes or None,
        "seq": model,
        "kv_seq": model,
        "heads": model,
        "ff": model,
        "vocab": model,
        "expert": model,
        "embed": None,
    }


class _Ctx:
    __slots__ = ("mesh", "rules")

    def __init__(self, mesh, rules):
        self.mesh = mesh
        self.rules = rules


@contextmanager
def sharding_rules(mesh, rules: Optional[Dict[str, Rule]] = None):
    """Activate a logical-axis sharding context (tracing-time state)."""
    merged = default_rules(mesh)
    if rules:
        merged.update(rules)
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = _Ctx(mesh, merged)
    try:
        yield _TLS.ctx
    finally:
        _TLS.ctx = prev


def _current() -> Optional[_Ctx]:
    return getattr(_TLS, "ctx", None)


def current_mesh():
    ctx = _current()
    return ctx.mesh if ctx is not None else None


def get_rule(name: str) -> Rule:
    ctx = _current()
    if ctx is None:
        return None
    return ctx.rules.get(name)


def _axes_of(rule: Rule) -> Tuple[str, ...]:
    if rule is None:
        return ()
    return (rule,) if isinstance(rule, str) else tuple(rule)


def axis_size(name: str) -> int:
    """Product of mesh-axis sizes the logical axis maps to (1 outside a
    context)."""
    ctx = _current()
    if ctx is None:
        return 1
    n = 1
    for a in _axes_of(ctx.rules.get(name)):
        n *= ctx.mesh.shape[a]
    return n


def hint(x, *logical_axes):
    """Annotate ``x`` with a sharding constraint derived from logical axis
    names (one per dimension, ``None`` = replicated).  Identity when no
    context is active, on 1-sized mappings, and on non-divisible dims."""
    ctx = _current()
    if ctx is None:
        return x
    mesh = ctx.mesh
    spec = []
    pinned = False
    for dim, name in zip(x.shape, logical_axes):
        axes = _axes_of(ctx.rules.get(name)) if name else ()
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if n <= 1 or dim % n != 0:
            spec.append(None)
        else:
            spec.append(axes[0] if len(axes) == 1 else axes)
            pinned = True
    if not pinned:
        return x
    spec += [None] * (x.ndim - len(spec))
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P(*spec)))
    except Exception:
        # inside shard_map bodies (or other manual regions) constraints
        # don't apply — the caller already owns the layout
        return x
