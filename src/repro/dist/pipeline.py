"""GPipe-style pipeline parallelism over a mesh axis.

Layers are stacked on a leading axis and split contiguously across the
pipeline stages; microbatches stream through a ppermute ring.  Bubbles
execute as wasted (masked) compute — the SPMD program is identical on
every device, which is what keeps XLA happy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.dist.smap import shard_map


def pipeline_apply(stage_fn, params, x, *, mesh, n_microbatches: int = 1,
                   axis: str = None):
    """Run ``stage_fn(layers_local, h)`` as an N-stage pipeline.

    params: pytree with a leading stacked-layer dim divisible by the number
    of stages; x: (B, ...) with B divisible by n_microbatches.  Returns the
    same value as folding all layers sequentially over x.
    """
    axis = axis or mesh.axis_names[0]
    n_stages = mesh.shape[axis]
    m = n_microbatches
    b = x.shape[0]
    assert b % m == 0, (b, m)
    mb = b // m

    def body(layers_local, x_all):
        sid = lax.axis_index(axis)
        xs = x_all.reshape(m, mb, *x_all.shape[1:])
        buf = jnp.zeros_like(xs[0])
        out = jnp.zeros_like(xs)

        def step(t, carry):
            buf, out = carry
            feed = lax.dynamic_index_in_dim(xs, jnp.clip(t, 0, m - 1), 0,
                                            keepdims=False)
            inp = jnp.where(sid == 0, feed, buf)
            y = stage_fn(layers_local, inp)
            # hand off to the next stage; stage 0 keeps reading fresh input
            nbuf = lax.ppermute(y, axis,
                                [(i, i + 1) for i in range(n_stages - 1)])
            idx = t - (n_stages - 1)
            upd = lax.dynamic_update_index_in_dim(
                out, y, jnp.clip(idx, 0, m - 1), 0)
            take = (sid == n_stages - 1) & (idx >= 0)
            out = jnp.where(take, upd, out)
            return nbuf, out

        _, out = lax.fori_loop(0, m + n_stages - 1, step, (buf, out))
        # only the last stage holds real outputs; psum broadcasts them
        out = lax.psum(jnp.where(sid == n_stages - 1, out, 0.0), axis)
        return out.reshape(x_all.shape)

    layer_specs = jax.tree_util.tree_map(lambda _: P(axis), params)
    return shard_map(body, mesh=mesh, in_specs=(layer_specs, P()),
                     out_specs=P())(params, x)
