"""shard_map wrapper (API drift shim).

Collective-heavy code (MoE expert parallel, split-KV decode, pipeline)
goes through here so jax version drift is absorbed in one place.
``check_rep=False`` by default: our bodies mix psum/pmax merges whose
replication typing the checker rejects on some versions.
"""

from __future__ import annotations

try:                                    # jax >= 0.4.31 experimental API
    from jax.experimental.shard_map import shard_map as _shard_map
except ImportError:                     # newer jax: promoted to jax.shard_map
    from jax import shard_map as _shard_map


def shard_map(f, *, mesh, in_specs, out_specs, check_rep: bool = False):
    try:
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_rep)
    except TypeError:                   # check_rep removed upstream
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs)
