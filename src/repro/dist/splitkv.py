"""Split-KV (flash-decode) attention under shard_map.

The KV cache's sequence dimension is sharded over a mesh axis; each device
writes the new token into its local shard (if it owns the slot), computes
a *partial* softmax over its local keys, and the partials are merged with
a pmax/psum log-sum-exp reduction — numerically identical to attention
over the full cache.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.dist.smap import shard_map


def _write_local(cache, new, start, offset, l_local, scale_rank3=False):
    """Write ``new`` (global position ``start``) into the local cache shard
    covering [offset, offset + l_local)."""
    pos = start - offset
    idx = jnp.clip(pos, 0, l_local - 1)
    zeros = (0, idx) + (0,) * (cache.ndim - 2)
    updated = lax.dynamic_update_slice(cache, new.astype(cache.dtype), zeros)
    in_range = (pos >= 0) & (pos < l_local)
    return jnp.where(in_range, updated, cache)


def splitkv_decode_attention(q, k_new, v_new, k_cache, v_cache, start, window,
                             *, mesh, batch_axes: Tuple[str, ...],
                             seq_axis: str,
                             k_scale: Optional[jnp.ndarray] = None,
                             v_scale: Optional[jnp.ndarray] = None,
                             new_scales: Optional[Tuple] = None):
    """One decode step against a sequence-sharded KV cache.

    q: (B, 1, n_heads, d_head); k_new/v_new: (B, 1, n_kv, d_head) (already
    quantized when scales are given); k_cache/v_cache: (B, max_seq, n_kv,
    d_head) sharded over ``seq_axis`` on dim 1.  Returns (out (B, 1,
    n_heads, d_head), updated caches) with caches sharded as they came in.
    """
    quant = k_scale is not None
    B, _, n_heads, d_head = q.shape
    n_kv = k_cache.shape[2]
    group = n_heads // n_kv
    n_seq = mesh.shape[seq_axis]
    out_dtype = q.dtype

    baxes = tuple(a for a in batch_axes if a in mesh.axis_names)
    nb = 1
    for a in baxes:
        nb *= mesh.shape[a]
    bspec = (baxes if len(baxes) > 1 else baxes[0]) \
        if (nb > 1 and B % nb == 0) else None

    cache_spec = P(bspec, seq_axis, None, None)
    scale_spec = P(bspec, seq_axis, None)
    new_spec = P(bspec, None, None, None)

    def body(q, k_new, v_new, kc, vc, start, window, ks, vs, ks_new, vs_new):
        l_local = kc.shape[1]
        offset = lax.axis_index(seq_axis) * l_local
        kc = _write_local(kc, k_new, start, offset, l_local)
        vc = _write_local(vc, v_new, start, offset, l_local)
        if quant:
            ks = _write_local(ks, ks_new, start, offset, l_local)
            vs = _write_local(vs, vs_new, start, offset, l_local)
            k_all = kc.astype(jnp.float32) * ks[..., None]
            v_all = vc.astype(jnp.float32) * vs[..., None]
        else:
            k_all = kc.astype(jnp.float32)
            v_all = vc.astype(jnp.float32)

        k_pos = offset + jnp.arange(l_local, dtype=jnp.int32)
        valid = k_pos <= start
        valid &= jnp.where(window > 0, k_pos > start - window, True)

        qg = q.astype(jnp.float32).reshape(B, 1, n_kv, group, d_head)
        # (B, n_kv, group, 1, l_local) partial scores
        s = jnp.einsum("bsngh,bknh->bngsk", qg, k_all,
                       preferred_element_type=jnp.float32)
        s = s / jnp.sqrt(jnp.float32(d_head))
        s = jnp.where(valid[None, None, None, None, :], s, -1e30)
        m_loc = jnp.max(s, axis=-1)
        m = lax.pmax(m_loc, seq_axis)
        p = jnp.exp(s - m[..., None])
        p = jnp.where(valid[None, None, None, None, :], p, 0.0)
        l_sum = lax.psum(jnp.sum(p, axis=-1), seq_axis)
        o = jnp.einsum("bngsk,bknh->bsngh", p, v_all)
        o = lax.psum(o, seq_axis)
        o = o / jnp.maximum(l_sum, 1e-30).transpose(0, 3, 1, 2)[..., None]
        return (o.reshape(B, 1, n_heads, d_head).astype(out_dtype),
                kc, vc, ks, vs)

    if quant:
        ks_new, vs_new = new_scales
    else:
        # zero-size placeholders keep one body signature for both paths
        z3 = jnp.zeros((B, 1, 0), jnp.float32)
        k_scale = v_scale = jnp.zeros((B, k_cache.shape[1], 0), jnp.float32)
        ks_new, vs_new = z3, z3

    out, kc, vc, ks, vs = shard_map(
        body, mesh=mesh,
        in_specs=(new_spec, new_spec, new_spec, cache_spec, cache_spec,
                  P(), P(), scale_spec, scale_spec,
                  P(bspec, None, None), P(bspec, None, None)),
        out_specs=(new_spec, cache_spec, cache_spec, scale_spec, scale_spec),
    )(q, k_new, v_new, k_cache, v_cache, start, window,
      k_scale, v_scale, ks_new, vs_new)

    caches = (kc, vc, ks, vs) if quant else (kc, vc)
    return out, caches
