"""Sharding tables for params, batches and decode state.

All entry points are *heuristic but safe*: a dimension is only pinned to a
mesh axis when it divides the axis-size product, otherwise it stays
replicated, so every table is valid on any mesh (jit/device_put reshard as
needed — these are placement hints, not correctness requirements).
"""

from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes a global batch dimension spreads over."""
    return tuple(a for a in mesh.axis_names if a != "model")


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def cache_seq_len(seq_len: int, pad: int = 256) -> int:
    """Decode-cache length for a prompt of ``seq_len``: room for generated
    tokens, padded to a multiple of 256 so the sequence axis stays
    divisible by any production model-axis size."""
    return ((seq_len + pad + 255) // 256) * 256


def _batch_spec(mesh, dim: int):
    axes = batch_axes(mesh)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    if n > 1 and dim % n == 0:
        return axes if len(axes) > 1 else axes[0]
    return None


def _model_spec(mesh, dim: int):
    if "model" in mesh.axis_names and mesh.shape["model"] > 1 \
            and dim % mesh.shape["model"] == 0:
        return "model"
    return None


def _leaf_spec(path, leaf, mesh, fsdp: bool):
    """Tensor-parallel spec for one parameter leaf.

    2D+ weights shard their widest "width" dim over "model"; with fsdp the
    opposite end additionally shards over the data axes.  Stacked-layer
    leading dims, norm scales and biases stay replicated."""
    keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
    shape = leaf.shape
    stacked = "layers" in keys
    first = 1 if stacked else 0           # skip the (n_layers, ...) dim
    ndim = len(shape)
    if ndim - first < 2:                  # scales, biases, mix weights
        return P()
    spec = [None] * ndim
    name = keys[-1] if keys else ""
    if name in ("w_gate", "w_up", "w_down") and ndim - first == 3:
        # routed experts (E, d, f): expert-parallel over "model",
        # FSDP over the widest remaining dim
        spec[first] = _model_spec(mesh, shape[first])
        if fsdp:
            tail = first + 2 if name != "w_down" else first + 1
            spec[tail] = _batch_spec(mesh, shape[tail])
        return P(*spec)
    # generic 2D matmul weight: "model" on the last dim when divisible,
    # else the first non-stacked dim; fsdp on the other end
    if _model_spec(mesh, shape[-1]) is not None:
        spec[-1] = "model"
        if fsdp:
            spec[first] = _batch_spec(mesh, shape[first])
    elif _model_spec(mesh, shape[first]) is not None:
        spec[first] = "model"
        if fsdp:
            spec[-1] = _batch_spec(mesh, shape[-1])
    return P(*spec)


def param_shardings(cfg, mesh, fsdp: bool = True):
    """NamedSharding pytree matching ``init_params(cfg, key)``."""
    from repro.models import init_params
    abstract = jax.eval_shape(lambda k: init_params(cfg, k),
                              jax.random.PRNGKey(0))
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, _leaf_spec(path, leaf, mesh,
                                                          fsdp)),
        abstract)


def train_batch_shardings(cfg, mesh):
    """Shardings for {"inputs", "labels"} train batches (batch-dim DP)."""
    def shard(ndim_tail):
        return NamedSharding(mesh, P(batch_axes(mesh) or None,
                                     *([None] * ndim_tail)))
    inputs = shard(2 if not cfg.embed_inputs else 1)
    return {"inputs": inputs, "labels": shard(1)}


def prefill_shardings(cfg, mesh):
    return {"inputs": train_batch_shardings(cfg, mesh)["inputs"]}


def decode_token_shardings(cfg, mesh, batch: int):
    spec = _batch_spec(mesh, batch)
    if cfg.embed_inputs:
        return NamedSharding(mesh, P(spec))
    return NamedSharding(mesh, P(spec, None))


def decode_state_shardings(cfg, mesh, batch: int):
    """DecodeState shardings: KV caches shard their sequence dim over
    "model" (split-KV decode), batch dims over the data axes."""
    from repro.models.model import DecodeState, init_decode_state
    abstract = jax.eval_shape(
        lambda: init_decode_state(cfg, batch, 8, jnp.bfloat16))

    b = _batch_spec(mesh, batch)

    def cache_spec(leaf, seq_dim: int):
        spec = [None] * len(leaf.shape)
        if len(spec) >= 2:
            spec[1] = b
        return spec

    def shard(name, leaf):
        spec = cache_spec(leaf, 2)
        if name in ("k_cache", "v_cache", "k_scale", "v_scale") \
                and len(leaf.shape) > 2:
            # actual runtime seq length is the caller's max_seq, not the
            # abstract one — pin only the axis name; divisibility is
            # enforced by the split-KV fast-path gate at trace time
            if "model" in mesh.axis_names and mesh.shape["model"] > 1 \
                    and leaf.shape[2] > 0:
                spec[2] = "model"
        return NamedSharding(mesh, P(*spec))

    return DecodeState(
        k_cache=shard("k_cache", abstract.k_cache),
        v_cache=shard("v_cache", abstract.v_cache),
        k_scale=shard("k_scale", abstract.k_scale),
        v_scale=shard("v_scale", abstract.v_scale),
        conv_state=NamedSharding(mesh, P(None, b)),
        ssm_state=NamedSharding(mesh, P(None, b)),
        length=replicated(mesh),
    )
