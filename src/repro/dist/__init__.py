# Distribution layer: logical-axis sharding context, parameter/state
# sharding tables, shard_map wrapper, split-KV decode attention, and
# pipeline parallelism.  Every entry point degrades to a no-op on a
# single device so the smoke tests and CPU benches never pay for it.
