"""Vectorized (JAX) Monte-Carlo overcommit simulator (paper §4.4).

The paper: "We built a simulator that models cluster configurations and
workloads, which recommended a 1.5x overcommit factor."  This is that
simulator, jax.vmap'd over candidate factors x trials x hosts:

  - each host packs critical pods to ~stateless fill plus preemptible pods
    filling (factor-1) x capacity of extended resource;
  - per-pod demand is a correlated diurnal level + lognormal noise;
  - a factor is SAFE if P(host busy > evict threshold) stays under a target
    violation rate (QoS evictions are disruptive, so they must stay rare).

The recommendation is the largest safe factor on the grid, additionally
clamped by the analytic O_max memory bound (= 1.66x with paper constants).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.tiers import QOS_EVICT_UTILIZATION, o_max


@dataclasses.dataclass(frozen=True)
class OvercommitSimConfig:
    n_hosts: int = 512
    n_trials: int = 64
    host_cores: float = 100.0
    critical_fill: float = 0.45       # fraction of physical cores requested
    critical_demand_mean: float = 0.40  # demand per requested core
    preempt_demand_mean: float = 0.40
    demand_sigma: float = 0.38        # lognormal sigma
    diurnal_amp: float = 0.30         # correlated load swing
    evict_threshold: float = QOS_EVICT_UTILIZATION
    max_violation_rate: float = 0.02  # hosts-over-threshold budget
    seed: int = 0


def _host_busy(key, cfg: OvercommitSimConfig, factor: jnp.ndarray):
    """Busy-core fraction for (n_trials, n_hosts) hosts at one factor."""
    k1, k2, k3 = jax.random.split(key, 3)
    shape = (cfg.n_trials, cfg.n_hosts)
    # correlated diurnal phase per trial (cluster-wide load level)
    phase = jax.random.uniform(k1, (cfg.n_trials, 1)) * 2 * jnp.pi
    diurnal = 1.0 + cfg.diurnal_amp * jnp.sin(phase)
    ln = lambda k: jnp.exp(cfg.demand_sigma * jax.random.normal(k, shape)
                           - 0.5 * cfg.demand_sigma ** 2)
    crit_req = cfg.critical_fill * cfg.host_cores
    pre_req = (factor - 1.0) * cfg.host_cores
    crit_busy = crit_req * cfg.critical_demand_mean * ln(k2) * diurnal
    pre_busy = pre_req * cfg.preempt_demand_mean * ln(k3) * diurnal
    return (crit_busy + pre_busy) / cfg.host_cores


def violation_rate(cfg: OvercommitSimConfig, factor: float) -> float:
    key = jax.random.PRNGKey(cfg.seed)
    busy = _host_busy(key, cfg, jnp.asarray(factor))
    return float(jnp.mean(busy > cfg.evict_threshold))


@partial(jax.jit, static_argnames=("cfg",))
def _grid_violation_rates(cfg: OvercommitSimConfig,
                          factors: jnp.ndarray) -> jnp.ndarray:
    """Per-factor violation rates: the whole factors x trials x hosts
    Monte-Carlo grid is one jitted vmap (the frozen config is a static
    argument, so each config compiles once and re-runs in microseconds)."""
    key = jax.random.PRNGKey(cfg.seed)

    def rate(f):
        busy = _host_busy(key, cfg, f)
        return jnp.mean(busy > cfg.evict_threshold)

    return jax.vmap(rate)(factors)


def factor_grid(grid_lo: float, grid_hi: float,
                grid_step: float) -> np.ndarray:
    """Candidate-factor grid with an exact endpoint: ``np.arange(lo,
    hi + 1e-9, step)`` accumulates float error and drops ``hi`` for many
    (lo, hi, step) triples (e.g. 1.0..1.3 by 0.1 ends at 1.2000000000000002
    > 1.3 + 1e-9's predecessor) — rounding a ``linspace`` over the rounded
    step count keeps every factor and the endpoint exact."""
    n = max(0, int(round((grid_hi - grid_lo) / grid_step)))
    return np.round(np.linspace(grid_lo, grid_lo + n * grid_step, n + 1), 9)


def recommend_factor(cfg: OvercommitSimConfig = OvercommitSimConfig(),
                     grid_lo: float = 1.0, grid_hi: float = 2.0,
                     grid_step: float = 0.05) -> Dict[str, object]:
    """Sweep the factor grid (one jitted vmap) and pick the largest safe
    factor, clamped by O_max — an argmax over the safe mask, no host loop.

    The result carries an explicit ``safe`` flag: when NO factor on the
    grid clears the violation budget and the O_max bound, ``recommended``
    falls back to ``grid_lo`` *without* implying it is safe — callers
    (the capacity planner example, the overcommit bench) must check
    ``safe`` before acting on the recommendation."""
    factors = factor_grid(grid_lo, grid_hi, grid_step)
    rates = np.asarray(_grid_violation_rates(cfg, jnp.asarray(factors)))
    omax = o_max()
    valid = (rates <= cfg.max_violation_rate) & (factors <= omax)
    safe = bool(valid.any())
    # grid is ascending: the argmax over the reversed mask is the largest
    # safe factor
    best = (float(factors[len(valid) - 1 - int(np.argmax(valid[::-1]))])
            if safe else grid_lo)
    return {
        "factors": [round(float(f), 3) for f in factors],
        "violation_rates": [float(r) for r in rates],
        "o_max": omax,
        "safe": safe,
        "recommended": round(best, 3),
    }
