"""Struct-of-arrays fleet representation — the vectorized simulation core.

The seed implementation carried one Python ``SEState`` object per
service-environment, so every orchestrator phase was a dict loop and the
whole stack only ran at ``scale=0.02``.  ``FleetState`` holds the same
state as parallel numpy arrays (one row per service-environment); the
orchestrator, QoS controller, drills and the scenario-ensemble driver all
operate on boolean masks and reductions over these arrays, which is what
lets ``scale=1.0`` (~22k services, paper Table 3) synthesize and fail over
in seconds and lets JAX vmap scenario ensembles over the aggregates.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.core.tiers import (BASELINE_CORES, DEFAULT_CLASS_OF_TIER,
                              SERVICES_PER_TIER, FailureClass, Tier)

# ---------------------------------------------------------------------------
# Codes (int8 columns)
# ---------------------------------------------------------------------------

PLACEMENT_STEADY, PLACEMENT_BURST, PLACEMENT_CLOUD, PLACEMENT_DOWN = range(4)
PLACEMENT_NAMES = ("steady", "burst", "cloud", "down")
PLACEMENT_CODE = {n: i for i, n in enumerate(PLACEMENT_NAMES)}

# steady-pool occupancy: set by the orchestrator at placement time;
# POOL_NONE = not (yet) accounted against any pool — never released
POOL_STATELESS, POOL_OVERCOMMIT, POOL_NONE = 0, 1, 2

_FC_ORDER = (FailureClass.ALWAYS_ON, FailureClass.ACTIVE_MIGRATE,
             FailureClass.RESTORE_LATER, FailureClass.TERMINATE)
FCLASS_CODE: Dict[FailureClass, int] = {fc: i for i, fc in enumerate(_FC_ORDER)}
CODE_FCLASS: Dict[int, FailureClass] = {i: fc for fc, i in FCLASS_CODE.items()}
AO, AM, RL, TM = (FCLASS_CODE[fc] for fc in _FC_ORDER)


@dataclasses.dataclass
class EdgeArrays:
    """Dependency edges in array form (for vectorized drills/analysis)."""
    src: np.ndarray            # caller row index, int32
    dst: np.ndarray            # callee row index, int32
    fail_open: np.ndarray      # bool — False = fail-close (UNSAFE)
    # per-edge RPC volume (Table 2 cell volume split across the cell's
    # edges) — the graph engine uses it to rank hardening candidates
    weight: Optional[np.ndarray] = None   # float32

    @property
    def n(self) -> int:
        return len(self.src)


@dataclasses.dataclass
class FleetState:
    """Parallel arrays over service-environments (row = one SE)."""
    names: List[str]
    tier: np.ndarray               # int8 Tier value
    fclass: np.ndarray             # int8 FCLASS_CODE
    cores_per_replica: np.ndarray  # float64
    replicas: np.ndarray           # int64 — steady-state spec
    replicas_live: np.ndarray      # int64
    placement: np.ndarray          # int8 PLACEMENT_*
    pool: np.ndarray               # int8 POOL_* — steady pool occupied
    locked: np.ndarray             # bool
    traffic_enabled: np.ndarray    # bool
    edges: Optional[EdgeArrays] = None
    index: Dict[str, int] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.index:
            self.index = {n: i for i, n in enumerate(self.names)}

    # ------------------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.names)

    @property
    def spec_cores(self) -> np.ndarray:
        return self.cores_per_replica * self.replicas

    @property
    def cores_live(self) -> np.ndarray:
        return self.cores_per_replica * self.replicas_live

    @property
    def preemptible(self) -> np.ndarray:
        return self.fclass >= RL

    @property
    def survives(self) -> np.ndarray:
        return self.fclass <= AM

    def class_mask(self, fc) -> np.ndarray:
        code = FCLASS_CODE[fc] if isinstance(fc, FailureClass) else fc
        return self.fclass == code

    def class_cores(self, fc, placement: Optional[str] = None) -> float:
        m = self.class_mask(fc)
        if placement is not None:
            m = m & (self.placement == PLACEMENT_CODE[placement])
        return float(self.cores_live[m].sum())

    def class_envs(self, fc, placement: str) -> int:
        m = (self.class_mask(fc)
             & (self.placement == PLACEMENT_CODE[placement])
             & (self.replicas_live > 0))
        return int(np.count_nonzero(m))

    def class_core_totals(self) -> Tuple[float, float, float, float]:
        """(always_on, active_migrate, restore_later, terminate) spec cores."""
        cores = self.spec_cores
        return tuple(float(cores[self.fclass == c].sum())
                     for c in (AO, AM, RL, TM))

    def apply_ufa_target_classes(self) -> int:
        """Array analogue of ``service.apply_ufa_target_classes``:
        T1 Always-On -> Active-Migrate (paper Table 5 goal state)."""
        m = (self.tier == int(Tier.T1)) & (self.fclass == AO)
        self.fclass[m] = AM
        return int(np.count_nonzero(m))

    # ------------------------------------------------------------------
    @classmethod
    def from_specs(cls, fleet: Dict[str, "object"],
                   with_edges: bool = False) -> "FleetState":
        names = list(fleet)
        n = len(names)
        tier = np.empty(n, np.int8)
        fclass = np.empty(n, np.int8)
        cpr = np.empty(n, np.float64)
        replicas = np.empty(n, np.int64)
        for i, s in enumerate(fleet.values()):
            tier[i] = int(s.tier)
            fclass[i] = FCLASS_CODE[s.failure_class]
            cpr[i] = s.cores_per_replica
            replicas[i] = s.replicas
        fs = cls(names=names, tier=tier, fclass=fclass,
                 cores_per_replica=cpr, replicas=replicas,
                 replicas_live=replicas.copy(),
                 placement=np.zeros(n, np.int8),
                 pool=np.full(n, POOL_NONE, np.int8),
                 locked=np.zeros(n, bool),
                 traffic_enabled=np.ones(n, bool))
        if with_edges:
            fs.edges = edges_from_specs(fleet, fs.index)
        return fs


def edges_from_specs(fleet: Dict[str, "object"],
                     index: Optional[Dict[str, int]] = None) -> EdgeArrays:
    index = index or {n: i for i, n in enumerate(fleet)}
    src, dst, fo = [], [], []
    for name, s in fleet.items():
        i = index[name]
        for d in s.deps:
            j = index.get(d)
            if j is None:
                continue
            src.append(i)
            dst.append(j)
            fo.append(bool(s.fail_open.get(d, True)))
    src_a = np.asarray(src, np.int32)
    dst_a = np.asarray(dst, np.int32)
    tier = np.fromiter((int(s.tier) for s in fleet.values()), np.int8,
                       len(fleet))
    return EdgeArrays(src=src_a, dst=dst_a,
                      fail_open=np.asarray(fo, bool),
                      weight=_edge_weights(tier, src_a, dst_a))


def _edge_weights(tier: np.ndarray, src: np.ndarray,
                  dst: np.ndarray) -> np.ndarray:
    """Per-edge RPC volume: the Table 2 cell volume split evenly across the
    edges in that (caller_tier, callee_tier) cell — the same rule
    ``dependency.generate_traces`` uses to weight its sampled traffic."""
    from repro.core.service import _TABLE2
    n_tiers = len(_T)
    vol = np.asarray([[_TABLE2[t][c] for c in range(n_tiers)] for t in _T],
                     np.float64)
    cell = tier[src].astype(np.int64) * n_tiers + tier[dst]
    counts = np.bincount(cell, minlength=n_tiers * n_tiers)
    return (vol.ravel()[cell]
            / np.maximum(counts[cell], 1)).astype(np.float32)


# ---------------------------------------------------------------------------
# Array-native fleet synthesis (fast path for paper scale)
# ---------------------------------------------------------------------------

_T = list(Tier)
_REPLICA_OPTIONS = np.array([0.5, 1.0, 2.0, 4.0])


def synthesize_fleet_state(scale: float = 1.0, seed: int = 0,
                           unsafe_fraction: float = 0.08,
                           mean_deps: float = 6.0,
                           demand_fraction: float = 0.25,
                           with_edges: bool = True,
                           unsafe_chain_fraction: float = 0.0) -> FleetState:
    """Array-native analogue of ``service.synthesize_fleet``: same tier
    structure (Tables 1-3), same footprint distribution, no per-service
    Python objects.  ~22k services synthesize in well under a second.

    unsafe_chain_fraction plants fail-close edges between *critical*
    services (caller and callee both survive failover).  These edges break
    nothing on their own — critical services never go dark — but they relay
    breakage: a critical caller whose critical callee breaks through an
    unsafe preemptible dependency breaks too.  They are the transitive
    failure chains the graph engine's multi-hop propagation exists to find
    (default 0.0 keeps the one-hop fleet shape the seed tests pin down).
    """
    from repro.core.service import _TABLE2   # single source for Table 2
    rng = np.random.default_rng(seed)

    tiers, cprs, reps = [], [], []
    counts = {}
    for tier in _T:
        n = max(2, int(round(SERVICES_PER_TIER[tier] * scale)))
        counts[tier] = n
        tier_cores = BASELINE_CORES[tier] * scale * demand_fraction
        w = rng.lognormal(0.0, 1.2, n)
        cores = tier_cores * w / w.sum()
        # options c in (0.5, 1, 2, 4) with c <= 2*cores; 0.5 as fallback
        k = np.searchsorted(_REPLICA_OPTIONS, 2 * cores, side="right")
        pick = rng.integers(0, np.maximum(k, 1))
        cpr = _REPLICA_OPTIONS[np.where(k > 0, pick, 0)]
        tiers.append(np.full(n, int(tier), np.int8))
        cprs.append(cpr)
        reps.append(np.maximum(1, np.round(cores / cpr)).astype(np.int64))

    tier_arr = np.concatenate(tiers)
    cpr_arr = np.concatenate(cprs)
    rep_arr = np.concatenate(reps)
    n = len(tier_arr)
    fclass = np.empty(n, np.int8)
    for t in _T:
        fclass[tier_arr == int(t)] = FCLASS_CODE[DEFAULT_CLASS_OF_TIER[t]]
    names = [f"{Tier(int(t)).name.lower()}-svc-{i:05d}"
             for i, t in enumerate(tier_arr)]

    fs = FleetState(names=names, tier=tier_arr, fclass=fclass,
                    cores_per_replica=cpr_arr, replicas=rep_arr,
                    replicas_live=rep_arr.copy(),
                    placement=np.zeros(n, np.int8),
                    pool=np.full(n, POOL_NONE, np.int8),
                    locked=np.zeros(n, bool),
                    traffic_enabled=np.ones(n, bool))

    if with_edges:
        # tier start offsets in the concatenated arrays
        starts, off = {}, 0
        for t in _T:
            starts[t] = off
            off += counts[t]
        n_deps = np.maximum(0, rng.normal(mean_deps, 2.0, n)).astype(np.int64)
        src = np.repeat(np.arange(n, dtype=np.int32), n_deps)
        m = len(src)
        # callee tier ~ Table 2 row of the caller's tier
        row_cdf = {int(t): np.cumsum(np.asarray(_TABLE2[t], np.float64)
                                     / sum(_TABLE2[t])) for t in _T}
        u = rng.random(m)
        callee_tier = np.empty(m, np.int8)
        for t in _T:
            sel = tier_arr[src] == int(t)
            callee_tier[sel] = np.searchsorted(row_cdf[int(t)], u[sel])
        callee_tier = np.minimum(callee_tier, len(_T) - 1)
        # uniform callee within the tier
        base = np.array([starts[Tier(int(c))] for c in range(len(_T))],
                        np.int64)
        span = np.array([counts[Tier(int(c))] for c in range(len(_T))],
                        np.int64)
        dst = (base[callee_tier]
               + rng.integers(0, span[callee_tier])).astype(np.int32)
        keep = src != dst
        src, dst, callee_tier = src[keep], dst[keep], callee_tier[keep]
        # fail-close only on tier-inverted (critical -> preemptible) edges
        inverted = (fclass[src] <= AM) & (fclass[dst] >= RL)
        fail_close = inverted & (rng.random(len(src)) < unsafe_fraction)
        if unsafe_chain_fraction > 0.0:
            # relay edges: fail-close between critical services (multi-hop
            # chains).  Drawn AFTER the inverted-edge draw so that
            # unsafe_chain_fraction=0.0 is bit-identical to the seed stream.
            chain = (fclass[src] <= AM) & (fclass[dst] <= AM)
            fail_close |= chain & (rng.random(len(src))
                                   < unsafe_chain_fraction)
        fs.edges = EdgeArrays(src=src, dst=dst, fail_open=~fail_close,
                              weight=_edge_weights(tier_arr, src, dst))
    return fs
