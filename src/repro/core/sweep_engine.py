"""Sharded fused sweep engine: full-peak scenario ensembles at 100k+.

The paper certifies UFA against rare full-peak failovers by exploring the
scenario space; PR 4's composition ran the analytic model
(``scenarios._sweep_jit``), the timeline scan (``timeline_sim._sweep_jit``)
and the dependency propagation (``graph.blackhole_ensemble``) as *separate*
jitted calls with host round-trips between them, and materialized the full
(S, T, series) trace stack even when only verdicts were wanted — which is
why ensembles capped out around 256 scenarios.  This module fuses the
three stages into ONE jitted, device-parallel pipeline:

  * per scenario, ``scenarios.scenario_outcome`` (closed-form verdicts),
    ``timeline_sim.timeline_verdicts`` (the ``lax.scan`` timeline kernel,
    summary-only — no trace materialization) and the dependency-propagation
    penalty are composed inside one ``vmap``;
  * the blackhole propagation runs on device inside the same program:
    unique ``evict_fraction`` dark sets (shared uniform draws, as in
    ``blackhole_ensemble``) go through the ``lax.while_loop`` fixed point
    once, and each scenario *gathers* its broken-critical fraction — the
    (S, n) dark matrix and the per-scenario verdicts never touch the host
    between stages;
  * the scenario axis is bucket-padded and reshaped to ``(n_chunks,
    chunk)`` mega-batches driven by ``lax.map`` — chunk widths and chunk
    counts are padded to powers of two, so grids from 256 to 100k+
    scenarios reuse a handful of compiled shapes (no recompile per size
    within a padding bucket; see ``bucket_shape`` / ``compiled_variants``);
  * the chunk axis is sharded across devices via ``repro.dist``
    (``ctx.sharding_rules`` + a ``NamedSharding`` over a 1-D "scenarios"
    mesh), and the scenario buffers are donated to the pipeline.

Config (fleet aggregates, timeline constants, graph edges) is precomputed
once into device-resident arrays and passed as *traced* arguments, so the
jit cache is keyed on static shapes only — re-running with a different
fleet or scenario values never recompiles.

Equivalence contract (pinned by ``tests/test_sweep_engine.py``): the fused
pipeline matches the composed ``sweep_scenarios`` + ``sweep_timeline`` +
propagation path exactly (bit-for-bit) on every verdict key, sharded or
not.
"""

from __future__ import annotations

import time
from contextlib import nullcontext
from functools import partial
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import obs
from repro.core.scenarios import (FleetAggregates, analytic_consts,
                                  scenario_grid, scenario_outcome,
                                  stage_seed)
from repro.core.timeline_sim import (PARAM_KEYS, TimelineConfig,
                                     default_scenario, default_ts,
                                     timeline_verdicts,
                                     timeline_verdicts_batch,
                                     validate_grid)
from repro.dist import ctx as dist_ctx
from repro.kernels import backend as _kbackend

# mega-batch width for lax.map chunking: big enough to amortize scan-step
# overhead, small enough that a chunk's per-step working set stays in
# cache (measured fastest on CPU among {256..64k} widths)
CHUNK = 4096
# smallest padded width — tiny interactive grids don't pay for a full
# 4096-wide chunk (and every bucket stays divisible by 8 devices)
MIN_BUCKET = 256


def _pow2_ceil(n: int) -> int:
    return 1 << max(0, int(n - 1).bit_length())


def bucket_shape(n: int, chunk: int = CHUNK) -> tuple[int, int]:
    """Padded ``(n_chunks, width)`` for an ``n``-scenario grid: width is a
    power of two in [MIN_BUCKET, chunk], the chunk count a power of two —
    so every grid size in a bucket compiles (and caches) the same shapes.
    """
    if n <= chunk:
        return 1, max(MIN_BUCKET, _pow2_ceil(n))
    return _pow2_ceil(-(-n // chunk)), chunk


def _fused_verdicts(consts: Dict, p: Dict, ts, temporal: bool,
                    tau=None) -> Dict:
    """ONE scenario, all stages: the analytic closed-form verdicts plus
    (``temporal``) the ``t_``-prefixed timeline-scan verdicts — the same
    kernels the standalone sweeps vmap, composed in one trace.  ``tau``
    (a traced f32 scalar, or None) threads the opt-in soft relaxation
    into both kernels — sigmoid verdict indicators for the capacity
    optimizer; None traces the historical bit-exact ops."""
    out = dict(scenario_outcome(consts["a"], p, tau))
    if temporal:
        tsum = timeline_verdicts(consts["t"], p, ts, tau)
        out.update({f"t_{k}": v for k, v in tsum.items()})
    return out


def _fused_verdicts_block(consts: Dict, p: Dict, ts, temporal: bool,
                          reducer: str, tau=None) -> Dict:
    """One WIDTH-wide scenario block.  ``reducer="scan"`` vmaps the
    per-scenario fused trace (the historical, bit-exact default path);
    ``reducer="pallas"`` keeps the analytic stage identical but runs the
    timeline carry through the segmented Pallas verdict-reduction kernel
    (``timeline_verdicts_batch``) — exact on every verdict except the
    float32-tight availability integral.  Soft mode (``tau``) always
    takes the scan path: the Pallas reducer is hard-only."""
    if reducer == "pallas" and temporal and tau is None:
        out = dict(jax.vmap(
            lambda q: dict(scenario_outcome(consts["a"], q)))(p))
        tsum = timeline_verdicts_batch(consts["t"], p, ts)
        out.update({f"t_{k}": v for k, v in tsum.items()})
        return out
    return jax.vmap(
        lambda q: _fused_verdicts(consts, q, ts, temporal, tau))(p)


@partial(jax.jit, static_argnames=("temporal", "reducer"),
         donate_argnums=(1,))
def _run_chunks(consts, pchunks, ts, tau=None, *, temporal,
                reducer="scan"):
    """Fused pipeline, explicit ``dep_broken_frac``: lax.map over
    ``(n_chunks, width)`` scenario mega-batches of the fused scenario
    block function.  ``tau=None`` vs a traced scalar hit different jit
    cache entries (different pytree structures), so the hard path's
    compiled program is untouched by soft runs."""
    def one(p):
        p = dict(p, dep_broken_frac=dist_ctx.hint(p["dep_broken_frac"],
                                                  "batch"))
        return _fused_verdicts_block(consts, p, ts, temporal, reducer, tau)
    return lax.map(one, pchunks)


@partial(jax.jit, static_argnames=("temporal", "reducer"),
         donate_argnums=(2, 3, 4))
def _run_chunks_dep(consts, dep, pchunks, invchunks, storm_invchunks,
                    dark_u, ts, tau=None, *, temporal, reducer="scan"):
    """Fused pipeline with the dependency stage in-program: propagate the
    (U, n) unique dark sets to their fixed point (backend-dispatched —
    the Pallas ELL kernel when ``dep`` carries the ELL adjacency), then
    every scenario gathers its broken-critical fraction/counts by
    unique-fraction index — no host materialization between propagation
    and the availability model.  ``dark_u`` carries the blackhole uniques
    AND the cascade-storm uniques (``combined_dark_uniques``): one
    while_loop settles both stages, and each scenario gathers its storm
    verdict (``storm_broken_frac``) by its second index."""
    from repro.graph.propagation import broken_critical_fractions
    counts, frac, n_dark = broken_critical_fractions(dark_u, dep)

    def one(args):
        p, inv, sinv = args
        p = dict(p, dep_broken_frac=dist_ctx.hint(frac[inv], "batch"),
                 storm_broken_frac=dist_ctx.hint(frac[sinv], "batch"))
        out = _fused_verdicts_block(consts, p, ts, temporal, reducer, tau)
        out["dep_n_broken_critical"] = counts[inv]
        out["dep_n_dark"] = n_dark[inv]
        return out
    return lax.map(one, (pchunks, invchunks, storm_invchunks))


def compiled_variants() -> int:
    """Number of compiled pipeline programs (jit cache entries across both
    entry points) — the scale bench asserts this does not grow across
    grid sizes within a padding bucket."""
    return int(_run_chunks._cache_size() + _run_chunks_dep._cache_size())


class SweepEngine:
    """One fleet's fused sweep pipeline: config uploaded once, then
    ``run`` executes arbitrary scenario grids end to end in one jitted,
    sharded program.

    Parameters
      agg       class-level fleet aggregates (the analytic model's input)
      timeline  ``TimelineConfig`` (from ``Orchestrator.timeline_config()``
                or ``config_for_fleet``)
      graph     optional ``CallGraph`` — enables the in-pipeline
                dependency stage (per-scenario blackholes keyed on
                ``evict_fraction``, shared draws under ``seed``)
      ts        time grid for the timeline scan (default 2h / 240 steps)
      chunk     mega-batch width (power of two; default ``CHUNK``)
      devices   devices to shard the scenario axis over (a sequence, or
                an int meaning the first k of ``jax.devices()``).
                Explicitly-passed devices always shard; the default (all
                local devices) shards only multi-chunk grids, where the
                partition overhead amortizes — small interactive grids
                run single-device either way
      reducer   timeline-carry backend: "scan" (sequential ``lax.scan``,
                bit-exact vs the composed sweeps) or "pallas" (the
                segmented verdict-reduction kernel; float32-tight on the
                availability integral, exact elsewhere).  Default: per
                backend via ``kernels.backend.use_ufa_kernels()`` —
                "pallas" on accelerators / ``REPRO_UFA_KERNELS=1``,
                "scan" on plain CPU
      analytic_extra  optional kwargs dict forwarded to
                ``analytic_consts`` (``ao_buffer`` / ``spawn_mult``) —
                the capacity optimizer's hook for verifying an optimized
                design through the real hard pipeline
    """

    def __init__(self, agg: FleetAggregates, timeline: TimelineConfig, *,
                 graph=None, seed: int = 0,
                 ts: Optional[np.ndarray] = None,
                 chunk: int = CHUNK,
                 devices: Optional[object] = None,
                 reducer: Optional[str] = None,
                 analytic_extra: Optional[Dict] = None):
        if reducer is None:
            reducer = "pallas" if _kbackend.use_ufa_kernels() else "scan"
        assert reducer in ("scan", "pallas"), reducer
        self.reducer = reducer
        self.consts = {"a": analytic_consts(agg, **(analytic_extra or {})),
                       "t": timeline.as_consts()}
        self._preheat = timeline.preheat_s
        self.ts = np.asarray(default_ts() if ts is None else ts, np.float64)
        self._ts_dev = jnp.asarray(self.ts, jnp.float32)
        self.chunk = int(chunk)
        self.graph = graph
        self.seed = seed
        if graph is not None:
            from repro.graph.propagation import dep_consts
            self.dep = dep_consts(graph)
            # the cascade-storm stage draws its dark sets from a stream
            # independent of the blackhole draws, derived from the one
            # engine seed (campaign reproducibility without stream reuse)
            self.storm_seed = stage_seed(seed, "storm")
        # explicit devices force sharding; by default shard only when the
        # grid spills past one chunk — partition overhead loses on small
        # grids (see the README scaling table), and the thin wrappers
        # (sweep_scenarios / sweep_with_dependency_ensemble) must not
        # silently slow the 256-scenario default down on multi-device
        # hosts
        self._devices_explicit = devices is not None
        if devices is None:
            devices = jax.devices()
        elif isinstance(devices, int):
            devices = jax.devices()[:devices]
        self.devices = list(devices)
        self.mesh = (jax.make_mesh((len(self.devices),), ("scenarios",),
                                   devices=self.devices)
                     if len(self.devices) > 1 else None)

    # ------------------------------------------------------------------
    def _params(self, grid: Dict[str, np.ndarray], n: int, shape) -> Dict:
        """Bucket-pad + chunk the scenario axes to float32 ``shape``
        arrays (missing axes filled with the operating-point defaults)."""
        defaults = default_scenario(burst_delay_s=self._preheat)
        out = {}
        for k in PARAM_KEYS:
            if k in ("dep_broken_frac", "storm_broken_frac"):
                continue                    # computed stages, not axes
            col = (np.asarray(grid[k], np.float32) if k in grid
                   else np.full(n, defaults[k], np.float32))
            out[k] = self._chunked(col, shape)
        return out

    def _chunked(self, col: np.ndarray, shape) -> np.ndarray:
        """(n,) -> (n_chunks, width), padding with the last scenario."""
        pad = shape[0] * shape[1] - len(col)
        if pad:
            col = np.concatenate([col, np.repeat(col[-1:], pad, axis=0)])
        return col.reshape(shape)

    def _shard_for(self, shape) -> bool:
        """Shard this run?  Explicit ``devices`` always shard; otherwise
        only multi-chunk grids (> one CHUNK) amortize the partition
        overhead."""
        if self.mesh is None or shape[1] % len(self.devices):
            return False
        return self._devices_explicit or shape[0] > 1

    def _put(self, tree, shard: bool):
        """Shard the chunk axis over the scenario mesh (replicated when
        sharding is off for this run)."""
        if not shard:
            return tree
        return jax.device_put(
            tree, NamedSharding(self.mesh, P(None, "scenarios")))

    # ------------------------------------------------------------------
    def dep_fractions(self, fractions: np.ndarray
                      ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Per-scenario dependency verdicts as host arrays — the
        *composed*-path helper the equivalence tests pit against the
        in-pipeline stage: (broken_critical_frac f32, n_broken_critical
        i32, n_dark i32), computed by the same device kernel."""
        from repro.graph.propagation import (broken_critical_fractions,
                                             shared_blackhole_draws)
        dark_u, inv = shared_blackhole_draws(self.graph, fractions,
                                             seed=self.seed)
        counts, frac, n_dark = broken_critical_fractions(
            jnp.asarray(dark_u), self.dep)
        return (np.asarray(frac)[inv], np.asarray(counts)[inv],
                np.asarray(n_dark)[inv])

    def storm_fractions(self, refracs: np.ndarray) -> np.ndarray:
        """Per-scenario STORM-stage broken-critical fractions as a host
        array — the composed-path mirror of the in-pipeline cascade-storm
        stage (same derived ``storm_seed`` stream, same device kernel),
        for equivalence tests and host-side what-ifs."""
        from repro.graph.propagation import (broken_critical_fractions,
                                             shared_blackhole_draws)
        dark_u, inv = shared_blackhole_draws(self.graph,
                                             np.asarray(refracs, np.float64),
                                             seed=self.storm_seed)
        _, frac, _ = broken_critical_fractions(jnp.asarray(dark_u),
                                               self.dep)
        return np.asarray(frac)[inv]

    # ------------------------------------------------------------------
    def run(self, grid: Optional[Dict[str, np.ndarray]] = None,
            dep_broken_frac: Optional[np.ndarray] = None,
            temporal: bool = True,
            soft_tau: Optional[float] = None) -> Dict[str, np.ndarray]:
        """Evaluate every scenario in ``grid`` through the fused pipeline;
        returns the analytic verdicts, the ``t_``-prefixed temporal
        verdicts (unless ``temporal=False``), the grid axes, and — when
        the engine has a graph and no explicit ``dep_broken_frac`` — the
        ``dep_n_broken_critical`` / ``dep_n_dark`` propagation verdicts.

        The grid is validated up front (``timeline_sim.validate_grid``):
        unknown axes raise instead of silently sweeping nothing (a
        misspelled key used to fall back to the operating-point default
        for every scenario), and empty/zero-length grids raise instead of
        crashing deep inside the chunker.

        ``soft_tau`` (opt-in): evaluate the SOFT-relaxed pipeline at that
        temperature — verdict keys come back as sigmoid indicators in
        [0, 1] (float, not bool).  Forces the scan reducer (the Pallas
        verdict reduction is hard-only); ``None`` runs the historical
        bit-exact program."""
        grid = scenario_grid() if grid is None else grid
        n = validate_grid(grid)
        tau = (None if soft_tau is None
               else jnp.asarray(soft_tau, jnp.float32))
        reducer = self.reducer if tau is None else "scan"
        shape = bucket_shape(n, self.chunk)
        # one enabled() branch per run() call — free off (and the result
        # below is host-materialized, so the interior timing is honest)
        meter = obs.enabled()
        if meter:
            t0 = time.perf_counter()
            variants0 = compiled_variants()
        params = self._params(grid, n, shape)
        use_dep = self.graph is not None and dep_broken_frac is None
        shard = self._shard_for(shape)

        rules = {"batch": "scenarios"}
        cm = (dist_ctx.sharding_rules(self.mesh, rules)
              if shard else nullcontext())
        with cm:
            if use_dep:
                from repro.graph.propagation import combined_dark_uniques
                fractions = (np.asarray(grid["evict_fraction"])
                             if "evict_fraction" in grid
                             else np.ones(n))
                storm_fr = (np.asarray(grid["storm_refrac"])
                            if "storm_refrac" in grid else None)
                dark_u, inv, storm_inv = combined_dark_uniques(
                    self.graph, fractions, storm_fr,
                    seed=self.seed, storm_seed=self.storm_seed)
                out = _run_chunks_dep(
                    self.consts, self.dep,
                    self._put(params, shard),
                    self._put(self._chunked(inv, shape), shard),
                    self._put(self._chunked(storm_inv, shape), shard),
                    jnp.asarray(dark_u), self._ts_dev, tau,
                    temporal=temporal, reducer=reducer)
            else:
                frac = (np.zeros(n, np.float32) if dep_broken_frac is None
                        else np.asarray(dep_broken_frac, np.float32))
                params["dep_broken_frac"] = self._chunked(frac, shape)
                sfrac = (np.asarray(grid["storm_broken_frac"], np.float32)
                         if "storm_broken_frac" in grid
                         else np.zeros(n, np.float32))
                params["storm_broken_frac"] = self._chunked(sfrac, shape)
                out = _run_chunks(self.consts, self._put(params, shard),
                                  self._ts_dev, tau, temporal=temporal,
                                  reducer=reducer)

        result = {k: np.asarray(v).reshape(-1, *v.shape[2:])[:n]
                  for k, v in out.items()}
        result.update({k: np.asarray(v) for k, v in grid.items()})
        if meter:
            dt = time.perf_counter() - t0
            variants = compiled_variants()
            obs.inc("ufa_sweep_runs_total")
            obs.inc("ufa_sweep_scenarios_total", n)
            if dt > 0:
                obs.set_gauge("ufa_sweep_scenarios_per_s", n / dt)
            obs.observe("ufa_sweep_run_seconds", dt)
            padded = shape[0] * shape[1]
            obs.set_gauge("ufa_sweep_padding_waste_ratio",
                          (padded - n) / padded)
            obs.set_gauge("ufa_sweep_compiled_variants", variants)
            if variants > variants0:
                obs.inc("ufa_sweep_compile_misses_total",
                        variants - variants0)
        return result


def fused_sweep(fs, grid: Optional[Dict[str, np.ndarray]] = None, *,
                with_graph: bool = True, seed: int = 0, region=None,
                ts: Optional[np.ndarray] = None, temporal: bool = True,
                chunk: int = CHUNK,
                devices: Optional[object] = None
                ) -> Dict[str, np.ndarray]:
    """Convenience one-shot: build the engine for a fleet (``FleetState``
    or dict of ``ServiceSpec``) and run a grid through the full fused
    pipeline (dependency stage included when the fleet has edges and
    ``with_graph``)."""
    from repro.core.timeline_sim import config_for_fleet
    agg = (FleetAggregates.from_fleet_state(fs) if hasattr(fs, "fclass")
           else FleetAggregates.from_fleet(fs))
    graph = None
    if with_graph and hasattr(fs, "fclass"):
        from repro.graph import CallGraph
        graph = CallGraph.from_fleet_state(fs)
    timeline = config_for_fleet(fs, region=region)
    eng = SweepEngine(agg, timeline, graph=graph, seed=seed, ts=ts,
                      chunk=chunk, devices=devices)
    return eng.run(grid, temporal=temporal)


def tile_grid(grid: Dict[str, np.ndarray], n: int) -> Dict[str, np.ndarray]:
    """Tile a scenario grid out to ``n`` rows (cycling the base grid) —
    the scale benches use this to sweep {256 .. 100k+} scenario counts
    with the paper's axes."""
    return {k: np.resize(np.asarray(v), n) for k, v in grid.items()}
