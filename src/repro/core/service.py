"""Service / workload model and paper-scale fleet synthesis.

A ``ServiceSpec`` is the UFA unit of management: a service-environment with a
tier, a failure class, a replica footprint and RPC dependencies.  The fleet
synthesizer reproduces the paper's shape: per-tier service counts (Table 3),
per-tier core budgets (Table 1) and tier-biased cross-tier call volumes
(Table 2), at a configurable scale factor so tests run in milliseconds and
benchmarks at paper scale.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Tuple

from repro.core.tiers import (BASELINE_CORES, DEFAULT_CLASS_OF_TIER,
                              SERVICES_PER_TIER, FailureClass, Tier)


@dataclasses.dataclass
class ServiceSpec:
    name: str
    tier: Tier
    failure_class: FailureClass
    cores_per_replica: float
    replicas: int                      # per region, steady state
    mem_per_core_gb: float = 4.0
    deps: List[str] = dataclasses.field(default_factory=list)
    # per-dependency behavior when the callee is unavailable:
    # True = fail-open (degrades gracefully), False = fail-close (UNSAFE)
    fail_open: Dict[str, bool] = dataclasses.field(default_factory=dict)
    # ML-workload annotation (examples / serving integration)
    arch_id: Optional[str] = None

    @property
    def cores(self) -> float:
        return self.cores_per_replica * self.replicas

    def unsafe_deps(self) -> List[str]:
        return [d for d in self.deps if not self.fail_open.get(d, True)]


# Table 2, collapsed to P(callee_tier | caller_tier) — used to synthesize a
# call graph whose cross-tier volume distribution matches the paper's.
_T = list(Tier)
_TABLE2 = {  # rows: caller, cols: callee (requests, arbitrary units)
    Tier.T0: [47.1, 940, 2300, 1820, 144, 100, 1770],
    Tier.T1: [10.7, 21800, 2240, 387, 6.07, 70.4, 18600],
    Tier.T2: [25.3, 2020, 663, 77.0, 0.0309, 1.17, 2700],
    Tier.T3: [7.95, 288, 119, 16.9, 0.192, 6.09, 1060],
    Tier.T4: [0.788, 11.5, 0.599, 0.228, 1.19, 0.0121, 22.1],
    Tier.T5: [0.29, 76.1, 0.266, 0.849, 0.0013, 4.52, 14.1],
    Tier.NP: [107, 1530, 471, 126, 12.8, 18.3, 3130],
}


def synthesize_fleet(scale: float = 0.02, seed: int = 0,
                     unsafe_fraction: float = 0.08,
                     mean_deps: float = 6.0,
                     demand_fraction: float = 0.25,
                     as_arrays: bool = False,
                     unsafe_chain_fraction: float = 0.0):
    """Builds a fleet whose tier structure matches Tables 1-3.

    scale: fraction of the paper's service counts (0.02 -> ~440 services).
    unsafe_fraction: fraction of *tier-inverted* edges that are fail-close
    (the defects UFA's tooling must find before oversubscription is safe).
    demand_fraction: Table 1 reports *global, 2x-provisioned allocations*;
    per-region steady-state demand is allocation/2 (strip the failover
    buffer) /2 (each region serves half the cities) = 0.25.
    as_arrays: return a struct-of-arrays ``FleetState`` instead of a dict
    of ServiceSpecs — the fast path that makes scale=1.0 (~22k services)
    synthesize in a fraction of a second (array-native RNG; same tier
    structure, different draw order than the object path).
    unsafe_chain_fraction: fraction of critical->critical edges that are
    fail-close *relay* edges — harmless alone, but they carry breakage
    multiple hops up the call graph (see ``repro.graph``); 0.0 keeps the
    seed's one-hop fleet shape and RNG stream.
    """
    if as_arrays:
        from repro.core.fleet_state import synthesize_fleet_state
        return synthesize_fleet_state(
            scale=scale, seed=seed, unsafe_fraction=unsafe_fraction,
            mean_deps=mean_deps, demand_fraction=demand_fraction,
            unsafe_chain_fraction=unsafe_chain_fraction)
    rng = random.Random(seed)
    fleet: Dict[str, ServiceSpec] = {}
    by_tier: Dict[Tier, List[str]] = {t: [] for t in _T}

    for tier in _T:
        n = max(2, int(round(SERVICES_PER_TIER[tier] * scale)))
        tier_cores = BASELINE_CORES[tier] * scale * demand_fraction
        # skewed footprint: few heavy services, many light (lognormal)
        weights = [rng.lognormvariate(0, 1.2) for _ in range(n)]
        wsum = sum(weights)
        for i in range(n):
            name = f"{tier.name.lower()}-svc-{i:04d}"
            cores = tier_cores * weights[i] / wsum
            options = [c for c in (0.5, 1.0, 2.0, 4.0) if c <= 2 * cores]
            cores_per_replica = rng.choice(options or [0.5])
            replicas = max(1, int(round(cores / cores_per_replica)))
            fleet[name] = ServiceSpec(
                name=name, tier=tier,
                failure_class=DEFAULT_CLASS_OF_TIER[tier],
                cores_per_replica=cores_per_replica, replicas=replicas)
            by_tier[tier].append(name)

    # dependency edges, callee tier ~ Table 2 row of the caller tier
    for name, spec in fleet.items():
        row = _TABLE2[spec.tier]
        total = sum(row)
        n_deps = max(0, int(rng.gauss(mean_deps, 2)))
        for _ in range(n_deps):
            r = rng.uniform(0, total)
            acc = 0.0
            callee_tier = _T[-1]
            for t, w in zip(_T, row):
                acc += w
                if r <= acc:
                    callee_tier = t
                    break
            candidates = by_tier[callee_tier]
            callee = rng.choice(candidates)
            if callee == name or callee in spec.deps:
                continue
            spec.deps.append(callee)
            # tier-inverted edges (critical -> preemptible) may be fail-close
            inverted = (spec.failure_class.survives_failover and
                        fleet[callee].failure_class.preemptible)
            # critical -> critical relay edges (multi-hop chains); the
            # nested guard keeps the RNG stream untouched when the chain
            # fraction is 0.0 (seed-pinned fleets stay identical)
            chain = (unsafe_chain_fraction > 0.0 and not inverted
                     and spec.failure_class.survives_failover
                     and fleet[callee].failure_class.survives_failover)
            if inverted and rng.random() < unsafe_fraction:
                spec.fail_open[callee] = False
            elif chain and rng.random() < unsafe_chain_fraction:
                spec.fail_open[callee] = False
            else:
                spec.fail_open[callee] = True
    return fleet


def apply_ufa_target_classes(fleet: Dict[str, ServiceSpec]) -> int:
    """Paper Table 5 end-state classification: the "Tier1+ Active-Migrate"
    rollout phase (455K cores returned) moved T1 off the dedicated 2x
    buffer.  Re-class T1 Always-On -> Active-Migrate (T0 keeps its 2x
    buffer); returns the number of re-classed services."""
    n = 0
    for s in fleet.values():
        if s.tier == Tier.T1 and s.failure_class == FailureClass.ALWAYS_ON:
            s.failure_class = FailureClass.ACTIVE_MIGRATE
            n += 1
    return n


def fleet_cores(fleet: Dict[str, ServiceSpec]) -> Dict[Tier, float]:
    out = {t: 0.0 for t in _T}
    for s in fleet.values():
        out[s.tier] += s.cores
    return out


def tier_inverted_edges(fleet: Dict[str, ServiceSpec]) -> List[Tuple[str, str]]:
    """(caller, callee) edges from surviving classes into preemptible ones."""
    out = []
    for s in fleet.values():
        if not s.failure_class.survives_failover:
            continue
        for d in s.deps:
            # callee may have been re-classed; look up live
            out.append((s.name, d))
    return out


def unsafe_edges(fleet: Dict[str, ServiceSpec]) -> List[Tuple[str, str]]:
    out = []
    for s in fleet.values():
        for d in s.unsafe_deps():
            out.append((s.name, d))
    return out
