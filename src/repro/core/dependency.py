"""Runtime fail-close dependency detection (paper §6, first layer).

Monitors RPC traffic and correlates caller errors with callee failures: if a
caller endpoint consistently returns errors when a callee endpoint fails,
the (caller -> callee) edge is classified fail-close.  Here the "live
traffic" is generated from the synthesized fleet's call graph — the planted
``fail_open=False`` edges are the ground truth the detector must find.

The paper's runtime layer sees 62 *trillion* RPCs a week, so this module is
array-native end to end — no per-RPC Python objects anywhere on the hot
path:

  * edges are integer IDs (``TraceEdges``) with Table-2 / cold-path
    sampling weights held as arrays;
  * trace generation is one vectorized draw per chunk (a jitted JAX kernel:
    alias-method categorical sampling over the edge distribution — the O(1)
    form of inverse-CDF sampling — plus Bernoulli failure/error draws),
    returning ``(edge_id, callee_failed, caller_errored)`` arrays instead
    of dataclass objects;
  * edge statistics are one fused scatter-add histogram per chunk — a
    2-bit outcome code per record, one ``(n_edges, 4)`` histogram giving
    all four per-edge count columns in a single pass, folded into int64
    accumulators so evidence streams through ``ingest_batch`` chunk by
    chunk without ever materializing the full record stream.  On CPU the
    histogram is a host ``np.bincount`` (measured ~7x faster than XLA's
    CPU scatter for the same segment-sum); on accelerator backends (or
    ``REPRO_UFA_KERNELS=1``) it is the device-resident Pallas kernel in
    ``repro.kernels.ufa.ingest`` — same dispatch rule as
    ``kernels.backend.default_interpret``;
  * ``detect()`` is a jitted threshold kernel over the count arrays.

The scalar reference implementation (one ``RPCRecord`` per RPC, a Python
dict per edge) lives in ``tests/scalar_reference.py`` and pins this
engine's statistics; the record-based API here (``RPCRecord``,
``generate_traces``, ``RuntimeFailCloseDetector.ingest``) is a thin compat
layer over the arrays.

Throughput on one CPU core: >20M records/s sampled + ingested, which is
what makes ``runtime_analysis`` at paper scale (~22k services, ~120k
edges, ~48M sampled RPCs at the default ~400 observations/edge) a
seconds-scale operation instead of an hours-scale one.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Dict, Iterable, List, Optional, Set, Tuple, Union

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.fleet_state import FleetState
from repro.core.service import ServiceSpec
from repro.kernels.backend import use_ufa_kernels as _use_ufa_kernels

# chunk size for the streaming sample->ingest loop: big enough to amortize
# kernel dispatch, small enough to keep transient arrays off the heap
_CHUNK_RECORDS = 4_000_000

# default trace mix (the scalar reference uses the same constants)
AMBIENT_CALLEE_FAILURE = 0.025
AMBIENT_CALLER_ERROR = 0.003
PROPAGATION_PROB = 0.92          # P(caller errors | callee failed, fail-close)
COLD_PATH_FRACTION = 0.18
COLD_TRAFFIC_FACTOR = 0.01       # cold paths carry ~100x less traffic


@dataclasses.dataclass(frozen=True)
class RPCRecord:
    caller: str
    callee: str
    callee_failed: bool
    caller_errored: bool


# ---------------------------------------------------------------------------
# edge universe
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TraceEdges:
    """Integer-ID edge universe the telemetry engine samples and
    aggregates over: edge ``i`` is ``edge_names[i]`` with sampling weight
    ``weight[i]`` (Table-2 cell volume split across the cell's edges, cold
    paths x0.01)."""
    edge_names: List[Tuple[str, str]]
    weight: np.ndarray            # float64 — relative RPC volume
    unsafe: np.ndarray            # bool — planted fail-close (ground truth)
    cold: np.ndarray              # bool — under-observed unsafe paths
    caller_tier: np.ndarray       # int8
    callee_tier: np.ndarray       # int8

    # lazily-built sampling state (alias tables + device arrays)
    _tables: Optional[tuple] = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def n(self) -> int:
        return len(self.edge_names)

    def unsafe_keys(self) -> Set[Tuple[str, str]]:
        return {self.edge_names[i] for i in np.flatnonzero(self.unsafe)}

    def cold_keys(self) -> Set[Tuple[str, str]]:
        return {self.edge_names[i] for i in np.flatnonzero(self.cold)}

    def sampling_tables(self):
        """(prob, alias, unsafe) device arrays for the sampling kernel."""
        if self._tables is None:
            p = self.weight / self.weight.sum()
            prob, alias = _alias_table(p)
            self._tables = (jnp.asarray(prob), jnp.asarray(alias),
                            jnp.asarray(self.unsafe))
        return self._tables


def _alias_table(p: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Vose alias tables for O(1) categorical sampling: draw bucket i
    uniformly, accept i with probability prob[i], else take alias[i]."""
    n = len(p)
    scaled = (np.asarray(p, np.float64) * n).tolist()
    prob = np.ones(n, np.float32)
    alias = np.arange(n, dtype=np.int32)
    small = [i for i in range(n) if scaled[i] < 1.0]
    large = [i for i in range(n) if scaled[i] >= 1.0]
    while small and large:
        s, l = small.pop(), large.pop()
        prob[s] = scaled[s]
        alias[s] = l
        scaled[l] -= 1.0 - scaled[s]
        (small if scaled[l] < 1.0 else large).append(l)
    # numerical leftovers keep probability 1 of themselves
    return prob, alias


def trace_edges(fleet: Union[Dict[str, ServiceSpec], FleetState],
                seed: int = 0,
                cold_path_fraction: float = COLD_PATH_FRACTION
                ) -> Optional[TraceEdges]:
    """Builds the edge universe from either fleet representation.  The
    weight rule matches the scalar reference exactly: an edge in Table-2
    cell (caller_tier, callee_tier) carries cell_volume / n_edges_in_cell;
    ``cold_path_fraction`` of unsafe edges carry ~100x less traffic — the
    defects runtime analysis tends to miss and static analysis catches.
    Returns None for an edge-free fleet."""
    from repro.core.service import _TABLE2
    from repro.core.tiers import Tier

    if isinstance(fleet, FleetState):
        assert fleet.edges is not None, "FleetState synthesized without edges"
        e = fleet.edges
        if e.n == 0:
            return None
        names = fleet.names
        edge_names = [(names[s], names[d])
                      for s, d in zip(e.src.tolist(), e.dst.tolist())]
        caller_tier = fleet.tier[e.src]
        callee_tier = fleet.tier[e.dst]
        unsafe = ~np.asarray(e.fail_open, bool)
        if e.weight is not None:
            weight = np.asarray(e.weight, np.float64)
        else:
            weight = None
    else:
        edge_names = []
        caller_tier_l: List[int] = []
        callee_tier_l: List[int] = []
        unsafe_l: List[bool] = []
        for s in fleet.values():
            ct = int(s.tier)
            for d in s.deps:
                edge_names.append((s.name, d))
                caller_tier_l.append(ct)
                callee_tier_l.append(int(fleet[d].tier))
                unsafe_l.append(not s.fail_open.get(d, True))
        if not edge_names:
            return None
        caller_tier = np.asarray(caller_tier_l, np.int8)
        callee_tier = np.asarray(callee_tier_l, np.int8)
        unsafe = np.asarray(unsafe_l, bool)
        weight = None

    if weight is None:
        tiers = list(Tier)
        vol = np.asarray([[_TABLE2[t][c] for c in range(len(tiers))]
                          for t in tiers], np.float64)
        cell = caller_tier.astype(np.int64) * len(tiers) + callee_tier
        counts = np.bincount(cell, minlength=len(tiers) ** 2)
        weight = vol.ravel()[cell] / np.maximum(counts[cell], 1)

    rng = np.random.default_rng(seed)
    cold = unsafe & (rng.random(len(unsafe)) < cold_path_fraction)
    weight = np.where(cold, weight * COLD_TRAFFIC_FACTOR, weight)
    return TraceEdges(edge_names=edge_names, weight=weight, unsafe=unsafe,
                      cold=cold, caller_tier=caller_tier,
                      callee_tier=callee_tier)


# ---------------------------------------------------------------------------
# vectorized trace sampling
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n",))
def _sample_kernel(key, n: int, prob, alias, unsafe,
                   t_fail, t_prop, p_err):
    """One vectorized draw of ``n`` RPCs: alias-method edge choice + the
    Bernoulli failure/error draws, from 4 u32 lanes per record.  The
    16-bit Bernoulli thresholds quantize the failure/propagation rates to
    1/65536 (<0.03% relative) — far below the sampling noise of any
    realistic stream."""
    r = jax.random.bits(key, (4, n), jnp.uint32)
    n_edges = prob.shape[0]
    u0 = (r[0] >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    i = jnp.minimum((u0 * n_edges).astype(jnp.int32), n_edges - 1)
    v = (r[1] >> 8).astype(jnp.float32) * (1.0 / (1 << 24))
    eid = jnp.where(v < prob[i], i, alias[i])
    failed = (r[2] & jnp.uint32(0xFFFF)).astype(jnp.int32) < t_fail
    prop = (r[2] >> 16).astype(jnp.int32) < t_prop
    amb = (r[3] >> 8).astype(jnp.float32) * (1.0 / (1 << 24)) < p_err
    errored = (unsafe[eid] & failed & prop) | amb
    return eid, failed, errored


def _trace_key(seed: int):
    # rbg bit generation is ~4x faster than threefry on CPU for wide draws
    return jax.random.key(seed, impl="rbg")


def _iter_trace_chunks(edges: TraceEdges, n_records: int, seed: int,
                       ambient_callee_failure: float,
                       ambient_caller_error: float,
                       propagation_prob: float,
                       chunk_records: int = _CHUNK_RECORDS):
    """Yields device ``(edge_id, callee_failed, caller_errored)`` chunks.
    The single source of the sampling stream: ``sample_traces`` and
    ``runtime_analysis`` both draw from here, so a seed always names the
    same stream regardless of which API consumes it."""
    prob, alias, unsafe = edges.sampling_tables()
    t_fail = int(ambient_callee_failure * 65536)
    t_prop = int(propagation_prob * 65536)
    n_chunks = max(1, -(-n_records // chunk_records))
    keys = jax.random.split(_trace_key(seed), n_chunks)
    done = 0
    for k in range(n_chunks):
        n = min(chunk_records, n_records - done)
        if n <= 0:
            break
        done += n
        yield _sample_kernel(keys[k], n, prob, alias, unsafe,
                             t_fail, t_prop, ambient_caller_error)


def sample_traces(edges: TraceEdges, n_records: int, seed: int = 0,
                  ambient_callee_failure: float = AMBIENT_CALLEE_FAILURE,
                  ambient_caller_error: float = AMBIENT_CALLER_ERROR,
                  propagation_prob: float = PROPAGATION_PROB,
                  ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Samples ``n_records`` RPCs over the edge universe in one vectorized
    draw (chunked above ``_CHUNK_RECORDS``); returns the
    ``(edge_id, callee_failed, caller_errored)`` arrays.  A fail-close
    edge propagates the callee's failure to the caller (minus flakiness);
    fail-open edges don't."""
    chunks = [tuple(np.asarray(a) for a in c)
              for c in _iter_trace_chunks(edges, n_records, seed,
                                          ambient_callee_failure,
                                          ambient_caller_error,
                                          propagation_prob)]
    if len(chunks) == 1:
        return chunks[0]
    return tuple(np.concatenate([c[i] for c in chunks]) for i in range(3))


def generate_traces(fleet: Dict[str, ServiceSpec], n_records: int = 200_000,
                    seed: int = 0,
                    ambient_callee_failure: float = AMBIENT_CALLEE_FAILURE,
                    ambient_caller_error: float = AMBIENT_CALLER_ERROR,
                    cold_path_fraction: float = COLD_PATH_FRACTION
                    ) -> Tuple[List[RPCRecord], Set[Tuple[str, str]]]:
    """Record-object compat layer over ``sample_traces`` (the seed API).
    Materializing one ``RPCRecord`` per RPC is exactly what the array
    engine exists to avoid — use ``sample_traces`` + ``ingest_batch`` for
    anything bigger than a spot check."""
    edges = trace_edges(fleet, seed=seed,
                        cold_path_fraction=cold_path_fraction)
    if edges is None:
        return [], set()
    eid, failed, errored = sample_traces(
        edges, n_records, seed=seed,
        ambient_callee_failure=ambient_callee_failure,
        ambient_caller_error=ambient_caller_error)
    names = edges.edge_names
    records = [RPCRecord(*names[e], f, er)
               for e, f, er in zip(eid.tolist(), failed.tolist(),
                                   errored.tolist())]
    return records, edges.cold_keys()


# ---------------------------------------------------------------------------
# streaming detector
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EdgeStats:
    calls: int = 0
    callee_failures: int = 0
    errors_given_failure: int = 0
    errors_given_ok: int = 0


@jax.jit
def _detect_kernel(calls, failures, err_fail, err_ok,
                   min_failures, threshold, lift):
    """Thresholding over the per-edge count arrays: enough failure
    evidence, error probability under failure above the propagation
    threshold, and a lift over the ambient error rate."""
    p_fail = err_fail / jnp.maximum(failures, 1.0)
    ok_calls = jnp.maximum(calls - failures, 1.0)
    p_ok = err_ok / ok_calls
    return ((failures >= min_failures)
            & (p_fail >= threshold)
            & (p_fail >= lift * jnp.maximum(p_ok, 1e-4)))


class RuntimeFailCloseDetector:
    """Streaming correlation of caller errors with callee failures.

    Evidence lives in four per-edge int64 count arrays; ``ingest_batch``
    scatter-adds one ``(edge_id, callee_failed, caller_errored)`` chunk
    into them, so arbitrarily long streams accumulate without ever being
    materialized.  Bind the detector to a ``TraceEdges`` universe for the
    array-native path; the record-based ``ingest`` interns (caller,
    callee) pairs on the fly and routes through the same accumulators.
    """

    def __init__(self, min_failures: int = 5,
                 propagation_threshold: float = 0.5,
                 lift_threshold: float = 5.0,
                 edges: Optional[TraceEdges] = None):
        self.min_failures = min_failures
        self.propagation_threshold = propagation_threshold
        self.lift_threshold = lift_threshold
        self.edges = edges
        if edges is not None:
            self._names: List[Tuple[str, str]] = edges.edge_names
            self._ids: Optional[Dict[Tuple[str, str], int]] = None
            n = edges.n
        else:
            self._names = []
            self._ids = {}
            n = 0
        self.calls = np.zeros(n, np.int64)
        self.callee_failures = np.zeros(n, np.int64)
        self.errors_given_failure = np.zeros(n, np.int64)
        self.errors_given_ok = np.zeros(n, np.int64)

    # ------------------------------------------------------------------
    @property
    def n_edges(self) -> int:
        return len(self.calls)

    @property
    def n_records(self) -> int:
        return int(self.calls.sum())

    def _grow(self, n: int):
        pad = n - len(self.calls)
        if pad > 0:
            for attr in ("calls", "callee_failures", "errors_given_failure",
                         "errors_given_ok"):
                setattr(self, attr,
                        np.concatenate([getattr(self, attr),
                                        np.zeros(pad, np.int64)]))

    def _edge_id(self, caller: str, callee: str) -> int:
        if self._ids is None:
            # bound mode: lazy reverse index over the universe (duplicate
            # (caller, callee) pairs map to their first edge id)
            ids: Dict[Tuple[str, str], int] = {}
            for i, key in enumerate(self._names):
                ids.setdefault(key, i)
            self._ids = ids
        i = self._ids.get((caller, callee))
        if i is None:
            if self.edges is not None:
                raise KeyError(f"unknown edge {(caller, callee)} for a "
                               "detector bound to a TraceEdges universe")
            i = len(self._names)
            self._ids[(caller, callee)] = i
            self._names.append((caller, callee))
        return i

    # ------------------------------------------------------------------
    def ingest_batch(self, edge_id: np.ndarray, callee_failed: np.ndarray,
                     caller_errored: np.ndarray):
        """Scatter-add one chunk of the stream into the per-edge counts
        (the segment-sum reduction of the array engine), fused to a
        single pass: each record gets the 2-bit outcome code
        ``2 * callee_failed + caller_errored`` and one histogram of
        ``edge_id * 4 + code`` yields all four detector columns at once
        (vs the historical four masks + four ``bincount`` sweeps).

        Backend dispatch (``repro.kernels.backend.use_ufa_kernels``): on
        accelerators the chunk stays device-resident through the Pallas
        scatter-add histogram kernel and only the (n_edges, 4) int32
        block crosses to the host; on CPU the fused ``np.bincount`` is
        the measured-faster fallback.  Both fold into the same int64
        accumulators."""
        n = self.n_edges
        # one enabled() branch per multi-million-record chunk — free off
        meter = obs.enabled()
        t0 = time.perf_counter() if meter else 0.0
        if n and _use_ufa_kernels():
            backend = "pallas"
            from repro.kernels.ufa.ingest import ingest_hist
            counts = np.asarray(
                ingest_hist(jnp.asarray(edge_id), jnp.asarray(callee_failed),
                            jnp.asarray(caller_errored), n), np.int64)
        else:
            backend = "numpy"
            eid = np.asarray(edge_id)
            code = ((np.asarray(callee_failed, np.uint8) << 1)
                    | np.asarray(caller_errored, np.uint8))
            key_t = np.int64 if 4 * n >= (1 << 31) else np.int32
            counts = np.bincount(eid.astype(key_t) * 4 + code,
                                 minlength=4 * n).reshape(-1, 4)
        self.calls += counts.sum(axis=1)
        self.callee_failures += counts[:, 2] + counts[:, 3]
        self.errors_given_failure += counts[:, 3]
        self.errors_given_ok += counts[:, 1]
        # int64 headroom guard: far before wraparound could corrupt the
        # evidence (2^62 calls on one edge is ~70k years of the paper's
        # 62T RPCs/week), fail loudly instead
        assert int(self.calls.max(initial=0)) < (1 << 62), \
            "per-edge call count approaching int64 overflow"
        if meter:
            dt = time.perf_counter() - t0
            n_rec = len(np.asarray(edge_id))
            obs.inc("ufa_ingest_records_total", n_rec, backend=backend)
            obs.inc("ufa_ingest_batches_total", backend=backend)
            if dt > 0:
                obs.set_gauge("ufa_ingest_records_per_s", n_rec / dt)

    def ingest(self, records: Iterable[RPCRecord]):
        """Record-object compat: intern edges, then batch-ingest."""
        recs = list(records)
        if not recs:
            return
        eid = np.asarray([self._edge_id(r.caller, r.callee) for r in recs],
                         np.int64)
        self._grow(len(self._names))
        self.ingest_batch(eid,
                          np.asarray([r.callee_failed for r in recs]),
                          np.asarray([r.caller_errored for r in recs]))

    # ------------------------------------------------------------------
    @property
    def stats(self) -> Dict[Tuple[str, str], EdgeStats]:
        """Per-edge stats view (compat; materialized on demand)."""
        out: Dict[Tuple[str, str], EdgeStats] = {}
        for i in np.flatnonzero(self.calls > 0):
            out[self._names[i]] = EdgeStats(
                calls=int(self.calls[i]),
                callee_failures=int(self.callee_failures[i]),
                errors_given_failure=int(self.errors_given_failure[i]),
                errors_given_ok=int(self.errors_given_ok[i]))
        return out

    def detect_mask(self) -> np.ndarray:
        """Jitted threshold kernel over the count arrays -> edge mask."""
        if self.n_edges == 0:
            return np.zeros(0, bool)
        mask = _detect_kernel(
            jnp.asarray(self.calls.astype(np.float32)),
            jnp.asarray(self.callee_failures.astype(np.float32)),
            jnp.asarray(self.errors_given_failure.astype(np.float32)),
            jnp.asarray(self.errors_given_ok.astype(np.float32)),
            self.min_failures, self.propagation_threshold,
            self.lift_threshold)
        mask = np.asarray(mask)
        if obs.enabled():
            obs.inc("ufa_detect_runs_total")
            obs.set_gauge("ufa_detect_edges_flagged",
                          int(np.count_nonzero(mask)))
        return mask

    def detect(self) -> Set[Tuple[str, str]]:
        mask = self.detect_mask()
        found: Set[Tuple[str, str]] = set()
        for i in np.flatnonzero(mask):
            found.add(self._names[i])
        return found


# ---------------------------------------------------------------------------
# end-to-end runtime analysis
# ---------------------------------------------------------------------------


def runtime_analysis(fleet: Union[Dict[str, ServiceSpec], FleetState],
                     n_records: Optional[int] = None,
                     seed: int = 0,
                     chunk_records: int = _CHUNK_RECORDS
                     ) -> Dict[str, object]:
    """n_records defaults to ~400 observations per edge — the paper's
    runtime layer sees trillions of RPCs/day, so evidence per hot edge is
    plentiful while cold paths (~100x less traffic) stay under-observed.

    The stream is sampled and ingested in chunks (sample kernel on device,
    scatter-add reduction on host, overlapped), so paper scale (~48M
    records over ~120k edges) runs in a few seconds without ever holding
    the stream in memory.  Accepts either fleet representation; with a
    ``FleetState`` the detection graph is built straight from the edge
    mask (no per-edge Python objects anywhere).
    """
    from repro.graph import CallGraph

    edges = trace_edges(fleet, seed=seed)
    is_arrays = isinstance(fleet, FleetState)
    if edges is None:
        # edge-free fleet: same contract, empty evidence and a 0-unsafe
        # detection graph (when a graph can be built at all)
        if is_arrays:
            graph = (CallGraph.from_fleet_state(fleet)
                     if fleet.edges is not None else None)
        else:
            graph = CallGraph.from_detections(fleet, set())
        return {"found": set(), "graph": graph, "truth": set(),
                "cold_paths": set(), "true_positives": 0,
                "false_positives": 0, "missed": 0, "missed_cold": 0,
                "precision": 0.0, "recall": 0.0, "n_records": 0,
                "gen_ingest_s": 0.0, "records_per_s": 0.0,
                "detector": RuntimeFailCloseDetector()}
    if n_records is None:
        n_records = 400 * max(1, edges.n)

    det = RuntimeFailCloseDetector(edges=edges)
    t0 = time.perf_counter()
    pending = None            # overlap device sampling with host scatter-add
    for chunk in _iter_trace_chunks(edges, n_records, seed,
                                    AMBIENT_CALLEE_FAILURE,
                                    AMBIENT_CALLER_ERROR, PROPAGATION_PROB,
                                    chunk_records):
        if pending is not None:
            det.ingest_batch(*pending)
        pending = chunk
    if pending is not None:
        det.ingest_batch(*pending)
    gen_ingest_s = time.perf_counter() - t0

    mask = det.detect_mask()
    found = {edges.edge_names[i] for i in np.flatnonzero(mask)}
    truth = edges.unsafe_keys()
    cold = edges.cold_keys()
    tp = found & truth
    # the detections ARE the graph: certification/planning downstream run
    # on what this layer found, not on the planted ground truth
    if is_arrays:
        graph = CallGraph.from_detection_mask(fleet, mask)
    else:
        graph = CallGraph.from_detections(fleet, found)
    return {
        "found": found,
        "graph": graph,
        "truth": truth,
        "cold_paths": cold,
        "true_positives": len(tp),
        "false_positives": len(found - truth),
        "missed": len(truth - found),
        "missed_cold": len((truth - found) & cold),
        "precision": len(tp) / max(1, len(found)),
        "recall": len(tp) / max(1, len(truth)),
        "n_records": n_records,
        "gen_ingest_s": gen_ingest_s,
        "records_per_s": n_records / max(1e-9, gen_ingest_s),
        "detector": det,
    }
