"""Runtime fail-close dependency detection (paper §6, first layer).

Monitors RPC traffic and correlates caller errors with callee failures: if a
caller endpoint consistently returns errors when a callee endpoint fails,
the (caller -> callee) edge is classified fail-close.  Here the "live
traffic" is generated from the synthesized fleet's call graph — the planted
``fail_open=False`` edges are the ground truth the detector must find.
"""

from __future__ import annotations

import dataclasses
import random
from collections import defaultdict
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.core.service import ServiceSpec


@dataclasses.dataclass(frozen=True)
class RPCRecord:
    caller: str
    callee: str
    callee_failed: bool
    caller_errored: bool


def generate_traces(fleet: Dict[str, ServiceSpec], n_records: int = 200_000,
                    seed: int = 0, ambient_callee_failure: float = 0.025,
                    ambient_caller_error: float = 0.003,
                    cold_path_fraction: float = 0.18
                    ) -> Tuple[List[RPCRecord], Set[Tuple[str, str]]]:
    """Samples RPCs over the fleet's edges.  A fail-close edge propagates the
    callee's failure to the caller (minus flakiness); fail-open edges don't.
    ``cold_path_fraction`` of unsafe edges carry ~100x less traffic — these
    are the defects runtime analysis tends to miss and static analysis
    catches (paper: the static layer "detected defects missed by runtime
    analysis in less commonly executed paths").
    """
    from repro.core.service import _TABLE2
    rng = random.Random(seed)
    edges = [(s.name, d) for s in fleet.values() for d in s.deps]
    if not edges:
        return [], set()
    unsafe = {(s.name, d) for s in fleet.values() for d in s.unsafe_deps()}
    cold: Set[Tuple[str, str]] = {
        e for e in unsafe if rng.random() < cold_path_fraction}
    # per-edge traffic volume follows the Table 2 cross-tier matrix: an edge
    # in cell (caller_tier, callee_tier) carries cell_volume / n_edges_in_cell
    tier_of = {n: s.tier for n, s in fleet.items()}
    cell_edges: Dict[Tuple[int, int], int] = {}
    for caller, callee in edges:
        cell = (int(tier_of[caller]), int(tier_of[callee]))
        cell_edges[cell] = cell_edges.get(cell, 0) + 1
    weights = []
    for e in edges:
        caller, callee = e
        cell = (int(tier_of[caller]), int(tier_of[callee]))
        vol = _TABLE2[tier_of[caller]][int(tier_of[callee])]
        w = vol / cell_edges[cell]
        weights.append(w * (0.01 if e in cold else 1.0))
    tot = sum(weights)
    cum = []
    acc = 0.0
    for w in weights:
        acc += w
        cum.append(acc)

    records: List[RPCRecord] = []
    for _ in range(n_records):
        r = rng.uniform(0, tot)
        lo, hi = 0, len(cum) - 1
        while lo < hi:
            mid = (lo + hi) // 2
            if cum[mid] < r:
                lo = mid + 1
            else:
                hi = mid
        caller, callee = edges[lo]
        callee_failed = rng.random() < ambient_callee_failure
        if (caller, callee) in unsafe:
            caller_errored = (callee_failed and rng.random() < 0.92) or \
                rng.random() < ambient_caller_error
        else:
            caller_errored = rng.random() < ambient_caller_error
        records.append(RPCRecord(caller, callee, callee_failed, caller_errored))
    return records, cold


@dataclasses.dataclass
class EdgeStats:
    calls: int = 0
    callee_failures: int = 0
    errors_given_failure: int = 0
    errors_given_ok: int = 0


class RuntimeFailCloseDetector:
    """Streaming correlation of caller errors with callee failures."""

    def __init__(self, min_failures: int = 5, propagation_threshold: float = 0.5,
                 lift_threshold: float = 5.0):
        self.stats: Dict[Tuple[str, str], EdgeStats] = defaultdict(EdgeStats)
        self.min_failures = min_failures
        self.propagation_threshold = propagation_threshold
        self.lift_threshold = lift_threshold

    def ingest(self, records: Iterable[RPCRecord]):
        for r in records:
            st = self.stats[(r.caller, r.callee)]
            st.calls += 1
            if r.callee_failed:
                st.callee_failures += 1
                if r.caller_errored:
                    st.errors_given_failure += 1
            elif r.caller_errored:
                st.errors_given_ok += 1

    def detect(self) -> Set[Tuple[str, str]]:
        out: Set[Tuple[str, str]] = set()
        for edge, st in self.stats.items():
            if st.callee_failures < self.min_failures:
                continue  # not enough failure evidence on this edge
            p_fail = st.errors_given_failure / st.callee_failures
            ok_calls = max(1, st.calls - st.callee_failures)
            p_ok = st.errors_given_ok / ok_calls
            if p_fail >= self.propagation_threshold and \
                    p_fail >= self.lift_threshold * max(p_ok, 1e-4):
                out.add(edge)
        return out


def runtime_analysis(fleet: Dict[str, ServiceSpec],
                     n_records: Optional[int] = None,
                     seed: int = 0) -> Dict[str, object]:
    """n_records defaults to ~400 observations per edge — the paper's
    runtime layer sees trillions of RPCs/day, so evidence per hot edge is
    plentiful while cold paths (~100x less traffic) stay under-observed."""
    n_edges = sum(len(s.deps) for s in fleet.values())
    if n_records is None:
        n_records = 400 * max(1, n_edges)
    records, cold = generate_traces(fleet, n_records, seed)
    det = RuntimeFailCloseDetector()
    det.ingest(records)
    found = det.detect()
    truth = {(s.name, d) for s in fleet.values() for d in s.unsafe_deps()}
    tp = found & truth
    # the detections ARE the graph: certification/planning downstream run
    # on what this layer found, not on the planted ground truth
    from repro.graph import CallGraph
    return {
        "found": found,
        "graph": CallGraph.from_detections(fleet, found),
        "truth": truth,
        "cold_paths": cold,
        "true_positives": len(tp),
        "false_positives": len(found - truth),
        "missed": len(truth - found),
        "missed_cold": len((truth - found) & cold),
        "precision": len(tp) / max(1, len(found)),
        "recall": len(tp) / max(1, len(truth)),
    }
