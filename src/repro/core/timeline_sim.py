"""Array-native discrete-time failover timeline simulator.

The paper's headline claims are *temporal* — full-peak failovers preempt
Restore-Later services and restore them under differentiated SLAs while
the fleet sustains 99.97% availability — but the vmapped sweep in
``scenarios.py`` scores each scenario with a closed-form outcome, and the
event-driven ``omg.Orchestrator`` produces a real timeline only one
scenario at a time.  This module closes that gap: a ``jax.lax.scan`` over
time steps evolves the per-tier live cores, placed-pool accounting,
burst-conversion ramp, Always-On upscale, Active-Migrate migration waves,
Restore-Later eviction and the delayed cloud restore (honoring
``CloudPool.provision_time`` semantics: a cloud batch activates only after
``grant / provision_rate`` seconds), emitting availability / utilization /
SLA traces per step.  ``vmap`` over the existing ``scenario_grid`` runs
thousands of temporal drills per second — scenario diversity the scalar
orchestrator cannot reach (Basiri et al.: dependability claims must be
validated by executing failure timelines against an SLA model).

Equivalence contract (pinned by ``tests/test_timeline_sim.py``):

  * the kernel's per-step traces match the scalar reference stepper in
    ``tests/scalar_reference.py`` (same spec, independent Python-loop
    implementation) to float32 precision, env counts and verdicts exactly;
  * on a config extracted from an ``Orchestrator`` (via
    ``Orchestrator.timeline_config()``) the traces match the
    orchestrator's ``Timeline`` snapshots at the snapshot times, for
    fleets where the aggregate view is exact (single migration/restore
    waves, no pool overflow) — which covers every small-fleet test mix.

Aggregation semantics (documented deviations from the event loop):

  * multi-wave migrations/restores move ``total / n_waves`` cores per
    wave (the orchestrator first-fits concrete SEs in array order);
  * all cloud spill is treated as one provisioning batch that activates
    at ``first_spill_wave + grant / rate`` (the orchestrator provisions
    per wave; exact when the spill is confined to one wave);
  * a cloud-quota shortfall leaves the remainder down for the whole
    horizon (``rl_done_s = inf``) — the seed orchestrator stops retrying
    but still stamps a completion time.

All time comparisons use a ``EPS_T`` = 1e-3 s tolerance so float32 event
arithmetic cannot miss a boundary the float64 event loop hits exactly.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.fleet_state import (AM, AO, POOL_OVERCOMMIT, POOL_STATELESS,
                                    RL, TM)
from repro.core.tiers import (QOS_EVICT_UTILIZATION, RTO_SECONDS,
                              FailureClass, Tier)

EPS_T = 1e-3                    # time-comparison tolerance (seconds)
N_TIERS = len(Tier)
N_CLASSES = 4
BASE_AVAILABILITY = 0.9997      # ambient (paper Fig 8)
AVAIL_SLA_TOL = 5e-5            # integral may dip this far below ambient
RESTORE_THRESH = 0.999          # tier counts as restored above this frac

_DEMAND_CRIT = 0.62             # demand per live core, critical classes
_DEMAND_PRE = 0.35              # demand per live core, preemptible classes

# ---------------------------------------------------------------------------
# Soft relaxation (opt-in): sigmoid-smoothed SLA indicators
# ---------------------------------------------------------------------------
#
# The capacity optimizer (repro.optim.capacity) differentiates through
# the fused pipeline, but the SLA verdicts are hard booleans (step
# functions — zero gradient).  Passing a temperature ``tau`` to
# ``timeline_verdicts`` / ``scenario_outcome`` replaces every hard
# comparison with a sigmoid of the *signed margin*, in units of a
# per-quantity scale times tau, so the verdicts become floats in (0, 1)
# that tend to the exact booleans as tau -> 0 (an annealing schedule
# recovers the hard model; pinned by tests/test_capacity_opt.py).
# ``tau=None`` (the default) traces the ORIGINAL ops — a literal no-op,
# so the bit-exactness contract of the fused engine is untouched.

SOFT_TIME_SCALE = 60.0          # seconds: deadline margins
SOFT_FRAC_SCALE = 0.02          # utilization / fraction margins
SOFT_AVAIL_SCALE = 2.0e-5       # availability-integral margins
SOFT_CORES_FRAC = 0.01          # cores margins, as a fraction of fleet total
SOFT_DEP_SCALE = 1e-6           # broken-critical fractions (quantized at
                                # 1/n_crit, so the pass threshold sits at
                                # 1e-7 — below one broken service)


def soft_ge(x, y, scale, tau):
    """Soft indicator of ``x >= y``: sigmoid of the margin in units of
    ``scale * tau``.  Tends to the hard boolean as ``tau -> 0`` (the
    razor's-edge case ``x == y`` saturates to 0.5 instead of True —
    measure zero for the continuous margins this is applied to)."""
    return jax.nn.sigmoid((x - y) / (scale * tau))


def _cores_scale(c: Dict):
    """Cores-margin scale for one fleet: 1% of the class total."""
    return SOFT_CORES_FRAC * (c["ao"] + c["am"] + c["rl"] + c["tm"])


# ---------------------------------------------------------------------------
# Config extraction — the scan kernel and the Orchestrator consume
# identical inputs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TimelineConfig:
    """Aggregate fleet/region state the timeline kernel simulates over.

    Produced by ``extract_timeline_config`` from a steady-state
    ``Orchestrator`` (post-placement), so pool occupancy — including the
    overcommit-spill split the orchestrator tracks per SE — is identical
    between the event loop and the scan kernel."""
    # class aggregates (spec cores; live == spec in steady state)
    ao_cores: float
    am_cores: float
    rl_cores: float
    tm_cores: float
    am_envs: float
    rl_envs: float
    tm_envs: float
    # (n_tiers, n_classes) spec cores — per-tier live-core traces
    tier_class_cores: np.ndarray
    # steady pools, post-placement
    stateless_cap: float
    overcommit_cap: float
    steady_used0: float
    overcommit_used0: float
    oc_preempt_cores: float     # preemptible cores accounted in overcommit
    sl_preempt_cores: float     # preemptible overflow spilled to stateless
    am_stateless_cores: float   # AM cores accounted in the stateless pool
    # batch -> burst conversion
    burst_cap_full: float
    spawn_rate: float           # cores/s once conversion starts
    # cloud (§4.6)
    cloud_quota: float
    cloud_rate: float
    phys_cores: float
    # orchestrator tunables (single-sourced from Orchestrator at extract)
    kill_s: float = 5.0
    preheat_s: float = 270.0
    mbb_wave_s: float = 45.0
    mbb_parallelism: float = 2000.0
    rl_wave_s: float = 120.0
    rl_rto_s: float = float(RTO_SECONDS[FailureClass.RESTORE_LATER])

    def tier_totals(self) -> np.ndarray:
        """Per-tier spec cores summed over failure classes — the
        denominator turning the kernel's ``tier_live`` traces into live
        fractions (``serving.failover`` actuates replicas from these)."""
        return np.asarray(self.tier_class_cores, np.float64).sum(axis=1)

    def as_consts(self) -> Dict[str, jnp.ndarray]:
        """float32 device constants for the jitted kernel."""
        f = lambda v: jnp.asarray(v, jnp.float32)
        return {
            "ao": f(self.ao_cores), "am": f(self.am_cores),
            "rl": f(self.rl_cores), "tm": f(self.tm_cores),
            "am_envs": f(self.am_envs), "rl_envs": f(self.rl_envs),
            "tm_envs": f(self.tm_envs),
            "tier_class": f(self.tier_class_cores),
            "stateless_cap": f(self.stateless_cap),
            "overcommit_cap": f(self.overcommit_cap),
            "steady_used0": f(self.steady_used0),
            "overcommit_used0": f(self.overcommit_used0),
            "oc_preempt_cores": f(self.oc_preempt_cores),
            "sl_preempt_cores": f(self.sl_preempt_cores),
            "am_stateless_cores": f(self.am_stateless_cores),
            "burst_cap_full": f(self.burst_cap_full),
            "spawn_rate": f(self.spawn_rate),
            "cloud_quota": f(self.cloud_quota),
            "cloud_rate": f(self.cloud_rate),
            "phys_cores": f(self.phys_cores),
            "kill_s": f(self.kill_s), "preheat_s": f(self.preheat_s),
            "mbb_wave_s": f(self.mbb_wave_s),
            "mbb_parallelism": f(self.mbb_parallelism),
            "rl_wave_s": f(self.rl_wave_s), "rl_rto_s": f(self.rl_rto_s),
        }


def extract_timeline_config(orch) -> TimelineConfig:
    """Read a steady-state ``Orchestrator`` into a ``TimelineConfig``.

    Must run before ``failover()``: it captures the post-placement,
    pre-eviction pool occupancy the event loop starts from."""
    fs, region = orch.fs, orch.region
    cores = fs.spec_cores
    cls = [float(cores[fs.fclass == c].sum()) for c in (AO, AM, RL, TM)]
    tier_class = np.zeros((N_TIERS, N_CLASSES), np.float64)
    for t in range(N_TIERS):
        tmask = fs.tier == t
        for c in range(N_CLASSES):
            tier_class[t, c] = float(cores[tmask & (fs.fclass == c)].sum())
    pre = fs.preemptible
    return TimelineConfig(
        ao_cores=cls[0], am_cores=cls[1], rl_cores=cls[2], tm_cores=cls[3],
        am_envs=float(np.count_nonzero(fs.fclass == AM)),
        rl_envs=float(np.count_nonzero(fs.fclass == RL)),
        tm_envs=float(np.count_nonzero(fs.fclass == TM)),
        tier_class_cores=tier_class,
        stateless_cap=float(region.steady.stateless.capacity),
        overcommit_cap=float(region.steady.overcommit.capacity),
        steady_used0=float(region.steady.stateless.used),
        overcommit_used0=float(region.steady.overcommit.used),
        oc_preempt_cores=float(
            cores[pre & (fs.pool == POOL_OVERCOMMIT)].sum()),
        sl_preempt_cores=float(
            cores[pre & (fs.pool == POOL_STATELESS)].sum()),
        am_stateless_cores=float(
            cores[(fs.fclass == AM) & (fs.pool == POOL_STATELESS)].sum()),
        burst_cap_full=float(region.batch.convertible_cores),
        spawn_rate=float(orch.SPAWN_CORES_PER_HOST_S * region.batch.n_hosts),
        cloud_quota=float(region.cloud.quota_cores),
        cloud_rate=float(region.cloud.provision_rate_cores_per_s),
        phys_cores=float(region.steady.physical_cores),
        kill_s=float(orch.KILL_LATENCY_S),
        preheat_s=float(orch.BATCH_EVICT_S + orch.PREFETCH_S),
        mbb_wave_s=float(orch.MBB_WAVE_S),
        mbb_parallelism=float(orch.MBB_PARALLELISM),
        rl_wave_s=float(orch.RL_RESTORE_WAVE_S),
    )


def config_for_fleet(fleet, region=None) -> TimelineConfig:
    """Build a ``TimelineConfig`` for a fleet (dict of ``ServiceSpec`` or a
    ``FleetState``): sizes a fresh region (unless given), performs the
    orchestrator's steady-state placement, extracts.

    Side-effect free for the caller: placement runs against a *copy* of
    the region (pool counters zeroed first, so a region that already had
    an orchestrator placed into it is not double-counted) and a
    ``FleetState``'s ``pool`` column is restored afterwards.  To extract
    from live orchestrator state instead, use
    ``Orchestrator.timeline_config()``."""
    import copy

    from repro.core.capacity import RegionCapacity
    from repro.core.omg import Orchestrator
    if region is None:
        region = RegionCapacity.for_fleet("timeline", fleet)
    else:
        region = copy.deepcopy(region)
        region.steady.stateless.used = 0.0
        region.steady.overcommit.used = 0.0
    pool_save = fleet.pool.copy() if hasattr(fleet, "pool") else None
    try:
        return extract_timeline_config(Orchestrator(fleet, region))
    finally:
        if pool_save is not None:
            fleet.pool[:] = pool_save


# ---------------------------------------------------------------------------
# Scenario parameters
# ---------------------------------------------------------------------------

PARAM_KEYS = ("traffic_mult", "burst_delay_s", "burst_availability",
              "cloud_quota_frac", "overcommit_factor", "evict_fraction",
              "dep_broken_frac",
              # chaos fault families (repro.chaos): partial-region
              # degradation + the cascading dependency-storm schedule.
              # All are exact no-ops at the defaults below, so legacy
              # grids keep bit-identical verdicts.
              "region_degradation", "storm_refrac", "storm_t0_s",
              "storm_period_s", "storm_recover_s", "storm_broken_frac",
              # eviction-order knobs (repro.optim.capacity): per-class
              # shifts of the evicted fraction — RL is evicted at
              # ``evict_fraction + rl_evict_delta``, TM at ``+
              # tm_evict_delta``.  Budget-conserving orders keep
              # rl*d_rl + tm*d_tm == 0 (same total cores evicted, a
              # different mix).  Additive forms, exact no-ops at 0.
              "rl_evict_delta", "tm_evict_delta")


def default_scenario(**overrides) -> Dict[str, float]:
    """The paper's operating point (2x traffic, full burst, full quota).

    The chaos knobs default to "no fault": zero capacity degradation and
    a storm with zero re-darkening amplitude (``storm_refrac``) — the
    finite schedule constants are inert until the amplitude is raised.
    The eviction-order deltas default to 0: pro-rata class eviction."""
    p = {"traffic_mult": 2.0, "burst_delay_s": 270.0,
         "burst_availability": 1.0, "cloud_quota_frac": 1.0,
         "overcommit_factor": 1.5, "evict_fraction": 1.0,
         "dep_broken_frac": 0.0,
         "region_degradation": 0.0, "storm_refrac": 0.0,
         "storm_t0_s": 1800.0, "storm_period_s": 1800.0,
         "storm_recover_s": 600.0, "storm_broken_frac": 0.0,
         "rl_evict_delta": 0.0, "tm_evict_delta": 0.0}
    p.update(overrides)
    return p


def validate_grid(grid) -> int:
    """Validate a scenario grid (dict of parallel axis columns) and
    return the scenario count.

    Raises a labeled ``ValueError`` on the two silent-failure modes that
    used to pass straight through the sweep paths: an *unknown* key (a
    typo like ``trafic_mult`` swept nothing — every real axis fell back
    to its default and the run returned plausible-looking verdicts for
    the wrong ensemble) and an *empty* grid (crashed deep inside the
    engine's bucket padding with an obscure reshape error).  Ragged axis
    lengths are rejected for the same reason."""
    if not grid:
        raise ValueError("empty scenario grid: no axes given (pass at "
                         "least one PARAM_KEYS column, or None for the "
                         "default grid)")
    unknown = sorted(set(grid) - set(PARAM_KEYS))
    if unknown:
        raise ValueError(
            f"unknown scenario grid key(s) {unknown}: a misspelled axis "
            "would silently sweep nothing (defaults would be used "
            f"instead); valid axes: {sorted(PARAM_KEYS)}")
    n = len(next(iter(grid.values())))
    if n == 0:
        raise ValueError("empty scenario grid: zero-length scenario axes")
    ragged = {k: len(v) for k, v in grid.items() if len(v) != n}
    if ragged:
        raise ValueError(f"ragged scenario grid: axis lengths {ragged} "
                         f"differ from {n}")
    return n


def default_ts(horizon_s: float = 7200.0, n_steps: int = 240) -> np.ndarray:
    """Uniform step grid from 0: long enough to see the RL RTO expire."""
    return np.arange(n_steps, dtype=np.float64) * (horizon_s / n_steps)


# ---------------------------------------------------------------------------
# The kernel: schedule arithmetic + per-step state + lax.scan
# ---------------------------------------------------------------------------


def _schedule(c: Dict, p: Dict, tau=None) -> Dict:
    """Scenario-level event times and capacity splits (scalar, traceable).

    ``tau`` (opt-in): soft-relaxation temperature — the hard feasibility
    booleans (``ao_ok``) become sigmoid indicators and the infinite
    ``rl_done_t`` sentinel on a cloud-quota shortfall becomes a smooth
    finite overrun, so gradients flow; ``None`` traces the original
    ops."""
    mult = p["traffic_mult"]
    evict = p["evict_fraction"]

    # partial-region degradation: a fraction of the surviving region's
    # hosts (stateless capacity and physical cores alike) is lost for the
    # whole horizon.  ``x * (1 - 0)`` is exact in float32, so the default
    # is a bitwise no-op.
    cap_scale = 1.0 - p.get("region_degradation", 0.0)
    stateless_eff = c["stateless_cap"] * cap_scale

    burst_cap = c["burst_cap_full"] * p["burst_availability"]
    ramp_total = burst_cap / jnp.maximum(c["spawn_rate"], 1e-9)
    tick_s = ramp_total / 10.0
    burst_full_t = p["burst_delay_s"] + ramp_total

    n_am_waves = jnp.ceil(c["am_envs"] / c["mbb_parallelism"])
    am_done_t = burst_full_t + n_am_waves * c["mbb_wave_s"]
    am_in_burst = jnp.minimum(c["am"], burst_cap)

    ao_need = c["ao"] * (mult - 1.0)
    # steady free once the preemptible spill is evicted and AM released
    am_release_frac = c["am_stateless_cores"] / jnp.maximum(c["am"], 1e-9)
    am_released = am_in_burst * am_release_frac
    free_at_am_done = (stateless_eff
                       - (c["steady_used0"] - evict * c["sl_preempt_cores"]
                          - am_released))
    if tau is None:
        ao_ok = ao_need <= free_at_am_done + 1e-6
    else:
        ao_ok = soft_ge(free_at_am_done + 1e-6, ao_need, _cores_scale(c),
                        tau)
    ao_short = jnp.maximum(0.0, ao_need - free_at_am_done)

    # eviction-order deltas shift the per-class evicted fraction (additive
    # forms: ``x + rl*0.0`` is exact in float32, so default grids keep
    # bit-identical verdicts)
    d_rl = p.get("rl_evict_delta", 0.0)
    rl_need = c["rl"] * evict + c["rl"] * d_rl
    rl_envs_evicted = c["rl_envs"] * evict + c["rl_envs"] * d_rl
    n_rl_waves = jnp.maximum(
        1.0, jnp.ceil(rl_envs_evicted / c["mbb_parallelism"]))
    rl_last_wave_t = burst_full_t + n_rl_waves * c["rl_wave_s"]
    burst_free_rl = jnp.maximum(0.0, burst_cap - am_in_burst)
    quota_eff = c["cloud_quota"] * p["cloud_quota_frac"]
    total_cloud = jnp.minimum(
        jnp.maximum(0.0, rl_need - burst_free_rl), quota_eff)
    per_wave = rl_need / n_rl_waves
    k_star = jnp.minimum(
        jnp.floor(burst_free_rl / jnp.maximum(per_wave, 1e-9)) + 1.0,
        n_rl_waves)
    cloud_start_t = burst_full_t + k_star * c["rl_wave_s"]
    cloud_arrival_t = cloud_start_t + total_cloud / jnp.maximum(
        c["cloud_rate"], 1e-9)
    rl_shortfall = jnp.maximum(0.0, rl_need - burst_free_rl - quota_eff)
    rl_ok_soft = None
    if tau is None:
        rl_done_t = jnp.where(
            rl_shortfall > 1e-6, jnp.inf,
            jnp.maximum(rl_last_wave_t,
                        jnp.where(total_cloud > 1e-6, cloud_arrival_t, 0.0)))
    else:
        # smooth relaxation of the infinite-shortfall sentinel: the
        # beyond-quota remainder provisions at the same cloud rate (a
        # finite, monotone overrun past the RTO), and the "any cloud at
        # all" gate softens over ~1 core
        cloud_gate = soft_ge(total_cloud, 0.5, 0.25, tau)
        rl_done_t = (jnp.maximum(rl_last_wave_t,
                                 cloud_gate * cloud_arrival_t)
                     + rl_shortfall / jnp.maximum(c["cloud_rate"], 1e-2))
        # the signed no-shortfall margin (the hard verdict gates on
        # rl_shortfall > 1e-6, whose one-sided max(0, .) has no sign to
        # smooth) — _finalize folds this into rl_rto_met
        rl_ok_soft = soft_ge(0.0, rl_need - burst_free_rl - quota_eff,
                             _cores_scale(c), tau)

    return {"burst_cap": burst_cap, "tick_s": tick_s,
            "rl_ok_soft": rl_ok_soft,
            "cap_scale": cap_scale, "stateless_eff": stateless_eff,
            "storm_refrac": p.get("storm_refrac", 0.0),
            "storm_t0": p.get("storm_t0_s", 1800.0),
            "storm_period": p.get("storm_period_s", 1800.0),
            "storm_recover": p.get("storm_recover_s", 600.0),
            "burst_full_t": burst_full_t,
            "n_am_waves": n_am_waves, "am_done_t": am_done_t,
            "am_in_burst": am_in_burst,
            "am_release_frac": am_release_frac,
            "ao_need": ao_need, "ao_ok": ao_ok, "ao_short": ao_short,
            "rl_need": rl_need, "rl_envs_evicted": rl_envs_evicted,
            "n_rl_waves": n_rl_waves, "rl_last_wave_t": rl_last_wave_t,
            "burst_free_rl": burst_free_rl, "quota_eff": quota_eff,
            "total_cloud": total_cloud, "cloud_start_t": cloud_start_t,
            "cloud_arrival_t": cloud_arrival_t,
            "rl_shortfall": rl_shortfall, "rl_done_t": rl_done_t}


def _storm_darkness(s: Dict, t):
    """Cascading-storm re-darkening envelope at time ``t``: from
    ``storm_t0`` on, a pulse of amplitude ``storm_refrac`` fires every
    ``storm_period`` seconds and linearly re-restores over
    ``storm_recover`` seconds — a sawtooth dark mask that re-darkens
    already-restored capacity mid-timeline (seed failures cascading
    back).  Identically 0.0 when ``storm_refrac`` is 0 (every factor is
    finite, so no 0*inf hazard), which keeps default scenarios bitwise
    unchanged."""
    k = jnp.clip(jnp.floor((t - s["storm_t0"] + EPS_T)
                           / jnp.maximum(s["storm_period"], 1e-9)),
                 0.0, 1e6)
    since = t - s["storm_t0"] - k * s["storm_period"]
    env = jnp.clip(1.0 - since / jnp.maximum(s["storm_recover"], 1e-9),
                   0.0, 1.0)
    gate = jnp.where(t >= s["storm_t0"] - EPS_T, 1.0, 0.0)
    return s["storm_refrac"] * env * gate


def _instant_core(c: Dict, p: Dict, s: Dict, t, tau=None) -> Dict:
    """Per-step series the scan *carry* consumes (availability, the
    demand-model utilization, the cloud draw, per-tier live cores) plus
    the intermediates the trace-only extras derive from.  This is the
    summary-only hot path — ``timeline_verdicts`` scans exactly this, the
    trace path layers ``_instant`` on top, so summary outputs are the
    same ops (hence bit-identical) in both.  ``tau`` softens the
    knob-dependent time gates and the QoS penalty step (see
    ``_schedule``); ``None`` traces the original ops."""
    mult = p["traffic_mult"]
    evicted = (t >= c["kill_s"] - EPS_T)
    e = jnp.where(evicted, p["evict_fraction"], 0.0)
    # per-class eviction-order shifts, gated like ``e`` (zero before the
    # kill): additive forms keep default grids bit-identical
    d_rl_t = jnp.where(evicted, p.get("rl_evict_delta", 0.0), 0.0)
    d_tm_t = jnp.where(evicted, p.get("tm_evict_delta", 0.0), 0.0)

    # Active-Migrate MBB waves into burst
    am_waves_done = jnp.clip(
        jnp.floor((t - s["burst_full_t"] + EPS_T) / c["mbb_wave_s"]),
        0.0, s["n_am_waves"])
    am_envs_moved = jnp.minimum(c["am_envs"],
                                c["mbb_parallelism"] * am_waves_done)
    am_attempt = c["am"] * am_envs_moved / jnp.maximum(c["am_envs"], 1.0)
    am_moved = jnp.minimum(am_attempt, s["burst_cap"])

    # Always-On in-place upscale at migration completion
    if tau is None:
        ao_scaled = s["ao_ok"] & (t >= s["am_done_t"] - EPS_T)
        ao_live = c["ao"] * jnp.where(ao_scaled, mult, 1.0)
    else:
        ao_scaled = s["ao_ok"] * soft_ge(t, s["am_done_t"] - EPS_T,
                                         SOFT_TIME_SCALE, tau)
        ao_live = c["ao"] * (1.0 + ao_scaled * (mult - 1.0))

    # Restore-Later waves: burst first, the cloud batch after provisioning
    rl_waves_done = jnp.clip(
        jnp.floor((t - s["burst_full_t"] + EPS_T) / c["rl_wave_s"]),
        0.0, s["n_rl_waves"])
    processed = s["rl_need"] * rl_waves_done / s["n_rl_waves"]
    rl_burst = jnp.minimum(processed, s["burst_free_rl"])
    cloud_req = processed - rl_burst
    cloud_prov = jnp.minimum(cloud_req, s["quota_eff"])
    if tau is None:
        cloud_arrived = jnp.where(t >= s["cloud_arrival_t"] - EPS_T,
                                  s["total_cloud"], 0.0)
    else:
        cloud_arrived = s["total_cloud"] * soft_ge(
            t, s["cloud_arrival_t"] - EPS_T, SOFT_TIME_SCALE, tau)
    cloud_live = jnp.minimum(cloud_arrived, cloud_prov)
    # the cascade storm re-darkens a fraction of whatever has been
    # restored so far (burst conversions and cloud grants alike) — the
    # time-varying dark mask of a dependency storm, not a new eviction
    storm_dark = _storm_darkness(s, t)
    rl_restored = (rl_burst + cloud_live) * (1.0 - storm_dark)
    rl_live = c["rl"] - (e + d_rl_t) * c["rl"] + rl_restored
    tm_live = c["tm"] * (1.0 - e - d_tm_t)

    # demand-model utilization (drives the SLA verdict / QoS penalty):
    # Always-On busy is constant — the upscale spreads 2x demand over 2x
    # cores — while unmigrated AM absorbs the multiplier on 1x cores
    am_steady_cores = c["am"] - am_moved
    pre_steady = ((c["rl"] + c["tm"]) * (1.0 - e)
                  - (c["rl"] * d_rl_t + c["tm"] * d_tm_t))
    busy_model = (c["ao"] * _DEMAND_CRIT * mult
                  + am_steady_cores * _DEMAND_CRIT * mult
                  + pre_steady * _DEMAND_PRE)
    util_model = jnp.minimum(
        1.0, busy_model / jnp.maximum(s["stateless_eff"], 1.0))

    # availability: AO shortfall bites from the eviction, overdue RL after
    # the RTO expires, broken criticals (propagation verdict) while their
    # dark dependencies stay dark, QoS stress while the model runs hot
    crit = jnp.maximum(c["ao"] + c["am"], 1.0)
    rl_down = c["rl"] - rl_live
    tm_down = c["tm"] - tm_live
    ao_pen = jnp.where(evicted, 0.5 * s["ao_short"] / crit, 0.0)
    overdue = jnp.where(t > c["rl_rto_s"] + EPS_T, 1.0, 0.0)
    rl_pen = 0.1 * rl_down / jnp.maximum(c["rl"], 1.0) * overdue
    dark_tot = jnp.maximum(
        s["rl_need"] + (p["evict_fraction"]
                        + p.get("tm_evict_delta", 0.0)) * c["tm"], 1e-9)
    dark_frac = (rl_down + tm_down) / dark_tot
    dep_pen = 0.5 * p["dep_broken_frac"] * dark_frac
    if tau is None:
        util_pen = jnp.where(util_model > QOS_EVICT_UTILIZATION, 1e-4, 0.0)
    else:
        util_pen = 1e-4 * soft_ge(util_model, QOS_EVICT_UTILIZATION,
                                  SOFT_FRAC_SCALE, tau)
    # criticals the STORM's dark set breaks (its own propagation verdict)
    # are down exactly while the storm mask holds capacity dark
    storm_pen = 0.5 * p.get("storm_broken_frac", 0.0) * storm_dark
    availability = jnp.clip(
        BASE_AVAILABILITY - ao_pen - rl_pen - dep_pen - util_pen
        - storm_pen, 0.0, 1.0)

    # per-tier live cores: class live-fraction applied to the tier x class
    # core composition
    class_live = jnp.stack([ao_live, c["am"], rl_live, tm_live])
    class_total = jnp.stack([c["ao"], c["am"], c["rl"], c["tm"]])
    frac = class_live / jnp.maximum(class_total, 1e-9)
    tier_live = (c["tier_class"] * frac[None, :]).sum(axis=1)

    return {"e": e, "evicted": evicted, "am_envs_moved": am_envs_moved,
            "am_moved": am_moved, "ao_scaled": ao_scaled,
            "ao_live": ao_live, "rl_restored": rl_restored,
            "rl_burst": rl_burst, "rl_live": rl_live, "tm_live": tm_live,
            "am_steady_cores": am_steady_cores,
            "cloud_used": cloud_prov, "util_model": util_model,
            "availability": availability, "tier_live": tier_live}


def _instant(c: Dict, p: Dict, s: Dict, t) -> Dict:
    """All per-step series at time ``t`` (pure function of the schedule —
    the scan carry layers accumulators/first-crossings on top): the
    summary core plus the trace-only extras (pool accounting, env counts,
    the conversion ramp, physical utilization)."""
    k = _instant_core(c, p, s, t)
    mult = p["traffic_mult"]
    e = k["e"]

    # burst conversion ramp (10 spawner ticks, orchestrator semantics)
    ticks = jnp.clip(jnp.floor((t - p["burst_delay_s"] + EPS_T)
                               / jnp.maximum(s["tick_s"], 1e-9)), 0.0, 10.0)
    burst_online = s["burst_cap"] * ticks / 10.0
    burst_capacity = jnp.where(t >= p["burst_delay_s"] - EPS_T,
                               s["burst_cap"], 0.0)

    ao_extra = jnp.where(k["ao_scaled"], s["ao_need"], 0.0)

    # placed-pool accounting
    steady_used = (c["steady_used0"] - e * c["sl_preempt_cores"]
                   - k["am_moved"] * s["am_release_frac"] + ao_extra)
    overcommit_used = c["overcommit_used0"] - e * c["oc_preempt_cores"]
    burst_used = k["am_moved"] + k["rl_burst"]

    # env-count series (orchestrator snapshot names); the eviction-order
    # deltas shift the per-class counts (additive, exact no-ops at 0)
    d_rl_t = jnp.where(k["evicted"], p.get("rl_evict_delta", 0.0), 0.0)
    d_tm_t = jnp.where(k["evicted"], p.get("tm_evict_delta", 0.0), 0.0)
    am_bursted = k["am_envs_moved"]
    am_steady = c["am_envs"] - am_bursted
    rl_bursted = jnp.round(s["rl_envs_evicted"] * k["rl_restored"]
                           / jnp.maximum(s["rl_need"], 1e-9))
    rl_not_bursted = jnp.round((e + d_rl_t) * c["rl_envs"]) - rl_bursted
    rl_t_steady = jnp.round((1.0 - e) * (c["rl_envs"] + c["tm_envs"])
                            - (d_rl_t * c["rl_envs"]
                               + d_tm_t * c["tm_envs"]))
    terminated = jnp.round((e + d_tm_t) * c["tm_envs"])

    # utilization, orchestrator-mirror (traffic multiplier on survivors)
    pre_steady = ((c["rl"] + c["tm"]) * (1.0 - e)
                  - (c["rl"] * d_rl_t + c["tm"] * d_tm_t))
    busy = (k["ao_live"] * _DEMAND_CRIT * mult
            + k["am_steady_cores"] * _DEMAND_CRIT * mult
            + pre_steady * _DEMAND_PRE)
    utilization = jnp.minimum(
        1.0, busy / jnp.maximum(c["phys_cores"] * s["cap_scale"], 1.0))

    return {"steady_used": steady_used, "overcommit_used": overcommit_used,
            "burst_capacity": burst_capacity, "burst_online": burst_online,
            "burst_used": burst_used, "cloud_used": k["cloud_used"],
            "ao_live": k["ao_live"], "am_live": c["am"] + 0.0 * t,
            "rl_live": k["rl_live"], "tm_live": k["tm_live"],
            "am_steady": am_steady, "am_bursted": am_bursted,
            "rl_bursted": rl_bursted, "rl_not_bursted": rl_not_bursted,
            "rl_t_steady": rl_t_steady, "terminated": terminated,
            "utilization": utilization, "util_model": k["util_model"],
            "availability": k["availability"],
            "tier_live": k["tier_live"]}


def _carry0(ts) -> Dict:
    """Initial scan carry — every leaf pinned to a strong float32/bool so
    no Python-scalar weak type (or x64-mode float64) leaks into the scan
    carry (regression-tested by ``tests/test_sweep_engine.py``)."""
    f32 = jnp.float32
    return {
        "prev_t": jnp.asarray(ts[0], f32),
        "avail_int": jnp.asarray(0.0, f32),
        "avail_min": jnp.asarray(1.0, f32),
        "util_peak": jnp.asarray(0.0, f32),
        "cloud_peak": jnp.asarray(0.0, f32),
        "below_seen": jnp.zeros(N_TIERS, bool),
        "restore_t": jnp.full(N_TIERS, jnp.inf, f32),
    }


def _carry_step(carry: Dict, core: Dict, t, tier_total) -> Dict:
    """Fold one step's core series into the running accumulators /
    first-crossing trackers (shared by the trace and summary-only scans)."""
    dt = jnp.maximum(t - carry["prev_t"], 0.0)
    frac = core["tier_live"] / tier_total
    below = frac < RESTORE_THRESH
    below_seen = carry["below_seen"] | below
    restore_t = jnp.where(
        below_seen & ~below & jnp.isinf(carry["restore_t"]),
        t, carry["restore_t"])
    return {
        "prev_t": jnp.asarray(t, jnp.float32),
        "avail_int": carry["avail_int"] + core["availability"] * dt,
        "avail_min": jnp.minimum(carry["avail_min"],
                                 core["availability"]),
        "util_peak": jnp.maximum(carry["util_peak"],
                                 core["util_model"]),
        "cloud_peak": jnp.maximum(carry["cloud_peak"],
                                  core["cloud_used"]),
        "below_seen": below_seen, "restore_t": restore_t,
    }


def _finalize(c: Dict, p: Dict, s: Dict, carry: Dict, ts, tau=None) -> Dict:
    """Per-scenario summary/verdicts from the final carry (shared by the
    trace and summary-only paths — identical ops, identical bits).
    ``tau`` replaces the hard verdicts with sigmoid margins and the
    boolean AND with a product of indicators (see the soft-relaxation
    block at the top of the module); ``None`` traces the original ops."""
    span = jnp.maximum(ts[-1] - ts[0], 1e-9)
    availability_mean = carry["avail_int"] / span
    time_to_restore = jnp.where(carry["below_seen"], carry["restore_t"], 0.0)
    oc_cap_s = s["stateless_eff"] * (p["overcommit_factor"] - 1.0)
    preempt_resident = ((c["rl"] + c["tm"]) * (1.0 - p["evict_fraction"])
                        - (c["rl"] * p.get("rl_evict_delta", 0.0)
                           + c["tm"] * p.get("tm_evict_delta", 0.0)))
    # the SLA verdict scores the post-migration steady point (stranded AM
    # only), like the analytic model: the pre-migration transient — 2x
    # traffic on Active-Migrate before burst absorbs it — stays visible in
    # the trace and in util_peak, but is not an SLA breach by itself
    am_stranded = c["am"] - s["am_in_burst"]
    busy_post = (c["ao"] * _DEMAND_CRIT * p["traffic_mult"]
                 + am_stranded * _DEMAND_CRIT * p["traffic_mult"]
                 + preempt_resident * _DEMAND_PRE)
    util_post = jnp.minimum(
        1.0, busy_post / jnp.maximum(s["stateless_eff"], 1.0))
    if tau is None:
        preempt_fit = preempt_resident <= oc_cap_s + 1e-6
        dep_ok = p["dep_broken_frac"] <= 0.0
        avail_ok = availability_mean >= BASE_AVAILABILITY - AVAIL_SLA_TOL
        util_ok = util_post <= QOS_EVICT_UTILIZATION
        rl_rto_met = s["rl_done_t"] <= c["rl_rto_s"] + EPS_T
        sla_ok = (s["ao_ok"] & rl_rto_met & preempt_fit & dep_ok & avail_ok
                  & util_ok & (s["am_done_t"] <= 30.0 * 60.0)
                  & (s["burst_full_t"] <= 20.0 * 60.0))
    else:
        cs = _cores_scale(c)
        preempt_fit = soft_ge(oc_cap_s + 1e-6, preempt_resident, cs, tau)
        dep_ok = soft_ge(1e-7, p["dep_broken_frac"], SOFT_DEP_SCALE, tau)
        avail_ok = soft_ge(availability_mean,
                           BASE_AVAILABILITY - AVAIL_SLA_TOL,
                           SOFT_AVAIL_SCALE, tau)
        util_ok = soft_ge(QOS_EVICT_UTILIZATION, util_post,
                          SOFT_FRAC_SCALE, tau)
        rl_rto_met = (soft_ge(c["rl_rto_s"] + EPS_T, s["rl_done_t"],
                              SOFT_TIME_SCALE, tau) * s["rl_ok_soft"])
        sla_ok = (s["ao_ok"] * rl_rto_met * preempt_fit * dep_ok
                  * avail_ok * util_ok
                  * soft_ge(30.0 * 60.0, s["am_done_t"],
                            SOFT_TIME_SCALE, tau)
                  * soft_ge(20.0 * 60.0, s["burst_full_t"],
                            SOFT_TIME_SCALE, tau))
    summary = {
        "burst_full_s": s["burst_full_t"], "am_done_s": s["am_done_t"],
        "rl_done_s": s["rl_done_t"], "rl_rto_met": rl_rto_met,
        "ao_ok": s["ao_ok"], "ao_short_cores": s["ao_short"],
        "rl_shortfall_cores": s["rl_shortfall"],
        "cloud_grant_cores": s["total_cloud"],
        "cloud_arrival_s": s["cloud_arrival_t"],
        "peak_cloud_cores": carry["cloud_peak"],
        "availability_mean": availability_mean,
        "availability_min": carry["avail_min"],
        "util_peak": carry["util_peak"], "util_post": util_post,
        "time_to_restore_s": time_to_restore,
        "preempt_fit": preempt_fit, "dep_ok": dep_ok,
        "avail_ok": avail_ok, "util_ok": util_ok, "sla_ok": sla_ok,
    }
    return summary


def timeline_verdicts_batch(c: Dict, p: Dict, ts: jnp.ndarray, *,
                            interpret=None) -> Dict:
    """Summary verdicts for a BATCH of scenarios (every param leaf
    ``(S,)``) with the scan carry replaced by the segmented Pallas
    verdict-reduction kernel (``repro.kernels.ufa.reduce``): the
    schedule/instant ops are the identical ``_schedule``/``_instant_core``
    functions vmapped over (scenario, step), so the per-step series are
    bit-identical to the scan path — but the T sequential carry steps
    become one blocked reduction over the whole (S, T) slab.  Min/max and
    first-crossing outputs are exact vs ``timeline_verdicts``; the
    availability integral is a reordered float32 sum (float32-tight, not
    bitwise), which is why the sweep engine selects this path per backend
    (``reducer="pallas"``) rather than by default."""
    from repro.kernels.ufa.reduce import timeline_reduce

    def series_one(q):
        sch = _schedule(c, q)
        core = jax.vmap(lambda t: _instant_core(c, q, sch, t))(ts)
        return sch, core

    s, core = jax.vmap(series_one)(p)
    tier_total = jnp.maximum(c["tier_class"].sum(axis=1), 1e-9)
    carry = timeline_reduce(
        core["availability"], core["util_model"], core["cloud_used"],
        core["tier_live"] / tier_total, ts,
        thresh=RESTORE_THRESH, interpret=interpret)
    return jax.vmap(lambda q, sch, cr: _finalize(c, q, sch, cr, ts))(
        p, s, carry)


def _simulate(c: Dict, p: Dict, ts: jnp.ndarray) -> Tuple[Dict, Dict]:
    """One scenario: scan the step function over ``ts``; returns
    (per-step traces, per-scenario summary/verdicts)."""
    s = _schedule(c, p)
    tier_total = jnp.maximum(c["tier_class"].sum(axis=1), 1e-9)

    def body(carry, t):
        out = _instant(c, p, s, t)      # superset of the core series
        return _carry_step(carry, out, t, tier_total), out

    carry, traces = jax.lax.scan(body, _carry0(ts), ts)
    return traces, _finalize(c, p, s, carry, ts)


def timeline_verdicts(c: Dict, p: Dict, ts: jnp.ndarray, tau=None) -> Dict:
    """Summary-only timeline kernel for ONE scenario (scalar params): the
    same ``lax.scan`` as ``_simulate`` but with no per-step trace outputs,
    so the compiled program never materializes the (T, series) stack —
    the fused sweep engine vmaps this over bucket-padded scenario chunks.
    Summary outputs are op-for-op identical to ``_simulate``'s (pinned by
    ``tests/test_sweep_engine.py``).

    ``tau`` (opt-in soft relaxation): a traced temperature scalar turns
    the boolean verdicts into differentiable sigmoid indicators — the
    capacity optimizer's ``jax.grad`` path; ``tau=None`` (the default)
    traces the original hard ops, bit-identical to before."""
    s = _schedule(c, p, tau)
    tier_total = jnp.maximum(c["tier_class"].sum(axis=1), 1e-9)

    def body(carry, t):
        core = _instant_core(c, p, s, t, tau)
        return _carry_step(carry, core, t, tier_total), None

    carry, _ = jax.lax.scan(body, _carry0(ts), ts)
    return _finalize(c, p, s, carry, ts, tau)


_simulate_jit = jax.jit(_simulate)
# vmap over the scenario axis only: consts and the time grid are shared.
# The trace variant materializes the full (S, T, series) stack; the
# summary variant is the default sweep path (verdicts only).
_sweep_jit = jax.jit(jax.vmap(_simulate, in_axes=(None, 0, None)))
_sweep_summary_jit = jax.jit(jax.vmap(timeline_verdicts,
                                      in_axes=(None, 0, None)))


def _as_params(p: Dict[str, float]) -> Dict[str, jnp.ndarray]:
    return {k: jnp.asarray(p[k], jnp.float32) for k in PARAM_KEYS}


def simulate_timeline(cfg: TimelineConfig,
                      params: Optional[Dict[str, float]] = None,
                      ts: Optional[np.ndarray] = None
                      ) -> Dict[str, np.ndarray]:
    """Run ONE scenario timeline; returns ``{"t": ts, traces..., summary
    scalars...}`` as numpy.  ``ts`` may be any increasing grid — pass the
    orchestrator's snapshot times to compare against its ``Timeline``."""
    base = default_scenario(burst_delay_s=cfg.preheat_s)
    params = dict(base, **(params or {}))
    ts = default_ts() if ts is None else np.asarray(ts, np.float64)
    traces, summary = _simulate_jit(cfg.as_consts(), _as_params(params),
                                    jnp.asarray(ts, jnp.float32))
    out = {"t": ts}
    out.update({k: np.asarray(v) for k, v in traces.items()})
    out.update({k: np.asarray(v) for k, v in summary.items()})
    return out


def sweep_timeline(cfg: TimelineConfig,
                   grid: Optional[Dict[str, np.ndarray]] = None,
                   ts: Optional[np.ndarray] = None,
                   dep_broken_frac: Optional[np.ndarray] = None,
                   return_traces: bool = False) -> Dict[str, np.ndarray]:
    """Temporal verdicts for every scenario in the grid, in one vmapped
    scan: per-scenario time-to-restore per tier, availability integral vs
    99.97%, peak on-demand cloud draw, and the SLA verdict — plus the full
    per-step traces when ``return_traces``.

    ``grid`` defaults to ``scenarios.scenario_grid()`` (the same axes the
    analytic sweep uses); ``dep_broken_frac`` folds the dependency-graph
    propagation verdicts into the availability trace (see
    ``scenarios.sweep_with_dependency_ensemble``)."""
    from repro.core.scenarios import scenario_grid
    grid = scenario_grid() if grid is None else grid
    n = validate_grid(grid)
    params = {k: jnp.asarray(np.asarray(grid[k]), jnp.float32)
              for k in PARAM_KEYS if k in grid}
    if dep_broken_frac is None:
        dep_broken_frac = grid.get("dep_broken_frac", np.zeros(n))
    params["dep_broken_frac"] = jnp.asarray(
        np.asarray(dep_broken_frac), jnp.float32)
    defaults = default_scenario(burst_delay_s=cfg.preheat_s)
    for k in PARAM_KEYS:                       # missing axes -> defaults
        if k not in params:
            params[k] = jnp.full(n, defaults[k], jnp.float32)
    ts = default_ts() if ts is None else np.asarray(ts, np.float64)
    tsj = jnp.asarray(ts, jnp.float32)
    meter = obs.enabled()            # one branch per sweep — free off
    t0 = time.perf_counter() if meter else 0.0
    if return_traces:
        traces, summary = _sweep_jit(cfg.as_consts(), params, tsj)
        out = {k: np.asarray(v) for k, v in summary.items()}
        out["t"] = ts
        out.update({f"trace_{k}": np.asarray(v) for k, v in traces.items()})
    else:
        # summary-only kernel: same ops for the verdicts, but the (S, T,
        # series) trace stack is never materialized
        summary = _sweep_summary_jit(cfg.as_consts(), params, tsj)
        out = {k: np.asarray(v) for k, v in summary.items()}
    if meter:
        dt = time.perf_counter() - t0
        obs.inc("ufa_timeline_scenarios_total", n)
        if dt > 0:
            obs.set_gauge("ufa_timeline_scenarios_per_s", n / dt)
    return out


def summarize_timeline_sweep(result: Dict[str, np.ndarray]
                             ) -> Dict[str, object]:
    """Ensemble-level digest of a ``sweep_timeline`` result."""
    n = len(result["sla_ok"])
    finite_rl = result["rl_done_s"][np.isfinite(result["rl_done_s"])]
    return {
        "n_scenarios": n,
        "n_sla_ok": int(result["sla_ok"].sum()),
        "n_rl_rto_met": int(result["rl_rto_met"].sum()),
        "availability_mean_min": float(result["availability_mean"].min()),
        "availability_floor": float(result["availability_min"].min()),
        "worst_finite_rl_done_min": (float(finite_rl.max() / 60.0)
                                     if len(finite_rl) else float("nan")),
        "n_rl_never_restored": int(np.isinf(result["rl_done_s"]).sum()),
        "peak_cloud_cores_max": float(result["peak_cloud_cores"].max()),
        "worst_util_peak": float(result["util_peak"].max()),
    }
