"""Cluster capacity model: hosts, resource pools, placement, overcommit.

Implements the paper's §4.4 partitioning: each host advertises
``stateless.cpu`` (physical) plus ``overcommit.cpu`` (extended resource =
(factor-1) x physical), so preemptible pods schedule into reserved failover
headroom without interfering with critical placement.  Also the §4.5 batch
clusters that convert to "burst" capacity, and the §4.6 cloud pool with
quota + provisioning-latency semantics.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

from repro.core.tiers import (QOS_COOL_UTILIZATION, QOS_EVICT_UTILIZATION,
                              FailureClass, Tier, o_max)

# region-sizing constants shared with the analytic scenario model
DEFAULT_SLACK = 1.06               # fragmentation slack on steady sizing
BATCH_CORES_PER_HOST = 120.0
BATCH_BURST_HEADROOM = 1.35        # burst sized to hold (AM + RL) * this
BATCH_PREEMPTIBLE_FRACTION = 0.9


CLOUD_RATE_FLOOR = 10.0            # min cloud provisioning rate (cores/s)
CLOUD_RATE_RL_DIVISOR = 1200.0     # rate scales with the RL footprint


def default_cloud_quota(rl_cores: float) -> float:
    """Cloud quota a region is provisioned with (§4.6 sizing rule).
    Pure arithmetic — safe to call on jax tracers (scenario model)."""
    return 0.5 * rl_cores + 100.0


def default_cloud_rate(rl_cores: float) -> float:
    """Cloud provisioning rate (cores/s) for a region's RL footprint."""
    return max(CLOUD_RATE_FLOOR, rl_cores / CLOUD_RATE_RL_DIVISOR)


@dataclasses.dataclass
class PoolState:
    """Aggregate view of one scheduling pool (cores)."""
    capacity: float
    used: float = 0.0

    @property
    def free(self) -> float:
        return self.capacity - self.used

    def alloc(self, cores: float) -> bool:
        if cores > self.free + 1e-9:
            return False
        self.used += cores
        return True

    def release(self, cores: float):
        self.used = max(0.0, self.used - cores)


@dataclasses.dataclass
class Cluster:
    """A (region-local) cluster of identical hosts with two CPU pools."""
    name: str
    n_hosts: int
    cores_per_host: float
    overcommit_factor: float = 1.5
    mem_per_core_gb: float = 8.0

    def __post_init__(self):
        phys = self.n_hosts * self.cores_per_host
        self.stateless = PoolState(capacity=phys)
        self.overcommit = PoolState(
            capacity=phys * (self.overcommit_factor - 1.0))

    @property
    def physical_cores(self) -> float:
        return self.n_hosts * self.cores_per_host

    @property
    def advertised_cores(self) -> float:
        return self.stateless.capacity + self.overcommit.capacity

    def utilization(self, demand_fraction: float = 1.0) -> float:
        """Fraction of physical cores busy given current placements and a
        demand level (0..1) applied to allocated cores."""
        busy = (self.stateless.used + self.overcommit.used) * demand_fraction
        return min(1.0, busy / max(1.0, self.physical_cores))


@dataclasses.dataclass
class BatchCluster:
    """Batch (analytics/ML) cluster convertible to burst capacity (§4.5)."""
    name: str
    n_hosts: int
    cores_per_host: float
    preemptible_fraction: float = BATCH_PREEMPTIBLE_FRACTION
    converted: bool = False
    burst: Optional[PoolState] = None

    @property
    def convertible_cores(self) -> float:
        return self.n_hosts * self.cores_per_host * self.preemptible_fraction

    def convert(self) -> PoolState:
        self.converted = True
        self.burst = PoolState(capacity=self.convertible_cores)
        return self.burst

    def release(self):
        self.converted = False
        self.burst = None


@dataclasses.dataclass
class CloudPool:
    """On-demand cloud capacity with quota and slow provisioning (§4.6)."""
    quota_cores: float = 100_000.0
    provision_rate_cores_per_s: float = 300.0   # tens of thousands over ~minutes
    provisioned: float = 0.0
    used: float = 0.0

    def provision_time(self, cores: float) -> float:
        grant = min(cores, self.quota_cores - self.provisioned)
        return grant / self.provision_rate_cores_per_s

    def provision(self, cores: float) -> float:
        grant = min(cores, self.quota_cores - self.provisioned)
        self.provisioned += grant
        return grant

    def release_all(self):
        self.provisioned = 0.0
        self.used = 0.0


def safe_overcommit_bound(mem_per_host_core: float = 8.0,
                          mem_per_service_core: float = 4.0,
                          alpha_m: float = 0.75,
                          alpha_c: float = 0.90) -> float:
    """O_max from §4.4 — the memory-ratio ceiling on oversubscription."""
    return o_max(mem_per_host_core, mem_per_service_core, alpha_m, alpha_c)


@dataclasses.dataclass
class RegionCapacity:
    """All capacity in one region: steady-state + batch + cloud."""
    name: str
    steady: Cluster
    batch: BatchCluster
    cloud: CloudPool

    @classmethod
    def for_fleet(cls, name: str, fleet: "object",
                  overcommit_factor: float = 1.5,
                  slack: float = DEFAULT_SLACK,
                  model: str = "ufa") -> "RegionCapacity":
        """Size a region for a fleet (a dict of ServiceSpecs, or a
        ``FleetState`` whose class totals reduce in one pass).

        model="legacy": every tier gets a dedicated 2x buffer
            -> stateless = 2 * total_demand, no overcommit pool.
        model="ufa":   Always-On keeps a 2x buffer, Active-Migrate keeps 1x
            (its failover lands in burst), preemptible classes run in the
            overcommit pool -> stateless = 2*AO + AM.
        """
        if hasattr(fleet, "class_core_totals"):      # FleetState fast path
            ao, am, rl, tm = fleet.class_core_totals()
        else:
            ao = am = rl = tm = 0.0
            for s in fleet.values():
                fc = s.failure_class
                if fc == FailureClass.ALWAYS_ON:
                    ao += s.cores
                elif fc == FailureClass.ACTIVE_MIGRATE:
                    am += s.cores
                elif fc == FailureClass.RESTORE_LATER:
                    rl += s.cores
                else:
                    tm += s.cores
        if model == "legacy":
            stateless = 2.0 * (ao + am + rl + tm) * slack
            factor = 1.0
        else:
            stateless = (2.0 * ao + am) * slack
            factor = overcommit_factor
            # the overcommit pool must hold all preemptible demand
            assert stateless * (factor - 1.0) >= (rl + tm), (
                stateless, factor, rl + tm)
        n_hosts = max(4, math.ceil(stateless / 100.0))
        # burst must absorb AM (MBB) + RL (restore): batch sized accordingly
        batch_cores = (am + rl) * BATCH_BURST_HEADROOM \
            / BATCH_PREEMPTIBLE_FRACTION
        batch_hosts = max(2, math.ceil(batch_cores / BATCH_CORES_PER_HOST))
        return cls(
            name=name,
            steady=Cluster(f"{name}-steady", n_hosts=n_hosts,
                           cores_per_host=100.0, overcommit_factor=factor),
            batch=BatchCluster(f"{name}-batch", n_hosts=batch_hosts,
                               cores_per_host=BATCH_CORES_PER_HOST),
            cloud=CloudPool(quota_cores=default_cloud_quota(rl),
                            provision_rate_cores_per_s=default_cloud_rate(rl)),
        )


def provisioning_multiple(fleet_cores_steady: float,
                          region_physical: float) -> float:
    """Global provisioned-to-needed ratio (2x legacy -> 1.3x UFA goal)."""
    return 2 * region_physical / max(1.0, fleet_cores_steady)
