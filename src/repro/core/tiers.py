"""Service tiers, failure-behavior classes, and SLA/RTO tables.

Encodes the paper's Tables 1 and 4:

  - Tiers T0 (most critical) .. T5 (least critical), plus NP (non-production).
  - Failure classes: Always-On, Active-Migrate, Restore-Later, Terminate.
  - Default tier -> failure-class mapping used by UFA.
  - Baseline fleet core counts per tier (Table 1) used to synthesize a
    paper-scale fleet for the benchmarks.
"""

from __future__ import annotations

import enum
from typing import Dict


class Tier(enum.IntEnum):
    """Business-criticality tier. Lower value = higher priority."""
    T0 = 0   # Infrastructure and critical applications
    T1 = 1   # Critical trip flow
    T2 = 2   # Business critical applications
    T3 = 3   # Internal tools critical to applications
    T4 = 4   # Internal tools used by employees
    T5 = 5   # Test versions and the rest
    NP = 6   # Non-production (staging, shadow, ...)

    @property
    def is_critical(self) -> bool:
        return self in (Tier.T0, Tier.T1, Tier.T2)


class FailureClass(enum.Enum):
    """Behavior during a (peak) regional failover — paper Table 4."""
    ALWAYS_ON = "always_on"          # in-place expand into failover buffer; secs RTO
    ACTIVE_MIGRATE = "active_migrate"  # make-before-break live migration; secs RTO
    RESTORE_LATER = "restore_later"  # break-before-make; <= 1 hour RTO
    TERMINATE = "terminate"          # down until failback

    @property
    def preemptible(self) -> bool:
        return self in (FailureClass.RESTORE_LATER, FailureClass.TERMINATE)

    @property
    def survives_failover(self) -> bool:
        return self in (FailureClass.ALWAYS_ON, FailureClass.ACTIVE_MIGRATE)


# Default tier -> failure class mapping (paper §4: "typically T0/T1 Always-On,
# T2 Active-Migrate, T3-T5 Restore-Later, NP Terminate").
DEFAULT_CLASS_OF_TIER: Dict[Tier, FailureClass] = {
    Tier.T0: FailureClass.ALWAYS_ON,
    Tier.T1: FailureClass.ALWAYS_ON,
    Tier.T2: FailureClass.ACTIVE_MIGRATE,
    Tier.T3: FailureClass.RESTORE_LATER,
    Tier.T4: FailureClass.RESTORE_LATER,
    Tier.T5: FailureClass.RESTORE_LATER,
    Tier.NP: FailureClass.TERMINATE,
}

# Recovery-time objectives in (simulated) seconds — paper Table 4 + §3.
RTO_SECONDS: Dict[FailureClass, float] = {
    FailureClass.ALWAYS_ON: 1.0,          # sub-second to seconds
    FailureClass.ACTIVE_MIGRATE: 60.0,    # secs (migration window)
    FailureClass.RESTORE_LATER: 3600.0,   # up to 1 hour
    FailureClass.TERMINATE: float("inf"),  # restored only at failback
}

# Paper Table 1 — baseline steady-state CPU cores per tier (global).
BASELINE_CORES: Dict[Tier, int] = {
    Tier.T0: 201_000,
    Tier.T1: 3_030_000,
    Tier.T2: 400_000,
    Tier.T3: 254_000,
    Tier.T4: 23_100,
    Tier.T5: 22_100,
    Tier.NP: 249_000,
}

# Paper Table 3 — number of services per tier.
SERVICES_PER_TIER: Dict[Tier, int] = {
    Tier.T0: 96,
    Tier.T1: 607,
    Tier.T2: 561,
    Tier.T3: 1550,
    Tier.T4: 283,
    Tier.T5: 882,
    Tier.NP: 18_000,
}

TOTAL_BASELINE_CORES = sum(BASELINE_CORES.values())  # ~4.18M globally

# Provisioning multipliers (paper §3 goal state).
LEGACY_PROVISIONING = 2.0
UFA_PROVISIONING = 1.3

# Peak / full failover definitions (paper §2).
PEAK_TRAFFIC_FRACTION = 0.85    # riders-on-trip >= 85% of weekly peak
FULL_FAILOVER_CITY_FRACTION = 0.50  # > 50% of cities fail over

# QoS controller thresholds (paper §4.4).
QOS_EVICT_UTILIZATION = 0.75
QOS_COOL_UTILIZATION = 0.70

# Overcommit constants (paper §4.4).
MEM_PER_HOST_CORE_GB = 8.0      # M_h
MEM_PER_SERVICE_CORE_GB = 4.0   # M_s
SAFE_MEM_FRACTION = 0.75        # alpha_m
SAFE_CPU_FRACTION = 0.90        # alpha_c


def o_max(m_h: float = MEM_PER_HOST_CORE_GB, m_s: float = MEM_PER_SERVICE_CORE_GB,
          alpha_m: float = SAFE_MEM_FRACTION, alpha_c: float = SAFE_CPU_FRACTION
          ) -> float:
    """Maximum achievable overcommit O_max = (M_h/M_s) * (alpha_m/alpha_c)."""
    return (m_h / m_s) * (alpha_m / alpha_c)
