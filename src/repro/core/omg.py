"""OMG — the UFA failover/failback orchestrator (paper §4.1, Figs 5/6).

Drives the full peak-failover sequence over the discrete-event loop:

  detect mode -> lockdown -> BBM-evict Terminate/Restore-Later ->
  batch->burst conversion (preheat: evict batch jobs + prefetch images) ->
  MBB-migrate Active-Migrate into burst, city-by-city traffic shift ->
  Always-On in-place scale-up into freed headroom ->
  Restore-Later restore in burst (+cloud as last resort, honoring cloud
  provisioning latency) within 1h RTO ->
  (operator-triggered) failback mirroring the MBB flow.

The orchestrator is fully vectorized over a ``FleetState`` struct-of-arrays:
every phase is a masked batch update and every snapshot a handful of array
reductions, so a paper-scale fleet (~22k service-environments) fails over
in well under a second of wall time.  ``orch.se`` exposes per-service views
backed by the arrays for tests, examples and callbacks.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Tuple, Union

import numpy as np

from repro import obs
from repro.core.capacity import RegionCapacity
from repro.core.events import EventLoop
from repro.core.fleet_state import (AM, AO, PLACEMENT_BURST, PLACEMENT_CLOUD,
                                    PLACEMENT_DOWN, PLACEMENT_NAMES,
                                    PLACEMENT_STEADY, POOL_NONE,
                                    POOL_OVERCOMMIT, POOL_STATELESS, RL, TM,
                                    CODE_FCLASS, FleetState)
from repro.core.service import ServiceSpec
from repro.core.tiers import RTO_SECONDS, FailureClass, Tier
from repro.core.traffic import FailoverModeDetector


def _first_fit(cores: np.ndarray, free: float) -> np.ndarray:
    """Greedy first-fit in array order against ``free`` capacity.  Returns
    the boolean take-mask.  The common all-fit case is one cumsum; the
    overflow tail (rare — regions are sized so everything fits) falls back
    to a scalar walk, matching per-item ``PoolState.alloc`` semantics."""
    m = len(cores)
    if m == 0:
        return np.zeros(0, bool)
    csum = np.cumsum(cores)
    taken = csum <= free + 1e-9
    k = int(np.count_nonzero(taken))
    if k == m:
        return taken
    rem = free - (csum[k - 1] if k > 0 else 0.0)
    for i in range(k, m):
        if cores[i] <= rem + 1e-9:
            taken[i] = True
            rem -= cores[i]
    return taken


class SEView:
    """Read view of one service-environment row (compat with the seed's
    ``SEState`` object API: tests and examples read these attributes)."""

    __slots__ = ("_fs", "_i", "_spec")

    def __init__(self, fs: FleetState, i: int, spec: Optional[ServiceSpec]):
        self._fs = fs
        self._i = i
        self._spec = spec

    @property
    def spec(self) -> ServiceSpec:
        if self._spec is None:
            fs, i = self._fs, self._i
            self._spec = ServiceSpec(
                name=fs.names[i], tier=Tier(int(fs.tier[i])),
                failure_class=CODE_FCLASS[int(fs.fclass[i])],
                cores_per_replica=float(fs.cores_per_replica[i]),
                replicas=int(fs.replicas[i]))
        return self._spec

    @property
    def placement(self) -> str:
        return PLACEMENT_NAMES[self._fs.placement[self._i]]

    @property
    def replicas_live(self) -> int:
        return int(self._fs.replicas_live[self._i])

    @property
    def locked(self) -> bool:
        return bool(self._fs.locked[self._i])

    @property
    def traffic_enabled(self) -> bool:
        return bool(self._fs.traffic_enabled[self._i])

    @property
    def cores_live(self) -> float:
        return float(self._fs.cores_live[self._i])


SEState = SEView   # seed-name compat


@dataclasses.dataclass
class Timeline:
    """Snapshot log: every series stays aligned with ``t``.

    A metric may join mid-run (e.g. ``burst_online`` only appears during
    the conversion ramp): its series is NaN-backfilled for the snapshots
    it missed, and NaN-padded whenever a later snapshot omits it, so
    ``as_arrays`` always returns equal-length arrays — never ragged."""
    t: List[float] = dataclasses.field(default_factory=list)
    series: Dict[str, List[float]] = dataclasses.field(default_factory=dict)

    def snap(self, now: float, **metrics: float):
        self.t.append(now)
        n = len(self.t)
        for k, v in metrics.items():
            col = self.series.get(k)
            if col is None:
                col = [float("nan")] * (n - 1)
                self.series[k] = col
            col.append(float(v))
        for col in self.series.values():
            if len(col) < n:
                col.append(float("nan"))

    def at(self, key: str) -> List[Tuple[float, float]]:
        return [(t, v) for t, v in zip(self.t, self.series[key])
                if v == v]          # skip NaN (snapshots without this key)

    def as_arrays(self) -> Dict[str, np.ndarray]:
        """Deterministically ordered (``t`` first, then sorted keys),
        every array aligned to ``len(t)``."""
        out = {"t": np.asarray(self.t, np.float64)}
        for k in sorted(self.series):
            out[k] = np.asarray(self.series[k], np.float64)
        return out


@dataclasses.dataclass
class FailoverReport:
    mode: str
    timeline: Timeline
    burst_full_at_s: Optional[float] = None
    am_migrated_at_s: Optional[float] = None
    rl_restored_at_s: Optional[float] = None
    rl_rto_met: bool = False
    cloud_cores_used: float = 0.0
    cloud_provision_s: float = 0.0     # provisioning latency spent (§4.6)
    always_on_ok: bool = True
    evictions_first_hour: int = 0
    notes: List[str] = dataclasses.field(default_factory=list)


class Orchestrator:
    """UFA failover orchestration for one surviving region."""

    # tunables calibrated to the paper's reported behavior
    KILL_LATENCY_S = 5.0                 # cluster-level kill, bypasses workflows
    BATCH_EVICT_S = 90.0                 # preemptible batch jobs drain
    PREFETCH_S = 180.0                   # p2p image prefetch into burst zones
    SPAWN_CORES_PER_HOST_S = 0.45        # fig 7: burst fully online ~8 min
    MBB_WAVE_S = 45.0                    # one parallel migration wave
    MBB_PARALLELISM = 2000               # envs per wave (paper §4.3)
    RL_RESTORE_WAVE_S = 120.0
    CITY_WAVE_S = 30.0                   # city-group traffic moves
    TRAFFIC_MULTIPLIER = 2.0             # surviving region absorbs 2x

    def __init__(self, fleet: Union[Dict[str, ServiceSpec], FleetState],
                 region: RegionCapacity,
                 loop: Optional[EventLoop] = None, scale: float = 1.0,
                 on_evict: Optional[Callable] = None,
                 on_migrate: Optional[Callable] = None,
                 on_restore: Optional[Callable] = None,
                 tracer=None):
        if isinstance(fleet, FleetState):
            self.fleet: Optional[Dict[str, ServiceSpec]] = None
            self.fs = fleet
        else:
            self.fleet = fleet
            self.fs = FleetState.from_specs(fleet)
        self.region = region
        self.loop = loop or EventLoop()
        if tracer is not None:
            # every scheduled wave/grant/restore becomes a sim-time span
            self.loop.tracer = tracer
        self.scale = scale
        self.on_evict = on_evict
        self.on_migrate = on_migrate
        self.on_restore = on_restore
        self.detector = FailoverModeDetector()
        self.timeline = Timeline()
        self._se_views: Optional[Dict[str, SEView]] = None
        self._place_steady_state()
        self.report: Optional[FailoverReport] = None
        self._state = "steady"
        self._cloud_ready_at = 0.0
        self._pending_cloud = 0
        self._rl_waves_done = False

    # ------------------------------------------------------------------
    @property
    def se(self) -> Dict[str, SEView]:
        """Per-service views over the arrays (lazy; tests/examples only)."""
        if self._se_views is None:
            get = self.fleet.get if self.fleet is not None else lambda _n: None
            self._se_views = {
                name: SEView(self.fs, i, get(name))
                for i, name in enumerate(self.fs.names)}
        return self._se_views

    def _spec_of(self, i: int) -> ServiceSpec:
        return self.se[self.fs.names[i]].spec

    def _emit(self, cb: Optional[Callable], mask: np.ndarray):
        if cb is None:
            return
        for i in np.flatnonzero(mask):
            cb(self._spec_of(int(i)))

    # ------------------------------------------------------------------
    def _place_steady_state(self):
        """Steady state: Always-On/Active-Migrate in the stateless pool,
        Restore-Later/Terminate opportunistically in the overcommit pool
        (overflow spills into stateless fragmentation slack — tracked, so
        eviction later frees the pool each SE actually occupies)."""
        fs = self.fs
        cores = fs.spec_cores
        pre = fs.preemptible
        fs.pool[:] = POOL_NONE

        idx = np.flatnonzero(pre)
        taken = _first_fit(cores[idx], self.region.steady.overcommit.free)
        oc_idx = idx[taken]
        fs.pool[oc_idx] = POOL_OVERCOMMIT
        self.region.steady.overcommit.used += float(cores[oc_idx].sum())

        overflow = np.zeros(fs.n, bool)
        overflow[idx[~taken]] = True
        sl_idx = np.flatnonzero(~pre | overflow)
        taken_sl = _first_fit(cores[sl_idx], self.region.steady.stateless.free)
        fs.pool[sl_idx[taken_sl]] = POOL_STATELESS
        self.region.steady.stateless.used += float(cores[sl_idx[taken_sl]].sum())

    # ------------------------------------------------------------------
    def timeline_config(self):
        """Extract the aggregate inputs the array-native timeline kernel
        (``repro.core.timeline_sim``) needs so that the ``lax.scan``
        simulator and this orchestrator consume *identical* state: class
        core totals, the post-placement pool occupancy (including the
        overcommit-spill split), batch/cloud sizing and the wave/ramp
        tunables.  Call in steady state (before ``failover``)."""
        from repro.core.timeline_sim import extract_timeline_config
        return extract_timeline_config(self)

    # ------------------------------------------------------------------
    def sweep_engine(self, *, graph=None, seed: int = 0, ts=None,
                     devices=None, reducer=None):
        """Fused sweep engine over THIS orchestrator's steady state: the
        analytic model, the timeline scan and (with ``graph``) the
        dependency propagation composed in one jitted, device-parallel
        pipeline (``repro.core.sweep_engine``).  Call in steady state —
        it snapshots ``timeline_config()``; the returned engine then runs
        arbitrary scenario grids (256 .. 100k+) without touching the
        orchestrator again."""
        from repro.core.scenarios import FleetAggregates
        from repro.core.sweep_engine import SweepEngine
        agg = (FleetAggregates.from_fleet_state(self.fs)
               if hasattr(self.fs, "fclass")
               else FleetAggregates.from_fleet(self.fs))
        return SweepEngine(agg, self.timeline_config(), graph=graph,
                           seed=seed, ts=ts, devices=devices,
                           reducer=reducer)

    # ------------------------------------------------------------------
    def class_cores(self, fc: FailureClass, placement: Optional[str] = None
                    ) -> float:
        return self.fs.class_cores(fc, placement)

    def class_envs(self, fc: FailureClass, placement: str) -> int:
        return self.fs.class_envs(fc, placement)

    def _snap(self, **extra):
        fs = self.fs
        burst = (self.region.batch.burst.used
                 if self.region.batch.burst else 0.0)
        burst_cap = (self.region.batch.burst.capacity
                     if self.region.batch.burst else 0.0)
        pl, fc = fs.placement, fs.fclass
        down = pl == PLACEMENT_DOWN
        live = fs.replicas_live > 0
        steady_live = (pl == PLACEMENT_STEADY) & live

        def envs(cmask, pcode):
            return int(np.count_nonzero(cmask & (pl == pcode) & live))

        rl_m, tm_m, am_m = fc == RL, fc == TM, fc == AM
        self.timeline.snap(
            self.loop.now,
            steady_used=self.region.steady.stateless.used,
            overcommit_used=self.region.steady.overcommit.used,
            burst_capacity=burst_cap,
            burst_used=burst,
            cloud_used=self.region.cloud.provisioned,
            rl_t_steady=int(np.count_nonzero((rl_m | tm_m) & steady_live)),
            rl_bursted=(envs(rl_m, PLACEMENT_BURST)
                        + envs(rl_m, PLACEMENT_CLOUD)),
            rl_not_bursted=int(np.count_nonzero(rl_m & down)),
            terminated=int(np.count_nonzero(tm_m & down)),
            am_steady=envs(am_m, PLACEMENT_STEADY),
            am_bursted=envs(am_m, PLACEMENT_BURST),
            utilization=self._utilization(),
            **extra)

    def _utilization(self) -> float:
        # demand-weighted: live cores x traffic multiplier on critical SEs
        fs = self.fs
        mult = self.TRAFFIC_MULTIPLIER if self._state != "steady" else 1.0
        steady = fs.placement == PLACEMENT_STEADY
        pre = fs.preemptible
        demand = np.where(pre, 0.35, 0.62)
        m = np.where(fs.survives, mult, 1.0)
        busy = float((fs.cores_live * demand * m)[steady].sum())
        return min(1.0, busy / max(1.0, self.region.steady.physical_cores))

    # ------------------------------------------------------------------
    # Failover
    # ------------------------------------------------------------------
    def failover(self, tv_failover: float = 1.0) -> FailoverReport:
        fs = self.fs
        mode = self.detector.mode(tv_failover)
        rep = FailoverReport(mode=mode, timeline=self.timeline)
        self.report = rep
        self._state = "failover"
        self.loop.log(f"failover start, mode={mode}")
        self._snap()
        if mode == "non-peak":
            # only city traffic moves; nothing is preempted
            self.loop.schedule(self.CITY_WAVE_S * 4, lambda: self._snap(),
                               "city-traffic")
            rep.always_on_ok = True
            rep.rl_rto_met = True
            self.loop.run()
            return rep

        # ---- peak mode ----
        t0 = self.loop.now
        # 1. lockdown
        fs.locked[fs.fclass != AO] = True
        self.loop.log("lockdown complete")

        # 2. immediate BBM eviction of Terminate + Restore-Later
        def evict_all():
            mask = fs.preemptible & (fs.placement == PLACEMENT_STEADY)
            cores = fs.cores_live
            self.region.steady.overcommit.release(
                float(cores[mask & (fs.pool == POOL_OVERCOMMIT)].sum()))
            self.region.steady.stateless.release(
                float(cores[mask & (fs.pool == POOL_STATELESS)].sum()))
            fs.placement[mask] = PLACEMENT_DOWN
            fs.replicas_live[mask] = 0
            fs.traffic_enabled[mask] = False
            fs.pool[mask] = POOL_NONE
            self._emit(self.on_evict, mask)
            obs.inc("ufa_orch_envs_total", int(mask.sum()), action="evicted")
            self.loop.log(f"BBM evicted {int(mask.sum())} preemptible SEs")
            self._snap()
        self.loop.schedule(self.KILL_LATENCY_S, evict_all, "bbm-evict")

        # 3. batch -> burst conversion (preheat)
        burst_pool_holder: Dict[str, object] = {}

        def start_conversion():
            pool = self.region.batch.convert()
            pool_full = pool.capacity
            burst_pool_holder["pool"] = pool
            # capacity comes online progressively (spawner ramp, rate
            # proportional to batch-cluster host count -> scale-invariant)
            steps = 10
            rate = self.SPAWN_CORES_PER_HOST_S * self.region.batch.n_hosts
            ramp_total = pool_full / rate if pool_full > 0 else 0.0
            self._online = 0.0

            def make_tick(i):
                def tick():
                    frac = (i + 1) / steps
                    self._online = pool_full * frac
                    self._snap(burst_online=self._online)
                    if i == steps - 1:
                        rep.burst_full_at_s = self.loop.now - t0
                        self.loop.log("burst capacity fully online")
                        migrate_am()
                        restore_rl()
                return tick
            for i in range(steps):
                self.loop.schedule(ramp_total * (i + 1) / steps, make_tick(i),
                                   "burst-tick")
        self.loop.schedule(self.BATCH_EVICT_S + self.PREFETCH_S,
                           start_conversion, "burst-conversion")

        # 4. MBB migration of Active-Migrate into burst (masked waves)
        def migrate_am():
            pool = burst_pool_holder["pool"]
            ams = np.flatnonzero((fs.fclass == AM)
                                 & (fs.placement == PLACEMENT_STEADY))
            waves = [ams[i:i + self.MBB_PARALLELISM]
                     for i in range(0, len(ams), self.MBB_PARALLELISM)]

            def run_wave(idx):
                def w():
                    wave = waves[idx]
                    cores = fs.cores_live[wave]
                    taken = _first_fit(cores, pool.free)
                    moved = wave[taken]
                    pool.used += float(cores[taken].sum())
                    # make-before-break: new up, traffic re-pointed,
                    # old instances terminated -> steady capacity freed
                    # (only for SEs actually accounted in the pool)
                    self.region.steady.stateless.release(float(
                        fs.cores_live[moved[fs.pool[moved]
                                            == POOL_STATELESS]].sum()))
                    fs.placement[moved] = PLACEMENT_BURST
                    fs.pool[moved] = POOL_NONE
                    for i in wave[~taken]:
                        rep.notes.append(
                            f"burst full; {fs.names[i]} stays in steady")
                    if self.on_migrate is not None:
                        for i in moved:
                            self.on_migrate(self._spec_of(int(i)))
                    obs.inc("ufa_orch_envs_total", int(len(moved)),
                            action="migrated")
                    self._snap()
                    if idx + 1 < len(waves):
                        self.loop.schedule(self.MBB_WAVE_S, run_wave(idx + 1),
                                           "mbb-wave")
                    else:
                        rep.am_migrated_at_s = self.loop.now - t0
                        self.loop.log("Active-Migrate migration complete")
                        scale_always_on()
                return w
            if waves:
                self.loop.schedule(self.MBB_WAVE_S, run_wave(0), "mbb-wave")
            else:
                rep.am_migrated_at_s = self.loop.now - t0
                scale_always_on()

        # 5. Always-On in-place expansion to absorb 2x traffic
        def scale_always_on():
            ao_mask = fs.fclass == AO
            need = float(fs.cores_live[ao_mask].sum()) * \
                (self.TRAFFIC_MULTIPLIER - 1.0)
            got = self.region.steady.stateless.alloc(need)
            if not got:
                # failover buffer + freed overcommit cover it by construction;
                # flag if not
                rep.always_on_ok = False
                rep.notes.append(
                    f"Always-On scale-up short by "
                    f"{need - self.region.steady.stateless.free:.0f} cores")
            else:
                fs.replicas_live[ao_mask] = (
                    fs.replicas_live[ao_mask]
                    * self.TRAFFIC_MULTIPLIER).astype(np.int64)
            self.loop.log("Always-On scaled for 2x traffic")
            self._snap()

        # 6. Restore-Later restoration within 1h RTO (burst, then cloud —
        #    cloud grants arrive after their provisioning delay, §4.6)
        def finalize_rl():
            rep.rl_restored_at_s = self.loop.now - t0
            rep.rl_rto_met = (rep.rl_restored_at_s <=
                              RTO_SECONDS[FailureClass.RESTORE_LATER])
            rep.cloud_cores_used = self.region.cloud.provisioned
            self.loop.log("Restore-Later restoration complete")

        def restore_rl():
            pool = burst_pool_holder["pool"]
            rls_idx = np.flatnonzero((fs.fclass == RL)
                                     & (fs.placement == PLACEMENT_DOWN))
            rls = rls_idx[np.argsort(fs.tier[rls_idx], kind="stable")]
            spec_cores = fs.spec_cores

            def activate(items: np.ndarray, pcode: int):
                fs.placement[items] = pcode
                fs.replicas_live[items] = fs.replicas[items]
                fs.traffic_enabled[items] = True
                if self.on_restore is not None:
                    for i in items:
                        self.on_restore(self._spec_of(int(i)))
                obs.inc("ufa_orch_envs_total", int(len(items)),
                        action="restored")

            def restore_batch(start):
                def w():
                    wave = rls[start:start + self.MBB_PARALLELISM]
                    cores = spec_cores[wave]
                    taken = _first_fit(cores, pool.free)
                    cloud_pos = np.flatnonzero(~taken)
                    cloud_cores = cores[cloud_pos]
                    quota_left = (self.region.cloud.quota_cores
                                  - self.region.cloud.provisioned)
                    granted = (np.cumsum(cloud_cores)
                               <= quota_left + 1e-9) if len(cloud_pos) else \
                        np.zeros(0, bool)
                    broke = bool(len(cloud_pos)) and not granted.all()
                    if broke:
                        # the first cloud failure aborts the wave: nothing
                        # after that SE (burst-eligible or not) is processed
                        j = int(cloud_pos[int(np.argmin(granted))])
                        rep.notes.append(
                            f"cloud quota exhausted at {fs.names[wave[j]]}")
                        wave, cores, taken = wave[:j], cores[:j], taken[:j]
                        cloud_pos = np.flatnonzero(~taken)
                    count = len(wave)
                    # burst restores are immediate
                    pool.used += float(cores[taken].sum())
                    activate(wave[taken], PLACEMENT_BURST)
                    # cloud restores wait for provisioning
                    if len(cloud_pos):
                        base = max(self.loop.now, self._cloud_ready_at)
                        items = wave[cloud_pos]
                        for i in items:
                            dt = self.region.cloud.provision_time(
                                spec_cores[i])
                            self.region.cloud.provision(spec_cores[i])
                            base += dt
                            rep.cloud_provision_s += dt
                        self._cloud_ready_at = base
                        self._pending_cloud += 1

                        def arrive(items=items):
                            activate(items, PLACEMENT_CLOUD)
                            self._pending_cloud -= 1
                            self._snap()
                            if self._pending_cloud == 0 and \
                                    self._rl_waves_done:
                                finalize_rl()
                        self.loop.schedule(base - self.loop.now, arrive,
                                           "cloud-provision")
                    self._snap()
                    nxt = start + count
                    if nxt < len(rls) and count > 0:
                        self.loop.schedule(self.RL_RESTORE_WAVE_S,
                                           restore_batch(nxt),
                                           "rl-restore-wave")
                    else:
                        self._rl_waves_done = True
                        if self._pending_cloud == 0:
                            finalize_rl()
                return w
            self.loop.schedule(self.RL_RESTORE_WAVE_S, restore_batch(0),
                               "rl-restore-wave")

        self.loop.run()
        self._snap()
        return rep

    # ------------------------------------------------------------------
    def failback(self) -> None:
        """Operator-triggered recovery (paper §4.7 / Fig 6)."""
        fs = self.fs
        self._state = "failback"
        self.loop.log("failback start")

        def move_back():
            away = ((fs.placement == PLACEMENT_BURST)
                    | (fs.placement == PLACEMENT_CLOUD))
            cores = fs.spec_cores
            for group, pool, code in (
                    (away & fs.preemptible, self.region.steady.overcommit,
                     POOL_OVERCOMMIT),
                    (away & ~fs.preemptible, self.region.steady.stateless,
                     POOL_STATELESS)):
                idx = np.flatnonzero(group)
                taken = _first_fit(cores[idx], pool.free)
                pool.used += float(cores[idx[taken]].sum())
                fs.pool[idx[taken]] = code
                fs.pool[idx[~taken]] = POOL_NONE
            fs.placement[away] = PLACEMENT_STEADY
            fs.replicas_live[away] = fs.replicas[away]
            ao_mask = fs.fclass == AO
            fs.replicas_live[ao_mask] = fs.replicas[ao_mask]  # shrink to 1x
            self._snap()

        def reenable_terminate():
            mask = (fs.fclass == TM) & (fs.placement == PLACEMENT_DOWN)
            fs.placement[mask] = PLACEMENT_STEADY
            fs.replicas_live[mask] = fs.replicas[mask]
            fs.traffic_enabled[mask] = True
            idx = np.flatnonzero(mask)
            cores = fs.cores_live
            taken = _first_fit(cores[idx], self.region.steady.overcommit.free)
            self.region.steady.overcommit.used += float(cores[idx[taken]].sum())
            fs.pool[idx[taken]] = POOL_OVERCOMMIT
            fs.pool[idx[~taken]] = POOL_NONE
            self._snap()

        def release_resources():
            # wait until 40% of batch capacity is freed before batch resumes
            self.region.batch.release()
            self.region.cloud.release_all()
            fs.locked[:] = False
            self._state = "steady"
            self.loop.log("failback complete; locks released")
            self._snap()

        self.loop.schedule(self.CITY_WAVE_S * 4, move_back, "traffic-back")
        self.loop.schedule(self.CITY_WAVE_S * 6, reenable_terminate,
                           "reenable-terminate")
        self.loop.schedule(self.CITY_WAVE_S * 10, release_resources,
                           "release-resources")
        self.loop.run()
