"""OMG — the UFA failover/failback orchestrator (paper §4.1, Figs 5/6).

Drives the full peak-failover sequence over the discrete-event loop:

  detect mode -> lockdown -> BBM-evict Terminate/Restore-Later ->
  batch->burst conversion (preheat: evict batch jobs + prefetch images) ->
  MBB-migrate Active-Migrate into burst, city-by-city traffic shift ->
  Always-On in-place scale-up into freed headroom ->
  Restore-Later restore in burst (+cloud as last resort) within 1h RTO ->
  (operator-triggered) failback mirroring the MBB flow.

The orchestrator operates on the synthesized fleet + RegionCapacity model
and emits a timestamped metrics timeline from which the paper's Figures
7-10 are reproduced.  Optional callbacks let the ML-serving layer execute
*real* preemption / re-deployment of model workloads in the examples.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.capacity import PoolState, RegionCapacity
from repro.core.events import EventLoop
from repro.core.service import ServiceSpec
from repro.core.tiers import RTO_SECONDS, FailureClass, Tier
from repro.core.traffic import FailoverModeDetector


@dataclasses.dataclass
class SEState:
    """Runtime state of one service-environment in the surviving region."""
    spec: ServiceSpec
    placement: str = "steady"       # steady | burst | cloud | down
    replicas_live: int = 0
    locked: bool = False
    traffic_enabled: bool = True

    @property
    def cores_live(self) -> float:
        return self.replicas_live * self.spec.cores_per_replica


@dataclasses.dataclass
class Timeline:
    t: List[float] = dataclasses.field(default_factory=list)
    series: Dict[str, List[float]] = dataclasses.field(default_factory=dict)

    def snap(self, now: float, **metrics: float):
        self.t.append(now)
        for k, v in metrics.items():
            self.series.setdefault(k, []).append(v)

    def at(self, key: str) -> List[Tuple[float, float]]:
        return list(zip(self.t, self.series[key]))


@dataclasses.dataclass
class FailoverReport:
    mode: str
    timeline: Timeline
    burst_full_at_s: Optional[float] = None
    am_migrated_at_s: Optional[float] = None
    rl_restored_at_s: Optional[float] = None
    rl_rto_met: bool = False
    cloud_cores_used: float = 0.0
    always_on_ok: bool = True
    evictions_first_hour: int = 0
    notes: List[str] = dataclasses.field(default_factory=list)


class Orchestrator:
    """UFA failover orchestration for one surviving region."""

    # tunables calibrated to the paper's reported behavior
    KILL_LATENCY_S = 5.0                 # cluster-level kill, bypasses workflows
    BATCH_EVICT_S = 90.0                 # preemptible batch jobs drain
    PREFETCH_S = 180.0                   # p2p image prefetch into burst zones
    SPAWN_CORES_PER_HOST_S = 0.45        # fig 7: burst fully online ~8 min
    MBB_WAVE_S = 45.0                    # one parallel migration wave
    MBB_PARALLELISM = 2000               # envs per wave (paper §4.3)
    RL_RESTORE_WAVE_S = 120.0
    CITY_WAVE_S = 30.0                   # city-group traffic moves
    TRAFFIC_MULTIPLIER = 2.0             # surviving region absorbs 2x

    def __init__(self, fleet: Dict[str, ServiceSpec], region: RegionCapacity,
                 loop: Optional[EventLoop] = None, scale: float = 1.0,
                 on_evict: Optional[Callable] = None,
                 on_migrate: Optional[Callable] = None,
                 on_restore: Optional[Callable] = None):
        self.fleet = fleet
        self.region = region
        self.loop = loop or EventLoop()
        self.scale = scale
        self.on_evict = on_evict
        self.on_migrate = on_migrate
        self.on_restore = on_restore
        self.detector = FailoverModeDetector()
        self.timeline = Timeline()
        self.se: Dict[str, SEState] = {}
        self._place_steady_state()
        self.report: Optional[FailoverReport] = None
        self._state = "steady"

    # ------------------------------------------------------------------
    def _place_steady_state(self):
        """Steady state: Always-On/Active-Migrate in the stateless pool,
        Restore-Later/Terminate opportunistically in the overcommit pool."""
        for name, spec in self.fleet.items():
            st = SEState(spec=spec, replicas_live=spec.replicas)
            pool = (self.region.steady.overcommit
                    if spec.failure_class.preemptible
                    else self.region.steady.stateless)
            ok = pool.alloc(st.cores_live)
            if not ok:  # overflow -> stateless pool (fragmentation slack)
                self.region.steady.stateless.alloc(st.cores_live)
                st.placement = "steady"
            self.se[name] = st

    def _by_class(self, fc: FailureClass) -> List[SEState]:
        return [s for s in self.se.values() if s.spec.failure_class == fc]

    def class_cores(self, fc: FailureClass, placement: Optional[str] = None
                    ) -> float:
        return sum(s.cores_live for s in self._by_class(fc)
                   if placement is None or s.placement == placement)

    def class_envs(self, fc: FailureClass, placement: str) -> int:
        return sum(1 for s in self._by_class(fc)
                   if s.placement == placement and s.replicas_live > 0)

    def _snap(self, **extra):
        burst = (self.region.batch.burst.used
                 if self.region.batch.burst else 0.0)
        burst_cap = (self.region.batch.burst.capacity
                     if self.region.batch.burst else 0.0)
        self.timeline.snap(
            self.loop.now,
            steady_used=self.region.steady.stateless.used,
            overcommit_used=self.region.steady.overcommit.used,
            burst_capacity=burst_cap,
            burst_used=burst,
            cloud_used=self.region.cloud.provisioned,
            rl_t_steady=(self.class_envs(FailureClass.RESTORE_LATER, "steady")
                         + self.class_envs(FailureClass.TERMINATE, "steady")),
            rl_bursted=self.class_envs(FailureClass.RESTORE_LATER, "burst")
            + self.class_envs(FailureClass.RESTORE_LATER, "cloud"),
            rl_not_bursted=sum(
                1 for s in self._by_class(FailureClass.RESTORE_LATER)
                if s.placement == "down"),
            terminated=sum(1 for s in self._by_class(FailureClass.TERMINATE)
                           if s.placement == "down"),
            am_steady=self.class_envs(FailureClass.ACTIVE_MIGRATE, "steady"),
            am_bursted=self.class_envs(FailureClass.ACTIVE_MIGRATE, "burst"),
            utilization=self._utilization(),
            **extra)

    def _utilization(self) -> float:
        # demand-weighted: live cores x traffic multiplier on critical SEs
        mult = self.TRAFFIC_MULTIPLIER if self._state != "steady" else 1.0
        busy = 0.0
        for s in self.se.values():
            if s.placement in ("steady",):
                demand = 0.62 if not s.spec.failure_class.preemptible else 0.35
                m = mult if s.spec.failure_class.survives_failover else 1.0
                busy += s.cores_live * demand * m
        return min(1.0, busy / max(1.0, self.region.steady.physical_cores))

    # ------------------------------------------------------------------
    # Failover
    # ------------------------------------------------------------------
    def failover(self, tv_failover: float = 1.0) -> FailoverReport:
        mode = self.detector.mode(tv_failover)
        rep = FailoverReport(mode=mode, timeline=self.timeline)
        self.report = rep
        self._state = "failover"
        self.loop.log(f"failover start, mode={mode}")
        self._snap()
        if mode == "non-peak":
            # only city traffic moves; nothing is preempted
            self.loop.schedule(self.CITY_WAVE_S * 4, lambda: self._snap())
            rep.always_on_ok = True
            rep.rl_rto_met = True
            self.loop.run()
            return rep

        # ---- peak mode ----
        t0 = self.loop.now
        # 1. lockdown
        for s in self.se.values():
            if s.spec.failure_class != FailureClass.ALWAYS_ON:
                s.locked = True
        self.loop.log("lockdown complete")

        # 2. immediate BBM eviction of Terminate + Restore-Later
        def evict_all():
            n = 0
            for s in self.se.values():
                if s.spec.failure_class.preemptible and s.placement == "steady":
                    freed = s.cores_live
                    self.region.steady.overcommit.release(freed)
                    self.region.steady.stateless.release(0.0)
                    s.placement = "down"
                    s.replicas_live = 0
                    s.traffic_enabled = False
                    n += 1
                    if self.on_evict:
                        self.on_evict(s.spec)
            self.loop.log(f"BBM evicted {n} preemptible SEs")
            self._snap()
        self.loop.schedule(self.KILL_LATENCY_S, evict_all, "bbm-evict")

        # 3. batch -> burst conversion (preheat)
        burst_pool_holder: Dict[str, PoolState] = {}

        def start_conversion():
            pool = self.region.batch.convert()
            pool_full = pool.capacity
            burst_pool_holder["pool"] = pool
            # capacity comes online progressively (spawner ramp, rate
            # proportional to batch-cluster host count -> scale-invariant)
            steps = 10
            rate = self.SPAWN_CORES_PER_HOST_S * self.region.batch.n_hosts
            ramp_total = pool_full / rate if pool_full > 0 else 0.0
            self._online = 0.0

            def make_tick(i):
                def tick():
                    frac = (i + 1) / steps
                    self._online = pool_full * frac
                    self._snap(burst_online=self._online)
                    if i == steps - 1:
                        rep.burst_full_at_s = self.loop.now - t0
                        self.loop.log("burst capacity fully online")
                        migrate_am()
                        restore_rl()
                return tick
            for i in range(steps):
                self.loop.schedule(ramp_total * (i + 1) / steps, make_tick(i))
        self.loop.schedule(self.BATCH_EVICT_S + self.PREFETCH_S,
                           start_conversion, "burst-conversion")

        # 4. MBB migration of Active-Migrate into burst
        def migrate_am():
            pool = burst_pool_holder["pool"]
            ams = [s for s in self._by_class(FailureClass.ACTIVE_MIGRATE)
                   if s.placement == "steady"]
            waves = [ams[i:i + self.MBB_PARALLELISM]
                     for i in range(0, len(ams), self.MBB_PARALLELISM)]

            def run_wave(idx):
                def w():
                    for s in waves[idx]:
                        if not pool.alloc(s.cores_live):
                            rep.notes.append(
                                f"burst full; {s.spec.name} stays in steady")
                            continue
                        # make-before-break: new up, traffic re-pointed,
                        # old instances terminated -> steady capacity freed
                        self.region.steady.stateless.release(s.cores_live)
                        s.placement = "burst"
                        if self.on_migrate:
                            self.on_migrate(s.spec)
                    self._snap()
                    if idx + 1 < len(waves):
                        self.loop.schedule(self.MBB_WAVE_S, run_wave(idx + 1))
                    else:
                        rep.am_migrated_at_s = self.loop.now - t0
                        self.loop.log("Active-Migrate migration complete")
                        scale_always_on()
                return w
            if waves:
                self.loop.schedule(self.MBB_WAVE_S, run_wave(0))
            else:
                rep.am_migrated_at_s = self.loop.now - t0
                scale_always_on()

        # 5. Always-On in-place expansion to absorb 2x traffic
        def scale_always_on():
            need = self.class_cores(FailureClass.ALWAYS_ON) * \
                (self.TRAFFIC_MULTIPLIER - 1.0)
            got = self.region.steady.stateless.alloc(need)
            if not got:
                # failover buffer + freed overcommit cover it by construction;
                # flag if not
                rep.always_on_ok = False
                rep.notes.append(
                    f"Always-On scale-up short by "
                    f"{need - self.region.steady.stateless.free:.0f} cores")
            else:
                for s in self._by_class(FailureClass.ALWAYS_ON):
                    s.replicas_live = int(
                        s.replicas_live * self.TRAFFIC_MULTIPLIER)
            self.loop.log("Always-On scaled for 2x traffic")
            self._snap()

        # 6. Restore-Later restoration within 1h RTO (burst, then cloud)
        def restore_rl():
            pool = burst_pool_holder["pool"]
            rls = sorted((s for s in self._by_class(FailureClass.RESTORE_LATER)
                          if s.placement == "down"),
                         key=lambda s: s.spec.tier)
            need = sum(s.cores_live or s.spec.cores for s in rls)

            def restore_batch(idx):
                def w():
                    i = idx
                    count = 0
                    while i < len(rls) and count < self.MBB_PARALLELISM:
                        s = rls[i]
                        cores = s.spec.cores
                        if pool.alloc(cores):
                            s.placement = "burst"
                        else:
                            granted = self.region.cloud.provision(cores)
                            if granted < cores:
                                rep.notes.append(
                                    f"cloud quota exhausted at {s.spec.name}")
                                break
                            s.placement = "cloud"
                        s.replicas_live = s.spec.replicas
                        s.traffic_enabled = True
                        if self.on_restore:
                            self.on_restore(s.spec)
                        i += 1
                        count += 1
                    self._snap()
                    if i < len(rls) and count > 0:
                        self.loop.schedule(self.RL_RESTORE_WAVE_S,
                                           restore_batch(i))
                    else:
                        rep.rl_restored_at_s = self.loop.now - t0
                        rep.rl_rto_met = (rep.rl_restored_at_s <=
                                          RTO_SECONDS[FailureClass.RESTORE_LATER])
                        rep.cloud_cores_used = self.region.cloud.provisioned
                        self.loop.log("Restore-Later restoration complete")
                return w
            self.loop.schedule(self.RL_RESTORE_WAVE_S, restore_batch(0))

        self.loop.run()
        self._snap()
        return rep

    # ------------------------------------------------------------------
    def failback(self) -> None:
        """Operator-triggered recovery (paper §4.7 / Fig 6)."""
        self._state = "failback"
        t0 = self.loop.now
        self.loop.log("failback start")

        def move_back():
            for s in self.se.values():
                if s.placement in ("burst", "cloud"):
                    pool = (self.region.steady.overcommit
                            if s.spec.failure_class.preemptible
                            else self.region.steady.stateless)
                    pool.alloc(s.spec.cores)
                    s.placement = "steady"
                    s.replicas_live = s.spec.replicas
                if s.spec.failure_class == FailureClass.ALWAYS_ON:
                    s.replicas_live = s.spec.replicas  # shrink to 1x
            self._snap()

        def reenable_terminate():
            for s in self._by_class(FailureClass.TERMINATE):
                if s.placement == "down":
                    s.placement = "steady"
                    s.replicas_live = s.spec.replicas
                    s.traffic_enabled = True
                    self.region.steady.overcommit.alloc(s.cores_live)
            self._snap()

        def release_resources():
            # wait until 40% of batch capacity is freed before batch resumes
            self.region.batch.release()
            self.region.cloud.release_all()
            for s in self.se.values():
                s.locked = False
            self._state = "steady"
            self.loop.log("failback complete; locks released")
            self._snap()

        self.loop.schedule(self.CITY_WAVE_S * 4, move_back, "traffic-back")
        self.loop.schedule(self.CITY_WAVE_S * 6, reenable_terminate)
        self.loop.schedule(self.CITY_WAVE_S * 10, release_resources)
        self.loop.run()
