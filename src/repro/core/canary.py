"""Canary regression gate (paper §6, third layer).

Always-On / Active-Migrate deployments entering the canary zone get a
5-minute window during which traffic to ALL Restore-Later/Terminate
services is blocked; if the canary's error metrics regress, the deployment
rolls back — a new fail-close dependency was about to ship.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Set, Tuple

from repro.core.service import ServiceSpec
from repro.core.tiers import FailureClass


@dataclasses.dataclass
class Deployment:
    service: str
    # newly introduced dependency (callee, fail_open) or None
    new_dep: Optional[Tuple[str, bool]] = None


@dataclasses.dataclass
class GateResult:
    deployment: Deployment
    passed: bool
    error_rate: float


class CanaryRegressionGate:
    """5-minute blackhole of preemptible callees + metric comparison."""

    BASELINE_ERROR = 0.0008
    REGRESSION_THRESHOLD = 0.004

    def __init__(self, fleet: Dict[str, ServiceSpec], seed: int = 0):
        self.fleet = fleet
        self.rng = random.Random(seed)
        self.rolled_back: List[Deployment] = []

    def _canary_error_rate(self, dep: Deployment) -> float:
        """Error rate observed while preemptible callees are blackholed."""
        base = max(0.0, self.rng.gauss(self.BASELINE_ERROR, 0.0002))
        spec = self.fleet.get(dep.service)
        if spec is None:
            return base
        # existing unsafe deps toward preemptible callees surface here too
        for callee in spec.unsafe_deps():
            if self.fleet[callee].failure_class.preemptible:
                base += 0.25
        if dep.new_dep is not None:
            callee, fail_open = dep.new_dep
            c = self.fleet.get(callee)
            if (c is not None and c.failure_class.preemptible
                    and not fail_open):
                base += self.rng.uniform(0.2, 0.6)  # hard failure under block
        return min(1.0, base)

    def evaluate(self, dep: Deployment) -> GateResult:
        spec = self.fleet.get(dep.service)
        if spec is None or not spec.failure_class.survives_failover:
            return GateResult(dep, True, 0.0)  # gate targets critical classes
        err = self._canary_error_rate(dep)
        passed = err < self.REGRESSION_THRESHOLD
        if not passed:
            self.rolled_back.append(dep)
        return GateResult(dep, passed, err)

    def run_window(self, n_deployments: int, regression_rate: float = 6e-5
                   ) -> Dict[str, object]:
        """Simulate a deployment stream (paper: ~8,000/week, 3 regressions
        caught in a 45-day window => ~4e-4 regression rate post-static)."""
        names = [n for n, s in self.fleet.items()
                 if s.failure_class.survives_failover]
        preemptible = [n for n, s in self.fleet.items()
                       if s.failure_class.preemptible]
        caught = 0
        shipped_bad = 0
        for i in range(n_deployments):
            svc = self.rng.choice(names)
            new_dep = None
            if preemptible and self.rng.random() < regression_rate:
                new_dep = (self.rng.choice(preemptible), False)  # fail-close!
            res = self.evaluate(Deployment(svc, new_dep))
            if new_dep is not None:
                if res.passed:
                    shipped_bad += 1
                else:
                    caught += 1
        return {"deployments": n_deployments, "regressions_caught": caught,
                "regressions_shipped": shipped_bad}
