"""Canary regression gate (paper §6, third layer).

Always-On / Active-Migrate deployments entering the canary zone get a
5-minute window during which traffic to ALL Restore-Later/Terminate
services is blocked; if the canary's error metrics regress, the deployment
rolls back — a new fail-close dependency was about to ship.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.service import ServiceSpec
from repro.core.tiers import FailureClass


@dataclasses.dataclass
class Deployment:
    service: str
    # newly introduced dependency (callee, fail_open) or None
    new_dep: Optional[Tuple[str, bool]] = None


@dataclasses.dataclass
class GateResult:
    deployment: Deployment
    passed: bool
    error_rate: float


class CanaryRegressionGate:
    """5-minute blackhole of preemptible callees + metric comparison."""

    BASELINE_ERROR = 0.0008
    BASELINE_SIGMA = 0.0002
    REGRESSION_THRESHOLD = 0.004
    UNSAFE_DEP_ERROR = 0.25           # per blackholed fail-close dep
    HARD_FAILURE_BUMP = (0.2, 0.6)    # new fail-close dep under the block

    def __init__(self, fleet: Dict[str, ServiceSpec], seed: int = 0):
        self.fleet = fleet
        self.rng = random.Random(seed)
        self._np_rng = np.random.default_rng(seed)
        self.rolled_back: List[Deployment] = []

    def _canary_error_rate(self, dep: Deployment) -> float:
        """Error rate observed while preemptible callees are blackholed."""
        base = max(0.0, self.rng.gauss(self.BASELINE_ERROR,
                                       self.BASELINE_SIGMA))
        spec = self.fleet.get(dep.service)
        if spec is None:
            return base
        # existing unsafe deps toward preemptible callees surface here too
        for callee in spec.unsafe_deps():
            if self.fleet[callee].failure_class.preemptible:
                base += self.UNSAFE_DEP_ERROR
        if dep.new_dep is not None:
            callee, fail_open = dep.new_dep
            c = self.fleet.get(callee)
            if (c is not None and c.failure_class.preemptible
                    and not fail_open):
                base += self.rng.uniform(*self.HARD_FAILURE_BUMP)
        return min(1.0, base)

    def evaluate(self, dep: Deployment) -> GateResult:
        spec = self.fleet.get(dep.service)
        if spec is None or not spec.failure_class.survives_failover:
            return GateResult(dep, True, 0.0)  # gate targets critical classes
        err = self._canary_error_rate(dep)
        passed = err < self.REGRESSION_THRESHOLD
        if not passed:
            self.rolled_back.append(dep)
        return GateResult(dep, passed, err)

    def run_window(self, n_deployments: int, regression_rate: float = 6e-5
                   ) -> Dict[str, object]:
        """Simulate a deployment stream (paper: ~8,000/week, 3 regressions
        caught in a 45-day window => ~4e-4 regression rate post-static).

        Vectorized: one array draw per decision — deployed service,
        regression injection, gaussian baseline error, hard-failure bump
        under the 5-minute blackhole — instead of a Python loop over 48k
        deployments; the model constants are the class attributes
        ``evaluate`` uses.  Rolled-back deployments still land on
        ``self.rolled_back`` (there are few; the stream itself is never
        materialized)."""
        stats = [(n, sum(1 for c in s.unsafe_deps()
                         if self.fleet[c].failure_class.preemptible))
                 for n, s in self.fleet.items()
                 if s.failure_class.survives_failover]
        names = [n for n, _ in stats]
        preemptible = [n for n, s in self.fleet.items()
                       if s.failure_class.preemptible]
        # existing unsafe deps toward preemptible callees surface under the
        # blackhole exactly as in the scalar model
        unsafe_bump = self.UNSAFE_DEP_ERROR * np.asarray(
            [k for _, k in stats], np.float64)
        rng = self._np_rng
        n = n_deployments
        svc = rng.integers(0, len(stats), n)
        err = np.clip(rng.normal(self.BASELINE_ERROR, self.BASELINE_SIGMA,
                                 n), 0.0, None)
        err += unsafe_bump[svc]
        regressed = (np.zeros(n, bool) if not preemptible
                     else rng.random(n) < regression_rate)
        # an injected regression is a new fail-close dep on a preemptible
        # callee: a hard failure while the canary blackhole is up
        err += np.where(regressed, rng.uniform(*self.HARD_FAILURE_BUMP, n),
                        0.0)
        err = np.minimum(err, 1.0)
        passed = err < self.REGRESSION_THRESHOLD
        failed = np.flatnonzero(~passed)
        callee = rng.integers(0, max(1, len(preemptible)), len(failed))
        for j, i in enumerate(failed):
            new_dep = ((preemptible[callee[j]], False)
                       if regressed[i] else None)
            self.rolled_back.append(Deployment(names[svc[i]], new_dep))
        return {"deployments": n_deployments,
                "regressions_caught": int((regressed & ~passed).sum()),
                "regressions_shipped": int((regressed & passed).sum())}
