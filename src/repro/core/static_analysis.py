"""Static fail-close analysis over a service IR (paper §6, second layer).

The paper's tool traces call paths across Go/Java codebases to decide
whether a downstream RPC error can propagate to the caller's response.  We
model a service's code as a small IR: functions containing *statements*;
an RPC callsite either PROPAGATES the error to its caller (Go: ``if err !=
nil { return err }`` / Java: unhandled throw), HANDLES it (fallback,
default, log-and-continue), or WRAPS it into a degraded-but-successful
response.  The analyzer walks the intra-service call graph from each
handler entrypoint and classifies every reachable RPC edge.

IR synthesis plants the fleet's ground-truth edge behavior, including the
cold-path defects that runtime analysis misses (they're still visible to
whole-program analysis).
"""

from __future__ import annotations

import dataclasses
import enum
import random
from typing import Dict, List, Optional, Set, Tuple

from repro.core.service import ServiceSpec


class ErrBehavior(enum.Enum):
    PROPAGATE = "propagate"   # fail-close at this site
    HANDLE = "handle"         # fail-open: swallowed/fallback
    WRAP_DEGRADED = "wrap"    # fail-open: degraded success


@dataclasses.dataclass
class Statement:
    kind: str                         # "rpc" | "call"
    target: str                       # callee service (rpc) or function (call)
    on_error: ErrBehavior = ErrBehavior.HANDLE


@dataclasses.dataclass
class Function:
    name: str
    body: List[Statement] = dataclasses.field(default_factory=list)
    # does this function propagate errors returned by its callees upward?
    propagates_callee_errors: bool = True


@dataclasses.dataclass
class ServiceIR:
    service: str
    entrypoints: List[str] = dataclasses.field(default_factory=list)
    functions: Dict[str, Function] = dataclasses.field(default_factory=dict)


def synthesize_ir(fleet: Dict[str, ServiceSpec], seed: int = 0,
                  max_depth: int = 3) -> Dict[str, ServiceIR]:
    """Builds an IR per service whose RPC error behavior realizes the
    fleet's planted fail_open/fail_close ground truth, burying some sites
    behind helper-function indirection (so naive per-function scans miss
    them but the whole-service walk does not)."""
    rng = random.Random(seed)
    irs: Dict[str, ServiceIR] = {}
    for name, spec in fleet.items():
        ir = ServiceIR(service=name)
        handler = Function(f"{name}.Handle", propagates_callee_errors=True)
        ir.functions[handler.name] = handler
        ir.entrypoints.append(handler.name)
        for i, dep in enumerate(spec.deps):
            fail_open = spec.fail_open.get(dep, True)
            behavior = (ErrBehavior.HANDLE if fail_open
                        else ErrBehavior.PROPAGATE)
            if fail_open and rng.random() < 0.3:
                behavior = ErrBehavior.WRAP_DEGRADED
            depth = rng.randint(0, max_depth)
            parent = handler
            for d in range(depth):
                helper = Function(f"{name}.helper_{i}_{d}",
                                  propagates_callee_errors=True)
                ir.functions[helper.name] = helper
                parent.body.append(Statement("call", helper.name))
                parent = helper
            parent.body.append(Statement("rpc", dep, on_error=behavior))
        irs[name] = ir
    return irs


class StaticFailCloseAnalyzer:
    """Whole-service walk: an RPC edge is fail-close iff some path from an
    entrypoint reaches the callsite AND the error propagates through every
    frame back to the entrypoint's response."""

    def analyze_service(self, ir: ServiceIR) -> Dict[str, bool]:
        verdicts: Dict[str, bool] = {}   # callee -> fail_close?

        def walk(fn_name: str, frames_propagate: bool, depth: int = 0,
                 seen: Optional[Set[str]] = None):
            seen = seen or set()
            if fn_name in seen or depth > 32:
                return
            seen = seen | {fn_name}
            fn = ir.functions.get(fn_name)
            if fn is None:
                return
            for st in fn.body:
                if st.kind == "rpc":
                    closes = (st.on_error == ErrBehavior.PROPAGATE
                              and frames_propagate)
                    verdicts[st.target] = verdicts.get(st.target, False) or closes
                else:
                    callee = ir.functions.get(st.target)
                    prop = frames_propagate and (
                        callee.propagates_callee_errors if callee else True)
                    walk(st.target, prop, depth + 1, seen)

        for ep in ir.entrypoints:
            walk(ep, True)
        return verdicts

    def analyze_fleet(self, irs: Dict[str, ServiceIR]
                      ) -> Set[Tuple[str, str]]:
        out: Set[Tuple[str, str]] = set()
        for name, ir in irs.items():
            for callee, closes in self.analyze_service(ir).items():
                if closes:
                    out.add((name, callee))
        return out


def static_analysis(fleet: Dict[str, ServiceSpec], seed: int = 0
                    ) -> Dict[str, object]:
    irs = synthesize_ir(fleet, seed)
    found = StaticFailCloseAnalyzer().analyze_fleet(irs)
    truth = {(s.name, d) for s in fleet.values() for d in s.unsafe_deps()}
    tp = found & truth
    from repro.graph import CallGraph
    return {
        "found": found,
        "graph": CallGraph.from_detections(fleet, found),
        "truth": truth,
        "true_positives": len(tp),
        "false_positives": len(found - truth),
        "missed": len(truth - found),
        "precision": len(tp) / max(1, len(found)),
        "recall": len(tp) / max(1, len(truth)),
    }
