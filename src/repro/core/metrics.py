"""Availability / utilization / cores-returned accounting (paper §8).

Pulls together the fleet + orchestration models into the quantities the
paper reports: request availability through a failover window (Fig 8),
regional CPU utilization (Fig 10), fleet utilization growth (Fig 11), and
the phased cores-returned schedule (Table 5).
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Tuple

from repro.core.capacity import RegionCapacity
from repro.core.omg import Orchestrator
from repro.core.service import ServiceSpec
from repro.core.tiers import BASELINE_CORES, FailureClass, Tier

BASELINE_AVAILABILITY = 0.9997   # ambient (paper Fig 8)


def availability_during_failover(fleet: Dict[str, ServiceSpec],
                                 orch: Orchestrator,
                                 n_samples: int = 48, seed: int = 3
                                 ) -> List[Tuple[float, float]]:
    """Core-flow availability sampled through the failover window.

    A critical request succeeds unless (a) ambient noise, (b) a fail-close
    dependency on a currently-down service fires, or (c) Always-On capacity
    is short (only if the orchestrator reported a shortfall).
    """
    rng = random.Random(seed)
    tl = orch.timeline
    out: List[Tuple[float, float]] = []
    unsafe = [(s, d) for s in fleet.values()
              if s.failure_class.survives_failover
              for d in s.unsafe_deps()]
    crit_cores = sum(s.cores for s in fleet.values()
                     if s.failure_class.survives_failover)
    if not tl.t:
        return [(0.0, BASELINE_AVAILABILITY)]
    t_end = tl.t[-1]
    rl_down_windows = []
    for i, t in enumerate(tl.t):
        down = tl.series.get("rl_not_bursted", [0] * len(tl.t))[i]
        rl_down_windows.append((t, down))

    # sample times are nondecreasing and rl_down_windows is time-ascending,
    # so one merged sweep replaces the per-sample rescan: advance a shared
    # pointer to the last window at or before t (O(n+m) total, same
    # last-match semantics as the scan it replaced)
    n_win = len(rl_down_windows)
    j = -1
    for i in range(n_samples):
        t = t_end * i / max(1, n_samples - 1)
        avail = BASELINE_AVAILABILITY + rng.gauss(0, 2e-5)
        # fail-close cascade: weight by affected caller cores
        while j + 1 < n_win and rl_down_windows[j + 1][0] <= t:
            j += 1
        down_now = rl_down_windows[j][1] if j >= 0 else 0.0
        if down_now > 0 and unsafe:
            affected = sum(s.cores for s, d in unsafe
                           if fleet.get(d) is not None
                           and fleet[d].failure_class.preemptible)
            avail -= 0.9 * affected / max(1.0, crit_cores)
        if orch.report is not None and not orch.report.always_on_ok:
            avail -= 0.05
        out.append((t, max(0.0, min(1.0, avail))))
    return out


def regional_utilization_series(orch: Orchestrator, demand_level: float = 0.565
                                ) -> List[Tuple[float, float]]:
    """Fig 10: physical-core utilization of the surviving region.  At the
    failover peak the paper reports ~50.2% average."""
    tl = orch.timeline
    out = []
    for i, t in enumerate(tl.t):
        live_steady = tl.series["steady_used"][i] + tl.series["overcommit_used"][i]
        phys = orch.region.steady.physical_cores
        out.append((t, min(1.0, demand_level * live_steady / max(1.0, phys))))
    return out


# ---------------------------------------------------------------------------
# Phased rollout (Table 5, Fig 11)
# ---------------------------------------------------------------------------

# (phase label, class freed, cores returned) — straight from Table 5
TABLE5_PHASES: List[Tuple[str, str, int]] = [
    ("Terminate class", "terminate", 263_000),
    ("Tier4/5 Restore-Later class", "restore_later", 62_000),
    ("Tier3 Restore-Later class", "restore_later", 159_000),
    ("Tier2+ Active-Migrate class", "active_migrate", 92_000),
    ("Tier1+ Active-Migrate class", "active_migrate", 455_000),
]

TOTAL_RETURNED = sum(c for _, _, c in TABLE5_PHASES)      # 1.031M
BBM_CLASSES = {"terminate", "restore_later"}


def phased_rollout(baseline_cores: float = 4.18e6,
                   months: int = 11,
                   demand_growth: float = 0.17,
                   start_utilization: float = 0.20
                   ) -> Dict[str, object]:
    """Reproduces Table 5 + Fig 11: cores returned per phase, BBM/MBB split,
    and fleet utilization trajectory 20% -> ~31%."""
    busy0 = baseline_cores * start_utilization
    returned = 0.0
    series = []
    per_phase = []
    for i, (label, cls, cores) in enumerate(TABLE5_PHASES):
        returned += cores
        frac = (i + 1) / len(TABLE5_PHASES)
        busy = busy0 * (1.0 + demand_growth * frac)
        provisioned = baseline_cores - returned
        series.append((frac * months, busy / provisioned))
        per_phase.append({"phase": label, "class": cls, "cores": cores,
                          "cumulative": int(returned),
                          "utilization": busy / provisioned})
    bbm = sum(c for _, cls, c in TABLE5_PHASES if cls in BBM_CLASSES)
    mbb = TOTAL_RETURNED - bbm
    return {
        "per_phase": per_phase,
        "total_returned": TOTAL_RETURNED,
        "bbm_cores": bbm, "mbb_cores": mbb,
        "bbm_fraction": bbm / TOTAL_RETURNED,
        "mbb_fraction": mbb / TOTAL_RETURNED,
        "utilization_series": series,
        "final_utilization": series[-1][1],
        "provisioning_multiple_before": 2.0,
        "provisioning_multiple_after": 2.0 * (baseline_cores - TOTAL_RETURNED)
        / baseline_cores,
    }


def failover_minutes_history() -> Dict[int, float]:
    """Fig 2: yearly full-peak failover minutes (~<20h/yr on average, 0.23%
    of the year at the 2021 anomaly, declining trend)."""
    return {2020: 540.0, 2021: 1210.0, 2022: 420.0, 2023: 260.0}


def failover_counts_history() -> Dict[int, int]:
    """Fig 3: yearly regional failover counts (declining 2020-2023)."""
    return {2020: 24, 2021: 16, 2022: 13, 2023: 11}
