"""Minimal deterministic discrete-event simulation kernel.

OMG orchestration, drills and the failover benchmarks all run on this: a
priority queue of (time, seq, fn) with a monotonically advancing clock.

Observability: attaching a ``repro.obs.Tracer`` (``loop.tracer = t``, or
``Orchestrator(..., tracer=t)``) turns every fired event into a sim-time
span on the Chrome trace — spanning *scheduled-at → fired-at*, i.e. the
window the orchestration was waiting on that action (handlers run in
zero sim-time; their host wall-time is attached as an arg) — and every
``log()`` into an instant marker.  With no tracer attached the loop does
no per-event bookkeeping at all.
"""

from __future__ import annotations

import heapq
import itertools
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro import obs


class EventLoop:
    def __init__(self):
        # (fire_time, seq, fn, label)
        self._q: List[Tuple[float, int, Callable, str]] = []
        self._seq = itertools.count()
        self.now: float = 0.0
        self._trace: List[Tuple[float, str]] = []
        self.tracer = None                    # optional repro.obs.Tracer
        self._sched: Dict[int, float] = {}    # seq -> scheduled-at (tracer only)

    def schedule(self, delay: float, fn: Callable, label: str = ""):
        assert delay >= 0, delay
        seq = next(self._seq)
        if self.tracer is not None:
            self._sched[seq] = self.now
        heapq.heappush(self._q, (self.now + delay, seq, fn, label))

    def log(self, msg: str):
        self._trace.append((self.now, msg))
        if self.tracer is not None:
            self.tracer.sim_instant(msg, self.now)

    @property
    def trace(self):
        return list(self._trace)

    def run(self, until: Optional[float] = None,
            max_events: int = 10_000_000) -> int:
        n = 0
        while self._q:
            if n >= max_events:
                # fail LOUDLY: a schedule that re-enqueues itself (e.g. a
                # buggy chaos-storm schedule) used to spin to the cap and
                # silently return a half-run simulation
                head_t, _, head_fn, head_label = self._q[0]
                raise RuntimeError(
                    f"EventLoop.run exceeded max_events={max_events} at "
                    f"sim time {self.now:.3f}s with {len(self._q)} events "
                    f"still queued (next: "
                    f"{head_label or getattr(head_fn, '__name__', 'event')!r}"
                    f" at t={head_t:.3f}s) — a schedule is likely "
                    f"re-enqueueing itself; raise max_events if the "
                    f"workload is legitimately this large")
            t, seq, fn, label = heapq.heappop(self._q)
            if until is not None and t > until:
                # re-push with the ORIGINAL seq: a fresh seq would reorder
                # this event behind later-scheduled same-time events on the
                # next run() call
                heapq.heappush(self._q, (t, seq, fn, label))
                break
            self.now = max(self.now, t)
            if self.tracer is not None:
                name = label or getattr(fn, "__name__", "event")
                t_sched = self._sched.pop(seq, t)
                host0 = time.perf_counter()
                fn()
                self.tracer.sim_span(
                    name, t_sched, t,
                    args={"host_ms": round(
                        (time.perf_counter() - host0) * 1e3, 3)})
            else:
                fn()
            if obs.enabled():
                obs.inc("ufa_orch_events_total",
                        label=label or getattr(fn, "__name__", "event"))
            n += 1
        return n
