"""Minimal deterministic discrete-event simulation kernel.

OMG orchestration, drills and the failover benchmarks all run on this: a
priority queue of (time, seq, fn) with a monotonically advancing clock.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Callable, List, Optional, Tuple


class EventLoop:
    def __init__(self):
        # (fire_time, seq, fn, label)
        self._q: List[Tuple[float, int, Callable, str]] = []
        self._seq = itertools.count()
        self.now: float = 0.0
        self._trace: List[Tuple[float, str]] = []

    def schedule(self, delay: float, fn: Callable, label: str = ""):
        assert delay >= 0, delay
        heapq.heappush(self._q, (self.now + delay, next(self._seq), fn, label))

    def log(self, msg: str):
        self._trace.append((self.now, msg))

    @property
    def trace(self):
        return list(self._trace)

    def run(self, until: Optional[float] = None,
            max_events: int = 10_000_000) -> int:
        n = 0
        while self._q and n < max_events:
            t, _, fn, label = heapq.heappop(self._q)
            if until is not None and t > until:
                heapq.heappush(self._q, (t, next(self._seq), fn, label))
                break
            self.now = max(self.now, t)
            fn()
            n += 1
        return n
