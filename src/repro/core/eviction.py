"""QoS controller and utilization-aware placement (paper §4.4).

Safety mechanism (1): evict overcommit pods when a node exceeds 75% CPU,
cooling it below 70%.  Safety mechanism (2): placement prefers the least
utilized hosts.  The host population is modeled explicitly here (unlike the
aggregate pools in capacity.py) because the paper's eviction-rate result
(312/hr peak vs 160/hr baseline, concentrated in the first failover hour)
is a host-tail phenomenon.

The controller sweep is vectorized: hosts/pods flatten into parallel
arrays, hot hosts and their victim sets fall out of a segmented-cumsum
over (host, -busy)-sorted preemptible pods.  ``HostArrays`` is the
array-native population for paper-scale sweeps (~40K hosts / ~850K pods
per pass); the object-based ``Host`` API converts through the same path.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Tuple, Union

import numpy as np

from repro.core.tiers import (QOS_COOL_UTILIZATION, QOS_EVICT_UTILIZATION,
                              FailureClass)


@dataclasses.dataclass
class HostPod:
    service: str
    cores: float
    preemptible: bool
    utilization: float = 0.35     # demand as fraction of requested cores


@dataclasses.dataclass
class Host:
    hid: int
    cores: float = 100.0
    pods: List[HostPod] = dataclasses.field(default_factory=list)

    def busy_cores(self) -> float:
        return sum(p.cores * p.utilization for p in self.pods)

    def utilization(self) -> float:
        return self.busy_cores() / self.cores


@dataclasses.dataclass
class HostArrays:
    """Struct-of-arrays host population (paper-scale sweeps)."""
    host_cores: np.ndarray        # (H,) float
    pod_host: np.ndarray          # (P,) int32
    pod_cores: np.ndarray         # (P,) float
    pod_util: np.ndarray          # (P,) float
    pod_pre: np.ndarray           # (P,) bool
    alive: np.ndarray             # (P,) bool — False once evicted

    @property
    def n_hosts(self) -> int:
        return len(self.host_cores)

    @property
    def n_pods(self) -> int:
        return int(np.count_nonzero(self.alive))

    def host_busy(self) -> np.ndarray:
        busy = self.pod_cores * self.pod_util * self.alive
        return np.bincount(self.pod_host, weights=busy,
                           minlength=self.n_hosts)

    def utilization(self) -> np.ndarray:
        return self.host_busy() / self.host_cores


def _select_victims(pod_host: np.ndarray, pod_busy: np.ndarray,
                    candidate: np.ndarray, host_busy: np.ndarray,
                    host_cores: np.ndarray, evict_at: float,
                    cool_to: float) -> np.ndarray:
    """Flat pod indices to evict, in (host asc, busy desc) order: on each
    host above ``evict_at``, drop the busiest preemptible pods until
    utilization falls to ``cool_to``.  One lexsort + one segmented
    exclusive-cumsum — no per-host Python loop."""
    hot = host_busy > evict_at * host_cores
    cand = candidate & hot[pod_host]
    idx = np.flatnonzero(cand)
    if len(idx) == 0:
        return idx
    order = np.lexsort((-pod_busy[idx], pod_host[idx]))
    sidx = idx[order]
    sb = pod_busy[sidx]
    sh = pod_host[sidx]
    cum_excl = np.cumsum(sb) - sb
    seg_start = np.empty(len(sidx), bool)
    seg_start[0] = True
    seg_start[1:] = sh[1:] != sh[:-1]
    base = np.maximum.accumulate(np.where(seg_start, cum_excl, -np.inf))
    freed_before = cum_excl - base
    evict = host_busy[sh] - freed_before > cool_to * host_cores[sh]
    return sidx[evict]


class QoSController:
    """Evict-above-75 / cool-below-70 on a host population (``Host`` list
    or array-native ``HostArrays``)."""

    def __init__(self, hosts: Union[List[Host], HostArrays],
                 evict_at: float = QOS_EVICT_UTILIZATION,
                 cool_to: float = QOS_COOL_UTILIZATION):
        self.hosts = hosts
        self.evict_at = evict_at
        self.cool_to = cool_to
        self.evictions: List[Tuple[float, int, str]] = []  # (t, host, service)

    def sweep(self, now: float) -> int:
        """One controller pass; returns number of evictions."""
        if isinstance(self.hosts, HostArrays):
            return self._sweep_arrays(self.hosts, now)
        return self._sweep_hosts(self.hosts, now)

    def _sweep_arrays(self, ha: HostArrays, now: float) -> int:
        busy = ha.pod_cores * ha.pod_util * ha.alive
        victims = _select_victims(ha.pod_host, busy, ha.pod_pre & ha.alive,
                                  ha.host_busy(), ha.host_cores,
                                  self.evict_at, self.cool_to)
        ha.alive[victims] = False
        self.evictions.extend(
            (now, int(ha.pod_host[j]), f"pod-{int(j)}") for j in victims)
        return len(victims)

    def _sweep_hosts(self, hosts: List[Host], now: float) -> int:
        flat = [(hi, p) for hi, h in enumerate(hosts) for p in h.pods]
        if not flat:
            return 0
        pod_host = np.fromiter((hi for hi, _ in flat), np.int64, len(flat))
        busy = np.fromiter((p.cores * p.utilization for _, p in flat),
                           np.float64, len(flat))
        pre = np.fromiter((p.preemptible for _, p in flat), bool, len(flat))
        host_cores = np.fromiter((h.cores for h in hosts), np.float64,
                                 len(hosts))
        host_busy = np.bincount(pod_host, weights=busy, minlength=len(hosts))
        victims = _select_victims(pod_host, busy, pre, host_busy, host_cores,
                                  self.evict_at, self.cool_to)
        for j in victims:
            hi, p = flat[j]
            hosts[hi].pods.remove(p)
            self.evictions.append((now, hosts[hi].hid, p.service))
        return len(victims)

    def place(self, pod: HostPod) -> Optional[Host]:
        """Utilization-aware placement: least-utilized feasible host."""
        assert not isinstance(self.hosts, HostArrays), \
            "object-pod placement needs the Host-list population"
        best = None
        for h in self.hosts:
            free = h.cores - sum(p.cores for p in h.pods)
            if free < pod.cores:
                continue
            if best is None or h.utilization() < best.utilization():
                best = h
        if best is not None:
            best.pods.append(pod)
        return best


def make_host_population(n_hosts: int, seed: int = 0,
                         critical_fill: float = 0.45,
                         preempt_fill: float = 0.25,
                         cores: float = 100.0) -> List[Host]:
    """Hosts packed with a mix of critical + preemptible pods (the paper
    co-hosts all four classes on each host deliberately)."""
    rng = random.Random(seed)
    hosts = []
    for i in range(n_hosts):
        h = Host(hid=i, cores=cores)
        filled = 0.0
        target = cores * critical_fill * rng.uniform(0.7, 1.3)
        j = 0
        while filled < target:
            c = rng.choice([0.5, 1, 2, 4])
            h.pods.append(HostPod(f"crit-{i}-{j}", c, preemptible=False,
                                  utilization=max(0.05, rng.gauss(0.35, 0.12))))
            filled += c
            j += 1
        filled = 0.0
        target = cores * preempt_fill * rng.uniform(0.6, 1.4)
        while filled < target:
            c = rng.choice([0.5, 1, 2, 4])
            h.pods.append(HostPod(f"pre-{i}-{j}", c, preemptible=True,
                                  utilization=max(0.05, rng.gauss(0.35, 0.15))))
            filled += c
            j += 1
        hosts.append(h)
    return hosts


def make_host_arrays(n_hosts: int, seed: int = 0,
                     critical_fill: float = 0.45,
                     preempt_fill: float = 0.25,
                     cores: float = 100.0) -> HostArrays:
    """Array-native population: same statistical shape as
    ``make_host_population`` with no per-pod Python objects (~850K pods at
    the paper's 40K-host deployment)."""
    rng = np.random.default_rng(seed)
    sizes = np.array([0.5, 1.0, 2.0, 4.0])
    mean_pod = sizes.mean()

    hosts_pods, hosts_pre = [], []
    for fill, spread, pre in ((critical_fill, (0.7, 1.3), False),
                              (preempt_fill, (0.6, 1.4), True)):
        target = cores * fill * rng.uniform(*spread, n_hosts)
        count = np.maximum(1, np.round(target / mean_pod)).astype(np.int64)
        hosts_pods.append(count)
        hosts_pre.append(pre)

    pod_host, pod_pre = [], []
    for count, pre in zip(hosts_pods, hosts_pre):
        pod_host.append(np.repeat(np.arange(n_hosts), count))
        pod_pre.append(np.full(int(count.sum()), pre))
    pod_host = np.concatenate(pod_host).astype(np.int32)
    pod_pre = np.concatenate(pod_pre)
    n_pods = len(pod_host)
    pod_cores = rng.choice(sizes, n_pods)
    sigma = np.where(pod_pre, 0.15, 0.12)
    pod_util = np.maximum(0.05, rng.normal(0.35, sigma))
    return HostArrays(host_cores=np.full(n_hosts, cores),
                      pod_host=pod_host, pod_cores=pod_cores,
                      pod_util=pod_util, pod_pre=pod_pre,
                      alive=np.ones(n_pods, bool))


def failover_eviction_trace(n_hosts: int = 40_000, hours: int = 12,
                            failover_hour: int = 6, seed: int = 7
                            ) -> Dict[str, object]:
    """Reproduces the §8 eviction analysis over a deployment of ~850K pods
    (~40K hosts x ~21 pods): hourly QoS-eviction counts around a failover.

    Host busy-fraction peaks are modeled N(mu(t), sigma) with mu following
    the diurnal load; a host whose peak crosses the 75% threshold has ~1.2
    pods evicted to cool below 70%.  Calibration targets the paper: baseline
    *peak* ~160/hr, failover-hour spike ~312/hr (~2x), near-zero off-peak,
    with the spike concentrated in the first failover hour.
    """
    rng = random.Random(seed)
    sigma = 0.1213
    evict_per_hot_host = 1.2
    per_hour: List[int] = []
    for hour in range(hours):
        # diurnal busy mean: off-peak 0.30 .. daily-peak 0.42
        diurnal = 0.5 - 0.5 * math.cos(2 * math.pi * (hour % 24) / 24.0)
        mu = 0.30 + 0.12 * diurnal
        if hour == failover_hour:
            mu = 0.449   # 2x-traffic surge while burst capacity ramps
        elif hour == failover_hour + 1:
            mu = max(mu, 0.36)  # residual elevation, then back to ambient
        z = (QOS_EVICT_UTILIZATION - mu) / sigma
        p = 0.5 * math.erfc(z / math.sqrt(2))
        # binomial(n_hosts, p) via normal approximation + jitter
        mean = n_hosts * p
        std = math.sqrt(max(1e-9, n_hosts * p * (1 - p)))
        n_hot = max(0, int(round(rng.gauss(mean, std))))
        per_hour.append(int(round(n_hot * evict_per_hot_host)))
    baseline_peak = max(c for i, c in enumerate(per_hour)
                        if i not in (failover_hour, failover_hour + 1))
    return {"per_hour": per_hour, "peak": max(per_hour),
            "failover_hour": failover_hour,
            "baseline_peak": max(1, baseline_peak),
            "peak_over_baseline": max(per_hour) / max(1, baseline_peak)}
