"""QoS controller and utilization-aware placement (paper §4.4).

Safety mechanism (1): evict overcommit pods when a node exceeds 75% CPU,
cooling it below 70%.  Safety mechanism (2): placement prefers the least
utilized hosts.  The host population is modeled explicitly here (unlike the
aggregate pools in capacity.py) because the paper's eviction-rate result
(312/hr peak vs 160/hr baseline, concentrated in the first failover hour)
is a host-tail phenomenon.
"""

from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Optional, Tuple

from repro.core.tiers import (QOS_COOL_UTILIZATION, QOS_EVICT_UTILIZATION,
                              FailureClass)


@dataclasses.dataclass
class HostPod:
    service: str
    cores: float
    preemptible: bool
    utilization: float = 0.35     # demand as fraction of requested cores


@dataclasses.dataclass
class Host:
    hid: int
    cores: float = 100.0
    pods: List[HostPod] = dataclasses.field(default_factory=list)

    def busy_cores(self) -> float:
        return sum(p.cores * p.utilization for p in self.pods)

    def utilization(self) -> float:
        return self.busy_cores() / self.cores


class QoSController:
    """Evict-above-75 / cool-below-70 on a host population."""

    def __init__(self, hosts: List[Host],
                 evict_at: float = QOS_EVICT_UTILIZATION,
                 cool_to: float = QOS_COOL_UTILIZATION):
        self.hosts = hosts
        self.evict_at = evict_at
        self.cool_to = cool_to
        self.evictions: List[Tuple[float, int, str]] = []  # (t, host, service)

    def sweep(self, now: float) -> int:
        """One controller pass; returns number of evictions."""
        n = 0
        for h in self.hosts:
            if h.utilization() <= self.evict_at:
                continue
            # evict preemptible pods (highest-utilization first) until cool
            victims = sorted((p for p in h.pods if p.preemptible),
                             key=lambda p: -p.cores * p.utilization)
            for v in victims:
                if h.utilization() <= self.cool_to:
                    break
                h.pods.remove(v)
                self.evictions.append((now, h.hid, v.service))
                n += 1
        return n

    def place(self, pod: HostPod) -> Optional[Host]:
        """Utilization-aware placement: least-utilized feasible host."""
        best = None
        for h in self.hosts:
            free = h.cores - sum(p.cores for p in h.pods)
            if free < pod.cores:
                continue
            if best is None or h.utilization() < best.utilization():
                best = h
        if best is not None:
            best.pods.append(pod)
        return best


def make_host_population(n_hosts: int, seed: int = 0,
                         critical_fill: float = 0.45,
                         preempt_fill: float = 0.25,
                         cores: float = 100.0) -> List[Host]:
    """Hosts packed with a mix of critical + preemptible pods (the paper
    co-hosts all four classes on each host deliberately)."""
    rng = random.Random(seed)
    hosts = []
    for i in range(n_hosts):
        h = Host(hid=i, cores=cores)
        filled = 0.0
        target = cores * critical_fill * rng.uniform(0.7, 1.3)
        j = 0
        while filled < target:
            c = rng.choice([0.5, 1, 2, 4])
            h.pods.append(HostPod(f"crit-{i}-{j}", c, preemptible=False,
                                  utilization=max(0.05, rng.gauss(0.35, 0.12))))
            filled += c
            j += 1
        filled = 0.0
        target = cores * preempt_fill * rng.uniform(0.6, 1.4)
        while filled < target:
            c = rng.choice([0.5, 1, 2, 4])
            h.pods.append(HostPod(f"pre-{i}-{j}", c, preemptible=True,
                                  utilization=max(0.05, rng.gauss(0.35, 0.15))))
            filled += c
            j += 1
        hosts.append(h)
    return hosts


def failover_eviction_trace(n_hosts: int = 40_000, hours: int = 12,
                            failover_hour: int = 6, seed: int = 7
                            ) -> Dict[str, object]:
    """Reproduces the §8 eviction analysis over a deployment of ~850K pods
    (~40K hosts x ~21 pods): hourly QoS-eviction counts around a failover.

    Host busy-fraction peaks are modeled N(mu(t), sigma) with mu following
    the diurnal load; a host whose peak crosses the 75% threshold has ~1.2
    pods evicted to cool below 70%.  Calibration targets the paper: baseline
    *peak* ~160/hr, failover-hour spike ~312/hr (~2x), near-zero off-peak,
    with the spike concentrated in the first failover hour.
    """
    rng = random.Random(seed)
    sigma = 0.1213
    evict_per_hot_host = 1.2
    per_hour: List[int] = []
    for hour in range(hours):
        # diurnal busy mean: off-peak 0.30 .. daily-peak 0.42
        diurnal = 0.5 - 0.5 * math.cos(2 * math.pi * (hour % 24) / 24.0)
        mu = 0.30 + 0.12 * diurnal
        if hour == failover_hour:
            mu = 0.449   # 2x-traffic surge while burst capacity ramps
        elif hour == failover_hour + 1:
            mu = max(mu, 0.36)  # residual elevation, then back to ambient
        z = (QOS_EVICT_UTILIZATION - mu) / sigma
        p = 0.5 * math.erfc(z / math.sqrt(2))
        # binomial(n_hosts, p) via normal approximation + jitter
        mean = n_hosts * p
        std = math.sqrt(max(1e-9, n_hosts * p * (1 - p)))
        n_hot = max(0, int(round(rng.gauss(mean, std))))
        per_hour.append(int(round(n_hot * evict_per_hot_host)))
    baseline_peak = max(c for i, c in enumerate(per_hour)
                        if i not in (failover_hour, failover_hour + 1))
    return {"per_hour": per_hour, "peak": max(per_hour),
            "failover_hour": failover_hour,
            "baseline_peak": max(1, baseline_peak),
            "peak_over_baseline": max(per_hour) / max(1, baseline_peak)}
