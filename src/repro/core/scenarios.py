"""Scenario-ensemble failover analysis (vmapped analytic capacity model).

Resilience claims need *ensembles* of failure scenarios, not one trace
(Basiri et al., chaos engineering): this module closes the loop by
evaluating the UFA failover capacity model over a grid of scenario
parameters in one ``jax.vmap`` — per-scenario SLA verdicts and an
availability estimate for hundreds/thousands of variants in milliseconds.

The analytic model mirrors the discrete-event orchestrator's arithmetic
(same sizing rules, same wave/ramp constants) but collapses time to the
closed-form completion points, which is what makes it vmappable.

Scenario axes:
  traffic_mult        surviving-region traffic multiplier (paper: 2.0)
  burst_delay_s       preheat delay before burst capacity starts ramping
  burst_availability  fraction of batch capacity actually convertible
  cloud_quota_frac    multiplier on the region's cloud quota
  overcommit_factor   host-level overcommit (paper: 1.5, O_max 1.66)
  evict_fraction      fraction of preemptible demand actually evicted
"""

from __future__ import annotations

import dataclasses
import itertools
import zlib
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import capacity as C
from repro.core.fleet_state import FleetState
from repro.core.omg import Orchestrator
from repro.core.tiers import QOS_EVICT_UTILIZATION, RTO_SECONDS, FailureClass

# single-source constants: orchestrator tunables + region-sizing rules —
# retuning either automatically retunes the scenario certification
_SLACK = C.DEFAULT_SLACK
_SPAWN_CORES_PER_HOST_S = Orchestrator.SPAWN_CORES_PER_HOST_S
_BATCH_CORES_PER_HOST = C.BATCH_CORES_PER_HOST
_MBB_WAVE_S = Orchestrator.MBB_WAVE_S
_MBB_PARALLELISM = Orchestrator.MBB_PARALLELISM
_RL_WAVE_S = Orchestrator.RL_RESTORE_WAVE_S
_PREHEAT_S = Orchestrator.BATCH_EVICT_S + Orchestrator.PREFETCH_S
_RL_RTO_S = RTO_SECONDS[FailureClass.RESTORE_LATER]
_QOS_EVICT = QOS_EVICT_UTILIZATION
_BASE_AVAILABILITY = 0.9997


def stage_seed(seed: int, stage: str) -> int:
    """Derive an independent integer seed for a named pipeline stage from
    one campaign seed.

    A single chaos-campaign/ensemble ``seed`` parameterizes several
    random stages (the blackhole draws, the cascade-storm draws, the
    correlated fault sampler).  Reusing the raw integer for each stage
    correlates their streams — e.g. the dependency ensemble's uniform
    draws and the sweep engine's draws used to be the SAME stream.  This
    folds the crc32 of the stage name into a ``jax.random`` key, so every
    (seed, stage) pair maps to an independent stream while the whole
    campaign stays reproducible from the one seed."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed),
                             zlib.crc32(stage.encode()) & 0x7FFFFFFF)
    return int(jax.random.randint(key, (), 0, np.iinfo(np.int32).max))


@dataclasses.dataclass(frozen=True)
class FleetAggregates:
    """Class-level core/env totals — all the analytic model needs."""
    ao_cores: float
    am_cores: float
    rl_cores: float
    tm_cores: float
    am_envs: int
    rl_envs: int

    @property
    def total_cores(self) -> float:
        return self.ao_cores + self.am_cores + self.rl_cores + self.tm_cores

    @classmethod
    def from_fleet_state(cls, fs: FleetState) -> "FleetAggregates":
        from repro.core.fleet_state import AM, RL
        ao, am, rl, tm = fs.class_core_totals()
        return cls(ao_cores=ao, am_cores=am, rl_cores=rl, tm_cores=tm,
                   am_envs=int(np.count_nonzero(fs.fclass == AM)),
                   rl_envs=int(np.count_nonzero(fs.fclass == RL)))

    @classmethod
    def from_fleet(cls, fleet: Dict[str, "object"]) -> "FleetAggregates":
        fs = FleetState.from_specs(fleet)
        return cls.from_fleet_state(fs)


def scenario_grid(traffic_mult=(1.6, 1.8, 2.0, 2.2),
                  burst_delay_s=(180.0, 270.0, 360.0, 600.0),
                  burst_availability=(1.0, 0.85, 0.7, 0.5),
                  cloud_quota_frac=(1.0, 0.5, 0.25, 0.0),
                  overcommit_factor=(1.5,),
                  evict_fraction=(1.0,)) -> Dict[str, np.ndarray]:
    """Cartesian scenario grid, flattened to parallel parameter arrays
    (defaults: 4^4 = 256 variants around the paper's operating point)."""
    axes = dict(traffic_mult=traffic_mult, burst_delay_s=burst_delay_s,
                burst_availability=burst_availability,
                cloud_quota_frac=cloud_quota_frac,
                overcommit_factor=overcommit_factor,
                evict_fraction=evict_fraction)
    rows = list(itertools.product(*axes.values()))
    cols = np.asarray(rows, np.float64).T
    return {k: cols[i] for i, k in enumerate(axes)}


def operating_point_mask(grid: Dict[str, np.ndarray]) -> np.ndarray:
    """Boolean mask selecting the paper's operating point in a scenario
    grid: 2x traffic, normal preheat, full burst, full cloud quota, full
    eviction — the single scenario the event-driven orchestrator runs."""
    return ((np.asarray(grid["traffic_mult"]) == 2.0)
            & (np.asarray(grid["burst_delay_s"]) == 270.0)
            & (np.asarray(grid["burst_availability"]) == 1.0)
            & (np.asarray(grid["cloud_quota_frac"]) == 1.0)
            & (np.asarray(grid["evict_fraction"]) == 1.0))


def _scenario_outcome(consts: Dict[str, jnp.ndarray],
                      p: Dict[str, jnp.ndarray], tau=None):
    """SLA outcome of ONE scenario (all scalars — vmapped over the grid).

    ``tau`` (opt-in soft relaxation, see ``timeline_sim.soft_ge``): the
    hard boolean verdicts become sigmoid indicators of the signed margins
    and ``sla_ok`` their product, so ``jax.grad`` flows through the
    closed-form model — the capacity optimizer's analytic stage.
    ``tau=None`` (the default) traces the original ops, bit-identical."""
    from repro.core.timeline_sim import (SOFT_DEP_SCALE, SOFT_FRAC_SCALE,
                                         SOFT_TIME_SCALE, soft_ge)
    ao, am = consts["ao"], consts["am"]
    rl, tm = consts["rl"], consts["tm"]
    am_envs, rl_envs = consts["am_envs"], consts["rl_envs"]

    mult = p["traffic_mult"]
    oc = p["overcommit_factor"]
    evict = p["evict_fraction"]
    # eviction-order shifts (optim.capacity): per-class deltas on the
    # evicted fraction, additive so a present-but-zero knob is exact
    d_rl = p.get("rl_evict_delta", 0.0)
    d_tm = p.get("tm_evict_delta", 0.0)
    cs = 0.01 * (ao + am + rl + tm)          # cores-margin scale (soft)

    # region sizing (same rule as RegionCapacity.for_fleet, model="ufa");
    # the optimizer overrides the hand-tuned 2x Always-On buffer via the
    # optional ``ao_buffer`` const (1 + buffer fraction) — key-conditional
    # so legacy consts trace the identical program
    buf = consts["ao_buffer"] if "ao_buffer" in consts else 2.0
    stateless = (buf * ao + am) * _SLACK
    # partial-region degradation (chaos fault family): a fraction of the
    # surviving region's serving capacity is lost — not a binary
    # blackhole.  Conditional on key presence so legacy grids trace the
    # identical program; x * (1 - 0) is exact in float32, so a present-
    # but-zero knob is a bitwise no-op.
    if "region_degradation" in p:
        stateless = stateless * (1.0 - p["region_degradation"])
    oc_cap = stateless * (oc - 1.0)
    preempt_resident = ((rl + tm) * (1.0 - evict)
                        - (rl * d_rl + tm * d_tm))
    if tau is None:
        preempt_fit = preempt_resident <= oc_cap + 1e-6
    else:
        preempt_fit = soft_ge(oc_cap + 1e-6, preempt_resident, cs, tau)

    # batch -> burst conversion (same sizing rule as for_fleet); the
    # optional ``spawn_mult`` const is the optimizer's burst-conversion
    # ramp knob (spawner throughput multiplier)
    batch_cores = (am + rl) * C.BATCH_BURST_HEADROOM \
        / C.BATCH_PREEMPTIBLE_FRACTION
    burst_cap = (batch_cores * C.BATCH_PREEMPTIBLE_FRACTION
                 * p["burst_availability"])
    spawn_rate = _SPAWN_CORES_PER_HOST_S * batch_cores / _BATCH_CORES_PER_HOST
    if "spawn_mult" in consts:
        spawn_rate = spawn_rate * consts["spawn_mult"]
    burst_full_s = p["burst_delay_s"] + burst_cap / jnp.maximum(spawn_rate,
                                                                1e-9)

    # Active-Migrate MBB into burst
    am_in_burst = jnp.minimum(am, burst_cap)
    am_waves = jnp.ceil(am_envs / _MBB_PARALLELISM)
    am_done_s = burst_full_s + am_waves * _MBB_WAVE_S
    am_stranded = am - am_in_burst            # stays in steady if burst full

    # Always-On in-place scale-up into freed headroom
    free_after_am = stateless - ao - am + am_in_burst
    ao_need = ao * (mult - 1.0)
    ao_short = jnp.maximum(0.0, ao_need - free_after_am)
    if tau is None:
        ao_ok = ao_short <= 1e-6
    else:
        # signed margin (ao_short is one-sided: 0 exactly at the boundary
        # would read 0.5 through the sigmoid)
        ao_ok = soft_ge(free_after_am + 1e-6, ao_need, cs, tau)

    # Restore-Later: burst first, cloud (with provisioning latency) last
    burst_left = jnp.maximum(0.0, burst_cap - am_in_burst)
    rl_need = rl * evict + rl * d_rl          # evicted RL demand to restore
    rl_in_burst = jnp.minimum(rl_need, burst_left)
    cloud_need = rl_need - rl_in_burst
    quota = C.default_cloud_quota(rl) * p["cloud_quota_frac"]
    cloud_grant = jnp.minimum(cloud_need, quota)
    rl_down = cloud_need - cloud_grant
    # default_cloud_rate via its constants (python max() is not trace-safe)
    cloud_rate = jnp.maximum(C.CLOUD_RATE_FLOOR,
                             rl / C.CLOUD_RATE_RL_DIVISOR)
    cloud_delay = cloud_grant / cloud_rate
    rl_waves = jnp.ceil(rl_envs / _MBB_PARALLELISM)
    rl_done_s = burst_full_s + rl_waves * _RL_WAVE_S + cloud_delay
    if tau is None:
        rl_ok = (rl_down <= 1e-6) & (rl_done_s <= _RL_RTO_S)
    else:
        # signed fit margin: quota vs. what must come from the cloud
        # (rl_down is one-sided, same boundary problem as ao_short); the
        # +1.0-core shift keeps the fully-served point deep in the "ok"
        # tail instead of on the 0.5 knife edge
        rl_ok = (soft_ge(quota + 1.0, cloud_need, cs, tau)
                 * soft_ge(_RL_RTO_S, rl_done_s, SOFT_TIME_SCALE, tau))

    # surviving-region utilization at the post-migration peak
    busy = (ao * mult * 0.62 + am_in_burst * 0.0
            + am_stranded * 0.62 * mult + preempt_resident * 0.35)
    util_peak = busy / jnp.maximum(stateless, 1.0)
    if tau is None:
        util_ok = util_peak <= _QOS_EVICT
    else:
        util_ok = soft_ge(_QOS_EVICT, util_peak, SOFT_FRAC_SCALE, tau)

    # availability estimate: AO shortfall bites immediately; unrestored RL
    # degrades the fraction of critical flows that (safely) depend on it;
    # every critical service the dependency-graph propagation says *breaks*
    # under this scenario's blackhole is hard-down for the failover window
    crit = jnp.maximum(ao + am, 1.0)
    rl_exposure = 0.1 * rl_down / jnp.maximum(rl, 1.0)
    window_frac = jnp.minimum(1.0, rl_done_s / _RL_RTO_S)
    dep_broken = p["dep_broken_frac"]
    if tau is None:
        dep_ok = dep_broken <= 0.0
        availability = (_BASE_AVAILABILITY
                        - 0.5 * ao_short / crit
                        - rl_exposure * window_frac
                        - 0.5 * dep_broken
                        - jnp.where(util_ok, 0.0, 1e-4))
        availability = jnp.clip(availability, 0.0, 1.0)
        sla_ok = (ao_ok & rl_ok & preempt_fit & dep_ok
                  & (am_done_s <= 30.0 * 60.0)
                  & (burst_full_s <= 20.0 * 60.0) & util_ok)
    else:
        # broken-critical fractions are quantized at 1/n_crit (~2e-4 for
        # paper-scale fleets): a 1e-7 threshold with a 1e-6 scale keeps
        # "nothing broken" (exactly 0) in the ok tail and the smallest
        # nonzero fraction firmly refused
        dep_ok = soft_ge(1e-7, dep_broken, SOFT_DEP_SCALE, tau)
        availability = (_BASE_AVAILABILITY
                        - 0.5 * ao_short / crit
                        - rl_exposure * window_frac
                        - 0.5 * dep_broken
                        - 1e-4 * (1.0 - util_ok))
        availability = jnp.clip(availability, 0.0, 1.0)
        sla_ok = (ao_ok * rl_ok * preempt_fit * dep_ok
                  * soft_ge(30.0 * 60.0, am_done_s, SOFT_TIME_SCALE, tau)
                  * soft_ge(20.0 * 60.0, burst_full_s, SOFT_TIME_SCALE, tau)
                  * util_ok)
    # cascading dependency storm (chaos fault family): the storm's dark
    # set re-breaks ``storm_broken_frac`` of criticals with pulse
    # amplitude ``storm_refrac`` while the timeline kernel re-darkens the
    # restored capacity; the closed-form mirror charges the exposure
    # once.  Conditional-key + exact-at-zero, like degradation above.
    if "storm_refrac" in p:
        storm_frac = p.get("storm_broken_frac", 0.0)
        storm_exposure = storm_frac * p["storm_refrac"]
        availability = jnp.clip(availability - 0.5 * storm_exposure,
                                0.0, 1.0)
        if tau is None:
            storm_ok = storm_exposure <= 1e-6
            sla_ok = sla_ok & storm_ok
        else:
            storm_ok = soft_ge(1e-7, storm_exposure, SOFT_DEP_SCALE, tau)
            sla_ok = sla_ok * storm_ok
    out = {
        "dep_broken_frac": dep_broken,
        "dep_ok": dep_ok,
        "burst_full_s": burst_full_s,
        "am_done_s": am_done_s,
        "rl_done_s": rl_done_s,
        "rl_down_cores": rl_down,
        "cloud_grant_cores": cloud_grant,
        "cloud_delay_s": cloud_delay,
        "util_peak": util_peak,
        "ao_ok": ao_ok,
        "rl_ok": rl_ok,
        "preempt_fit": preempt_fit,
        "util_ok": util_ok,
        "availability": availability,
        "sla_ok": sla_ok,
    }
    if "storm_refrac" in p and "storm_broken_frac" in p:
        # emitted only when the storm stage supplied a traced verdict (a
        # vmapped output must not be a trace-time constant)
        out["storm_ok"] = storm_ok
        out["storm_broken_frac"] = storm_frac
    return out


# public kernel entry point: the fused sweep engine vmaps this (one
# scalar scenario) fused with the timeline scan and the dependency
# penalty — same ops as the standalone sweep, hence bit-identical
scenario_outcome = _scenario_outcome


def analytic_consts(agg: FleetAggregates, *, ao_buffer=None,
                    spawn_mult=None) -> Dict[str, jnp.ndarray]:
    """f32 device constants for ``scenario_outcome`` (precomputed once,
    passed as traced arguments so the jit cache is keyed on shapes, not
    fleet values).

    ``ao_buffer`` / ``spawn_mult`` (optional floats, the capacity
    optimizer's hooks): when given, they are added as consts keys and
    ``scenario_outcome`` replaces the hand-tuned 2x Always-On sizing
    coefficient / scales the burst spawner throughput.  Absent keys trace
    the original program — the historical sweeps stay bit-identical."""
    out = {"ao": jnp.asarray(agg.ao_cores, jnp.float32),
           "am": jnp.asarray(agg.am_cores, jnp.float32),
           "rl": jnp.asarray(agg.rl_cores, jnp.float32),
           "tm": jnp.asarray(agg.tm_cores, jnp.float32),
           "am_envs": jnp.asarray(agg.am_envs, jnp.float32),
           "rl_envs": jnp.asarray(agg.rl_envs, jnp.float32)}
    if ao_buffer is not None:
        out["ao_buffer"] = jnp.asarray(ao_buffer, jnp.float32)
    if spawn_mult is not None:
        out["spawn_mult"] = jnp.asarray(spawn_mult, jnp.float32)
    return out


# compiled once per (grid-shape, consts-structure); reused across sweeps
_sweep_jit = jax.jit(jax.vmap(_scenario_outcome, in_axes=(None, 0)))


def sweep_scenarios(agg: FleetAggregates,
                    grid: Optional[Dict[str, np.ndarray]] = None,
                    dep_broken_frac: Optional[np.ndarray] = None,
                    timeline: Optional[object] = None,
                    ts: Optional[np.ndarray] = None
                    ) -> Dict[str, np.ndarray]:
    """Evaluate the failover model over every scenario in one vmap.

    dep_broken_frac: optional per-scenario fraction of critical services
    the dependency-graph blackhole propagation says break (see
    ``sweep_with_dependency_ensemble``); defaults to 0 everywhere (a fully
    hardened fleet).

    timeline: optional ``timeline_sim.TimelineConfig`` — also runs the
    vmapped discrete-time timeline kernel over the same grid and merges
    its *temporal* verdicts (per-tier time-to-restore, availability
    integral vs 99.97%, peak on-demand cloud draw, temporal SLA) under
    ``t_``-prefixed keys alongside the analytic ones.  ``ts`` overrides
    the default 2h/240-step grid."""
    from repro.core.timeline_sim import validate_grid
    grid = grid if grid is not None else scenario_grid()
    n = validate_grid(grid)
    if timeline is not None:
        # one fused, sharded, jitted pipeline: analytic model + timeline
        # scan in a single vmap (the t_-prefixed temporal verdicts come
        # from the same compiled program, no host round-trip between
        # stages) — see repro.core.sweep_engine
        from repro.core.sweep_engine import SweepEngine
        eng = SweepEngine(agg, timeline, ts=ts)
        return eng.run(grid, dep_broken_frac=dep_broken_frac)
    consts = analytic_consts(agg)
    params = {k: jnp.asarray(v, jnp.float32) for k, v in grid.items()}
    if dep_broken_frac is None:
        dep_broken_frac = np.zeros(n)
    params["dep_broken_frac"] = jnp.asarray(dep_broken_frac, jnp.float32)
    out = _sweep_jit(consts, params)
    result = {k: np.asarray(v) for k, v in out.items()}
    result.update({k: np.asarray(v) for k, v in grid.items()})
    return result


def sweep_with_dependency_ensemble(fs: FleetState,
                                   grid: Optional[Dict[str, np.ndarray]]
                                   = None,
                                   seed: int = 0,
                                   temporal: bool = False,
                                   region: Optional[object] = None,
                                   ts: Optional[np.ndarray] = None
                                   ) -> Dict[str, np.ndarray]:
    """Scenario sweep with the dependency layer closed in: each scenario's
    ``evict_fraction`` sets its blackhole intensity — that fraction of
    preemptible services goes dark, with the uniform draws shared across
    scenarios, so equal fractions share one dark set and differing
    fractions give *nested* sets (vary the grid's ``evict_fraction`` axis
    for ensemble diversity).  One batched multi-hop propagation certifies
    the whole ensemble and the per-scenario broken-critical fractions feed
    the availability estimate/SLA verdicts.

    temporal=True additionally runs the discrete-time timeline kernel
    over the grid (sizing a region for ``fs`` unless ``region`` is given)
    and folds the same propagation verdicts into the availability
    *trace*: a broken critical's penalty decays as its dark dependencies
    restore, and the ``t_``-prefixed temporal verdicts land next to the
    analytic ones."""
    from repro.graph import CallGraph
    grid = grid if grid is not None else scenario_grid()
    graph = CallGraph.from_fleet_state(fs)
    agg = FleetAggregates.from_fleet_state(fs)
    # one campaign seed, independent per-stage streams: the ensemble
    # stage and the fused engine stage used to consume the SAME raw
    # integer — identical uniform draws, so any analysis comparing the
    # two paths saw perfectly correlated "independent" ensembles.  Each
    # stage now folds its name into the campaign seed (``stage_seed``),
    # keeping the whole run reproducible from the one integer.
    if temporal:
        # the fused engine: propagation + analytic model + timeline scan
        # in ONE jitted, device-parallel pipeline (sweep_engine) — the
        # per-scenario broken-critical verdicts never touch the host
        # before the availability trace consumes them
        from repro.core.sweep_engine import SweepEngine
        from repro.core.timeline_sim import config_for_fleet
        timeline = config_for_fleet(fs, region=region)
        eng = SweepEngine(agg, timeline, graph=graph,
                          seed=stage_seed(seed, "sweep-engine"), ts=ts)
        return eng.run(grid)
    from repro.graph import blackhole_ensemble
    ens = blackhole_ensemble(graph, seed=stage_seed(seed, "blackhole-ensemble"),
                             fractions=np.asarray(grid["evict_fraction"]))
    result = sweep_scenarios(agg, grid,
                             dep_broken_frac=ens["broken_critical_frac"])
    # int32, matching the fused temporal path's device-computed counts
    result["dep_n_broken_critical"] = np.asarray(ens["n_broken_critical"],
                                                 np.int32)
    result["dep_n_dark"] = np.asarray(ens["n_dark"], np.int32)
    return result


def summarize_sweep(result: Dict[str, np.ndarray]) -> Dict[str, object]:
    n = len(result["sla_ok"])
    ok = int(result["sla_ok"].sum())
    out = {
        "n_scenarios": n,
        "n_sla_ok": ok,
        "sla_ok_fraction": ok / max(1, n),
        "availability_min": float(result["availability"].min()),
        "availability_mean": float(result["availability"].mean()),
        "worst_rl_done_min": float(result["rl_done_s"].max() / 60.0),
        "worst_util_peak": float(result["util_peak"].max()),
    }
    if "dep_ok" in result:
        out["n_dep_ok"] = int(result["dep_ok"].sum())
        out["worst_dep_broken_frac"] = float(
            result["dep_broken_frac"].max())
    if "t_sla_ok" in result:        # temporal verdicts present
        finite = result["t_rl_done_s"][np.isfinite(result["t_rl_done_s"])]
        out["n_t_sla_ok"] = int(result["t_sla_ok"].sum())
        out["n_analytic_temporal_agree"] = int(
            (result["sla_ok"] == result["t_sla_ok"]).sum())
        out["t_availability_mean_min"] = float(
            result["t_availability_mean"].min())
        out["t_worst_finite_rl_done_min"] = (
            float(finite.max() / 60.0) if len(finite) else float("nan"))
        out["t_n_rl_never_restored"] = int(
            np.isinf(result["t_rl_done_s"]).sum())
        out["t_peak_cloud_cores_max"] = float(
            result["t_peak_cloud_cores"].max())
    return out


def scenario_records(result: Dict[str, np.ndarray]) -> list:
    """Per-scenario verdict rows (JSON-serializable) for the bench log."""
    keys = ["traffic_mult", "burst_delay_s", "burst_availability",
            "cloud_quota_frac", "overcommit_factor", "evict_fraction",
            "burst_full_s", "rl_done_s", "util_peak", "availability",
            "ao_ok", "rl_ok", "util_ok", "sla_ok"]
    n = len(result["sla_ok"])
    return [{k: (bool(result[k][i]) if result[k].dtype == bool
                 else round(float(result[k][i]), 6)) for k in keys}
            for i in range(n)]
