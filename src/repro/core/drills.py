"""Validation drills (paper §5): dependency-safety certification and UFA
failover certification.

Dependency safety: graduated traffic blackholing (0% -> 100%) toward
Restore-Later/Terminate services; a critical service is certified only if
its error rate stays at baseline under complete dependency isolation.

Failover certification: runs the end-to-end OMG workflow at peak and
non-peak and checks every class SLA.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Set, Tuple

from repro.core.capacity import RegionCapacity
from repro.core.omg import FailoverReport, Orchestrator
from repro.core.service import ServiceSpec
from repro.core.tiers import RTO_SECONDS, FailureClass


BLACKHOLE_STEPS = (0.0, 0.1, 0.25, 0.5, 0.75, 1.0)


@dataclasses.dataclass
class CertResult:
    service: str
    certified: bool
    failing_deps: List[str]
    max_error_rate: float


def _error_rate_under_blackhole(spec: ServiceSpec,
                                fleet: Dict[str, ServiceSpec],
                                fraction: float, rng: random.Random,
                                baseline: float = 0.0003) -> float:
    """Caller error rate when `fraction` of traffic to preemptible callees
    is blackholed: fail-open deps degrade gracefully; fail-close propagate."""
    err = max(0.0, rng.gauss(baseline, 1e-4))
    for callee in spec.deps:
        c = fleet.get(callee)
        if c is None or not c.failure_class.preemptible:
            continue
        if not spec.fail_open.get(callee, True):
            err += fraction * 0.9      # hard failure propagates
    return min(1.0, err)


def dependency_safety_certification(fleet: Dict[str, ServiceSpec],
                                    seed: int = 0,
                                    error_budget: float = 0.002
                                    ) -> Dict[str, CertResult]:
    """Graduated blackholing for every critical service."""
    rng = random.Random(seed)
    results: Dict[str, CertResult] = {}
    for name, spec in fleet.items():
        if not spec.failure_class.survives_failover:
            continue
        worst = 0.0
        for frac in BLACKHOLE_STEPS:
            worst = max(worst,
                        _error_rate_under_blackhole(spec, fleet, frac, rng))
            if worst > error_budget:
                break  # abort the drill early, exactly like production
        failing = [d for d in spec.unsafe_deps()
                   if fleet.get(d) is not None
                   and fleet[d].failure_class.preemptible]
        results[name] = CertResult(service=name,
                                   certified=worst <= error_budget,
                                   failing_deps=failing,
                                   max_error_rate=worst)
    return results


def remediate(fleet: Dict[str, ServiceSpec],
              edges: Set[Tuple[str, str]],
              strategy: str = "fail_open") -> int:
    """Apply the paper's remediation strategies to detected unsafe edges:
    code-level fail-open conversion (default), or up-tiering the callee."""
    n = 0
    for caller, callee in edges:
        spec = fleet.get(caller)
        if spec is None or callee not in spec.fail_open:
            continue
        if spec.fail_open[callee]:
            continue
        if strategy == "fail_open":
            spec.fail_open[callee] = True
        elif strategy == "up_tier":
            target = fleet[callee]
            target.failure_class = FailureClass.ACTIVE_MIGRATE
        n += 1
    return n


@dataclasses.dataclass
class FailoverCertification:
    peak_report: FailoverReport
    classes_ok: Dict[str, bool]
    availability_ok: bool
    certified: bool


def failover_certification(fleet: Dict[str, ServiceSpec],
                           scale: float = 1.0,
                           overcommit_factor: float = 1.5
                           ) -> FailoverCertification:
    """End-to-end drill: full-peak failover with all cities moved."""
    region = RegionCapacity.for_fleet("drill-region", fleet,
                                      overcommit_factor=overcommit_factor)
    orch = Orchestrator(fleet, region, scale=scale)
    rep = orch.failover(tv_failover=1.0)   # full peak
    classes_ok = {
        "always_on": rep.always_on_ok,
        "active_migrate": (rep.am_migrated_at_s or 1e18) <= 30 * 60,
        "restore_later": rep.rl_rto_met,
        "burst_under_20min": (rep.burst_full_at_s or 1e18) <= 20 * 60,
    }
    # availability: critical services must not depend fail-close on anything
    # that was preempted
    unsafe_hit = [
        (s.name, d) for s in fleet.values()
        if s.failure_class.survives_failover
        for d in s.unsafe_deps()
        if fleet.get(d) is not None and fleet[d].failure_class.preemptible]
    availability_ok = not unsafe_hit and rep.always_on_ok
    orch.failback()
    return FailoverCertification(
        peak_report=rep, classes_ok=classes_ok,
        availability_ok=availability_ok,
        certified=availability_ok and all(classes_ok.values()))
