"""Validation drills (paper §5): dependency-safety certification and UFA
failover certification.

Dependency safety: graduated traffic blackholing (0% -> 100%) toward
Restore-Later/Terminate services; a critical service is certified only if
its error rate stays at baseline under complete dependency isolation.
Whether a service breaks under the blackhole comes from the graph
engine's *multi-hop* fixed-point propagation (``repro.graph``): fail-close
chains relay failure any number of hops up the call graph, so a critical
service with no direct unsafe dependency still fails the drill if a
critical callee of it breaks.  The graduated error-rate model is then
vectorized over the whole fleet at once — one (steps x services) pass
certifies every critical service simultaneously at paper scale.

Failover certification: runs the end-to-end OMG workflow at peak and
non-peak and checks every class SLA; its availability verdict uses the
same propagation engine.
"""

from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Set, Tuple

import numpy as np

from repro.core.capacity import RegionCapacity
from repro.core.fleet_state import RL, FleetState
from repro.core.omg import FailoverReport, Orchestrator
from repro.core.service import ServiceSpec
from repro.core.tiers import RTO_SECONDS, FailureClass

BLACKHOLE_STEPS = (0.0, 0.1, 0.25, 0.5, 0.75, 1.0)
BASELINE_ERROR = 0.0003


@dataclasses.dataclass
class CertResult:
    service: str
    certified: bool
    failing_deps: List[str]
    max_error_rate: float


def _error_rate_under_blackhole(spec: ServiceSpec,
                                fleet: Dict[str, ServiceSpec],
                                fraction: float, rng: random.Random,
                                baseline: float = BASELINE_ERROR) -> float:
    """Scalar reference of the error model (kept for spot checks): caller
    error rate when `fraction` of traffic to preemptible callees is
    blackholed — fail-open deps degrade gracefully; fail-close propagate."""
    err = max(0.0, rng.gauss(baseline, 1e-4))
    for callee in spec.deps:
        c = fleet.get(callee)
        if c is None or not c.failure_class.preemptible:
            continue
        if not spec.fail_open.get(callee, True):
            err += fraction * 0.9      # hard failure propagates
    return min(1.0, err)


def _blackhole_worst(breaks: np.ndarray, seed: int,
                     error_budget: float) -> np.ndarray:
    """Worst observed error rate per caller over the graduated blackhole
    steps, with production semantics: the drill aborts at the first step
    whose error exceeds the budget.  ``breaks`` is the per-caller break
    indicator (>=1.0 where the multi-hop propagation says the service
    breaks under a full blackhole, 0.0 where it degrades gracefully)."""
    rng = np.random.default_rng(seed)
    fracs = np.asarray(BLACKHOLE_STEPS)
    n = len(breaks)
    noise = np.clip(rng.normal(BASELINE_ERROR, 1e-4, (len(fracs), n)),
                    0.0, None)
    errs = np.minimum(1.0, noise + fracs[:, None] * 0.9
                      * breaks[None, :])
    exceeded = errs > error_budget
    aborted = exceeded.any(axis=0)
    first = np.argmax(exceeded, axis=0)
    return np.where(aborted, errs[first, np.arange(n)], errs.max(axis=0))


def dependency_safety_certification(fleet: Dict[str, ServiceSpec],
                                    seed: int = 0,
                                    error_budget: float = 0.002
                                    ) -> Dict[str, CertResult]:
    """Graduated blackholing for every critical service (one vectorized
    pass over the whole fleet, multi-hop via the graph engine)."""
    from repro.graph import CallGraph, certify
    graph = CallGraph.from_specs(fleet)
    cert = certify(graph)            # dark = every preemptible service
    worst = _blackhole_worst(cert.broken_critical.astype(float), seed,
                             error_budget)

    broken = {graph.names[i] for i in np.flatnonzero(cert.broken)}
    results: Dict[str, CertResult] = {}
    for i, (name, spec) in enumerate(fleet.items()):
        if not spec.failure_class.survives_failover:
            continue
        # the fail-close deps that actually carried the failure in
        # (multi-hop: a broken *critical* callee counts too)
        failing = [d for d in spec.unsafe_deps() if d in broken]
        results[name] = CertResult(service=name,
                                   certified=bool(worst[i] <= error_budget),
                                   failing_deps=failing,
                                   max_error_rate=float(worst[i]))
    return results


def certify_fleet_state(fs: FleetState, seed: int = 0,
                        error_budget: float = 0.002) -> Dict[str, object]:
    """Array-native blackhole certification over a ``FleetState`` (requires
    edge arrays): multi-hop propagation decides who breaks, the graduated
    error model decides who gets flagged.  Returns summary counts + the
    flagged-caller mask."""
    from repro.graph import CallGraph, certify
    assert fs.edges is not None, "FleetState synthesized without edges"
    graph = CallGraph.from_fleet_state(fs)
    cert = certify(graph)
    worst = _blackhole_worst(cert.broken_critical.astype(float), seed,
                             error_budget)
    crit = fs.survives
    flagged = crit & (worst > error_budget)
    e = fs.edges
    unsafe_edge = (~e.fail_open) & (fs.fclass[e.dst] >= RL)
    return {
        "n_critical": int(np.count_nonzero(crit)),
        "n_certified": int(np.count_nonzero(crit & ~flagged)),
        "n_flagged": int(np.count_nonzero(flagged)),
        "flagged_mask": flagged,
        "unsafe_edges": int(np.count_nonzero(
            unsafe_edge & fs.survives[e.src])),
        # multi-hop extras: criticals broken only through relay chains,
        # and how many propagation rounds the fixed point took
        "n_multi_hop": int(np.count_nonzero(cert.multi_hop)),
        "propagation_rounds": cert.rounds,
    }


def remediate(fleet: Dict[str, ServiceSpec],
              edges: Set[Tuple[str, str]],
              strategy: str = "fail_open") -> int:
    """Apply the paper's remediation strategies to detected unsafe edges:
    code-level fail-open conversion (default), or up-tiering the callee."""
    n = 0
    for caller, callee in edges:
        spec = fleet.get(caller)
        if spec is None or callee not in spec.fail_open:
            continue
        if spec.fail_open[callee]:
            continue
        if strategy == "fail_open":
            spec.fail_open[callee] = True
        elif strategy == "up_tier":
            target = fleet[callee]
            target.failure_class = FailureClass.ACTIVE_MIGRATE
        n += 1
    return n


@dataclasses.dataclass
class FailoverCertification:
    peak_report: FailoverReport
    classes_ok: Dict[str, bool]
    availability_ok: bool
    certified: bool


def failover_certification(fleet: Dict[str, ServiceSpec],
                           scale: float = 1.0,
                           overcommit_factor: float = 1.5
                           ) -> FailoverCertification:
    """End-to-end drill: full-peak failover with all cities moved."""
    region = RegionCapacity.for_fleet("drill-region", fleet,
                                      overcommit_factor=overcommit_factor)
    orch = Orchestrator(fleet, region, scale=scale)
    rep = orch.failover(tv_failover=1.0)   # full peak
    classes_ok = {
        "always_on": rep.always_on_ok,
        "active_migrate": (rep.am_migrated_at_s or 1e18) <= 30 * 60,
        "restore_later": rep.rl_rto_met,
        "burst_under_20min": (rep.burst_full_at_s or 1e18) <= 20 * 60,
    }
    # availability: no critical service may break — multi-hop — when the
    # preempted (blackholed) services go dark
    from repro.graph import CallGraph, certify
    dep_cert = certify(CallGraph.from_specs(fleet))
    availability_ok = dep_cert.ok and rep.always_on_ok
    orch.failback()
    return FailoverCertification(
        peak_report=rep, classes_ok=classes_ok,
        availability_ok=availability_ok,
        certified=availability_ok and all(classes_ok.values()))
