"""City traffic model, peak detection, and the failover-mode rule.

The orchestrator's peak/non-peak decision (§4.1) is:
    mode = PEAK  iff  tv_failover >= T * tv_peak
with tv_peak the past week's peak and T the periodically-recomputed
threshold (the paper pins the *definition* of a peak failure at 85% of
weekly peak riders-on-trip).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence

from repro.core.tiers import FULL_FAILOVER_CITY_FRACTION, PEAK_TRAFFIC_FRACTION


@dataclasses.dataclass(frozen=True)
class City:
    name: str
    weight: float          # share of global traffic
    home_region: str


def make_cities(n: int = 100, seed: int = 0,
                regions: Sequence[str] = ("regionA", "regionB")) -> List[City]:
    """Zipf-weighted cities split across two home regions."""
    ws = [1.0 / (i + 1) ** 0.8 for i in range(n)]
    tot = sum(ws)
    return [City(f"city-{i:03d}", ws[i] / tot, regions[i % len(regions)])
            for i in range(n)]


def diurnal_traffic(t_seconds: float, base: float = 1.0) -> float:
    """Global traffic level: daily double-hump + weekly modulation, in
    arbitrary units with weekly peak ~= base."""
    day = 86400.0
    week = 7 * day
    tod = (t_seconds % day) / day
    # two rush-hour humps
    hump = (math.exp(-((tod - 0.35) ** 2) / 0.008) +
            1.25 * math.exp(-((tod - 0.75) ** 2) / 0.01))
    dow = 0.85 + 0.15 * math.sin(2 * math.pi * ((t_seconds % week) / week) - 1.2)
    return base * (0.25 + 0.55 * hump) * dow


def weekly_peak(base: float = 1.0, samples: int = 2048) -> float:
    week = 7 * 86400.0
    return max(diurnal_traffic(i * week / samples, base) for i in range(samples))


@dataclasses.dataclass
class FailoverModeDetector:
    """Implements: peak iff tv_failover >= T * tv_peak."""
    threshold_fraction: float = PEAK_TRAFFIC_FRACTION
    tv_peak: float = 1.0

    def recompute_threshold(self, base: float = 1.0):
        self.tv_peak = weekly_peak(base)

    def mode(self, tv_failover: float) -> str:
        return ("peak" if tv_failover >= self.threshold_fraction * self.tv_peak
                else "non-peak")


def is_full_failover(cities_failed: int, cities_total: int) -> bool:
    return cities_failed > FULL_FAILOVER_CITY_FRACTION * cities_total


def region_traffic(cities: Sequence[City], assignment: Dict[str, str],
                   t_seconds: float, base: float = 1.0) -> Dict[str, float]:
    """Traffic per region given a city->region routing assignment."""
    g = diurnal_traffic(t_seconds, base)
    out: Dict[str, float] = {}
    for c in cities:
        r = assignment.get(c.name, c.home_region)
        out[r] = out.get(r, 0.0) + g * c.weight
    return out
