"""Roofline analysis from the dry-run artifacts (single-pod mesh).

Per (arch x shape):
  compute term    = HLO_FLOPs_global / (chips * peak_FLOP/s)
                  = flops_per_device / peak            [s]
  memory term     = HLO_bytes_per_device / HBM_bw      [s]
  collective term = wire_bytes_per_device / link_bw    [s]

plus MODEL_FLOPS (6*N*D train / 2*N*D prefill / 2*N*B decode, N = active
params for MoE), the useful-compute ratio MODEL_FLOPS / HLO_FLOPs (catches
remat/redundancy waste), the dominant bottleneck, and a roofline fraction
  = (MODEL_FLOPS time) / dominant term
— the score an ideal kernel/sharding would push toward 1.0.

Caveats recorded in EXPERIMENTS.md: HLO numbers come from the CPU-backend
SPMD compile (TPU is the target, not the runtime); while-loop bodies are
cost-corrected by the dryrun two-point probe; 'bytes accessed' is XLA's
buffer-traffic estimate, an upper bound on HBM traffic after fusion.

Usage: PYTHONPATH=src python -m repro.launch.roofline [--mesh single]
Writes artifacts/roofline.md + artifacts/roofline.json.
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.configs import SHAPES, all_archs
from repro.launch.mesh import (HBM_BANDWIDTH, ICI_LINK_BANDWIDTH,
                               PEAK_FLOPS_BF16)

ART = Path(__file__).resolve().parents[3] / "artifacts"


def model_flops(arch, shape_name: str) -> float:
    ss = SHAPES[shape_name]
    n = arch.config.active_param_count()
    if ss.kind == "train":
        return 6.0 * n * ss.global_batch * ss.seq_len
    if ss.kind == "prefill":
        return 2.0 * n * ss.global_batch * ss.seq_len
    return 2.0 * n * ss.global_batch      # decode: one token per sequence


def decode_ideal_bytes(arch, shape_name: str) -> float:
    """Ideal HBM traffic for one decode step: every active parameter read
    once (bf16) + the visible KV cache read once — the bandwidth floor that
    defines decode roofline."""
    cfg = arch.config
    ss = SHAPES[shape_name]
    param_bytes = 2.0 * cfg.active_param_count()
    kv_bytes = 0.0
    if cfg.has_attn:
        windows = list(cfg.window_pattern) or [0]
        reps = (cfg.n_layers + len(windows) - 1) // len(windows)
        per_layer = (windows * reps)[: cfg.n_layers]
        for w in per_layer:
            vis = min(ss.seq_len, w) if w > 0 else ss.seq_len
            kv_bytes += (2 * ss.global_batch * vis * cfg.n_kv_heads
                         * cfg.d_head * 2.0)
    if cfg.has_ssm:
        sd = cfg.ssm_dims
        kv_bytes += (ss.global_batch * sd.n_heads * sd.head_dim
                     * sd.d_state * 4.0 * cfg.n_layers)
    return param_bytes + kv_bytes


def suggest(dom: str, arch, shape_name: str) -> str:
    ss = SHAPES[shape_name]
    if dom == "collective":
        if arch.config.is_moe:
            return ("shrink expert-FSDP gather: shard experts over more axes "
                    "or cache gathered expert slabs across microbatches")
        if ss.kind == "decode":
            return ("drop FSDP for decode (params fit replicated per model "
                    "shard) to remove per-token weight all-gathers")
        return ("overlap the FSDP all-gather with the previous layer's "
                "compute (async collectives) or widen the model axis share")
    if dom == "memory":
        if ss.kind == "decode":
            return ("decode is cache-bandwidth-bound by nature; quantize the "
                    "KV cache (int8) or batch more sequences per step")
        return ("reduce remat recompute (dots-saveable policy) and fuse the "
                "attention softmax (flash kernel) to cut score traffic")
    return ("compute-bound: raise MXU occupancy — bigger per-device batch, "
            "fused flash-attention kernel, avoid fp32 upcasts in hot paths")


def analyze(mesh_kind: str = "single"):
    rows = []
    for arch_id, arch in sorted(all_archs().items()):
        for shape_name in SHAPES:
            p = ART / "dryrun" / f"{arch_id}__{shape_name}__{mesh_kind}.json"
            if not p.exists():
                continue
            r = json.loads(p.read_text())
            if r["status"] == "skipped":
                rows.append({"arch": arch_id, "shape": shape_name,
                             "status": "skipped"})
                continue
            if r["status"] != "ok":
                rows.append({"arch": arch_id, "shape": shape_name,
                             "status": "error", "error": r.get("error")})
                continue
            ca = r.get("cost_analysis") or r["cost_analysis_raw"]
            chips = r["devices"]
            fl_dev = ca.get("flops", 0.0)
            by_dev = ca.get("bytes accessed", 0.0)
            wire_dev = r.get("collective_wire_bytes_per_device", 0.0)
            t_comp = fl_dev / PEAK_FLOPS_BF16
            t_mem = by_dev / HBM_BANDWIDTH
            t_coll = wire_dev / ICI_LINK_BANDWIDTH
            mf = model_flops(arch, shape_name)
            ss = SHAPES[shape_name]
            if ss.kind == "decode":
                # decode is bandwidth-limited: ideal = params+cache once
                t_useful = (decode_ideal_bytes(arch, shape_name)
                            / (chips * HBM_BANDWIDTH))
            else:
                t_useful = mf / (chips * PEAK_FLOPS_BF16)
            dom = max((t_comp, "compute"), (t_mem, "memory"),
                      (t_coll, "collective"))[1]
            t_dom = max(t_comp, t_mem, t_coll)
            rows.append({
                "arch": arch_id, "shape": shape_name, "status": "ok",
                "chips": chips,
                "t_compute_s": t_comp, "t_memory_s": t_mem,
                "t_collective_s": t_coll, "dominant": dom,
                "model_flops": mf,
                "hlo_flops_global": fl_dev * chips,
                "useful_ratio": mf / max(1.0, fl_dev * chips),
                "roofline_fraction": t_useful / max(1e-12, t_dom),
                "hbm_temp_gib": r.get("memory_analysis", {}).get(
                    "temp_size_in_bytes", 0) / 2**30,
                "suggestion": suggest(dom, arch, shape_name),
            })
    return rows


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful ratio | roofline frac | next lever |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r["status"] != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"{r['status']} | — | — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3e} | "
            f"{r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} | "
            f"**{r['dominant']}** | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.2f} | {r['suggestion'][:60]} |\n")
    return "".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = analyze(args.mesh)
    (ART / "roofline.json").write_text(json.dumps(rows, indent=2))
    md = to_markdown(rows)
    (ART / "roofline.md").write_text(md)
    print(md)
    ok = [r for r in rows if r["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        coll = max(ok, key=lambda r: r["t_collective_s"] /
                   max(1e-12, max(r["t_compute_s"], r["t_memory_s"])))
        print(f"\nworst roofline fraction: {worst['arch']} {worst['shape']} "
              f"({worst['roofline_fraction']:.3f})")
        print(f"most collective-bound:   {coll['arch']} {coll['shape']}")


if __name__ == "__main__":
    main()
