import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this proves the distribution config is coherent without real
hardware: jax.jit(step).lower(**input_specs).compile() must succeed on the
single-pod 16×16 mesh and the 2×16×16 multi-pod mesh, and we record
memory_analysis / cost_analysis / collective traffic for the roofline.

Cost-accounting note: XLA's cost_analysis counts while-loop (lax.scan)
bodies ONCE, so scan-over-layers programs under-report FLOPs/bytes and the
HLO text shows per-layer collectives once.  We therefore run a two-point
probe per cell: the same step is re-lowered with n_layers=1 and n_layers=2
fully UNROLLED; (C2 - C1) isolates one layer's exact cost (including its
optimizer update and collectives) and corrected = C1 + (L-1)*(C2 - C1).
Raw and corrected numbers are both recorded.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh single|multi|both]
Artifacts: artifacts/dryrun/{arch}__{shape}__{mesh}.json (skip if exists,
--force to redo).
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, all_archs, get_arch, input_specs
from repro.configs.base import ArchSpec
from repro.dist import sharding as shd
from repro.dist.ctx import sharding_rules
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models import MoEParallel, init_params
from repro.optim import make_optimizer
from repro.train import (make_decode_fn, make_prefill_step,
                         make_train_state_abstract, make_train_step)
from repro.train.train_step import TrainState

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def _mem_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # backend without memory analysis
        return {"error": repr(e)}
    if ma is None:
        return {"error": "None"}
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        try:
            out[attr] = int(getattr(ma, attr))
        except Exception:
            pass
    return out


def _cost_analysis_dict(compiled):
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": repr(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    out = {}
    for k, v in dict(ca).items():
        if isinstance(v, (int, float)) and "{" not in k:
            out[k] = float(v)
    return out


def _with_rules(fn, mesh, rules=None):
    """Activate the logical-axis sharding context during tracing."""
    def wrapped(*a, **k):
        with sharding_rules(mesh, rules):
            return fn(*a, **k)
    return wrapped


VARIANT_KEYS = ("remat", "fsdp", "block_local", "seq_parallel", "ssm_chunk")


def apply_variant(arch: ArchSpec, variant: dict) -> ArchSpec:
    """Variant knobs for perf iterations (EXPERIMENTS.md §Perf)."""
    cfg = arch.config
    if "remat" in variant:
        cfg = dataclasses.replace(cfg, remat=str(variant["remat"]))
    if variant.get("block_local"):
        cfg = dataclasses.replace(cfg, block_local_attn=True)
    if variant.get("seq_parallel"):
        cfg = dataclasses.replace(cfg, seq_parallel_attn=True)
    if variant.get("ssm_chunk"):
        cfg = dataclasses.replace(cfg, ssm_chunk=int(variant["ssm_chunk"]))
    if variant.get("kv_quant"):
        cfg = dataclasses.replace(cfg, kv_quant=True)
    if variant.get("pad_heads"):
        # pad q AND kv heads proportionally to a TP-divisible count (zero
        # weights for padded heads keep the math exact; see
        # tests/test_models.py::test_padded_heads_are_exact)
        ph = int(variant["pad_heads"])
        kv = max(1, ph * cfg.n_kv_heads // max(1, cfg.n_heads))
        cfg = dataclasses.replace(cfg, n_heads=ph, n_kv_heads=kv)
    return dataclasses.replace(arch, config=cfg)


def build_cell(arch: ArchSpec, shape_name: str, mesh, variant: dict = None):
    """Returns (fn, args_abstract, in_shardings, donate_argnums, out_shd)."""
    variant = variant or {}
    arch = apply_variant(arch, variant)
    cfg = arch.config
    ss = SHAPES[shape_name]
    specs = input_specs(arch, shape_name)
    fsdp = bool(int(variant.get("fsdp", 1)))

    moe_par = None
    if cfg.is_moe:
        moe_par = MoEParallel(mode="shard_map", model_axis="model",
                              fsdp_axes=(shd.batch_axes(mesh) if fsdp else ()),
                              mesh=mesh)

    ps = shd.param_shardings(cfg, mesh, fsdp=fsdp)

    if ss.kind == "train":
        opt = make_optimizer(state_dtype=cfg.param_dtype)
        step, _ = make_train_step(cfg, opt, moe_parallel=moe_par)
        state_abs = make_train_state_abstract(cfg, opt)
        state_shd = TrainState(params=ps,
                               opt=type(state_abs.opt)(
                                   step=shd.replicated(mesh), m=ps, v=ps),
                               step=shd.replicated(mesh))
        bs = shd.train_batch_shardings(cfg, mesh)
        args = (state_abs, {"inputs": specs["inputs"], "labels": specs["labels"]})
        metrics_shd = {k: shd.replicated(mesh)
                       for k in ("loss", "ce", "aux", "grad_norm", "lr")}
        return (_with_rules(step, mesh), args, (state_shd, bs), (0,),
                (state_shd, metrics_shd))

    if ss.kind == "prefill":
        fn = make_prefill_step(cfg, moe_parallel=moe_par)
        params_abs = jax.eval_shape(lambda k: init_params(cfg, k),
                                    jax.random.PRNGKey(0))
        args = (params_abs, specs["inputs"])
        from jax.sharding import NamedSharding, PartitionSpec as P
        nb = 1
        for a in shd.batch_axes(mesh):
            nb *= mesh.shape[a]
        baxes = shd.batch_axes(mesh) if SHAPES[shape_name].global_batch % nb == 0 else None
        vax = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
        logits_shd = NamedSharding(mesh, P(baxes, vax))
        return (_with_rules(fn, mesh), args,
                (ps, shd.prefill_shardings(cfg, mesh)["inputs"]), (),
                logits_shd)

    # decode
    fn = make_decode_fn(cfg)
    params_abs = jax.eval_shape(lambda k: init_params(cfg, k),
                                jax.random.PRNGKey(0))
    st_shd = shd.decode_state_shardings(cfg, mesh, ss.global_batch)
    tok_shd = shd.decode_token_shardings(cfg, mesh, ss.global_batch)
    args = (params_abs, specs["state"], specs["tokens"])
    from jax.sharding import NamedSharding, PartitionSpec as P
    nb = 1
    for a in shd.batch_axes(mesh):
        nb *= mesh.shape[a]
    baxes = shd.batch_axes(mesh) if ss.global_batch % nb == 0 else None
    vax = "model" if cfg.vocab_size % mesh.shape["model"] == 0 else None
    logits_shd = NamedSharding(mesh, P(baxes, vax))
    return (_with_rules(fn, mesh), args, (ps, st_shd, tok_shd), (1,),
            (logits_shd, st_shd))


def _compile_cell(arch: ArchSpec, shape_name: str, mesh, variant: dict = None):
    fn, args, in_shd, donate, out_shd = build_cell(arch, shape_name, mesh, variant)
    with mesh:
        jfn = jax.jit(fn, in_shardings=in_shd, out_shardings=out_shd,
                      donate_argnums=donate)
        lowered = jfn.lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


_PROBE_KEYS = ("flops", "transcendentals", "bytes accessed")


def _probe(arch: ArchSpec, shape_name: str, mesh, n_layers: int,
           variant: dict = None, window: int = None):
    """Compile an unrolled n_layers variant; return cost + collective dict.
    ``window`` overrides the per-layer window (None = arch default)."""
    cfg_p = dataclasses.replace(arch.config, n_layers=n_layers,
                                unroll_layers=True)
    if window is not None:
        cfg_p = dataclasses.replace(cfg_p, window_pattern=(window,))
    elif cfg_p.window_pattern:
        cfg_p = dataclasses.replace(
            cfg_p, window_pattern=tuple(arch.config.window_pattern[:n_layers]) or (0,))
    arch_p = dataclasses.replace(arch, config=cfg_p)
    _, compiled = _compile_cell(arch_p, shape_name, mesh, variant)
    ca = _cost_analysis_dict(compiled)
    coll = hlo_analysis.parse_collectives(compiled.as_text())
    return ca, coll


def _mix(c1, c2, weight_body: float):
    """outside + weight_body * (c2 - c1) for cost dicts."""
    out = {}
    for k in _PROBE_KEYS:
        a, b = c1.get(k, 0.0), c2.get(k, 0.0)
        out[k] = a + weight_body * max(0.0, b - a)
    return out


def _mix_coll(coll1, coll2, weight_body: float):
    out = {}
    for kind in set(coll1) | set(coll2):
        c1 = coll1.get(kind, {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
        c2 = coll2.get(kind, {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
        out[kind] = {f: c1[f] + weight_body * max(0.0, c2[f] - c1[f])
                     for f in c1}
    return out


def _add_cost(a, b):
    return {k: a.get(k, 0.0) + b.get(k, 0.0) for k in set(a) | set(b)}


def corrected_costs(arch: ArchSpec, shape_name: str, mesh,
                    variant: dict = None):
    """Two-point probe: corrected = C1 + (L-1)*(C2-C1).  For mixed
    local/global window patterns the per-layer body is probed separately for
    each layer type and mixed by the pattern's counts."""
    import numpy as _np
    cfg = arch.config
    L = cfg.n_layers
    windows = list(_np.asarray(cfg.layer_windows()))
    n_local = sum(1 for w in windows if w > 0)
    n_global = L - n_local
    if 0 < n_local and 0 < n_global:
        w_local = max(w for w in windows if w > 0)
        ca1l, co1l = _probe(arch, shape_name, mesh, 1, variant, window=int(w_local))
        ca2l, co2l = _probe(arch, shape_name, mesh, 2, variant, window=int(w_local))
        ca1g, co1g = _probe(arch, shape_name, mesh, 1, variant, window=0)
        ca2g, co2g = _probe(arch, shape_name, mesh, 2, variant, window=0)
        # outside = C1g - body_g ; total = outside + n_l*body_l + n_g*body_g
        cost = _mix(ca1g, ca2g, n_global - 1.0)            # outside + n_g*body_g
        cost = _add_cost(cost, _mix({k: 0.0 for k in _PROBE_KEYS},
                                    {k: max(0.0, ca2l.get(k, 0.0) - ca1l.get(k, 0.0))
                                     for k in _PROBE_KEYS}, n_local))
        coll = _mix_coll(co1g, co2g, n_global - 1.0)
        body_l = _mix_coll({}, {k: {f: max(0.0, co2l.get(k, {}).get(f, 0.0)
                                           - co1l.get(k, {}).get(f, 0.0))
                                    for f in ("count", "bytes", "wire_bytes")}
                                for k in set(co1l) | set(co2l)}, n_local)
        for kind, v in body_l.items():
            if kind in coll:
                coll[kind] = {f: coll[kind][f] + v[f] for f in v}
            else:
                coll[kind] = v
    else:
        ca1, coll1 = _probe(arch, shape_name, mesh, 1, variant)
        ca2, coll2 = _probe(arch, shape_name, mesh, 2, variant)
        cost = _mix(ca1, ca2, L - 1.0)
        coll = _mix_coll(coll1, coll2, L - 1.0)
    total_wire = sum(v["wire_bytes"] for v in coll.values())
    return cost, coll, total_wire


def run_cell(arch_id: str, shape_name: str, mesh_kind: str,
             force: bool = False, save_hlo: bool = False,
             probe: bool = True, variant: dict = None,
             tag: str = "") -> dict:
    variant = variant or {}
    ART_DIR.mkdir(parents=True, exist_ok=True)
    suffix = f"__variant-{tag}" if tag else ""
    out_path = ART_DIR / f"{arch_id}__{shape_name}__{mesh_kind}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())

    arch = get_arch(arch_id)
    result = {"arch": arch_id, "shape": shape_name, "mesh": mesh_kind,
              "variant": variant, "tag": tag,
              "n_layers": arch.config.n_layers,
              "params": arch.config.param_count(),
              "active_params": arch.config.active_param_count()}
    if not arch.shape_runnable(shape_name):
        result["status"] = "skipped"
        result["skip_reason"] = arch.skips[shape_name]
        out_path.write_text(json.dumps(result, indent=2))
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        fn, args, in_shd, donate, out_shd = build_cell(arch, shape_name,
                                                       mesh, variant)
        with mesh:
            jfn = jax.jit(fn, in_shardings=in_shd, out_shardings=out_shd,
                          donate_argnums=donate)
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t1
        hlo = compiled.as_text()
        coll_total, coll = hlo_analysis.collective_summary(hlo)
        result.update({
            "status": "ok",
            "devices": int(mesh.devices.size),
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "memory_analysis": _mem_analysis_dict(compiled),
            "cost_analysis_raw": _cost_analysis_dict(compiled),
            "collectives_raw": coll,
            "hlo_lines": hlo.count("\n"),
        })
        if probe:
            t2 = time.time()
            cost_c, coll_c, wire_c = corrected_costs(arch, shape_name, mesh,
                                                     variant)
            result["cost_analysis"] = cost_c
            result["collectives"] = coll_c
            result["collective_wire_bytes_per_device"] = wire_c
            result["probe_s"] = round(time.time() - t2, 2)
        if save_hlo:
            (ART_DIR / f"{arch_id}__{shape_name}__{mesh_kind}.hlo.txt"
             ).write_text(hlo)
    except Exception as e:
        result["status"] = "error"
        result["error"] = f"{type(e).__name__}: {e}"
        result["traceback"] = traceback.format_exc()[-4000:]
    out_path.write_text(json.dumps(result, indent=2))
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--no-probe", action="store_true")
    ap.add_argument("--variant", action="append", default=[],
                    help="key=value perf-variant knobs (repeatable)")
    ap.add_argument("--tag", default="", help="artifact suffix for variants")
    args = ap.parse_args()
    variant = {}
    for kv in args.variant:
        k, _, v = kv.partition("=")
        variant[k] = v

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = [(a, s) for a in sorted(all_archs()) for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        cells = [(args.arch, args.shape)]

    n_ok = n_skip = n_err = 0
    for arch_id, shape_name in cells:
        for mk in meshes:
            r = run_cell(arch_id, shape_name, mk, force=args.force,
                         save_hlo=args.save_hlo, probe=not args.no_probe,
                         variant=variant, tag=args.tag)
            status = r["status"]
            if status == "ok":
                n_ok += 1
                ca = r.get("cost_analysis", r.get("cost_analysis_raw", {}))
                mem = r.get("memory_analysis", {})
                print(f"[OK]   {arch_id:28s} {shape_name:12s} {mk:6s} "
                      f"compile={r.get('compile_s', 0):7.1f}s "
                      f"flops/dev={ca.get('flops', 0):.3e} "
                      f"wire_B/dev={r.get('collective_wire_bytes_per_device', 0):.3e} "
                      f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB",
                      flush=True)
            elif status == "skipped":
                n_skip += 1
                print(f"[SKIP] {arch_id:28s} {shape_name:12s} {mk:6s}", flush=True)
            else:
                n_err += 1
                print(f"[ERR]  {arch_id:28s} {shape_name:12s} {mk:6s} "
                      f"{r['error'][:160]}", flush=True)
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    return 0 if n_err == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
