"""Production mesh definitions.

A *pod* is the UFA analogue of a region: the production deployment is
dual-pod active-active (2 × 256 chips).  ``make_production_mesh`` is a
function (never a module-level constant) so importing this module never
touches jax device state.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (possibly fake) local devices exist."""
    return jax.make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants (per chip) used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BANDWIDTH = 819e9         # B/s
ICI_LINK_BANDWIDTH = 50e9     # B/s per link
HBM_BYTES = 16 * 2**30        # 16 GiB
VMEM_BYTES = 128 * 2**20
