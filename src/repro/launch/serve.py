"""Serving launcher: batched tiered requests against one arch.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b \\
      --requests 32 [--failover-at 16]

Uses the REDUCED config (CPU-servable).  --failover-at N triggers the UFA
request-plane failover (preemptible tiers blocked + running waves
preempted) after N submissions, demonstrating differentiated SLAs.
"""

import argparse

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.tiers import Tier
from repro.models import init_params
from repro.serving import Request, ServingEngine, TieredScheduler


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--max-batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--failover-at", type=int, default=None)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    cfg = arch.reduced
    print(f"serving {args.arch} (reduced: {cfg.param_count()/1e6:.1f}M params)")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           max_seq=args.prompt_len + args.max_new + 8)
    sched = TieredScheduler({"pod0": engine})

    rng = np.random.default_rng(0)
    tiers = list(Tier)
    for i in range(args.requests):
        if args.failover_at is not None and i == args.failover_at:
            print(f"-- failover injected after {i} submissions --")
            sched.enter_failover()
        if cfg.embed_inputs:
            prompt = list(rng.integers(0, cfg.vocab_size, args.prompt_len))
        else:
            prompt = list(rng.integers(0, 2, args.prompt_len))
        sched.submit(Request(i, tier=tiers[i % len(tiers)], prompt=prompt,
                             max_new_tokens=args.max_new))
        sched.tick()
    for _ in range(10 * args.requests):
        if sched.queue_depth() == 0 and not any(
                e.wave for e in sched.engines.values()):
            break
        sched.tick()
    if sched.failover_active:
        sched.exit_failover()

    print(f"tokens decoded: {engine.tokens_decoded}")
    print(f"{'tier':>6} {'served':>7} {'rejected':>9} {'availability':>13}")
    for t in Tier:
        s = engine.counters["served"][t]
        r = engine.counters["rejected"][t]
        if s + r:
            print(f"{t.name:>6} {s:>7} {r:>9} {engine.availability(t):>12.2f}")


if __name__ == "__main__":
    main()
