"""Parse collective ops and their byte volumes out of lowered/compiled HLO.

``compiled.cost_analysis()`` reports FLOPs and bytes-accessed but NOT
collective traffic, so we scan the (post-SPMD-partitioning, per-device) HLO
text and sum operand sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

COLLECTIVE_KINDS = ("all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute")

# e.g.  %all-reduce.5 = f32[8,128]{1,0} all-reduce(f32[8,128]{1,0} %add.3, ...)
_OP_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_SHAPE_RE = re.compile(r"\b(pred|[sufbc]\d+|bf16|f8e4m3fn|f8e5m2|token)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))   # [n_groups, group_size]<=[...]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return 1


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Returns {kind: {count, bytes, wire_bytes}} from per-device HLO.

    Post-optimization HLO doesn't print operand shapes inline, so we parse the
    *result* shape (printed between '=' and the op name) and derive the
    per-device payload from the collective semantics:

      all-gather:        operand = result / group          (result is gathered)
      reduce-scatter:    operand = result * group
      all-reduce / all-to-all / collective-permute: operand = result

    ``bytes``     = per-device operand payload.
    ``wire_bytes``= ring-algorithm link-traffic estimate per device:
      all-gather / reduce-scatter: (g-1)/g * full payload
      all-reduce: 2 * (g-1)/g * payload
      all-to-all: (g-1)/g * payload;  collective-permute: payload.

    '-done' halves of async pairs are skipped so each op counts once.
    NOTE: ops inside while-loop bodies appear once in the HLO text; callers
    must scale per-layer collectives by the trip count (see dryrun.py).
    """
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "bytes": 0.0, "wire_bytes": 0.0})
    for line in hlo_text.splitlines():
        if " = " not in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        if m.group(2) == "-done":  # async completion: counted at -start
            continue
        kind = m.group(1)
        eq = line.index(" = ")
        result_seg = line[eq + 3:m.start()]
        res_bytes = 0
        for sm in _SHAPE_RE.finditer(result_seg):
            res_bytes += _shape_bytes(sm.group(1), sm.group(2))
        g = _group_size(line)
        if kind == "all-gather":
            payload = res_bytes          # full gathered size
            operand = res_bytes / g
            wire = (g - 1) / g * payload
        elif kind == "reduce-scatter":
            payload = res_bytes * g
            operand = payload
            wire = (g - 1) / g * payload
        elif kind == "all-reduce":
            operand = res_bytes
            wire = 2 * (g - 1) / g * res_bytes
        elif kind == "all-to-all":
            operand = res_bytes
            wire = (g - 1) / g * res_bytes
        else:  # collective-permute
            operand = res_bytes
            wire = res_bytes
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += float(operand)
        stats[kind]["wire_bytes"] += float(wire)
    return dict(stats)


def collective_summary(hlo_text: str) -> Tuple[float, Dict[str, Dict[str, float]]]:
    stats = parse_collectives(hlo_text)
    total = sum(v["wire_bytes"] for v in stats.values())
    return total, stats
