"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --steps 20
  PYTHONPATH=src python -m repro.launch.train --arch hymba-1.5b --full \\
      --devices 8 --mesh 4x2          # spawns with fake devices

Uses the REDUCED config by default (CPU-trainable); --full selects the
assigned full config (only sensible on real accelerators).  With
--devices > 1 the launcher re-executes itself with
XLA_FLAGS=--xla_force_host_platform_device_count so the parent process
keeps a single device.
"""

import argparse
import os
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="use the full assigned config (accelerators only)")
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--mesh", default="",
                    help="DxM mesh, e.g. 4x2 (defaults to devicesx1)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--_inner", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.devices > 1 and not args._inner:
        env = dict(os.environ)
        env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count="
                            f"{args.devices}")
        raise SystemExit(subprocess.call(
            [sys.executable, "-m", "repro.launch.train", "--_inner",
             *sys.argv[1:]], env=env))

    import jax

    from repro.configs import get_arch
    from repro.data import SyntheticLMDataset, make_train_iterator
    from repro.dist.ctx import sharding_rules
    from repro.dist.sharding import param_shardings, train_batch_shardings
    from repro.optim import cosine_schedule, make_optimizer
    from repro.train import make_train_state, make_train_step
    from repro.train.trainer import Trainer

    arch = get_arch(args.arch)
    cfg = arch.config if args.full else arch.reduced
    print(f"arch={args.arch} cfg={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    opt = make_optimizer(lr=cosine_schedule(3e-3, 10, args.steps))
    step_fn, _ = make_train_step(cfg, opt, n_loss_chunks=2)
    state = make_train_state(cfg, jax.random.PRNGKey(0), opt)
    ds = SyntheticLMDataset(vocab_size=cfg.vocab_size, seq_len=args.seq,
                            global_batch=args.batch, seed=0)

    if len(jax.devices()) > 1:
        d, m = (map(int, args.mesh.split("x")) if args.mesh
                else (len(jax.devices()), 1))
        mesh = jax.make_mesh((d, m), ("data", "model"))
        ps = param_shardings(cfg, mesh)
        state = state._replace(
            params=jax.device_put(state.params, ps),
            opt=state.opt._replace(m=jax.device_put(state.opt.m, ps),
                                   v=jax.device_put(state.opt.v, ps)))
        bs = train_batch_shardings(cfg, mesh)

        def wrapped(state, batch):
            with sharding_rules(mesh):
                return step_fn(state, batch)

        trainer = Trainer(cfg, wrapped, args.ckpt_dir, checkpoint_every=50)
        with mesh:
            it = make_train_iterator(ds, shardings=bs)
            state, rep = trainer.run(state, it, args.steps)
    else:
        trainer = Trainer(cfg, step_fn, args.ckpt_dir, checkpoint_every=50)
        state, rep = trainer.run(state, make_train_iterator(ds), args.steps)

    print(f"done: {rep.steps_done} steps, loss {rep.losses[0]:.3f} -> "
          f"{rep.final_loss:.3f}, stragglers={len(rep.straggler_steps)}")


if __name__ == "__main__":
    main()
