"""Capacity optimization walkthrough: replace the paper's hand-tuned
provisioning knobs with a differentiable search over the fused sweep
engine.

Starts from the legacy 2x-buffer design (~1.98x provisioned/steady),
anneals ``jax.grad`` through the soft-relaxed pipeline, polishes with a
vmapped CEM loop over the bit-exact hard objective, hard-verifies the
optimum through a real ``SweepEngine`` on the 48-scenario certification
ensemble, then feeds the availability gradient at the optimum back into
the hardening planner as blast-radius weights.

  PYTHONPATH=src python examples/optimize_capacity.py           # full
  PYTHONPATH=src python examples/optimize_capacity.py --smoke   # CI
"""

import argparse

import numpy as np

from repro.core.service import synthesize_fleet
from repro.graph import CallGraph, plan_hardening
from repro.optim import hardening_weights, optimize_capacity


def main(smoke: bool = False):
    scale = 0.02 if smoke else 0.05
    fs = synthesize_fleet(scale=scale, seed=7, as_arrays=True)
    fs.apply_ufa_target_classes()
    graph = CallGraph.from_fleet_state(fs)
    plan = plan_hardening(graph)
    fs.edges.fail_open[graph.input_edge_indices(plan.hardened_edges)] = True
    print(f"fleet: {fs.n} services at scale {scale}, "
          f"{len(plan.hardened_edges)} edges hardened fail-open")

    kw = (dict(grad_steps=20, taus=(1.0, 0.1, 0.03), cem_generations=4,
               cem_population=24) if smoke else {})
    res = optimize_capacity(fs, mode="both", **kw)
    v = res.verification
    print(f"\nprovisioning multiple: {res.start_multiple:.3f}x (legacy "
          f"start) -> {res.provisioning_multiple:.3f}x (optimized)")
    print(f"knob optimum: buffer={res.design['buffer'] - 1:.3f}, "
          f"overcommit={res.design['overcommit']:.3f}x, "
          f"ramp={res.design['spawn_mult']:.3f}, "
          f"evict_lambda={res.design['evict_lambda']:+.3f}")
    print(f"hard verification ({v['n_scenarios']} scenarios): "
          f"sla_ok {v['n_sla_ok']}, t_sla_ok {v['n_t_sla_ok']}, "
          f"t_avail_ok {v['n_t_avail_ok']}, "
          f"min availability {v['availability_min']:.6f} "
          f"-> all_ok={v['all_ok']}")
    assert res.improved and v["all_ok"]
    if smoke:
        # CI gate: one grad step + a few CEM generations must already
        # beat the legacy start point and hard-certify
        assert res.provisioning_multiple <= 1.4, res.provisioning_multiple

    w = hardening_weights(fs, graph, knobs=res.knobs)
    top = np.argsort(w)[::-1][:5]
    print("\nblast-radius-weighted hardening (availability gradient at "
          "the optimum):")
    for i in top:
        print(f"  {w[i]:8.3f}  {graph.names[i]}")
    wplan = plan_hardening(graph, service_weights=w)
    print(f"weighted plan: {len(wplan.hardened_edges)} edges, "
          f"certified={wplan.certified}")
    assert wplan.certified


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small fleet + tiny budgets (CI gate)")
    main(ap.parse_args().smoke)
