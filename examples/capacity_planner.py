"""Capacity planning walkthrough: the §4.4 overcommit analysis.

Computes O_max from the paper's constants, runs the JAX Monte-Carlo
overcommit simulator across a factor grid, prints the violation curve and
the recommendation, then sizes a UFA region for a synthesized fleet and
compares provisioned cores against the legacy 2x model.

  PYTHONPATH=src python examples/capacity_planner.py
"""

from repro.core.capacity import RegionCapacity
from repro.core.overcommit_sim import OvercommitSimConfig, recommend_factor
from repro.core.service import synthesize_fleet
from repro.core.tiers import o_max


def main():
    print(f"O_max = (M_h/M_s)*(alpha_m/alpha_c) = {o_max():.3f}  "
          f"(paper: 1.66)")
    r = recommend_factor(OvercommitSimConfig())
    print("\nfactor  P(host > 75% busy)")
    for f, v in zip(r["factors"], r["violation_rates"]):
        bar = "#" * int(v * 400)
        marker = "  <= recommended" if abs(f - r["recommended"]) < 1e-9 else ""
        print(f"  {f:.2f}   {v:7.4f} {bar}{marker}")
    if not r["safe"]:
        print("\nWARNING: no factor on the grid met the violation budget "
              f"— {r['recommended']}x is the grid floor, NOT certified safe")
    print(f"\nsimulator recommendation: {r['recommended']}x "
          f"(safe={r['safe']}, paper: 1.5x), clamped by "
          f"O_max={r['o_max']:.2f}")

    fleet = synthesize_fleet(scale=0.05, seed=7)
    demand = sum(s.cores for s in fleet.values())
    ufa = RegionCapacity.for_fleet("region", fleet, model="ufa",
                                   overcommit_factor=r["recommended"])
    legacy = RegionCapacity.for_fleet("region", fleet, model="legacy")
    saved = legacy.steady.physical_cores - ufa.steady.physical_cores
    print(f"\nfleet steady demand/region: {demand:,.0f} cores")
    print(f"legacy 2x provisioning:     {legacy.steady.physical_cores:,.0f} cores")
    print(f"UFA provisioning:           {ufa.steady.physical_cores:,.0f} cores "
          f"(+{ufa.steady.overcommit.capacity:,.0f} overcommit pool)")
    print(f"cores returned:             {saved:,.0f} "
          f"({saved/legacy.steady.physical_cores:.0%} of legacy)")


if __name__ == "__main__":
    main()
