"""Dependency-hardening campaign, end to end (paper §5-6).

The paper's safety pipeline before the 2x buffer could be dropped: detect
fail-close dependencies (runtime correlation + static analysis), build the
call graph *from the detections*, propagate a full blackhole through it to
see which critical services break (multi-hop, through relay chains), run
the greedy hardening planner until the fleet certifies, then keep it
certified with the regression gate.  Prints the hardened-edge count next
to the paper's 4,000+ figure.

  PYTHONPATH=src python examples/harden_fleet.py
  # with host-phase tracing + a metrics snapshot:
  PYTHONPATH=src python examples/harden_fleet.py --trace --metrics-out
"""

import argparse
import os
import time
from contextlib import nullcontext

import numpy as np

from repro.core.dependency import runtime_analysis
from repro.core.drills import remediate
from repro.core.scenarios import scenario_grid, summarize_sweep, \
    sweep_with_dependency_ensemble
from repro.core.service import synthesize_fleet, unsafe_edges
from repro.core.static_analysis import static_analysis
from repro.graph import (CallGraph, blackhole_ensemble, certify,
                         plan_hardening, regression_gate)

SCALE = 0.15          # detection runs on the object fleet (IR + traces)
SEED = 7


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--trace", nargs="?", const="harden_trace.json",
                    default=None, metavar="PATH",
                    help="write a Chrome trace of the pipeline's host "
                         "phases (open in https://ui.perfetto.dev)")
    ap.add_argument("--metrics-out", nargs="?", const="harden_metrics.prom",
                    default=None, metavar="PATH",
                    help="enable the metrics registry and write a "
                         "Prometheus snapshot (+ JSONL next to it)")
    args = ap.parse_args()
    tracer, prof = None, None
    if args.trace or args.metrics_out:
        from repro import obs
        from repro.obs.profiler import Profiler
        obs.enable()
        if args.trace:
            tracer = obs.Tracer()
            obs.set_tracer(tracer)
        prof = Profiler(tracer)

    def phase(name):
        return prof.phase(name) if prof is not None else nullcontext()

    # ---- detect ---------------------------------------------------------
    fleet = synthesize_fleet(scale=SCALE, seed=SEED, unsafe_fraction=0.10,
                             unsafe_chain_fraction=0.04)
    truth = set(unsafe_edges(fleet))
    print(f"fleet: {len(fleet)} services, {len(truth)} planted fail-close "
          f"edges (incl. critical->critical relay chains)")

    with phase("runtime-detection"):
        ra = runtime_analysis(fleet, n_records=1_500_000, seed=SEED)
    with phase("static-analysis"):
        sa = static_analysis(fleet, seed=SEED)
    detected = (ra["found"] | sa["found"])
    recall = len(detected & truth) / max(1, len(truth))
    print(f"detection: runtime={len(ra['found'])} static={len(sa['found'])} "
          f"combined_recall={recall:.2f} "
          f"(paper Table 6: 3041 runtime + 1114 static)")

    # ---- build graph from the detections + propagate --------------------
    graph = CallGraph.from_detections(fleet, detected & truth)
    cert0 = certify(graph)
    print(f"\nblackhole certification (multi-hop): "
          f"{cert0.n_broken_critical}/{cert0.n_critical} critical services "
          f"break, {int(cert0.multi_hop.sum())} only through relay chains "
          f"({cert0.rounds} propagation rounds)")

    # ---- plan hardening -------------------------------------------------
    t0 = time.time()
    with phase("plan-hardening"):
        plan = plan_hardening(graph, batch=12)
    print(f"\nhardening planner: {plan.n_hardened} edges converted "
          f"fail-open over {plan.rounds} rounds ({time.time() - t0:.1f}s) "
          f"-> certified={plan.certified}")
    print(f"  paper: 4,000+ dependencies hardened fleet-wide; this fleet "
          f"is scale={SCALE}, i.e. ~{int(plan.n_hardened / SCALE):,} "
          f"full-scale-equivalent conversions")
    print("  trajectory (hardened -> broken criticals): "
          + " ".join(f"{t['n_hardened']}->{t['n_broken_critical']}"
                     for t in plan.trajectory))

    # ---- re-certify against the ground truth ----------------------------
    remediate(fleet, set(plan.hardened_edge_names))
    cert1 = certify(CallGraph.from_specs(fleet))
    print(f"\nre-certification on the remediated fleet: "
          f"broken criticals {cert0.n_broken_critical} -> "
          f"{cert1.n_broken_critical} (ok={cert1.ok})")

    # ---- gate future regressions ----------------------------------------
    hardened = plan.graph
    crit = hardened.names[int(np.flatnonzero(hardened.critical)[0])]
    pre = hardened.names[int(np.flatnonzero(hardened.preemptible)[0])]
    gate = regression_gate(hardened, hardened.with_edge(crit, pre,
                                                        fail_open=False))
    print(f"regression gate on a planted {crit} -> {pre} fail-close edge: "
          f"ok={gate.ok} violations={gate.violations}")

    # ---- scenario ensemble with the dependency layer closed in ----------
    from repro.core.fleet_state import synthesize_fleet_state
    fs = synthesize_fleet_state(scale=1.0, seed=SEED,
                                unsafe_chain_fraction=0.05)
    g_paper = CallGraph.from_fleet_state(fs)
    t0 = time.time()
    with phase("certify-paper-scale"):
        cert_paper = certify(g_paper)
        ens = blackhole_ensemble(g_paper, n_scenarios=256, seed=SEED)
    dt = time.time() - t0
    print(f"\npaper scale: {g_paper.n} SEs / {g_paper.n_edges} edges — "
          f"full certification + 256-scenario blackhole ensemble in "
          f"{dt:.2f}s ({cert_paper.n_broken_critical} broken criticals "
          f"un-hardened; ensemble ok-rate "
          f"{float(np.mean(ens['ok'])):.2f})")
    res = sweep_with_dependency_ensemble(
        fs, scenario_grid(evict_fraction=(1.0, 0.75, 0.5, 0.25)), seed=SEED)
    s = summarize_sweep(res)
    print(f"scenario sweep with dependency verdicts: "
          f"{s['n_dep_ok']}/{s['n_scenarios']} scenarios dependency-clean, "
          f"worst broken-critical fraction {s['worst_dep_broken_frac']:.3f}")

    if args.trace or args.metrics_out:
        from repro import obs
        from repro.obs import export
        if args.trace:
            tracer.save(args.trace)
            print(f"\nwrote {args.trace} ({len(tracer)} events; load in "
                  f"https://ui.perfetto.dev)")
        if args.metrics_out:
            export.write_prometheus(args.metrics_out)
            jsonl = os.path.splitext(args.metrics_out)[0] + ".jsonl"
            export.write_jsonl(jsonl, meta={"example": "harden_fleet",
                                            "scale": SCALE})
            print(f"wrote {args.metrics_out} + {jsonl}")
        obs.set_tracer(None)
        obs.disable()


if __name__ == "__main__":
    main()
