"""Adversarial chaos campaign: hunt the SLA-violating frontier.

Synthesizes and hardens a Tables-1-3 fleet, then runs a chaos campaign
(``repro.chaos``): bandit-allocated bisection along fault-severity rays
— traffic spikes, preheat stalls, burst/quota/eviction shortfalls,
partial-region degradation, cascading dependency storms and the paper's
correlated compound incident — with every probe round evaluated as ONE
batched call into the fused sweep engine.  Prints the frontier report
(max survivable severity per fault family, minimal counterexamples),
replays every probe bit-exactly on an independent engine, and finishes
with a correlated Monte-Carlo fault sample scored in a single sweep.

  PYTHONPATH=src python examples/chaos_campaign.py
  # coarser/faster: localize to 1/32 with at most 8 bisection rounds
  PYTHONPATH=src python examples/chaos_campaign.py --tol 32 --max-rounds 8
  # with the observability plane on: Chrome trace + Prometheus snapshot
  PYTHONPATH=src python examples/chaos_campaign.py --trace --metrics-out
"""

import argparse
import os
import time
from contextlib import nullcontext

import numpy as np

from repro.chaos import campaign_for_fleet, sample_faults, verify_report
from repro.core.service import synthesize_fleet
from repro.graph import CallGraph, plan_hardening


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.05,
                    help="fleet synthesis scale (0.05 = paper bench fleet)")
    ap.add_argument("--seed", type=int, default=7,
                    help="ONE campaign seed: engine blackhole draws, "
                         "storm draws and fault sampling all derive "
                         "independent streams from it")
    ap.add_argument("--tol", type=float, default=64,
                    help="frontier resolution as 1/TOL severity units")
    ap.add_argument("--max-rounds", type=int, default=64,
                    help="bisection round cap")
    ap.add_argument("--round-budget", type=int, default=None,
                    help="max rays probed per round (bandit budget; "
                         "default probes every active ray)")
    ap.add_argument("--samples", type=int, default=512,
                    help="correlated Monte-Carlo faults scored at the end")
    ap.add_argument("--trace", nargs="?", const="chaos_trace.json",
                    default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the campaign "
                         "phases; open in https://ui.perfetto.dev")
    ap.add_argument("--metrics-out", nargs="?", const="metrics.prom",
                    default=None, metavar="PATH",
                    help="enable the metrics registry and write a "
                         "Prometheus snapshot (+ JSONL next to it)")
    args = ap.parse_args()

    tracer, prof = None, None
    if args.trace or args.metrics_out:
        from repro import obs
        from repro.obs.profiler import Profiler
        obs.enable()
        if args.trace:
            tracer = obs.Tracer()
            obs.set_tracer(tracer)
        prof = Profiler(tracer)

    def phase(name):
        return prof.phase(name) if prof is not None else nullcontext()

    fs = synthesize_fleet(scale=args.scale, seed=7, as_arrays=True)
    fs.apply_ufa_target_classes()
    graph = CallGraph.from_fleet_state(fs)
    with phase("plan-hardening"):
        plan = plan_hardening(graph)
    fs.edges.fail_open[graph.input_edge_indices(plan.hardened_edges)] = True
    print(f"fleet: {fs.n} service-environments, hardened "
          f"{plan.n_hardened} edges (certified={plan.certified})")

    tol = 1.0 / args.tol
    t0 = time.time()
    camp = campaign_for_fleet(fs, seed=args.seed, tol=tol,
                              max_rounds=args.max_rounds,
                              round_budget=args.round_budget,
                              profiler=prof)
    report = camp.run()
    dt = time.time() - t0
    print(f"\ncampaign: {report.n_evals} engine evals in {dt:.1f}s "
          f"({report.n_rounds} bisection rounds)\n")
    print(report.render())

    print("\n== frontier in knob coordinates ==")
    for r in report.rays:
        knobs = r.frontier_knobs()
        if knobs is None:
            continue
        active = {_knob_of(f): round(knobs[_knob_of(f)], 4)
                  for f in sorted(r.direction)}
        print(f"  {r.name:22s} severity {r.frontier_severity:.4f} -> "
              f"{active}")

    # bit-exact audit: replay every probe on an independent engine
    with phase("chaos-verify"):
        fresh = campaign_for_fleet(fs, seed=args.seed, tol=tol)
        audit = verify_report(report, fresh.engine)
    print(f"\nre-verification: {audit['n_probes']} probes replayed on an "
          f"independent engine, bit-identical")

    # correlated Monte-Carlo: joint fault draws (Gaussian copula — the
    # compound incidents the paper worries about), scored in ONE sweep
    with phase("chaos-sample"):
        sample = sample_faults(args.seed, args.samples)
        ok, _ = camp.oracle(sample["grid"])
    sev = sample["severity"]
    fail = ~ok
    print(f"\n== correlated Monte-Carlo ({args.samples} joint faults) ==")
    print(f"  SLA violations: {int(fail.sum())}/{args.samples} "
          f"({fail.mean():.1%})")
    if fail.any():
        worst = sev[fail].max(axis=0)
        mild = sev[fail].sum(axis=1).argmin()
        print("  mildest violating draw (severity per family):")
        for j, name in enumerate(sample["families"]):
            if sev[fail][mild, j] > 0.05:
                print(f"    {name:22s} {sev[fail][mild, j]:.3f}")

    if args.trace or args.metrics_out:
        from repro import obs
        from repro.obs import export
        if args.trace:
            tracer.save(args.trace)
            print(f"\nwrote {args.trace} ({len(tracer)} events; load in "
                  f"https://ui.perfetto.dev)")
        if args.metrics_out:
            export.write_prometheus(args.metrics_out)
            jsonl = os.path.splitext(args.metrics_out)[0] + ".jsonl"
            export.write_jsonl(jsonl, meta={"example": "chaos_campaign",
                                            "seed": args.seed})
            print(f"wrote {args.metrics_out} + {jsonl}")
        obs.set_tracer(None)
        obs.disable()


def _knob_of(family: str) -> str:
    from repro.chaos import FAULT_LIBRARY
    return FAULT_LIBRARY[family].knob


if __name__ == "__main__":
    main()
