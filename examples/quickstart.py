"""Quickstart: train a tiny decoder LM with the repro stack in ~30 seconds.

  PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

import jax

from repro.data import SyntheticLMDataset, make_train_iterator
from repro.models import LMConfig
from repro.optim import cosine_schedule, make_optimizer
from repro.train import make_train_state, make_train_step
from repro.train.trainer import Trainer


def main():
    cfg = LMConfig(name="quickstart", n_layers=4, d_model=128, n_heads=4,
                   n_kv_heads=2, d_head=32, d_ff=256, vocab_size=256,
                   tie_embeddings=True)
    print(f"model: {cfg.param_count()/1e6:.2f}M params")

    opt = make_optimizer(lr=cosine_schedule(8e-3, warmup=8, total=80),
                         weight_decay=0.01)
    step, _ = make_train_step(cfg, opt, n_loss_chunks=2)
    state = make_train_state(cfg, jax.random.PRNGKey(0), opt)
    ds = SyntheticLMDataset(vocab_size=256, seq_len=64, global_batch=16,
                            seed=0, n_clusters=8)

    with tempfile.TemporaryDirectory() as ckdir:
        trainer = Trainer(cfg, step, ckdir, checkpoint_every=20)
        state, rep = trainer.run(state, make_train_iterator(ds), n_steps=80)
    print(f"step  1 loss: {rep.losses[0]:.3f}")
    print(f"step {rep.steps_done} loss: {rep.final_loss:.3f}")
    assert rep.final_loss < rep.losses[0] - 0.25, "model must learn"
    print("OK — loss decreased; checkpointing + straggler watchdog exercised")


if __name__ == "__main__":
    main()
