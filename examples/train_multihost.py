"""Distributed-training walkthrough on a (simulated) 8-device mesh:
FSDP+TP sharded train steps, elastic checkpoint restore onto a smaller
mesh — the UFA Restore-Later path for a preempted training job.

Spawns itself with XLA_FLAGS=--xla_force_host_platform_device_count=8 so
the parent process keeps a single device.

  PYTHONPATH=src python examples/train_multihost.py
"""

import os
import subprocess
import sys
import tempfile
import textwrap

INNER = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from repro.dist.ctx import sharding_rules
    from repro.dist.sharding import param_shardings, train_batch_shardings
    from repro.data import SyntheticLMDataset
    from repro.models import LMConfig
    from repro.train import make_train_state, make_train_step
    from repro.checkpoint import save_checkpoint, load_checkpoint

    cfg = LMConfig(name="mh", n_layers=4, d_model=128, n_heads=8,
                   n_kv_heads=4, d_head=16, d_ff=256, vocab_size=512,
                   tie_embeddings=True)
    ckdir = {ckdir!r}
    phase = {phase!r}
    if phase == "train8":
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        n_steps, start = 6, 0
    else:
        mesh = jax.make_mesh((2, 1), ("data", "model"))
        n_steps, start = 4, 6
    ps = param_shardings(cfg, mesh)
    bs = train_batch_shardings(cfg, mesh)
    step_fn, opt = make_train_step(cfg, n_loss_chunks=2)
    state = make_train_state(cfg, jax.random.PRNGKey(0), opt)
    if phase == "resume2":
        state, _ = load_checkpoint(ckdir, state)    # elastic reshard-on-load
    state = state._replace(params=jax.device_put(state.params, ps),
                           opt=state.opt._replace(
                               m=jax.device_put(state.opt.m, ps),
                               v=jax.device_put(state.opt.v, ps)))
    ds = SyntheticLMDataset(vocab_size=512, seq_len=32, global_batch=8, seed=3)
    def wrapped(state, batch):
        with sharding_rules(mesh):
            return step_fn(state, batch)
    jstep = jax.jit(wrapped, donate_argnums=(0,))
    with mesh:
        for i in range(start, start + n_steps):
            batch = {{k: jax.device_put(v, bs[k])
                      for k, v in ds.batch(i).items()}}
            state, m = jstep(state, batch)
            print(f"[{{phase}}] devices={{len(jax.devices())}} "
                  f"step {{i}} loss {{float(m['loss']):.4f}}", flush=True)
    if phase == "train8":
        save_checkpoint(ckdir, start + n_steps, state)
""")


def run(phase: str, devices: int, ckdir: str):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env.setdefault("PYTHONPATH", "src")
    code = INNER.format(ckdir=ckdir, phase=phase)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True)
    sys.stdout.write(out.stdout)
    if out.returncode != 0:
        sys.stderr.write(out.stderr)
        raise SystemExit(out.returncode)


def main():
    with tempfile.TemporaryDirectory() as ckdir:
        print("== phase 1: FSDP+TP training on a 4x2 mesh (8 devices) ==")
        run("train8", 8, ckdir)
        print("== phase 2: preempted; elastic restore on a 2x1 mesh ==")
        run("resume2", 2, ckdir)
        print("OK — the job continued on 4x fewer devices from the same "
              "checkpoint (UFA Restore-Later semantics)")


if __name__ == "__main__":
    main()
