"""Temporal scenario ensemble: execute failure *timelines*, not point
estimates.

Synthesizes a Tables-1-3 fleet, then runs the fused sweep engine
(``repro.core.sweep_engine``: analytic model + the discrete-time failover
kernel + dependency propagation in one jitted, device-parallel pipeline)
over the scenario grid — per-scenario time-to-restore per tier, the
availability integral against the 99.97% SLA, and the peak on-demand
cloud draw, alongside the analytic closed-form verdicts.

  PYTHONPATH=src python examples/temporal_sweep.py
  # 64k-scenario ensemble, sharded over 8 virtual host devices:
  PYTHONPATH=src python examples/temporal_sweep.py --grid-size 65536 \\
      --devices 8
  # with the observability plane on: Chrome trace (load in Perfetto),
  # Prometheus + JSONL metric snapshots, SLO burn-rate verdicts
  PYTHONPATH=src python examples/temporal_sweep.py --trace --metrics-out
"""

import argparse
import os
import subprocess
import sys
import time
from contextlib import nullcontext

import numpy as np

from repro.core.scenarios import (operating_point_mask, scenario_grid,
                                  summarize_sweep,
                                  sweep_with_dependency_ensemble)
from repro.core.service import synthesize_fleet
from repro.core.sweep_engine import tile_grid
from repro.core.tiers import Tier
from repro.graph import CallGraph, plan_hardening


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid-size", type=int, default=256,
                    help="scenario count (the 256-point base grid is "
                         "tiled out; the fused engine bucket-pads)")
    ap.add_argument("--devices", type=int, default=1,
                    help="virtual host devices to shard the scenario "
                         "axis over (re-executes under XLA_FLAGS)")
    ap.add_argument("--trace", nargs="?", const="failover_trace.json",
                    default=None, metavar="PATH",
                    help="write a Chrome trace-event JSON of the run "
                         "(host pipeline phases + a traced event-loop "
                         "failover); open in https://ui.perfetto.dev")
    ap.add_argument("--metrics-out", nargs="?", const="metrics.prom",
                    default=None, metavar="PATH",
                    help="enable the metrics registry and write a "
                         "Prometheus snapshot (+ JSONL next to it)")
    args = ap.parse_args()
    if args.devices > 1 and "_TEMPORAL_SWEEP_CHILD" not in os.environ:
        env = dict(os.environ, _TEMPORAL_SWEEP_CHILD="1")
        env["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count="
                            f"{args.devices}").strip()
        env.setdefault("PYTHONPATH", "src")
        raise SystemExit(subprocess.run(
            [sys.executable, *sys.argv], env=env).returncode)

    tracer, prof = None, None
    if args.trace or args.metrics_out:
        from repro import obs
        from repro.obs.profiler import Profiler
        obs.enable()
        if args.trace:
            tracer = obs.Tracer()
            obs.set_tracer(tracer)
        prof = Profiler(tracer)

    def phase(name):
        return prof.phase(name) if prof is not None else nullcontext()

    fs = synthesize_fleet(scale=0.1, seed=7, as_arrays=True,
                          unsafe_chain_fraction=0.02)
    fs.apply_ufa_target_classes()
    import jax
    print(f"fleet: {fs.n} service-environments, "
          f"{float(fs.spec_cores.sum()):,.0f} cores | "
          f"grid={args.grid_size} devices={len(jax.devices())}")

    grid = tile_grid(scenario_grid(), args.grid_size)

    # 1. the un-remediated fleet: fail-close chains break criticals in
    #    every blackhole scenario, sinking the availability trace
    with phase("sweep-unhardened"):
        res0 = sweep_with_dependency_ensemble(fs, grid=grid, temporal=True)
    print(f"\nbefore hardening: t_sla_ok="
          f"{int(res0['t_sla_ok'].sum())}/{len(res0['t_sla_ok'])} "
          f"worst avail integral "
          f"{float(res0['t_availability_mean'].min()):.5f}")

    # 2. harden: greedily fail-open the highest-blast-radius unsafe edges
    #    until the full blackhole certifies (paper's 4,000+ conversions)
    graph = CallGraph.from_fleet_state(fs)
    with phase("plan-hardening"):
        plan = plan_hardening(graph)
    # plan indices are CSR positions; map back to FleetState edge order
    fs.edges.fail_open[graph.input_edge_indices(plan.hardened_edges)] = True
    print(f"hardened {plan.n_hardened} edges in {plan.rounds} rounds "
          f"(certified={plan.certified})")

    # 3. the hardened fleet, same temporal ensemble (fused engine path —
    #    warm after step 1 compiled the bucket)
    t0 = time.time()
    with phase("sweep-hardened"):
        res = sweep_with_dependency_ensemble(fs, grid=grid, temporal=True)
    dt = time.time() - t0
    print(f"fused sweep: {len(res['sla_ok'])} scenarios in {dt:.2f}s "
          f"({len(res['sla_ok'])/dt:,.0f} scenarios/s)")
    summary = summarize_sweep(res)
    print("\n== ensemble digest (analytic + temporal, hardened fleet) ==")
    for k, v in summary.items():
        print(f"  {k:32s} {v}")

    print("\n== analytic vs temporal disagreements ==")
    diff = np.flatnonzero(res["sla_ok"] != res["t_sla_ok"])
    print(f"  {len(diff)} of {len(res['sla_ok'])} scenarios differ")
    for i in diff[:5]:
        print(f"  mult={res['traffic_mult'][i]:.1f} "
              f"burst_avail={res['burst_availability'][i]:.2f} "
              f"quota={res['cloud_quota_frac'][i]:.2f} "
              f"evict={res['evict_fraction'][i]:.2f}: "
              f"analytic={bool(res['sla_ok'][i])} "
              f"temporal={bool(res['t_sla_ok'][i])} "
              f"t_rl_done={res['t_rl_done_s'][i]/60.0:.1f}min")

    print("\n== worst temporal scenarios (availability integral) ==")
    order = np.argsort(res["t_availability_mean"])[:5]
    for i in order:
        ttr = res["t_time_to_restore_s"][i]
        t3 = ttr[int(Tier.T3)]
        print(f"  avail_mean={res['t_availability_mean'][i]:.5f} "
              f"mult={res['traffic_mult'][i]:.1f} "
              f"burst_avail={res['burst_availability'][i]:.2f} "
              f"quota={res['cloud_quota_frac'][i]:.2f} "
              f"dep_broken={res['dep_broken_frac'][i]:.3f} "
              f"T3_restore={'never' if np.isinf(t3) else f'{t3/60:.0f}min'} "
              f"peak_cloud={res['t_peak_cloud_cores'][i]:,.0f}")

    op = operating_point_mask(res)
    i = int(np.flatnonzero(op)[0])
    print("\n== paper operating point, per-tier time-to-restore ==")
    for t in Tier:
        v = res["t_time_to_restore_s"][i][int(t)]
        label = ("never (until failback)" if np.isinf(v)
                 else "no interruption" if v == 0.0 else f"{v/60.0:.1f} min")
        print(f"  {t.name:3s} {label}")
    print(f"  availability integral: "
          f"{res['t_availability_mean'][i]:.5f} (SLA 0.9997) "
          f"temporal_sla_ok={bool(res['t_sla_ok'][i])}")

    if args.trace or args.metrics_out:
        from repro import obs
        from repro.core.timeline_sim import config_for_fleet, sweep_timeline
        from repro.obs import export, slo

        # SLO burn-rate monitor over full per-step availability traces
        # (multi-window multi-burn-rate against the 99.97% target),
        # verdict quality judged against the kernel's own avail_ok
        with phase("slo-monitor"):
            cfg = config_for_fleet(fs)
            n_slo = min(args.grid_size, 256)
            tr = sweep_timeline(cfg, grid=tile_grid(scenario_grid(), n_slo),
                                return_traces=True)
            verd = slo.sweep_alerts(tr["trace_availability"], tr["t"])
            quality = slo.alert_quality(verd["alert"], ~tr["avail_ok"],
                                        verd["t_first_alert"])
        print("\n== SLO burn-rate monitor (99.97% target) ==")
        print(f"  rules: {[r.name for r in slo.DEFAULT_RULES]}")
        print(f"  alerts on {quality['n_alerts']}/{quality['n_scenarios']} "
              f"scenarios ({quality['n_violations']} true SLA violations): "
              f"precision={quality['precision']:.2f} "
              f"recall={quality['recall']:.2f} "
              f"median time-to-first-alert="
              f"{quality['median_t_first_alert']:.0f}s")

        if args.trace:
            # one traced event-loop failover: the orchestration waves
            # (BBM evict, burst conversion, MBB/RL waves, cloud grants)
            # render as sim-time spans alongside the host phases above
            from repro.core.capacity import RegionCapacity
            from repro.core.omg import Orchestrator
            with phase("traced-failover"):
                orch = Orchestrator(fs, RegionCapacity.for_fleet("tr", fs),
                                    tracer=tracer)
                orch.failover()
            tracer.save(args.trace)
            print(f"\nwrote {args.trace} ({len(tracer)} events; load in "
                  f"https://ui.perfetto.dev)")
        if args.metrics_out:
            export.write_prometheus(args.metrics_out)
            jsonl = os.path.splitext(args.metrics_out)[0] + ".jsonl"
            export.write_jsonl(jsonl, meta={"example": "temporal_sweep",
                                            "grid_size": args.grid_size})
            print(f"wrote {args.metrics_out} + {jsonl}")
        obs.set_tracer(None)
        obs.disable()


if __name__ == "__main__":
    main()
