"""Temporal scenario ensemble: execute failure *timelines*, not point
estimates.

Synthesizes a Tables-1-3 fleet, then runs the fused sweep engine
(``repro.core.sweep_engine``: analytic model + the discrete-time failover
kernel + dependency propagation in one jitted, device-parallel pipeline)
over the scenario grid — per-scenario time-to-restore per tier, the
availability integral against the 99.97% SLA, and the peak on-demand
cloud draw, alongside the analytic closed-form verdicts.

  PYTHONPATH=src python examples/temporal_sweep.py
  # 64k-scenario ensemble, sharded over 8 virtual host devices:
  PYTHONPATH=src python examples/temporal_sweep.py --grid-size 65536 \\
      --devices 8
"""

import argparse
import os
import subprocess
import sys
import time

import numpy as np

from repro.core.scenarios import (operating_point_mask, scenario_grid,
                                  summarize_sweep,
                                  sweep_with_dependency_ensemble)
from repro.core.service import synthesize_fleet
from repro.core.sweep_engine import tile_grid
from repro.core.tiers import Tier
from repro.graph import CallGraph, plan_hardening


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--grid-size", type=int, default=256,
                    help="scenario count (the 256-point base grid is "
                         "tiled out; the fused engine bucket-pads)")
    ap.add_argument("--devices", type=int, default=1,
                    help="virtual host devices to shard the scenario "
                         "axis over (re-executes under XLA_FLAGS)")
    args = ap.parse_args()
    if args.devices > 1 and "_TEMPORAL_SWEEP_CHILD" not in os.environ:
        env = dict(os.environ, _TEMPORAL_SWEEP_CHILD="1")
        env["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                            f" --xla_force_host_platform_device_count="
                            f"{args.devices}").strip()
        env.setdefault("PYTHONPATH", "src")
        raise SystemExit(subprocess.run(
            [sys.executable, *sys.argv], env=env).returncode)

    fs = synthesize_fleet(scale=0.1, seed=7, as_arrays=True,
                          unsafe_chain_fraction=0.02)
    fs.apply_ufa_target_classes()
    import jax
    print(f"fleet: {fs.n} service-environments, "
          f"{float(fs.spec_cores.sum()):,.0f} cores | "
          f"grid={args.grid_size} devices={len(jax.devices())}")

    grid = tile_grid(scenario_grid(), args.grid_size)

    # 1. the un-remediated fleet: fail-close chains break criticals in
    #    every blackhole scenario, sinking the availability trace
    res0 = sweep_with_dependency_ensemble(fs, grid=grid, temporal=True)
    print(f"\nbefore hardening: t_sla_ok="
          f"{int(res0['t_sla_ok'].sum())}/{len(res0['t_sla_ok'])} "
          f"worst avail integral "
          f"{float(res0['t_availability_mean'].min()):.5f}")

    # 2. harden: greedily fail-open the highest-blast-radius unsafe edges
    #    until the full blackhole certifies (paper's 4,000+ conversions)
    graph = CallGraph.from_fleet_state(fs)
    plan = plan_hardening(graph)
    # plan indices are CSR positions; map back to FleetState edge order
    fs.edges.fail_open[graph.input_edge_indices(plan.hardened_edges)] = True
    print(f"hardened {plan.n_hardened} edges in {plan.rounds} rounds "
          f"(certified={plan.certified})")

    # 3. the hardened fleet, same temporal ensemble (fused engine path —
    #    warm after step 1 compiled the bucket)
    t0 = time.time()
    res = sweep_with_dependency_ensemble(fs, grid=grid, temporal=True)
    dt = time.time() - t0
    print(f"fused sweep: {len(res['sla_ok'])} scenarios in {dt:.2f}s "
          f"({len(res['sla_ok'])/dt:,.0f} scenarios/s)")
    summary = summarize_sweep(res)
    print("\n== ensemble digest (analytic + temporal, hardened fleet) ==")
    for k, v in summary.items():
        print(f"  {k:32s} {v}")

    print("\n== analytic vs temporal disagreements ==")
    diff = np.flatnonzero(res["sla_ok"] != res["t_sla_ok"])
    print(f"  {len(diff)} of {len(res['sla_ok'])} scenarios differ")
    for i in diff[:5]:
        print(f"  mult={res['traffic_mult'][i]:.1f} "
              f"burst_avail={res['burst_availability'][i]:.2f} "
              f"quota={res['cloud_quota_frac'][i]:.2f} "
              f"evict={res['evict_fraction'][i]:.2f}: "
              f"analytic={bool(res['sla_ok'][i])} "
              f"temporal={bool(res['t_sla_ok'][i])} "
              f"t_rl_done={res['t_rl_done_s'][i]/60.0:.1f}min")

    print("\n== worst temporal scenarios (availability integral) ==")
    order = np.argsort(res["t_availability_mean"])[:5]
    for i in order:
        ttr = res["t_time_to_restore_s"][i]
        t3 = ttr[int(Tier.T3)]
        print(f"  avail_mean={res['t_availability_mean'][i]:.5f} "
              f"mult={res['traffic_mult'][i]:.1f} "
              f"burst_avail={res['burst_availability'][i]:.2f} "
              f"quota={res['cloud_quota_frac'][i]:.2f} "
              f"dep_broken={res['dep_broken_frac'][i]:.3f} "
              f"T3_restore={'never' if np.isinf(t3) else f'{t3/60:.0f}min'} "
              f"peak_cloud={res['t_peak_cloud_cores'][i]:,.0f}")

    op = operating_point_mask(res)
    i = int(np.flatnonzero(op)[0])
    print("\n== paper operating point, per-tier time-to-restore ==")
    for t in Tier:
        v = res["t_time_to_restore_s"][i][int(t)]
        label = ("never (until failback)" if np.isinf(v)
                 else "no interruption" if v == 0.0 else f"{v/60.0:.1f} min")
        print(f"  {t.name:3s} {label}")
    print(f"  availability integral: "
          f"{res['t_availability_mean'][i]:.5f} (SLA 0.9997) "
          f"temporal_sla_ok={bool(res['t_sla_ok'][i])}")


if __name__ == "__main__":
    main()
