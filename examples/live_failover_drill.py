"""Live-workload failover drill: the UFA control plane driving real serving.

The timeline kernel simulates a full-peak regional failover for a
paper-shaped fleet; ``serving.FailoverBridge`` replays its per-tier
capacity traces as replica actuation on a pool of jitted serving engines
behind the hardened ``TieredScheduler``; an open-loop Poisson workload
(a synthetic millions-of-users trace, critical traffic doubling as the
surviving region absorbs the failed region) flows through the same
window.  Every request gets a user-visible verdict, and the report is
*measured request* SLOs — availability, p50/p99 latency, goodput,
time-to-restore — fed through the ``obs`` burn-rate monitors, per §4.2:
the critical tier rides through untouched while the preemptible tier
degrades visibly and restores within its differentiated RTO.

The full run then turns the drill into a chaos-campaign target: bisection
over the request-plane fault families (arrival spikes, retry storms)
localizes the severity at which the measured SLA first breaks, and the
campaign replays bit-exactly through a fresh oracle.

  PYTHONPATH=src python examples/live_failover_drill.py
  PYTHONPATH=src python examples/live_failover_drill.py --smoke   # CI
"""

import argparse
import time

from repro import obs
from repro.chaos import verify_report
from repro.core.tiers import FailureClass, RTO_SECONDS
from repro.serving import DrillSpec, drill_oracle, request_campaign, run_drill


def main(smoke: bool = False):
    obs.enable()
    spec = DrillSpec()
    rto = RTO_SECONDS[FailureClass.RESTORE_LATER]

    t0 = time.time()
    rep = run_drill(spec)
    print(rep.render())
    print(f"drill wall time {time.time() - t0:.1f}s "
          f"(includes jit compiles on the first run)")
    print("replica actuation:", " -> ".join(
        f"t={t:.0f}s {tier.name}x{tgt}" for t, tier, tgt in
        rep.actuation_log))

    crit, pre = rep.crit, rep.pre
    # user-visible differentiated SLAs, asserted from the measured report
    assert rep.sla_ok, "drill SLA verdict failed"
    assert crit.availability >= spec.avail_slo, crit.availability
    assert not crit.slo_alert, "burn-rate alert on the critical tier"
    assert crit.p99_s <= spec.crit_p99_slo_s, crit.p99_s
    assert pre.time_to_restore_s <= rto, pre.time_to_restore_s
    assert pre.slo_alert, "blackout must be user-visible on the pre tier"
    # ... and cross-checked against the obs metrics plane
    assert obs.value("ufa_serving_requests_total", tier=crit.tier,
                     outcome="served") == crit.served
    print(f"PASS  critical {crit.tier}: availability "
          f"{crit.availability:.4f} >= {spec.avail_slo} with no alert; "
          f"preemptible {pre.tier}: restored in "
          f"{pre.time_to_restore_s:.0f}s <= RTO {rto:.0f}s "
          f"(alert fired at t={pre.t_first_alert_s:.0f}s)")
    if smoke:
        return

    # ---- chaos: hunt the request-level SLA frontier ---------------------
    print("\nchaos campaign over the request-plane fault families:")
    t0 = time.time()
    camp = request_campaign(spec, tol=1.0 / 8.0, max_rounds=5)
    crep = camp.run()
    print(crep.render())
    print(f"campaign wall time {time.time() - t0:.1f}s")
    assert crep.op_ok and crep.n_localized >= 1
    out = verify_report(crep, oracle=drill_oracle(spec))
    print(f"replayed {out['n_probes']} probes bit-exactly: "
          f"{len(out['mismatches'])} mismatches")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="drill + SLA asserts only (CI-sized)")
    main(ap.parse_args().smoke)
