"""End-to-end serving driver (the paper's kind of workload): serve a small
model with batched, tiered requests through the UFA request plane.

Runs a qwen3-family reduced model, a realistic tiered request mix (Table 2
volume shape), wave batching with strict-priority + aging scheduling, and a
mid-run failover window with preemptible-tier blocking — printing per-tier
latency/availability, throughput, and the differentiated-SLA effect.

  PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core.tiers import Tier
from repro.models import init_params
from repro.serving import Request, ServingEngine, TieredScheduler


def main():
    arch = get_arch("qwen3-1.7b")
    cfg = arch.reduced
    print(f"serving {cfg.name}: {cfg.param_count()/1e6:.2f}M params "
          f"(reduced config of {arch.arch_id})")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=8, max_seq=64)
    sched = TieredScheduler({"pod0": engine})

    rng = np.random.default_rng(0)
    # request mix skewed like production: mostly critical-tier traffic
    tier_mix = [Tier.T0] * 1 + [Tier.T1] * 6 + [Tier.T2] * 2 + \
        [Tier.T3] * 2 + [Tier.T4] * 1 + [Tier.T5] * 2
    rid = 0

    def submit(n):
        nonlocal rid
        for _ in range(n):
            sched.submit(Request(rid, tier=tier_mix[rid % len(tier_mix)],
                                 prompt=list(rng.integers(0, cfg.vocab_size, 8)),
                                 max_new_tokens=4))
            rid += 1

    t0 = time.perf_counter()
    submit(24)
    for _ in range(60):
        sched.tick()

    print("\n== failover window: preemptible tiers blocked ==")
    sched.enter_failover()
    submit(24)
    for _ in range(60):
        sched.tick()
    sched.exit_failover()

    print("== failback: all tiers restored ==")
    submit(12)
    for _ in range(80):
        sched.tick()
        if sched.queue_depth() == 0 and not engine.wave:
            break
    dt = time.perf_counter() - t0

    total_served = sum(engine.counters["served"].values())
    print(f"\n{total_served} requests served, "
          f"{engine.tokens_decoded} tokens decoded in {dt:.1f}s "
          f"({engine.tokens_decoded/dt:.0f} tok/s on CPU)")
    print(f"{'tier':>6} {'served':>7} {'rejected':>9} {'availability':>13}")
    for t in Tier:
        s = engine.counters["served"][t]
        r = engine.counters["rejected"][t]
        if s + r == 0:
            continue
        print(f"{t.name:>6} {s:>7} {r:>9} {engine.availability(t):>12.2f}")
    assert engine.availability(Tier.T1) == 1.0
    print("\ndifferentiated SLA holds: critical tiers at 1.00 availability "
          "through the failover; preemptible tiers failed fast (paper §4.2)")


if __name__ == "__main__":
    main()
