"""End-to-end UFA failover drill with REAL ML workloads (the paper's kind
of system: serving infrastructure).

Two-region active-active deployment in miniature:
  - a T1 (Always-On) serving engine answering tiered requests,
  - a T5 (Restore-Later) training job running opportunistically in the
    overcommit pool,
  - the OMG orchestrator wired to both via its eviction/restore hooks.

We inject a full-peak regional failure, watch UFA evict the trainer,
block preemptible-tier traffic, keep T0/T1 availability at 100%, restore
the trainer from its checkpoint within RTO, and fail back.

  PYTHONPATH=src python examples/failover_drill.py
"""

import tempfile

import jax
import numpy as np

from repro.core.capacity import RegionCapacity
from repro.core.drills import remediate
from repro.core.metrics import availability_during_failover
from repro.core.omg import Orchestrator
from repro.core.scenarios import (FleetAggregates, summarize_sweep,
                                  sweep_scenarios)
from repro.core.service import synthesize_fleet, unsafe_edges
from repro.core.tiers import Tier
from repro.data import SyntheticLMDataset, make_train_iterator
from repro.models import LMConfig, init_params
from repro.serving import Request, ServingEngine, TieredScheduler
from repro.train import make_train_state, make_train_step
from repro.train.trainer import Trainer

CFG = LMConfig(name="drill", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
               d_head=16, d_ff=128, vocab_size=128, tie_embeddings=True)


def main():
    # ---- control plane --------------------------------------------------
    fleet = synthesize_fleet(scale=0.02, seed=4)
    n_unsafe = len(unsafe_edges(fleet))
    remediate(fleet, set(unsafe_edges(fleet)))
    print(f"fleet: {len(fleet)} services; {n_unsafe} fail-close edges "
          f"remediated before the drill")
    region = RegionCapacity.for_fleet("regionB", fleet)

    # ---- data plane ------------------------------------------------------
    params = init_params(CFG, jax.random.PRNGKey(0))
    engine = ServingEngine(CFG, params, max_batch=4, max_seq=48)
    sched = TieredScheduler({"serving-t1": engine})
    step_fn, opt = make_train_step(CFG, n_loss_chunks=2)
    ds = SyntheticLMDataset(vocab_size=128, seq_len=16, global_batch=4, seed=1)

    with tempfile.TemporaryDirectory() as ckdir:
        trainer = Trainer(CFG, step_fn, ckdir, checkpoint_every=2)
        tstate = make_train_state(CFG, jax.random.PRNGKey(0), opt)
        tstate, rep0 = trainer.run(tstate, make_train_iterator(ds), 6)
        print(f"batch training in overcommit pool: {rep0.steps_done} steps, "
              f"loss {rep0.final_loss:.3f}")

        def on_evict(spec):
            if not trainer._preempt_requested:
                print(f"  [UFA] evicting preemptible workloads "
                      f"(e.g. {spec.name}) — BBM")
                trainer.request_preempt()
                sched.enter_failover()

        restored = []
        orch = Orchestrator(fleet, region, scale=0.02, on_evict=on_evict,
                            on_restore=lambda s: restored.append(s.name))

        print("\n== injecting full-peak regional failure ==")
        report = orch.failover(tv_failover=1.0)

        rng = np.random.default_rng(0)
        for i in range(18):
            sched.submit(Request(i, tier=Tier(i % 6),
                                 prompt=list(rng.integers(0, 128, 8)),
                                 max_new_tokens=2))
        while sched.tick():
            pass

        print(f"mode={report.mode} | burst full at "
              f"{report.burst_full_at_s/60:.1f} min | AM migrated at "
              f"{report.am_migrated_at_s/60:.1f} min | RL restored at "
              f"{report.rl_restored_at_s/60:.1f} min (1h RTO met: "
              f"{report.rl_rto_met})")
        print(f"restored {len(restored)} Restore-Later services in "
              f"burst/cloud capacity")
        series = availability_during_failover(fleet, orch)
        print(f"availability through the window: min="
              f"{min(a for _, a in series):.4f} (paper: 0.9997)")
        for t in (Tier.T0, Tier.T1, Tier.T4, Tier.T5):
            s = engine.counters["served"][t]
            r = engine.counters["rejected"][t]
            print(f"  tier {t.name}: served={s} rejected={r} "
                  f"availability={engine.availability(t):.2f}")

        print("\n== restoring the preempted training job (BBM revive) ==")
        sched.exit_failover()
        t2 = make_train_state(CFG, jax.random.PRNGKey(9), opt)
        t2, start = trainer.maybe_resume(t2)
        trainer._preempt_requested = False
        t2, rep2 = trainer.run(t2, make_train_iterator(ds, start_step=start),
                               4, start_step=start)
        print(f"training resumed at step {start}, continued "
              f"{rep2.steps_done} steps, loss {rep2.final_loss:.3f}")

        orch.failback()
        print(f"failback complete at t={orch.loop.now/60:.1f} min; all "
              f"{len(orch.se)} services back in steady state")

    # ---- scenario ensemble: one drill is an anecdote, 256 are evidence --
    print("\n== scenario-ensemble sweep (vmapped capacity model) ==")
    agg = FleetAggregates.from_fleet(fleet)
    res = sweep_scenarios(agg)   # default 4^4 grid around the paper's point
    s = summarize_sweep(res)
    print(f"evaluated {s['n_scenarios']} failover scenarios in one vmap: "
          f"{s['n_sla_ok']} meet every class SLA "
          f"({s['sla_ok_fraction']:.0%})")
    print(f"availability min={s['availability_min']:.4f} "
          f"mean={s['availability_mean']:.4f}; worst Restore-Later "
          f"completion {s['worst_rl_done_min']:.0f} min (RTO 60)")
    bad = ~res["sla_ok"]
    if bad.any():
        fail_idx = np.flatnonzero(bad)
        i = int(fail_idx[np.argmin(res["availability"][fail_idx])])
        print(f"worst scenario: traffic x{res['traffic_mult'][i]:.1f}, "
              f"burst availability {res['burst_availability'][i]:.0%}, "
              f"preheat {res['burst_delay_s'][i]:.0f}s, cloud quota "
              f"x{res['cloud_quota_frac'][i]:.2f} -> availability "
              f"{res['availability'][i]:.4f}")


if __name__ == "__main__":
    main()
