"""Vectorized FleetState engine: scalar-equivalence, paper-scale speed,
headline provisioning invariants, and the scenario-ensemble driver."""

import time

import numpy as np
import pytest

from repro.core.capacity import RegionCapacity, provisioning_multiple
from repro.core.drills import certify_fleet_state
from repro.core.fleet_state import FleetState, synthesize_fleet_state
from repro.core.omg import Orchestrator
from repro.core.scenarios import (FleetAggregates, scenario_grid,
                                  summarize_sweep, sweep_scenarios)
from repro.core.service import (apply_ufa_target_classes, fleet_cores,
                                synthesize_fleet)
from repro.core.tiers import BASELINE_CORES, FailureClass, Tier

from scalar_reference import ScalarOrchestrator


# ---------------------------------------------------------------------------
# Equivalence: the vectorized orchestrator reproduces the scalar seed
# ---------------------------------------------------------------------------


def _run_pair(scale=0.02, seed=1):
    fleet = synthesize_fleet(scale=scale, seed=seed)
    ref = ScalarOrchestrator(fleet, RegionCapacity.for_fleet("r", fleet),
                            scale=scale)
    vec = Orchestrator(fleet, RegionCapacity.for_fleet("r", fleet),
                       scale=scale)
    rep_ref = ref.failover(tv_failover=1.0)
    rep_vec = vec.failover(tv_failover=1.0)
    return ref, vec, rep_ref, rep_vec


def test_vectorized_matches_scalar_timeline():
    ref, vec, rep_ref, rep_vec = _run_pair()
    assert rep_ref.cloud_cores_used == 0, \
        "fixture must not spill to cloud (seed semantics differ there)"
    ref_arrs, vec_arrs = ref.timeline.as_arrays(), vec.timeline.as_arrays()
    assert list(vec_arrs) == list(ref_arrs)
    for key, want in ref_arrs.items():
        got = vec_arrs[key]
        # NaN marks snapshots without that metric (e.g. burst_online
        # outside the conversion ramp): patterns must match too
        np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-6,
                                   equal_nan=True, err_msg=key)
    for field in ("mode", "burst_full_at_s", "am_migrated_at_s",
                  "rl_restored_at_s", "rl_rto_met", "always_on_ok"):
        assert getattr(rep_vec, field) == pytest.approx(
            getattr(rep_ref, field)), field


def test_vectorized_matches_scalar_placements_and_failback():
    ref, vec, _, _ = _run_pair(seed=2)
    for name, s in ref.se.items():
        v = vec.se[name]
        assert v.placement == s.placement, name
        assert v.replicas_live == s.replicas_live, name
        assert v.locked == s.locked, name
    ref.failback()
    vec.failback()
    for name, s in ref.se.items():
        v = vec.se[name]
        assert v.placement == s.placement, name
        assert v.replicas_live == s.replicas_live, name
        assert not v.locked


# ---------------------------------------------------------------------------
# Paper-headline invariants at scale=0.1
# ---------------------------------------------------------------------------


def test_headline_invariants_scale_0_1():
    """Figs 7-10 / §3 goal state: UFA provisions a small multiple of demand
    (vs the legacy dedicated 2x buffer) while every class meets its SLA."""
    fleet = synthesize_fleet(scale=0.1, seed=7)
    apply_ufa_target_classes(fleet)   # Table 5 end-state: T1 -> Active-Migrate
    total = sum(s.cores for s in fleet.values())

    legacy = RegionCapacity.for_fleet("legacy", fleet, model="legacy")
    ufa = RegionCapacity.for_fleet("ufa", fleet, model="ufa")
    legacy_mult = provisioning_multiple(2 * total,
                                        legacy.steady.physical_cores)
    ufa_mult = provisioning_multiple(2 * total, ufa.steady.physical_cores)
    assert legacy_mult >= 2.0
    assert ufa_mult <= 1.4            # paper goal: 1.3x (attained 1.5x)

    orch = Orchestrator(fleet, ufa, scale=0.1)
    rep = orch.failover(tv_failover=1.0)
    assert rep.always_on_ok           # Always-On in-place scale-up succeeds
    assert rep.rl_rto_met             # Restore-Later within the 1h RTO
    assert rep.burst_full_at_s < 20 * 60
    orch.failback()
    assert all(v.placement == "steady" for v in orch.se.values())


# ---------------------------------------------------------------------------
# Array-native synthesis + full-scale failover speed
# ---------------------------------------------------------------------------


def test_array_synthesis_matches_tables():
    fs = synthesize_fleet_state(scale=0.2, seed=3)
    cores = fs.spec_cores
    for tier in Tier:
        got = float(cores[fs.tier == int(tier)].sum())
        target = BASELINE_CORES[tier] * 0.2 * 0.25
        assert abs(got - target) / max(1, target) < 0.35, tier
    # unsafe edges only on tier-inverted (critical -> preemptible) edges
    e = fs.edges
    bad = ~e.fail_open
    assert bad.any()
    assert (fs.fclass[e.src[bad]] <= 1).all()
    assert (fs.fclass[e.dst[bad]] >= 2).all()


def test_full_scale_failover_under_30s():
    """Acceptance: scale=1.0 (~22k services) synthesizes + fails over at
    peak in < 30 s on CPU."""
    t0 = time.time()
    fs = synthesize_fleet(scale=1.0, seed=7, as_arrays=True)
    fs.apply_ufa_target_classes()
    region = RegionCapacity.for_fleet("r", fs)
    orch = Orchestrator(fs, region, scale=1.0)
    rep = orch.failover(tv_failover=1.0)
    elapsed = time.time() - t0
    assert fs.n > 20_000
    assert elapsed < 30.0, elapsed
    assert rep.always_on_ok and rep.rl_rto_met
    # vectorized drill over the same fleet
    cert = certify_fleet_state(fs, seed=0)
    assert cert["n_flagged"] > 0              # un-remediated fleet
    assert cert["n_critical"] > 500
    # remediation: flip fail-close edges open, re-certify
    fs.edges.fail_open[:] = True
    cert2 = certify_fleet_state(fs, seed=0)
    assert cert2["n_flagged"] == 0


def test_fleet_state_from_specs_roundtrip():
    fleet = synthesize_fleet(scale=0.05, seed=0)
    fs = FleetState.from_specs(fleet, with_edges=True)
    assert fs.n == len(fleet)
    assert float(fs.spec_cores.sum()) == pytest.approx(
        sum(s.cores for s in fleet.values()))
    assert fs.edges.n == sum(len(s.deps) for s in fleet.values())
    for fc in FailureClass:
        want = sum(s.cores for s in fleet.values() if s.failure_class == fc)
        assert fs.class_cores(fc) == pytest.approx(want)


# ---------------------------------------------------------------------------
# Scenario-ensemble driver
# ---------------------------------------------------------------------------


def test_scenario_sweep_grid_and_verdicts():
    fs = synthesize_fleet_state(scale=0.1, seed=7)
    fs.apply_ufa_target_classes()
    agg = FleetAggregates.from_fleet_state(fs)
    grid = scenario_grid()
    res = sweep_scenarios(agg, grid)
    n = len(grid["traffic_mult"])
    assert n >= 256
    assert len(res["sla_ok"]) == n
    summary = summarize_sweep(res)
    assert summary["n_scenarios"] == n
    # the paper's operating point (2x traffic, full burst, normal preheat,
    # full quota) must pass every SLA
    op = ((res["traffic_mult"] == 2.0) & (res["burst_availability"] == 1.0)
          & (res["burst_delay_s"] <= 300.0) & (res["cloud_quota_frac"] == 1.0))
    assert op.any()
    assert res["sla_ok"][op].all()
    assert (res["availability"][op] >= 0.999).all()
    # degrading burst availability can only hurt: compare matched scenarios
    hi = res["burst_availability"] == 1.0
    lo = res["burst_availability"] == 0.5
    assert res["availability"][lo].mean() <= res["availability"][hi].mean()
    assert res["sla_ok"].sum() < n   # ensemble includes failing scenarios


def test_scenario_model_tracks_orchestrator():
    """The analytic model's verdict agrees with the discrete-event
    orchestrator at the paper's operating point."""
    fleet = synthesize_fleet(scale=0.05, seed=7)
    region = RegionCapacity.for_fleet("r", fleet)
    orch = Orchestrator(fleet, region, scale=0.05)
    rep = orch.failover(tv_failover=1.0)

    agg = FleetAggregates.from_fleet(fleet)
    res = sweep_scenarios(agg, scenario_grid(
        traffic_mult=(2.0,), burst_delay_s=(270.0,),
        burst_availability=(1.0,), cloud_quota_frac=(1.0,)))
    assert bool(res["ao_ok"][0]) == rep.always_on_ok
    assert bool(res["rl_ok"][0]) == rep.rl_rto_met
    # completion-time estimates in the same ballpark as the event loop
    assert res["burst_full_s"][0] == pytest.approx(rep.burst_full_at_s,
                                                   rel=0.35)
    assert res["rl_done_s"][0] <= 3600.0
