"""The seed's scalar (per-object, dict-loop) OMG orchestrator, kept
verbatim as a behavioral reference: the vectorized FleetState engine must
reproduce its timeline exactly on fleets where no pool overflow / cloud
spill occurs (where the seed's known accounting bugs don't fire)."""

from typing import Callable, Dict, List, Optional

import dataclasses

from repro.core.capacity import PoolState, RegionCapacity
from repro.core.events import EventLoop
from repro.core.omg import FailoverReport, Timeline
from repro.core.service import ServiceSpec
from repro.core.tiers import RTO_SECONDS, FailureClass
from repro.core.traffic import FailoverModeDetector


@dataclasses.dataclass
class ScalarSEState:
    spec: ServiceSpec
    placement: str = "steady"       # steady | burst | cloud | down
    replicas_live: int = 0
    locked: bool = False
    traffic_enabled: bool = True

    @property
    def cores_live(self) -> float:
        return self.replicas_live * self.spec.cores_per_replica


class ScalarOrchestrator:
    """Seed implementation (reference for the equivalence test)."""

    KILL_LATENCY_S = 5.0
    BATCH_EVICT_S = 90.0
    PREFETCH_S = 180.0
    SPAWN_CORES_PER_HOST_S = 0.45
    MBB_WAVE_S = 45.0
    MBB_PARALLELISM = 2000
    RL_RESTORE_WAVE_S = 120.0
    CITY_WAVE_S = 30.0
    TRAFFIC_MULTIPLIER = 2.0

    def __init__(self, fleet: Dict[str, ServiceSpec], region: RegionCapacity,
                 loop: Optional[EventLoop] = None, scale: float = 1.0):
        self.fleet = fleet
        self.region = region
        self.loop = loop or EventLoop()
        self.scale = scale
        self.detector = FailoverModeDetector()
        self.timeline = Timeline()
        self.se: Dict[str, ScalarSEState] = {}
        self._place_steady_state()
        self.report: Optional[FailoverReport] = None
        self._state = "steady"

    def _place_steady_state(self):
        for name, spec in self.fleet.items():
            st = ScalarSEState(spec=spec, replicas_live=spec.replicas)
            pool = (self.region.steady.overcommit
                    if spec.failure_class.preemptible
                    else self.region.steady.stateless)
            ok = pool.alloc(st.cores_live)
            if not ok:
                self.region.steady.stateless.alloc(st.cores_live)
                st.placement = "steady"
            self.se[name] = st

    def _by_class(self, fc: FailureClass) -> List[ScalarSEState]:
        return [s for s in self.se.values() if s.spec.failure_class == fc]

    def class_cores(self, fc: FailureClass,
                    placement: Optional[str] = None) -> float:
        return sum(s.cores_live for s in self._by_class(fc)
                   if placement is None or s.placement == placement)

    def class_envs(self, fc: FailureClass, placement: str) -> int:
        return sum(1 for s in self._by_class(fc)
                   if s.placement == placement and s.replicas_live > 0)

    def _snap(self, **extra):
        burst = (self.region.batch.burst.used
                 if self.region.batch.burst else 0.0)
        burst_cap = (self.region.batch.burst.capacity
                     if self.region.batch.burst else 0.0)
        self.timeline.snap(
            self.loop.now,
            steady_used=self.region.steady.stateless.used,
            overcommit_used=self.region.steady.overcommit.used,
            burst_capacity=burst_cap,
            burst_used=burst,
            cloud_used=self.region.cloud.provisioned,
            rl_t_steady=(self.class_envs(FailureClass.RESTORE_LATER, "steady")
                         + self.class_envs(FailureClass.TERMINATE, "steady")),
            rl_bursted=self.class_envs(FailureClass.RESTORE_LATER, "burst")
            + self.class_envs(FailureClass.RESTORE_LATER, "cloud"),
            rl_not_bursted=sum(
                1 for s in self._by_class(FailureClass.RESTORE_LATER)
                if s.placement == "down"),
            terminated=sum(1 for s in self._by_class(FailureClass.TERMINATE)
                           if s.placement == "down"),
            am_steady=self.class_envs(FailureClass.ACTIVE_MIGRATE, "steady"),
            am_bursted=self.class_envs(FailureClass.ACTIVE_MIGRATE, "burst"),
            utilization=self._utilization(),
            **extra)

    def _utilization(self) -> float:
        mult = self.TRAFFIC_MULTIPLIER if self._state != "steady" else 1.0
        busy = 0.0
        for s in self.se.values():
            if s.placement in ("steady",):
                demand = 0.62 if not s.spec.failure_class.preemptible else 0.35
                m = mult if s.spec.failure_class.survives_failover else 1.0
                busy += s.cores_live * demand * m
        return min(1.0, busy / max(1.0, self.region.steady.physical_cores))

    def failover(self, tv_failover: float = 1.0) -> FailoverReport:
        mode = self.detector.mode(tv_failover)
        rep = FailoverReport(mode=mode, timeline=self.timeline)
        self.report = rep
        self._state = "failover"
        self.loop.log(f"failover start, mode={mode}")
        self._snap()
        if mode == "non-peak":
            self.loop.schedule(self.CITY_WAVE_S * 4, lambda: self._snap())
            rep.always_on_ok = True
            rep.rl_rto_met = True
            self.loop.run()
            return rep

        t0 = self.loop.now
        for s in self.se.values():
            if s.spec.failure_class != FailureClass.ALWAYS_ON:
                s.locked = True
        self.loop.log("lockdown complete")

        def evict_all():
            n = 0
            for s in self.se.values():
                if s.spec.failure_class.preemptible and s.placement == "steady":
                    freed = s.cores_live
                    self.region.steady.overcommit.release(freed)
                    s.placement = "down"
                    s.replicas_live = 0
                    s.traffic_enabled = False
                    n += 1
            self.loop.log(f"BBM evicted {n} preemptible SEs")
            self._snap()
        self.loop.schedule(self.KILL_LATENCY_S, evict_all, "bbm-evict")

        burst_pool_holder: Dict[str, PoolState] = {}

        def start_conversion():
            pool = self.region.batch.convert()
            pool_full = pool.capacity
            burst_pool_holder["pool"] = pool
            steps = 10
            rate = self.SPAWN_CORES_PER_HOST_S * self.region.batch.n_hosts
            ramp_total = pool_full / rate if pool_full > 0 else 0.0
            self._online = 0.0

            def make_tick(i):
                def tick():
                    frac = (i + 1) / steps
                    self._online = pool_full * frac
                    self._snap(burst_online=self._online)
                    if i == steps - 1:
                        rep.burst_full_at_s = self.loop.now - t0
                        self.loop.log("burst capacity fully online")
                        migrate_am()
                        restore_rl()
                return tick
            for i in range(steps):
                self.loop.schedule(ramp_total * (i + 1) / steps, make_tick(i))
        self.loop.schedule(self.BATCH_EVICT_S + self.PREFETCH_S,
                           start_conversion, "burst-conversion")

        def migrate_am():
            pool = burst_pool_holder["pool"]
            ams = [s for s in self._by_class(FailureClass.ACTIVE_MIGRATE)
                   if s.placement == "steady"]
            waves = [ams[i:i + self.MBB_PARALLELISM]
                     for i in range(0, len(ams), self.MBB_PARALLELISM)]

            def run_wave(idx):
                def w():
                    for s in waves[idx]:
                        if not pool.alloc(s.cores_live):
                            rep.notes.append(
                                f"burst full; {s.spec.name} stays in steady")
                            continue
                        self.region.steady.stateless.release(s.cores_live)
                        s.placement = "burst"
                    self._snap()
                    if idx + 1 < len(waves):
                        self.loop.schedule(self.MBB_WAVE_S, run_wave(idx + 1))
                    else:
                        rep.am_migrated_at_s = self.loop.now - t0
                        self.loop.log("Active-Migrate migration complete")
                        scale_always_on()
                return w
            if waves:
                self.loop.schedule(self.MBB_WAVE_S, run_wave(0))
            else:
                rep.am_migrated_at_s = self.loop.now - t0
                scale_always_on()

        def scale_always_on():
            need = self.class_cores(FailureClass.ALWAYS_ON) * \
                (self.TRAFFIC_MULTIPLIER - 1.0)
            got = self.region.steady.stateless.alloc(need)
            if not got:
                rep.always_on_ok = False
                rep.notes.append(
                    f"Always-On scale-up short by "
                    f"{need - self.region.steady.stateless.free:.0f} cores")
            else:
                for s in self._by_class(FailureClass.ALWAYS_ON):
                    s.replicas_live = int(
                        s.replicas_live * self.TRAFFIC_MULTIPLIER)
            self.loop.log("Always-On scaled for 2x traffic")
            self._snap()

        def restore_rl():
            pool = burst_pool_holder["pool"]
            rls = sorted((s for s in self._by_class(FailureClass.RESTORE_LATER)
                          if s.placement == "down"),
                         key=lambda s: s.spec.tier)

            def restore_batch(idx):
                def w():
                    i = idx
                    count = 0
                    while i < len(rls) and count < self.MBB_PARALLELISM:
                        s = rls[i]
                        cores = s.spec.cores
                        if pool.alloc(cores):
                            s.placement = "burst"
                        else:
                            granted = self.region.cloud.provision(cores)
                            if granted < cores:
                                rep.notes.append(
                                    f"cloud quota exhausted at {s.spec.name}")
                                break
                            s.placement = "cloud"
                        s.replicas_live = s.spec.replicas
                        s.traffic_enabled = True
                        i += 1
                        count += 1
                    self._snap()
                    if i < len(rls) and count > 0:
                        self.loop.schedule(self.RL_RESTORE_WAVE_S,
                                           restore_batch(i))
                    else:
                        rep.rl_restored_at_s = self.loop.now - t0
                        rep.rl_rto_met = (rep.rl_restored_at_s <=
                                          RTO_SECONDS[FailureClass.RESTORE_LATER])
                        rep.cloud_cores_used = self.region.cloud.provisioned
                        self.loop.log("Restore-Later restoration complete")
                return w
            self.loop.schedule(self.RL_RESTORE_WAVE_S, restore_batch(0))

        self.loop.run()
        self._snap()
        return rep

    def failback(self) -> None:
        self._state = "failback"
        self.loop.log("failback start")

        def move_back():
            for s in self.se.values():
                if s.placement in ("burst", "cloud"):
                    pool = (self.region.steady.overcommit
                            if s.spec.failure_class.preemptible
                            else self.region.steady.stateless)
                    pool.alloc(s.spec.cores)
                    s.placement = "steady"
                    s.replicas_live = s.spec.replicas
                if s.spec.failure_class == FailureClass.ALWAYS_ON:
                    s.replicas_live = s.spec.replicas
            self._snap()

        def reenable_terminate():
            for s in self._by_class(FailureClass.TERMINATE):
                if s.placement == "down":
                    s.placement = "steady"
                    s.replicas_live = s.spec.replicas
                    s.traffic_enabled = True
                    self.region.steady.overcommit.alloc(s.cores_live)
            self._snap()

        def release_resources():
            self.region.batch.release()
            self.region.cloud.release_all()
            for s in self.se.values():
                s.locked = False
            self._state = "steady"
            self.loop.log("failback complete; locks released")
            self._snap()

        self.loop.schedule(self.CITY_WAVE_S * 4, move_back, "traffic-back")
        self.loop.schedule(self.CITY_WAVE_S * 6, reenable_terminate)
        self.loop.schedule(self.CITY_WAVE_S * 10, release_resources)
        self.loop.run()


# ---------------------------------------------------------------------------
# Scalar telemetry reference (the seed's runtime fail-close layer, kept
# verbatim): one Python RPCRecord per RPC, a binary search per sample, a
# dict update per record.  The array-native engine in
# ``repro.core.dependency`` must (a) produce bit-identical per-edge counts
# when ingesting the same record stream, and (b) match this pipeline's
# precision/recall statistics when each samples its own stream.
# ---------------------------------------------------------------------------

import random
from collections import defaultdict
from typing import Iterable, Set, Tuple

from repro.core.dependency import EdgeStats, RPCRecord


def scalar_generate_traces(fleet: Dict[str, ServiceSpec],
                           n_records: int = 200_000, seed: int = 0,
                           ambient_callee_failure: float = 0.025,
                           ambient_caller_error: float = 0.003,
                           cold_path_fraction: float = 0.18):
    """Seed implementation of ``generate_traces`` (reference)."""
    from repro.core.service import _TABLE2
    rng = random.Random(seed)
    edges = [(s.name, d) for s in fleet.values() for d in s.deps]
    if not edges:
        return [], set()
    unsafe = {(s.name, d) for s in fleet.values() for d in s.unsafe_deps()}
    cold: Set[Tuple[str, str]] = {
        e for e in unsafe if rng.random() < cold_path_fraction}
    tier_of = {n: s.tier for n, s in fleet.items()}
    cell_edges: Dict[Tuple[int, int], int] = {}
    for caller, callee in edges:
        cell = (int(tier_of[caller]), int(tier_of[callee]))
        cell_edges[cell] = cell_edges.get(cell, 0) + 1
    weights = []
    for e in edges:
        caller, callee = e
        cell = (int(tier_of[caller]), int(tier_of[callee]))
        vol = _TABLE2[tier_of[caller]][int(tier_of[callee])]
        w = vol / cell_edges[cell]
        weights.append(w * (0.01 if e in cold else 1.0))
    tot = sum(weights)
    cum = []
    acc = 0.0
    for w in weights:
        acc += w
        cum.append(acc)

    records = []
    for _ in range(n_records):
        r = rng.uniform(0, tot)
        lo, hi = 0, len(cum) - 1
        while lo < hi:
            mid = (lo + hi) // 2
            if cum[mid] < r:
                lo = mid + 1
            else:
                hi = mid
        caller, callee = edges[lo]
        callee_failed = rng.random() < ambient_callee_failure
        if (caller, callee) in unsafe:
            caller_errored = (callee_failed and rng.random() < 0.92) or \
                rng.random() < ambient_caller_error
        else:
            caller_errored = rng.random() < ambient_caller_error
        records.append(RPCRecord(caller, callee, callee_failed,
                                 caller_errored))
    return records, cold


class ScalarFailCloseDetector:
    """Seed implementation of ``RuntimeFailCloseDetector`` (reference)."""

    def __init__(self, min_failures: int = 5,
                 propagation_threshold: float = 0.5,
                 lift_threshold: float = 5.0):
        self.stats: Dict[Tuple[str, str], EdgeStats] = defaultdict(EdgeStats)
        self.min_failures = min_failures
        self.propagation_threshold = propagation_threshold
        self.lift_threshold = lift_threshold

    def ingest(self, records: Iterable[RPCRecord]):
        for r in records:
            st = self.stats[(r.caller, r.callee)]
            st.calls += 1
            if r.callee_failed:
                st.callee_failures += 1
                if r.caller_errored:
                    st.errors_given_failure += 1
            elif r.caller_errored:
                st.errors_given_ok += 1

    def detect(self) -> Set[Tuple[str, str]]:
        out: Set[Tuple[str, str]] = set()
        for edge, st in self.stats.items():
            if st.callee_failures < self.min_failures:
                continue
            p_fail = st.errors_given_failure / st.callee_failures
            ok_calls = max(1, st.calls - st.callee_failures)
            p_ok = st.errors_given_ok / ok_calls
            if p_fail >= self.propagation_threshold and \
                    p_fail >= self.lift_threshold * max(p_ok, 1e-4):
                out.add(edge)
        return out


def scalar_runtime_analysis(fleet: Dict[str, ServiceSpec],
                            n_records: Optional[int] = None,
                            seed: int = 0) -> Dict[str, object]:
    """Seed implementation of ``runtime_analysis`` (reference; graph build
    omitted — the statistics are what the parity tests compare)."""
    n_edges = sum(len(s.deps) for s in fleet.values())
    if n_records is None:
        n_records = 400 * max(1, n_edges)
    records, cold = scalar_generate_traces(fleet, n_records, seed)
    det = ScalarFailCloseDetector()
    det.ingest(records)
    found = det.detect()
    truth = {(s.name, d) for s in fleet.values() for d in s.unsafe_deps()}
    tp = found & truth
    return {
        "found": found,
        "truth": truth,
        "cold_paths": cold,
        "true_positives": len(tp),
        "false_positives": len(found - truth),
        "missed": len(truth - found),
        "missed_cold": len((truth - found) & cold),
        "precision": len(tp) / max(1, len(found)),
        "recall": len(tp) / max(1, len(truth)),
    }


# ---------------------------------------------------------------------------
# Scalar timeline-stepper reference (for the array-native discrete-time
# failover simulator in ``repro.core.timeline_sim``): a plain Python loop
# over the time grid with if/else control flow — no arrays, no closed-form
# vectorized tricks — implementing the same documented semantics.  The
# equivalence tests pin the ``lax.scan`` kernel's traces to this stepper
# (float32-tight tolerances; env counts, event times and verdicts exact).
# ---------------------------------------------------------------------------

import math


def scalar_timeline(cfg, params=None, ts=None):
    """Reference for ``timeline_sim.simulate_timeline``: same
    ``TimelineConfig`` / scenario-params / time-grid inputs, same output
    keys, scalar Python arithmetic throughout."""
    from repro.core.timeline_sim import (AVAIL_SLA_TOL, BASE_AVAILABILITY,
                                         EPS_T, N_TIERS, RESTORE_THRESH,
                                         default_scenario, default_ts)
    from repro.core.tiers import QOS_EVICT_UTILIZATION

    p = dict(default_scenario(burst_delay_s=cfg.preheat_s),
             **(params or {}))
    if ts is None:
        ts = default_ts()
    ts = [float(t) for t in ts]

    ao, am, rl, tm = (cfg.ao_cores, cfg.am_cores, cfg.rl_cores,
                      cfg.tm_cores)
    mult = p["traffic_mult"]
    evict = p["evict_fraction"]

    # ---- schedule (mirrors the spec in timeline_sim, scalar math) ----
    burst_cap = cfg.burst_cap_full * p["burst_availability"]
    ramp_total = burst_cap / max(cfg.spawn_rate, 1e-9)
    tick_s = ramp_total / 10.0
    burst_full_t = p["burst_delay_s"] + ramp_total

    n_am_waves = math.ceil(cfg.am_envs / cfg.mbb_parallelism)
    am_done_t = burst_full_t + n_am_waves * cfg.mbb_wave_s
    am_in_burst = min(am, burst_cap)

    ao_need = ao * (mult - 1.0)
    am_release_frac = cfg.am_stateless_cores / max(am, 1e-9)
    am_released = am_in_burst * am_release_frac
    free_at_am_done = cfg.stateless_cap - (
        cfg.steady_used0 - evict * cfg.sl_preempt_cores - am_released)
    ao_ok = ao_need <= free_at_am_done + 1e-6
    ao_short = max(0.0, ao_need - free_at_am_done)

    rl_need = rl * evict
    rl_envs_evicted = cfg.rl_envs * evict
    n_rl_waves = max(1, math.ceil(rl_envs_evicted / cfg.mbb_parallelism))
    rl_last_wave_t = burst_full_t + n_rl_waves * cfg.rl_wave_s
    burst_free_rl = max(0.0, burst_cap - am_in_burst)
    quota_eff = cfg.cloud_quota * p["cloud_quota_frac"]
    total_cloud = min(max(0.0, rl_need - burst_free_rl), quota_eff)
    per_wave = rl_need / n_rl_waves
    k_star = min(math.floor(burst_free_rl / max(per_wave, 1e-9)) + 1,
                 n_rl_waves)
    cloud_start_t = burst_full_t + k_star * cfg.rl_wave_s
    cloud_arrival_t = cloud_start_t + total_cloud / max(cfg.cloud_rate,
                                                        1e-9)
    rl_shortfall = max(0.0, rl_need - burst_free_rl - quota_eff)
    if rl_shortfall > 1e-6:
        rl_done_t = float("inf")
    else:
        rl_done_t = rl_last_wave_t
        if total_cloud > 1e-6:
            rl_done_t = max(rl_done_t, cloud_arrival_t)

    tier_class = cfg.tier_class_cores
    tier_total = [max(sum(tier_class[t]), 1e-9) for t in range(N_TIERS)]

    series = {k: [] for k in (
        "steady_used", "overcommit_used", "burst_capacity", "burst_online",
        "burst_used", "cloud_used", "ao_live", "am_live", "rl_live",
        "tm_live", "am_steady", "am_bursted", "rl_bursted",
        "rl_not_bursted", "rl_t_steady", "terminated", "utilization",
        "util_model", "availability")}
    tier_live_rows = []
    avail_int, avail_min = 0.0, 1.0
    util_peak, cloud_peak = 0.0, 0.0
    below_seen = [False] * N_TIERS
    restore_t = [float("inf")] * N_TIERS
    prev_t = ts[0]

    for t in ts:
        evicted = t >= cfg.kill_s - EPS_T
        e = evict if evicted else 0.0

        ticks = math.floor((t - p["burst_delay_s"] + EPS_T)
                           / max(tick_s, 1e-9))
        ticks = min(10, max(0, ticks))
        burst_online = burst_cap * ticks / 10.0
        burst_capacity = burst_cap if t >= p["burst_delay_s"] - EPS_T \
            else 0.0

        waves = math.floor((t - burst_full_t + EPS_T) / cfg.mbb_wave_s)
        waves = min(n_am_waves, max(0, waves))
        am_envs_moved = min(cfg.am_envs, cfg.mbb_parallelism * waves)
        am_moved = min(am * am_envs_moved / max(cfg.am_envs, 1.0),
                       burst_cap)

        ao_scaled = ao_ok and t >= am_done_t - EPS_T
        ao_live = ao * (mult if ao_scaled else 1.0)
        ao_extra = ao_need if ao_scaled else 0.0

        rl_waves = math.floor((t - burst_full_t + EPS_T) / cfg.rl_wave_s)
        rl_waves = min(n_rl_waves, max(0, rl_waves))
        processed = rl_need * rl_waves / n_rl_waves
        rl_burst = min(processed, burst_free_rl)
        cloud_prov = min(processed - rl_burst, quota_eff)
        cloud_live = total_cloud if t >= cloud_arrival_t - EPS_T else 0.0
        cloud_live = min(cloud_live, cloud_prov)
        rl_restored = rl_burst + cloud_live
        rl_live = rl - e * rl + rl_restored
        tm_live = tm * (1.0 - e)

        steady_used = (cfg.steady_used0 - e * cfg.sl_preempt_cores
                       - am_moved * am_release_frac + ao_extra)
        overcommit_used = cfg.overcommit_used0 - e * cfg.oc_preempt_cores
        burst_used = am_moved + rl_burst

        am_bursted = am_envs_moved
        rl_bursted = round(rl_envs_evicted * rl_restored
                           / max(rl_need, 1e-9))
        rl_not_bursted = round(e * cfg.rl_envs) - rl_bursted
        rl_t_steady = round((1.0 - e) * (cfg.rl_envs + cfg.tm_envs))
        terminated = round(e * cfg.tm_envs)

        am_steady_cores = am - am_moved
        pre_steady = (rl + tm) * (1.0 - e)
        busy = (ao_live * 0.62 * mult + am_steady_cores * 0.62 * mult
                + pre_steady * 0.35)
        utilization = min(1.0, busy / max(cfg.phys_cores, 1.0))
        busy_model = (ao * 0.62 * mult + am_steady_cores * 0.62 * mult
                      + pre_steady * 0.35)
        util_model = min(1.0, busy_model / max(cfg.stateless_cap, 1.0))

        crit = max(ao + am, 1.0)
        rl_down = rl - rl_live
        tm_down = tm - tm_live
        ao_pen = 0.5 * ao_short / crit if evicted else 0.0
        rl_pen = (0.1 * rl_down / max(rl, 1.0)
                  if t > cfg.rl_rto_s + EPS_T else 0.0)
        dark_tot = max(rl_need + evict * tm, 1e-9)
        dep_pen = 0.5 * p["dep_broken_frac"] * (rl_down + tm_down) / dark_tot
        util_pen = 1e-4 if util_model > QOS_EVICT_UTILIZATION else 0.0
        availability = min(1.0, max(
            0.0, BASE_AVAILABILITY - ao_pen - rl_pen - dep_pen - util_pen))

        class_live = [ao_live, am, rl_live, tm_live]
        class_total = [ao, am, rl, tm]
        frac = [class_live[c] / max(class_total[c], 1e-9) for c in range(4)]
        tier_live = [sum(tier_class[ti][c] * frac[c] for c in range(4))
                     for ti in range(N_TIERS)]

        for k, v in (("steady_used", steady_used),
                     ("overcommit_used", overcommit_used),
                     ("burst_capacity", burst_capacity),
                     ("burst_online", burst_online),
                     ("burst_used", burst_used), ("cloud_used", cloud_prov),
                     ("ao_live", ao_live), ("am_live", am),
                     ("rl_live", rl_live), ("tm_live", tm_live),
                     ("am_steady", cfg.am_envs - am_bursted),
                     ("am_bursted", am_bursted), ("rl_bursted", rl_bursted),
                     ("rl_not_bursted", rl_not_bursted),
                     ("rl_t_steady", rl_t_steady),
                     ("terminated", terminated),
                     ("utilization", utilization),
                     ("util_model", util_model),
                     ("availability", availability)):
            series[k].append(v)
        tier_live_rows.append(tier_live)

        avail_int += availability * max(0.0, t - prev_t)
        avail_min = min(avail_min, availability)
        util_peak = max(util_peak, util_model)
        cloud_peak = max(cloud_peak, cloud_prov)
        for ti in range(N_TIERS):
            below = tier_live[ti] / tier_total[ti] < RESTORE_THRESH
            if below:
                below_seen[ti] = True
            elif below_seen[ti] and math.isinf(restore_t[ti]):
                restore_t[ti] = t
        prev_t = t

    span = max(ts[-1] - ts[0], 1e-9)
    availability_mean = avail_int / span
    oc_cap_s = cfg.stateless_cap * (p["overcommit_factor"] - 1.0)
    preempt_resident = (rl + tm) * (1.0 - evict)
    preempt_fit = preempt_resident <= oc_cap_s + 1e-6
    dep_ok = p["dep_broken_frac"] <= 0.0
    avail_ok = availability_mean >= BASE_AVAILABILITY - AVAIL_SLA_TOL
    # verdict utilization: post-migration steady point (stranded AM only)
    am_stranded = am - am_in_burst
    busy_post = (ao * 0.62 * mult + am_stranded * 0.62 * mult
                 + preempt_resident * 0.35)
    util_post = min(1.0, busy_post / max(cfg.stateless_cap, 1.0))
    util_ok = util_post <= QOS_EVICT_UTILIZATION
    rl_rto_met = rl_done_t <= cfg.rl_rto_s + EPS_T
    sla_ok = (ao_ok and rl_rto_met and preempt_fit and dep_ok and avail_ok
              and util_ok and am_done_t <= 30.0 * 60.0
              and burst_full_t <= 20.0 * 60.0)
    out = {"t": ts}
    out.update(series)
    out["tier_live"] = tier_live_rows
    out.update({
        "burst_full_s": burst_full_t, "am_done_s": am_done_t,
        "rl_done_s": rl_done_t, "rl_rto_met": rl_rto_met,
        "ao_ok": ao_ok, "ao_short_cores": ao_short,
        "rl_shortfall_cores": rl_shortfall,
        "cloud_grant_cores": total_cloud,
        "cloud_arrival_s": cloud_arrival_t, "peak_cloud_cores": cloud_peak,
        "availability_mean": availability_mean, "availability_min": avail_min,
        "util_peak": util_peak, "util_post": util_post,
        "time_to_restore_s": [restore_t[ti] if below_seen[ti] else 0.0
                              for ti in range(N_TIERS)],
        "preempt_fit": preempt_fit, "dep_ok": dep_ok, "avail_ok": avail_ok,
        "util_ok": util_ok, "sla_ok": sla_ok,
    })
    return out
