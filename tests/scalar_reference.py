"""The seed's scalar (per-object, dict-loop) OMG orchestrator, kept
verbatim as a behavioral reference: the vectorized FleetState engine must
reproduce its timeline exactly on fleets where no pool overflow / cloud
spill occurs (where the seed's known accounting bugs don't fire)."""

from typing import Callable, Dict, List, Optional

import dataclasses

from repro.core.capacity import PoolState, RegionCapacity
from repro.core.events import EventLoop
from repro.core.omg import FailoverReport, Timeline
from repro.core.service import ServiceSpec
from repro.core.tiers import RTO_SECONDS, FailureClass
from repro.core.traffic import FailoverModeDetector


@dataclasses.dataclass
class ScalarSEState:
    spec: ServiceSpec
    placement: str = "steady"       # steady | burst | cloud | down
    replicas_live: int = 0
    locked: bool = False
    traffic_enabled: bool = True

    @property
    def cores_live(self) -> float:
        return self.replicas_live * self.spec.cores_per_replica


class ScalarOrchestrator:
    """Seed implementation (reference for the equivalence test)."""

    KILL_LATENCY_S = 5.0
    BATCH_EVICT_S = 90.0
    PREFETCH_S = 180.0
    SPAWN_CORES_PER_HOST_S = 0.45
    MBB_WAVE_S = 45.0
    MBB_PARALLELISM = 2000
    RL_RESTORE_WAVE_S = 120.0
    CITY_WAVE_S = 30.0
    TRAFFIC_MULTIPLIER = 2.0

    def __init__(self, fleet: Dict[str, ServiceSpec], region: RegionCapacity,
                 loop: Optional[EventLoop] = None, scale: float = 1.0):
        self.fleet = fleet
        self.region = region
        self.loop = loop or EventLoop()
        self.scale = scale
        self.detector = FailoverModeDetector()
        self.timeline = Timeline()
        self.se: Dict[str, ScalarSEState] = {}
        self._place_steady_state()
        self.report: Optional[FailoverReport] = None
        self._state = "steady"

    def _place_steady_state(self):
        for name, spec in self.fleet.items():
            st = ScalarSEState(spec=spec, replicas_live=spec.replicas)
            pool = (self.region.steady.overcommit
                    if spec.failure_class.preemptible
                    else self.region.steady.stateless)
            ok = pool.alloc(st.cores_live)
            if not ok:
                self.region.steady.stateless.alloc(st.cores_live)
                st.placement = "steady"
            self.se[name] = st

    def _by_class(self, fc: FailureClass) -> List[ScalarSEState]:
        return [s for s in self.se.values() if s.spec.failure_class == fc]

    def class_cores(self, fc: FailureClass,
                    placement: Optional[str] = None) -> float:
        return sum(s.cores_live for s in self._by_class(fc)
                   if placement is None or s.placement == placement)

    def class_envs(self, fc: FailureClass, placement: str) -> int:
        return sum(1 for s in self._by_class(fc)
                   if s.placement == placement and s.replicas_live > 0)

    def _snap(self, **extra):
        burst = (self.region.batch.burst.used
                 if self.region.batch.burst else 0.0)
        burst_cap = (self.region.batch.burst.capacity
                     if self.region.batch.burst else 0.0)
        self.timeline.snap(
            self.loop.now,
            steady_used=self.region.steady.stateless.used,
            overcommit_used=self.region.steady.overcommit.used,
            burst_capacity=burst_cap,
            burst_used=burst,
            cloud_used=self.region.cloud.provisioned,
            rl_t_steady=(self.class_envs(FailureClass.RESTORE_LATER, "steady")
                         + self.class_envs(FailureClass.TERMINATE, "steady")),
            rl_bursted=self.class_envs(FailureClass.RESTORE_LATER, "burst")
            + self.class_envs(FailureClass.RESTORE_LATER, "cloud"),
            rl_not_bursted=sum(
                1 for s in self._by_class(FailureClass.RESTORE_LATER)
                if s.placement == "down"),
            terminated=sum(1 for s in self._by_class(FailureClass.TERMINATE)
                           if s.placement == "down"),
            am_steady=self.class_envs(FailureClass.ACTIVE_MIGRATE, "steady"),
            am_bursted=self.class_envs(FailureClass.ACTIVE_MIGRATE, "burst"),
            utilization=self._utilization(),
            **extra)

    def _utilization(self) -> float:
        mult = self.TRAFFIC_MULTIPLIER if self._state != "steady" else 1.0
        busy = 0.0
        for s in self.se.values():
            if s.placement in ("steady",):
                demand = 0.62 if not s.spec.failure_class.preemptible else 0.35
                m = mult if s.spec.failure_class.survives_failover else 1.0
                busy += s.cores_live * demand * m
        return min(1.0, busy / max(1.0, self.region.steady.physical_cores))

    def failover(self, tv_failover: float = 1.0) -> FailoverReport:
        mode = self.detector.mode(tv_failover)
        rep = FailoverReport(mode=mode, timeline=self.timeline)
        self.report = rep
        self._state = "failover"
        self.loop.log(f"failover start, mode={mode}")
        self._snap()
        if mode == "non-peak":
            self.loop.schedule(self.CITY_WAVE_S * 4, lambda: self._snap())
            rep.always_on_ok = True
            rep.rl_rto_met = True
            self.loop.run()
            return rep

        t0 = self.loop.now
        for s in self.se.values():
            if s.spec.failure_class != FailureClass.ALWAYS_ON:
                s.locked = True
        self.loop.log("lockdown complete")

        def evict_all():
            n = 0
            for s in self.se.values():
                if s.spec.failure_class.preemptible and s.placement == "steady":
                    freed = s.cores_live
                    self.region.steady.overcommit.release(freed)
                    s.placement = "down"
                    s.replicas_live = 0
                    s.traffic_enabled = False
                    n += 1
            self.loop.log(f"BBM evicted {n} preemptible SEs")
            self._snap()
        self.loop.schedule(self.KILL_LATENCY_S, evict_all, "bbm-evict")

        burst_pool_holder: Dict[str, PoolState] = {}

        def start_conversion():
            pool = self.region.batch.convert()
            pool_full = pool.capacity
            burst_pool_holder["pool"] = pool
            steps = 10
            rate = self.SPAWN_CORES_PER_HOST_S * self.region.batch.n_hosts
            ramp_total = pool_full / rate if pool_full > 0 else 0.0
            self._online = 0.0

            def make_tick(i):
                def tick():
                    frac = (i + 1) / steps
                    self._online = pool_full * frac
                    self._snap(burst_online=self._online)
                    if i == steps - 1:
                        rep.burst_full_at_s = self.loop.now - t0
                        self.loop.log("burst capacity fully online")
                        migrate_am()
                        restore_rl()
                return tick
            for i in range(steps):
                self.loop.schedule(ramp_total * (i + 1) / steps, make_tick(i))
        self.loop.schedule(self.BATCH_EVICT_S + self.PREFETCH_S,
                           start_conversion, "burst-conversion")

        def migrate_am():
            pool = burst_pool_holder["pool"]
            ams = [s for s in self._by_class(FailureClass.ACTIVE_MIGRATE)
                   if s.placement == "steady"]
            waves = [ams[i:i + self.MBB_PARALLELISM]
                     for i in range(0, len(ams), self.MBB_PARALLELISM)]

            def run_wave(idx):
                def w():
                    for s in waves[idx]:
                        if not pool.alloc(s.cores_live):
                            rep.notes.append(
                                f"burst full; {s.spec.name} stays in steady")
                            continue
                        self.region.steady.stateless.release(s.cores_live)
                        s.placement = "burst"
                    self._snap()
                    if idx + 1 < len(waves):
                        self.loop.schedule(self.MBB_WAVE_S, run_wave(idx + 1))
                    else:
                        rep.am_migrated_at_s = self.loop.now - t0
                        self.loop.log("Active-Migrate migration complete")
                        scale_always_on()
                return w
            if waves:
                self.loop.schedule(self.MBB_WAVE_S, run_wave(0))
            else:
                rep.am_migrated_at_s = self.loop.now - t0
                scale_always_on()

        def scale_always_on():
            need = self.class_cores(FailureClass.ALWAYS_ON) * \
                (self.TRAFFIC_MULTIPLIER - 1.0)
            got = self.region.steady.stateless.alloc(need)
            if not got:
                rep.always_on_ok = False
                rep.notes.append(
                    f"Always-On scale-up short by "
                    f"{need - self.region.steady.stateless.free:.0f} cores")
            else:
                for s in self._by_class(FailureClass.ALWAYS_ON):
                    s.replicas_live = int(
                        s.replicas_live * self.TRAFFIC_MULTIPLIER)
            self.loop.log("Always-On scaled for 2x traffic")
            self._snap()

        def restore_rl():
            pool = burst_pool_holder["pool"]
            rls = sorted((s for s in self._by_class(FailureClass.RESTORE_LATER)
                          if s.placement == "down"),
                         key=lambda s: s.spec.tier)

            def restore_batch(idx):
                def w():
                    i = idx
                    count = 0
                    while i < len(rls) and count < self.MBB_PARALLELISM:
                        s = rls[i]
                        cores = s.spec.cores
                        if pool.alloc(cores):
                            s.placement = "burst"
                        else:
                            granted = self.region.cloud.provision(cores)
                            if granted < cores:
                                rep.notes.append(
                                    f"cloud quota exhausted at {s.spec.name}")
                                break
                            s.placement = "cloud"
                        s.replicas_live = s.spec.replicas
                        s.traffic_enabled = True
                        i += 1
                        count += 1
                    self._snap()
                    if i < len(rls) and count > 0:
                        self.loop.schedule(self.RL_RESTORE_WAVE_S,
                                           restore_batch(i))
                    else:
                        rep.rl_restored_at_s = self.loop.now - t0
                        rep.rl_rto_met = (rep.rl_restored_at_s <=
                                          RTO_SECONDS[FailureClass.RESTORE_LATER])
                        rep.cloud_cores_used = self.region.cloud.provisioned
                        self.loop.log("Restore-Later restoration complete")
                return w
            self.loop.schedule(self.RL_RESTORE_WAVE_S, restore_batch(0))

        self.loop.run()
        self._snap()
        return rep

    def failback(self) -> None:
        self._state = "failback"
        self.loop.log("failback start")

        def move_back():
            for s in self.se.values():
                if s.placement in ("burst", "cloud"):
                    pool = (self.region.steady.overcommit
                            if s.spec.failure_class.preemptible
                            else self.region.steady.stateless)
                    pool.alloc(s.spec.cores)
                    s.placement = "steady"
                    s.replicas_live = s.spec.replicas
                if s.spec.failure_class == FailureClass.ALWAYS_ON:
                    s.replicas_live = s.spec.replicas
            self._snap()

        def reenable_terminate():
            for s in self._by_class(FailureClass.TERMINATE):
                if s.placement == "down":
                    s.placement = "steady"
                    s.replicas_live = s.spec.replicas
                    s.traffic_enabled = True
                    self.region.steady.overcommit.alloc(s.cores_live)
            self._snap()

        def release_resources():
            self.region.batch.release()
            self.region.cloud.release_all()
            for s in self.se.values():
                s.locked = False
            self._state = "steady"
            self.loop.log("failback complete; locks released")
            self._snap()

        self.loop.schedule(self.CITY_WAVE_S * 4, move_back, "traffic-back")
        self.loop.schedule(self.CITY_WAVE_S * 6, reenable_terminate)
        self.loop.schedule(self.CITY_WAVE_S * 10, release_resources)
        self.loop.run()


# ---------------------------------------------------------------------------
# Scalar telemetry reference (the seed's runtime fail-close layer, kept
# verbatim): one Python RPCRecord per RPC, a binary search per sample, a
# dict update per record.  The array-native engine in
# ``repro.core.dependency`` must (a) produce bit-identical per-edge counts
# when ingesting the same record stream, and (b) match this pipeline's
# precision/recall statistics when each samples its own stream.
# ---------------------------------------------------------------------------

import random
from collections import defaultdict
from typing import Iterable, Set, Tuple

from repro.core.dependency import EdgeStats, RPCRecord


def scalar_generate_traces(fleet: Dict[str, ServiceSpec],
                           n_records: int = 200_000, seed: int = 0,
                           ambient_callee_failure: float = 0.025,
                           ambient_caller_error: float = 0.003,
                           cold_path_fraction: float = 0.18):
    """Seed implementation of ``generate_traces`` (reference)."""
    from repro.core.service import _TABLE2
    rng = random.Random(seed)
    edges = [(s.name, d) for s in fleet.values() for d in s.deps]
    if not edges:
        return [], set()
    unsafe = {(s.name, d) for s in fleet.values() for d in s.unsafe_deps()}
    cold: Set[Tuple[str, str]] = {
        e for e in unsafe if rng.random() < cold_path_fraction}
    tier_of = {n: s.tier for n, s in fleet.items()}
    cell_edges: Dict[Tuple[int, int], int] = {}
    for caller, callee in edges:
        cell = (int(tier_of[caller]), int(tier_of[callee]))
        cell_edges[cell] = cell_edges.get(cell, 0) + 1
    weights = []
    for e in edges:
        caller, callee = e
        cell = (int(tier_of[caller]), int(tier_of[callee]))
        vol = _TABLE2[tier_of[caller]][int(tier_of[callee])]
        w = vol / cell_edges[cell]
        weights.append(w * (0.01 if e in cold else 1.0))
    tot = sum(weights)
    cum = []
    acc = 0.0
    for w in weights:
        acc += w
        cum.append(acc)

    records = []
    for _ in range(n_records):
        r = rng.uniform(0, tot)
        lo, hi = 0, len(cum) - 1
        while lo < hi:
            mid = (lo + hi) // 2
            if cum[mid] < r:
                lo = mid + 1
            else:
                hi = mid
        caller, callee = edges[lo]
        callee_failed = rng.random() < ambient_callee_failure
        if (caller, callee) in unsafe:
            caller_errored = (callee_failed and rng.random() < 0.92) or \
                rng.random() < ambient_caller_error
        else:
            caller_errored = rng.random() < ambient_caller_error
        records.append(RPCRecord(caller, callee, callee_failed,
                                 caller_errored))
    return records, cold


class ScalarFailCloseDetector:
    """Seed implementation of ``RuntimeFailCloseDetector`` (reference)."""

    def __init__(self, min_failures: int = 5,
                 propagation_threshold: float = 0.5,
                 lift_threshold: float = 5.0):
        self.stats: Dict[Tuple[str, str], EdgeStats] = defaultdict(EdgeStats)
        self.min_failures = min_failures
        self.propagation_threshold = propagation_threshold
        self.lift_threshold = lift_threshold

    def ingest(self, records: Iterable[RPCRecord]):
        for r in records:
            st = self.stats[(r.caller, r.callee)]
            st.calls += 1
            if r.callee_failed:
                st.callee_failures += 1
                if r.caller_errored:
                    st.errors_given_failure += 1
            elif r.caller_errored:
                st.errors_given_ok += 1

    def detect(self) -> Set[Tuple[str, str]]:
        out: Set[Tuple[str, str]] = set()
        for edge, st in self.stats.items():
            if st.callee_failures < self.min_failures:
                continue
            p_fail = st.errors_given_failure / st.callee_failures
            ok_calls = max(1, st.calls - st.callee_failures)
            p_ok = st.errors_given_ok / ok_calls
            if p_fail >= self.propagation_threshold and \
                    p_fail >= self.lift_threshold * max(p_ok, 1e-4):
                out.add(edge)
        return out


def scalar_runtime_analysis(fleet: Dict[str, ServiceSpec],
                            n_records: Optional[int] = None,
                            seed: int = 0) -> Dict[str, object]:
    """Seed implementation of ``runtime_analysis`` (reference; graph build
    omitted — the statistics are what the parity tests compare)."""
    n_edges = sum(len(s.deps) for s in fleet.values())
    if n_records is None:
        n_records = 400 * max(1, n_edges)
    records, cold = scalar_generate_traces(fleet, n_records, seed)
    det = ScalarFailCloseDetector()
    det.ingest(records)
    found = det.detect()
    truth = {(s.name, d) for s in fleet.values() for d in s.unsafe_deps()}
    tp = found & truth
    return {
        "found": found,
        "truth": truth,
        "cold_paths": cold,
        "true_positives": len(tp),
        "false_positives": len(found - truth),
        "missed": len(truth - found),
        "missed_cold": len((truth - found) & cold),
        "precision": len(tp) / max(1, len(found)),
        "recall": len(tp) / max(1, len(truth)),
    }
