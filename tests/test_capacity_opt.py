"""Capacity-optimizer suite: gradient correctness (finite differences
through the soft-relaxed fused pipeline), soft->hard verdict agreement at
low temperature, the optimizer itself (grad + CEM improve on the legacy
start and hard-verify), and the sweep-input-validation / failure-mode
bugfix regressions that rode along (unknown grid keys, empty grids, the
``recommend_factor`` safe flag + exact grid endpoint, the hardening
planner's vanished-under-``-O`` stall assert)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.fleet_state import FleetState
from repro.core.scenarios import (FleetAggregates, scenario_grid,
                                  scenario_outcome, sweep_scenarios)
from repro.core.service import apply_ufa_target_classes, synthesize_fleet
from repro.core.sweep_engine import SweepEngine
from repro.core.timeline_sim import (config_for_fleet, default_ts,
                                     sweep_timeline, validate_grid)
from repro.optim.capacity import (DesignBase, _design_params, _grid_cols,
                                  certification_grid, design_consts,
                                  eviction_deltas, hardening_weights,
                                  knob_design, legacy_knobs, make_knobs,
                                  optimize_capacity, provisioning,
                                  soft_loss, ufa_knobs, verify_design)

SCALE, SEED = 0.02, 7


@pytest.fixture(scope="module")
def fs():
    fleet = synthesize_fleet(scale=SCALE, seed=SEED)
    apply_ufa_target_classes(fleet)
    return FleetState.from_specs(fleet)


@pytest.fixture(scope="module")
def engine(fs):
    agg = FleetAggregates.from_fleet_state(fs)
    return SweepEngine(agg, config_for_fleet(fs), reducer="scan")


@pytest.fixture(scope="module")
def base(fs):
    return DesignBase.from_fleet_state(fs).as_arrays()


# ---------------------------------------------------------------------------
# gradient correctness: jax.grad vs central finite differences
# ---------------------------------------------------------------------------


def _fd(f, knobs, key, idx, eps):
    def bump(s):
        k2 = dict(knobs)
        k2[key] = (knobs[key] + s if idx is None
                   else knobs[key].at[idx].add(s))
        return k2
    return (f(bump(eps)) - f(bump(-eps))) / (2.0 * eps)


def test_grad_matches_finite_differences(base):
    """jax.grad through the FULL soft pipeline (analytic + timeline scan)
    matches central differences on the smooth knobs: the buffer fraction
    and all three tier-mix promotion flows (4 knobs >= the required 3)."""
    cols = _grid_cols(certification_grid())
    ts = jnp.asarray(default_ts(), jnp.float32)
    tau = jnp.asarray(1.0, jnp.float32)
    pen = jnp.asarray(200.0, jnp.float32)
    knobs = make_knobs(buffer=0.6, promote=(0.4, 0.3, 0.2), overcommit=1.4,
                       ramp=0.9, evict_lambda=0.2)
    g = jax.grad(soft_loss)(knobs, base, cols, ts, tau, pen)
    f = lambda k: float(soft_loss(k, base, cols, ts, tau, pen))
    for key, idx in (("buffer", None), ("promote", 0), ("promote", 1),
                     ("promote", 2)):
        a = float(g[key]) if idx is None else float(g[key][idx])
        n = _fd(f, knobs, key, idx, eps=0.05)
        assert abs(a - n) <= 0.08 * max(abs(n), abs(a), 1e-3), \
            (key, idx, a, n)


def test_grad_ramp_matches_fd_analytic(base):
    """The burst-ramp knob checked on the analytic (closed-form) stage,
    where the path is smooth — the timeline stage quantizes wave counts
    through ceil(), which finite differences see as steps and autodiff
    correctly treats as flat."""
    cols = _grid_cols(certification_grid())
    tau = jnp.asarray(1.0, jnp.float32)

    def loss(knobs):
        design = knob_design(base, knobs)
        consts = design_consts(design)
        params = _design_params(design, cols)
        out = jax.vmap(lambda q: scenario_outcome(consts["a"], q, tau)
                       )(params)
        return (100.0 * (1.0 - jnp.mean(out["sla_ok"]))
                + 10.0 * (1.0 - jnp.mean(out["rl_ok"])))

    knobs = make_knobs(buffer=0.1, promote=(0.05, 0.05, 0.05),
                       overcommit=1.2, ramp=0.7, evict_lambda=0.3)
    a = float(jax.grad(loss)(knobs)["ramp"])
    n = _fd(lambda k: float(loss(k)), knobs, "ramp", None, eps=0.05)
    assert abs(a) > 1e-4                      # the knob has real signal
    assert abs(a - n) <= 0.08 * max(abs(n), abs(a)), (a, n)


# ---------------------------------------------------------------------------
# soft -> hard agreement
# ---------------------------------------------------------------------------


def test_low_tau_soft_reproduces_hard_verdicts(engine):
    """At tau -> 0 every sigmoid indicator saturates: thresholding the
    soft verdicts at 0.5 must reproduce the bit-exact hard verdicts on
    the full default grid (256 scenarios, brutal corners included)."""
    hard = engine.run()
    soft = engine.run(soft_tau=1e-3)
    for k in hard:
        if hard[k].dtype == bool:
            assert ((soft[k] >= 0.5) == hard[k]).all(), k


def test_soft_runs_leave_hard_path_bit_identical(engine):
    """Interleaving soft runs must not perturb the hard program: the
    hard pipeline and the soft pipeline are separate jit cache entries
    (tau=None vs a traced scalar have different pytree structures)."""
    before = engine.run()
    engine.run(soft_tau=0.5)
    after = engine.run()
    for k in before:
        np.testing.assert_array_equal(before[k], after[k], err_msg=k)


def test_zero_eviction_deltas_are_bitwise_noop(engine):
    """Explicit rl/tm_evict_delta = 0 columns trace the same program
    state as an un-extended grid — additive delta forms are exact."""
    grid = scenario_grid()
    n = len(next(iter(grid.values())))
    plain = engine.run(grid)
    padded = engine.run(dict(grid, rl_evict_delta=np.zeros(n),
                             tm_evict_delta=np.zeros(n)))
    for k in plain:
        np.testing.assert_array_equal(plain[k], padded[k], err_msg=k)


def test_eviction_deltas_conserve_budget(base):
    """The order knob only re-mixes eviction across classes: for any
    lambda and depth, rl*d_rl + tm*d_tm == 0 and both per-class evicted
    fractions stay in [0, 1]."""
    e = jnp.asarray([0.3, 0.7, 1.0], jnp.float32)
    for lam in (-1.0, -0.4, 0.0, 0.5, 1.0):
        design = {"rl": jnp.asarray(1500.0), "tm": jnp.asarray(400.0),
                  "evict_lambda": jnp.asarray(lam)}
        d_rl, d_tm = eviction_deltas(design, e)
        budget = 1500.0 * np.asarray(d_rl) + 400.0 * np.asarray(d_tm)
        np.testing.assert_allclose(budget, 0.0, atol=1e-3)
        assert ((np.asarray(e) + np.asarray(d_rl) >= -1e-6).all()
                and (np.asarray(e) + np.asarray(d_rl) <= 1 + 1e-6).all())
        assert ((np.asarray(e) + np.asarray(d_tm) >= -1e-6).all()
                and (np.asarray(e) + np.asarray(d_tm) <= 1 + 1e-6).all())


# ---------------------------------------------------------------------------
# the optimizer
# ---------------------------------------------------------------------------


def test_optimizer_grad_improves_and_verifies(fs):
    res = optimize_capacity(fs, mode="grad", grad_steps=25,
                            taus=(1.0, 0.1, 0.03))
    assert res.improved
    assert res.provisioning_multiple <= 1.4
    v = res.verification
    assert v["all_ok"], v
    assert v["n_t_avail_ok"] == v["n_scenarios"]


def test_optimizer_cem_improves_and_verifies(fs):
    res = optimize_capacity(fs, mode="cem", cem_generations=5,
                            cem_population=24, seed=3)
    assert res.improved
    v = res.verification
    assert v["n_sla_ok"] == v["n_scenarios"], v
    assert v["n_t_sla_ok"] == v["n_scenarios"], v


def test_hand_tuned_ufa_design_verifies(fs):
    """The paper's hand-tuned operating point passes the certification
    ensemble through the real hard engine — the optimizer's constraint
    set is anchored to a known-feasible design."""
    base = DesignBase.from_fleet_state(fs).as_arrays()
    design = knob_design(base, ufa_knobs())
    assert provisioning(design) < 1.1
    assert verify_design(design)["all_ok"]
    assert provisioning(knob_design(base, legacy_knobs())) > 1.8


def test_hardening_weights_feed_planner(fs):
    from repro.graph import CallGraph, plan_hardening
    fsa = synthesize_fleet(scale=SCALE, seed=SEED, as_arrays=True)
    fsa.apply_ufa_target_classes()
    graph = CallGraph.from_fleet_state(fsa)
    w = hardening_weights(fsa, graph)
    assert w.shape == (fsa.n,) and (w >= 0).all()
    crit = np.asarray(graph.critical, bool)
    np.testing.assert_allclose(w[crit].mean(), 1.0, rtol=1e-3)
    plan = plan_hardening(graph, service_weights=w)
    assert plan.certified


# ---------------------------------------------------------------------------
# sweep-input validation (unknown keys, empty grids)
# ---------------------------------------------------------------------------


def test_unknown_grid_key_raises(engine, fs):
    """A misspelled axis used to be silently dropped — every real axis
    fell back to its default and the sweep returned verdicts for the
    wrong ensemble."""
    bad = {"trafic_mult": np.asarray([1.8, 2.0])}        # sic
    with pytest.raises(ValueError, match="trafic_mult"):
        engine.run(bad)
    agg = FleetAggregates.from_fleet_state(fs)
    with pytest.raises(ValueError, match="unknown scenario grid key"):
        sweep_scenarios(agg, bad)
    with pytest.raises(ValueError, match="trafic_mult"):
        sweep_timeline(config_for_fleet(fs), bad)


def test_empty_grid_raises(engine):
    with pytest.raises(ValueError, match="empty scenario grid"):
        engine.run({})
    with pytest.raises(ValueError, match="empty scenario grid"):
        engine.run({"traffic_mult": np.asarray([])})
    with pytest.raises(ValueError, match="ragged"):
        validate_grid({"traffic_mult": np.ones(3),
                       "evict_fraction": np.ones(2)})


# ---------------------------------------------------------------------------
# recommend_factor: explicit safe flag + exact grid endpoint
# ---------------------------------------------------------------------------


def test_recommend_factor_reports_unsafe():
    """When NO factor clears the violation budget the old code returned
    grid_lo with nothing marking it unsafe — callers acted on a factor
    that failed its own acceptance test."""
    from repro.core.overcommit_sim import OvercommitSimConfig, \
        recommend_factor
    cfg = OvercommitSimConfig(n_hosts=64, n_trials=16, critical_fill=0.95,
                              critical_demand_mean=0.95,
                              preempt_demand_mean=0.95,
                              max_violation_rate=0.0)
    rec = recommend_factor(cfg, grid_lo=1.2, grid_hi=1.6, grid_step=0.1)
    assert rec["safe"] is False
    assert rec["recommended"] == 1.2          # fallback, flagged unsafe
    ok = recommend_factor(OvercommitSimConfig(n_hosts=64, n_trials=16))
    assert ok["safe"] is True


def test_factor_grid_exact_endpoint():
    """np.arange(lo, hi + 1e-9, step) drifts and can drop the endpoint;
    the linspace grid keeps every factor and the endpoint exact."""
    from repro.core.overcommit_sim import factor_grid
    for lo, hi, step in ((1.0, 2.0, 0.05), (1.0, 1.3, 0.1),
                         (1.1, 1.66, 0.07), (1.0, 1.65, 0.05)):
        g = factor_grid(lo, hi, step)
        assert g[0] == lo and g[-1] == hi, (lo, hi, step, g)
        np.testing.assert_allclose(np.diff(g), step, atol=1e-9)


# ---------------------------------------------------------------------------
# planner stall: labeled error instead of a bare assert
# ---------------------------------------------------------------------------


def test_plan_hardening_stall_raises(monkeypatch):
    """Broken criticals with no fail-close frontier (propagation verdicts
    inconsistent with the edge mask) used to trip a bare ``assert`` that
    vanishes under ``python -O``, leaving the loop spinning to
    max_rounds — it must raise a labeled RuntimeError."""
    from repro.graph import planner
    from repro.graph.callgraph import _build_csr
    src = np.array([0], np.int32)
    dst = np.array([1], np.int32)
    g = _build_csr(2, src, dst, np.array([True]),      # edge is fail-OPEN
                   np.ones(1, np.float32),
                   np.array([True, False]),            # 0 critical, live
                   np.array([False, True]),            # 1 preemptible
                   ["crit", "pre"])
    monkeypatch.setattr(
        planner, "fixed_point",
        lambda dark, consts: (jnp.ones_like(dark), jnp.asarray(0)))
    with pytest.raises(RuntimeError, match="no fail-close"):
        planner.plan_hardening(g)
