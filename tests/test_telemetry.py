"""Array-native RPC telemetry engine vs the scalar reference.

Three layers of pinning (mirroring how the fleet engine is pinned to the
scalar orchestrator):

  * exact: scatter-add (segment-sum) ingest must produce bit-identical
    per-edge counts — and identical detections — to the scalar
    dict-per-edge detector on the *same* record stream;
  * statistical: the array sampler draws its own stream (different RNG),
    so ``runtime_analysis`` must match the scalar pipeline's
    precision/recall within a small epsilon on the same fleet;
  * behavioral: cold paths (~100x less traffic) must stay under-observed —
    the runtime layer's misses are exactly the cold-path defects the
    static layer exists to catch.
"""

import numpy as np
import pytest

from repro.core.dependency import (RuntimeFailCloseDetector,
                                   runtime_analysis, sample_traces,
                                   trace_edges)
from repro.core.fleet_state import synthesize_fleet_state
from repro.core.service import synthesize_fleet, unsafe_edges
from repro.graph import CallGraph

from tests.scalar_reference import (ScalarFailCloseDetector,
                                    scalar_generate_traces,
                                    scalar_runtime_analysis)


def _stats_dict(det):
    return {k: (s.calls, s.callee_failures, s.errors_given_failure,
                s.errors_given_ok) for k, s in det.stats.items()}


# ---------------------------------------------------------------------------
# exact parity on an identical record stream
# ---------------------------------------------------------------------------


def test_ingest_exact_parity_on_identical_stream():
    fleet = synthesize_fleet(scale=0.02, seed=5, unsafe_fraction=0.3)
    records, _ = scalar_generate_traces(fleet, 40_000, seed=2)
    scalar = ScalarFailCloseDetector()
    scalar.ingest(records)
    arr = RuntimeFailCloseDetector()
    arr.ingest(records)
    assert _stats_dict(arr) == _stats_dict(scalar)
    assert arr.detect() == scalar.detect()
    assert arr.n_records == len(records)


def test_detect_thresholds_match_scalar():
    """Gate boundaries: not-enough-failures, propagation threshold, lift
    over ambient — the jitted kernel and the scalar loop must agree."""
    from repro.core.dependency import RPCRecord

    recs = []
    # edge A: 4 failures only (below min_failures=5) — never flagged
    for i in range(80):
        recs.append(RPCRecord("a", "low", i % 20 == 0, i % 20 == 0))
    # edge B: plenty of failures, perfect propagation — flagged
    for i in range(200):
        recs.append(RPCRecord("a", "close", i % 10 == 0, i % 10 == 0))
    # edge C: errors uncorrelated with failures (high ambient) — lift gate
    for i in range(200):
        recs.append(RPCRecord("a", "noisy", i % 10 == 0, i % 3 == 0))
    scalar = ScalarFailCloseDetector()
    scalar.ingest(recs)
    arr = RuntimeFailCloseDetector()
    arr.ingest(recs)
    want = scalar.detect()
    assert arr.detect() == want
    assert ("a", "close") in want
    assert ("a", "low") not in want and ("a", "noisy") not in want


def test_ingest_batch_streaming_matches_one_shot():
    """Evidence accumulated chunk-by-chunk == one-shot ingest of the full
    stream (the streaming property runtime_analysis relies on)."""
    fs = synthesize_fleet_state(scale=0.05, seed=3)
    edges = trace_edges(fs, seed=0)
    eid, failed, errored = sample_traces(edges, 90_000, seed=4)
    one = RuntimeFailCloseDetector(edges=edges)
    one.ingest_batch(eid, failed, errored)
    chunked = RuntimeFailCloseDetector(edges=edges)
    for lo in range(0, len(eid), 17_001):
        sl = slice(lo, lo + 17_001)
        chunked.ingest_batch(eid[sl], failed[sl], errored[sl])
    for attr in ("calls", "callee_failures", "errors_given_failure",
                 "errors_given_ok"):
        assert (getattr(one, attr) == getattr(chunked, attr)).all(), attr
    assert one.detect() == chunked.detect()


# ---------------------------------------------------------------------------
# statistical parity: each pipeline samples its own stream
# ---------------------------------------------------------------------------


def test_runtime_analysis_matches_scalar_statistics():
    fleet = synthesize_fleet(scale=0.05, seed=11, unsafe_fraction=0.2)
    truth = set(unsafe_edges(fleet))
    assert len(truth) >= 15            # enough edges for stable recall
    ra = runtime_analysis(fleet, seed=11)
    sc = scalar_runtime_analysis(fleet, seed=11)
    assert ra["truth"] == sc["truth"] == truth
    # no false positives on either path (lift gate vs 0.003 ambient)
    assert ra["false_positives"] == 0
    assert sc["false_positives"] == 0
    assert abs(ra["recall"] - sc["recall"]) <= 0.2
    # the misses are the under-observed cold paths on both pipelines
    assert ra["missed"] == ra["missed_cold"]
    assert sc["missed"] == sc["missed_cold"]


def test_cold_paths_underobserved_and_are_the_misses():
    """Cold unsafe edges carry ~100x less traffic, so they lack failure
    evidence — the static layer's reason to exist (paper §6)."""
    fleet = synthesize_fleet(scale=0.05, seed=11, unsafe_fraction=0.2)
    ra = runtime_analysis(fleet, seed=11)
    det = ra["detector"]
    edges = trace_edges(fleet, seed=11)
    cold, unsafe = edges.cold, edges.unsafe
    hot_unsafe = unsafe & ~cold
    if cold.any() and hot_unsafe.any():
        cold_mean = det.calls[cold].mean()
        hot_mean = det.calls[hot_unsafe].mean()
        assert cold_mean < 0.05 * hot_mean
    # every missed edge is cold, and every miss lacked failure evidence
    missed = ra["truth"] - ra["found"]
    assert missed <= ra["cold_paths"]
    name_to_id = {k: i for i, k in enumerate(edges.edge_names)}
    for e in missed:
        assert det.callee_failures[name_to_id[e]] < det.min_failures
    # hot unsafe edges with evidence are all found
    for i in np.flatnonzero(hot_unsafe):
        if det.callee_failures[i] >= det.min_failures:
            assert edges.edge_names[i] in ra["found"]


# ---------------------------------------------------------------------------
# FleetState (array) path end to end
# ---------------------------------------------------------------------------


def test_runtime_analysis_on_fleet_state_builds_detection_graph():
    fs = synthesize_fleet_state(scale=0.05, seed=9, unsafe_fraction=0.2)
    # small chunks force the multi-chunk streaming path
    ra = runtime_analysis(fs, n_records=350_000, seed=9,
                          chunk_records=100_000)
    assert isinstance(ra["graph"], CallGraph)
    # the detections ARE the graph the downstream layers consume
    assert ra["graph"].unsafe_edge_keys() == ra["found"]
    assert ra["false_positives"] == 0
    assert ra["recall"] >= 0.5
    truth_from_fs = {(fs.names[s], fs.names[d])
                     for s, d, fo in zip(fs.edges.src, fs.edges.dst,
                                         fs.edges.fail_open) if not fo}
    assert ra["truth"] == truth_from_fs


def test_detection_mask_graph_matches_name_set_builder():
    """from_detection_mask (array path) == from_detections (name-set path)
    on the same detections."""
    fs = synthesize_fleet_state(scale=0.05, seed=9, unsafe_fraction=0.2)
    edges = trace_edges(fs, seed=9)
    mask = edges.unsafe.copy()           # "perfect detector"
    g_mask = CallGraph.from_detection_mask(fs, mask)
    g_set = CallGraph.from_detections(fs, edges.unsafe_keys())
    assert g_mask.unsafe_edge_keys() == g_set.unsafe_edge_keys()
    assert (g_mask.src == g_set.src).all()
    assert (g_mask.fail_open == g_set.fail_open).all()


def test_generate_traces_compat_roundtrip():
    """The record-object compat layer and the array path describe the same
    stream: re-ingesting materialized records reproduces the array
    counts."""
    fleet = synthesize_fleet(scale=0.02, seed=5, unsafe_fraction=0.3)
    from repro.core.dependency import generate_traces
    records, cold = generate_traces(fleet, 30_000, seed=3)
    assert len(records) == 30_000
    edges = trace_edges(fleet, seed=3)
    assert cold == edges.cold_keys()
    det_rec = RuntimeFailCloseDetector()
    det_rec.ingest(records)
    det_arr = RuntimeFailCloseDetector(edges=edges)
    det_arr.ingest_batch(*sample_traces(edges, 30_000, seed=3))
    want = {k: v for k, v in _stats_dict(det_arr).items()}
    assert _stats_dict(det_rec) == want
