"""QoS-sweep edge cases (eviction.py) and multi-hop drill paths
(drills.py) the main suites don't touch."""

import numpy as np
import pytest

from repro.core.drills import (certify_fleet_state,
                               dependency_safety_certification, remediate)
from repro.core.eviction import (Host, HostArrays, HostPod, QoSController,
                                 make_host_arrays)
from repro.core.fleet_state import FleetState
from repro.core.service import ServiceSpec
from repro.core.tiers import (QOS_COOL_UTILIZATION, QOS_EVICT_UTILIZATION,
                              FailureClass, Tier)


# ---------------------------------------------------------------------------
# QoS controller edge cases
# ---------------------------------------------------------------------------


def _host_arrays(pod_cores, pod_util, pod_pre, n_hosts=1, cores=100.0,
                 pod_host=None):
    n = len(pod_cores)
    return HostArrays(
        host_cores=np.full(n_hosts, cores),
        pod_host=(np.zeros(n, np.int32) if pod_host is None
                  else np.asarray(pod_host, np.int32)),
        pod_cores=np.asarray(pod_cores, float),
        pod_util=np.asarray(pod_util, float),
        pod_pre=np.asarray(pod_pre, bool),
        alive=np.ones(n, bool))


def test_qos_sweep_zero_preemptible_pods_evicts_nothing():
    """An all-critical hot host has no eviction candidates: the sweep
    must not touch it (zero Restore-Later/Terminate pods)."""
    ha = _host_arrays(pod_cores=[40, 40, 20], pod_util=[0.9, 0.9, 0.8],
                      pod_pre=[False, False, False])
    assert ha.utilization()[0] > QOS_EVICT_UTILIZATION
    ctl = QoSController(ha)
    assert ctl.sweep(now=0.0) == 0
    assert ha.alive.all()
    assert ctl.evictions == []


def test_qos_sweep_single_host_cools_below_target():
    """HostArrays with one host: the busiest preemptible pods go first
    and eviction stops once the host cools below the 70% target."""
    ha = _host_arrays(pod_cores=[30, 20, 20, 20, 10],
                      pod_util=[1.0, 1.0, 1.0, 0.9, 0.5],
                      pod_pre=[False, True, True, True, True])
    before = ha.utilization()[0]
    assert before > QOS_EVICT_UTILIZATION
    ctl = QoSController(ha)
    n = ctl.sweep(now=1.0)
    assert n > 0
    after = ha.utilization()[0]
    assert after <= QOS_COOL_UTILIZATION + 1e-9
    # critical pod untouched; evicted pods are the busiest preemptibles
    assert ha.alive[0]
    dead = np.flatnonzero(~ha.alive)
    busy = ha.pod_cores * ha.pod_util
    alive_pre = ha.alive & ha.pod_pre
    if alive_pre.any() and len(dead):
        assert busy[dead].min() >= busy[alive_pre].max() - 1e-9
    # a second sweep on the cooled host is a no-op
    assert ctl.sweep(now=2.0) == 0


def test_qos_sweep_cool_host_untouched_and_empty_population():
    ha = _host_arrays(pod_cores=[20, 10], pod_util=[0.5, 0.4],
                      pod_pre=[True, True])
    assert ha.utilization()[0] < QOS_EVICT_UTILIZATION
    assert QoSController(ha).sweep(now=0.0) == 0
    # empty Host-list population
    assert QoSController([]).sweep(now=0.0) == 0
    # Host-list population where every host is cool
    hosts = [Host(hid=0, pods=[HostPod("a", 10.0, True, 0.3)])]
    assert QoSController(hosts).sweep(now=0.0) == 0


def test_qos_sweep_object_and_array_paths_agree():
    """The Host-list path and the HostArrays path select the same number
    of victims on an identical two-host population (one hot, one cool)."""
    pods = [  # (host, cores, util, preemptible)
        (0, 30.0, 1.0, False), (0, 25.0, 1.0, True), (0, 20.0, 1.0, True),
        (0, 15.0, 0.8, True), (1, 20.0, 0.5, True), (1, 10.0, 0.4, False)]
    hosts = [Host(hid=0), Host(hid=1)]
    for i, (h, c, u, p) in enumerate(pods):
        hosts[h].pods.append(HostPod(f"p{i}", c, p, u))
    ha = _host_arrays(pod_cores=[c for _, c, _, _ in pods],
                      pod_util=[u for _, _, u, _ in pods],
                      pod_pre=[p for _, _, _, p in pods],
                      n_hosts=2, pod_host=[h for h, _, _, _ in pods])
    n_obj = QoSController(hosts).sweep(now=0.0)
    n_arr = QoSController(ha).sweep(now=0.0)
    assert n_obj == n_arr > 0
    assert sum(len(h.pods) for h in hosts) == int(ha.alive.sum())


def test_make_host_arrays_one_host():
    ha = make_host_arrays(n_hosts=1, seed=3)
    assert ha.n_hosts == 1
    assert (ha.pod_host == 0).all()
    assert ha.n_pods > 0
    QoSController(ha).sweep(now=0.0)      # must not raise on 1-host shape


# ---------------------------------------------------------------------------
# drills.py multi-hop paths
# ---------------------------------------------------------------------------


def _chain_fleet():
    """a(T1,AO) -closed-> b(T2,AM) -closed-> c(T3,RL): `a` has NO direct
    preemptible dependency — it can only break through the relay chain.
    `d` is a critical caller with a fail-open dep (stays certified)."""
    c = ServiceSpec("c", Tier.T3, FailureClass.RESTORE_LATER, 1.0, 4)
    b = ServiceSpec("b", Tier.T2, FailureClass.ACTIVE_MIGRATE, 1.0, 4,
                    deps=["c"], fail_open={"c": False})
    a = ServiceSpec("a", Tier.T1, FailureClass.ALWAYS_ON, 1.0, 4,
                    deps=["b"], fail_open={"b": False})
    d = ServiceSpec("d", Tier.T1, FailureClass.ALWAYS_ON, 1.0, 4,
                    deps=["c"], fail_open={"c": True})
    return {"a": a, "b": b, "c": c, "d": d}


def test_blackhole_drill_flags_multi_hop_chain():
    fleet = _chain_fleet()
    res = dependency_safety_certification(fleet, seed=0)
    assert not res["b"].certified          # direct unsafe dep on dark c
    assert not res["a"].certified          # multi-hop: only via b
    assert res["d"].certified              # fail-open degrades gracefully
    # a's failing dep is the *critical* relay b, not a preemptible
    assert res["a"].failing_deps == ["b"]
    assert res["b"].failing_deps == ["c"]


def test_certify_fleet_state_counts_multi_hop():
    fs = FleetState.from_specs(_chain_fleet(), with_edges=True)
    cert = certify_fleet_state(fs, seed=0)
    assert cert["n_critical"] == 3                  # a, b, d
    assert cert["n_flagged"] == 2                   # a and b
    assert cert["n_multi_hop"] == 1                 # a: relay-only breakage
    assert cert["propagation_rounds"] >= 2          # two hops to fixpoint
    assert cert["unsafe_edges"] == 1                # only b->c is inverted


def test_remediating_relay_edge_certifies_transitively():
    """Hardening the single critical->preemptible edge (b->c) un-breaks
    the whole chain — a recovers without touching a->b."""
    fleet = _chain_fleet()
    n = remediate(fleet, {("b", "c")})
    assert n == 1
    res = dependency_safety_certification(fleet, seed=0)
    assert all(r.certified for r in res.values())
    fs = FleetState.from_specs(fleet, with_edges=True)
    cert = certify_fleet_state(fs, seed=0)
    assert cert["n_flagged"] == 0 and cert["n_multi_hop"] == 0


def test_certify_fleet_state_requires_edges():
    fs = FleetState.from_specs(_chain_fleet(), with_edges=False)
    with pytest.raises(AssertionError):
        certify_fleet_state(fs)


def test_drill_all_critical_fleet_trivially_certifies():
    """Zero Restore-Later services: nothing can go dark, every critical
    service certifies."""
    fleet = {
        "x": ServiceSpec("x", Tier.T0, FailureClass.ALWAYS_ON, 1.0, 4,
                         deps=["y"], fail_open={"y": False}),
        "y": ServiceSpec("y", Tier.T2, FailureClass.ACTIVE_MIGRATE, 1.0, 4),
    }
    res = dependency_safety_certification(fleet, seed=0)
    assert all(r.certified for r in res.values())
    cert = certify_fleet_state(FleetState.from_specs(fleet, with_edges=True))
    assert cert["n_flagged"] == 0
    assert cert["unsafe_edges"] == 0      # x->y is critical->critical
