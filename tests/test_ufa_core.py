"""UFA control-plane unit + property tests: tiers, capacity, overcommit,
traffic, eviction, dependency analysis, canary."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import tiers as T
from repro.core.capacity import (BatchCluster, CloudPool, Cluster, PoolState,
                                 RegionCapacity, safe_overcommit_bound)
from repro.core.canary import CanaryRegressionGate, Deployment
from repro.core.dependency import (RuntimeFailCloseDetector, generate_traces,
                                   runtime_analysis)
from repro.core.eviction import (Host, HostPod, QoSController,
                                 failover_eviction_trace,
                                 make_host_population)
from repro.core.service import synthesize_fleet, fleet_cores, unsafe_edges
from repro.core.static_analysis import static_analysis
from repro.core.traffic import (FailoverModeDetector, diurnal_traffic,
                                is_full_failover, make_cities, weekly_peak)

SETTINGS = dict(deadline=None, max_examples=25)


def test_o_max_paper_constants():
    assert abs(T.o_max() - 5.0 / 3.0) < 1e-9   # (8/4)*(0.75/0.9) = 1.666


@given(m_h=st.floats(1, 32), m_s=st.floats(1, 32),
       am=st.floats(0.1, 1.0), ac=st.floats(0.1, 1.0))
@settings(**SETTINGS)
def test_o_max_monotonic(m_h, m_s, am, ac):
    base = T.o_max(m_h, m_s, am, ac)
    assert T.o_max(m_h * 2, m_s, am, ac) == pytest.approx(base * 2)
    assert T.o_max(m_h, m_s * 2, am, ac) == pytest.approx(base / 2)
    assert base > 0


def test_tier_class_defaults():
    assert T.DEFAULT_CLASS_OF_TIER[T.Tier.T0] == T.FailureClass.ALWAYS_ON
    assert T.DEFAULT_CLASS_OF_TIER[T.Tier.T2] == T.FailureClass.ACTIVE_MIGRATE
    assert T.DEFAULT_CLASS_OF_TIER[T.Tier.NP] == T.FailureClass.TERMINATE
    for fc in T.FailureClass:
        assert fc.preemptible != fc.survives_failover
    assert sum(T.BASELINE_CORES.values()) == pytest.approx(4.18e6, rel=0.01)


@given(cap=st.floats(1, 1e6), reqs=st.lists(st.floats(0.1, 1e4), max_size=20))
@settings(**SETTINGS)
def test_pool_invariants(cap, reqs):
    pool = PoolState(capacity=cap)
    granted = []
    for r in reqs:
        if pool.alloc(r):
            granted.append(r)
        assert -1e-6 <= pool.used <= pool.capacity + 1e-6
    for r in granted:
        pool.release(r)
    assert pool.used == pytest.approx(0.0, abs=1e-6)


def test_cluster_pools():
    c = Cluster("x", n_hosts=10, cores_per_host=100, overcommit_factor=1.5)
    assert c.physical_cores == 1000
    assert c.overcommit.capacity == pytest.approx(500)
    assert c.advertised_cores == pytest.approx(1500)


def test_fleet_matches_tables():
    fleet = synthesize_fleet(scale=0.05, seed=0)
    cores = fleet_cores(fleet)
    for tier, c in cores.items():
        target = T.BASELINE_CORES[tier] * 0.05 * 0.25
        assert abs(c - target) / max(1, target) < 0.35, tier
    # unsafe edges only exist on tier-inverted (critical->preemptible) edges
    for caller, callee in unsafe_edges(fleet):
        assert fleet[caller].failure_class.survives_failover
        assert fleet[callee].failure_class.preemptible


def test_mode_detector():
    det = FailoverModeDetector()
    det.recompute_threshold()
    peak = det.tv_peak
    assert det.mode(0.86 * peak) == "peak"
    assert det.mode(0.84 * peak) == "non-peak"
    assert is_full_failover(51, 100) and not is_full_failover(50, 100)


def test_traffic_diurnal():
    pk = weekly_peak()
    assert 0 < diurnal_traffic(3600) <= pk * 1.01
    cities = make_cities(10)
    assert abs(sum(c.weight for c in cities) - 1.0) < 1e-9


def test_qos_controller_cools_hosts():
    hosts = make_host_population(20, seed=1, critical_fill=0.5,
                                 preempt_fill=0.4)
    for h in hosts:
        for p in h.pods:
            p.utilization = 0.9
    qos = QoSController(hosts)
    n = qos.sweep(now=0.0)
    assert n > 0
    for h in hosts:
        # hosts with preemptible pods left must be cooled or out of victims
        if any(p.preemptible for p in h.pods):
            assert h.utilization() <= 0.75 + 1e-9 or True
    # critical pods never evicted
    for (_, _, svc) in qos.evictions:
        assert svc.startswith("pre-")


def test_eviction_trace_shape():
    t = failover_eviction_trace(n_hosts=40_000, hours=12, failover_hour=6,
                                seed=7)
    assert t["peak"] == t["per_hour"][6]          # spike at failover hour
    assert 1.5 <= t["peak_over_baseline"] <= 3.0  # paper: ~2x
    assert t["per_hour"][0] < t["baseline_peak"]  # off-peak is quiet


def test_runtime_detector_lift_logic():
    det = RuntimeFailCloseDetector(min_failures=3)
    from repro.core.dependency import RPCRecord
    recs = []
    for i in range(200):
        fail = i % 10 == 0
        recs.append(RPCRecord("a", "b", fail, fail))          # fail-close
        recs.append(RPCRecord("a", "c", fail, False))         # fail-open
    det.ingest(recs)
    found = det.detect()
    assert ("a", "b") in found and ("a", "c") not in found


def test_dependency_pipeline_end_to_end():
    fleet = synthesize_fleet(scale=0.05, seed=3)
    truth = set(unsafe_edges(fleet))
    ra = runtime_analysis(fleet, seed=1)
    sa = static_analysis(fleet, seed=2)
    assert ra["false_positives"] == 0
    assert sa["precision"] == 1.0 and sa["recall"] == 1.0
    combined = (ra["found"] | sa["found"]) & truth
    assert len(combined) == len(truth)            # layers are complementary


def test_canary_gate_blocks_failclose_dep():
    fleet = synthesize_fleet(scale=0.05, seed=3)
    from repro.core.drills import remediate
    remediate(fleet, set(unsafe_edges(fleet)))
    gate = CanaryRegressionGate(fleet, seed=0)
    crit = next(n for n, s in fleet.items()
                if s.failure_class.survives_failover)
    pre = next(n for n, s in fleet.items() if s.failure_class.preemptible)
    ok = gate.evaluate(Deployment(crit, new_dep=None))
    bad = gate.evaluate(Deployment(crit, new_dep=(pre, False)))
    assert ok.passed and not bad.passed


def test_cloud_pool_quota():
    cp = CloudPool(quota_cores=100, provision_rate_cores_per_s=10)
    assert cp.provision(80) == 80
    assert cp.provision(50) == 20     # quota-clamped
    cp.release_all()
    assert cp.provisioned == 0


def test_region_for_fleet_sizing():
    fleet = synthesize_fleet(scale=0.05, seed=0)
    ufa = RegionCapacity.for_fleet("r", fleet, model="ufa")
    legacy = RegionCapacity.for_fleet("r", fleet, model="legacy")
    total = sum(s.cores for s in fleet.values())
    # UFA provisions strictly less steady capacity than legacy 2x
    assert ufa.steady.physical_cores < legacy.steady.physical_cores
    assert legacy.steady.physical_cores >= 2.0 * total
    # and the overcommit pool covers all preemptible demand
    pre = sum(s.cores for s in fleet.values()
              if s.failure_class.preemptible)
    assert ufa.steady.overcommit.capacity >= pre
