"""Elastic resharding + multi-device behavior (subprocess-isolated so the
main test process keeps a single CPU device)."""

import json
import os
import subprocess
import sys
import tempfile
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(n_devices: int, code: str) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_elastic_reshard_save_8_load_2():
    """Save a sharded train state on 8 devices, resume on 2 (UFA restore
    path: a preempted job revives on whatever capacity burst offers)."""
    with tempfile.TemporaryDirectory() as d:
        save_code = textwrap.dedent(f"""
            import jax, jax.numpy as jnp
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.models import LMConfig
            from repro.train import make_train_state, make_train_step
            from repro.checkpoint import save_checkpoint
            from repro.data import SyntheticLMDataset
            cfg = LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                           n_kv_heads=2, d_head=16, d_ff=128, vocab_size=128,
                           tie_embeddings=True)
            assert len(jax.devices()) == 8
            mesh = jax.make_mesh((4, 2), ("data", "model"))
            from repro.dist.sharding import param_shardings
            ps = param_shardings(cfg, mesh)
            step, opt = make_train_step(cfg, n_loss_chunks=2)
            state = make_train_state(cfg, jax.random.PRNGKey(0), opt)
            state = state._replace(params=jax.device_put(state.params, ps))
            ds = SyntheticLMDataset(vocab_size=128, seq_len=16,
                                    global_batch=8, seed=1)
            jstep = jax.jit(step)
            for i in range(3):
                state, m = jstep(state, {{k: jnp.asarray(v)
                                          for k, v in ds.batch(i).items()}})
            save_checkpoint({d!r}, 3, state)
            print("LOSS", float(m["loss"]))
        """)
        out1 = _run(8, save_code)
        loss_8 = float(out1.split("LOSS")[1].strip())

        load_code = textwrap.dedent(f"""
            import jax, jax.numpy as jnp
            from repro.models import LMConfig
            from repro.train import make_train_state, make_train_step
            from repro.checkpoint import load_checkpoint
            from repro.data import SyntheticLMDataset
            from repro.dist.sharding import param_shardings
            cfg = LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                           n_kv_heads=2, d_head=16, d_ff=128, vocab_size=128,
                           tie_embeddings=True)
            assert len(jax.devices()) == 2
            mesh = jax.make_mesh((2, 1), ("data", "model"))
            ps = param_shardings(cfg, mesh)
            step, opt = make_train_step(cfg, n_loss_chunks=2)
            like = make_train_state(cfg, jax.random.PRNGKey(9), opt)
            state, _ = load_checkpoint({d!r}, like)
            state = state._replace(params=jax.device_put(state.params, ps))
            ds = SyntheticLMDataset(vocab_size=128, seq_len=16,
                                    global_batch=8, seed=1)
            jstep = jax.jit(step)
            state, m = jstep(state, {{k: jnp.asarray(v)
                                      for k, v in ds.batch(3).items()}})
            print("LOSS", float(m["loss"]))
        """)
        out2 = _run(2, load_code)
        loss_2 = float(out2.split("LOSS")[1].strip())
        # resumed step-4 loss on a different mesh must be close to the
        # step-3 loss trajectory (same data, same params)
        assert abs(loss_2 - loss_8) < 0.5


def test_splitkv_decode_multidevice_matches_single():
    """Split-KV shard_map decode on a 1x4 mesh == single-device decode."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.dist.ctx import sharding_rules
        from repro.dist import sharding as shd
        from repro.models import (LMConfig, init_params, init_decode_state,
                                  decode_step)
        cfg = LMConfig(name="t", n_layers=2, d_model=64, n_heads=4,
                       n_kv_heads=2, d_head=16, d_ff=128, vocab_size=128)
        p = init_params(cfg, jax.random.PRNGKey(0))
        toks = jax.random.randint(jax.random.PRNGKey(1), (2, 6), 0, 128)
        # single-device reference
        st = init_decode_state(cfg, 2, 16, jnp.float32)
        ref = []
        for t in range(6):
            lg, st = decode_step(p, cfg, st, toks[:, t])
            ref.append(lg)
        # sharded: seq dim of the cache over 4-way "model" axis
        mesh = jax.make_mesh((1, 4), ("data", "model"))
        st2 = init_decode_state(cfg, 2, 16, jnp.float32)
        st_shd = shd.decode_state_shardings(cfg, mesh, 2)
        st2 = jax.device_put(st2, st_shd)
        def step(st, tok):
            with sharding_rules(mesh):
                return decode_step(p, cfg, st, tok)
        jstep = jax.jit(step, donate_argnums=(0,))
        with mesh:
            got = []
            for t in range(6):
                lg, st2 = jstep(st2, toks[:, t])
                got.append(lg)
        err = max(float(jnp.abs(a - b).max()) for a, b in zip(ref, got))
        print("ERR", err)
        assert err < 5e-3, err
    """)
    out = _run(4, code)
    assert "ERR" in out


def test_compressed_psum_matches_fp32_mean():
    """int8-compressed gradient psum ~= exact mean across 4 devices."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.dist.smap import shard_map
        from repro.optim.compression import compressed_psum_grads
        mesh = jax.make_mesh((4,), ("data",))
        g = jax.random.normal(jax.random.PRNGKey(0), (4, 256))
        def f(g_local):
            key = jax.random.PRNGKey(jax.lax.axis_index("data"))
            return compressed_psum_grads({"g": g_local[0]}, "data", key)["g"]
        out = shard_map(f, mesh=mesh, in_specs=P("data", None),
                        out_specs=P())(g)
        want = g.mean(axis=0)
        err = float(jnp.abs(out - want).max())
        rel = err / float(jnp.abs(want).max())
        print("REL", rel)
        assert rel < 0.05, rel
    """)
    _run(4, code)


def test_production_mesh_shapes():
    code = textwrap.dedent("""
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert m1.axis_names == ("data", "model") and m1.devices.size == 256
        m2 = make_production_mesh(multi_pod=True)
        assert m2.axis_names == ("pod", "data", "model")
        assert m2.devices.size == 512
        print("OK")
    """)
    _run(512, code)
