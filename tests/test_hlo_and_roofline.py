"""HLO collective parser + roofline math unit tests."""

import pytest

from repro.launch.hlo_analysis import parse_collectives, collective_summary


HLO = """
HloModule jit_f
  %all-gather = f32[256,128]{1,0} all-gather(%param.1), channel_id=1, replica_groups=[4,2]<=[2,4]T(1,0), dimensions={0}, use_global_device_ids=true
  %dot = f32[8,128]{1,0} dot(%param, %all-gather), lhs_contracting_dims={1}
  %all-reduce = f32[64]{0} all-reduce(%wrapped), channel_id=2, replica_groups=[2,4]<=[8], to_apply=%region_0.0
  ROOT %all-reduce.1 = f32[] all-reduce(%all-reduce), channel_id=3, replica_groups=[4,2]<=[2,4]T(1,0), to_apply=%region_1
  %reduce-scatter = bf16[16,8]{1,0} reduce-scatter(%x), replica_groups=[2,4]<=[8], dimensions={0}
  %collective-permute-start = (f32[4], f32[4]) collective-permute-start(%y), source_target_pairs={{0,1}}
  %cp2 = f32[4] collective-permute-done(%collective-permute-start)
  %a2a = (f32[2,4]{1,0}, f32[2,4]{1,0}) all-to-all(%p, %q), replica_groups={{0,1},{2,3}}
"""


def test_parse_collectives_kinds_and_counts():
    stats = parse_collectives(HLO)
    assert stats["all-gather"]["count"] == 1
    assert stats["all-reduce"]["count"] == 2
    assert stats["reduce-scatter"]["count"] == 1
    assert stats["collective-permute"]["count"] == 1   # -done skipped
    assert stats["all-to-all"]["count"] == 1


def test_parse_collectives_bytes():
    stats = parse_collectives(HLO)
    # all-gather result 256*128*4 bytes, group=2 -> operand = result/2
    assert stats["all-gather"]["bytes"] == 256 * 128 * 4 / 2
    # ring wire = (g-1)/g * result
    assert stats["all-gather"]["wire_bytes"] == pytest.approx(
        256 * 128 * 4 * 0.5)
    # all-reduce payload 64*4 + scalar 4; wire 2*(g-1)/g
    assert stats["all-reduce"]["bytes"] == 64 * 4 + 4
    # reduce-scatter result bf16 16*8*2, group 4 -> operand x4
    assert stats["reduce-scatter"]["bytes"] == 16 * 8 * 2 * 4
    # all-to-all: tuple result summed, explicit groups of 2
    assert stats["all-to-all"]["bytes"] == 2 * (2 * 4 * 4)


def test_parser_ignores_non_collective_lines():
    stats = parse_collectives("%dot = f32[8] dot(%a, %b)\n")
    assert stats == {}


def test_roofline_model_flops():
    from repro.configs import get_arch
    from repro.launch.roofline import decode_ideal_bytes, model_flops
    arch = get_arch("llama3.2-3b")
    n = arch.config.param_count()
    assert model_flops(arch, "train_4k") == pytest.approx(
        6.0 * n * 256 * 4096)
    assert model_flops(arch, "decode_32k") == pytest.approx(2.0 * n * 128)
    ib = decode_ideal_bytes(arch, "decode_32k")
    assert ib > 2.0 * n                       # params + cache
    # windowed arch touches less cache than a full-attention one of same size
    gemma = get_arch("gemma3-4b")
    full_equiv = (2 * 128 * 32768 * gemma.config.n_kv_heads
                  * gemma.config.d_head * 2.0 * gemma.config.n_layers)
    windowed = decode_ideal_bytes(gemma, "decode_32k") \
        - 2.0 * gemma.config.param_count()
    assert windowed < full_equiv * 0.4        # 5/6 layers are window-bounded


def test_dryrun_artifacts_if_present():
    """If the sweep has run, every artifact must be ok or a documented skip."""
    import json
    from pathlib import Path
    art = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"
    if not art.exists():
        pytest.skip("dry-run artifacts not generated yet")
    files = [p for p in art.glob("*.json") if "variant" not in p.name]
    assert files
    for p in files:
        r = json.loads(p.read_text())
        assert r["status"] in ("ok", "skipped"), (p.name, r.get("error"))
        if r["status"] == "skipped":
            assert "long_500k" in p.name
