"""Temporal failover-timeline kernel: scalar-reference equivalence,
Orchestrator-snapshot equivalence, hypothesis invariants, the
``Timeline`` alignment regression, and the temporal-sweep API."""

import math
import time

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.capacity import (BatchCluster, CloudPool, Cluster,
                                 RegionCapacity)
from repro.core.omg import Orchestrator, Timeline
from repro.core.scenarios import (FleetAggregates, operating_point_mask,
                                  scenario_grid, summarize_sweep,
                                  sweep_scenarios,
                                  sweep_with_dependency_ensemble)
from repro.core.service import ServiceSpec, synthesize_fleet
from repro.core.tiers import FailureClass, Tier
from repro.core.timeline_sim import (EPS_T, TimelineConfig, config_for_fleet,
                                     default_scenario, default_ts,
                                     simulate_timeline,
                                     summarize_timeline_sweep,
                                     sweep_timeline)

from scalar_reference import scalar_timeline

# series compared against the orchestrator's Timeline snapshots
ORCH_KEYS = ("steady_used", "overcommit_used", "burst_capacity",
             "burst_used", "cloud_used", "utilization", "am_steady",
             "am_bursted", "rl_t_steady", "terminated", "rl_bursted",
             "rl_not_bursted")
COUNT_KEYS = ("am_steady", "am_bursted", "rl_bursted", "rl_not_bursted",
              "rl_t_steady", "terminated")
BOOL_KEYS = ("ao_ok", "rl_rto_met", "preempt_fit", "dep_ok", "avail_ok",
             "util_ok", "sla_ok")


def _mix_fleet(n_ao=3, n_am=2, n_rl=4, n_tm=2):
    """Small explicit fleet; AO sized so the UFA region always fits the
    preemptible classes in its overcommit pool."""
    fleet = {}

    def add(pfx, n, tier, fc, cores):
        for i in range(n):
            name = f"{pfx}-{i}"
            fleet[name] = ServiceSpec(name, tier, fc, 1.0,
                                      int(cores * (i + 1)))
    add("ao", n_ao, Tier.T0, FailureClass.ALWAYS_ON, 40)
    add("am", n_am, Tier.T2, FailureClass.ACTIVE_MIGRATE, 20)
    add("rl", n_rl, Tier.T3, FailureClass.RESTORE_LATER, 6)
    add("tm", n_tm, Tier.NP, FailureClass.TERMINATE, 4)
    return fleet


def _dedup_last(tl):
    """Orchestrator snapshot arrays, keeping the LAST snapshot at each
    distinct time (intermediate same-time snaps capture half-applied
    state the time-indexed kernel cannot represent)."""
    t = tl["t"]
    keep = np.ones(len(t), bool)
    keep[:-1] = t[:-1] != t[1:]
    return {k: v[keep] for k, v in tl.items()}


# ---------------------------------------------------------------------------
# Equivalence 1: the scan kernel matches the scalar reference stepper
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("params", [
    {},                                             # paper operating point
    {"traffic_mult": 1.6, "evict_fraction": 0.75},
    {"burst_availability": 0.5, "cloud_quota_frac": 1.0},
    {"cloud_quota_frac": 0.0},                      # RL never restores
    {"burst_delay_s": 600.0, "dep_broken_frac": 0.1},
])
def test_kernel_matches_scalar_reference(params):
    fleet = synthesize_fleet(scale=0.02, seed=1)
    cfg = config_for_fleet(fleet)
    ts = default_ts(7200.0, 240)
    got = simulate_timeline(cfg, params=params, ts=ts)
    want = scalar_timeline(cfg, params=params, ts=ts)
    for key, vals in want.items():
        if key == "t":
            continue
        w = np.asarray(vals, np.float64)
        g = np.asarray(got[key], np.float64)
        if key in COUNT_KEYS or key in BOOL_KEYS:
            assert np.array_equal(g, w), key       # counts/verdicts exact
        else:
            # float32 kernel vs float64 stepper: ulp-level agreement only
            np.testing.assert_allclose(g, w, rtol=2e-5, atol=2e-2,
                                       err_msg=key)


def test_kernel_matches_scalar_reference_across_mixes():
    ts = default_ts(7200.0, 200)
    for kw in (dict(n_am=0), dict(n_rl=0, n_tm=0), dict(n_am=0, n_rl=0,
                                                        n_tm=0), dict()):
        cfg = config_for_fleet(_mix_fleet(**kw))
        got = simulate_timeline(cfg, ts=ts)
        want = scalar_timeline(cfg, ts=ts)
        for key in ("rl_live", "tier_live", "availability", "burst_used",
                    "rl_done_s", "time_to_restore_s"):
            np.testing.assert_allclose(
                np.asarray(got[key], np.float64),
                np.asarray(want[key], np.float64),
                rtol=2e-5, atol=2e-2, err_msg=str((kw, key)))
        for key in COUNT_KEYS + BOOL_KEYS:
            assert np.array_equal(np.asarray(got[key]),
                                  np.asarray(want[key])), (kw, key)


# ---------------------------------------------------------------------------
# Equivalence 2: the kernel matches Orchestrator.failover() snapshots
# ---------------------------------------------------------------------------


def _compare_with_orchestrator(fleet, region, cores_atol=1e-2,
                               envs_atol=0.0, time_atol=1e-2):
    orch = Orchestrator(fleet, region)
    cfg = orch.timeline_config()           # extract BEFORE the failover
    rep = orch.failover(tv_failover=1.0)
    tl = _dedup_last(orch.timeline.as_arrays())
    res = simulate_timeline(cfg, ts=tl["t"])
    for key in ORCH_KEYS:
        want, got = tl[key], res[key]
        m = np.isfinite(want)
        atol = envs_atol if key in COUNT_KEYS else cores_atol
        if key == "utilization":
            atol = 1e-5
        np.testing.assert_allclose(got[m], want[m], atol=atol, rtol=1e-6,
                                   err_msg=key)
    assert abs(res["burst_full_s"] - rep.burst_full_at_s) <= time_atol
    assert abs(res["am_done_s"] - rep.am_migrated_at_s) <= time_atol
    assert bool(res["ao_ok"]) == rep.always_on_ok
    assert bool(res["rl_rto_met"]) == rep.rl_rto_met
    return rep, res


@pytest.mark.parametrize("mix", [
    dict(),                          # all four classes
    dict(n_am=0),                    # AO + RL/TM, no Active-Migrate
    dict(n_rl=0, n_tm=0, n_am=3),    # AO + AM only
    dict(n_am=0, n_rl=0, n_tm=0),    # Always-On only
])
def test_kernel_matches_orchestrator_small_mixes(mix):
    fleet = _mix_fleet(**mix)
    rep, res = _compare_with_orchestrator(
        fleet, RegionCapacity.for_fleet("r", fleet))
    assert abs(res["rl_done_s"] - rep.rl_restored_at_s) <= 1e-2


def test_kernel_matches_orchestrator_synthesized_fleet():
    """The 0.02-scale Tables-1-3 fleet (same fixture as the seed
    equivalence tests): single migration/restore waves, no cloud spill —
    the regime where the aggregate kernel is exact."""
    fleet = synthesize_fleet(scale=0.02, seed=1)
    rep, res = _compare_with_orchestrator(
        fleet, RegionCapacity.for_fleet("r", fleet))
    assert rep.cloud_cores_used == 0, "fixture must not spill to cloud"
    assert abs(res["rl_done_s"] - rep.rl_restored_at_s) <= 1e-2
    assert res["peak_cloud_cores"] == 0.0


def test_kernel_matches_orchestrator_cloud_spill():
    """Shrunken batch cluster forces Restore-Later into the cloud; the
    kernel must honor the provisioning delay (grant / rate) before the
    cloud batch activates.  First-fit fragmentation makes the aggregate
    split approximate to within one SE."""
    fleet = synthesize_fleet(scale=0.02, seed=1)
    base = RegionCapacity.for_fleet("r", fleet)
    am = sum(s.cores for s in fleet.values()
             if s.failure_class == FailureClass.ACTIVE_MIGRATE)
    rl = sum(s.cores for s in fleet.values()
             if s.failure_class == FailureClass.RESTORE_LATER)
    n_hosts = max(1, int((am + 0.3 * rl) / (120.0 * 0.9)))
    region = RegionCapacity(
        "r", steady=base.steady,
        batch=BatchCluster("r-batch", n_hosts=n_hosts, cores_per_host=120.0),
        cloud=CloudPool(quota_cores=50_000.0,
                        provision_rate_cores_per_s=10.0))
    orch = Orchestrator(fleet, region)
    cfg = orch.timeline_config()
    rep = orch.failover(tv_failover=1.0)
    tl = _dedup_last(orch.timeline.as_arrays())
    res = simulate_timeline(cfg, ts=tl["t"])
    assert rep.cloud_cores_used > 0, "fixture must spill to cloud"
    # largest SE in this fleet is ~30 cores: fragmentation bound
    for key in ("burst_used", "cloud_used"):
        m = np.isfinite(tl[key])
        np.testing.assert_allclose(res[key][m], tl[key][m], atol=35.0,
                                   rtol=1e-6, err_msg=key)
    for key in ("rl_bursted", "rl_not_bursted"):
        m = np.isfinite(tl[key])
        np.testing.assert_allclose(res[key][m], tl[key][m], atol=3.0,
                                   err_msg=key)
    # completion = last wave + provisioning delay; fragmentation shifts the
    # grant by <= one SE -> delay by <= cores/rate
    assert rep.cloud_provision_s > 0
    assert abs(res["rl_done_s"] - rep.rl_restored_at_s) <= 35.0 / 10.0
    assert float(res["cloud_arrival_s"]) >= float(res["burst_full_s"])
    # cloud restores contribute no live cores before the arrival time:
    # restored RL cores up to then fit inside the burst leftover
    before = ((tl["t"] < float(res["cloud_arrival_s"]) - EPS_T)
              & (tl["t"] >= cfg.kill_s))     # post-evict, pre-arrival
    restored = res["rl_live"]                # evict_fraction == 1: all of
    burst_free_rl = max(cfg.burst_cap_full   # rl_live is restored cores
                        - min(cfg.am_cores, cfg.burst_cap_full), 0.0)
    assert (restored[before] <= burst_free_rl + 1e-2).all()


# ---------------------------------------------------------------------------
# Property-based invariants (hypothesis; stubbed deterministically when
# hypothesis is absent — see conftest.py)
# ---------------------------------------------------------------------------


_TS_PROP = default_ts(5400.0, 120)


def _build_cfg(ao, am, rl, tm, batch_hosts, quota, rate):
    fleet = {}
    for pfx, n, tier, fc, cores in (
            ("ao", 2, Tier.T0, FailureClass.ALWAYS_ON, ao),
            ("am", 2, Tier.T2, FailureClass.ACTIVE_MIGRATE, am),
            ("rl", 3, Tier.T3, FailureClass.RESTORE_LATER, rl),
            ("tm", 2, Tier.NP, FailureClass.TERMINATE, tm)):
        for i in range(n):
            if cores <= 0:
                continue
            name = f"{pfx}-{i}"
            fleet[name] = ServiceSpec(name, tier, fc, 0.5,
                                      max(1, int(cores * (i + 1))))
    if not fleet:
        fleet["ao-0"] = ServiceSpec("ao-0", Tier.T0,
                                    FailureClass.ALWAYS_ON, 0.5, 4)
    total_crit = sum(s.cores for s in fleet.values()
                     if s.failure_class.survives_failover)
    region = RegionCapacity(
        "p", steady=Cluster("p-s", n_hosts=max(
            2, math.ceil(2.2 * max(total_crit, 10.0) / 100.0)),
            cores_per_host=100.0, overcommit_factor=1.5),
        batch=BatchCluster("p-b", n_hosts=batch_hosts,
                           cores_per_host=120.0),
        cloud=CloudPool(quota_cores=quota,
                        provision_rate_cores_per_s=rate))
    return config_for_fleet(fleet, region=region)


@given(ao=st.integers(0, 60), am=st.integers(0, 40), rl=st.integers(0, 30),
       tm=st.integers(0, 20), batch_hosts=st.integers(1, 12),
       quota=st.floats(0.0, 2000.0), rate=st.floats(5.0, 200.0),
       mult=st.floats(1.2, 2.4), evict=st.floats(0.0, 1.0),
       avail=st.floats(0.3, 1.0), qfrac=st.floats(0.0, 1.0))
@settings(deadline=None, max_examples=30)
def test_timeline_invariants_property(ao, am, rl, tm, batch_hosts, quota,
                                      rate, mult, evict, avail, qfrac):
    """Over random fleets/regions/scenarios: live cores never negative,
    placed-pool accounting conserves capacity, the RL cloud batch never
    activates before its provisioning delay elapses, availability stays
    in [0, 1]."""
    cfg = _build_cfg(ao, am, rl, tm, batch_hosts, quota, rate)
    params = {"traffic_mult": mult, "evict_fraction": evict,
              "burst_availability": avail, "cloud_quota_frac": qfrac}
    res = simulate_timeline(cfg, params=params, ts=_TS_PROP)

    eps = 1e-2
    for key in ("ao_live", "am_live", "rl_live", "tm_live"):
        assert (res[key] >= -eps).all(), key
    assert (res["tier_live"] >= -eps).all()
    # live cores never exceed spec (+ the Always-On upscale)
    assert (res["ao_live"] <= cfg.ao_cores * mult + eps).all()
    assert (res["rl_live"] <= cfg.rl_cores + eps).all()
    assert (res["tm_live"] <= cfg.tm_cores + eps).all()
    # placed-pool accounting conserves capacity
    assert (res["steady_used"] >= -eps).all()
    assert (res["steady_used"] <= cfg.stateless_cap + eps).all()
    assert (res["overcommit_used"] >= -eps).all()
    assert (res["overcommit_used"] <= cfg.overcommit_cap + eps).all()
    assert (res["burst_used"] <= res["burst_capacity"] + eps).all()
    assert (res["burst_used"] >= -eps).all()
    quota_eff = cfg.cloud_quota * qfrac
    assert (res["cloud_used"] <= quota_eff + eps).all()
    # RL restore via cloud never begins before the provisioning delay
    # elapses: before the aggregated cloud batch arrives, restored RL
    # cores are burst-only (bounded by the burst left over after AM)
    early = ((res["t"] < float(res["cloud_arrival_s"]) - EPS_T)
             & (res["t"] >= cfg.kill_s))    # post-evict, pre-arrival
    restored = res["rl_live"] - cfg.rl_cores * (1.0 - evict)
    burst_cap = cfg.burst_cap_full * avail
    rl_burst_max = min(max(burst_cap - min(cfg.am_cores, burst_cap), 0.0),
                       cfg.rl_cores * evict)
    assert (restored[early] <= rl_burst_max + eps).all()
    if np.isfinite(res["cloud_arrival_s"]) and res["cloud_grant_cores"] > 0:
        # the batch is requested no earlier than the first restore wave
        assert float(res["cloud_arrival_s"]) >= float(
            res["burst_full_s"]) + cfg.rl_wave_s - EPS_T
    # availability trace well-formed
    assert (res["availability"] >= 0.0).all()
    assert (res["availability"] <= 1.0).all()
    assert 0.0 <= float(res["availability_mean"]) <= 1.0
    # verdict consistency
    if np.isfinite(res["rl_done_s"]):
        assert bool(res["rl_rto_met"]) == (
            float(res["rl_done_s"]) <= cfg.rl_rto_s + EPS_T)
    else:
        assert not bool(res["rl_rto_met"])


# ---------------------------------------------------------------------------
# Timeline alignment regression (satellite: ragged mid-run series)
# ---------------------------------------------------------------------------


def test_timeline_mid_run_keys_stay_aligned():
    tl = Timeline()
    tl.snap(0.0, a=1.0)
    tl.snap(1.0, a=2.0, b=10.0)      # b joins mid-run
    tl.snap(2.0, b=20.0)             # a omitted mid-run
    arrs = tl.as_arrays()
    # deterministic order: t first, then sorted keys — and all aligned
    assert list(arrs) == ["t", "a", "b"]
    assert all(len(v) == 3 for v in arrs.values())
    np.testing.assert_allclose(arrs["t"], [0.0, 1.0, 2.0])
    np.testing.assert_allclose(arrs["a"], [1.0, 2.0, np.nan])
    np.testing.assert_allclose(arrs["b"], [np.nan, 10.0, 20.0])
    # at() drops the NaN holes but keeps (t, value) pairing correct
    assert tl.at("b") == [(1.0, 10.0), (2.0, 20.0)]
    assert tl.at("a") == [(0.0, 1.0), (1.0, 2.0)]


def test_orchestrator_timeline_arrays_aligned():
    """burst_online only exists during the conversion ramp — the ragged
    case the fix targets; every array must align with t."""
    fleet = synthesize_fleet(scale=0.02, seed=2)
    orch = Orchestrator(fleet, RegionCapacity.for_fleet("r", fleet))
    orch.failover(tv_failover=1.0)
    arrs = orch.timeline.as_arrays()
    n = len(arrs["t"])
    assert n > 4
    for k, v in arrs.items():
        assert len(v) == n, k
    assert np.isnan(arrs["burst_online"][0])          # pre-conversion snap
    assert np.isfinite(arrs["burst_online"]).any()    # ramp snaps recorded
    assert list(arrs)[0] == "t" and list(arrs)[1:] == sorted(list(arrs)[1:])


# ---------------------------------------------------------------------------
# Temporal sweep API + acceptance
# ---------------------------------------------------------------------------


def test_sweep_timeline_256_scenarios_under_5s():
    """Acceptance: 256-scenario x >= 200-step full-peak ensemble in < 5 s
    on CPU, including compilation."""
    fleet = synthesize_fleet(scale=0.05, seed=7)
    cfg = config_for_fleet(fleet)
    ts = default_ts(7200.0, 240)
    t0 = time.time()
    res = sweep_timeline(cfg, grid=scenario_grid(), ts=ts)
    elapsed = time.time() - t0
    assert elapsed < 5.0, elapsed
    n = len(res["sla_ok"])
    assert n >= 256
    s = summarize_timeline_sweep(res)
    assert s["n_scenarios"] == n
    # paper operating point passes the temporal SLA...
    grid = scenario_grid()
    op = operating_point_mask(grid)
    assert op.any()
    assert res["sla_ok"][op].all()
    assert (res["availability_mean"][op] >= 0.999).all()
    # ...and some zero-quota scenario leaves RL stranded past the horizon
    # (this 0.05-scale fleet over-fills burst when availability degrades)
    dead = grid["cloud_quota_frac"] == 0.0
    assert (np.isinf(res["rl_done_s"]) & dead).any()
    assert not (np.isinf(res["rl_done_s"]) & ~dead
                & (grid["burst_availability"] == 1.0)).any()
    assert res["sla_ok"].sum() < n
    # per-tier time-to-restore: RL tiers restore after the critical tiers
    ttr = res["time_to_restore_s"][op]
    assert (ttr[:, int(Tier.T3)] >= ttr[:, int(Tier.T2)]).all()
    # Terminate (NP) stays down for the whole horizon
    assert np.isinf(ttr[:, int(Tier.NP)]).all()


def test_config_for_fleet_is_side_effect_free():
    """Extracting a config must not disturb the caller's region pool
    counters or a FleetState's pool column — and re-extracting from a
    region that already hosted an orchestrator must not double-count."""
    fleet = synthesize_fleet(scale=0.02, seed=1, as_arrays=True)
    region = RegionCapacity.for_fleet("r", fleet)
    orch = Orchestrator(fleet, region)      # places into region for real
    used = region.steady.stateless.used
    pool_before = fleet.pool.copy()
    cfg1 = config_for_fleet(fleet, region=region)
    cfg2 = config_for_fleet(fleet, region=region)   # second call: no drift
    assert region.steady.stateless.used == used
    assert np.array_equal(fleet.pool, pool_before)
    assert cfg1.steady_used0 == cfg2.steady_used0
    # and it matches what the live orchestrator extracts
    assert cfg1.steady_used0 == pytest.approx(
        orch.timeline_config().steady_used0)
    assert cfg1.overcommit_used0 == pytest.approx(
        region.steady.overcommit.used)


def test_sweep_scenarios_merges_temporal_verdicts():
    fleet = synthesize_fleet(scale=0.02, seed=1)
    cfg = config_for_fleet(fleet)
    agg = FleetAggregates.from_fleet(fleet)
    grid = scenario_grid(traffic_mult=(2.0,), burst_delay_s=(270.0,),
                         burst_availability=(1.0, 0.5),
                         cloud_quota_frac=(1.0, 0.0))
    res = sweep_scenarios(agg, grid, timeline=cfg)
    n = len(grid["traffic_mult"])
    for key in ("t_sla_ok", "t_rl_done_s", "t_availability_mean",
                "t_time_to_restore_s", "t_peak_cloud_cores"):
        assert key in res and len(res[key]) == n, key
    summary = summarize_sweep(res)
    assert summary["n_t_sla_ok"] <= n
    assert "t_availability_mean_min" in summary
    # analytic and temporal verdicts agree at the operating point
    op = ((res["burst_availability"] == 1.0)
          & (res["cloud_quota_frac"] == 1.0))
    assert (res["sla_ok"][op] == res["t_sla_ok"][op]).all()


def test_dependency_ensemble_folds_into_trace():
    """Propagation verdicts modulate the availability *trace*: scenarios
    whose blackhole breaks criticals lose availability while their dark
    dependencies stay dark."""
    fleet = synthesize_fleet(scale=0.05, seed=3, as_arrays=True)
    res = sweep_with_dependency_ensemble(
        fleet, grid=scenario_grid(traffic_mult=(2.0,),
                                  burst_delay_s=(270.0,),
                                  burst_availability=(1.0,),
                                  cloud_quota_frac=(1.0,),
                                  evict_fraction=(0.5, 1.0)),
        temporal=True)
    assert "t_availability_mean" in res
    broken = res["dep_broken_frac"] > 0
    if broken.any() and (~broken).any():
        assert (res["t_availability_mean"][broken].max()
                < res["t_availability_mean"][~broken].min())
    # temporal availability never exceeds the ambient baseline
    assert (res["t_availability_mean"] <= 0.9997 + 1e-6).all()
