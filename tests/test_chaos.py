"""Chaos-campaign engine tests: fault library + correlated sampler,
frontier bisection (property-tested against synthetic oracles), storm /
degradation model equivalences, N-region topologies, stage-seed stream
independence, and the end-to-end campaign + bit-exact re-verification
on a small fleet."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.chaos import (FAMILIES, FAULT_LIBRARY, Campaign, Ray,
                         RegionTopology, campaign_for_fleet,
                         correlation_matrix, default_rays, expand_failures,
                         reduce_pattern_verdicts, sample_faults,
                         severity_grid, verify_report)
from repro.chaos.faults import ray_severities
from repro.core.scenarios import stage_seed
from repro.core.timeline_sim import default_ts

TS = default_ts(7200.0, 240)


# ---------------------------------------------------------------------------
# fault library
# ---------------------------------------------------------------------------

def test_family_value_severity_roundtrip():
    for fam in FAULT_LIBRARY.values():
        s = np.linspace(0.0, 1.0, 9)
        np.testing.assert_allclose(fam.severity(fam.value(s)), s, atol=1e-12)
        # severity 0 is the operating point, severity 1 the worst case
        assert fam.value(0.0) == fam.base
        assert fam.value(1.0) == fam.worst


def test_severity_grid_emits_every_knob():
    sev = np.zeros((3, len(FAMILIES)))
    sev[1, 0] = 0.5
    grid = severity_grid(sev)
    assert len(grid) == len(FAMILIES)         # constant grid signature
    for name in FAMILIES:
        fam = FAULT_LIBRARY[name]
        assert fam.knob in grid
        assert grid[fam.knob][0] == fam.base  # zero severity -> base knob
    fam0 = FAULT_LIBRARY[FAMILIES[0]]
    assert grid[fam0.knob][1] == pytest.approx(fam0.value(0.5))


def test_ray_validation():
    with pytest.raises(ValueError):
        Ray("empty", {})
    with pytest.raises(KeyError):
        Ray("bad", {"not_a_family": 1.0})
    with pytest.raises(ValueError):
        Ray("bad", {"traffic_spike": 1.5})
    with pytest.raises(KeyError):
        ray_severities({"nope": 1.0}, [0.5])


# ---------------------------------------------------------------------------
# correlated sampler (property tests)
# ---------------------------------------------------------------------------

@given(seed=st.integers(min_value=0, max_value=2**20))
@settings(max_examples=5)
def test_sampler_marginals_and_reproducibility(seed):
    """Marginals are Uniform(0, max_sev) and one campaign seed
    reproduces the draw exactly."""
    out = sample_faults(seed, 1024, max_severity=0.8)
    sev = out["severity"]
    assert sev.shape == (1024, len(FAMILIES))
    assert (sev >= 0.0).all() and (sev <= 0.8).all()
    # Uniform(0, 0.8): mean 0.4, sd 0.8/sqrt(12) ~ 0.23 -> sem ~ 0.0072
    np.testing.assert_allclose(sev.mean(axis=0), 0.4, atol=0.05)
    again = sample_faults(seed, 1024, max_severity=0.8)
    assert np.array_equal(sev, again["severity"])


@given(seed=st.integers(min_value=0, max_value=2**20))
@settings(max_examples=5)
def test_sampler_correlation_sign(seed):
    """Requested positive correlations show up with the right sign (and
    roughly the right magnitude) in the realized draws; unrequested
    pairs stay near zero."""
    out = sample_faults(seed, 2048)
    sev = out["severity"]
    idx = {name: j for j, name in enumerate(out["families"])}
    c = np.corrcoef(sev.T)
    r = c[idx["evict_shortfall"], idx["traffic_spike"]]
    assert 0.4 < r < 0.8, r                 # requested 0.6 (copula ~0.59)
    r2 = c[idx["traffic_spike"], idx["quota_shortfall"]]
    assert 0.3 < r2 < 0.7, r2               # requested 0.5
    r0 = c[idx["preheat_stall"], idx["burst_shortfall"]]
    assert abs(r0) < 0.15, r0               # independent pair


def test_sampler_seed_stream_independent_of_engine_stages():
    """The fault sampler and the engine's blackhole/storm stages derive
    DIFFERENT streams from the same campaign seed."""
    stages = ["faults", "sweep-engine", "blackhole-ensemble", "storm"]
    seeds = [stage_seed(12345, s) for s in stages]
    assert len(set(seeds)) == len(seeds)


def test_correlation_matrix_rejects_invalid():
    with pytest.raises(np.linalg.LinAlgError):
        correlation_matrix(pairs={("evict_shortfall", "traffic_spike"): 1.2})


# ---------------------------------------------------------------------------
# stage_seed regression (satellite: seed reuse across pipeline stages)
# ---------------------------------------------------------------------------

def test_stage_seed_deterministic_and_distinct():
    assert stage_seed(3, "sweep-engine") == stage_seed(3, "sweep-engine")
    assert stage_seed(3, "sweep-engine") != stage_seed(3,
                                                       "blackhole-ensemble")
    assert stage_seed(3, "sweep-engine") != stage_seed(4, "sweep-engine")


def test_dependency_ensemble_stages_draw_different_blackholes():
    """Regression: ``sweep_with_dependency_ensemble`` used to feed the
    SAME integer seed to both ``blackhole_ensemble`` and ``SweepEngine``
    — identical uniform draws in two supposedly independent stages.  The
    derived per-stage streams must produce different dark sets for the
    same campaign seed."""
    from repro.core.service import synthesize_fleet
    from repro.graph import CallGraph
    from repro.graph.propagation import shared_blackhole_draws

    fs = synthesize_fleet(scale=0.02, seed=7, as_arrays=True)
    graph = CallGraph.from_fleet_state(fs)
    fr = np.asarray([0.6, 0.6, 0.6, 0.6])
    dark_a, _ = shared_blackhole_draws(
        graph, fr, seed=stage_seed(0, "sweep-engine"))
    dark_b, _ = shared_blackhole_draws(
        graph, fr, seed=stage_seed(0, "blackhole-ensemble"))
    assert dark_a.shape == dark_b.shape
    assert not np.array_equal(np.asarray(dark_a), np.asarray(dark_b))


# ---------------------------------------------------------------------------
# frontier bisection against synthetic oracles (property tests)
# ---------------------------------------------------------------------------

def _threshold_oracle(thresholds):
    """Monotone synthetic oracle: a row fails iff its (single active)
    family severity reaches that family's threshold."""

    def oracle(grid):
        n = len(next(iter(grid.values())))
        ok = np.ones(n, bool)
        for i in range(n):
            worst_name, worst_s = None, 0.0
            for name in FAMILIES:
                fam = FAULT_LIBRARY[name]
                s = float(fam.severity(grid[fam.knob][i]))
                if s > worst_s:
                    worst_name, worst_s = name, s
            if worst_name is not None and worst_s >= thresholds[worst_name]:
                ok[i] = False
        return ok, {"sla_ok": ok}

    return oracle


@given(t1=st.floats(min_value=0.05, max_value=0.95),
       t2=st.floats(min_value=0.05, max_value=0.95),
       t3=st.floats(min_value=0.05, max_value=0.95))
@settings(max_examples=10)
def test_bisection_brackets_straddle_thresholds(t1, t2, t3):
    """For a monotone oracle the localized bracket must straddle the
    true threshold, be narrower than tol, and put the frontier estimate
    within tol of the truth."""
    names = ("traffic_spike", "quota_shortfall", "dependency_storm")
    thresholds = dict.fromkeys(FAMILIES, 2.0)    # others never fail
    thresholds.update(dict(zip(names, (t1, t2, t3))))
    tol = 1.0 / 128.0
    camp = Campaign(oracle=_threshold_oracle(thresholds),
                    rays=[Ray(n, {n: 1.0}) for n in names], tol=tol, seed=1)
    rep = camp.run()
    assert rep.op_ok
    assert rep.n_localized == 3
    for name in names:
        r = rep.ray(name)
        t = thresholds[name]
        assert r.status == "localized"
        assert r.hi - r.lo <= tol
        assert r.lo < t <= r.hi + 1e-12, (name, r.lo, r.hi, t)
        assert abs(r.frontier_severity - t) <= tol
        assert r.counterexample is not None
        fam = FAULT_LIBRARY[name]
        # minimal counterexample: the knob at the lowest KNOWN-failing
        # severity
        assert r.counterexample[fam.knob] == pytest.approx(fam.value(r.hi))


@given(t1=st.floats(min_value=0.1, max_value=0.9),
       budget=st.integers(min_value=1, max_value=3))
@settings(max_examples=5)
def test_bisection_probe_log_is_monotone(t1, budget):
    """Every pass-severity observed on a ray is strictly below every
    fail-severity (monotone oracle -> monotone probe record), under any
    bandit round budget."""
    names = ("traffic_spike", "evict_shortfall", "burst_shortfall")
    thresholds = dict.fromkeys(FAMILIES, 2.0)
    thresholds.update({n: t1 for n in names})
    camp = Campaign(oracle=_threshold_oracle(thresholds),
                    rays=[Ray(n, {n: 1.0}) for n in names],
                    tol=1.0 / 64.0, round_budget=budget, seed=2)
    rep = camp.run()
    assert rep.n_localized == 3
    for name in names:
        probes = [p for p in rep.probe_log if p["ray"] == name
                  and p["severity"] > 0.0]
        passed = [p["severity"] for p in probes if p["ok"]]
        failed = [p["severity"] for p in probes if not p["ok"]]
        assert failed, name
        if passed:
            assert max(passed) < min(failed), name
    # a budget of k probes at most k rays per bisection round
    assert rep.n_rounds >= int(np.ceil((rep.n_evals - len(names) - 1)
                                       / budget))


def test_campaign_no_violation_and_degenerate():
    rays = [Ray("traffic_spike", {"traffic_spike": 1.0})]
    rep = Campaign(oracle=lambda g: (
        np.ones(len(next(iter(g.values()))), bool),
        {"sla_ok": np.ones(len(next(iter(g.values()))), bool)}),
        rays=rays, seed=0).run()
    assert rep.rays[0].status == "no_violation"
    assert rep.n_evals == 2                  # op probe + severity-1 probe
    assert rep.rays[0].counterexample is None

    rep = Campaign(oracle=lambda g: (
        np.zeros(len(next(iter(g.values()))), bool),
        {"sla_ok": np.zeros(len(next(iter(g.values()))), bool)}),
        rays=rays, seed=0).run()
    assert not rep.op_ok
    assert rep.rays[0].status == "degenerate"
    assert rep.render()                      # renders without crashing


def test_campaign_rejects_bad_config():
    with pytest.raises(ValueError):
        Campaign(oracle=None, engine=None)
    with pytest.raises(ValueError):
        Campaign(oracle=lambda g: None, tol=0.0)
    with pytest.raises(ValueError):
        Campaign(oracle=lambda g: None, rays=[])


# ---------------------------------------------------------------------------
# N-region topologies
# ---------------------------------------------------------------------------

def test_two_region_single_failure_is_paper_operating_point():
    topo = RegionTopology.uniform(2)
    grid, pid, rid = expand_failures(topo, topo.single_failures())
    np.testing.assert_allclose(grid["traffic_mult"], [2.0, 2.0])
    assert grid["region_degradation"].tolist() == [0.0, 0.0]
    assert pid.tolist() == [0, 1] and rid.tolist() == [1, 0]


def test_three_region_multipliers_and_reduction():
    topo = RegionTopology.uniform(3)
    failed = np.concatenate([topo.single_failures(),
                             [[True, True, False]]])
    degr = np.zeros(failed.shape)
    degr[3, 2] = 0.4                        # last survivor also degraded
    grid, pid, rid = expand_failures(topo, failed, degr)
    # single failure: each of 2 survivors absorbs half the shed third
    np.testing.assert_allclose(grid["traffic_mult"][:6], 1.5)
    # double failure: lone survivor takes all traffic
    np.testing.assert_allclose(grid["traffic_mult"][6:], 3.0)
    assert grid["region_degradation"][6] == pytest.approx(0.4)

    # verdict reduction: a pattern passes iff EVERY survivor passes
    res = {"sla_ok": np.array([1, 1, 1, 0, 1, 1, 1], bool),
           "availability": np.array([.999, .999, .999, .9, .999, .999, .99])}
    red = reduce_pattern_verdicts(res, pid, topo, rid, n_patterns=4)
    assert red["sla_ok"].tolist() == [True, False, True, True]
    assert red["worst_region"][1] == rid[3]
    np.testing.assert_allclose(red["availability"][1], (.9 + .999) / 2)


def test_weighted_topology_and_validation():
    topo = RegionTopology(weights=(3.0, 1.0), names=("big", "small"))
    # big region fails: the small region absorbs 3x its own traffic
    grid, _, _ = expand_failures(topo, [[True, False]])
    np.testing.assert_allclose(grid["traffic_mult"], [4.0])
    with pytest.raises(ValueError):
        expand_failures(topo, [[True, True]])     # no survivor
    with pytest.raises(ValueError):
        RegionTopology(weights=(1.0,), names=("solo",))
    with pytest.raises(ValueError):
        RegionTopology(weights=(1.0, -1.0), names=("a", "b"))


# ---------------------------------------------------------------------------
# event-loop runaway guard (satellite)
# ---------------------------------------------------------------------------

def test_event_loop_max_events_guard():
    from repro.core.events import EventLoop

    loop = EventLoop()

    def rearm():
        loop.schedule(1.0, rearm, label="storm-rearm")

    loop.schedule(0.0, rearm, label="storm-rearm")
    with pytest.raises(RuntimeError, match="max_events=50.*storm-rearm"):
        loop.run(max_events=50)
    # a bounded workload under the cap still completes normally
    loop2 = EventLoop()
    for i in range(10):
        loop2.schedule(float(i), lambda: None)
    assert loop2.run(max_events=50) == 10


# ---------------------------------------------------------------------------
# storm / degradation model against the engine (small fleet)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_fleet():
    from repro.core.service import synthesize_fleet
    fs = synthesize_fleet(scale=0.05, seed=7, as_arrays=True)
    fs.apply_ufa_target_classes()
    return fs


@pytest.fixture(scope="module")
def engine(small_fleet):
    from repro.core.capacity import RegionCapacity
    from repro.core.omg import Orchestrator
    from repro.graph import CallGraph
    graph = CallGraph.from_fleet_state(small_fleet)
    orch = Orchestrator(small_fleet,
                        RegionCapacity.for_fleet("chaos-test", small_fleet),
                        scale=1.0)
    return orch.sweep_engine(graph=graph, seed=5, ts=TS)


def test_storm_stage_matches_composed_passthrough(engine):
    """The in-pipeline cascade-storm stage (combined dark uniques, one
    fixed point) is bit-identical to composing the engine with
    host-computed dep/storm fractions (two separate fixed points)."""
    grid = {"evict_fraction": np.array([1.0, 0.8, 0.6, 1.0]),
            "storm_refrac": np.array([0.0, 0.5, 1.0, 1.0]),
            "traffic_mult": np.array([2.0, 2.0, 2.0, 2.2])}
    fused = engine.run(dict(grid))

    dep_frac, _, _ = engine.dep_fractions(grid["evict_fraction"])
    storm_frac = engine.storm_fractions(grid["storm_refrac"])
    composed = engine.run({**grid, "storm_broken_frac": storm_frac},
                          dep_broken_frac=dep_frac)
    for k in fused:
        if k.startswith("dep_n"):
            continue                      # propagation diagnostics only
        if k in composed:
            assert np.array_equal(np.asarray(fused[k]),
                                  np.asarray(composed[k]),
                                  equal_nan=np.asarray(
                                      fused[k]).dtype.kind == "f"), k
    # the storm actually propagated something at refrac 1.0
    assert fused["storm_broken_frac"][2] > 0.0
    assert not fused["storm_ok"][2]


def test_storm_degrades_timeline_and_analytic_availability(engine):
    """A cascade storm re-darkens restored capacity: temporal mean
    availability and the analytic verdict must both degrade relative to
    the storm-free scenario; zero-refrac rows are exact no-ops."""
    grid = {"evict_fraction": np.array([1.0, 1.0]),
            "storm_refrac": np.array([0.0, 1.0])}
    res = engine.run(grid)
    base = engine.run({"evict_fraction": np.array([1.0, 1.0])})
    # refrac 0 row identical to a grid that never mentions the storm
    for k in ("sla_ok", "t_sla_ok", "availability", "t_availability_mean"):
        assert np.asarray(res[k])[0] == np.asarray(base[k])[0], k
    assert res["availability"][1] < res["availability"][0]
    assert res["t_availability_mean"][1] < res["t_availability_mean"][0]
    assert not res["storm_ok"][1]
    assert not res["sla_ok"][1]


def test_region_degradation_raises_utilization(engine):
    grid = {"region_degradation": np.array([0.0, 0.5])}
    res = engine.run(grid)
    assert res["util_peak"][1] > res["util_peak"][0]
    # peak transient utilization saturates at 1.0 either way on this
    # fleet; the steady post-restore utilization shows the lost capacity
    assert res["t_util_post"][1] > res["t_util_post"][0]


# ---------------------------------------------------------------------------
# end-to-end campaign on the engine + bit-exact re-verification
# ---------------------------------------------------------------------------

def test_campaign_on_engine_reproducible_and_reverifiable(small_fleet):
    from repro.graph import CallGraph
    from repro.graph.planner import plan_hardening

    graph = CallGraph.from_fleet_state(small_fleet)
    plan = plan_hardening(graph)
    small_fleet.edges.fail_open[
        graph.input_edge_indices(plan.hardened_edges)] = True

    rays = [Ray("preheat_stall", {"preheat_stall": 1.0}),
            Ray("burst_shortfall", {"burst_shortfall": 1.0}),
            Ray("dependency_storm", {"dependency_storm": 1.0})]
    camp = campaign_for_fleet(small_fleet, seed=11, rays=rays, tol=1 / 32.0)
    rep = camp.run()
    assert rep.op_ok, "hardened small fleet must pass its operating point"
    assert rep.n_localized >= 2
    assert rep.n_evals < rep.grid_equiv_evals / 3

    # single-seed reproducibility: a fresh campaign is byte-identical
    rep2 = campaign_for_fleet(small_fleet, seed=11, rays=rays,
                              tol=1 / 32.0).run()
    assert rep.to_json(sort_keys=True) == rep2.to_json(sort_keys=True)

    # bit-exact replay of every probe on a fresh engine, in one batch
    fresh = campaign_for_fleet(small_fleet, seed=11, rays=rays,
                               tol=1 / 32.0)
    out = verify_report(rep, fresh.engine)
    assert out["n_probes"] == rep.n_evals
    assert not out["mismatches"]

    # and verify_report actually detects drift
    tampered = rep.probe_log[-1]["verdict"]
    key = "availability" if "availability" in tampered else "sla_ok"
    orig = tampered[key]
    tampered[key] = (not orig) if isinstance(orig, bool) else orig + 0.5
    with pytest.raises(AssertionError):
        verify_report(rep, fresh.engine)
    tampered[key] = orig
