"""Dependency-graph engine: scalar-BFS equivalence (exact), blast radius,
blackhole ensembles, the hardening planner, the regression gate, and the
drills/scenarios integration."""

import numpy as np
import pytest

from repro.core.drills import certify_fleet_state
from repro.core.fleet_state import synthesize_fleet_state
from repro.core.scenarios import (scenario_grid, summarize_sweep,
                                  sweep_with_dependency_ensemble)
from repro.core.service import synthesize_fleet, unsafe_edges
from repro.graph import (CallGraph, blackhole_ensemble, blast_radius,
                         certify, plan_hardening, propagate, propagate_many,
                         regression_gate)
from repro.graph.callgraph import _build_csr


# ---------------------------------------------------------------------------
# scalar reference: worklist BFS over reversed fail-close edges
# ---------------------------------------------------------------------------


def bfs_propagate(n, edges, dark):
    """Reference fixed point: edges = [(caller, callee, fail_open)], dark =
    iterable of dark nodes.  Failure flows callee -> caller along
    fail-close edges only."""
    callers_of = {}                      # callee -> [callers via fail-close]
    for u, v, fo in edges:
        if not fo:
            callers_of.setdefault(v, []).append(u)
    broken = set(dark)
    frontier = list(broken)
    while frontier:
        v = frontier.pop()
        for u in callers_of.get(v, ()):
            if u not in broken:
                broken.add(u)
                frontier.append(u)
    return broken


def random_graph(rng, n=None, p_edge=0.15, p_close=0.5):
    """Random digraph with cycles, self-loop-free, mixed fail-open/close
    boundaries, random critical/preemptible masks."""
    n = n if n is not None else rng.integers(4, 60)
    m = rng.random((n, n)) < p_edge
    np.fill_diagonal(m, False)
    src, dst = np.nonzero(m)
    fail_open = rng.random(len(src)) >= p_close
    critical = rng.random(n) < 0.4
    preemptible = ~critical & (rng.random(n) < 0.7)
    g = _build_csr(n, src.astype(np.int32), dst.astype(np.int32),
                   fail_open, np.ones(len(src), np.float32),
                   critical, preemptible, [f"svc-{i}" for i in range(n)])
    edges = list(zip(src.tolist(), dst.tolist(), fail_open.tolist()))
    return g, edges


def test_kernel_matches_bfs_randomized():
    """Property-style: random graphs (cycles included) x random preemption
    sets — the CSR fixed-point kernel must match the BFS reference
    EXACTLY, node for node."""
    for seed in range(20):
        rng = np.random.default_rng(seed)
        g, edges = random_graph(rng)
        for _ in range(4):
            dark = rng.random(g.n) < rng.uniform(0.0, 0.6)
            want = bfs_propagate(g.n, edges, np.flatnonzero(dark))
            got = propagate(g, dark)
            assert set(np.flatnonzero(got)) == want, (seed, dark)


def test_kernel_matches_bfs_batched():
    rng = np.random.default_rng(42)
    g, edges = random_graph(rng, n=40)
    dark = rng.random((16, g.n)) < 0.3
    broken, rounds = propagate_many(g, dark)
    assert rounds >= 1
    for s in range(16):
        want = bfs_propagate(g.n, edges, np.flatnonzero(dark[s]))
        assert set(np.flatnonzero(broken[s])) == want, s


def test_cycle_and_fail_open_boundary():
    # a -> b -> c -> a all fail-close (a cycle), c -> d fail-CLOSE,
    # b -> e fail-OPEN; darkening d must break the whole cycle but spare e's
    # side of the boundary
    names = list("abcde")
    src = np.array([0, 1, 2, 2, 1], np.int32)
    dst = np.array([1, 2, 0, 3, 4], np.int32)
    fo = np.array([False, False, False, False, True])
    g = _build_csr(5, src, dst, fo, np.ones(5, np.float32),
                   np.array([True, True, True, False, False]),
                   np.array([False, False, False, True, True]), names)
    broken = propagate(g, np.array([False, False, False, True, False]))
    assert broken.tolist() == [True, True, True, True, False]
    # darkening e (fail-open caller side) breaks nothing else
    broken2 = propagate(g, np.array([False, False, False, False, True]))
    assert broken2.tolist() == [False, False, False, False, True]


def test_fleet_certification_matches_bfs():
    """The real synthesized fleet (with relay chains): multi-hop certify
    equals the BFS reference on the full preemption blackhole."""
    fs = synthesize_fleet_state(scale=0.05, seed=11,
                                unsafe_chain_fraction=0.06)
    g = CallGraph.from_fleet_state(fs)
    edges = list(zip(g.src.tolist(), g.dst.tolist(),
                     g.fail_open.tolist()))
    want = bfs_propagate(g.n, edges, np.flatnonzero(g.preemptible))
    cert = certify(g)
    assert set(np.flatnonzero(cert.broken)) == want
    assert cert.n_broken_critical > 0
    # chains present: some criticals broke with no direct unsafe cause
    assert cert.multi_hop.sum() > 0


def test_blast_radius_matches_bfs():
    for seed in (1, 5, 9):
        rng = np.random.default_rng(seed)
        g, edges = random_graph(rng, n=35)
        sources = np.arange(g.n)
        radius = blast_radius(g, sources=sources)
        for j in sources:
            want = bfs_propagate(g.n, edges, [j])
            assert radius[j] == sum(g.critical[u] for u in want), (seed, j)


def test_blackhole_ensemble_nested_monotone():
    """Shared uniform draws + sorted fractions -> nested dark sets -> the
    broken counts must be monotone in the blackhole fraction."""
    fs = synthesize_fleet_state(scale=0.05, seed=3,
                                unsafe_chain_fraction=0.05)
    g = CallGraph.from_fleet_state(fs)
    fr = np.linspace(0.0, 1.0, 64)
    ens = blackhole_ensemble(g, seed=0, fractions=fr)
    assert (np.diff(ens["n_dark"]) >= 0).all()
    assert (np.diff(ens["n_broken_critical"]) >= 0).all()
    assert ens["n_broken_critical"][0] == 0        # empty blackhole
    assert not ens["ok"][-1]                       # full blackhole breaks
    assert len(ens["ok"]) == 64


def test_planner_hardens_until_certified():
    fs = synthesize_fleet_state(scale=0.05, seed=7,
                                unsafe_chain_fraction=0.06)
    g = CallGraph.from_fleet_state(fs)
    assert not certify(g).ok
    plan = plan_hardening(g, batch=16)
    assert plan.certified
    assert certify(plan.graph).ok
    assert 0 < plan.n_hardened <= g.n_unsafe
    # trajectory: broken criticals decrease monotonically to zero
    broken = [t["n_broken_critical"] for t in plan.trajectory]
    assert broken[-1] == 0
    assert all(b1 >= b2 for b1, b2 in zip(broken, broken[1:]))
    # relay chains mean certification needs fewer conversions than there
    # are unsafe edges (chains die once their entry edges are hardened)
    assert plan.n_hardened < g.n_unsafe


def test_regression_gate_flags_planted_edge():
    fs = synthesize_fleet_state(scale=0.05, seed=7,
                                unsafe_chain_fraction=0.06)
    hardened = plan_hardening(CallGraph.from_fleet_state(fs)).graph
    # hardened fleet passes its own gate
    assert regression_gate(hardened, hardened).ok
    # plant a new unsafe edge critical -> preemptible: flagged
    crit = int(np.flatnonzero(hardened.critical)[0])
    pre = int(np.flatnonzero(hardened.preemptible)[0])
    bad = hardened.with_edge(hardened.names[crit], hardened.names[pre],
                             fail_open=False)
    gate = regression_gate(hardened, bad)
    assert not gate.ok
    assert (hardened.names[crit], hardened.names[pre]) in [
        (c, d) for c, d, _ in gate.violations]
    # a new unsafe edge between preemptible services with no critical
    # fail-close callers reaches nothing critical: gate passes
    pre2 = int(np.flatnonzero(hardened.preemptible)[1])
    benign = hardened.with_edge(hardened.names[pre],
                                hardened.names[pre2], fail_open=False)
    gate2 = regression_gate(hardened, benign)
    assert gate2.ok and gate2.new_unsafe_edges


def test_detections_build_equivalent_graph():
    """Static analysis has perfect recall/precision on the synthesized IR,
    so the graph built from its detections certifies identically to the
    ground-truth graph."""
    from repro.core.static_analysis import static_analysis
    fleet = synthesize_fleet(scale=0.05, seed=3)
    sa = static_analysis(fleet, seed=2)
    g_det, g_truth = sa["graph"], CallGraph.from_specs(fleet)
    assert g_det.unsafe_edge_keys() == g_truth.unsafe_edge_keys()
    assert (certify(g_det).broken == certify(g_truth).broken).all()


def test_drills_flag_multi_hop_chain():
    """A critical service with NO direct unsafe dependency but a fail-close
    edge onto a broken critical callee must be flagged by the drill — the
    case the one-hop error model could not see."""
    fs = synthesize_fleet_state(scale=0.05, seed=11,
                                unsafe_chain_fraction=0.06)
    cert = certify_fleet_state(fs, seed=0)
    assert cert["n_multi_hop"] > 0
    assert cert["propagation_rounds"] >= 2
    g = CallGraph.from_fleet_state(fs)
    relay_only = certify(g).multi_hop
    assert (cert["flagged_mask"] & relay_only).sum() == relay_only.sum()
    # hardening everything un-flags everyone
    fs.edges.fail_open[:] = True
    cert2 = certify_fleet_state(fs, seed=0)
    assert cert2["n_flagged"] == 0 and cert2["n_multi_hop"] == 0


def test_scenario_sweep_with_dependency_ensemble():
    fs = synthesize_fleet_state(scale=0.05, seed=7,
                                unsafe_chain_fraction=0.05)
    fs.apply_ufa_target_classes()
    grid = scenario_grid(evict_fraction=(1.0, 0.75, 0.5, 0.25))
    res = sweep_with_dependency_ensemble(fs, grid, seed=0)
    n = len(grid["evict_fraction"])
    assert len(res["dep_ok"]) == n
    # un-hardened fleet: full-eviction scenarios must fail the dep check
    full = res["evict_fraction"] == 1.0
    assert not res["dep_ok"][full].any()
    assert not res["sla_ok"][full].any()
    summary = summarize_sweep(res)
    assert summary["n_dep_ok"] < n
    assert summary["worst_dep_broken_frac"] > 0
    # hardened fleet: dep check passes everywhere and availability is
    # pointwise >= the un-hardened sweep's
    fs.edges.fail_open[:] = True
    res2 = sweep_with_dependency_ensemble(fs, grid, seed=0)
    assert res2["dep_ok"].all()
    assert (res2["availability"] >= res["availability"] - 1e-9).all()


def test_unsafe_edges_object_path_with_chains():
    """Object-path synthesis with relay chains: every unsafe edge is
    either tier-inverted (critical -> preemptible) or a critical ->
    critical relay, and relays actually occur."""
    with_chains = synthesize_fleet(scale=0.05, seed=3,
                                   unsafe_chain_fraction=0.3)
    relays = 0
    for c, d in unsafe_edges(with_chains):
        assert with_chains[c].failure_class.survives_failover
        if with_chains[d].failure_class.survives_failover:
            relays += 1
        else:
            assert with_chains[d].failure_class.preemptible
    assert relays > 0
    # and relays feed multi-hop breakage the drill can see
    g = CallGraph.from_specs(with_chains)
    assert certify(g).multi_hop.sum() > 0
