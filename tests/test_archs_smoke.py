"""Per-assigned-architecture smoke tests: REDUCED config of the same family,
one forward + one train step on CPU, asserting output shapes and no NaNs.
The FULL configs are exercised only via the dry-run (no allocation)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import SHAPES, all_archs, input_specs
from repro.models import forward, init_params, logits_fn
from repro.train import make_train_state, make_train_step

ARCHS = sorted(all_archs())


@pytest.mark.parametrize("arch_id", ARCHS)
def test_reduced_forward(arch_id):
    arch = all_archs()[arch_id]
    cfg = arch.reduced
    p = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    if cfg.embed_inputs:
        x = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    else:  # stub modality frontend provides embeddings
        x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    h, _ = forward(p, cfg, x)
    logits = logits_fn(p, cfg, h)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))


@pytest.mark.parametrize("arch_id", ARCHS)
def test_reduced_train_step(arch_id):
    arch = all_archs()[arch_id]
    cfg = arch.reduced
    step, opt = make_train_step(cfg, n_loss_chunks=2)
    state = make_train_state(cfg, jax.random.PRNGKey(0), opt)
    B, S = 2, 16
    if cfg.embed_inputs:
        inputs = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                    cfg.vocab_size)
    else:
        inputs = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model))
    batch = {"inputs": inputs,
             "labels": jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                                          cfg.vocab_size)}
    state, metrics = jax.jit(step)(state, batch)
    assert not bool(jnp.isnan(metrics["loss"]))
    assert int(state.step) == 1


@pytest.mark.parametrize("arch_id", ARCHS)
def test_full_config_matches_assignment(arch_id):
    """The FULL config must carry the exact assigned numbers."""
    expected = {
        "musicgen-large": (48, 2048, 32, 32, 8192, 2048),
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "gemma3-4b": (34, 2560, 8, 4, 10240, 262144),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "mamba2-780m": (48, 1536, 0, 1, 0, 50280),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
    }[arch_id]
    c = all_archs()[arch_id].config
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab_size) == expected


def test_moe_extras():
    a = all_archs()
    kimi = a["kimi-k2-1t-a32b"].config
    assert (kimi.n_experts, kimi.moe_top_k) == (384, 8)
    phi = a["phi3.5-moe-42b-a6.6b"].config
    assert (phi.n_experts, phi.moe_top_k) == (16, 2)
    assert a["mamba2-780m"].config.ssm_state == 128
    assert a["hymba-1.5b"].config.ssm_state == 16


def test_input_specs_cover_all_runnable_cells():
    n_runnable = 0
    for arch in all_archs().values():
        for shape in SHAPES:
            if not arch.shape_runnable(shape):
                assert shape == "long_500k"  # only documented skip rule
                continue
            specs = input_specs(arch, shape)
            assert specs
            n_runnable += 1
    assert n_runnable == 33  # 40 cells - 7 documented long_500k skips


def test_param_counts_plausible():
    a = all_archs()
    assert abs(a["command-r-plus-104b"].config.param_count() / 1e9 - 104) < 6
    assert abs(a["kimi-k2-1t-a32b"].config.param_count() / 1e12 - 1.0) < 0.08
    assert abs(a["kimi-k2-1t-a32b"].config.active_param_count() / 1e9 - 32) < 2
    assert abs(a["mamba2-780m"].config.param_count() / 1e9 - 0.78) < 0.05
    assert abs(a["phi3.5-moe-42b-a6.6b"].config.param_count() / 1e9 - 42) < 2
    assert abs(a["phi3.5-moe-42b-a6.6b"].config.active_param_count() / 1e9
               - 6.6) < 0.5
