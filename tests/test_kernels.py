"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

SETTINGS = dict(deadline=None, max_examples=12)


@pytest.mark.parametrize("S,H,d,window,dtype", [
    (128, 2, 64, 0, jnp.float32),
    (256, 4, 64, 64, jnp.float32),
    (256, 1, 128, 100, jnp.float32),
    (128, 2, 64, 0, jnp.bfloat16),
])
def test_flash_attention(S, H, d, window, dtype):
    B = 2
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, d), dtype)
    k = jax.random.normal(ks[1], (B, S, H, d), dtype)
    v = jax.random.normal(ks[2], (B, S, H, d), dtype)
    out = ops.flash_attention(q, k, v, causal=True, window=window)
    want = ref.ref_flash_attention(q, k, v, causal=True, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@given(length=st.integers(1, 1024), window=st.sampled_from([0, 64, 300]),
       kv=st.sampled_from([1, 2, 4]))
@settings(**SETTINGS)
def test_decode_attention_hypothesis(length, window, kv):
    B, d, K = 2, 64, 1024
    H = kv * 2
    ks = jax.random.split(jax.random.PRNGKey(length), 3)
    q = jax.random.normal(ks[0], (B, H, d))
    kc = jax.random.normal(ks[1], (B, K, kv, d))
    vc = jax.random.normal(ks[2], (B, K, kv, d))
    out = ops.decode_attention(q, kc, vc, length, window, block_k=256)
    want = ref.ref_decode_attention(q, kc, vc, length, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


@given(S=st.sampled_from([64, 128]), chunk=st.sampled_from([16, 32, 64]),
       H=st.sampled_from([1, 2, 4]), G_is_H=st.booleans())
@settings(**SETTINGS)
def test_ssd_scan_hypothesis(S, chunk, H, G_is_H):
    B, P, N = 2, 16, 32
    G = H if G_is_H else 1
    ks = jax.random.split(jax.random.PRNGKey(S * chunk + H), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a = -jnp.exp(jax.random.normal(ks[2], (H,)))
    b = jax.random.normal(ks[3], (B, S, G, N))
    c = jax.random.normal(ks[4], (B, S, G, N))
    y_k, s_k = ops.ssd_scan(x, dt, a, b, c, chunk=chunk)
    from repro.models.layers import ssd_chunked
    y_r, s_r = ssd_chunked(x, dt, a, b, c, chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_r),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("E,C,D,F,dtype", [
    (2, 128, 256, 128, jnp.float32),
    (4, 256, 512, 256, jnp.float32),
    (2, 128, 256, 128, jnp.bfloat16),
])
def test_grouped_matmul(E, C, D, F, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (E, C, D), dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (E, D, F), dtype)
    out = ops.grouped_matmul(x, w)
    want = ref.ref_grouped_matmul(x, w)
    tol = 2e-3 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@given(rows=st.integers(1, 64), d=st.sampled_from([128, 512, 1024]),
       bf16=st.booleans())
@settings(**SETTINGS)
def test_rmsnorm_hypothesis(rows, d, bf16):
    dtype = jnp.bfloat16 if bf16 else jnp.float32
    x = jax.random.normal(jax.random.PRNGKey(rows + d), (rows, d), dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), (d,), dtype)
    out = ops.rmsnorm(x, s)
    want = ref.ref_rmsnorm(x, s)
    tol = 1e-5 if not bf16 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)
