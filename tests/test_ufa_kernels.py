"""UFA Pallas kernels (interpret mode on CPU): exact parity of the three
device kernels — ELL frontier propagation, scatter-add histogram ingest,
segmented verdict reduction — against their XLA references and scalar
ground truth, the ``REPRO_UFA_KERNELS`` backend dispatch end to end
(graph layer, planner, detector, sweep engine), edge cases (empty
frontier, edge-free graph, zero records), and the x64 dtype pins."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.dependency import RuntimeFailCloseDetector, runtime_analysis
from repro.core.fleet_state import synthesize_fleet_state
from repro.core.scenarios import FleetAggregates, scenario_grid
from repro.core.service import synthesize_fleet
from repro.core.sweep_engine import SweepEngine
from repro.core.timeline_sim import config_for_fleet, default_ts
from repro.graph import (CallGraph, blackhole_ensemble, blast_radius,
                         certify, plan_hardening, propagate, propagate_many)
from repro.graph.callgraph import _build_csr
from repro.kernels.backend import default_interpret, use_ufa_kernels
from repro.kernels.ufa.ingest import (N_CODES, ingest_hist, ref_ingest_hist)
from repro.kernels.ufa.propagation import (ell_from_csr, fixed_point_ell,
                                           ref_fixed_point)
from repro.kernels.ufa.reduce import ref_timeline_reduce, timeline_reduce

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_ALLOWED = (np.float32, np.bool_, np.int32)


# ---------------------------------------------------------------------------
# scalar references (standalone — no coupling to other test modules)
# ---------------------------------------------------------------------------


def bfs_broken(n, src, dst, closed, dark):
    """Worklist BFS fixed point: failure flows callee -> caller along
    fail-close (closed) edges only."""
    callers_of = {}
    for u, v, c in zip(src.tolist(), dst.tolist(), closed.tolist()):
        if c:
            callers_of.setdefault(v, []).append(u)
    broken = set(np.flatnonzero(dark).tolist())
    frontier = list(broken)
    while frontier:
        v = frontier.pop()
        for u in callers_of.get(v, ()):
            if u not in broken:
                broken.add(u)
                frontier.append(u)
    out = np.zeros(n, bool)
    out[list(broken)] = True
    return out


def random_csr(rng, n=None, p_edge=0.15, p_close=0.5):
    """Random digraph with cycles in CSR order (nonzero scan is row-major,
    so ``src`` is already sorted)."""
    n = n if n is not None else int(rng.integers(4, 60))
    m = rng.random((n, n)) < p_edge
    np.fill_diagonal(m, False)
    src, dst = np.nonzero(m)
    closed = rng.random(len(src)) < p_close
    indptr = np.searchsorted(src, np.arange(n + 1)).astype(np.int64)
    return n, indptr, src.astype(np.int32), dst.astype(np.int32), closed


def scalar_reduce(avail, util, cloud, frac, ts, thresh):
    """Step-by-step float32 replica of ``timeline_sim._carry_step`` (the
    sequential scan the kernel replaces): dt[0] = 0, first-crossing
    restore times, cumulative below_seen."""
    S, T = avail.shape
    R = frac.shape[2]
    avail_int = np.zeros(S, np.float32)
    avail_min = np.ones(S, np.float32)
    util_peak = np.zeros(S, np.float32)
    cloud_peak = np.zeros(S, np.float32)
    below_seen = np.zeros((S, R), bool)
    restore_t = np.full((S, R), np.inf, np.float32)
    prev_t = np.float32(ts[0])
    for t in range(T):
        dt = np.float32(max(np.float32(ts[t]) - prev_t, 0.0))
        prev_t = np.float32(ts[t])
        avail_int = np.float32(avail_int + avail[:, t] * dt)
        avail_min = np.minimum(avail_min, avail[:, t])
        util_peak = np.maximum(util_peak, util[:, t])
        cloud_peak = np.maximum(cloud_peak, cloud[:, t])
        below = frac[:, t, :] < thresh
        seen = below_seen | below
        cross = seen & ~below & np.isinf(restore_t)
        restore_t = np.where(cross, np.float32(ts[t]), restore_t)
        below_seen = seen
    return {"avail_int": avail_int, "avail_min": avail_min,
            "util_peak": util_peak, "cloud_peak": cloud_peak,
            "restore_t": restore_t, "below_seen": below_seen}


# ---------------------------------------------------------------------------
# backend dispatch helpers
# ---------------------------------------------------------------------------


def test_backend_helpers(monkeypatch):
    # this suite runs on CPU: interpret mode must be the default
    assert default_interpret() is True
    monkeypatch.delenv("REPRO_UFA_KERNELS", raising=False)
    assert use_ufa_kernels() is False          # CPU default: host paths
    monkeypatch.setenv("REPRO_UFA_KERNELS", "1")
    assert use_ufa_kernels() is True
    monkeypatch.setenv("REPRO_UFA_KERNELS", "0")
    assert use_ufa_kernels() is False
    monkeypatch.setenv("REPRO_UFA_KERNELS", "definitely")  # junk -> default
    assert use_ufa_kernels() is False


# ---------------------------------------------------------------------------
# kernel 1: ELL frontier propagation
# ---------------------------------------------------------------------------


def test_ell_from_csr_roundtrip():
    rng = np.random.default_rng(0)
    n, indptr, src, dst, closed = random_csr(rng, n=40)
    ell_dst, ell_closed, slot = ell_from_csr(n, indptr, dst, closed)
    K = ell_dst.shape[1]
    assert K % 8 == 0 and K >= np.diff(indptr).max()
    # every edge lands at (src, slot); pad slots are closed=False
    assert (ell_dst[src, slot] == dst).all()
    assert (ell_closed[src, slot] == closed).all()
    filled = np.zeros((n, K), bool)
    filled[src, slot] = True
    assert not ell_closed[~filled].any()


def test_propagation_matches_ref_and_bfs():
    """Random cyclic graphs x random dark batches: the Pallas fixed point
    must match the XLA scatter-max reference EXACTLY — broken matrix and
    round count — and the BFS scalar reference node for node."""
    for seed in range(8):
        rng = np.random.default_rng(seed)
        n, indptr, src, dst, closed = random_csr(rng)
        ell_dst, ell_closed, _ = ell_from_csr(n, indptr, dst, closed)
        dark = rng.random((5, n)) < rng.uniform(0.05, 0.5)
        got, rounds = fixed_point_ell(
            jnp.asarray(dark), jnp.asarray(ell_dst), jnp.asarray(ell_closed))
        want, ref_rounds = ref_fixed_point(
            jnp.asarray(dark), jnp.asarray(src), jnp.asarray(dst),
            jnp.asarray(closed))
        assert np.array_equal(np.asarray(got), np.asarray(want)), seed
        assert int(rounds) == int(ref_rounds), seed
        for s in range(5):
            assert np.array_equal(np.asarray(got[s]),
                                  bfs_broken(n, src, dst, closed, dark[s]))


def test_propagation_blocking_and_padding():
    """Non-multiple S and n against small block sizes: the pad rows/cols
    must never leak into (or corrupt) the live region."""
    rng = np.random.default_rng(3)
    n, indptr, src, dst, closed = random_csr(rng, n=37, p_edge=0.2)
    ell_dst, ell_closed, _ = ell_from_csr(n, indptr, dst, closed)
    dark = rng.random((5, n)) < 0.3
    got, rounds = fixed_point_ell(
        jnp.asarray(dark), jnp.asarray(ell_dst), jnp.asarray(ell_closed),
        block_s=2, block_r=8)
    want, ref_rounds = ref_fixed_point(
        jnp.asarray(dark), jnp.asarray(src), jnp.asarray(dst),
        jnp.asarray(closed))
    assert np.array_equal(np.asarray(got), np.asarray(want))
    assert int(rounds) == int(ref_rounds)


def test_propagation_cycle_and_fail_open_boundary():
    # a->b, b->c, c->a all fail-close (a cycle), c->d fail-close,
    # b->e fail-OPEN; darkening d breaks the whole cycle but spares e
    src = np.array([0, 1, 1, 2, 2], np.int32)
    dst = np.array([1, 2, 4, 0, 3], np.int32)
    closed = np.array([True, True, False, True, True])
    indptr = np.searchsorted(src, np.arange(6)).astype(np.int64)
    ell_dst, ell_closed, _ = ell_from_csr(5, indptr, dst, closed)
    dark = np.zeros((2, 5), bool)
    dark[0, 3] = True                   # d dark: cycle breaks, e survives
    dark[1, 4] = True                   # e dark: fail-open edge relays nothing
    got, _ = fixed_point_ell(
        jnp.asarray(dark), jnp.asarray(ell_dst), jnp.asarray(ell_closed))
    assert np.asarray(got[0]).tolist() == [True, True, True, True, False]
    assert np.asarray(got[1]).tolist() == [False, False, False, False, True]


def test_propagation_empty_cases():
    # edge-free graph: K == 0, one no-change round, broken == dark
    ell_dst, ell_closed, slot = ell_from_csr(
        6, np.zeros(7, np.int64), np.zeros(0, np.int64), np.zeros(0, bool))
    assert ell_dst.shape == (6, 0) and slot.shape == (0,)
    dark = np.eye(6, dtype=bool)[:3]
    got, rounds = fixed_point_ell(
        jnp.asarray(dark), jnp.asarray(ell_dst), jnp.asarray(ell_closed))
    assert np.array_equal(np.asarray(got), dark) and int(rounds) == 1
    # empty scenario batch
    rng = np.random.default_rng(1)
    n, indptr, _, dst, closed = random_csr(rng, n=10)
    ed, ec, _ = ell_from_csr(n, indptr, dst, closed)
    got0, rounds0 = fixed_point_ell(
        jnp.zeros((0, n), bool), jnp.asarray(ed), jnp.asarray(ec))
    assert got0.shape == (0, n) and int(rounds0) == 1


@pytest.fixture(scope="module")
def fleet_graph():
    fs = synthesize_fleet_state(scale=0.05, seed=7,
                                unsafe_chain_fraction=0.06)
    return CallGraph.from_fleet_state(fs)


@pytest.mark.parametrize("env", ["0", "1"])
def test_graph_layer_backends_agree(monkeypatch, fleet_graph, env):
    """certify / blast radius / ensembles / batched propagation return the
    same answers whichever backend ``edge_consts`` dispatches to (the
    Pallas path is compared against fixed expectations computed on the
    default path by the sibling parametrization)."""
    monkeypatch.setenv("REPRO_UFA_KERNELS", env)
    g = fleet_graph
    rng = np.random.default_rng(5)
    dark = rng.random((8, g.n)) < 0.2
    broken, rounds = propagate_many(g, dark)
    cert = certify(g)
    sources = np.flatnonzero(g.preemptible)[:64]
    radius = blast_radius(g, sources=sources)
    ens = blackhole_ensemble(g, seed=0, fractions=np.linspace(0, 1, 16))
    state = (broken, int(rounds), cert.broken, cert.n_broken_critical,
             radius, ens["n_broken_critical"], ens["n_dark"])
    cache = getattr(test_graph_layer_backends_agree, "_state", None)
    if cache is None:
        test_graph_layer_backends_agree._state = state
    else:
        for a, b in zip(cache, state):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    # single-scenario path vs BFS stays exact under either backend
    one = propagate(g, dark[0])
    assert np.array_equal(
        one, bfs_broken(g.n, g.src, g.dst, ~g.fail_open, dark[0]))


def test_planner_backends_agree(monkeypatch, fleet_graph):
    """The greedy hardening planner (frontier batches + in-place mask
    updates through ``harden_consts``) picks the identical edge sequence
    on both backends."""
    monkeypatch.setenv("REPRO_UFA_KERNELS", "0")
    plan_cpu = plan_hardening(fleet_graph, batch=16)
    monkeypatch.setenv("REPRO_UFA_KERNELS", "1")
    plan_dev = plan_hardening(fleet_graph, batch=16)
    assert plan_cpu.certified and plan_dev.certified
    assert plan_cpu.hardened_edges == plan_dev.hardened_edges
    assert plan_cpu.trajectory == plan_dev.trajectory


# ---------------------------------------------------------------------------
# kernel 2: scatter-add histogram ingest
# ---------------------------------------------------------------------------


def _random_records(rng, n_records, n_edges):
    eid = rng.integers(0, n_edges, n_records)
    failed = rng.random(n_records) < 0.3
    errored = rng.random(n_records) < 0.4
    return eid, failed, errored


def _np_hist(eid, failed, errored, n_edges):
    code = failed.astype(np.int64) * 2 + errored.astype(np.int64)
    return np.bincount(eid * N_CODES + code,
                       minlength=n_edges * N_CODES).reshape(-1, N_CODES)


@pytest.mark.parametrize("n_records,n_edges,block_n", [
    (10_000, 257, 4096),       # multi-block grid, padded rows
    (5_000, 256, 4096),        # n_edges already a multiple of 8: e_pad ==
                               # n_edges, the pad-sentinel regression case
    (999, 8, 256),             # tiny universe, heavy duplicates
    (4096, 1000, 4096),        # single block, no record padding
])
def test_ingest_hist_exact(n_records, n_edges, block_n):
    rng = np.random.default_rng(n_records)
    eid, failed, errored = _random_records(rng, n_records, n_edges)
    got = np.asarray(ingest_hist(
        jnp.asarray(eid), jnp.asarray(failed), jnp.asarray(errored),
        n_edges, block_n=block_n))
    ref = np.asarray(ref_ingest_hist(
        jnp.asarray(eid), jnp.asarray(failed), jnp.asarray(errored),
        n_edges))
    want = _np_hist(eid, failed, errored, n_edges)
    assert np.array_equal(got, want)
    assert np.array_equal(ref, want)
    assert got.sum() == n_records          # pads never counted


def test_ingest_hist_empty():
    z = jnp.zeros(0, jnp.int32)
    assert np.asarray(ingest_hist(z, z, z, 16)).sum() == 0
    assert ingest_hist(z, z, z, 0).shape == (0, N_CODES)
    eid = jnp.zeros(5, jnp.int32)
    assert ingest_hist(eid, eid, eid, 0).shape == (0, N_CODES)


@pytest.mark.parametrize("env", ["0", "1"])
def test_detector_backends_agree(monkeypatch, env):
    """``ingest_batch`` folds identical counts through either backend, so
    the full runtime analysis (sampled stream -> detection graph) must be
    bit-identical."""
    monkeypatch.setenv("REPRO_UFA_KERNELS", env)
    fleet = synthesize_fleet(scale=0.02, seed=3, as_arrays=True)
    res = runtime_analysis(fleet, n_records=60_000, seed=0)
    det = res["detector"]
    state = (det.calls, det.callee_failures, det.errors_given_failure,
             det.errors_given_ok, sorted(res["found"]), res["precision"],
             res["recall"])
    cache = getattr(test_detector_backends_agree, "_state", None)
    if cache is None:
        test_detector_backends_agree._state = state
    else:
        for a, b in zip(cache, state):
            assert np.array_equal(np.asarray(a), np.asarray(b))
    assert det.calls.dtype == np.int64
    assert det.n_records == 60_000


def test_ingest_overflow_guard():
    det = RuntimeFailCloseDetector()
    det.ingest([type("R", (), {"caller": "a", "callee": "b",
                               "callee_failed": True,
                               "caller_errored": True})()])
    assert det.calls.tolist() == [1]
    det.calls[:] = 1 << 62                 # evidence near the int64 ceiling
    with pytest.raises(AssertionError, match="overflow"):
        det.ingest_batch(np.zeros(1, np.int64), np.ones(1, bool),
                         np.ones(1, bool))


# ---------------------------------------------------------------------------
# kernel 3: segmented verdict reduction
# ---------------------------------------------------------------------------


def _random_series(rng, S=19, T=33, R=3):
    avail = rng.random((S, T), dtype=np.float32)
    util = rng.random((S, T), dtype=np.float32) * 1.5
    cloud = rng.random((S, T), dtype=np.float32) * 1e5
    # tier fractions hovering around the threshold so every scenario mixes
    # never-below / below-then-restored / still-below tiers
    frac = (0.995 + 0.01 * rng.random((S, T, R))).astype(np.float32)
    ts = np.cumsum(rng.random(T).astype(np.float32) * 30.0)
    return avail, util, cloud, frac, ts


def test_timeline_reduce_matches_scalar_and_ref():
    thresh = 0.999
    for seed in range(4):
        rng = np.random.default_rng(seed)
        avail, util, cloud, frac, ts = _random_series(rng)
        got = {k: np.asarray(v) for k, v in timeline_reduce(
            jnp.asarray(avail), jnp.asarray(util), jnp.asarray(cloud),
            jnp.asarray(frac), jnp.asarray(ts), thresh=thresh,
            block_s=8).items()}
        ref = {k: np.asarray(v) for k, v in ref_timeline_reduce(
            jnp.asarray(avail), jnp.asarray(util), jnp.asarray(cloud),
            jnp.asarray(frac), jnp.asarray(ts), thresh=thresh).items()}
        want = scalar_reduce(avail, util, cloud, frac, ts, thresh)
        for k in want:
            # selections (min/max/first-crossing/cumulative-OR) are exact;
            # the availability integral is a reordered float32 sum
            if k == "avail_int":
                np.testing.assert_allclose(got[k], want[k], rtol=3e-6)
                np.testing.assert_allclose(ref[k], want[k], rtol=3e-6)
            else:
                assert np.array_equal(got[k], want[k]), (seed, k)
                assert np.array_equal(ref[k], want[k]), (seed, k)
        assert np.array_equal(got["restore_t"] < np.inf,
                              got["below_seen"] & (got["restore_t"] < np.inf))


def test_timeline_reduce_crossing_semantics():
    """Hand-built tier trajectories: never below -> inf/False; dip then
    restore -> the FIRST timestamp at-threshold; below at the end -> inf
    restore but below_seen True (time_to_restore reports 0 downstream)."""
    ts = np.arange(6, dtype=np.float32) * 10.0
    frac = np.ones((1, 6, 3), np.float32)
    frac[0, 1:3, 1] = 0.5                  # tier 1: below at t=10,20
    frac[0, 2:, 2] = 0.5                   # tier 2: below from t=20 onward
    z = np.zeros((1, 6), np.float32)
    got = timeline_reduce(jnp.asarray(z), jnp.asarray(z), jnp.asarray(z),
                          jnp.asarray(frac), jnp.asarray(ts), thresh=0.999)
    assert np.asarray(got["below_seen"])[0].tolist() == [False, True, True]
    restore = np.asarray(got["restore_t"])[0]
    assert np.isinf(restore[0])            # never below
    assert restore[1] == 30.0              # first step back at full strength
    assert np.isinf(restore[2])            # never restored
    # single-step edge case: T == 1, dt[0] == 0 -> zero integral
    one = timeline_reduce(
        jnp.ones((2, 1)), jnp.zeros((2, 1)), jnp.zeros((2, 1)),
        jnp.ones((2, 1, 3)), jnp.asarray(ts[:1]), thresh=0.999)
    assert np.asarray(one["avail_int"]).tolist() == [0.0, 0.0]


@pytest.fixture(scope="module")
def engine_parts():
    fs = synthesize_fleet(scale=0.02, seed=1, as_arrays=True)
    fs.apply_ufa_target_classes()
    return (FleetAggregates.from_fleet_state(fs), config_for_fleet(fs),
            CallGraph.from_fleet_state(fs))


def test_sweep_engine_reducer_parity(engine_parts):
    """reducer="pallas" vs the bit-exact scan path on a full 256-scenario
    grid (dependency stage fused in): every verdict identical except the
    availability integral, which is float32-tight."""
    agg, cfg, graph = engine_parts
    ts = default_ts(7200.0, 120)
    grid = scenario_grid(evict_fraction=(1.0, 0.5))
    scan = SweepEngine(agg, cfg, graph=graph, ts=ts, reducer="scan").run(grid)
    pal = SweepEngine(agg, cfg, graph=graph, ts=ts,
                      reducer="pallas").run(grid)
    assert set(scan) == set(pal)
    for k in scan:
        if k == "t_availability_mean":
            np.testing.assert_allclose(pal[k], scan[k], rtol=1e-5)
        else:
            assert np.array_equal(pal[k], scan[k], equal_nan=True), k
        if k not in grid:
            assert pal[k].dtype in _ALLOWED, (k, pal[k].dtype)


def test_sweep_engine_reducer_dispatch(monkeypatch, engine_parts):
    agg, cfg, _ = engine_parts
    monkeypatch.setenv("REPRO_UFA_KERNELS", "1")
    assert SweepEngine(agg, cfg).reducer == "pallas"
    monkeypatch.setenv("REPRO_UFA_KERNELS", "0")
    assert SweepEngine(agg, cfg).reducer == "scan"
    with pytest.raises(AssertionError):
        SweepEngine(agg, cfg, reducer="fancy")


# ---------------------------------------------------------------------------
# dtype pins under x64
# ---------------------------------------------------------------------------


def test_kernels_no_float64_under_x64():
    """JAX_ENABLE_X64=1 must not leak float64/int64 out of any of the
    three kernels (or their refs): a weak Python scalar in kernel code
    would promote here."""
    code = textwrap.dedent("""
        import numpy as np
        import jax.numpy as jnp
        from repro.kernels.ufa.ingest import ingest_hist, ref_ingest_hist
        from repro.kernels.ufa.propagation import (ell_from_csr,
                                                   fixed_point_ell,
                                                   ref_fixed_point)
        from repro.kernels.ufa.reduce import (ref_timeline_reduce,
                                              timeline_reduce)
        allowed = (np.float32, np.bool_, np.int32)
        rng = np.random.default_rng(0)
        n = 30
        m = rng.random((n, n)) < 0.2
        np.fill_diagonal(m, False)
        src, dst = np.nonzero(m)
        closed = rng.random(len(src)) < 0.5
        indptr = np.searchsorted(src, np.arange(n + 1))
        ed, ec, _ = ell_from_csr(n, indptr, dst, closed)
        dark = rng.random((4, n)) < 0.3
        broken, rounds = fixed_point_ell(jnp.asarray(dark),
                                         jnp.asarray(ed), jnp.asarray(ec))
        ref, rref = ref_fixed_point(
            jnp.asarray(dark), jnp.asarray(src.astype(np.int32)),
            jnp.asarray(dst.astype(np.int32)), jnp.asarray(closed))
        assert broken.dtype == np.bool_ and rounds.dtype == np.int32
        assert np.array_equal(np.asarray(broken), np.asarray(ref))
        assert int(rounds) == int(rref)
        eid = rng.integers(0, 100, 5000)
        f = rng.random(5000) < 0.3
        e = rng.random(5000) < 0.4
        h = ingest_hist(jnp.asarray(eid), jnp.asarray(f), jnp.asarray(e),
                        100)
        hr = ref_ingest_hist(jnp.asarray(eid), jnp.asarray(f),
                             jnp.asarray(e), 100)
        assert h.dtype == np.int32 and hr.dtype == np.int32
        assert np.array_equal(np.asarray(h), np.asarray(hr))
        S, T, R = 9, 17, 3
        a = rng.random((S, T), dtype=np.float32)
        fr = (0.99 + 0.02 * rng.random((S, T, R))).astype(np.float32)
        ts = np.cumsum(rng.random(T).astype(np.float32))
        out = timeline_reduce(jnp.asarray(a), jnp.asarray(a),
                              jnp.asarray(a), jnp.asarray(fr),
                              jnp.asarray(ts), thresh=0.999)
        for k, v in out.items():
            assert v.dtype in allowed, (k, v.dtype)
        print("OK")
    """)
    env = dict(os.environ)
    env["JAX_ENABLE_X64"] = "1"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout


# ---------------------------------------------------------------------------
# regression guard: new/retired benchmark rows stay informational
# ---------------------------------------------------------------------------


def test_check_regression_tolerates_new_rows(tmp_path):
    """Rows present on only one side (new kernels benches / retired rows)
    must not fail the guard — they are reported, not gated."""
    base = {"rows": [{"name": "old_row", "us_per_call": 1e4},
                     {"name": "retired_row", "us_per_call": 5e4}]}
    fresh = {"rows": [{"name": "old_row", "us_per_call": 1.1e4},
                      {"name": "brand_new_kernel", "us_per_call": 9e9}]}
    bp = tmp_path / "BENCH_1.json"
    fp = tmp_path / "fresh.json"
    bp.write_text(json.dumps(base))
    fp.write_text(json.dumps(fresh))
    out = subprocess.run(
        [sys.executable, os.path.join(ROOT, "benchmarks",
                                      "check_regression.py"),
         str(fp), "--baseline", str(bp)],
        capture_output=True, text=True, timeout=120)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "brand_new_kernel" in out.stdout     # reported...
    assert "retired" in out.stdout
    assert "FAIL" not in out.stdout             # ...but never gated
