"""Fused sweep engine: composed-path bit-exact equivalence, bucket-padded
jit-cache reuse, sharded-vs-single-device equality, wrapper delegation,
and the float32 dtype pins (regression for silent float64/weak-type
promotion in the sweep/timeline paths)."""

import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.capacity import RegionCapacity
from repro.core.omg import Orchestrator
from repro.core.scenarios import (FleetAggregates, analytic_consts,
                                  scenario_grid, stage_seed,
                                  sweep_scenarios,
                                  sweep_with_dependency_ensemble,
                                  _sweep_jit)
from repro.core.service import synthesize_fleet
from repro.core.sweep_engine import (CHUNK, MIN_BUCKET, SweepEngine,
                                     bucket_shape, compiled_variants,
                                     fused_sweep, tile_grid)
from repro.core.timeline_sim import (config_for_fleet, default_ts,
                                     sweep_timeline)
from repro.graph import CallGraph

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
TS = default_ts(7200.0, 240)

# every key the fused jit emits must be float32 / bool / int32 — float64
# (or a weak-type promotion that only shows up under x64) is a regression
_ALLOWED = (np.float32, np.bool_, np.int32)


@pytest.fixture(scope="module")
def fleet():
    fs = synthesize_fleet(scale=0.05, seed=7, as_arrays=True)
    fs.apply_ufa_target_classes()
    return fs


@pytest.fixture(scope="module")
def parts(fleet):
    agg = FleetAggregates.from_fleet_state(fleet)
    cfg = config_for_fleet(fleet)
    graph = CallGraph.from_fleet_state(fleet)
    return agg, cfg, graph


def _composed(agg, cfg, grid, dep_frac, ts):
    """The PR-4 composition: analytic jit + timeline jit (with the trace
    stack materialized), separate calls, host round-trips between them."""
    params = {k: jnp.asarray(v, jnp.float32) for k, v in grid.items()}
    params["dep_broken_frac"] = jnp.asarray(dep_frac, jnp.float32)
    out = {k: np.asarray(v)
           for k, v in _sweep_jit(analytic_consts(agg), params).items()}
    tres = sweep_timeline(cfg, grid=grid, ts=ts, dep_broken_frac=dep_frac,
                          return_traces=True)
    for k, v in tres.items():
        if k != "t" and not k.startswith("trace_"):
            out[f"t_{k}"] = v
    return out


def test_fused_matches_composed_bit_exact_256(parts):
    """Tentpole acceptance: one fused jitted pipeline == the composed
    three-stage path, exactly, on every verdict key at 256 scenarios."""
    agg, cfg, _ = parts
    grid = scenario_grid()
    eng = SweepEngine(agg, cfg, ts=TS)
    fused = eng.run(grid)
    want = _composed(agg, cfg, grid, np.zeros(256), TS)
    assert set(want) <= set(fused)
    for k, v in want.items():
        got = fused[k]
        assert got.dtype == v.dtype, k
        assert np.array_equal(got, v, equal_nan=True), k


def test_fused_dependency_stage_matches_composed(parts):
    """With the propagation stage fused in-program, every verdict still
    matches the composed path fed the same (device-computed) per-scenario
    broken-critical fractions."""
    agg, cfg, graph = parts
    grid = scenario_grid(evict_fraction=(1.0, 0.75, 0.5, 0.25))
    eng = SweepEngine(agg, cfg, graph=graph, seed=0, ts=TS)
    fused = eng.run(grid)
    frac, counts, n_dark = eng.dep_fractions(
        np.asarray(grid["evict_fraction"]))
    want = _composed(agg, cfg, grid, frac, TS)
    for k, v in want.items():
        assert np.array_equal(fused[k], v, equal_nan=True), k
    assert np.array_equal(fused["dep_n_broken_critical"], counts)
    assert np.array_equal(fused["dep_n_dark"], n_dark)
    # the dependency verdicts agree with the legacy host-side ensemble
    from repro.graph import blackhole_ensemble
    ens = blackhole_ensemble(graph, seed=0,
                             fractions=np.asarray(grid["evict_fraction"]))
    assert np.array_equal(fused["dep_n_broken_critical"],
                          ens["n_broken_critical"])
    assert np.array_equal(fused["dep_n_dark"], ens["n_dark"])


def test_wrappers_delegate_to_fused_engine(parts, fleet):
    """The existing APIs are thin wrappers now: ``sweep_scenarios(...,
    timeline=cfg)`` and ``sweep_with_dependency_ensemble(...,
    temporal=True)`` return exactly what the engine returns."""
    agg, cfg, graph = parts
    grid = scenario_grid(evict_fraction=(1.0, 0.5))
    via_api = sweep_scenarios(agg, grid, timeline=cfg, ts=TS)
    direct = SweepEngine(agg, cfg, ts=TS).run(grid)
    assert set(via_api) == set(direct)
    for k in direct:
        assert np.array_equal(via_api[k], direct[k], equal_nan=True), k

    # the wrapper derives an independent stream for its engine stage from
    # the campaign seed (the seed-reuse fix) — delegation is still exact
    # against an engine built with the same derived seed
    via_dep = sweep_with_dependency_ensemble(fleet, grid=grid, seed=3,
                                             temporal=True, ts=TS)
    direct_dep = SweepEngine(agg, cfg, graph=graph,
                             seed=stage_seed(3, "sweep-engine"),
                             ts=TS).run(grid)
    for k in direct_dep:
        assert np.array_equal(via_dep[k], direct_dep[k],
                              equal_nan=True), k


def test_orchestrator_sweep_engine_wrapper(fleet):
    region = RegionCapacity.for_fleet("r", fleet)
    orch = Orchestrator(fleet, region)
    eng = orch.sweep_engine()
    res = eng.run(scenario_grid(), temporal=True)
    assert len(res["t_sla_ok"]) == 256
    # operating point: same config the standalone extraction produces
    cfg = config_for_fleet(fleet, region=region)
    want = SweepEngine(FleetAggregates.from_fleet_state(fleet),
                       cfg).run(scenario_grid())
    assert np.array_equal(res["t_rl_done_s"], want["t_rl_done_s"])


def test_bucket_shape_padding():
    assert bucket_shape(1) == (1, MIN_BUCKET)
    assert bucket_shape(256) == (1, 256)
    assert bucket_shape(257) == (1, 512)
    assert bucket_shape(CHUNK) == (1, CHUNK)
    assert bucket_shape(CHUNK + 1) == (2, CHUNK)
    assert bucket_shape(10 * CHUNK) == (16, CHUNK)
    assert bucket_shape(100_000) == (32, CHUNK)
    # every width divides cleanly over up to 8 virtual devices
    for n in (1, 100, 256, 5000, 100_000):
        _, width = bucket_shape(n)
        assert width % 8 == 0


def test_no_recompile_within_padding_bucket(parts):
    """Grid sizes that pad to the same (n_chunks, width) bucket must hit
    the same compiled pipeline (keyed jit cache on static shapes only)."""
    agg, cfg, _ = parts
    eng = SweepEngine(agg, cfg, ts=TS)
    base = scenario_grid()
    eng.run(tile_grid(base, 300))              # bucket (1, 512)
    n0 = compiled_variants()
    eng.run(tile_grid(base, 511))              # same bucket
    eng.run(tile_grid(base, 400))              # same bucket
    assert compiled_variants() == n0
    eng.run(tile_grid(base, 513))              # next bucket -> one compile
    assert compiled_variants() == n0 + 1
    # padded scenarios do not leak into results
    r_400 = eng.run(tile_grid(base, 400))
    assert len(r_400["sla_ok"]) == 400
    r_511 = eng.run(tile_grid(base, 511))
    assert np.array_equal(r_400["sla_ok"], r_511["sla_ok"][:400])


def test_fused_sweep_convenience(fleet):
    res = fused_sweep(fleet, scenario_grid(evict_fraction=(1.0, 0.5)),
                      seed=0, ts=TS)
    assert "t_sla_ok" in res and "dep_n_dark" in res
    assert len(res["sla_ok"]) == 512


def test_output_dtypes_pinned(parts):
    """Every fused-pipeline output is float32 / bool / int32 — the grid
    axes pass through untouched, but no verdict may silently promote."""
    agg, cfg, graph = parts
    grid = scenario_grid(evict_fraction=(1.0, 0.5))
    eng = SweepEngine(agg, cfg, graph=graph, ts=TS)
    res = eng.run(grid)
    for k, v in res.items():
        if k in grid:
            continue                           # host passthrough
        assert v.dtype in _ALLOWED, (k, v.dtype)
    tres = sweep_timeline(cfg, grid=grid, ts=TS)
    for k, v in tres.items():
        assert v.dtype in _ALLOWED, (k, v.dtype)


def _run(code, n_devices=1, x64=False):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    if x64:
        env["JAX_ENABLE_X64"] = "1"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600,
                         cwd=ROOT)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_equals_single_device():
    """Under 8 virtual host devices the scenario axis is sharded across
    the mesh; verdicts must match the single-device run bit-for-bit."""
    code = textwrap.dedent("""
        import numpy as np, jax
        assert len(jax.devices()) == 8, jax.devices()
        from repro.core.scenarios import FleetAggregates, scenario_grid
        from repro.core.service import synthesize_fleet
        from repro.core.sweep_engine import SweepEngine, tile_grid
        from repro.core.timeline_sim import config_for_fleet, default_ts
        from repro.graph import CallGraph
        fs = synthesize_fleet(scale=0.02, seed=1, as_arrays=True)
        fs.apply_ufa_target_classes()
        agg = FleetAggregates.from_fleet_state(fs)
        cfg = config_for_fleet(fs)
        graph = CallGraph.from_fleet_state(fs)
        ts = default_ts(7200.0, 120)
        grid = tile_grid(scenario_grid(evict_fraction=(1.0, 0.5)), 1024)
        sharded = SweepEngine(agg, cfg, graph=graph, ts=ts, devices=8)
        single = SweepEngine(agg, cfg, graph=graph, ts=ts, devices=1)
        assert sharded.mesh is not None and single.mesh is None
        # explicit devices force sharding even on a single-chunk grid;
        # the default engine only shards multi-chunk grids (the thin
        # wrappers must not slow small default grids on multi-dev hosts)
        assert sharded._shard_for((1, 1024)) is True
        default = SweepEngine(agg, cfg, graph=graph, ts=ts)
        assert default._shard_for((1, 1024)) is False
        assert default._shard_for((2, 4096)) is True
        a, b = sharded.run(grid), single.run(grid)
        assert set(a) == set(b)
        for k in a:
            assert np.array_equal(a[k], b[k], equal_nan=True), k
        print("OK", len(a["sla_ok"]))
    """)
    out = _run(code, n_devices=8)
    assert "OK 1024" in out


def test_no_float64_under_x64():
    """The dtype-drift regression: with JAX_ENABLE_X64=1 every fused /
    timeline verdict (and the scan carry behind them) must still come out
    float32 / bool / int32 — a Python-scalar or numpy-scalar config value
    leaking into the kernels would promote to float64 here."""
    code = textwrap.dedent("""
        import numpy as np
        from repro.core.scenarios import FleetAggregates, scenario_grid
        from repro.core.service import synthesize_fleet
        from repro.core.sweep_engine import SweepEngine
        from repro.core.timeline_sim import (config_for_fleet, default_ts,
                                             simulate_timeline,
                                             sweep_timeline)
        from repro.graph import CallGraph
        fs = synthesize_fleet(scale=0.02, seed=1, as_arrays=True)
        fs.apply_ufa_target_classes()
        cfg = config_for_fleet(fs)
        ts = default_ts(7200.0, 60)
        grid = scenario_grid(evict_fraction=(1.0, 0.5))
        allowed = (np.float32, np.bool_, np.int32)
        eng = SweepEngine(FleetAggregates.from_fleet_state(fs), cfg,
                          graph=CallGraph.from_fleet_state(fs), ts=ts)
        res = eng.run(grid)
        for k, v in res.items():
            if k in grid:
                continue
            assert v.dtype in allowed, (k, v.dtype)
        for k, v in sweep_timeline(cfg, grid=grid, ts=ts).items():
            assert v.dtype in allowed, (k, v.dtype)
        sim = simulate_timeline(cfg, ts=ts)
        for k, v in sim.items():
            if k != "t":
                assert v.dtype in allowed, (k, v.dtype)
        print("OK")
    """)
    out = _run(code, x64=True)
    assert "OK" in out
